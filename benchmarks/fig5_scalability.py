"""Fig 5 — SDEaaS scalability study (paper Section 8.1).

(a) throughput vs parallelization degree [2..10 workers]
(b) throughput vs ingestion rate multiplier [1,2,5,10]
(c) throughput vs number of summarized streams [50,500,5000]
(d) federated communication: synopses vs raw streams, vs #sites

This container has ONE core, so (a)'s multi-worker aggregate is simulated
the way the paper's mechanism works: streams are hash-partitioned into P
shards, per-shard update time is measured, and aggregate throughput =
batch_tuples / max-shard-time (workers run concurrently on a real
cluster). (b), (c), (d) are direct measurements.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro import core
from repro.core import batched, federated
from repro.streams import StockStream
from .common import time_fn, csv_row

_KINDS = lambda: dict(
    cm=core.CountMin(eps=0.002, delta=0.01),      # paper's parameters
    hll=core.HyperLogLog(rse=0.03),
    dft=core.DFT(window=64, n_coeffs=8, threshold=0.9),
)


def _update_fns(kinds):
    fns = {}
    for name, kind in kinds.items():
        if name == "dft":
            fns[name] = jax.jit(
                lambda st, vals, msk, k=kind: batched.stacked_step(
                    k, st, vals, msk))
        else:
            fns[name] = jax.jit(
                lambda st, syn, it, v, m, k=kind: batched.stacked_add_batch(
                    k, st, syn, it, v, m))
    return fns


def run(batch_tuples: int = 262144, full: bool = False):
    rows = []
    kinds = _KINDS()
    fns = _update_fns(kinds)

    # ---------------- (a) parallelization degree ----------------
    n_streams = 1000 if not full else 5000
    stock = StockStream(n_streams=n_streams, seed=1)
    sids, vals = stock.level1_batch(batch_tuples)
    for p in [2, 4, 6, 8, 10]:
        shard_of = sids % p
        shard_times = []
        for w in range(p):
            sel = shard_of == w
            t = 0.0
            cm_states = batched.stacked_init(kinds["cm"], 64)
            syn = jnp.asarray((sids[sel] % 64).astype(np.int32))
            items = jnp.asarray(sids[sel].astype(np.uint32))
            v = jnp.asarray(vals[sel])
            m = jnp.ones(int(sel.sum()), bool)
            t += time_fn(fns["cm"], cm_states, syn, items, v, m)
            shard_times.append(t)
        thr = batch_tuples / max(shard_times)
        rows.append(csv_row(f"fig5a_parallelism_{p}", max(shard_times),
                            f"throughput={thr:,.0f}tuples/s"))

    # ---------------- (b) ingestion rate ----------------
    base_sids, base_vals = stock.level1_batch(batch_tuples // 16)
    cm_state = batched.stacked_init(kinds["cm"], 64)
    for rate in [1, 2, 5, 10]:
        sids_r = np.tile(base_sids, rate)
        vals_r = np.tile(base_vals, rate)
        syn = jnp.asarray((sids_r % 64).astype(np.int32))
        items = jnp.asarray(sids_r.astype(np.uint32))
        v = jnp.asarray(vals_r)
        m = jnp.ones(len(sids_r), bool)
        t = time_fn(fns["cm"], cm_state, syn, items, v, m)
        thr = len(sids_r) / t
        rows.append(csv_row(f"fig5b_rate_x{rate}", t,
                            f"throughput={thr:,.0f}tuples/s"))

    # ---------------- (c) number of streams ----------------
    for ns in ([50, 500, 5000] if full else [50, 500, 2000]):
        st = StockStream(n_streams=ns, seed=2)
        dft_states = batched.stacked_init(kinds["dft"], ns)
        ticks = st.ticks(1)[0]
        v = jnp.asarray(ticks)
        m = jnp.ones(ns, bool)
        t = time_fn(fns["dft"], dft_states, v, m)
        thr = ns / t
        rows.append(csv_row(f"fig5c_streams_{ns}", t,
                            f"throughput={thr:,.0f}streams-ticks/s"))

    # ---------------- (d) federated communication ----------------
    # Per 5-minute ad-hoc query (paper setting): each site ships either
    #  synopses — CM + HLL site states (mergeable) + per-stream DFT
    #  ESTIMATE payloads (coefficients + mean/sigma, not the ring buffer)
    #  raw     — every Level-1/2 tuple of the window (16B) for the same
    #  (count, cardinality, correlation) queries.
    per_site_streams = 250
    ticks_per_window = 300          # 1 tick/s x 5 min per stream
    dft_payload = (2 * kinds["dft"].n_coeffs + 2) * 4
    syn_site = (federated.communication_bytes(
        kinds["cm"], kinds["cm"].init(None))
        + federated.communication_bytes(
            kinds["hll"], kinds["hll"].init(None))
        + per_site_streams * dft_payload)
    raw_site = per_site_streams * ticks_per_window * 16
    for n_sites in [2, 4, 8, 16]:
        syn_total = syn_site * n_sites
        raw_total = raw_site * n_sites
        rows.append(csv_row(
            f"fig5d_federated_{n_sites}sites", 0.0,
            f"synopses={syn_total/1e6:.2f}MB raw={raw_total/1e6:.2f}MB "
            f"gain={raw_total/max(syn_total,1):.1f}x"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
