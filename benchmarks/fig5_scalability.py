"""Fig 5 — SDEaaS scalability study (paper Section 8.1).

(a) throughput vs parallelization degree [2..10 workers]
(b) throughput vs ingestion rate multiplier [1,2,5,10]
(c) throughput vs number of summarized streams [50,500,5000]
(d) federated communication vs #sites: collective site merges
    (`merge_over_axis` operand bytes) vs host-merge state shipping vs
    raw streams, plus a live mesh-collective query on multi-device hosts
(e) routing scale: ingest throughput at 1M distinct hashed 64-bit
    stream ids vs the 65k that used to be the dense-table cap
(f) pipelined vs eager blue path: ingest throughput with 1024
    continuous synopses — the bounded async queue (deferred emission)
    against per-batch inline sync. Acceptance: pipelined >= 1.2x.

(a) runs on the ENGINE's fused blue path (one jitted, donated-buffer
dispatch per kind per batch, routing + routed + data-source rows in one
program). This container has ONE core, so the multi-worker aggregate is
simulated the way the paper's mechanism works: streams are
hash-partitioned into P shards, each shard is one SDE engine, per-shard
ingest time is measured, and aggregate throughput = batch_tuples /
max-shard-time (workers run concurrently on a real cluster). On a
multi-device host the same measurement also runs with ONE engine whose
kind stacks are sharded over the `synopsis` mesh axis (true scale-out).
(b), (c), (d) are direct measurements.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro import core
from repro.core import batched, federated
from repro.service import SDE
from repro.streams import StockStream
from .common import time_fn, csv_row

_KINDS = lambda: dict(
    cm=core.CountMin(eps=0.002, delta=0.01),      # paper's parameters
    hll=core.HyperLogLog(rse=0.03),
    dft=core.DFT(window=64, n_coeffs=8, threshold=0.9),
)


def _ingest_sync(eng: SDE, sids, vals):
    """Ingest and hand the updated stack states to time_fn so its
    block_until_ready waits for the dispatched update, not just the
    host-side enqueue (ingest itself returns None)."""
    eng.ingest(sids, vals)
    return [s.state for s in eng.stacks.values()]


def _update_fns(kinds):
    fns = {}
    for name, kind in kinds.items():
        if name == "dft":
            fns[name] = jax.jit(
                lambda st, vals, msk, k=kind: batched.stacked_step(
                    k, st, vals, msk))
        else:
            fns[name] = jax.jit(
                lambda st, syn, it, v, m, k=kind: batched.stacked_add_batch(
                    k, st, syn, it, v, m))
    return fns


def run(batch_tuples: int = 262144, full: bool = False):
    rows = []
    kinds = _KINDS()
    fns = _update_fns(kinds)

    # ---------------- (a) parallelization degree ----------------
    # fused blue path: each worker is one SDE maintaining one routed CM
    # synopsis PER STREAM + 1 data-source HLL over the full stream-id
    # population (paper setting); ingest is ONE dispatch per kind.
    n_streams = 1000 if not full else 5000
    stock = StockStream(n_streams=n_streams, seed=1)
    sids, vals = stock.level1_batch(batch_tuples)
    for p in [2, 4, 6, 8, 10]:
        shard_of = sids % p
        shard_times = []
        for w in range(p):
            sel = shard_of == w
            eng = SDE()
            eng.handle({"type": "build", "request_id": "b",
                        "synopsis_id": "cm", "kind": "countmin",
                        "params": {"eps": 0.002, "delta": 0.01,
                                   "weighted": False},
                        "per_stream_of_source": True,
                        "n_streams": n_streams})
            eng.handle({"type": "build", "request_id": "b2",
                        "synopsis_id": "card", "kind": "hyperloglog",
                        "params": {"rse": 0.03}})
            w_sids = sids[sel].astype(np.uint32)
            w_vals = vals[sel].astype(np.float32)
            t = time_fn(lambda s=w_sids, v=w_vals, e=eng: _ingest_sync(e, s, v))
            shard_times.append(t)
        thr = batch_tuples / max(shard_times)
        rows.append(csv_row(f"fig5a_parallelism_{p}", max(shard_times),
                            f"throughput={thr:,.0f}tuples/s"))

    # ---- (a') synopsis-axis sharding: one engine, stacks partitioned
    # across devices (requires a multi-device host; skipped on 1 device)
    if len(jax.devices()) > 1:
        n_dev = len(jax.devices())
        mesh = jax.make_mesh((n_dev, 1), ("data", "model"))
        eng = SDE(mesh=mesh)
        eng.handle({"type": "build", "request_id": "b", "synopsis_id":
                    "cm", "kind": "countmin",
                    "params": {"eps": 0.002, "delta": 0.01,
                               "weighted": False},
                    "per_stream_of_source": True, "n_streams": n_streams})
        sh_sids = sids.astype(np.uint32)
        sh_vals = vals.astype(np.float32)
        t = time_fn(lambda: _ingest_sync(eng, sh_sids, sh_vals))
        rows.append(csv_row(
            f"fig5a_sharded_{n_dev}dev", t,
            f"throughput={batch_tuples / t:,.0f}tuples/s"))

    # ---------------- (b) ingestion rate ----------------
    base_sids, base_vals = stock.level1_batch(batch_tuples // 16)
    cm_state = batched.stacked_init(kinds["cm"], 64)
    for rate in [1, 2, 5, 10]:
        sids_r = np.tile(base_sids, rate)
        vals_r = np.tile(base_vals, rate)
        syn = jnp.asarray((sids_r % 64).astype(np.int32))
        items = jnp.asarray(sids_r.astype(np.uint32))
        v = jnp.asarray(vals_r)
        m = jnp.ones(len(sids_r), bool)
        t = time_fn(fns["cm"], cm_state, syn, items, v, m)
        thr = len(sids_r) / t
        rows.append(csv_row(f"fig5b_rate_x{rate}", t,
                            f"throughput={thr:,.0f}tuples/s"))

    # ---------------- (c) number of streams ----------------
    for ns in ([50, 500, 5000] if full else [50, 500, 2000]):
        st = StockStream(n_streams=ns, seed=2)
        dft_states = batched.stacked_init(kinds["dft"], ns)
        ticks = st.ticks(1)[0]
        v = jnp.asarray(ticks)
        m = jnp.ones(ns, bool)
        t = time_fn(fns["dft"], dft_states, v, m)
        thr = ns / t
        rows.append(csv_row(f"fig5c_streams_{ns}", t,
                            f"throughput={thr:,.0f}streams-ticks/s"))

    # ---------------- (e) routing scale: hashed 64-bit stream ids ----
    # vertical scalability past the old 65536-slot dense route table:
    # per-stream synopses over 65k vs 1M DISTINCT hashed 63-bit ids,
    # ingest still one fused dispatch (probe included). Acceptance:
    # 1M-stream throughput within 2x of the 65k baseline.
    thr_by_ns = {}
    for ns in [1 << 16, 1 << 20]:
        rng = np.random.RandomState(7)
        sid_pop = np.unique(rng.randint(0, 2**63 - 1, ns, dtype=np.int64))
        eng = SDE()
        eng.handle({"type": "build", "request_id": "b", "synopsis_id":
                    "cm", "kind": "countmin",
                    "params": {"eps": 0.5, "delta": 0.5,
                               "weighted": False},
                    "per_stream_of_source": True,
                    "stream_ids": [int(s) for s in sid_pop]})
        stack = next(iter(eng.stacks.values()))
        e_sids = sid_pop[rng.randint(0, len(sid_pop), batch_tuples)]
        e_vals = np.ones(batch_tuples, np.float32)
        t = time_fn(lambda s=e_sids, v=e_vals, e=eng: _ingest_sync(e, s, v))
        thr_by_ns[ns] = batch_tuples / t
        rows.append(csv_row(
            f"fig5e_hashed_routing_{ns}streams", t,
            f"throughput={batch_tuples / t:,.0f}tuples/s "
            f"table={stack.table.size}slots "
            f"probe<={stack.n_probe}"))
    rows.append(csv_row(
        "fig5e_1M_vs_65k_slowdown", 0.0,
        f"ratio={thr_by_ns[1 << 16] / thr_by_ns[1 << 20]:.2f}x "
        "(acceptance <= 2x)"))

    # ---------------- (f) pipelined vs eager blue path ----------------
    # 1024 continuous per-stream synopses: the eager engine pays a
    # device->host sync per batch inside continuous emission, idling the
    # host while the device finishes and the device while the host preps
    # the next batch. The pipelined engine (bounded depth-2 queue) keeps
    # both busy; a final flush() + block makes the comparison fair.
    import time as _time
    n_syn = 1024
    n_batches = 16
    pipe_stock = StockStream(n_streams=n_syn, seed=3)
    pipe_batches = [pipe_stock.level1_batch(16384) for _ in range(n_batches)]
    pipe_build = {"type": "build", "request_id": "b", "synopsis_id": "cm",
                  "kind": "countmin",
                  "params": {"eps": 0.01, "delta": 0.05,
                             "weighted": False},
                  "per_stream_of_source": True, "n_streams": n_syn,
                  "continuous": True}
    thr_by_mode = {}
    for mode in ("eager", "pipelined"):
        def run_once(mode=mode):
            eng = SDE(pipelined=(mode == "pipelined"))
            assert eng.handle(pipe_build).ok
            eng.ingest(*pipe_batches[0])     # warmup: trace + compile
            eng.flush()
            t0 = _time.perf_counter()
            for sids, vals in pipe_batches:
                eng.ingest(sids, vals)
            eng.flush()
            jax.block_until_ready([s.state for s in eng.stacks.values()])
            return _time.perf_counter() - t0
        t = float(np.median([run_once() for _ in range(3)]))
        thr_by_mode[mode] = n_batches * len(pipe_batches[0][0]) / t
        rows.append(csv_row(
            f"fig5f_{mode}_{n_syn}syn", t,
            f"throughput={thr_by_mode[mode]:,.0f}tuples/s"))
    rows.append(csv_row(
        "fig5f_pipelined_speedup", 0.0,
        f"speedup={thr_by_mode['pipelined'] / thr_by_mode['eager']:.2f}x "
        "(acceptance >= 1.2x)"))

    # ---------------- (g) fused vs probe-then-scatter Pallas path ------
    # 1024 routed synopses on backend="pallas", same registry kernel both
    # ways; SDE_FUSED_PROBE flips whether the routing probe runs INSIDE
    # the Pallas grid (one HBM pass over state+table per batch) or as a
    # separate jnp probe ahead of the delta-buffer kernel. Wall clock here
    # is interpret-mode off-TPU (both modes pay the interpreter), so the
    # measured ratio is indicative; the HBM-byte acceptance (>= 1.2x
    # modeled gain at 1024 synopses) is gated by `roofline.py --check`.
    import os as _os
    n_syn_g = 1024
    g_stock = StockStream(n_streams=n_syn_g, seed=4)
    g_batches = [g_stock.level1_batch(4096) for _ in range(4)]
    g_build = {"type": "build", "request_id": "b", "synopsis_id": "cm",
               "kind": "countmin",
               "params": {"eps": 0.2, "delta": 0.3, "weighted": False},
               "per_stream_of_source": True, "n_streams": n_syn_g}
    t_by_fuse = {}
    for fuse in ("0", "1"):
        _os.environ["SDE_FUSED_PROBE"] = fuse
        try:
            def run_once():
                eng = SDE(backend="pallas")
                assert eng.handle(g_build).ok
                eng.ingest(*g_batches[0])    # warmup: trace + compile
                jax.block_until_ready(
                    [s.state for s in eng.stacks.values()])
                t0 = _time.perf_counter()
                for sids, vals in g_batches:
                    eng.ingest(sids, vals)
                jax.block_until_ready(
                    [s.state for s in eng.stacks.values()])
                return _time.perf_counter() - t0
            t = float(np.median([run_once() for _ in range(2)]))
        finally:
            _os.environ.pop("SDE_FUSED_PROBE", None)
        t_by_fuse[fuse] = t
        label = "fused" if fuse == "1" else "probe_then_scatter"
        thr = len(g_batches) * len(g_batches[0][0]) / t
        rows.append(csv_row(f"fig5g_{label}_{n_syn_g}syn", t,
                            f"throughput={thr:,.0f}tuples/s"))
    rows.append(csv_row(
        "fig5g_fused_speedup", 0.0,
        f"speedup={t_by_fuse['0'] / t_by_fuse['1']:.2f}x wall "
        "(interpret-mode; HBM-byte gate: roofline.py --check)"))

    # ---------------- (d) federated communication ----------------
    # Per 5-minute ad-hoc query (paper setting), three ways of answering
    # the same (count, cardinality, correlation) queries globally:
    #  collective — the mesh path: `federated.merge_over_axis` runs the
    #  site merge as psum/pmax/selection collectives, which combine
    #  in-network; operand bytes via `collective_operand_bytes`.
    #  host-merge — the legacy path: every site ships its full synopsis
    #  state to the responsible host (`Federation.query_bytes`).
    #  raw        — every Level-1/2 tuple of the window (16B).
    per_site_streams = 250
    ticks_per_window = 300          # 1 tick/s x 5 min per stream
    cm_st = kinds["cm"].init(None)
    hll_st = kinds["hll"].init(None)
    dft_st = kinds["dft"].init(None)
    site_state = (federated.communication_bytes(kinds["cm"], cm_st)
                  + federated.communication_bytes(kinds["hll"], hll_st)
                  + per_site_streams * federated.communication_bytes(
                      kinds["dft"], dft_st))
    raw_site = per_site_streams * ticks_per_window * 16
    for n_sites in [2, 4, 8, 16]:
        coll_total = (
            federated.collective_operand_bytes(kinds["cm"], cm_st, n_sites)
            + federated.collective_operand_bytes(kinds["hll"], hll_st,
                                                 n_sites)
            + per_site_streams * federated.collective_operand_bytes(
                kinds["dft"], dft_st, n_sites))
        host_total = site_state * n_sites
        raw_total = raw_site * n_sites
        assert coll_total <= host_total     # acceptance: never worse
        rows.append(csv_row(
            f"fig5d_federated_{n_sites}sites", 0.0,
            f"collective={coll_total/1e6:.2f}MB "
            f"host={host_total/1e6:.2f}MB raw={raw_total/1e6:.2f}MB "
            f"gain_vs_raw={raw_total/max(coll_total,1):.0f}x "
            f"gain_vs_host={host_total/max(coll_total,1):.1f}x"))

    # live collective measurement when the host has the devices for it:
    # a mesh federation answers one federated query as ONE compiled
    # collective program; the response reports the fig5d byte metrics
    if len(jax.devices()) >= 2:
        from repro.launch.mesh import try_federation_mesh
        from repro.service import Federation
        ns = min(4, len(jax.devices()))
        fed = Federation([f"s{i}" for i in range(ns)],
                         mesh=try_federation_mesh(ns))
        fed.broadcast({"type": "build", "request_id": "b",
                       "synopsis_id": "card", "kind": "hyperloglog",
                       "params": {"rse": 0.03}, "federated": True,
                       "responsible_site": "s0"})
        rng = np.random.RandomState(5)
        for i in range(ns):
            sids = rng.randint(i << 20, (i + 1) << 20, 65536)
            fed.sdes[f"s{i}"].ingest(sids.astype(np.int64),
                                     np.ones(65536, np.float32))
        req = {"type": "federated_query", "request_id": "q",
               "synopsis_id": "card", "responsible_site": "s0"}
        last = {}

        def timed_query():
            last["resp"] = fed.handle(req)
            return np.asarray(last["resp"].value)

        t = time_fn(timed_query)             # time_fn warms up first
        resp = last["resp"]
        rows.append(csv_row(
            f"fig5d_live_collective_{ns}sites", t,
            f"path={resp.params['path']} est={float(resp.value):,.0f} "
            f"collective={resp.params['collective_operand_bytes']}B "
            f"host={resp.params['host_merge_bytes']}B"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
