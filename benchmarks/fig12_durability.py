"""Fig 12 — checkpointing off the hot path: dirty-row deltas vs full.

The durability question: what does it COST to make the engine
crash-safe? 1024 per-stream CountMins ingest skewed traffic (each
8-batch interval touches a rotating ~19% window of the streams — the
hot set real workloads have) under three regimes:

  * none — no checkpointing: the throughput ceiling.
  * incr — ``SDE.snapshot(incremental=True, async_=True)`` every 8
    batches: a dirty-row delta chained on one full base. No pipeline
    fence (the bounded pull syncs only dirty slices), npz write + fsync
    on a background thread — only the host copy of the touched rows
    rides the hot path.
  * full — the pre-delta baseline: synchronous full snapshots at the
    same cadence. Every one fences the pipeline, pulls the whole stack
    and blocks on the write.

``--check`` gates CI on the three acceptance claims: incr keeps
>= 0.9x of the no-checkpoint throughput, full drops below 0.7x, and a
delta with <= 20% dirty rows ships <= 0.25x the bytes of a full
snapshot (measured by the CHECKPOINT_BYTES probe, which counts payload
bytes whether or not the write already retired).
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.kernels import ops as kops
from repro.service import SDE
from .common import csv_row

_N_SYNOPSES = 1024
_BATCH = 49152                 # tuples per ingest batch
_INTERVAL = 8                  # batches between snapshots
_WINDOW = 24                   # streams hot per interval (~2% dirty)
# wide, shallow CM rows: ingest cost scales with depth x batch, full
# snapshot cost with width x capacity — the realistic regime where the
# state dwarfs what one interval touches
_CM = {"eps": 0.001, "delta": 0.01, "weighted": False}


def _build_engine() -> SDE:
    eng = SDE(pipelined=True)
    r = eng.handle({"type": "build", "request_id": "b",
                    "synopsis_id": "cm", "kind": "countmin",
                    "params": _CM, "per_stream_of_source": True,
                    "n_streams": _N_SYNOPSES})
    assert r.ok, r.error
    return eng


def _interval_traffic(rng, offset):
    """One snapshot interval's batches, all drawn from a _WINDOW-wide
    stream window at ``offset`` — the rotating hot set."""
    out = []
    for _ in range(_INTERVAL):
        sids = ((offset + rng.randint(0, _WINDOW, _BATCH))
                % _N_SYNOPSES).astype(np.int64)
        out.append((sids, rng.uniform(0.5, 2.0, _BATCH)
                    .astype(np.float32)))
    return out


def _timed_run(eng, traffic, snap) -> float:
    """Wall seconds to ingest ``traffic`` (a list of intervals), calling
    ``snap()`` after each interval, ending on a drained pipeline."""
    t0 = time.perf_counter()
    for interval in traffic:
        for sids, vals in interval:
            eng.ingest(sids, vals)
        snap()
    eng.flush()
    return time.perf_counter() - t0


def run(full: bool = False, check: bool = False):
    rng = np.random.RandomState(0)
    intervals = 6 if full else 3
    repeats = 5
    step = dict(n=0)

    def next_step() -> int:
        step["n"] += 1
        return step["n"]

    modes = ("none", "incr", "full")
    engines = {}
    snaps = {}
    times = {m: [] for m in modes}
    # snapshots land on tmpfs when the host has one: the figure measures
    # the ENGINE's checkpoint overhead (fence, host pull, serialization),
    # and routing it through a spinning disk would gate CI on that
    # machine's fsync latency instead (the durability tests exercise
    # real files; this benchmark isolates the hot-path cost)
    base = "/dev/shm" if os.path.isdir("/dev/shm") else None
    with tempfile.TemporaryDirectory(dir=base) as tmp:
        for mode in modes:
            eng = _build_engine()
            engines[mode] = eng
            ck = f"{tmp}/{mode}"
            # warmup: compile the fused paths before the clock starts
            for sids, vals in _interval_traffic(rng, 0):
                eng.ingest(sids, vals)
            eng.flush()
            if mode != "none":
                eng.snapshot(ck, 0, incremental=False)
            if mode == "incr":
                # one untimed delta compiles the dirty-row gather and
                # leaves the chain the timed deltas extend
                for sids, vals in _interval_traffic(rng, _WINDOW):
                    eng.ingest(sids, vals)
                eng.snapshot(ck, next_step(), incremental=True,
                             async_=True, rebase_every=1_000_000)
            if mode == "none":
                snaps[mode] = lambda: None
            elif mode == "incr":
                # rebase_every sys-large: the timed window measures the
                # steady delta cadence, not a rebase spike
                snaps[mode] = lambda e=eng, c=ck: e.snapshot(
                    c, next_step(), incremental=True, async_=True,
                    rebase_every=1_000_000)
            else:
                snaps[mode] = lambda e=eng, c=ck: e.snapshot(
                    c, next_step(), incremental=False, async_=False)
        # repeats interleave round-robin across regimes: the process
        # slows slightly over its lifetime (allocator growth), and a
        # sequential schedule would bill all of that drift to whichever
        # regime ran last
        for rep in range(repeats):
            for mode in modes:
                traffic = [_interval_traffic(rng, (rep * intervals + i)
                                             * _WINDOW)
                           for i in range(intervals)]
                times[mode].append(
                    _timed_run(engines[mode], traffic, snaps[mode]))
        for eng in engines.values():
            eng.wait_for_snapshot()
        # best-of-N: the min is the interference-free estimate of each
        # regime's intrinsic cost (snapshot work is in-loop, so it stays
        # in the incr/full minima); medians of noisy wall times would
        # make the ratio gates flaky
        regimes = {m: float(np.min(ts)) for m, ts in times.items()}

        # bytes claim: one full vs one delta with <= 20% dirty rows,
        # measured through the CHECKPOINT_BYTES probe
        eng = engines["incr"]
        ck = f"{tmp}/incr"
        b0 = kops.CHECKPOINT_BYTES[eng.site]
        eng.snapshot(ck, next_step(), incremental=False)
        bytes_full = kops.CHECKPOINT_BYTES[eng.site] - b0
        for sids, vals in _interval_traffic(rng, 0):
            eng.ingest(sids, vals)
        b0 = kops.CHECKPOINT_BYTES[eng.site]
        eng.snapshot(ck, next_step(), incremental=True)
        bytes_delta = kops.CHECKPOINT_BYTES[eng.site] - b0
        dirty = int(kops.DIRTY_ROWS[eng.site])
        for e in engines.values():
            e.close()

    tuples = intervals * _INTERVAL * _BATCH
    thr = {m: tuples / t for m, t in regimes.items()}
    r_incr = thr["incr"] / thr["none"]
    r_full = thr["full"] / thr["none"]
    r_bytes = bytes_delta / bytes_full
    rows = [csv_row(
        f"fig12_durability_k{_N_SYNOPSES}_i{_INTERVAL}",
        regimes["incr"] / (intervals * _INTERVAL),
        f"thr_none={thr['none']:,.0f}t/s thr_incr={thr['incr']:,.0f}t/s "
        f"thr_full={thr['full']:,.0f}t/s incr_vs_none={r_incr:.3f}x "
        f"full_vs_none={r_full:.3f}x delta_bytes={bytes_delta} "
        f"full_bytes={bytes_full} bytes_ratio={r_bytes:.3f}x "
        f"dirty_rows={dirty}")]
    if check:
        assert r_incr >= 0.9, \
            f"incremental async checkpointing kept only {r_incr:.3f}x " \
            "of no-checkpoint throughput, acceptance floor is 0.9x"
        assert r_full < 0.7, \
            f"sync full snapshots kept {r_full:.3f}x — the old path " \
            "must visibly stall (< 0.7x) or the figure measures nothing"
        assert dirty <= 0.20 * _N_SYNOPSES + 1, \
            f"delta dirtied {dirty} rows; the bytes claim needs <= 20%"
        assert r_bytes <= 0.25, \
            f"delta shipped {r_bytes:.3f}x of full-snapshot bytes at " \
            f"{dirty}/{_N_SYNOPSES} dirty rows, acceptance is 0.25x"
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="assert the acceptance gates (CI mode)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    for row in run(full=args.full, check=args.check):
        print(row)
