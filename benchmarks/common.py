"""Shared benchmark utilities."""
from __future__ import annotations

import time
from typing import Callable, Tuple

import jax
import numpy as np


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds per call (blocks on jax async dispatch)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def csv_row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds*1e6:.1f},{derived}"
