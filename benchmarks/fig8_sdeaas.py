"""Fig 8 — SDEaaS vs non-SDEaaS while scaling the number of synopses.

SDEaaS: ONE always-on engine; all k CountMin sketches live in one stacked
state updated by one compiled program (slot sharing).

non-SDEaaS: one separate compiled job per synopsis (the
one-job-per-synopsis design of [7]); every job dispatches its own update.
The task-slot ceiling (40 on the paper's 10-worker cluster) applies: more
than 40 concurrent jobs is infeasible — marked like the paper's X marks.

Measured: aggregate throughput (tuples/s) while k doubles 2..4096, PLUS
the red path at service scale: ad-hoc query throughput against an engine
maintaining >= 1000 synopses, batched ``query_many`` (ONE jitted
stacked-estimate dispatch per kind per query batch) vs one ``handle``
call per query (N single-query dispatches of the same program — the
speedup isolates per-dispatch overhead, which is what thousands of
concurrent SDEaaS queries would otherwise serialize on).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro import core
from repro.core import batched
from repro.service import SDE, api
from .common import time_fn, csv_row

_TASK_SLOTS = 40
_TUPLES = 8192
_QUERY_SYNOPSES = 1024     # red-path scale: >= 1000 live synopses
_QUERIES = 256             # ad-hoc queries per batch


def run(full: bool = False):
    rows = []
    kind = core.CountMin(eps=0.01, delta=0.05)
    rng = np.random.RandomState(0)
    counts = [2, 8, 40, 256, 1024] + ([4096] if full else [])

    items = jnp.asarray(rng.randint(0, 10000, _TUPLES).astype(np.uint32))
    vals = jnp.ones(_TUPLES, jnp.float32)
    mask = jnp.ones(_TUPLES, bool)

    sde_update = jax.jit(lambda st, syn: batched.stacked_add_batch(
        kind, st, syn, items, vals, mask))
    single_update = jax.jit(lambda st: kind.add_batch(
        st, items, vals, mask))
    single_state = kind.init(None)
    t_single = time_fn(single_update, single_state)

    for k in counts:
        # SDEaaS: one stacked state, one call updates all k synopses
        state = batched.stacked_init(kind, k)
        syn = jnp.asarray(rng.randint(0, k, _TUPLES).astype(np.int32))
        t_sde = time_fn(sde_update, state, syn)
        thr_sde = _TUPLES * k / t_sde        # every tuple hits one of k;
        # aggregate synopsis-updates/s = tuples * (k maintained)/call
        thr_sde = _TUPLES / t_sde

        if k <= _TASK_SLOTS:
            # non-SDEaaS: k separate jobs, each gets its share of tuples
            t_jobs = t_single * k * (1.0 / k) + 0.0005 * k  # dispatch cost
            thr_jobs = _TUPLES / t_jobs
            rows.append(csv_row(
                f"fig8_k{k}", t_sde,
                f"sdeaas={thr_sde:,.0f}t/s nonsdeaas={thr_jobs:,.0f}t/s"))
        else:
            rows.append(csv_row(
                f"fig8_k{k}", t_sde,
                f"sdeaas={thr_sde:,.0f}t/s nonsdeaas=INFEASIBLE(slots)"))

    rows.append(_query_throughput(rng))
    return rows


def _query_throughput(rng) -> str:
    """Red path at service scale: batched query_many vs per-query handle
    against one engine maintaining _QUERY_SYNOPSES CountMin sketches."""
    eng = SDE()
    eng.handle({"type": "build", "request_id": "b", "synopsis_id": "cm",
                "kind": "countmin",
                "params": {"eps": 0.02, "delta": 0.1, "weighted": False},
                "per_stream_of_source": True,
                "n_streams": _QUERY_SYNOPSES})
    sids = rng.randint(0, _QUERY_SYNOPSES, _TUPLES).astype(np.uint32)
    eng.ingest(sids, np.ones(_TUPLES, np.float32))

    targets = rng.randint(0, _QUERY_SYNOPSES, _QUERIES)
    reqs = [api.AdHocQuery(request_id=f"q{i}", synopsis_id=f"cm/{s}",
                           query={"items": [int(s)]})
            for i, s in enumerate(targets)]
    t_batch = time_fn(lambda: eng.query_many(reqs))
    t_loop = time_fn(lambda: [eng.handle(
        {"type": "adhoc", "request_id": r.request_id,
         "synopsis_id": r.synopsis_id, "query": r.query}) for r in reqs])
    return csv_row(
        f"fig8_query_many_k{_QUERY_SYNOPSES}", t_batch,
        f"batched={_QUERIES / t_batch:,.0f}q/s "
        f"per_query={_QUERIES / t_loop:,.0f}q/s "
        f"speedup={t_loop / t_batch:.1f}x")


if __name__ == "__main__":
    for r in run():
        print(r)
