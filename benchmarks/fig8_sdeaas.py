"""Fig 8 — SDEaaS vs non-SDEaaS while scaling the number of synopses.

SDEaaS: ONE always-on engine; all k CountMin sketches live in one stacked
state updated by one compiled program (slot sharing).

non-SDEaaS: one separate compiled job per synopsis (the
one-job-per-synopsis design of [7]); every job dispatches its own update.
The task-slot ceiling (40 on the paper's 10-worker cluster) applies: more
than 40 concurrent jobs is infeasible — marked like the paper's X marks.

Measured: aggregate throughput (tuples/s) while k doubles 2..4096.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro import core
from repro.core import batched
from .common import time_fn, csv_row

_TASK_SLOTS = 40
_TUPLES = 8192


def run(full: bool = False):
    rows = []
    kind = core.CountMin(eps=0.01, delta=0.05)
    rng = np.random.RandomState(0)
    counts = [2, 8, 40, 256, 1024] + ([4096] if full else [])

    items = jnp.asarray(rng.randint(0, 10000, _TUPLES).astype(np.uint32))
    vals = jnp.ones(_TUPLES, jnp.float32)
    mask = jnp.ones(_TUPLES, bool)

    sde_update = jax.jit(lambda st, syn: batched.stacked_add_batch(
        kind, st, syn, items, vals, mask))
    single_update = jax.jit(lambda st: kind.add_batch(
        st, items, vals, mask))
    single_state = kind.init(None)
    t_single = time_fn(single_update, single_state)

    for k in counts:
        # SDEaaS: one stacked state, one call updates all k synopses
        state = batched.stacked_init(kind, k)
        syn = jnp.asarray(rng.randint(0, k, _TUPLES).astype(np.int32))
        t_sde = time_fn(sde_update, state, syn)
        thr_sde = _TUPLES * k / t_sde        # every tuple hits one of k;
        # aggregate synopsis-updates/s = tuples * (k maintained)/call
        thr_sde = _TUPLES / t_sde

        if k <= _TASK_SLOTS:
            # non-SDEaaS: k separate jobs, each gets its share of tuples
            t_jobs = t_single * k * (1.0 / k) + 0.0005 * k  # dispatch cost
            thr_jobs = _TUPLES / t_jobs
            rows.append(csv_row(
                f"fig8_k{k}", t_sde,
                f"sdeaas={thr_sde:,.0f}t/s nonsdeaas={thr_jobs:,.0f}t/s"))
        else:
            rows.append(csv_row(
                f"fig8_k{k}", t_sde,
                f"sdeaas={thr_sde:,.0f}t/s nonsdeaas=INFEASIBLE(slots)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
