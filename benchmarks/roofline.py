"""Aggregate dry-run records into the roofline table (EXPERIMENTS.md
§Roofline reads this output), and gate the fused Pallas update kernels.

  PYTHONPATH=src python -m benchmarks.roofline --dir benchmarks/out
  PYTHONPATH=src python -m benchmarks.roofline --check [--shape full]

``--check`` runs every registered update kernel (kernels.ops registry)
fused and unfused in interpret mode, proves the Pallas states are
byte-identical to the XLA reference path, and models the HBM bytes each
form moves per batch:

  fused     = R+W state (aliased) + R table mirror + R batch
  unfused   = probe pass (R table + R sids, W rows) + kernel pass
              (R rows + R batch + state traffic) — where the CM/AMS
              delta-buffer form pays 4 state-sized passes (W delta,
              R delta, R counts, W out) against the fused form's 2.

Per-kernel thresholds: the delta-buffer kinds (countmin_scatter,
ams_scatter) must model >= 1.2x; the already-aliased single-pass kinds
(hll_max, bloom_bitset, fm_bitmap, rhp_project) must not regress
(>= 1.0x) and are gated on byte equality. Records land next to the
dry-run records with ``mesh_name="cpu-interpret"`` so ``table()`` can
filter them the same way.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import time
from typing import Dict, List

# kernel-gate cases: (registry kernel, api kind, params, min modeled gain)
_GATE = [
    ("countmin_scatter", "countmin",
     {"eps": 0.1, "delta": 0.1, "weighted": False}, 1.2),
    ("ams_scatter", "ams", {"eps": 0.1, "delta": 0.1}, 1.2),
    ("hll_max", "hyperloglog", {"rse": 0.1}, 1.0),
    ("bloom_bitset", "bloom", {"n_elements": 64, "fpr": 0.05}, 1.0),
    ("fm_bitmap", "fm", {}, 1.0),
    ("rhp_project", "rhp", {"n_bits": 64}, 1.0),
]
_DELTA_KINDS = ("countmin_scatter", "ams_scatter")
# per-tuple batch bytes: hashed sid halves (2 x u32) + value f32 + mask
_TUPLE_B = 13
# per-tuple probe-path extra: R sids in probe (8) + W rows (4) + R rows
# in the scatter kernel (4) — the bytes fusion deletes
_PROBE_B = 16


def load(dir_: str) -> List[Dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        # legacy skip/error records stuffed the mesh NAME into ``mesh``
        # (ok records hold a dict there) — normalize so every record
        # carries ``mesh_name`` and table() can filter on one field
        if "mesh_name" not in r and isinstance(r.get("mesh"), str):
            r["mesh_name"] = r["mesh"]
        out.append(r)
    return out


def _fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/2**30:.2f}"


def table(records: List[Dict], mesh: str = "pod16x16") -> str:
    recs = [r for r in records
            if "arch" in r and r.get("mesh_name") == mesh]
    lines = [
        "| arch | shape | dom | compute_s | memory_s | coll_s | "
        "useful/HLO | roofline frac | HBM GiB/dev | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"])):
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — |"
                         f" — | — | — | {r['skipped'][:40]} |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | ERR | | | | | |"
                         f" | {r['error'].splitlines()[-1][:60]} |")
            continue
        ro = r["roofline"]
        pd = r["per_device"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {ro['dominant'][:-2]} "
            f"| {ro['compute_s']:.3f} | {ro['memory_s']:.3f} "
            f"| {ro['collective_s']:.3f} | {ro['hlo_useful_ratio']:.2f} "
            f"| {ro['roofline_fraction']:.3f} "
            f"| {_fmt_bytes(pd.get('peak_bytes'))} | |")
    lines.append(f"\n{len(recs)} dry-run record(s) on mesh `{mesh}`")
    return "\n".join(lines)


def summary(records: List[Dict]) -> Dict:
    ok = [r for r in records if "roofline" in r]
    skip = [r for r in records if "skipped" in r]
    err = [r for r in records if "error" in r]
    worst = sorted(ok, key=lambda r: r["roofline"]["roofline_fraction"])
    coll = sorted(ok, key=lambda r: -r["roofline"]["collective_s"])
    return dict(
        n_ok=len(ok), n_skip=len(skip), n_err=len(err),
        worst_fraction=[(r["arch"], r["shape"], r.get("mesh_name"),
                         round(r["roofline"]["roofline_fraction"], 4))
                        for r in worst[:5]],
        most_collective=[(r["arch"], r["shape"], r.get("mesh_name"),
                          round(r["roofline"]["collective_s"], 3))
                         for r in coll[:5]],
    )


# ---------------------------------------------------------------------------
# fused-kernel acceptance gate (--check)
# ---------------------------------------------------------------------------
def kernel_records(shape: str = "gate") -> List[Dict]:
    """One record per registry kernel: byte equality of the Pallas
    states (fused AND unfused) against the XLA reference engine, plus
    the modeled HBM traffic of each form at the gate shape."""
    import numpy as np
    import jax
    from repro.service import SDE

    # gate shapes keep the interpret-mode grids tiny (CI runs this on
    # CPU); --shape full scales to the 1024-synopsis acceptance point,
    # where the modeled gain is state-dominated
    full = shape == "full"
    n_syn = 1024 if full else 16
    t_tuples = 4096 if full else 512

    rng = np.random.RandomState(11)
    pop = np.unique(rng.randint(0, 2**62, size=4 * n_syn,
                                dtype=np.int64))[:n_syn]
    sids = pop[rng.randint(0, n_syn, t_tuples)]
    # sprinkle ids outside the routed population — the probe's miss
    # (-1) path must round-trip through every kernel form too
    sids[::max(t_tuples // 16, 1)] = int(pop.max()) + 1
    vals = rng.randint(1, 5, t_tuples).astype(np.float32)

    records = []
    for kernel, kind_name, params, min_gain in _GATE:
        states, wall, s_bytes, tbl_bytes = {}, None, None, None
        for backend, fuse in (("xla", "1"), ("pallas", "0"),
                              ("pallas", "1")):
            os.environ["SDE_FUSED_PROBE"] = fuse
            try:
                eng = SDE(backend=backend)
                r = eng.handle({
                    "type": "build", "request_id": "b",
                    "synopsis_id": "g", "kind": kind_name,
                    "params": params, "per_stream_of_source": True,
                    "stream_ids": [int(s) for s in pop]})
                assert r.ok, r.error
                t0 = time.perf_counter()
                eng.ingest(sids, vals)
                jax.block_until_ready(
                    [s.state for s in eng.stacks.values()])
                dt = time.perf_counter() - t0
            finally:
                os.environ.pop("SDE_FUSED_PROBE", None)
            stack = next(iter(eng.stacks.values()))
            states[(backend, fuse)] = np.asarray(stack.state)
            if (backend, fuse) == ("pallas", "1"):
                wall = dt
                s_bytes = states[(backend, fuse)].nbytes
                tbl_bytes = sum(np.asarray(a).nbytes
                                for a in stack.device_table())
            eng.close()
        byte_equal = (
            np.array_equal(states[("xla", "1")], states[("pallas", "1")])
            and np.array_equal(states[("xla", "1")],
                               states[("pallas", "0")]))
        state_passes = 4 if kernel in _DELTA_KINDS else 2
        fused = 2 * s_bytes + tbl_bytes + _TUPLE_B * t_tuples
        unfused = (state_passes * s_bytes + tbl_bytes
                   + (_TUPLE_B + _PROBE_B) * t_tuples)
        records.append(dict(
            kernel=kernel, kind=kind_name, shape=shape,
            n_synopses=n_syn, batch_tuples=t_tuples,
            state_bytes=s_bytes, table_bytes=tbl_bytes,
            fused_hbm_bytes=fused, unfused_hbm_bytes=unfused,
            modeled_gain=round(unfused / fused, 3), min_gain=min_gain,
            byte_equal=bool(byte_equal),
            wall_seconds_fused=round(wall, 4),
            backend="pallas", interpret=True,
            mesh_name="cpu-interpret"))
    return records


def check(records: List[Dict], out_dir: str = None) -> List[str]:
    """Print the gate table, persist the records, return failures."""
    failures = []
    lines = [
        "| kernel | n_syn | batch | modeled gain | min | bytes | ok |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in records:
        ok = r["byte_equal"] and r["modeled_gain"] >= r["min_gain"]
        if not r["byte_equal"]:
            failures.append(f"{r['kernel']}: pallas != xla state bytes")
        elif not ok:
            failures.append(
                f"{r['kernel']}: modeled gain {r['modeled_gain']}x "
                f"< required {r['min_gain']}x")
        lines.append(
            f"| {r['kernel']} | {r['n_synopses']} | {r['batch_tuples']} "
            f"| {r['modeled_gain']:.2f}x | {r['min_gain']:.1f}x "
            f"| {'equal' if r['byte_equal'] else 'DIFFER'} "
            f"| {'PASS' if ok else 'FAIL'} |")
    print("\n".join(lines))
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        for r in records:
            path = os.path.join(
                out_dir, f"kernel__{r['kernel']}__{r['shape']}.json")
            with open(path, "w") as f:
                json.dump(r, f, indent=1)
        print(f"\n{len(records)} kernel record(s) -> {out_dir}")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="benchmarks/out")
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--check", action="store_true",
                    help="run the fused-kernel acceptance gate")
    ap.add_argument("--shape", default="gate", choices=["gate", "full"],
                    help="--check problem size (full = 1024 synopses)")
    args = ap.parse_args()

    if args.check:
        failures = check(kernel_records(args.shape), out_dir=args.dir)
        if failures:
            print("\nFAIL:\n  " + "\n  ".join(failures))
            sys.exit(1)
        print("\nall update kernels pass the roofline gate")
        return

    records = load(args.dir)
    print(table(records, args.mesh))
    print()
    print(json.dumps(summary(records), indent=1))


if __name__ == "__main__":
    main()
