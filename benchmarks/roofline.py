"""Aggregate dry-run records into the roofline table (EXPERIMENTS.md
§Roofline reads this output).

  PYTHONPATH=src python -m benchmarks.roofline --dir benchmarks/out
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List


def load(dir_: str) -> List[Dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def _fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/2**30:.2f}"


def table(records: List[Dict], mesh: str = "pod16x16") -> str:
    lines = [
        "| arch | shape | dom | compute_s | memory_s | coll_s | "
        "useful/HLO | roofline frac | HBM GiB/dev | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(records, key=lambda x: (x["arch"], x["shape"])):
        if r.get("mesh_name", r.get("mesh")) not in (mesh,) and \
           not (isinstance(r.get("mesh"), str) and r["mesh"] == mesh):
            continue
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — |"
                         f" — | — | — | {r['skipped'][:40]} |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | ERR | | | | | |"
                         f" | {r['error'].splitlines()[-1][:60]} |")
            continue
        ro = r["roofline"]
        pd = r["per_device"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {ro['dominant'][:-2]} "
            f"| {ro['compute_s']:.3f} | {ro['memory_s']:.3f} "
            f"| {ro['collective_s']:.3f} | {ro['hlo_useful_ratio']:.2f} "
            f"| {ro['roofline_fraction']:.3f} "
            f"| {_fmt_bytes(pd.get('peak_bytes'))} | |")
    return "\n".join(lines)


def summary(records: List[Dict]) -> Dict:
    ok = [r for r in records if "roofline" in r]
    skip = [r for r in records if "skipped" in r]
    err = [r for r in records if "error" in r]
    worst = sorted(ok, key=lambda r: r["roofline"]["roofline_fraction"])
    coll = sorted(ok, key=lambda r: -r["roofline"]["collective_s"])
    return dict(
        n_ok=len(ok), n_skip=len(skip), n_err=len(err),
        worst_fraction=[(r["arch"], r["shape"], r.get("mesh_name"),
                         round(r["roofline"]["roofline_fraction"], 4))
                        for r in worst[:5]],
        most_collective=[(r["arch"], r["shape"], r.get("mesh_name"),
                          round(r["roofline"]["collective_s"], 3))
                         for r in coll[:5]],
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="benchmarks/out")
    ap.add_argument("--mesh", default="pod16x16")
    args = ap.parse_args()
    records = load(args.dir)
    print(table(records, args.mesh))
    print()
    print(json.dumps(summary(records), indent=1))


if __name__ == "__main__":
    main()
