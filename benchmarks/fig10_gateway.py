"""Fig 10 — gateway coalescing: N concurrent clients, one dispatch.

The serving question fig 8 left open: fig 8 shows ONE call amortizing
device work across synopses (blue path) and across queries (red path);
this figure shows the ``SynopsisGateway`` amortizing across CLIENTS.
64 clients each push 64-tuple ingest batches against an engine
maintaining 1024 CountMin synopses:

  * serial   — the pre-gateway front door: one ``SDE.ingest`` call per
    client per tick (64 fused dispatches per tick, one per client).
  * gateway  — 64 ``submit_nowait`` + ONE ``tick()``: the micro-batcher
    concatenates all 64 batches into one ``SDE.ingest`` (ONE fused
    dispatch per kind per tick), then fans the acks back out.

Both paths ingest identical traffic; the speedup is pure per-dispatch
overhead (trace-cache lookup, donation bookkeeping, kernel launch)
recovered by coalescing — the same effect as fig 8's ``query_many``
but on the write path, driven by concurrency instead of batch size.

``--check`` gates CI: speedup >= 4x AND the probe-verified invariant
that one gateway tick costs exactly ONE blue-path dispatch.
"""
from __future__ import annotations

import numpy as np

from repro.kernels import ops as kops
from repro.service import SDE, SynopsisGateway
from .common import time_fn, csv_row

_N_SYNOPSES = 1024
_N_CLIENTS = 64
_TUPLES_PER_CLIENT = 64
_CM = {"eps": 0.02, "delta": 0.1, "weighted": False}


def _build_engine() -> SDE:
    eng = SDE()
    r = eng.handle({"type": "build", "request_id": "b",
                    "synopsis_id": "cm", "kind": "countmin",
                    "params": _CM, "per_stream_of_source": True,
                    "n_streams": _N_SYNOPSES})
    assert r.ok, r.error
    return eng


def _client_batches(rng):
    return [(rng.randint(0, _N_SYNOPSES, _TUPLES_PER_CLIENT)
             .astype(np.int64),
             rng.uniform(0.5, 2.0, _TUPLES_PER_CLIENT)
             .astype(np.float32))
            for _ in range(_N_CLIENTS)]


def run(full: bool = False, check: bool = False):
    rng = np.random.RandomState(0)
    batches = _client_batches(rng)
    reqs = [{"type": "ingest", "request_id": f"i{j}",
             "stream_ids": sids.tolist(), "values": vals.tolist()}
            for j, (sids, vals) in enumerate(batches)]

    serial = _build_engine()

    def serial_tick():
        for sids, vals in batches:       # one dispatch PER CLIENT
            serial.ingest(sids, vals)
        return [serial.stacks[k].state for k in serial.stacks]

    gw = SynopsisGateway(_build_engine())
    clients = [gw.connect(f"c{j}") for j in range(_N_CLIENTS)]

    def gateway_tick():
        futs = [gw.submit_nowait(c, r) for c, r in zip(clients, reqs)]
        gw.tick()                        # ONE dispatch for all clients
        for f in futs:
            assert f.result().ok, f.result().error
        return [gw.sde.stacks[k].state for k in gw.sde.stacks]

    t_serial = time_fn(serial_tick, warmup=1, iters=5)
    t_gateway = time_fn(gateway_tick, warmup=1, iters=5)

    # probe the invariant on one extra tick: 64 clients, ONE dispatch
    d0 = kops.DISPATCH_COUNT.get("update:CountMin", 0)
    c0 = kops.GATEWAY_COALESCED.get("ingest", 0)
    gateway_tick()
    dispatches = kops.DISPATCH_COUNT["update:CountMin"] - d0
    coalesced = kops.GATEWAY_COALESCED["ingest"] - c0

    tuples = _N_CLIENTS * _TUPLES_PER_CLIENT
    speedup = t_serial / t_gateway
    rows = [csv_row(
        f"fig10_gateway_c{_N_CLIENTS}_k{_N_SYNOPSES}", t_gateway,
        f"gateway={tuples / t_gateway:,.0f}t/s "
        f"serial={tuples / t_serial:,.0f}t/s "
        f"speedup={speedup:.1f}x "
        f"dispatches_per_tick={dispatches} coalesced={coalesced}")]
    if check:
        assert dispatches == 1, \
            f"expected ONE blue dispatch per tick, saw {dispatches}"
        assert coalesced == _N_CLIENTS, \
            f"expected {_N_CLIENTS} coalesced requests, saw {coalesced}"
        assert speedup >= 4.0, \
            f"gateway speedup {speedup:.2f}x < 4x acceptance floor"
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="assert the acceptance gates (CI mode)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    for row in run(full=args.full, check=args.check):
        print(row)
