"""Fig 13 — multidim subpopulation queries: covering sets beat scans.

A 2-dimensional family (32 x 32 -> 1024 leaf groups, plus the level
fixing only dimension ``a`` and the population group) ingests a uniform
attribute stream, then answers the same subpopulation predicate
(``a in {v0..v7}``) two ways:

  * subpop  — ``subpop_query``: the predicate resolves to its covering
    key set (8 groups at level ``(a,)``), gathered + merged + estimated
    in ONE fused dispatch (``kernels.ops.estimate_subpop``).
  * scan    — the pre-multidim serving story: the client fetches ALL
    1024 leaf synopses through ``query_many`` (one stacked-estimate
    dispatch over the full leaf level) and combines the predicate's
    slice host-side.

Both answer the same question off the same maintained state, so the
estimates must agree within the sketch's own error — asserted — while
the covering-set path touches 8 rows instead of 1024. ``--check`` gates
CI on the serving claim: subpop query cost <= 0.25x of the scan-all
baseline at 1024 leaf synopses, and exactly one fused dispatch answers
the predicate (``DISPATCH_COUNT``).
"""
from __future__ import annotations

import numpy as np

from repro.kernels import ops as kops
from repro.service import SDE, api
from .common import csv_row, time_fn

_DIM = 32                    # per-dimension domain -> 32*32 leaf groups
_COVER = 8                   # predicate: a in {v0..v7}
_CM = {"eps": 0.01, "delta": 0.05, "weighted": False}


def _build_engine() -> SDE:
    eng = SDE()
    r = eng.handle({
        "type": "build_multidim", "request_id": "b0", "synopsis_id": "md",
        "kind": "countmin", "params": _CM,
        "dims": {"a": [f"a{i}" for i in range(_DIM)],
                 "b": [f"b{i}" for i in range(_DIM)]},
        # the predicate's level, the scan's leaf level, the mandatory
        # population group — the middle levels of the full family would
        # only slow the build down without being measured
        "levels": [["a"], ["a", "b"]]})
    assert r.ok, r.error
    return eng


def run(full: bool = False, check: bool = False):
    rng = np.random.RandomState(0)
    eng = _build_engine()
    spec = eng.multidim["md"]
    n_batches, batch = (8, 4096) if full else (2, 2048)
    for i in range(n_batches):
        a = rng.randint(0, _DIM, batch)
        b = rng.randint(0, _DIM, batch)
        recs = [{"a": f"a{x}", "b": f"b{y}"} for x, y in zip(a, b)]
        r = eng.handle({"type": "ingest_multidim", "request_id": f"i{i}",
                        "synopsis_id": "md", "records": recs,
                        "values": [1.0] * batch})
        assert r.ok, r.error
    eng.flush()

    # the question: how many records landed in leaf (a0, b0), within the
    # subpopulation a in {a0..a7}? (an item-count CM point query — the
    # same item probed through both paths)
    item = spec.leaf_key({"a": "a0", "b": "b0"})
    where = {"a": [f"a{i}" for i in range(_COVER)]}
    subpop_req = {"type": "subpop_query", "request_id": "q",
                  "synopsis_id": "md", "where": where,
                  "query": {"items": [item]}}

    # scan baseline: every leaf synopsis, one query_many (itself ONE
    # stacked dispatch — the fairest possible scan), combined host-side
    leaf_assign = spec.level_assignments(("a", "b"))
    leaf_keys = [spec.group_key(asg) for asg in leaf_assign]
    scan_qs = [api.AdHocQuery(request_id=f"s{i}",
                              synopsis_id=f"md/{k}",
                              query={"items": [item]})
               for i, k in enumerate(leaf_keys)]
    in_pred = np.asarray([asg["a"] in set(where["a"])
                          for asg in leaf_assign])

    def subpop():
        r = eng.handle(subpop_req)
        assert r.ok, r.error
        return float(np.asarray(r.value).ravel()[0])

    def scan():
        rs = eng.query_many(scan_qs)
        vals = np.asarray([float(np.asarray(r.value).ravel()[0])
                           for r in rs])
        return float(vals[in_pred].sum())

    est_sub, est_scan = subpop(), scan()
    # both paths estimate the count of leaf (a0, b0) — agreement within
    # the CM overcount budget (eps * subpop mass per covering row)
    tol = _CM["eps"] * n_batches * batch + 1.0
    assert abs(est_sub - est_scan) <= tol, \
        f"subpop {est_sub} vs scan {est_scan} (tol {tol})"

    before = int(kops.DISPATCH_COUNT["CountMin"])
    subpop()
    n_disp = int(kops.DISPATCH_COUNT["CountMin"]) - before

    iters = 10 if full else 3
    t_sub = time_fn(subpop, warmup=1, iters=iters)
    t_scan = time_fn(scan, warmup=1, iters=iters)
    ratio = t_sub / t_scan
    rows = [csv_row(
        f"fig13_subpop_g{len(leaf_keys)}_cover{_COVER}", t_sub,
        f"scan_us={t_scan*1e6:.1f} ratio={ratio:.3f} "
        f"dispatches={n_disp} est_subpop={est_sub:.0f} "
        f"est_scan={est_scan:.0f}")]
    if check:
        assert n_disp == 1, \
            f"subpop_query cost {n_disp} dispatches, acceptance is 1"
        assert ratio <= 0.25, \
            f"subpop query at {ratio:.3f}x of the scan baseline; " \
            "acceptance is <= 0.25x at 1024 leaf synopses"
    eng.close()
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="assert the acceptance gates (CI mode)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    for row in run(full=args.full, check=args.check):
        print(row)
