"""Fig 9 — hashed routing at scale (ISSUE 3 tentpole study).

The engine's stream->row map is an open-addressing hash table probed
INSIDE the fused blue-path programs (``kernels.ops.route_probe``), so
stream ids are arbitrary 63-bit values with no dense-table cap. This
harness measures the pieces that scale with the distinct-stream count:

  (a) host-side bulk registration (vectorized ``insert_many``) — the
      build-time cost of a per-stream synopsis population,
  (b) the device probe alone for a 262k-tuple batch — the per-ingest
      routing overhead added to the fused dispatch, vs the old dense
      ``route[sids]`` gather it replaces (measurable only at 65k where
      the dense table was even representable),
  (c) table footprint + probe bound — what keeps (b) flat: growth caps
      probe chains (PROBE_CAP) so the fused loop's trip count stays
      <= 32 regardless of occupancy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.service import routing
from repro.service.engine import _next_pow2
from .common import time_fn, csv_row


def _probe_fn(n_probe: int):
    @jax.jit
    def probe(klo, khi, trows, qlo, qhi):
        return kops.route_probe(klo, khi, trows, qlo, qhi,
                                n_probe=n_probe)
    return probe


def run(batch_tuples: int = 262144, full: bool = False):
    rows = []
    sizes = [1 << 16, 1 << 18, 1 << 20]
    if full:
        sizes.append(1 << 22)
    rng = np.random.RandomState(9)
    for ns in sizes:
        ids = np.unique(rng.randint(0, 2**63 - 1, ns, dtype=np.int64))

        # (a) bulk registration
        import time as _time
        t0 = _time.perf_counter()
        table = routing.RouteTable()
        table.insert_many(ids, np.arange(len(ids), dtype=np.int32))
        t_build = _time.perf_counter() - t0
        rows.append(csv_row(
            f"fig9a_register_{ns}", t_build,
            f"rate={len(ids) / t_build:,.0f}ids/s"))

        # (b) device probe for one ingest batch
        q = ids[rng.randint(0, len(ids), batch_tuples)]
        klo, khi = routing.split64(table.keys)
        qlo, qhi = routing.split64(q)
        n_probe = _next_pow2(table.max_probe)
        fn = _probe_fn(n_probe)
        args = tuple(jnp.asarray(a)
                     for a in (klo, khi, table.rows, qlo, qhi))
        t = time_fn(fn, *args)
        rows.append(csv_row(
            f"fig9b_probe_{ns}", t,
            f"throughput={batch_tuples / t:,.0f}lookups/s "
            f"n_probe={n_probe}"))

        # (c) table footprint + probe bound
        mem = table.size * (4 + 4 + 4)    # device mirror: lo+hi+rows
        rows.append(csv_row(
            f"fig9c_table_{ns}", 0.0,
            f"slots={table.size} load={table.load:.2f} "
            f"max_probe={table.max_probe} device_bytes={mem}"))

    # dense-gather reference at the old cap (the path this PR replaces —
    # only definable for ids < 65536)
    dense = jnp.arange(1 << 16, dtype=jnp.int32)
    sids = jnp.asarray(rng.randint(0, 1 << 16, batch_tuples)
                       .astype(np.int32))
    gather = jax.jit(lambda r, s: r[s])
    t = time_fn(gather, dense, sids)
    rows.append(csv_row(
        "fig9b_dense_gather_65k_reference", t,
        f"throughput={batch_tuples / t:,.0f}lookups/s "
        "(ids>=65536 were DROPPED)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
