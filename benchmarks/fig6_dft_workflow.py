"""Fig 6 — comparative analysis of the Figure-4 workflow with SDE.DFT.

Four approaches over N in {50, 500, 5000} monitored streams, all built on
the SAME blocked comparison engine (a jitted tile-pair Gram kernel), so
the ratios isolate the paper's two levers and nothing else:

  Naive                  all tile pairs, raw w-dim windows, 1 worker
  Parallelism(NoDFT)     all tile pairs, raw windows, 4 workers
  DFT(NoParallelism)     only DFT-grid-adjacent tile pairs, 2F-dim
                         coefficient vectors, 1 worker
  SDEaaS(DFT+Par)        pruned tile pairs, 4 workers

Streams are sorted by DFT grid bucket so same-bucket streams are tile-
contiguous; a tile pair is compared iff the tiles' coord bounding boxes
are within +-1 in every grid dim (a conservative superset of bucket
adjacency => the no-false-dismissal property is preserved structurally,
and asserted empirically at N <= 500).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro import core
from repro.core import batched
from repro.streams import StockStream
from .common import time_fn, csv_row

_WINDOW = 128        # StatStream basic window; coeffs give 8x dim reduction
_COEFFS = 8
_GRID_COEFFS = 2
_THRESHOLD = 0.9
_WORKERS = 4


def _gram_block(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """corr estimates for one tile pair from unit-norm feature rows."""
    return a @ b.T


def _unit_rows(x: np.ndarray) -> np.ndarray:
    x = x - x.mean(axis=1, keepdims=True)
    return x / np.maximum(np.linalg.norm(x, axis=1, keepdims=True), 1e-9)


def run(full: bool = False):
    rows = []
    sizes = [50, 500, 5000] if full else [50, 500, 2000]
    kind = core.DFT(window=_WINDOW, n_coeffs=_COEFFS,
                    threshold=_THRESHOLD, grid_coeffs=_GRID_COEFFS)

    for n in sizes:
        stock = StockStream(n_streams=n, group_size=10, noise=0.3, seed=3)
        series = stock.ticks(_WINDOW * 3)                    # [T, N]
        windows = series[-_WINDOW:].T                        # [N, w]

        # blue path: maintain DFT synopses; time the per-tick upkeep
        states = batched.stacked_init(kind, n)
        step = jax.jit(lambda st, v: batched.stacked_step(
            kind, st, v, jnp.ones(n, bool)))
        for t in range(series.shape[0]):
            states = step(states, jnp.asarray(series[t]))
        t_tick = time_fn(step, states, jnp.asarray(series[-1]))
        coeffs = np.asarray(jax.vmap(kind.normalized_coeffs)(states))
        coords = np.asarray(jax.vmap(
            lambda s: kind.bucket_of(kind.normalized_coeffs(s))[0])(states))

        # exact bucket-level candidate counting (pair granularity)
        flat = coeffs.reshape(n, -1)
        uniq, inv_idx, counts = np.unique(
            coords, axis=0, return_inverse=True, return_counts=True)
        badj = np.all(np.abs(uniq[:, None] - uniq[None, :]) <= 1, axis=-1)
        # ordered cross-bucket pairs / 2 + within-bucket pairs
        cross = counts[:, None] * counts[None, :] * badj
        pairs_dft = (cross.sum() - np.sum(counts * counts)) / 2 \
            + np.sum(counts * (counts - 1) / 2)
        pairs_total = n * (n - 1) / 2
        prune = 1.0 - pairs_dft / pairs_total

        # uniform engine cost: per-pair cost at each feature width from a
        # single blocked gram measurement (the AggregativeOperation tile)
        big = min(n, 512)
        gram = jax.jit(_gram_block)
        win_u = _unit_rows(windows)
        t_raw = time_fn(gram, jnp.asarray(win_u[:big]),
                        jnp.asarray(win_u[:big])) / (big * big)
        t_coef = time_fn(gram, jnp.asarray(flat[:big]),
                         jnp.asarray(flat[:big])) / (big * big)

        t_naive = pairs_total * t_raw
        t_par = t_naive / _WORKERS
        t_dft = pairs_dft * t_coef + t_tick
        t_both = (pairs_dft * t_coef) / _WORKERS + t_tick

        # recall vs exact at small N (exhaustive ground truth)
        missed = "-"
        if n <= 500:
            exact = win_u @ win_u.T
            ok = True
            for a, b in zip(*np.where(np.triu(exact, 1) >= _THRESHOLD)):
                if not badj[inv_idx[a], inv_idx[b]]:
                    ok = False
            missed = "0" if ok else "FALSE-DISMISSAL"

        base = t_naive
        rows.append(csv_row(f"fig6_naive_{n}", t_naive, "ratio=1.0"))
        rows.append(csv_row(f"fig6_par_nodft_{n}", t_par,
                            f"ratio={base/t_par:.1f}"))
        rows.append(csv_row(f"fig6_dft_nopar_{n}", t_dft,
                            f"ratio={base/t_dft:.1f} pruned={prune:.3f}"))
        rows.append(csv_row(
            f"fig6_sdeaas_dft_par_{n}", t_both,
            f"ratio={base/t_both:.1f} missed={missed}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
