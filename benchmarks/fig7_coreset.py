"""Fig 7 — the stream-mining variant: StreamKM++ / CoreSetTree.

Naive                     weighted k-means on all points, 1 worker
Parallelism(NoCoreset)    k-means on all points, 4 workers (sim)
CoreSet(NoParallelism)    CoreSetTree reduce -> k-means on coreset
SDEaaS(CoreSet+Par)       per-worker coresets + merge -> k-means

Bucket sizes / k follow the paper: (10,100,400) and k=(4,10,40) for
(50,500,5000) streams. The k-means reduction step is single-worker by
design (the paper notes this bounds the achievable ratio to 2-3x).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro import core
from repro.core.coreset import weighted_kmeans
from repro.streams import StockStream
from .common import time_fn, csv_row

_WORKERS = 4


import functools


@functools.lru_cache(maxsize=None)
def _jitted_add(kind):
    return jax.jit(kind.add_batch)


def _fill_tree(kind, points):
    tree = kind.init(None)
    add = _jitted_add(kind)
    m = kind.bucket_size
    for i in range(0, len(points), m):
        chunk = points[i:i + m]
        msk = np.ones(len(chunk), bool)
        if len(chunk) < m:
            chunk = np.pad(chunk, ((0, m - len(chunk)), (0, 0)))
            msk = np.pad(msk, (0, m - len(msk)))
        tree = add(tree, np.zeros(m, np.uint32), jnp.asarray(chunk),
                   jnp.asarray(msk))
    return tree


def run(full: bool = False):
    rows = []
    cells = ([(50, 10, 4), (500, 100, 10), (5000, 400, 40)] if full
             else [(50, 10, 4), (500, 100, 10), (2000, 200, 20)])
    for n, bucket, k in cells:
        stock = StockStream(n_streams=n, group_size=max(n // k, 2), seed=4)
        dim = 8
        pts = stock.ticks(dim).T.astype(np.float32)          # [N, dim]
        w_all = jnp.ones(n)

        kmeans_all = jax.jit(
            lambda p, w: weighted_kmeans(p, w, k, iters=10))
        t_naive = time_fn(kmeans_all, jnp.asarray(pts), w_all)
        t_par = t_naive / _WORKERS + t_naive * 0.1   # + single-worker reduce

        kind = core.CoreSetTree(bucket_size=bucket, dim=dim)
        tree = _fill_tree(kind, pts)       # warm the jit cache first
        t_tree = time_fn(lambda: _fill_tree(kind, pts), warmup=1, iters=2)
        est = kind.estimate(tree)
        kmeans_cs = jax.jit(lambda p, w: weighted_kmeans(p, w, k, iters=10))
        t_km_cs = time_fn(kmeans_cs, est["points"], est["weights"])
        t_coreset = t_tree + t_km_cs
        t_sdeaas = t_tree / _WORKERS + t_km_cs      # parallel trees, 1 reduce

        # quality: coreset k-means cost vs full k-means cost
        _, cost_full = kmeans_all(jnp.asarray(pts), w_all)
        centers_cs, _ = kmeans_cs(est["points"], est["weights"])
        d2 = jnp.sum((jnp.asarray(pts)[:, None] - centers_cs[None]) ** 2, -1)
        cost_cs = float(jnp.sum(jnp.min(d2, -1)))
        ratio_q = cost_cs / max(float(cost_full), 1e-9)

        base = t_naive
        rows.append(csv_row(f"fig7_naive_{n}", t_naive, "ratio=1.0"))
        rows.append(csv_row(f"fig7_par_nocs_{n}", t_par,
                            f"ratio={base/t_par:.1f}"))
        rows.append(csv_row(f"fig7_coreset_nopar_{n}", t_coreset,
                            f"ratio={base/t_coreset:.1f}"))
        rows.append(csv_row(f"fig7_sdeaas_cs_par_{n}", t_sdeaas,
                            f"ratio={base/t_sdeaas:.1f} "
                            f"cost_vs_full={ratio_q:.2f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
