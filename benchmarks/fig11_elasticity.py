"""Fig 11 — elasticity under drift: the reconciler keeps throughput.

A drifting Zipf workload over 64 per-stream CountMins on a 4-worker row
axis, ingested in chunks. Three placements race:

  * static     — WFD planned ONCE on the first phase's loads (what an
    offline planner ships), never revisited. When the hot set drifts —
    phase B concentrates 90% of the traffic on exactly the streams the
    static plan packed onto worker 0 — its bottleneck worker eats the
    whole phase.
  * reconciled — the live loop (``service/reconciler.py``): after every
    chunk the engine samples its own estimator synopses, re-plans WFD,
    and migrates rows through the migration plane. It chases the drift
    with one chunk of lag and the CM's estimation noise — this is the
    REAL engine reconciling, placements read back from row positions.
  * optimal    — per-chunk WFD on the true counts (oracle): the
    statically-optimal bound nothing adaptive can beat.

The metric is bottleneck work: a chunk costs its most-loaded worker's
tuple count (workers drain in parallel), a run costs the sum over
chunks, and modeled throughput is ``total_tuples / (W * cost)`` — 1.0
at perfect balance. ``--check`` gates CI on the paper's elasticity
claim (Section 7): reconciled stays within 1.2x of optimal while static
degrades by >= 2x, and the reconciler actually migrated rows.
"""
from __future__ import annotations

import time

import numpy as np

from repro.kernels import ops as kops
from repro.service import SDE, Reconciler, worst_fit_decreasing
from .common import csv_row

_W = 4
_N_STREAMS = 64
_CHUNKS_PER_PHASE = 16
_CHUNK = 256
_ZIPF_A = 0.9
_CM = {"eps": 0.05, "delta": 0.1, "weighted": False}
_EST_CM = {"eps": 0.01, "delta": 0.01, "weighted": False}


def _build_engine() -> SDE:
    eng = SDE()
    for req in (
        {"type": "build", "request_id": "b1", "synopsis_id": "pt",
         "kind": "countmin", "params": _CM,
         "per_stream_of_source": True, "n_streams": _N_STREAMS},
        {"type": "build", "request_id": "b2", "synopsis_id": "rhll",
         "kind": "hyperloglog", "params": {"rse": 0.05}},
        {"type": "build", "request_id": "b3", "synopsis_id": "rcm",
         "kind": "countmin", "params": _EST_CM},
    ):
        r = eng.handle(req)
        assert r.ok, r.error
    return eng


def _phase_probs(hot=None, hot_mass=0.9):
    """Zipf(a) over stream ranks; with ``hot``, that stream set takes
    ``hot_mass`` of the total (uniformly) and the rest stays Zipf."""
    p = 1.0 / np.arange(1, _N_STREAMS + 1) ** _ZIPF_A
    p /= p.sum()
    if hot is not None:
        mask = np.zeros(_N_STREAMS, bool)
        mask[list(hot)] = True
        p = np.where(mask, 0.0, p)
        p *= (1.0 - hot_mass) / p.sum()
        p[mask] = hot_mass / mask.sum()
    return p


def _engine_assign(eng) -> dict:
    kind = eng.entries["pt/0"].kind_key
    cap = eng.stacks[kind].capacity
    return {s: eng.entries[f"pt/{s}"].row * _W // cap
            for s in range(_N_STREAMS)}


def _chunk_cost(assign, counts) -> float:
    """Bottleneck work for one chunk under ``assign``: the most-loaded
    worker's tuple count (workers drain in parallel)."""
    loads = np.zeros(_W)
    for s in range(_N_STREAMS):
        loads[assign[s]] += counts[s]
    return float(loads.max())


def run(full: bool = False, check: bool = False):
    rng = np.random.RandomState(0)
    eng = _build_engine()
    rec = Reconciler(eng, "rhll", "rcm", n_workers=_W, min_gain=0.02)
    ones = np.ones(_CHUNK, np.float32)

    state = dict(cost_rec=0.0, cost_opt=0.0, cost_static=0.0,
                 t_reconcile=0.0, n_chunks=0, static=None)

    def run_phase(probs):
        chunk_counts = []
        for _ in range(_CHUNKS_PER_PHASE):
            sids = rng.choice(_N_STREAMS, _CHUNK, p=probs).astype(np.int64)
            counts = np.bincount(sids, minlength=_N_STREAMS)
            chunk_counts.append(counts)
            # placement DURING the chunk: the real engine's row layout
            state["cost_rec"] += _chunk_cost(_engine_assign(eng), counts)
            if state["static"] is not None:
                state["cost_static"] += _chunk_cost(
                    state["static"].assignments, counts)
            eng.ingest(sids, ones)
            t0 = time.perf_counter()
            rec.maybe_step()
            state["t_reconcile"] += time.perf_counter() - t0
            state["n_chunks"] += 1
        # the oracle: the best STATIC placement for this phase, planned
        # on the phase's true totals (per-chunk re-planning would just
        # chase sampling noise no real scheduler sees)
        phase_counts = np.sum(chunk_counts, axis=0)
        opt = worst_fit_decreasing(list(range(_N_STREAMS)),
                                   phase_counts, _W)
        for counts in chunk_counts:
            state["cost_opt"] += _chunk_cost(opt.assignments, counts)
        return phase_counts

    # warmup (uncounted): let the reconciler pull the fresh engine's
    # rows — all allocated into worker 0's slice — apart before the
    # measurement window opens, so the race starts from a warmed system
    for _ in range(2):
        sids = rng.choice(_N_STREAMS, _CHUNK,
                          p=_phase_probs()).astype(np.int64)
        eng.ingest(sids, ones)
        rec.maybe_step()

    # phase A: plain Zipf — this is also where the static plan is fitted
    # (it pays the same cost the reconciler does while both converge)
    counts_a = run_phase(_phase_probs())
    state["static"] = worst_fit_decreasing(
        list(range(_N_STREAMS)), counts_a, _W)
    state["cost_static"] = state["cost_rec"]

    # drift: each phase's hot set is EXACTLY one static worker's stream
    # set — maximally adversarial for a placement that cannot move. Pick
    # the workers holding the MOST streams (WFD isolates the Zipf head
    # on its own worker; a one-stream hot set is indivisible for
    # everyone, which would measure nothing)
    by_count = sorted(range(_W), key=lambda w: -sum(
        1 for ww in state["static"].assignments.values() if ww == w))
    n_drift = 2 if full else 1
    for w in by_count[:n_drift]:
        hot = [s for s, ww in state["static"].assignments.items()
               if ww == w]
        run_phase(_phase_probs(hot=hot))

    total = state["n_chunks"] * _CHUNK
    thr = {name: total / (_W * state[f"cost_{key}"])
           for name, key in (("reconciled", "rec"), ("static", "static"),
                             ("optimal", "opt"))}
    rec_vs_opt = state["cost_rec"] / state["cost_opt"]
    static_vs_opt = state["cost_static"] / state["cost_opt"]
    migrated = int(kops.MIGRATED_ROWS[eng.site])
    rows = [csv_row(
        f"fig11_elasticity_w{_W}_s{_N_STREAMS}",
        state["t_reconcile"] / state["n_chunks"],
        f"thr_reconciled={thr['reconciled']:.3f} "
        f"thr_static={thr['static']:.3f} thr_optimal={thr['optimal']:.3f} "
        f"rec_vs_opt={rec_vs_opt:.2f}x "
        f"static_vs_opt={static_vs_opt:.2f}x migrated_rows={migrated}")]
    if check:
        assert rec_vs_opt <= 1.2, \
            f"reconciled {rec_vs_opt:.2f}x of optimal, acceptance is 1.2x"
        assert static_vs_opt >= 2.0, \
            f"static only degraded {static_vs_opt:.2f}x; the drift must " \
            "cost a frozen placement >= 2x"
        assert migrated > 0, "reconciler never migrated a row"
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="assert the acceptance gates (CI mode)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    for row in run(full=args.full, check=args.check):
        print(row)
