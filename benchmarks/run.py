"""Benchmark runner — one harness per paper figure.

Prints ``name,us_per_call,derived`` CSV rows and writes the same rows
as machine-readable ``BENCH_summary.json`` (``--summary`` to relocate
it) so CI and regression tooling diff runs without scraping stdout.
``--full`` uses the paper's exact sizes (5000 streams etc.); default
sizes finish in ~2 minutes on one CPU core. Dry-run/roofline cells are
produced separately by ``python -m repro.launch.dryrun --all`` (they
need 512 fake devices).
"""
from __future__ import annotations

import argparse
import json
import sys


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (5000 streams)")
    ap.add_argument("--only", default=None,
                    help="comma list: fig5,fig6,fig7,fig8,fig9,fig10,"
                         "fig11,fig12,fig13")
    ap.add_argument("--summary", default="BENCH_summary.json",
                    help="machine-readable results file "
                         "(empty string to skip)")
    args = ap.parse_args(argv)

    from . import fig5_scalability, fig6_dft_workflow, fig7_coreset, \
        fig8_sdeaas, fig9_routing, fig10_gateway, fig11_elasticity, \
        fig12_durability, fig13_subpop

    figs = dict(fig5=fig5_scalability, fig6=fig6_dft_workflow,
                fig7=fig7_coreset, fig8=fig8_sdeaas,
                fig9=fig9_routing, fig10=fig10_gateway,
                fig11=fig11_elasticity, fig12=fig12_durability,
                fig13=fig13_subpop)
    only = set(args.only.split(",")) if args.only else set(figs)

    results = []
    print("name,us_per_call,derived")
    for name, mod in figs.items():
        if name not in only:
            continue
        try:
            for row in mod.run(full=args.full):
                print(row, flush=True)
                cells = row.split(",", 2)
                results.append(dict(
                    fig=name, name=cells[0],
                    us_per_call=float(cells[1]),
                    derived=cells[2] if len(cells) > 2 else ""))
        except Exception as e:  # keep the harness running
            print(f"{name}_ERROR,0,{e!r}", flush=True)
            results.append(dict(fig=name, name=f"{name}_ERROR",
                                us_per_call=0.0, derived=repr(e)))
    if args.summary:
        with open(args.summary, "w", encoding="utf-8") as f:
            json.dump(dict(full=args.full, rows=results), f, indent=2)
            f.write("\n")


if __name__ == "__main__":
    main()
