"""Benchmark runner — one harness per paper figure.

Prints ``name,us_per_call,derived`` CSV rows. ``--full`` uses the paper's
exact sizes (5000 streams etc.); default sizes finish in ~2 minutes on one
CPU core. Dry-run/roofline cells are produced separately by
``python -m repro.launch.dryrun --all`` (they need 512 fake devices).
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (5000 streams)")
    ap.add_argument("--only", default=None,
                    help="comma list: fig5,fig6,fig7,fig8,fig9,fig10,"
                         "fig11")
    args = ap.parse_args(argv)

    from . import fig5_scalability, fig6_dft_workflow, fig7_coreset, \
        fig8_sdeaas, fig9_routing, fig10_gateway, fig11_elasticity

    figs = dict(fig5=fig5_scalability, fig6=fig6_dft_workflow,
                fig7=fig7_coreset, fig8=fig8_sdeaas,
                fig9=fig9_routing, fig10=fig10_gateway,
                fig11=fig11_elasticity)
    only = set(args.only.split(",")) if args.only else set(figs)

    print("name,us_per_call,derived")
    for name, mod in figs.items():
        if name not in only:
            continue
        try:
            for row in mod.run(full=args.full):
                print(row, flush=True)
        except Exception as e:  # keep the harness running
            print(f"{name}_ERROR,0,{e!r}", flush=True)


if __name__ == "__main__":
    main()
