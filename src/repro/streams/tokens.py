"""Sharded, resumable LM token pipeline with SDE-backed statistics.

Synthetic zipf-mixture corpus (deterministic in (seed, shard, step)), the
substrate for train examples and smoke tests. Maintains the paper's "cost
estimator" synopses over the token stream — CountMin (token frequency) and
HLL (distinct tokens) per shard, mergeable across hosts — which the
launcher reports for load-balance decisions.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import CountMin, HyperLogLog


@dataclasses.dataclass
class TokenPipeline:
    vocab: int
    seq_len: int
    batch: int                    # per-shard batch
    shard: int = 0
    n_shards: int = 1
    seed: int = 0
    step: int = 0                 # resumable
    with_stats: bool = True

    def __post_init__(self):
        if self.with_stats:
            self.cm = CountMin(eps=0.001, delta=0.01, weighted=False)
            self.hll = HyperLogLog(rse=0.02)
            self.cm_state = self.cm.init(None)
            self.hll_state = self.hll.init(None)
            self._update = jax.jit(self._stats_update)

    def _stats_update(self, cm_state, hll_state, toks):
        flat = toks.reshape(-1).astype(jnp.uint32)
        ones = jnp.ones_like(flat, jnp.float32)
        mask = jnp.ones_like(flat, bool)
        return (self.cm.add_batch(cm_state, flat, ones, mask),
                self.hll.add_batch(hll_state, flat, ones, mask))

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + self.shard * 7919 + self.step)
            % (2**31 - 1))
        toks = (rng.zipf(1.3, size=(self.batch, self.seq_len + 1))
                % self.vocab).astype(np.int32)
        self.step += 1
        batch = dict(tokens=toks[:, :-1], labels=toks[:, 1:])
        if self.with_stats:
            self.cm_state, self.hll_state = self._update(
                self.cm_state, self.hll_state, jnp.asarray(batch["tokens"]))
        return batch

    # -- SDE statistics (cost-estimator role) ---------------------------
    def token_frequency(self, token_ids) -> np.ndarray:
        return np.asarray(self.cm.estimate(
            self.cm_state, jnp.asarray(np.asarray(token_ids, np.uint32))))

    def distinct_tokens(self) -> float:
        return float(self.hll.estimate(self.hll_state))

    def state(self) -> Dict:
        return dict(seed=self.seed, shard=self.shard, step=self.step)

    def restore(self, state: Dict):
        assert state["shard"] == self.shard
        self.seed, self.step = state["seed"], state["step"]
