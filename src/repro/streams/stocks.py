"""Deterministic synthetic financial stream (the paper's Level-1/Level-2
stock data, 5000 streams) with planted correlation structure.

Streams are grouped: members of a group share a latent driver (so true
pairwise Pearson within a group is high) — ground truth for validating the
DFT bucketization recall (fig 6). The generator is a pure function of
(seed, offset): checkpoint the offset, resume exactly (fault tolerance for
the ingest pipeline).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np


@dataclasses.dataclass
class StockStream:
    n_streams: int = 5000
    group_size: int = 10          # correlated group width
    noise: float = 0.25
    seed: int = 0
    offset: int = 0               # resumable position (ticks per stream)

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        self.n_groups = (self.n_streams + self.group_size - 1) \
            // self.group_size
        self._group_seed = rng.randint(0, 2**31 - 1, self.n_groups)
        self._stream_noise = rng.randint(0, 2**31 - 1, self.n_streams)
        self._walk = np.zeros(self.n_groups, np.float64)   # resumable state

    def group_of(self, stream: int) -> int:
        return stream // self.group_size

    @staticmethod
    def _u(counter: np.ndarray, seed) -> np.ndarray:
        """Counter-based white noise in (-1, 1): murmur3-mixed, NOT a
        linear congruence (that would put spectral lines in every
        stream — see DESIGN lessons)."""
        x = (counter.astype(np.uint64) * np.uint64(0x9E3779B9)
             + np.asarray(seed, np.uint64)).astype(np.uint32)
        x ^= x >> np.uint32(16)
        x = (x * np.uint32(0x85EBCA6B)).astype(np.uint32)
        x ^= x >> np.uint32(13)
        x = (x * np.uint32(0xC2B2AE35)).astype(np.uint32)
        x ^= x >> np.uint32(16)
        return (x / 2**32) * 2.0 - 1.0

    _DECAY = 0.9     # OU mean reversion (stationary, 1/f-ish spectrum)

    def ticks(self, n_ticks: int) -> np.ndarray:
        """[n_ticks, n_streams] next values; advances offset. Streams are
        group Ornstein-Uhlenbeck processes (low-frequency-dominated like
        stock returns, but stationary so independent groups decorrelate)
        + per-stream noise; within-group Pearson is high, cross-group ~0."""
        t = self.offset + np.arange(n_ticks)[:, None]        # [T, 1]
        g = np.arange(self.n_streams) // self.group_size     # [S]
        inc = self._u(t, self._group_seed[None, :])          # [T, G]
        walks = np.empty_like(inc)
        prev = self._walk
        for i in range(n_ticks):                 # OU recurrence (host-side)
            prev = self._DECAY * prev + inc[i]
            walks[i] = prev
        self._walk = prev
        base = walks[:, g]
        noise = self.noise * self._u(t, self._stream_noise[None, :])
        self.offset += n_ticks
        return (base + noise).astype(np.float32)

    def level1_batch(self, tuples: int) -> Tuple[np.ndarray, np.ndarray]:
        """Flat (stream_ids, values) batch of trade ticks — the SDE ingest
        format. Round-robin over streams, `tuples` total."""
        per = max(1, tuples // self.n_streams)
        vals = self.ticks(per)                               # [per, S]
        sids = np.tile(np.arange(self.n_streams, dtype=np.uint32), per)
        flat = vals.reshape(-1)
        if len(flat) > tuples:
            sids, flat = sids[:tuples], flat[:tuples]
        return sids, flat.astype(np.float32)

    def level2_batch(self, tuples: int) -> Tuple[np.ndarray, np.ndarray]:
        """Bid activity (counts) — heavier-tailed per-stream volumes."""
        rng = np.random.RandomState((self.seed + self.offset) % (2**31))
        sids = (rng.zipf(1.2, tuples) % self.n_streams).astype(np.uint32)
        vols = rng.rand(tuples).astype(np.float32) * 100.0
        return sids, vols

    def state(self) -> Dict:
        return dict(seed=self.seed, offset=self.offset,
                    walk=self._walk.tolist())

    @classmethod
    def from_state(cls, state: Dict, **kw) -> "StockStream":
        obj = cls(seed=state["seed"], offset=state["offset"], **kw)
        obj._walk = np.asarray(state["walk"], np.float64)
        return obj
