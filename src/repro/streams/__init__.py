from .stocks import StockStream
from .tokens import TokenPipeline

__all__ = ["StockStream", "TokenPipeline"]
