"""The row-granular migration plane: ONE way to move synopsis state.

Before this module, state movement was split across three disjoint,
mutually inconsistent paths — ``SDE.snapshot/restore`` (full-state host
round trip), ``merge_from`` (per-row host pulls) and ``batched.grow``
(pad-only, never shrink). Elastic placement (paper Section 7) needs to
move *rows* — between stack slots, devices and federation sites — while
ingest keeps running, so every mover now rides the same three
primitives:

  * :func:`extract_rows` — pull a set of rows out of a kind stack as a
    :class:`RowPayload`: host-numpy state slices PLUS the routing-table
    keys that pointed at them, shipped as uint32 (lo, hi) halves exactly
    like the device mirror and the snapshot wire format. A payload is
    self-contained: it can be implanted into any stack of the same kind
    on any device, mesh or site.
  * :func:`implant_rows` — scatter a payload into target rows (one
    ``.at[].set`` per state leaf), re-pin the stack's sharding, and
    commit every carried key with ONE vectorized table insert.
  * :func:`move_rows` — intra-stack relocation: gather the moving rows,
    re-init the vacated slots, scatter into the targets (all on device —
    no host round trip), then :meth:`RouteTable.remap_rows` rewrites the
    row targets in place. Keys never move slots, so ``max_probe`` — and
    therefore the fused programs' trace — is untouched; the single
    version bump republishes the device mirror atomically.

Fencing is the CALLER's contract (``SDE.migrate_rows`` etc. flush the
ingest pipeline first): by the time a plane primitive touches state, at
most the pipeline-depth in-flight batches have retired and nothing else
is dispatched until the move commits.

The snapshot wire helpers :func:`export_route` / :func:`import_route`
live here too, so ``SDE.snapshot``/``restore`` serialize routing through
the same uint32-halves convention as payloads instead of a bespoke copy.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batched
from . import routing

# uint32 halves of routing.EMPTY (-1): the hi half alone marks "this row
# carries no routed key" (valid ids have hi <= 0x7FFFFFFF), matching how
# the device probe detects empty slots.
_EMPTY_HI = np.uint32(0xFFFFFFFF)


@dataclasses.dataclass
class RowPayload:
    """A self-contained slice of a kind stack: ``n`` rows of state plus
    the routing keys and source flags that travel with them. State
    leaves are HOST numpy (committed nowhere), so a payload crosses
    devices, meshes and federation sites freely."""

    state: Any                # pytree of [n, ...] numpy leaves
    keys_lo: np.ndarray       # [n] uint32 — routed stream id, lo half
    keys_hi: np.ndarray       # [n] uint32 — hi half; 0xFFFFFFFF = no key
    source: np.ndarray        # [n] bool — row is a data-source synopsis

    @property
    def n(self) -> int:
        return int(self.keys_lo.shape[0])

    def stream_ids(self) -> np.ndarray:
        """int64 stream ids; -1 (routing.EMPTY) where a row carries no
        routed key."""
        return (self.keys_lo.astype(np.int64)
                | (self.keys_hi.astype(np.int64) << np.int64(32)))

    def nbytes(self) -> int:
        """Payload wire size — what a cross-site move actually ships."""
        return (sum(x.nbytes for x in jax.tree.leaves(self.state))
                + self.keys_lo.nbytes + self.keys_hi.nbytes
                + self.source.nbytes)


def _row_keys(stack, rows: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(lo, hi) uint32 key halves for ``rows`` of ``stack``'s table;
    EMPTY halves where no key routes to the row (source/anonymous)."""
    keys = np.full(rows.shape, routing.EMPTY, np.int64)
    t_keys, t_rows = stack.table.items()
    if t_keys.size:
        top = int(max(int(rows.max(initial=0)), int(t_rows.max())))
        row_to_key = np.full(top + 1, routing.EMPTY, np.int64)
        row_to_key[t_rows] = t_keys
        keys = row_to_key[rows]
    return routing.split64(keys)


def extract_rows(stack, rows: Sequence[int]) -> RowPayload:
    """Pull ``rows`` out of ``stack`` as a :class:`RowPayload`. One
    device gather per state leaf, then a host pull; the row->key reverse
    map is a single vectorized pass over the table. Rows stay live in
    the stack — removal is the caller's call (``SDE.extract_synopses``
    frees them when asked to)."""
    rows = np.asarray(list(rows), np.int32)
    # pad the gather index to a power-of-two bucket (repeating the last
    # row) so the per-shape XLA gather compiles O(log capacity) times
    # total instead of once per distinct row count — periodic dirty-row
    # snapshots would otherwise recompile on every delta
    n = rows.size
    pad = max(8, 1 << (n - 1).bit_length()) if n else 0
    idx = jnp.asarray(np.concatenate(
        [rows, np.full(pad - n, rows[-1] if n else 0, np.int32)]))
    state = jax.tree.map(lambda x: np.asarray(x[idx])[:n], stack.state)
    lo, hi = _row_keys(stack, rows)
    source = np.asarray([int(r) in stack.source_rows for r in rows], bool)
    return RowPayload(state=state, keys_lo=lo, keys_hi=hi, source=source)


def implant_rows(stack, rows: Sequence[int], payload: RowPayload) -> None:
    """Scatter ``payload`` into ``rows`` of ``stack``: one ``.at[].set``
    per state leaf, re-pinned to the stack's placement, then ONE
    vectorized table insert commits every carried key (the routing
    commit point — before it, ingest still routes to the old location;
    after it, to the new). Target rows must already be allocated
    (``used``) by the caller."""
    rows = np.asarray(list(rows), np.int32)
    if rows.size != payload.n:
        raise ValueError(
            f"implant_rows: {rows.size} target rows for a payload of "
            f"{payload.n} rows")
    if rows.size == 0:
        return
    if int(rows.max()) >= stack.capacity:
        raise ValueError(
            f"implant_rows: target row {int(rows.max())} outside stack "
            f"capacity {stack.capacity}")
    idx = jnp.asarray(rows)
    vals = jax.tree.map(jnp.asarray, payload.state)
    stack.state = jax.tree.map(
        lambda x, v: x.at[idx].set(v), stack.state, vals)
    stack._place()
    _mark_dirty(stack, rows)
    for r in rows:
        stack.used[int(r)] = True
    stack._free = None
    for r in rows[payload.source]:
        if int(r) not in stack.source_rows:
            stack.mark_source(int(r))
    routed = payload.keys_hi != _EMPTY_HI
    if routed.any():
        stack.table.insert_many(payload.stream_ids()[routed], rows[routed])


def move_rows(stack, mapping: Dict[int, int]) -> None:
    """Intra-stack relocation: move row ``src`` to ``mapping[src]`` for
    every pair at once, entirely on device — gather the movers, re-init
    the vacated slots, scatter into the targets (that order makes
    arbitrary permutations and chains safe), then remap the routing
    table's row targets in one atomic pass. Targets must be free rows or
    themselves sources of the same mapping; the mapping must be
    injective."""
    if not mapping:
        return
    src = np.asarray(list(mapping.keys()), np.int32)
    dst = np.asarray(list(mapping.values()), np.int32)
    if len(set(mapping.values())) != dst.size:
        raise ValueError("move_rows: mapping targets collide")
    srcset = set(int(s) for s in src)
    for d in dst:
        if stack.used[int(d)] and int(d) not in srcset:
            raise ValueError(
                f"move_rows: target row {int(d)} is occupied and not "
                "itself moving")
    src_d, dst_d = jnp.asarray(src), jnp.asarray(dst)
    moved = jax.tree.map(lambda x: x[src_d], stack.state)
    fresh = batched.stacked_init(stack.kind, src.size)
    stack.state = jax.tree.map(
        lambda x, f, m: x.at[src_d].set(f).at[dst_d].set(m),
        stack.state, fresh, moved)
    stack._place()
    for s in src:
        stack.used[int(s)] = False
    for d in dst:
        stack.used[int(d)] = True
    stack.source_rows = [mapping.get(r, r) for r in stack.source_rows]
    stack._source_idx = None
    stack._free = None
    stack.table.remap_rows(src, dst)
    # both ends of every move changed bytes (target got the mover, the
    # vacated source was re-initialized) — the next incremental snapshot
    # must ship them, or a reconciler rebalance would silently rot deltas
    _mark_dirty(stack, src)
    _mark_dirty(stack, dst)


def _mark_dirty(stack, rows) -> None:
    """Record rows the plane touched for incremental checkpointing; a
    stack without dirty tracking (bare test doubles) is a no-op."""
    mark = getattr(stack, "mark_dirty", None)
    if mark is not None:
        mark(rows)


# ---------------------------------------------------------------------------
# snapshot wire format for routing tables (uint32 halves — the same
# convention payload keys use). snapshot/restore call these instead of
# keeping their own split/join copies.
# ---------------------------------------------------------------------------
def export_route(table: routing.RouteTable) -> Dict[str, np.ndarray]:
    """Routing table -> checkpoint arrays. Keys ship as uint32 (lo, hi)
    halves plus the int32 rows array — byte-identical probe layout on
    import, independent of the restoring host's device count."""
    lo, hi = routing.split64(table.keys)
    return dict(keys_lo=lo, keys_hi=hi, rows=table.rows)


def import_route(arrays: Dict[str, np.ndarray],
                 meta: Dict[str, int]) -> routing.RouteTable:
    """Checkpoint arrays + manifest meta -> a RouteTable with the EXACT
    slot layout the exporter had (restore must not re-insert: probe
    chains that wrapped the table would land elsewhere and break the
    byte-equality contract)."""
    table = routing.RouteTable(meta["size"])
    lo = np.asarray(arrays["keys_lo"], np.uint32)
    hi = np.asarray(arrays["keys_hi"], np.uint32)
    table.keys = (lo.astype(np.int64) | (hi.astype(np.int64) << np.int64(32)))
    # force a writable copy: checkpoint arrays can arrive as read-only
    # views of device buffers, and insert_many mutates rows in place
    table.rows = np.array(arrays["rows"], np.int32)
    table.count = meta["count"]
    table.max_probe = meta["max_probe"]
    table.version += 1
    return table


def route_like(size: int) -> Dict[str, np.ndarray]:
    """Zero-filled arrays shaped like :func:`export_route` output — the
    restore-side structure template for the checkpoint reader."""
    return dict(keys_lo=np.zeros(size, np.uint32),
                keys_hi=np.zeros(size, np.uint32),
                rows=np.zeros(size, np.int32))
