"""Cross-tenant micro-batching gateway — N clients, one fused dispatch
per kind per tick.

The engine already amortizes device work per CALL: one fused blue-path
program per kind per ingest batch, ``query_many`` answering N queries in
one dispatch, the pipelined ingest queue overlapping host prep with
device work. What it lacked was a front door that turns N concurrent
CLIENTS into few calls — a serial per-client loop pays one dispatch per
client per batch, so serving cost scales with client count instead of
tick count. This module is that front door:

  * ``SynopsisGateway`` — a single-threaded asyncio actor owning one
    ``SDE``. Clients submit JSON request dicts; the ticked micro-batcher
    drains the arrival queue once per tick and coalesces it in
    arrival-preserving runs:

      - a run of ``ingest`` requests (any mix of clients/tenants) is
        ``np.concatenate``d into ONE ``SDE.ingest`` call — one fused
        blue-path dispatch per kind per tick, riding the existing
        ``IngestPipeline`` when the engine is pipelined. Every client's
        ack carries the same coalesced batch id.
      - a run of ``adhoc``/``query_many`` requests flattens into ONE
        ``SDE.query_many`` call (one stacked-estimate dispatch per kind
        touched); answers are demultiplexed back to their submitters.
      - every other request (build/stop/load/status/flush/shutdown)
        executes alone, exactly where it arrived — so per-client
        submission order is the engine's execution order, and the whole
        committed sequence is replayable (see ``replay_log``).

  * **Per-tenant namespaces** — a request's ``tenant`` prefixes every
    ``synopsis_id`` with ``"<tenant>::"`` before it reaches the engine
    (and is stripped from responses), so tenants can neither address nor
    collide with each other's synopses. Stream ids stay SHARED across
    tenants by design: the paper's claim (e) is many concurrent
    workflows maintaining synopses over the same streams, and shared
    stream ids are what lets their ingest coalesce into one dispatch.
    (Corollary: a data-source synopsis — ``stream_id=None`` — observes
    the engine's whole coalesced traffic, not one tenant's slice.)

  * **Per-client response logs** — continuous-query responses route to
    the BUILDING client's bounded ``BoundedResponseLog`` (the engine's
    single global deque generalized per client); responses whose
    subscriber is gone land in the gateway's bounded ``unrouted`` log.

  * **Admission control** — at most ``max_in_flight`` unacknowledged
    requests per client (an ``asyncio.Semaphore``); ``submit`` does not
    enqueue until a slot frees, so a socket server that awaits admission
    before reading the next line gets real backpressure via delayed
    acks (the client's TCP window fills instead of the engine's queue).

Observability: ``kernels.ops.GATEWAY_TICKS`` counts micro-batcher ticks
per gateway tag and ``GATEWAY_COALESCED`` counts client requests folded
into coalesced calls — paired with ``DISPATCH_COUNT``, tests assert the
invariant this module exists for: 64 clients ingesting concurrently
cost ONE blue-path dispatch per kind per tick, not 64.
"""
from __future__ import annotations

import asyncio
import concurrent.futures
import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.kernels import ops as kops
from . import api, pipeline
from .engine import SDE

NS_SEP = "::"


def namespaced(tenant: str, synopsis_id: str) -> str:
    """Tenant-prefixed synopsis key (identity for the empty tenant)."""
    return f"{tenant}{NS_SEP}{synopsis_id}" if tenant else synopsis_id


def check_tenant(tenant: str) -> str:
    """Reject tenant names carrying the namespace separator: tenant
    "a" + synopsis "b::c" would be indistinguishable from tenant
    "a::b" + synopsis "c", silently collapsing two tenants' namespaces
    (synopsis ids may contain "::" freely — only the LEFT side of the
    prefix must be separator-clean for the split to stay unambiguous)."""
    if NS_SEP in tenant:
        raise ValueError(
            f"tenant name {tenant!r} contains the reserved namespace "
            f"separator {NS_SEP!r}")
    return tenant


def strip_ns(tenant: str, synopsis_id: str) -> str:
    prefix = tenant + NS_SEP
    if tenant and synopsis_id.startswith(prefix):
        return synopsis_id[len(prefix):]
    return synopsis_id


class GatewayClient:
    """One connected client: its tenant default, bounded response log
    for continuous output, and the admission-control semaphore."""

    def __init__(self, client_id: str, tenant: str = "", *,
                 max_in_flight: int = 8, log_cap: Optional[int] = 1024):
        self.client_id = client_id
        self.tenant = tenant
        self.log = pipeline.BoundedResponseLog(log_cap)
        # set whenever continuous responses land in ``log`` — a socket
        # server's per-connection pusher task waits on it
        self.wakeup = asyncio.Event()
        self._slots = asyncio.Semaphore(max_in_flight)

    async def admit(self) -> None:
        """Block until an in-flight slot frees (admission control)."""
        await self._slots.acquire()

    def release(self) -> None:
        self._slots.release()


@dataclasses.dataclass
class _Item:
    """One queued request: submitting client, resolved tenant, the raw
    request dict, and the future its response resolves."""
    client: GatewayClient
    tenant: str
    req: Dict[str, Any]
    fut: Any


def _future():
    """An awaitable/result-able future that also works without a running
    event loop (synchronous benchmark/test drivers call
    ``submit_nowait`` + ``tick`` and read ``.result()``)."""
    try:
        return asyncio.get_running_loop().create_future()
    except RuntimeError:
        return concurrent.futures.Future()


class SynopsisGateway:
    """Multi-client micro-batching front door over one ``SDE``.

    Async use (the socket server, concurrent test clients)::

        gw = SynopsisGateway(SDE(), tick_interval=0.001)
        await gw.start()
        client = gw.connect("c0", tenant="acme")
        resp = await gw.submit(client, {"type": "ingest", ...})

    Synchronous use (benchmarks, deterministic tests): skip ``start``,
    enqueue with ``submit_nowait`` and drive ticks explicitly::

        futs = [gw.submit_nowait(c, req) for c, req in traffic]
        gw.tick()                       # ONE fused dispatch per kind
        acks = [f.result() for f in futs]
    """

    def __init__(self, sde: Optional[SDE] = None, *,
                 tick_interval: float = 0.001, max_in_flight: int = 8,
                 client_log_cap: Optional[int] = 1024,
                 tag: str = "gateway", reconciler=None,
                 wal=None, checkpointer=None):
        self.sde = sde if sde is not None else SDE()
        self.tag = tag
        # durability (service/wal.py): lifecycle requests are appended
        # to ``wal`` BEFORE they apply, ingest batches AFTER a
        # successful apply (keyed by the engine-assigned batch id, so a
        # refused batch never reaches the log), and the tick fsyncs
        # before any of its acks can leave the process (tick is
        # synchronous; conn handlers resolve futures only after it
        # returns) — acked implies recoverable. ``checkpointer`` rides
        # the tick too, taking an incremental snapshot every N batches.
        self.wal = wal
        self.checkpointer = checkpointer
        self.checkpoint_error: Optional[str] = None
        # optional elasticity loop (service/reconciler.py): rides the
        # micro-batcher tick — after each tick's coalesced dispatches,
        # ``maybe_step`` reconciles placement when its interval elapsed.
        # A reconcile failure must never take down serving; the last
        # error is kept for inspection instead.
        self.reconciler = reconciler
        self.reconcile_error: Optional[str] = None
        self.tick_interval = tick_interval
        self.max_in_flight = max_in_flight
        self.client_log_cap = client_log_cap
        self.clients: Dict[str, GatewayClient] = {}
        # continuous-query subscriptions: namespaced build synopsis_id
        # -> (client_id, tenant). Entry ids extend the build id with
        # "/<stream>", so routing walks the "/" prefix chain.
        self._subs: Dict[str, Tuple[str, str]] = {}
        # continuous responses whose subscriber disconnected
        self.unrouted = pipeline.BoundedResponseLog(client_log_cap)
        # execution-order record of every state-mutating engine call:
        # ("ingest", sids, vals, mask) for coalesced blue-path batches,
        # ("request", dict) for build/stop/load. ``replay_log`` replays
        # it serially — the oracle the equivalence tests compare against.
        self.commit_log: List[Tuple[Any, ...]] = []
        self.ticks = 0
        self.requests = 0
        self.closed = False
        self.closed_event = asyncio.Event()
        self._queue: List[_Item] = []
        self._arrival = asyncio.Event()
        self._task: Optional[asyncio.Task] = None

    # ------------------------------------------------------------------
    # clients
    # ------------------------------------------------------------------
    def connect(self, client_id: str, tenant: str = "") -> GatewayClient:
        if client_id in self.clients:
            raise ValueError(f"client id {client_id!r} already connected")
        check_tenant(tenant)
        client = GatewayClient(client_id, tenant,
                               max_in_flight=self.max_in_flight,
                               log_cap=self.client_log_cap)
        self.clients[client_id] = client
        return client

    def disconnect(self, client: GatewayClient) -> None:
        """Drop a client. Its subscriptions stay registered — later
        continuous responses fall into the bounded ``unrouted`` log."""
        self.clients.pop(client.client_id, None)

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------
    def submit_nowait(self, client: GatewayClient,
                      req: Dict[str, Any]):
        """Enqueue one request for the next tick; returns the future its
        response will resolve. The tenant is resolved per request
        (``req["tenant"]``, falling back to the client's default)."""
        fut = _future()
        if self.closed:
            fut.set_result(api.Response(
                request_id=str(req.get("request_id", "")), ok=False,
                error="gateway is shut down"))
            return fut
        tenant = str(req.get("tenant") or client.tenant)
        if NS_SEP in tenant:
            # per-request tenant overrides bypass ``connect`` — validate
            # here too, or "a::b" would silently alias tenant "a"'s
            # namespace (see ``check_tenant``)
            fut.set_result(api.Response(
                request_id=str(req.get("request_id", "")), ok=False,
                error=f"tenant name {tenant!r} contains the reserved "
                      f"namespace separator {NS_SEP!r}"))
            return fut
        self._queue.append(_Item(client, tenant, dict(req), fut))
        self._arrival.set()
        return fut

    async def submit(self, client: GatewayClient,
                     req: Dict[str, Any]) -> api.Response:
        """Admission-controlled submit: blocks while the client already
        has ``max_in_flight`` unacknowledged requests, then enqueues and
        awaits the (possibly coalesced) response."""
        await client.admit()
        try:
            return await self.submit_nowait(client, req)
        finally:
            client.release()

    @property
    def queued(self) -> int:
        return len(self._queue)

    # ------------------------------------------------------------------
    # the ticked micro-batcher
    # ------------------------------------------------------------------
    async def start(self) -> None:
        self._task = asyncio.create_task(self._run())

    async def _run(self) -> None:
        while not self.closed:
            await self._arrival.wait()
            if self.tick_interval > 0:
                # let a tick's worth of concurrent traffic accumulate
                await asyncio.sleep(self.tick_interval)
            self._arrival.clear()
            self.tick()

    async def stop(self) -> None:
        """Stop the batcher; queued requests still resolve (with errors
        once the gateway is closed). Idempotent."""
        self.tick()                      # drain what already arrived
        self.closed = True
        self.closed_event.set()
        self._arrival.set()
        if self._task is not None:
            await self._task
            self._task = None
        self.tick()                      # error-out any stragglers

    def tick(self) -> int:
        """Process everything queued right now as ONE tick: coalesce
        arrival-order runs, dispatch, demultiplex, route continuous
        output. Returns the number of requests processed."""
        batch, self._queue = self._queue, []
        if not batch:
            # still route: a pipelined engine may have retired batches
            # (and emitted continuous output) since the last tick
            self._route_continuous()
            self._maybe_reconcile()
            return 0
        self.ticks += 1
        self.requests += len(batch)
        kops.GATEWAY_TICKS[self.tag] += 1
        runs: List[Tuple[str, List[_Item]]] = []
        for item in batch:
            klass = self._class_of(item.req)
            if (runs and runs[-1][0] == klass
                    and klass in ("ingest", "query")):
                runs[-1][1].append(item)
            else:
                runs.append((klass, [item]))
        for klass, items in runs:
            if self.closed:
                for it in items:
                    it.fut.set_result(api.Response(
                        request_id=str(it.req.get("request_id", "")),
                        ok=False, error="gateway is shut down"))
                continue
            if klass == "ingest":
                self._do_ingest(items)
            elif klass == "query":
                self._do_query(items)
            else:
                self._do_one(items[0])
        if self.wal is not None:
            # durable-before-ack: one fsync per tick covers every
            # record this tick appended, before its futures are awaited
            self.wal.sync()
        if self.checkpointer is not None and not self.closed:
            try:
                self.checkpointer.maybe_snapshot()
            except Exception as e:  # noqa: BLE001 - serving must survive
                self.checkpoint_error = repr(e)
        self._route_continuous()
        self._maybe_reconcile()
        return len(batch)

    def _maybe_reconcile(self) -> None:
        if self.reconciler is None or self.closed:
            return
        try:
            self.reconciler.maybe_step()
        except Exception as e:  # noqa: BLE001 - serving must survive
            self.reconcile_error = repr(e)

    @staticmethod
    def _class_of(req: Dict[str, Any]) -> str:
        t = req.get("type")
        if t == "ingest":
            return "ingest"
        if t in ("adhoc", "query_many"):
            return "query"
        return "other"

    # ------------------------------------------------------------------
    # coalesced blue path: one SDE.ingest per run
    # ------------------------------------------------------------------
    def _do_ingest(self, items: List[_Item]) -> None:
        parts = []                       # (item, sids, vals, mask)
        for item in items:
            try:
                sids = np.asarray(item.req.get("stream_ids", []),
                                  np.int64).ravel()
                vals = np.asarray(item.req.get("values", []),
                                  np.float32).ravel()
                if len(sids) != len(vals):
                    raise ValueError(
                        f"ingest batch mismatch: {len(sids)} stream_ids "
                        f"vs {len(vals)} values")
                raw_mask = item.req.get("mask")
                mask = (np.ones(len(sids), bool) if raw_mask is None
                        else np.asarray(raw_mask, bool).ravel())
                if len(mask) != len(sids):
                    raise ValueError(
                        f"ingest batch mismatch: {len(sids)} stream_ids "
                        f"vs {len(mask)} mask entries")
                parts.append((item, sids, vals, mask))
            except Exception as e:  # noqa: BLE001 - fails alone
                item.fut.set_result(api.Response(
                    request_id=str(item.req.get("request_id", "")),
                    ok=False, error=repr(e)))
        if not parts:
            return
        sids = np.concatenate([p[1] for p in parts])
        vals = np.concatenate([p[2] for p in parts])
        mask = np.concatenate([p[3] for p in parts])
        try:
            batch_id = self.sde.ingest(sids, vals, mask)
        except Exception as e:  # noqa: BLE001 - service returns errors
            for item, *_ in parts:
                item.fut.set_result(api.Response(
                    request_id=str(item.req.get("request_id", "")),
                    ok=False, error=repr(e)))
            return
        if self.wal is not None:
            # logged POST-apply, keyed by the batch id the engine really
            # assigned: a batch the engine refuses never reaches the
            # log, so replay cannot be poisoned or steal an acked id.
            # Durable-before-ack still holds — the tick fsyncs before
            # any future's awaiter runs.
            try:
                self.sde.wal_seq = self.wal.append_ingest(
                    batch_id, sids, vals, mask)
            except Exception as e:  # noqa: BLE001 - serving must survive
                # applied but not durable: tell the clients so none of
                # them counts on this batch surviving a crash
                self.commit_log.append(("ingest", sids, vals, mask))
                for item, *_ in parts:
                    item.fut.set_result(api.Response(
                        request_id=str(item.req.get("request_id", "")),
                        ok=False,
                        error=f"ingested but WAL append failed: {e!r}"))
                return
        self.commit_log.append(("ingest", sids, vals, mask))
        kops.note_coalesced("ingest", len(parts))
        for item, part_sids, _, part_mask in parts:
            item.fut.set_result(api.Response(
                request_id=str(item.req.get("request_id", "")),
                value=dict(batch=batch_id, coalesced=len(parts),
                           tuples=int(part_mask.sum()),
                           in_flight=self.sde.pending_batches)))

    # ------------------------------------------------------------------
    # coalesced red path: one SDE.query_many per run
    # ------------------------------------------------------------------
    def _do_query(self, items: List[_Item]) -> None:
        flat: List[api.AdHocQuery] = []
        # (item, start, prefail) — for query_many, prefail maps entry
        # index -> pre-built error response (malformed entries fail
        # alone, mirroring the engine's own query_many semantics)
        slices = []
        for item in items:
            rid = str(item.req.get("request_id", ""))
            if item.req.get("type") == "adhoc":
                start = len(flat)
                flat.append(api.AdHocQuery(
                    request_id=rid,
                    synopsis_id=namespaced(
                        item.tenant, str(item.req.get("synopsis_id", ""))),
                    query=item.req.get("query")))
                slices.append((item, start, None))
            else:                        # query_many: flatten entries
                start = len(flat)
                prefail: Dict[int, api.Response] = {}
                queries = item.req.get("queries") or []
                for i, q in enumerate(queries):
                    sub_rid = f"{rid}/{i}"
                    if isinstance(q, dict):
                        flat.append(api.AdHocQuery(
                            request_id=sub_rid,
                            synopsis_id=namespaced(
                                item.tenant, str(q.get("synopsis_id", ""))),
                            query=q["query"] if "query" in q else {}))
                    else:
                        prefail[i] = api.Response(
                            request_id=sub_rid, ok=False,
                            error="query entry must be an object, got "
                                  f"{type(q).__name__}")
                slices.append((item, start, (len(queries), prefail)))
        try:
            answers = self.sde.query_many(flat) if flat else []
        except Exception as e:  # noqa: BLE001 - service returns errors
            for item, *_ in slices:
                item.fut.set_result(api.Response(
                    request_id=str(item.req.get("request_id", "")),
                    ok=False, error=repr(e)))
            return
        kops.note_coalesced("query", len(items))
        for item, start, many in slices:
            if many is None:             # adhoc: one answer, un-prefixed
                resp = answers[start]
                resp.synopsis_id = strip_ns(item.tenant, resp.synopsis_id)
                item.fut.set_result(resp)
                continue
            n_entries, prefail = many
            sub, cursor = [], start
            for i in range(n_entries):
                if i in prefail:
                    r = prefail[i]
                else:
                    r = answers[cursor]
                    cursor += 1
                    r.synopsis_id = strip_ns(item.tenant, r.synopsis_id)
                sub.append(r)
            n_fail = sum(1 for r in sub if not r.ok)
            item.fut.set_result(api.Response(
                request_id=str(item.req.get("request_id", "")),
                ok=n_fail == 0,
                error=(f"{n_fail}/{len(sub)} queries failed"
                       if n_fail else ""),
                value=[dataclasses.asdict(r) for r in sub]))

    # ------------------------------------------------------------------
    # everything else: serial, in place
    # ------------------------------------------------------------------
    def _do_one(self, item: _Item) -> None:
        req = dict(item.req)
        rtype = req.get("type")
        if item.tenant and isinstance(req.get("synopsis_id"), str):
            req["synopsis_id"] = namespaced(item.tenant,
                                            req["synopsis_id"])
        if item.tenant and isinstance(req.get("workflow_id"), str):
            # outlier workflow ids live in the same per-tenant namespace
            # as synopsis ids (their continuous responses route by them)
            req["workflow_id"] = namespaced(item.tenant,
                                            req["workflow_id"])
        seq = None
        if self.wal is not None and rtype in api.MUTATING_REQUESTS:
            # write-ahead, post-namespacing — replay sees exactly what
            # the engine saw (a request that fails live fails on replay
            # too, changing nothing). A WAL write error refuses the
            # request instead of killing the tick.
            try:
                seq = self.wal.append_request(req)
            except Exception as e:  # noqa: BLE001 - serving must survive
                item.fut.set_result(api.Response(
                    request_id=str(item.req.get("request_id", "")),
                    ok=False, error=f"WAL append failed: {e!r}"))
                return
        resp = self.sde.handle(req)
        if seq is not None:
            self.sde.wal_seq = seq
        if resp.ok and rtype == "ingest_multidim" and self.wal is not None:
            # data path: logged POST-apply keyed by the engine-assigned
            # batch id, like coalesced ingest above
            try:
                self.sde.wal_seq = self.wal.append_ingest_multidim(
                    int(resp.value["batch"]), req)
            except Exception as e:  # noqa: BLE001 - serving must survive
                self.commit_log.append(("request", req))
                item.fut.set_result(api.Response(
                    request_id=str(item.req.get("request_id", "")),
                    ok=False,
                    error=f"ingested but WAL append failed: {e!r}"))
                return
        if resp.ok and (rtype in api.MUTATING_REQUESTS
                        or rtype == "ingest_multidim"):
            self.commit_log.append(("request", req))
            if (rtype in ("build", "build_multidim")
                    and req.get("continuous")):
                cid = str(req.get("client_id") or item.client.client_id)
                self._subs[req.get("synopsis_id", "")] = (cid, item.tenant)
            elif rtype == "track_outliers":
                cid = str(req.get("client_id") or item.client.client_id)
                self._subs[req.get("workflow_id", "")] = (cid, item.tenant)
            elif rtype == "untrack_outliers":
                self._subs.pop(req.get("workflow_id", ""), None)
            elif rtype == "stop":
                dead = req.get("synopsis_id", "")
                self._subs = {k: v for k, v in self._subs.items()
                              if not (k == dead
                                      or k.startswith(dead + "/"))}
        if resp.ok and rtype == "shutdown":
            self.closed = True
            self.closed_event.set()
        if resp.ok and rtype == "status" and item.tenant \
                and isinstance(resp.value, dict):
            # a tenant's status sees ONLY its own namespace (the empty
            # tenant is the admin view over everything)
            prefix = item.tenant + NS_SEP
            resp.value = {k[len(prefix):]: v
                          for k, v in resp.value.items()
                          if k.startswith(prefix)}
        resp.synopsis_id = strip_ns(item.tenant, resp.synopsis_id)
        item.fut.set_result(resp)

    # ------------------------------------------------------------------
    # continuous output: per-client routing
    # ------------------------------------------------------------------
    def _route_continuous(self) -> None:
        """Move every retired continuous response from the engine's
        global log to its subscriber's bounded per-client log, with the
        tenant prefix stripped from both id fields."""
        for r in self.sde.continuous_out.drain():
            owner = self._owner_of(r.synopsis_id)
            if owner is None:
                self.unrouted.append(r)
                continue
            cid, tenant = owner
            client = self.clients.get(cid)
            if client is None:
                self.unrouted.append(r)
                continue
            if tenant:
                r = dataclasses.replace(
                    r,
                    synopsis_id=strip_ns(tenant, r.synopsis_id),
                    request_id=r.request_id.replace(tenant + NS_SEP,
                                                    "", 1))
            client.log.append(r)
            client.wakeup.set()

    def _owner_of(self, synopsis_id: str
                  ) -> Optional[Tuple[str, str]]:
        """Resolve a continuous entry id (``<build id>`` or
        ``<build id>/<stream>``) to its subscriber via the "/" prefix
        chain."""
        p = synopsis_id
        while True:
            if p in self._subs:
                return self._subs[p]
            if "/" not in p:
                return None
            p = p.rsplit("/", 1)[0]


def replay_log(commit_log, sde: Optional[SDE] = None) -> SDE:
    """The serialized oracle: replay a gateway's ``commit_log`` into a
    fresh single-client engine, serially, in commit order. Coalescing
    must be state-invisible — a gateway-driven engine's stacks are
    byte-identical to this replay (float scatter order WITHIN a
    coalesced batch is part of the committed record, which is why the
    log stores the concatenated arrays, not the per-client pieces)."""
    sde = sde if sde is not None else SDE()
    for entry in commit_log:
        if entry[0] == "ingest":
            _, sids, vals, mask = entry
            sde.ingest(sids, vals, mask)
        else:
            sde.handle(entry[1])
    sde.flush()
    return sde
