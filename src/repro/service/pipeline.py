"""Pipelined blue path — the bounded async ingest queue.

``SDE.ingest`` dispatches one fused update program per kind and one
stacked-estimate program per kind with continuous queries; JAX async
dispatch lets all of them run un-awaited. What used to serialize the
caller was ``_emit_continuous`` materializing the estimate outputs to
host (``np.asarray``) before ``ingest`` returned — a forced device→host
sync per batch, so host-side prep for batch N+1 (np normalization,
``split64``/``fold64``, mask work) could never overlap batch N's device
work.

This module decouples emission from ingestion:

  * ``PendingBatch`` — one ingest batch's un-materialized continuous
    outputs: per-kind device futures plus the monotonic batch id that
    keys their response ids.
  * ``IngestPipeline`` — a bounded (default depth-2, double-buffered)
    queue of pending batches. Submitting batch N+1 while N is in flight
    is free; submitting past the depth retires the oldest batch
    (materializes its futures into the engine's continuous output,
    oldest first, so response order is identical to eager execution).
    ``flush()`` is the explicit barrier: it drains everything, and the
    engine fences (flushes) before any operation that reads or mutates
    engine state — ``query_many``, stop/grow/build, snapshot, merge.
  * ``BoundedResponseLog`` — the ``continuous_out`` sink: a deque with a
    configurable cap and a dropped-count stat, so unread continuous
    responses cannot grow without bound.

The pipeline never re-orders or re-dispatches device work: programs are
dispatched in ingest order by the engine; this queue only defers the
host-side materialization. Retirement depth is observable through
``kernels.ops.PIPELINE_IN_FLIGHT`` / ``PIPELINE_MAX_IN_FLIGHT``.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Callable, List, Optional, Tuple

from repro.kernels import ops as kops


class BoundedResponseLog(collections.deque):
    """A bounded response sink: the engine's global ``continuous_out``
    and — through the gateway — one log per connected client. A deque
    bounded at ``cap`` responses; when full, appending evicts the oldest
    response and counts it in ``dropped`` (the continuous stream keeps
    flowing; a consumer that falls behind loses the oldest results,
    never the newest)."""

    def __init__(self, cap: Optional[int] = 65536):
        super().__init__(maxlen=cap if cap and cap > 0 else None)
        self.dropped = 0

    def append(self, response) -> None:
        if self.maxlen is not None and len(self) == self.maxlen:
            self.dropped += 1        # deque(maxlen) evicts from the left
        super().append(response)

    def drain(self) -> List[Any]:
        """Pop EVERY unread response, oldest first — one call per
        consumer wake-up, so a server writes a whole backlog with one
        syscall instead of one write per response."""
        out = []
        while self:
            out.append(self.popleft())
        return out


@dataclasses.dataclass
class PendingBatch:
    """One ingest batch's deferred continuous emission.

    ``emissions`` holds ``(ids, take, out)`` per kind: the continuous
    synopsis ids, the per-query result slicer from ``_plan_queries``,
    and the (device-future) ``estimate_all`` output. ``extras`` holds
    ``(plan, out)`` pairs for the continuous OUTLIER workflows
    (service/outliers.py): each plan finishes host-side at retirement —
    scoring the deferred estimates and emitting flagged groups — so
    outlier ticks pipeline exactly like continuous queries. Nothing here
    pins the engine's mutable state — lifecycle changes after dispatch
    cannot corrupt a pending batch, only delay its materialization.
    """
    batch_id: int
    emissions: List[Tuple[List[str], Callable[..., Any], Any]]
    extras: List[Tuple[Any, Any]] = dataclasses.field(default_factory=list)


class IngestPipeline:
    """Bounded queue of in-flight ingest batches (double-buffered at the
    default ``depth=2``): the engine submits each batch's pending
    emission right after dispatching its update programs and returns to
    the caller without waiting. The queue retires (materializes) the
    oldest batch only when a new submission would exceed the depth, or
    on an explicit ``flush()``.
    """

    def __init__(self, retire: Callable[[PendingBatch], None],
                 depth: int = 2, tag: str = ""):
        if depth < 1:
            raise ValueError(f"pipeline depth must be >= 1, got {depth}")
        self.depth = depth
        self.tag = tag
        self._retire = retire
        self._queue: collections.deque[PendingBatch] = collections.deque()
        self.batches_retired = 0

    @property
    def in_flight(self) -> int:
        return len(self._queue)

    @property
    def in_flight_ids(self) -> List[int]:
        """Batch ids still pending materialization, oldest first — the
        durability layer reads this to know which acked batches a crash
        right now would owe to WAL replay."""
        return [p.batch_id for p in self._queue]

    def submit(self, pending: PendingBatch) -> None:
        """Enqueue one batch's deferred emission; retires the oldest
        batch(es) beyond the depth bound so at most ``depth`` batches
        are ever pending materialization."""
        self._queue.append(pending)
        while len(self._queue) > self.depth:
            self._retire_oldest()
        kops.note_in_flight(self.tag, len(self._queue))

    def flush(self) -> int:
        """Explicit barrier: materialize EVERY pending batch, oldest
        first. Returns the number of batches drained."""
        n = 0
        while self._queue:
            self._retire_oldest()
            n += 1
        if n:
            kops.note_in_flight(self.tag, 0)
        return n

    def _retire_oldest(self) -> None:
        self._retire(self._queue.popleft())
        self.batches_retired += 1
