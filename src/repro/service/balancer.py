"""SDEaaS as a cost estimator for workflow placement (paper Section 7,
"...as a Cost Estimator for Enhanced Horizontal Scalability").

The engine's HLL answers "how many pieces of work" (distinct streams per
interval) and its CountMin answers "how big is each piece" (per-stream
frequency). The optimizer then sizes the worker pool and balances load
with Worst-Fit-Decreasing bin packing — exactly the paper's recipe
([24]'s WFD), so no worker is overloaded and throughput doesn't collapse
on skewed streams.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

from . import api
from .engine import SDE


@dataclasses.dataclass
class Placement:
    assignments: Dict[int, int]          # stream -> worker
    loads: List[float]                   # per-worker estimated load
    n_workers: int

    @property
    def imbalance(self) -> float:
        """max/mean load ratio (1.0 = perfect)."""
        mean = max(float(np.mean(self.loads)), 1e-9)
        return float(np.max(self.loads)) / mean


def estimate_workload(sde: SDE, hll_id: str, cm_id: str,
                      candidate_streams: Sequence[int]):
    """Query the engine's synopses — (#active streams, per-stream load) —
    through the batched red path: one ``query_many`` call, one jitted
    stacked-estimate dispatch per kind touched (the per-stream CM loads
    are a single [1, n_candidates] point-query batch). Candidate stream
    ids are arbitrary 63-bit ints: the engine folds item ids exactly the
    way ingest folds stream ids, so hashed id populations balance the
    same as dense ones."""
    for sid in (hll_id, cm_id):
        if sid not in sde.entries:
            raise KeyError(f"unknown synopsis {sid!r}")
    q_n, q_f = sde.query_many([
        api.AdHocQuery(request_id="wl-n", synopsis_id=hll_id),
        api.AdHocQuery(request_id="wl-f", synopsis_id=cm_id,
                       query={"items": [int(s) for s in candidate_streams]}),
    ])
    for q in (q_n, q_f):
        if not q.ok:
            raise ValueError(q.error)   # e.g. uncoercible candidate ids
    return float(q_n.value), np.asarray(q_f.value, np.float64)


def worst_fit_decreasing(stream_ids: Sequence[int],
                         stream_loads: Sequence[float],
                         n_workers: int) -> Placement:
    """WFD bin packing: heaviest piece first, into the least-loaded bin."""
    order = np.argsort(-np.asarray(stream_loads))
    loads = [0.0] * n_workers
    assignments: Dict[int, int] = {}
    for i in order:
        w = int(np.argmin(loads))
        assignments[int(stream_ids[i])] = w
        loads[w] += float(stream_loads[i])
    return Placement(assignments=assignments, loads=loads,
                     n_workers=n_workers)


def plan_workers(sde: SDE, hll_id: str, cm_id: str,
                 candidate_streams: Sequence[int],
                 capacity_per_worker: float) -> Placement:
    """Size the pool from the HLL cardinality + CM loads, then pack."""
    _, loads = estimate_workload(sde, hll_id, cm_id, candidate_streams)
    total = float(loads.sum())
    n_workers = max(1, int(np.ceil(total / capacity_per_worker)))
    return worst_fit_decreasing(candidate_streams, loads, n_workers)
