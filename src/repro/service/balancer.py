"""SDEaaS as a cost estimator for workflow placement (paper Section 7,
"...as a Cost Estimator for Enhanced Horizontal Scalability").

The engine's HLL answers "how many pieces of work" (distinct streams per
interval) and its CountMin answers "how big is each piece" (per-stream
frequency). The optimizer then sizes the worker pool and balances load
with Worst-Fit-Decreasing bin packing — exactly the paper's recipe
([24]'s WFD), so no worker is overloaded and throughput doesn't collapse
on skewed streams.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import api
from .engine import SDE


@dataclasses.dataclass
class PlacementDelta:
    """The minimal move set turning one placement into another — the
    reconciler's work order. ``target`` is the target placement with its
    worker labels rewritten to maximally overlap ``prev`` (see
    :meth:`Placement.diff`); ``moves`` lists ``(stream, src_worker,
    dst_worker)`` with ``src_worker=None`` for streams new in the
    target; ``dropped`` lists streams that left."""

    moves: List[Tuple[int, Optional[int], int]]
    dropped: List[int]
    target: "Placement"

    def apply(self, prev: "Placement") -> Dict[int, int]:
        """Replay the delta onto ``prev``'s assignment — by construction
        this reproduces ``target.assignments`` exactly (the property the
        tests lock)."""
        dropped = set(self.dropped)
        out = {s: w for s, w in prev.assignments.items()
               if s not in dropped}
        for s, _, dst in self.moves:
            out[s] = dst
        return out


@dataclasses.dataclass
class Placement:
    assignments: Dict[int, int]          # stream -> worker
    loads: List[float]                   # per-worker estimated load
    n_workers: int

    @property
    def imbalance(self) -> float:
        """max/mean load ratio (1.0 = perfect)."""
        mean = max(float(np.mean(self.loads)), 1e-9)
        return float(np.max(self.loads)) / mean

    def diff(self, prev: "Placement") -> PlacementDelta:
        """Minimal stream moves from ``prev`` to this placement.

        WFD assigns worker labels arbitrarily (bin 0 of the new plan has
        no relation to bin 0 of the old), so a naive label-wise diff
        moves nearly everything. When worker counts match, the target's
        labels are first permuted to maximize stream overlap with
        ``prev`` — an exact assignment problem solved by the Hungarian
        method on the overlap matrix (W is the worker pool, so O(W^3)
        is nothing) — and only streams whose *relabeled* worker changed
        move. Different worker counts skip relabeling (labels are
        incomparable across pool sizes)."""
        target = self
        if prev.n_workers == self.n_workers and self.n_workers > 1:
            w = self.n_workers
            overlap = np.zeros((w, w), np.int64)
            for s, nw in self.assignments.items():
                pw = prev.assignments.get(s)
                if pw is not None:
                    overlap[nw, pw] += 1
            perm = _max_overlap_labels(overlap)
            if any(perm[i] != i for i in range(w)):
                loads = [0.0] * w
                for i, load in enumerate(self.loads):
                    loads[perm[i]] = load
                target = Placement(
                    assignments={s: perm[nw] for s, nw
                                 in self.assignments.items()},
                    loads=loads, n_workers=w)
        moves = []
        for s in sorted(target.assignments):
            tw = target.assignments[s]
            pw = prev.assignments.get(s)
            if pw != tw:
                moves.append((s, pw, tw))
        dropped = sorted(s for s in prev.assignments
                         if s not in target.assignments)
        return PlacementDelta(moves=moves, dropped=dropped, target=target)


def _max_overlap_labels(overlap: np.ndarray) -> List[int]:
    """Exact max-weight label matching: ``perm[new_worker] ->
    prev_label`` maximizing ``sum(overlap[nw, perm[nw]])`` (Hungarian
    method with potentials, O(W^3), deterministic)."""
    n = overlap.shape[0]
    cost = (overlap.max() - overlap).astype(np.float64)  # minimize
    INF = float("inf")
    u = [0.0] * (n + 1)
    v = [0.0] * (n + 1)
    p = [0] * (n + 1)        # p[col] = row matched to col (1-based)
    way = [0] * (n + 1)
    for i in range(1, n + 1):
        p[0] = i
        j0 = 0
        minv = [INF] * (n + 1)
        used = [False] * (n + 1)
        while True:
            used[j0] = True
            i0, delta, j1 = p[j0], INF, -1
            for j in range(1, n + 1):
                if not used[j]:
                    cur = cost[i0 - 1][j - 1] - u[i0] - v[j]
                    if cur < minv[j]:
                        minv[j] = cur
                        way[j] = j0
                    if minv[j] < delta:
                        delta = minv[j]
                        j1 = j
            for j in range(n + 1):
                if used[j]:
                    u[p[j]] += delta
                    v[j] -= delta
                else:
                    minv[j] -= delta
            j0 = j1
            if p[j0] == 0:
                break
        while j0:
            j1 = way[j0]
            p[j0] = p[j1]
            j0 = j1
    perm = [0] * n
    for j in range(1, n + 1):
        perm[p[j] - 1] = j - 1
    return perm


def estimate_workload(sde: SDE, hll_id: str, cm_id: str,
                      candidate_streams: Sequence[int]):
    """Query the engine's synopses — (#active streams, per-stream load) —
    through the batched red path: one ``query_many`` call, one jitted
    stacked-estimate dispatch per kind touched (the per-stream CM loads
    are a single [1, n_candidates] point-query batch). Candidate stream
    ids are arbitrary 63-bit ints: the engine folds item ids exactly the
    way ingest folds stream ids, so hashed id populations balance the
    same as dense ones."""
    for sid in (hll_id, cm_id):
        if sid not in sde.entries:
            raise KeyError(f"unknown synopsis {sid!r}")
    q_n, q_f = sde.query_many([
        api.AdHocQuery(request_id="wl-n", synopsis_id=hll_id),
        api.AdHocQuery(request_id="wl-f", synopsis_id=cm_id,
                       query={"items": [int(s) for s in candidate_streams]}),
    ])
    for q in (q_n, q_f):
        if not q.ok:
            raise ValueError(q.error)   # e.g. uncoercible candidate ids
    return float(q_n.value), np.asarray(q_f.value, np.float64)


def worst_fit_decreasing(stream_ids: Sequence[int],
                         stream_loads: Sequence[float],
                         n_workers: int) -> Placement:
    """WFD bin packing: heaviest piece first, into the least-loaded bin.

    The bin scan is a heap — O(n log w), not the old O(n·w) per-item
    ``np.argmin`` — and fully deterministic: items sort by decreasing
    load with input order breaking load ties (stable sort), and equally
    loaded bins hand out the LOWEST worker id first (the ``(load, id)``
    heap key), so the same estimates always produce the same placement
    (reconcilers must not flap between equivalent plans).

    Duplicate candidate ids are coalesced FIRST (loads summed, first
    occurrence fixing the order): a stream is one piece of work however
    many times an estimator listed it. Packing duplicates separately
    let the same id land in two bins — the dict assignment kept only
    the last bin while BOTH loads stayed counted, so ``sum(loads)``
    exceeded the load of the streams actually assigned and the
    reconciler chased phantom imbalance."""
    loads_arr = np.asarray(stream_loads, np.float64)
    if len(stream_ids) != len(loads_arr):
        raise ValueError(
            f"worst_fit_decreasing: {len(stream_ids)} stream_ids vs "
            f"{len(loads_arr)} loads — the two must align 1:1")
    merged: Dict[int, float] = {}
    for sid, load in zip(stream_ids, loads_arr):
        sid = int(sid)
        merged[sid] = merged.get(sid, 0.0) + float(load)
    stream_ids = list(merged)
    loads_arr = np.fromiter(merged.values(), np.float64,
                            count=len(merged))
    order = np.argsort(-loads_arr, kind="stable")
    heap: List[Tuple[float, int]] = [(0.0, w) for w in range(n_workers)]
    loads = [0.0] * n_workers
    assignments: Dict[int, int] = {}
    for i in order:
        load, w = heapq.heappop(heap)
        assignments[int(stream_ids[i])] = w
        load += float(loads_arr[i])
        loads[w] = load
        heapq.heappush(heap, (load, w))
    return Placement(assignments=assignments, loads=loads,
                     n_workers=n_workers)


def plan_workers(sde: SDE, hll_id: str, cm_id: str,
                 candidate_streams: Sequence[int],
                 capacity_per_worker: float) -> Placement:
    """Size the pool from the HLL cardinality + CM loads, then pack."""
    _, loads = estimate_workload(sde, hll_id, cm_id, candidate_streams)
    total = float(loads.sum())
    n_workers = max(1, int(np.ceil(total / capacity_per_worker)))
    return worst_fit_decreasing(candidate_streams, loads, n_workers)
