"""Synopsis-based workflow optimization (paper Section 7, Plans 1-3).

Given a workflow (the paper's Figure 4: Split -> Filter/Count -> Join ->
Window -> AggregativeOperation -> Threshold/Clusters) and an accuracy
budget, rewrite exact operators to SDE-backed approximate ones and pick
the plan with the best predicted throughput under the budget.

Cost model (napkin math, per batch of U updates over N streams, window w,
F coefficients): exact pairwise aggregation costs N^2 w; DFT bucketing
costs U*F updates + candidate_fraction * N^2 * F comparisons; AMS rewrite
of Count costs U*depth. Error model: AMS eps_ams; DFT truncation is
one-sided (no false dismissals) with estimate bias bounded by the
discarded spectral mass. These formulas are validated against measured
throughputs in benchmarks/fig6.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class WorkflowSpec:
    n_streams: int
    window: int = 64
    updates_per_batch: int = 4096
    dft_coeffs: int = 8
    threshold: float = 0.9
    ams_eps: float = 0.05
    # measured/assumed candidate fraction after DFT bucket pruning
    candidate_fraction: float = 0.02


@dataclasses.dataclass(frozen=True)
class Plan:
    name: str
    rewrites: Dict[str, str]
    cost: float            # relative compute units per batch
    error: float           # worst-case relative error introduced
    parallelizable: bool   # whether the dominant stage shards


class Planner:
    def __init__(self, spec: WorkflowSpec):
        self.spec = spec

    def plans(self) -> List[Plan]:
        s = self.spec
        n2 = float(s.n_streams) ** 2 / 2.0
        exact = Plan(
            name="Plan0-exact",
            rewrites={},
            cost=s.updates_per_batch + n2 * s.window,
            error=0.0, parallelizable=True)
        plan1 = Plan(
            name="Plan1-AMS",                      # Count -> SDE.AMS
            rewrites={"Count": "SDE.AMS"},
            cost=s.updates_per_batch * 4 + n2 * s.dft_coeffs * 4,
            error=s.ams_eps, parallelizable=True)
        plan2 = Plan(
            name="Plan2-DFT",     # Window+Aggregative -> SDE.DFT buckets
            rewrites={"Window": "SDE.DFT", "AggregativeOperation":
                      "SDE.DFT.bucketed_pairs"},
            cost=(s.updates_per_batch * s.dft_coeffs
                  + s.candidate_fraction * n2 * s.dft_coeffs),
            error=_dft_error(s), parallelizable=True)
        plan3 = Plan(
            name="Plan3-AMS+DFT",
            rewrites={"Count": "SDE.AMS", "Window": "SDE.DFT",
                      "AggregativeOperation": "SDE.DFT.bucketed_pairs"},
            cost=(s.updates_per_batch * 4
                  + s.candidate_fraction * n2 * s.dft_coeffs),
            error=s.ams_eps + _dft_error(s), parallelizable=True)
        return [exact, plan1, plan2, plan3]

    def choose(self, accuracy_budget: float) -> Plan:
        """Best predicted throughput (lowest cost) within the budget."""
        feasible = [p for p in self.plans() if p.error <= accuracy_budget]
        return min(feasible, key=lambda p: p.cost)


def spec_from_engine(sde, hll_id: str, cm_id: str,
                     candidate_streams, **overrides) -> WorkflowSpec:
    """Calibrate the cost model from a LIVE engine's synopses with one
    batched red-path call (the paper's 'SDE as a cost estimator'): the
    HLL supplies n_streams, the CM point-query batch supplies the update
    volume. ``candidate_streams`` may be arbitrary 63-bit stream ids
    (hashed routing — ids are folded consistently with ingest).
    ``overrides`` pin any spec field the workflow fixes."""
    from .balancer import estimate_workload
    n_active, loads = estimate_workload(sde, hll_id, cm_id,
                                        candidate_streams)
    fields = dict(n_streams=max(1, int(round(n_active))),
                  updates_per_batch=max(1, int(loads.sum())))
    fields.update(overrides)
    return WorkflowSpec(**fields)


def _dft_error(s: WorkflowSpec) -> float:
    # truncation keeps >= the energy in the first F of w/2 unique coeffs;
    # for near-threshold pairs the bias is bounded by the discarded mass.
    kept = min(1.0, 2.0 * s.dft_coeffs / s.window)
    return max(0.0, (1.0 - kept) * (1.0 - s.threshold))
