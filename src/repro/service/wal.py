"""Write-ahead ingest log + crash recovery for the serving layer.

The engine's snapshots (``SDE.snapshot``, incremental or full) bound
recovery work to the last checkpoint; this module covers the tail —
everything acked AFTER it. The serving front ends
(``launch/sde_server.py`` JSON-lines mode and the
``SynopsisGateway`` micro-batcher) append lifecycle requests BEFORE
applying them (replay re-executes verbatim; a request that failed live
fails identically on replay) and ingest batches AFTER a successful
apply, keyed by the batch id the engine actually assigned — an ingest
that fails live never reaches the log, so replay can never consume a
batch id an acked batch owns. Either way the record is fsynced before
the ack leaves the process, so the durability contract is::

    acked  =>  in the WAL  =>  recoverable

Recovery (:func:`recover`) = restore the latest snapshot + replay the
WAL tail through the NORMAL ingest/request path. Exactly-once holds by
two independent watermarks, both persisted in every snapshot manifest:

  * ``seq``   — every WAL record carries a monotonic sequence number;
    replay skips records with ``seq <= sde.wal_seq`` (also what makes
    replay idempotent under duplicate or overlapping tails).
  * ``batch`` — ingest records additionally carry the monotonic engine
    batch id they became; replay skips batches
    ``<= sde.batches_ingested`` (belt-and-braces for snapshots taken by
    other writers into the same lineage).

Records are JSON lines (one fsync per serving tick, not per record); a
torn FINAL line — the signature of a crash mid-append — is tolerated
and dropped, torn interior lines are corruption and raise.
"""
from __future__ import annotations

import json
import os
import sys
from typing import Any, Dict, Iterator, Optional

import numpy as np

from repro.kernels import ops as kops
from .api import MUTATING_REQUESTS  # noqa: F401 — the replay set lives
#     with the request schemata now; re-exported here for compatibility
from .engine import SDE


class WriteAheadLog:
    """Append-only JSON-lines log of state-mutating engine calls.

    One instance per serving process; ``append_*`` buffers, ``sync``
    makes everything appended so far durable (flush + fsync — the
    serving loop calls it once per tick, before acks go out). Reopening
    an existing log resumes its sequence numbering, so a recovered
    server appends where the crashed one stopped."""

    def __init__(self, path: str, tag: str = "wal"):
        self.path = path
        self.tag = tag
        self.seq = 0
        # highest seq dropped by a truncation (a "trunc" marker record
        # persists it, so numbering never restarts inside a lineage)
        self._trunc_seq = 0
        if os.path.exists(path):
            for rec in read_records(path):
                self.seq = max(self.seq, int(rec.get("seq", 0)))
                if rec.get("kind") == "trunc":
                    self._trunc_seq = max(self._trunc_seq,
                                          int(rec.get("seq", 0)))
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        self._fh = open(path, "a", encoding="utf-8")
        self._dirty = False

    def append_ingest(self, batch: int, stream_ids, values,
                      mask=None) -> int:
        """Log one ingest batch. The serving front ends call this right
        AFTER a successful ``sde.ingest`` with the batch id the engine
        actually assigned (the second idempotence watermark), and fsync
        before the ack leaves — so the WAL never holds a record for an
        ingest that failed live, and batch ids in the log are exactly
        the acked ones."""
        return self._append(dict(
            kind="ingest", batch=int(batch),
            sids=np.asarray(stream_ids, np.int64).ravel().tolist(),
            vals=np.asarray(values, np.float32).ravel().tolist(),
            mask=(None if mask is None
                  else np.asarray(mask, bool).ravel().tolist())))

    def append_ingest_multidim(self, batch: int,
                               req: Dict[str, Any]) -> int:
        """Log one multidim ingest batch (the attribute-tagged form of
        ``append_ingest``): the raw request replays through the engine's
        normal ``ingest_multidim`` path, which re-derives the expanded
        group keys deterministically. Logged POST-apply with the engine
        batch id, same contract as ``append_ingest``."""
        return self._append(dict(kind="ingest_md", batch=int(batch),
                                 req=dict(req)))

    def append_request(self, req: Dict[str, Any]) -> int:
        """Log one lifecycle request (``api.MUTATING_REQUESTS``), already
        namespaced exactly as the engine will see it."""
        return self._append(dict(kind="req", req=dict(req)))

    def _append(self, rec: Dict[str, Any]) -> int:
        self.seq += 1
        rec["seq"] = self.seq
        self._fh.write(json.dumps(rec) + "\n")
        self._dirty = True
        kops.note_wal_append(self.tag)
        return self.seq

    def sync(self) -> None:
        """Make every appended record durable (flush + fsync). The
        serving loop's durable-before-ack point."""
        if not self._dirty:
            return
        self._fh.flush()
        os.fsync(self._fh.fileno())
        self._dirty = False

    def truncate_through(self, seq: int) -> None:
        """Drop every record with ``seq <=`` the watermark — they are
        folded into a snapshot that durably landed, so replay will never
        need them. Atomic (tmp + fsync + rename); a ``trunc`` marker
        record persists the watermark so a reopened log resumes its
        sequence numbering past the dropped records instead of reusing
        them (which would make replay skip genuinely new appends)."""
        seq = int(seq)
        if seq <= self._trunc_seq:
            return                       # nothing new to drop
        self.sync()
        tmp = self.path + ".tmp"
        with open(tmp, "w", encoding="utf-8") as f:
            f.write(json.dumps(dict(kind="trunc", seq=seq)) + "\n")
            # stream old -> new: never materializes the kept tail
            for r in read_records(self.path):
                if int(r.get("seq", 0)) > seq:
                    f.write(json.dumps(r) + "\n")
            f.flush()
            os.fsync(f.fileno())
        self._fh.close()
        os.replace(tmp, self.path)
        self._fh = open(self.path, "a", encoding="utf-8")
        self._trunc_seq = seq
        self.seq = max(self.seq, seq)

    def close(self) -> None:
        if self._fh.closed:
            return
        self.sync()
        self._fh.close()


def read_records(path: str) -> Iterator[Dict[str, Any]]:
    """Parse a WAL file, STREAMING: yields records one line at a time so
    recovery of a long un-truncated tail is O(1) in memory, never
    O(log size). A torn FINAL record (crash mid-append, fsync never
    completed — the ack for it never left either) is dropped; a torn
    interior record means real corruption and raises — detection is
    deferred one record (an unparseable line is held until the NEXT
    non-empty line proves it interior), so the error surfaces during
    iteration, not at generator creation."""
    with open(path, encoding="utf-8") as f:
        bad_line = None
        for lineno, raw in enumerate(f, 1):
            line = raw.strip()
            if not line:
                continue
            if bad_line is not None:
                raise ValueError(
                    f"corrupt WAL record at {path}:{bad_line} (not the "
                    "final line — this is not a torn append)")
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                bad_line = lineno        # torn tail unless more follows
                continue
            yield rec


def replay(sde: SDE, path: str) -> int:
    """Replay a WAL tail through the engine's normal paths. Skips
    records already folded into ``sde`` (``seq <= sde.wal_seq``; ingest
    batches ``<= sde.batches_ingested``), so replay is idempotent under
    duplicate/overlapping tails and exactly-once on top of any snapshot
    of the same lineage. Reads stream (``read_records`` is a generator),
    so replaying an arbitrarily long tail holds one record at a time.
    Returns the number of records applied."""
    if not os.path.exists(path):
        return 0
    n = 0
    for rec in read_records(path):
        seq = int(rec.get("seq", 0))
        if seq <= sde.wal_seq:
            continue
        kind = rec.get("kind")
        if kind == "ingest":
            batch = rec.get("batch")
            if batch is not None and int(batch) <= sde.batches_ingested:
                sde.wal_seq = seq        # snapshot already folded it
                continue
            try:
                sde.ingest(np.asarray(rec["sids"], np.int64),
                           np.asarray(rec["vals"], np.float32),
                           None if rec.get("mask") is None
                           else np.asarray(rec["mask"], bool))
            except Exception as e:  # noqa: BLE001 - poisoned record
                # ingest records are logged post-apply, so a record that
                # fails here came from a pre-fix log (logged before
                # validation) — the live call failed too, no batch id
                # was consumed, and recovery must not die on it
                print(f"[wal] skipping unreplayable ingest record "
                      f"seq={seq}: {e!r}", file=sys.stderr)
                sde.wal_seq = seq
                continue
        elif kind == "ingest_md":
            batch = rec.get("batch")
            if batch is not None and int(batch) <= sde.batches_ingested:
                sde.wal_seq = seq        # snapshot already folded it
                continue
            sde.handle(rec["req"])       # normal ingest_multidim path
        elif kind == "req":
            # lifecycle requests re-execute verbatim; a request that
            # failed live fails identically here (no state change)
            sde.handle(rec["req"])
        else:
            # e.g. the "trunc" watermark marker: carries no state —
            # just advance the seq cursor past it
            sde.wal_seq = seq
            continue
        sde.wal_seq = seq
        n += 1
    return n


class Checkpointer:
    """Periodic off-hot-path snapshots, paced by ingest batches: call
    ``maybe_snapshot()`` once per serving tick and every ``interval``
    ingested batches it takes one ``SDE.snapshot`` — incremental (a
    dirty-row delta chained on the last full base, rebasing every
    ``rebase_every`` deltas) and asynchronous (background npz write) by
    default. Steps continue from whatever the directory already holds,
    so a recovered server extends the existing lineage."""

    def __init__(self, sde: SDE, directory: str, *, interval: int = 8,
                 keep: int = 3, rebase_every: int = 8,
                 incremental: bool = True, async_: bool = True,
                 wal: Optional[WriteAheadLog] = None):
        from repro.training import checkpoint as ckpt
        self.sde = sde
        self.directory = directory
        self.interval = max(1, int(interval))
        self.keep = keep
        self.rebase_every = rebase_every
        self.incremental = incremental
        self.async_ = async_
        # when given the serving WAL, records folded into a snapshot
        # that durably landed are truncated away, bounding log growth
        # and restart re-parse time
        self.wal = wal
        last = ckpt.latest_step(directory)
        self.next_step = 0 if last is None else last + 1
        self._last_batches = sde.batches_ingested
        # wal_seq covered by the previous snapshot REQUEST — promoted to
        # a truncation watermark only once that save is known durable
        self._last_snap_seq: Optional[int] = None
        self.snapshots = 0

    def maybe_snapshot(self) -> Optional[str]:
        """Snapshot iff ``interval`` batches landed since the last one.
        Returns the mode taken ("full"/"delta") or None."""
        if self.sde.batches_ingested - self._last_batches < self.interval:
            return None
        return self.snapshot()

    def snapshot(self) -> str:
        failures = self.sde.ckpt_failures
        seq_now = self.sde.wal_seq
        mode = self.sde.snapshot(
            self.directory, self.next_step,
            incremental=self.incremental, keep=self.keep,
            async_=self.async_, rebase_every=self.rebase_every)
        # Truncate only through a snapshot KNOWN durable. Sync saves
        # land before SDE.snapshot returns (a failure raises above);
        # async saves lag one snapshot — SDE.snapshot joined the
        # previous background write and bumped ckpt_failures if it
        # never landed, so an unchanged counter certifies it.
        durable_seq = None
        if not self.async_:
            durable_seq = seq_now
        elif self._last_snap_seq and self.sde.ckpt_failures == failures:
            durable_seq = self._last_snap_seq
        if self.wal is not None and durable_seq:
            try:
                self.wal.truncate_through(durable_seq)
            except OSError:
                pass                     # rotation is best-effort only
        self._last_snap_seq = seq_now
        self.next_step += 1
        self._last_batches = self.sde.batches_ingested
        self.snapshots += 1
        return mode


def recover(checkpoint_dir: Optional[str], wal_path: Optional[str], *,
            pipelined: Optional[bool] = None, mesh=None,
            rules=None) -> SDE:
    """The restart path: restore the latest snapshot (a fresh engine
    when there is none) and replay the WAL tail. The result is
    byte-identical to the pre-crash engine's acked state."""
    from repro.training import checkpoint as ckpt
    if (checkpoint_dir is not None
            and ckpt.latest_step(checkpoint_dir) is not None):
        sde = SDE.restore(checkpoint_dir, mesh=mesh, rules=rules,
                          pipelined=pipelined)
    else:
        sde = SDE(mesh=mesh, rules=rules, pipelined=pipelined)
    if wal_path is not None:
        replay(sde, wal_path)
    return sde
