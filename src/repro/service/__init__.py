# SDE-as-a-Service: the always-on engine, its JSON API and the
# accuracy-budget workflow planner (paper Sections 3, 4, 7).
from .api import (Request, Response, parse_request, BuildSynopsis,
                  StopSynopsis, LoadSynopsis, AdHocQuery, QueryMany,
                  StatusReport)
from .engine import SDE, Federation
from .planner import Planner, WorkflowSpec

__all__ = ["Request", "Response", "parse_request", "BuildSynopsis",
           "StopSynopsis", "LoadSynopsis", "AdHocQuery", "QueryMany",
           "StatusReport", "SDE", "Federation", "Planner", "WorkflowSpec"]
