# SDE-as-a-Service: the always-on engine, its JSON API, the pipelined
# blue path and the accuracy-budget workflow planner (paper Sections 3,
# 4, 7).
from .api import (Request, Response, parse_request, BuildSynopsis,
                  StopSynopsis, LoadSynopsis, AdHocQuery, FederatedQuery,
                  QueryMany, Ingest, Flush, StatusReport)
from .engine import SDE, Federation
from .pipeline import BoundedResponseLog, IngestPipeline, PendingBatch
from .planner import Planner, WorkflowSpec

__all__ = ["Request", "Response", "parse_request", "BuildSynopsis",
           "StopSynopsis", "LoadSynopsis", "AdHocQuery", "FederatedQuery",
           "QueryMany", "Ingest", "Flush", "StatusReport", "SDE",
           "Federation", "BoundedResponseLog", "IngestPipeline",
           "PendingBatch", "Planner", "WorkflowSpec"]
