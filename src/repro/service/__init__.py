# SDE-as-a-Service: the always-on engine, its JSON API, the pipelined
# blue path, the multi-client micro-batching gateway, the row-granular
# migration plane + elasticity reconciler, and the accuracy-budget
# workflow planner (paper Sections 3, 4, 7).
from .api import (Request, Response, parse_request, BuildSynopsis,
                  StopSynopsis, LoadSynopsis, AdHocQuery, FederatedQuery,
                  QueryMany, Ingest, Flush, Shutdown, StatusReport,
                  BuildMultidim, IngestMultidim, SubpopQuery,
                  TrackOutliers, UntrackOutliers, MUTATING_REQUESTS)
from .balancer import (Placement, PlacementDelta, estimate_workload,
                       plan_workers, worst_fit_decreasing)
from .engine import SDE, Federation
from .gateway import GatewayClient, SynopsisGateway, replay_log
from .migration import (RowPayload, extract_rows, implant_rows,
                        move_rows)
from .outliers import OutlierWorkflow, OutlierPlan
from .pipeline import BoundedResponseLog, IngestPipeline, PendingBatch
from .planner import Planner, WorkflowSpec
from .reconciler import Reconciler
from .wal import WriteAheadLog, Checkpointer, recover, replay

__all__ = ["Request", "Response", "parse_request", "BuildSynopsis",
           "StopSynopsis", "LoadSynopsis", "AdHocQuery", "FederatedQuery",
           "QueryMany", "Ingest", "Flush", "Shutdown", "StatusReport",
           "BuildMultidim", "IngestMultidim", "SubpopQuery",
           "TrackOutliers", "UntrackOutliers", "MUTATING_REQUESTS",
           "Placement", "PlacementDelta", "estimate_workload",
           "plan_workers", "worst_fit_decreasing",
           "SDE", "Federation", "GatewayClient", "SynopsisGateway",
           "replay_log", "RowPayload", "extract_rows", "implant_rows",
           "move_rows", "OutlierWorkflow", "OutlierPlan",
           "BoundedResponseLog", "IngestPipeline",
           "PendingBatch", "Planner", "WorkflowSpec", "Reconciler",
           "WriteAheadLog", "Checkpointer", "recover", "replay"]
