# SDE-as-a-Service: the always-on engine, its JSON API, the pipelined
# blue path, the multi-client micro-batching gateway and the
# accuracy-budget workflow planner (paper Sections 3, 4, 7).
from .api import (Request, Response, parse_request, BuildSynopsis,
                  StopSynopsis, LoadSynopsis, AdHocQuery, FederatedQuery,
                  QueryMany, Ingest, Flush, Shutdown, StatusReport)
from .engine import SDE, Federation
from .gateway import GatewayClient, SynopsisGateway, replay_log
from .pipeline import BoundedResponseLog, IngestPipeline, PendingBatch
from .planner import Planner, WorkflowSpec

__all__ = ["Request", "Response", "parse_request", "BuildSynopsis",
           "StopSynopsis", "LoadSynopsis", "AdHocQuery", "FederatedQuery",
           "QueryMany", "Ingest", "Flush", "Shutdown", "StatusReport",
           "SDE", "Federation", "GatewayClient", "SynopsisGateway",
           "replay_log", "BoundedResponseLog", "IngestPipeline",
           "PendingBatch", "Planner", "WorkflowSpec"]
