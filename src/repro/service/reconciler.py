"""The elasticity loop: declared-vs-actual reconciliation of synopsis
placement (paper Section 7 made live).

``service/balancer.py`` computes the paper's placement plan — HLL counts
the pieces of work, CountMin sizes them, WFD packs them — and this
module ACTS on it. A :class:`Reconciler` periodically:

  1. **samples** the ingest rate and ``balancer.estimate_workload``
     (one batched red-path call — the engine estimates its own load),
     windowed: each pass balances the load since the LAST pass, so a
     drifting skew re-plans on what the stream is doing *now*;
  2. **plans** a WFD target placement over the worker pool — the slices
     of the ``synopsis`` mesh axis (a row's position picks its device
     shard) for a single engine, or the member sites of a
     :class:`~repro.service.engine.Federation`;
  3. **diffs** declared against actual via ``Placement.diff`` (worker
     labels matched to the current placement first, so only genuinely
     misplaced streams move);
  4. **applies** the delta through the migration plane
     (``service/migration.py``): intra-engine, ``SDE.migrate_rows``
     relocates rows between mesh-axis slices (growing stacks first when
     a slice would overflow); across a federation,
     ``extract_synopses``/``implant_synopses`` ship per-stream synopses
     between sites. Every mover fences through the ingest pipeline — at
     most the in-flight batches retire per pass — and ingest resumes
     against the new routing immediately after the atomic remap.

Hysteresis: a pass applies only when it would improve the max/mean load
imbalance by at least ``min_gain`` (reconcilers must damp, not flap).
Skips are cheap — one ``query_many`` dispatch — so tight intervals are
fine. Probes: ``kernels.ops.RECONCILE_COUNT`` / ``MIGRATED_ROWS`` /
``REBALANCE_IMBALANCE``, surfaced by the JSON ``status`` response.

Drive it off the gateway tick (``SynopsisGateway(reconciler=...)``),
the server flag (``sde_server --reconcile-interval``), or directly
(``step()``) in tests and benchmarks.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

from repro.kernels import ops as kops
from . import balancer
from .engine import SDE, Federation
from .routing import next_pow2


class Reconciler:
    """Close the loop: sample -> plan -> diff -> migrate.

    ``target`` is an :class:`SDE` (workers = slices of the ``synopsis``
    mesh axis; ``n_workers`` defaults to the axis size and may be set
    explicitly for single-device runs) or a :class:`Federation`
    (workers = member sites). ``hll_id``/``cm_id`` name the estimator
    synopses (a data-source HLL and CountMin); until both exist the
    reconciler skips quietly, so it can be wired up before any client
    builds them. ``placed`` names the per-stream build prefixes whose
    rows move (default: every per-stream build discovered in the
    engine). ``interval`` throttles :meth:`maybe_step`."""

    def __init__(self, target, hll_id: str, cm_id: str, *,
                 streams: Optional[Sequence[int]] = None,
                 placed: Optional[Sequence[str]] = None,
                 n_workers: Optional[int] = None,
                 interval: float = 0.0, min_gain: float = 0.05,
                 tag: Optional[str] = None):
        self.target = target
        self.federated = isinstance(target, Federation)
        self.hll_id = hll_id
        self.cm_id = cm_id
        self.streams = list(streams) if streams is not None else None
        self.placed = list(placed) if placed is not None else None
        self.interval = float(interval)
        self.min_gain = float(min_gain)
        if self.federated:
            self.n_workers = len(target.sites)
            self.tag = tag or "federation"
        else:
            if n_workers is None:
                n_workers = self._mesh_workers(target)
            if n_workers is None or n_workers < 1:
                raise ValueError(
                    "n_workers: pass it explicitly, or give the engine a "
                    "mesh with a synopsis axis to infer it from")
            self.n_workers = int(n_workers)
            self.tag = tag or target.site
        self._last_loads: Optional[Dict[int, float]] = None
        self._last_tuples = 0
        self._next_due: Optional[float] = None
        self.last_report: Optional[dict] = None

    @staticmethod
    def _mesh_workers(sde: SDE) -> Optional[int]:
        if sde.mesh is None or sde.mesh.empty:
            return None
        ax = sde.rules.synopsis
        if ax is None or ax not in sde.mesh.axis_names:
            return None
        return int(sde.mesh.shape[ax])

    # ------------------------------------------------------------------
    # scheduling
    # ------------------------------------------------------------------
    def maybe_step(self, now: Optional[float] = None) -> Optional[dict]:
        """Run :meth:`step` when ``interval`` has elapsed since the last
        pass (always, for ``interval<=0``). The gateway tick and the
        server loop call this — reconciling rides existing wakeups, no
        thread of its own."""
        if self.interval > 0:
            now = time.monotonic() if now is None else now
            if self._next_due is not None and now < self._next_due:
                return None
            self._next_due = now + self.interval
        return self.step()

    # ------------------------------------------------------------------
    # one reconcile pass
    # ------------------------------------------------------------------
    def step(self) -> dict:
        """Sample, plan, diff, migrate. Returns a report dict; its
        ``applied`` field tells whether anything moved (skips record
        their reason). Never raises for an incomplete world — missing
        estimators or zero traffic are normal early states."""
        sdes = (list(self.target.sdes.values()) if self.federated
                else [self.target])
        estimators = [s for s in sdes
                      if self.hll_id in s.entries and self.cm_id in s.entries]
        if not estimators:
            return self._skip("estimator synopses not built yet")
        tuples = sum(s.tuples_ingested for s in sdes)
        if tuples == self._last_tuples:
            return self._skip("no traffic since last pass")
        placed = self._discover_placed(sdes)
        if not placed:
            self._last_tuples = tuples
            return self._skip("no per-stream builds to place")
        streams = (self.streams if self.streams is not None
                   else sorted({s for m in placed.values() for s in m}))
        window = self._sample_window(estimators, streams)
        self._last_tuples = tuples
        if sum(window.values()) <= 0.0:
            return self._skip("no load in window")
        current = self._current_placement(placed, window)
        plan = balancer.worst_fit_decreasing(
            streams, [window[s] for s in streams], self.n_workers)
        delta = plan.diff(current)
        before, after = current.imbalance, delta.target.imbalance
        if not delta.moves or before - after < self.min_gain:
            self._note(before)
            self.last_report = dict(
                applied=False, reason="within hysteresis", moves=0,
                migrated_rows=0, imbalance_before=before,
                imbalance_after=before)
            return self.last_report
        moved = self._apply(delta, placed)
        self._note(after)
        self.last_report = dict(
            applied=True, reason="", moves=len(delta.moves),
            migrated_rows=moved, imbalance_before=before,
            imbalance_after=after)
        return self.last_report

    def _note(self, imbalance: float) -> None:
        """Record the pass under this reconciler's tag — and, for a
        federation, under every member site too, so each site's JSON
        ``status`` (which reads the probes by its own site tag) shows
        the control loop's activity."""
        kops.note_reconcile(self.tag, imbalance)
        if self.federated:
            for site in self.target.sites:
                if site != self.tag:
                    kops.note_reconcile(site, imbalance)

    def _skip(self, reason: str) -> dict:
        # same schema as the hysteresis/applied paths — consumers index
        # the report without guarding on which path produced it
        self.last_report = dict(applied=False, reason=reason, moves=0,
                                migrated_rows=0, imbalance_before=None,
                                imbalance_after=None)
        return self.last_report

    # ------------------------------------------------------------------
    # sampling
    # ------------------------------------------------------------------
    def _sample_window(self, estimators: List[SDE],
                       streams: List[int]) -> Dict[int, float]:
        """Per-stream load landed since the LAST pass: cumulative CM
        estimates (summed over federation sites — each site's CM saw its
        own traffic) minus the previous sample, clipped at zero (sketch
        noise must not produce negative work)."""
        totals = {s: 0.0 for s in streams}
        for sde in estimators:
            _, loads = balancer.estimate_workload(
                sde, self.hll_id, self.cm_id, streams)
            for s, ld in zip(streams, loads):
                totals[s] += float(ld)
        prev = self._last_loads or {}
        self._last_loads = totals
        return {s: max(totals[s] - prev.get(s, 0.0), 0.0) for s in streams}

    def _discover_placed(self, sdes: List[SDE]
                         ) -> Dict[str, Dict[int, List[SDE]]]:
        """{build prefix: {stream id: engines holding its entry}} for
        every per-stream build (entry id ``<prefix>/<stream>``),
        restricted to ``self.placed`` when given."""
        out: Dict[str, Dict[int, List[SDE]]] = {}
        for sde in sdes:
            for full, e in sde.entries.items():
                if e.stream_id is None or "/" not in full:
                    continue
                prefix, _, tail = full.rpartition("/")
                if tail != str(e.stream_id):
                    continue
                if self.placed is not None and prefix not in self.placed:
                    continue
                out.setdefault(prefix, {}).setdefault(
                    int(e.stream_id), []).append(sde)
        return out

    # ------------------------------------------------------------------
    # actual placement
    # ------------------------------------------------------------------
    def _current_placement(self, placed, window) -> balancer.Placement:
        """Derive the ACTUAL stream->worker map from engine state — row
        positions (mesh mode: worker = the row's slice of the synopsis
        axis) or entry residency (federation mode: worker = site index).
        Declared state is never trusted over what the engine holds."""
        assign: Dict[int, int] = {}
        prefix = sorted(placed)[0]       # placed stacks move in lockstep
        if self.federated:
            order = {s: i for i, s in enumerate(self.target.sites)}
            for stream, holders in placed[prefix].items():
                assign[stream] = order[holders[0].site]
        else:
            sde = self.target
            for stream, _ in placed[prefix].items():
                e = sde.entries[f"{prefix}/{stream}"]
                cap = sde.stacks[e.kind_key].capacity
                assign[stream] = e.row * self.n_workers // cap
        loads = [0.0] * self.n_workers
        for s, w in assign.items():
            loads[w] += window.get(s, 0.0)
        return balancer.Placement(assignments=assign, loads=loads,
                                  n_workers=self.n_workers)

    # ------------------------------------------------------------------
    # applying the delta
    # ------------------------------------------------------------------
    def _apply(self, delta: balancer.PlacementDelta, placed) -> int:
        assign = delta.target.assignments
        if self.federated:
            return self._apply_federated(assign, placed)
        moved = 0
        for prefix, members in placed.items():
            sde = self.target
            kinds = {}
            for stream in members:
                e = sde.entries[f"{prefix}/{stream}"]
                kinds.setdefault(e.kind_key, {})[stream] = e.row
            for kind, rows_by_stream in kinds.items():
                mapping = self._plan_stack(sde, kind, rows_by_stream,
                                           assign)
                moved += sde.migrate_rows(kind, mapping)
        return moved

    def _plan_stack(self, sde: SDE, kind, rows_by_stream: Dict[int, int],
                    assign: Dict[int, int]) -> Dict[int, int]:
        """Row moves realizing ``assign`` on one kind stack: every row
        lands inside its worker's contiguous slice of the row axis.
        Stacks grow (pow2 slices) when a slice would overflow; rows
        already in place stay put, movers fill each slice's lowest free
        rows — deterministic, minimal."""
        stack = sde.stacks[kind]
        W = self.n_workers
        desired: Dict[int, int] = {}
        for stream, row in sorted(rows_by_stream.items()):
            w = assign.get(stream)
            if w is not None:
                desired[row] = w
        for r, used in enumerate(stack.used):
            if used and r not in desired:
                # non-candidate rows (sources, other builds) stay where
                # they are — their current slice is their declared one
                desired[r] = min(r * W // stack.capacity, W - 1)
        demand = [0] * W
        for w in desired.values():
            demand[w] += 1
        # slice size: the smallest pow2 fitting both the demand and the
        # current rows (ceil-div keeps cap >= capacity for ANY W — a
        # doubling search can never make cap divisible by a non-pow2 W)
        ss = next_pow2(max(-(-stack.capacity // W), max(demand), 1))
        cap = W * ss
        if cap != stack.capacity:
            sde.resize_stack(kind, cap)
        stay = {r for r, w in desired.items()
                if w * ss <= r < (w + 1) * ss}
        free = {w: [r for r in range((w + 1) * ss - 1, w * ss - 1, -1)
                    if r not in stay] for w in range(W)}
        mapping: Dict[int, int] = {}
        for row in sorted(desired):
            if row in stay:
                continue
            mapping[row] = free[desired[row]].pop()
        return mapping

    def _apply_federated(self, assign: Dict[int, int], placed) -> int:
        """Ship per-stream synopses between sites: one
        ``extract_synopses`` payload per (source, destination) pair —
        routing keys travel inside the payloads, state through host
        numpy (the DCN of this reproduction)."""
        sites = self.target.sites
        order = {s: i for i, s in enumerate(sites)}
        moves: Dict[tuple, List[str]] = {}
        for prefix, members in placed.items():
            for stream, holders in members.items():
                w = assign.get(stream)
                if w is None:
                    continue
                src = order[holders[0].site]
                if src != w:
                    moves.setdefault((src, w), []).append(
                        f"{prefix}/{stream}")
        moved = 0
        for (src, dst), ids in sorted(moves.items()):
            package = self.target.sdes[sites[src]].extract_synopses(
                ids, remove=True)
            moved += self.target.sdes[sites[dst]].implant_synopses(package)
        return moved
