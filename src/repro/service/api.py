"""SDE API — JSON request/response schemata (paper Section 3, Figure 1).

All requests are lightweight JSON snippets so cross-(Big Data)-platform
workflows (anything that can produce/consume JSON) can drive the engine;
this mirrors the paper's Kafka RequestTopic contract.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional


@dataclasses.dataclass
class Request:
    """Every request carries an id plus optional multi-client routing
    fields (used by the gateway front door, ignored by a bare engine):

    tenant: synopsis-namespace key. The gateway prefixes every
      ``synopsis_id`` with ``"<tenant>::"`` so tenants can neither
      address nor collide with each other's synopses. The STREAM id
      space stays shared — the paper's claim (e): many concurrent
      workflows maintain synopses over the same streams.
    client_id: identifies the submitting client within a connection;
      continuous-query responses route to the building client's bounded
      per-client response log.
    """
    request_id: str
    tenant: str = ""
    client_id: str = ""


@dataclasses.dataclass
class BuildSynopsis(Request):
    """Create (or start maintaining) a synopsis on-the-fly.

    stream_id: single-stream synopsis target; None => data-source synopsis.
      Stream ids are ARBITRARY non-negative 63-bit ints (hashed user ids,
      sensor UUIDs, ...) — routing is hashed, there is no dense-table
      range cap and no re-keying requirement.
    per_stream_of_source: one synopsis per stream of the source with a
      single request (paper: 'a sample per stock ... single request');
      covers streams ``range(n_streams)``, or exactly ``stream_ids``
      when that list is given (sparse / hashed id populations).
    """
    synopsis_id: str = ""
    kind: str = "countmin"
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    stream_id: Optional[int] = None
    source_id: Optional[str] = None
    per_stream_of_source: bool = False
    n_streams: int = 0                    # per-stream builds: id range size
    stream_ids: Optional[List[int]] = None  # per-stream builds: explicit ids
    parallelism: int = 1                  # requested degree (data-source)
    scheme: str = "partition"             # partition | round_robin
    federated: bool = False
    responsible_site: Optional[str] = None
    continuous: bool = False              # emit estimate on every update


@dataclasses.dataclass
class StopSynopsis(Request):
    synopsis_id: str = ""


@dataclasses.dataclass
class LoadSynopsis(Request):
    """Plug an external synopsis definition while the service runs."""
    kind_name: str = ""
    factory_path: str = ""                # "module:callable"


@dataclasses.dataclass
class AdHocQuery(Request):
    synopsis_id: str = ""
    query: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class FederatedQuery(Request):
    """Global estimate over every site of a federation (paper Case 2/3:
    the responsible site synthesizes the answer from the sites' partial
    synopses). Served by ``Federation.handle`` — on a mesh-backed
    federation the site merge runs as ONE compiled collective over the
    ``site``/``pod`` axis; otherwise the legacy host-side gather+merge
    answers. The response's ``params`` carries the fig 5d communication
    metrics: ``collective_operand_bytes`` (what the collective merge
    ships across the site axis), ``host_merge_bytes`` (what gathering
    every site's state to the responsible host ships — also exactly what
    the executed path shipped when ``path == "host"``), ``path``
    ("collective" | "host") and ``sites`` (how many sites contributed a
    partial state)."""
    synopsis_id: str = ""
    query: Dict[str, Any] = dataclasses.field(default_factory=dict)
    responsible_site: str = ""


@dataclasses.dataclass
class QueryMany(Request):
    """Answer many ad-hoc queries in one request (SDEaaS batched red path).

    Each entry of ``queries`` is ``{"synopsis_id": ..., "query": {...}}``;
    the engine groups them by synopsis kind and evaluates every group with
    a single jitted stacked-estimate dispatch. The response ``value`` is
    the list of per-query response dicts in request order.
    """
    queries: List[Dict[str, Any]] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class Ingest(Request):
    """Blue-path data over JSON: one batch of (stream, value) tuples.

    The ack's ``value`` carries the monotonic batch counter assigned to
    this batch (``{"batch": n, ...}``) — the same counter that keys the
    batch's continuous-query response ids (``cq/<synopsis>/<n>``) — plus
    the pipeline's current in-flight depth, so a JSON-driven workflow
    can correlate deferred continuous output with the ingest that
    produced it under pipelined execution.
    """
    stream_ids: List[Any] = dataclasses.field(default_factory=list)
    values: List[float] = dataclasses.field(default_factory=list)
    mask: Optional[List[bool]] = None


@dataclasses.dataclass
class BuildMultidim(Request):
    """Build a multidimensional synopsis family in one request.

    ``dims`` maps dimension name -> finite domain of attribute values;
    ``levels`` optionally restricts the materialized group-by family to
    the listed dimension subsets (default: every subset — the full
    dyadic family of ``core.multidim``). The engine allocates one
    synopsis of ``kind`` per group across every level under entry ids
    ``<synopsis_id>/<group key>`` — ordinary per-stream entries on the
    fused blue path.
    """
    synopsis_id: str = ""
    kind: str = "countmin"
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    dims: Dict[str, List[Any]] = dataclasses.field(default_factory=dict)
    levels: Optional[List[List[str]]] = None
    continuous: bool = False


@dataclasses.dataclass
class IngestMultidim(Request):
    """Blue-path data as attribute-tagged records: ``records[i]`` maps
    every declared dimension to a value; the engine expands each record
    to its per-level group keys host-side and feeds ONE fused ingest
    per kind. ``items`` optionally carries per-record item identities
    (user ids, ...) for item-hashing sketches (HLL/Bloom/FM/CM/AMS);
    default is the record's leaf-group key, making coarse groups count
    distinct leaf subpopulations."""
    synopsis_id: str = ""
    records: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    values: List[float] = dataclasses.field(default_factory=list)
    mask: Optional[List[bool]] = None
    items: Optional[List[int]] = None


@dataclasses.dataclass
class SubpopQuery(Request):
    """Estimate over an arbitrary subpopulation: ``where`` is a
    conjunction of per-dimension predicates (value or list of values per
    dimension); the engine expands it into the covering key set of the
    matching level and answers with ONE fused
    merge-covering-set-then-estimate dispatch. ``query`` carries the
    kind's usual estimate args (as in ``AdHocQuery``)."""
    synopsis_id: str = ""
    where: Dict[str, Any] = dataclasses.field(default_factory=dict)
    query: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class TrackOutliers(Request):
    """Start a continuous outlier workflow over a multidim family: each
    ingest tick, every group of ``level`` is estimated alongside the
    population group — off the SAME maintained synopses, zero new
    builds — and groups whose stat deviates from the level's mean by
    ``threshold`` robust z-scores AND at least ``min_dev`` absolutely
    are emitted through the continuous-response path
    (``ow/<workflow>/<batch>``)."""
    workflow_id: str = ""
    synopsis_id: str = ""
    level: Optional[List[str]] = None     # default: the leaf level
    query: Dict[str, Any] = dataclasses.field(default_factory=dict)
    threshold: float = 3.0
    min_dev: float = 0.0


@dataclasses.dataclass
class UntrackOutliers(Request):
    workflow_id: str = ""


@dataclasses.dataclass
class Flush(Request):
    """Pipeline barrier: materialize every in-flight continuous batch
    into the engine's continuous output before the ack returns. The
    ack's ``value`` reports how many batches were drained. A no-op (0
    drained) on an eager engine or an idle pipeline."""


@dataclasses.dataclass
class Shutdown(Request):
    """Clean stop over the wire: flush every in-flight batch, release
    the engine's kind stacks and compiled-program caches (``SDE.close``)
    and ack with final counters. The JSON-lines server stops serving
    after acking; a socket client gets a clean stop it could never
    signal via EOF without dropping the connection mid-response."""


@dataclasses.dataclass
class StatusReport(Request):
    pass


@dataclasses.dataclass
class Response:
    request_id: str
    synopsis_id: str = ""
    ok: bool = True
    value: Any = None
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    error: str = ""

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), default=_jsonable)


def _jsonable(x):
    try:
        import numpy as np
        if isinstance(x, np.ndarray):
            return x.tolist()
        if isinstance(x, (np.generic,)):
            return x.item()
    except Exception:
        pass
    return str(x)


_KINDS = {
    "build": BuildSynopsis,
    "stop": StopSynopsis,
    "load": LoadSynopsis,
    "adhoc": AdHocQuery,
    "federated_query": FederatedQuery,
    "query_many": QueryMany,
    "ingest": Ingest,
    "build_multidim": BuildMultidim,
    "ingest_multidim": IngestMultidim,
    "subpop_query": SubpopQuery,
    "track_outliers": TrackOutliers,
    "untrack_outliers": UntrackOutliers,
    "flush": Flush,
    "shutdown": Shutdown,
    "status": StatusReport,
}

# Request types that mutate engine lifecycle state and must be
# write-ahead logged before they are applied (the WAL's replay set —
# ``service.wal`` re-exports this; ``ingest``/``ingest_multidim`` data
# is logged separately POST-apply, keyed by engine batch id).
MUTATING_REQUESTS = ("build", "stop", "load", "build_multidim",
                     "track_outliers", "untrack_outliers")


def parse_request(snippet: str | Dict[str, Any]) -> Request:
    """Parse a JSON request snippet into a typed request."""
    obj = json.loads(snippet) if isinstance(snippet, str) else dict(snippet)
    rtype = obj.pop("type")
    cls = _KINDS[rtype]
    fields = {f.name for f in dataclasses.fields(cls)}
    unknown = set(obj) - fields
    if unknown:
        raise ValueError(f"unknown fields for {rtype!r}: {sorted(unknown)}")
    return cls(**obj)
