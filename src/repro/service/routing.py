"""Hashed stream routing: arbitrary 63-bit stream ids -> kind-stack rows.

Replaces the fixed ``route[_MAX_STREAMS]`` dense table that silently
dropped every tuple with a stream id >= 2**16 (and rejected such builds).
A :class:`RouteTable` is an open-addressing hash table with linear
probing: pow2-sized ``keys``/``rows`` arrays, tombstone-free inserts on
build, full re-insert compaction on stop, and grow-and-rehash past
~``_MAX_LOAD`` load factor. Stream ids are arbitrary ints in
``[0, 2**63)`` — nothing is ever clamped, rejected or dropped for being
"too big".

Split of responsibilities:

  * HOST (this module, numpy): the authoritative table. Inserts/removes
    happen on the rare lifecycle path (build/stop/merge), so they are
    plain vectorized numpy — no device round trip per synopsis.
  * DEVICE (``kernels.ops.route_probe``): the per-batch lookup, a
    fixed-bound linear-probe gather chain that runs *inside* the fused
    blue-path programs (one dispatch per kind per batch, PR 1 contract).
    The device mirror stores keys split into uint32 lo/hi halves so the
    probe needs no 64-bit lanes (``jax_enable_x64`` stays off); it is
    replicated over multi-device meshes exactly like the old dense route.

The probe loop's trip count must be static under jit, so the table
tracks the longest insertion displacement (``max_probe``) and grows
whenever an insert would displace past :data:`PROBE_CAP` — this bounds
the fused gather chain (and jit retraces: the engine rounds ``max_probe``
up to a power of two) independent of table occupancy. In practice tables
settle around 0.25-0.5 load with probe chains <= 32.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

_MAX_LOAD = 0.7          # grow-and-rehash past this occupancy
PROBE_CAP = 32           # grow instead of probing longer than this
_MIN_SIZE = 64           # smallest table (pow2)
_GOLDEN = np.uint32(0x9E3779B9)

# host sentinel for an empty slot; its uint32 halves are both 0xFFFFFFFF,
# unreachable by valid ids (hi <= 0x7FFFFFFF for ids < 2**63) — the
# device probe detects empty slots from the hi half alone.
EMPTY = np.int64(-1)

MAX_STREAM_ID = (1 << 63) - 1


def _mix32(x: np.ndarray) -> np.ndarray:
    """murmur3 fmix32 on uint32 arrays — bit-identical to
    ``core.hashing.mix32`` (the device side of the probe)."""
    x = np.atleast_1d(np.asarray(x)).astype(np.uint32)  # uint32 wraps; the
    with np.errstate(over="ignore"):                    # scalar path warns
        x ^= x >> np.uint32(16)
        x = (x * np.uint32(0x85EBCA6B)).astype(np.uint32)
        x ^= x >> np.uint32(13)
        x = (x * np.uint32(0xC2B2AE35)).astype(np.uint32)
        x ^= x >> np.uint32(16)
    return x


def split64(sids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """int64 stream ids -> (lo, hi) uint32 halves."""
    s = np.asarray(sids, np.int64)
    lo = (s & np.int64(0xFFFFFFFF)).astype(np.uint32)
    hi = ((s >> np.int64(32)) & np.int64(0xFFFFFFFF)).astype(np.uint32)
    return lo, hi


def fold64(sids: np.ndarray) -> np.ndarray:
    """Fold a 64-bit stream id into the uint32 item identity the sketches
    hash. Identity for ids < 2**32 (``hi == 0``), so sketch contents are
    bit-identical to the pre-hashed-routing engine on small id spaces."""
    lo, hi = split64(sids)
    return (lo ^ (_mix32(hi) * _GOLDEN)).astype(np.uint32)


def slot_hash(lo: np.ndarray, hi: np.ndarray, size: int) -> np.ndarray:
    """Initial probe slot for keys given as uint32 halves. Must stay in
    lockstep with the jnp twin inside ``kernels.ops.route_probe``."""
    h = _mix32(lo.astype(np.uint32) ^ _mix32(hi.astype(np.uint32)
                                             ^ _GOLDEN))
    return (h & np.uint32(size - 1)).astype(np.int64)


class RouteTable:
    """Host-side open-addressing stream->row map (linear probing)."""

    def __init__(self, size: int = _MIN_SIZE):
        size = max(_MIN_SIZE, next_pow2(size))
        self.keys = np.full((size,), EMPTY, np.int64)
        self.rows = np.full((size,), -1, np.int32)
        self.count = 0
        self.max_probe = 1      # longest insert displacement + 1
        self.version = 0        # bumped on any mutation (device cache key)

    # -- read ----------------------------------------------------------
    @property
    def size(self) -> int:
        return int(self.keys.shape[0])

    @property
    def load(self) -> float:
        return self.count / self.size

    def lookup(self, sid: int) -> int:
        """Row for ``sid`` or -1 (host-side twin of the device probe)."""
        sid = int(sid)
        slot = int(slot_hash(*split64(np.int64(sid)), self.size).ravel()[0])
        mask = self.size - 1
        for _ in range(self.max_probe):
            k = self.keys[slot]
            if k == sid:
                return int(self.rows[slot])
            if k == EMPTY:
                return -1
            slot = (slot + 1) & mask
        return -1

    def lookup_many(self, sids: np.ndarray) -> np.ndarray:
        """Vectorized ``lookup``: int32 rows, -1 where a key is absent —
        the same probe rounds as ``_contains_many``, returning the row
        instead of a membership bit. The dirty-tracking resolver runs a
        whole ingest window of stream ids through this in a handful of
        numpy passes."""
        sids = np.asarray(sids, np.int64).ravel()
        out = np.full(sids.shape, -1, np.int32)
        if sids.size == 0 or self.count == 0:
            return out
        slot = slot_hash(*split64(sids), self.size)
        mask = self.size - 1
        active = np.ones(sids.shape, bool)
        for _ in range(self.max_probe):
            k = self.keys[slot]
            hit = active & (k == sids)
            out[hit] = self.rows[slot[hit]]
            active &= ~hit & (k != EMPTY)
            if not active.any():
                break
            slot = (slot + 1) & mask
        return out

    def items(self) -> Tuple[np.ndarray, np.ndarray]:
        """(stream_ids, rows) of every occupied slot."""
        occ = self.keys != EMPTY
        return self.keys[occ].copy(), self.rows[occ].copy()

    # -- write ---------------------------------------------------------
    def insert(self, sid: int, row: int) -> None:
        self.insert_many([sid], [row])

    def insert_many(self, sids: np.ndarray, rows: np.ndarray) -> None:
        """Bulk insert (vectorized rounds of probing — a 1M-stream build
        is a handful of numpy passes, not 1M Python probes). Re-inserting
        an existing key updates its row."""
        try:
            sids = np.asarray(sids, np.int64)
        except OverflowError as e:
            raise ValueError(
                "stream id outside [0, 2**63) — ids must be non-negative "
                "63-bit ints") from e
        rows = np.asarray(rows, np.int32)
        if sids.size == 0:
            return
        if sids.size > 1:
            # intra-batch duplicates: LAST occurrence wins, matching the
            # sequential-insert semantics (a tie-losing duplicate must
            # not land in a second slot and orphan a row mapping)
            _, idx = np.unique(sids[::-1], return_index=True)
            keep = np.sort(sids.size - 1 - idx)
            sids, rows = sids[keep], rows[keep]
        if np.any((sids < 0) | (sids > MAX_STREAM_ID)):
            bad = sids[(sids < 0) | (sids > MAX_STREAM_ID)][0]
            raise ValueError(
                f"stream id {int(bad)} outside [0, 2**63) — ids must be "
                "non-negative 63-bit ints")
        # reserve for genuinely NEW keys only: re-inserts (row updates)
        # must not count toward load or trigger a pointless grow
        fresh = int(np.count_nonzero(~self._contains_many(sids)))
        self._reserve(self.count + fresh)
        self._insert_rounds(sids, rows)
        self.version += 1

    def remap_rows(self, old_rows: np.ndarray, new_rows: np.ndarray) -> None:
        """Atomically rewrite row targets: every key routed to
        ``old_rows[i]`` now routes to ``new_rows[i]``. Keys never move —
        slot layout, ``count`` and ``max_probe`` are untouched, so the
        fused probe programs need no retrace — and the single version
        bump republishes the device mirror in one step (the migration
        plane's routing commit: a reader sees the old mapping or the new
        one, never a half-moved table)."""
        old = np.asarray(old_rows, np.int32)
        new = np.asarray(new_rows, np.int32)
        if old.shape != new.shape:
            raise ValueError(
                f"remap_rows: {old.size} old rows vs {new.size} new rows")
        if old.size == 0:
            return
        top = int(max(old.max(), new.max(), self.rows.max(initial=0)))
        rowmap = np.arange(top + 1, dtype=np.int32)
        rowmap[old] = new
        occ = self.rows >= 0
        self.rows[occ] = rowmap[self.rows[occ]]
        self.version += 1

    def remove_rows(self, dead_rows: np.ndarray) -> None:
        """Drop every key routed to ``dead_rows`` and compact by full
        re-insert (tombstone-free: stop is the rare path, and rebuilding
        keeps probe chains at their insert-time bound)."""
        dead = np.asarray(dead_rows, np.int32)
        keys, rows = self.items()
        keep = ~np.isin(rows, dead)
        if keep.all():
            # nothing routed to the dead rows (e.g. a source-only stop):
            # skip the rebuild and the device-mirror re-upload
            return
        self._rebuild(keys[keep], rows[keep], self.size)
        self.version += 1

    # -- internals -----------------------------------------------------
    def _contains_many(self, sids: np.ndarray) -> np.ndarray:
        """Vectorized membership test (the batched twin of ``lookup``)."""
        slot = slot_hash(*split64(sids), self.size)
        mask = self.size - 1
        found = np.zeros(sids.shape, bool)
        active = np.ones(sids.shape, bool)
        for _ in range(self.max_probe):
            k = self.keys[slot]
            hit = active & (k == sids)
            found |= hit
            active &= ~hit & (k != EMPTY)
            if not active.any():
                break
            slot = (slot + 1) & mask
        return found

    def _reserve(self, want_count: int) -> None:
        size = self.size
        while want_count > _MAX_LOAD * size:
            size *= 2
        if size != self.size:
            keys, rows = self.items()
            self._rebuild(keys, rows, size)

    def _rebuild(self, keys: np.ndarray, rows: np.ndarray,
                 size: int) -> None:
        size = max(_MIN_SIZE, next_pow2(size))
        self.keys = np.full((size,), EMPTY, np.int64)
        self.rows = np.full((size,), -1, np.int32)
        self.count = 0
        self.max_probe = 1
        if keys.size:
            self._insert_rounds(keys, rows)

    def _insert_rounds(self, sids: np.ndarray, rows: np.ndarray) -> None:
        """Vectorized linear-probe insertion. Each round places every
        pending key that (a) found an empty slot and (b) won the
        first-come tie-break for it; losers advance one slot. Grows and
        restarts if any key would displace past PROBE_CAP."""
        mask = self.size - 1
        slot = slot_hash(*split64(sids), self.size)
        pending = np.arange(sids.size)
        for dist in range(PROBE_CAP):
            k_at = self.keys[slot]
            dup = k_at == sids[pending]            # key already present
            if np.any(dup):
                self.rows[slot[dup]] = rows[pending[dup]]
                keepm = ~dup
                pending, slot = pending[keepm], slot[keepm]
                k_at = k_at[keepm]
            if pending.size == 0:
                return
            empty = k_at == EMPTY
            # first occurrence wins each contested empty slot this round
            place = np.zeros(pending.size, bool)
            if np.any(empty):
                cand = np.nonzero(empty)[0]
                _, first = np.unique(slot[cand], return_index=True)
                place[cand[first]] = True
                tgt = slot[place]
                self.keys[tgt] = sids[pending[place]]
                self.rows[tgt] = rows[pending[place]]
                self.count += tgt.size
                self.max_probe = max(self.max_probe, dist + 1)
            pending, slot = pending[~place], slot[~place]
            if pending.size == 0:
                return
            slot = (slot + 1) & mask
        # someone would probe past the cap: grow and re-insert the rest
        # (rebuild re-inserts the already-placed keys at the new size)
        keys_done, rows_done = self.items()
        self._rebuild(np.concatenate([keys_done, sids[pending]]),
                      np.concatenate([rows_done, rows[pending]]),
                      self.size * 2)


def next_pow2(n: int) -> int:
    return 1 << max(0, (int(n) - 1).bit_length())
