"""The SDEaaS engine — one always-on service maintaining thousands of
synopses for thousands of streams (paper Section 4).

Structure mirrors the paper's architecture, adapted to JAX:

  * blue path  : ``ingest(stream_ids, values)`` — ONE jitted update per
    synopsis *kind* updates every synopsis of that kind (stacked state =
    slot sharing). Stream routing (stream -> row) is a hashed open-
    addressing table (``service/routing.py``): stream ids are arbitrary
    63-bit ints, and the linear probe runs INSIDE the fused program
    (``kernels.ops.route_probe``), the analogue of
    RegisterSynopsis/HashData key creation.
  * red path   : ``handle(request_json)`` / ``query_many(requests)`` —
    queries read the same stacked state in place through ONE cached jitted
    stacked-estimate program per kind (``kernels.ops.estimate_all``): N
    ad-hoc queries against a kind are one dispatch, and all continuous
    queries of a kind are re-evaluated per ingest batch in one program.
    Queries never enter (or back-pressure) the update path.
  * yellow path: federated synopses — ``Federation`` keeps one SDE per
    site and synthesizes global estimates at the responsible site. On a
    mesh with a ``site``/``pod`` axis each site's state is pinned to its
    own device and the merge runs as a REAL collective inside one
    shard_map-ped program (``kernels.ops.estimate_collective`` driving
    ``core.federated.merge_over_axis``: psum/pmax/all_gather over the
    axis); off-mesh, host copies are gathered and tree-merged
    (``kernels.ops.estimate_merged`` — the equivalence oracle).

Capacity management: kind stacks grow by doubling (amortized re-jit),
"a request for a new synopsis assigns new tasks, not task slots"; the
routing tables grow-and-rehash independently of stack capacity.

Execution modes: the blue path runs **eager** (continuous-query outputs
are materialized to host before ``ingest`` returns — the pre-PR-4
behaviour) or **pipelined** (``SDE(pipelined=True)``, or env
``SDE_PIPELINED=1``): ingest dispatches the fused update and
stacked-estimate programs and returns immediately, parking the batch's
continuous outputs as device futures on a bounded depth-2 queue
(``service/pipeline.py``). Host prep for batch N+1 then overlaps batch
N's device work. Futures materialize into ``continuous_out`` when the
queue retires the batch (a newer submission exceeds the depth), on an
explicit ``flush()``, or at a fence — ``query_many``/``handle`` reads,
build/stop/grow, snapshot and elastic merge all drain the pipeline
first, so both modes produce byte-identical synopsis state and
identical continuous responses (ids and values) in the same order.
"""
from __future__ import annotations

import dataclasses
import importlib
import json
import os
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import core
from repro.core import batched, federated
from repro.core.multidim import MultidimSpec
from repro.core.synopsis import Synopsis, kind_params
from repro.kernels import ops as kops
from repro.sharding import specs
from . import api, migration, outliers, pipeline, routing

# dense route size of pre-hashed-routing snapshots (the old _MAX_STREAMS);
# restore migrates these into a RouteTable
_LEGACY_ROUTE_SLOTS = 1 << 16

# fold the parked ingest-window stream ids into per-stack dirty sets every
# this many batches — bounds _pending_dirty even when nobody snapshots
_DIRTY_RESOLVE_EVERY = 64


@dataclasses.dataclass
class _Entry:
    synopsis_id: str
    kind_key: Any                 # the frozen kind dataclass
    row: int
    stream_id: Optional[int]      # None => data-source synopsis
    federated: bool = False
    responsible_site: Optional[str] = None
    continuous: bool = False
    source_id: Optional[str] = None


class _KindStack:
    """All synopses of one kind: stacked state + hashed routing table.

    On a multi-device mesh the stacked state's leading [capacity] row
    axis is partitioned over the ``synopsis`` logical axis (horizontal
    scale-out, paper Fig. 5); the routing table's device mirror is
    replicated (like the old dense route array).
    """

    def __init__(self, kind: Synopsis, capacity: int = 64,
                 mesh: Optional[Mesh] = None,
                 rules: Optional[specs.MeshRules] = None,
                 device=None):
        self.kind = kind
        self.capacity = capacity
        self.mesh = mesh
        self.device = device            # pin to ONE device (federation site)
        self.rules = rules or specs.DEFAULT_RULES
        self.state = batched.stacked_init(kind, capacity)
        self.table = routing.RouteTable()  # stream id -> row (host side)
        self.source_rows: List[int] = []   # rows fed by ALL tuples
        self.used: List[bool] = [False] * capacity
        self.is_timeseries = hasattr(kind, "step")
        self._source_idx = None            # device cache, source_rows_idx()
        self._free: Optional[List[int]] = None   # alloc free list (lazy)
        self._dev_table = None             # device mirror of self.table
        self._dev_table_version = -1
        # rows whose bytes changed since the last snapshot — what an
        # incremental checkpoint ships. Bounded by capacity; a superset
        # is always safe (extra rows ship unchanged bytes), a miss never
        # is, so every mutation path marks here: alloc/free, the
        # migration plane (implant/move), merge and the deferred
        # ingest-window resolver (SDE._resolve_dirty)
        self.dirty: set[int] = set()
        self._place()

    def mark_dirty(self, rows) -> None:
        """Record rows whose state bytes (or lifecycle) changed since the
        last snapshot."""
        self.dirty.update(int(r) for r in rows)

    @property
    def sharding(self) -> Optional[NamedSharding]:
        if self.mesh is None or self.mesh.empty:
            return None
        return specs.stack_sharding(self.rules, self.mesh, self.capacity)

    def _place(self):
        """Pin state rows over the synopsis axis — or, for a federation
        site, to the site's own device, so ingest's jitted programs run
        where the site lives (the routing table's device mirror is placed
        lazily by ``device_table``)."""
        target = self.sharding if self.device is None else self.device
        if target is None:
            return
        self.state = jax.tree.map(
            lambda x: jax.device_put(x, target), self.state)

    def device_table(self):
        """(keys_lo, keys_hi, rows) device mirror of the routing table —
        the arrays ``kernels.ops.route_probe`` gathers from inside the
        fused programs. Rebuilt only when the host table mutated
        (build/stop/merge — the rare path); replicated on a mesh."""
        if (self._dev_table is None
                or self._dev_table_version != self.table.version):
            lo, hi = routing.split64(self.table.keys)
            arrs = (lo, hi, self.table.rows)
            if self.device is not None:
                self._dev_table = tuple(
                    jax.device_put(a, self.device) for a in arrs)
            elif self.mesh is not None and not self.mesh.empty:
                rep = NamedSharding(self.mesh, P())
                self._dev_table = tuple(
                    jax.device_put(a, rep) for a in arrs)
            else:
                self._dev_table = tuple(jnp.asarray(a) for a in arrs)
            self._dev_table_version = self.table.version
        return self._dev_table

    @property
    def n_probe(self) -> int:
        """Static probe bound for the fused programs: the table's longest
        insert displacement, pow2-rounded so jit retraces are bounded by
        log(PROBE_CAP) distinct values, not one per table state."""
        return _next_pow2(self.table.max_probe)

    def source_rows_idx(self) -> Optional[jax.Array]:
        """int32 index vector of data-source rows; None when there are
        none (lets the no-source fused path skip the merge branch at
        trace time). Cached on device; invalidated on lifecycle changes."""
        if not self.source_rows:
            return None
        if self._source_idx is None:
            self._source_idx = jnp.asarray(
                np.asarray(self.source_rows, np.int32))
        return self._source_idx

    def mark_source(self, row: int):
        self.source_rows.append(row)
        self._source_idx = None

    def out_sharding(self) -> Optional[NamedSharding]:
        """Replicate the (small) estimate outputs of a red-path dispatch
        when the stack is mesh-sharded; None off-mesh."""
        if self.mesh is None or self.mesh.empty:
            return None
        return NamedSharding(self.mesh, P())

    def row_bytes(self) -> int:
        """Actual device bytes of ONE row slice of the stacked state — the
        per-synopsis footprint. ``kind.memory_bytes()`` reports the
        abstract sketch size, which drifts from the stacked dtypes (e.g.
        Bloom bits are int32 lanes here, not packed bits)."""
        return sum((x.size // self.capacity) * x.dtype.itemsize
                   for x in jax.tree.leaves(self.state))

    def alloc(self) -> int:
        """Hand out the lowest free row (free-list backed: a 1M-stream
        per-source build is 1M O(1) pops, not 1M O(capacity) scans)."""
        if self._free is None:
            self._free = [i for i, u in enumerate(self.used)
                          if not u][::-1]
        if not self._free:
            old_cap = self.capacity
            self.capacity *= 2
            self.state = batched.grow(self.kind, self.state, self.capacity)
            self.used.extend([False] * old_cap)
            self._free = list(range(self.capacity - 1, old_cap - 1, -1))
            self._source_idx = None
            self._place()
        row = self._free.pop()
        self.used[row] = True
        # a freshly built synopsis differs from the base snapshot even
        # before its first tuple (build-without-ingest must still ship)
        self.dirty.add(row)
        return row

    def free(self, row: int):
        self.free_rows([row])

    def free_rows(self, rows: List[int]):
        """Release rows AND re-initialize their state: the next alloc of
        these slots must hand out fresh synopses, not the dead ones'
        counts (freed-row reuse corruption). Batched — stopping a
        per-stream group of thousands is ONE scatter, not one full-state
        copy per row. The routing table compacts by re-insert
        (tombstone-free), and the source-row index cache is ALWAYS
        dropped so a stopped data-source row cannot keep absorbing
        tuples through a stale cached vector."""
        for row in rows:
            self.used[row] = False
            if row in self.source_rows:
                self.source_rows.remove(row)
        self._source_idx = None
        self._free = None
        # freed rows are re-initialized below — changed bytes the next
        # delta must carry so a restored engine matches byte-for-byte
        self.dirty.update(int(r) for r in rows)
        self.table.remove_rows(np.asarray(rows, np.int32))
        idx = jnp.asarray(rows, jnp.int32)
        fresh = batched.stacked_init(self.kind, len(rows))
        self.state = jax.tree.map(
            lambda x, f: x.at[idx].set(f), self.state, fresh)
        if self.sharding is not None:
            self.state = jax.tree.map(
                lambda x: jax.device_put(x, self.sharding), self.state)


class SDE:
    """One SDEaaS instance (one site/cluster in federated settings).

    Pass a ``mesh`` to shard every kind stack's row axis across devices
    (the ``synopsis`` logical axis of ``sharding/specs.py``); omit it for
    single-device operation.
    """

    def __init__(self, site: str = "site-0",
                 backend: Optional[str] = None,
                 mesh: Optional[Mesh] = None,
                 rules: Optional[specs.MeshRules] = None,
                 pipelined: Optional[bool] = None, pipeline_depth: int = 2,
                 continuous_out_cap: Optional[int] = 65536,
                 device=None):
        self.site = site
        # backend=None defers to the SDE_BACKEND env toggle (default
        # "xla"), so whole suites flip to the Pallas registry kernels
        # untouched — the same pattern as SDE_PIPELINED below
        if backend is None:
            backend = os.environ.get("SDE_BACKEND", "") or "xla"
        self.backend = backend
        self.mesh = mesh
        if device is not None and mesh is not None:
            raise ValueError(
                "pass mesh= (shard stacks over devices) OR device= (pin a "
                "federation site to one device), not both")
        self.device = device
        self.rules = rules or specs.DEFAULT_RULES
        self.stacks: Dict[Any, _KindStack] = {}
        self.entries: Dict[str, _Entry] = {}
        # bounded: a consumer that falls behind loses the OLDEST
        # responses (counted in .dropped), never stalls ingest
        self.continuous_out = pipeline.BoundedResponseLog(continuous_out_cap)
        # pipelined=None defers to the SDE_PIPELINED env toggle, so whole
        # suites (CI's pipelined smoke job) flip execution mode untouched
        if pipelined is None:
            pipelined = os.environ.get("SDE_PIPELINED", "") not in ("", "0")
        self.pipelined = bool(pipelined)
        self._pipeline = (pipeline.IngestPipeline(
            self._retire_batch, depth=pipeline_depth, tag=site)
            if self.pipelined else None)
        self.tuples_ingested = 0
        self.batches_ingested = 0   # monotonic; keys continuous responses
        # continuous queries grouped by kind: {kind: (ids, rows)} — rebuilt
        # lazily after any lifecycle change so _emit_continuous issues one
        # stacked-estimate dispatch per kind, not one gather per entry
        self._cq_groups: Optional[Dict[Any, Any]] = None
        # multidim synopsis families (family id -> key-encoding spec) and
        # the continuous outlier workflows riding them; the per-tick
        # outlier plans invalidate together with _cq_groups (every
        # lifecycle mutation clears both through _invalidate_plans)
        self.multidim: Dict[str, MultidimSpec] = {}
        self.outliers: Dict[str, outliers.OutlierWorkflow] = {}
        self._ow_plans: Optional[List[outliers.OutlierPlan]] = None
        # durability plumbing. Ingest routes ON DEVICE (the probe runs
        # inside the fused program), so the hot path cannot know which
        # rows a batch touched; it appends the batch's stream ids here
        # instead, and _resolve_dirty folds whole windows into per-stack
        # dirty sets with one vectorized table lookup (deferred dirty
        # tracking — O(0) device work, O(batch) host append per ingest).
        self._pending_dirty: List[np.ndarray] = []
        # incremental-snapshot lineage: the full base step and the delta
        # steps stacked on it (oldest first), valid for _ckpt_dir
        self._ckpt_dir: Optional[str] = None
        self._ckpt_base: Optional[int] = None
        self._ckpt_chain: List[int] = []
        # background (async_=True) saves that never landed — detected at
        # the next snapshot, which then rebuilds from a fresh full base
        self.ckpt_failures = 0
        # highest write-ahead-log sequence number already folded into
        # this engine's state — snapshots persist it so recovery replays
        # only the WAL tail (exactly-once; see service/wal.py)
        self.wal_seq = 0

    def _new_stack(self, kind: Synopsis, capacity: int = 64) -> _KindStack:
        return _KindStack(kind, capacity, mesh=self.mesh, rules=self.rules,
                          device=self.device)

    # ------------------------------------------------------------------
    # red path: requests
    # ------------------------------------------------------------------
    def handle(self, snippet: str | dict) -> api.Response:
        try:
            req = api.parse_request(snippet)
            if isinstance(req, api.BuildSynopsis):
                return self._build(req)
            if isinstance(req, api.StopSynopsis):
                return self._stop(req)
            if isinstance(req, api.LoadSynopsis):
                return self._load(req)
            if isinstance(req, api.AdHocQuery):
                return self._query(req)
            if isinstance(req, api.QueryMany):
                return self._query_many_req(req)
            if isinstance(req, api.Ingest):
                return self._ingest_req(req)
            if isinstance(req, api.BuildMultidim):
                return self._build_multidim(req)
            if isinstance(req, api.IngestMultidim):
                return self._ingest_multidim_req(req)
            if isinstance(req, api.SubpopQuery):
                return self._subpop_query(req)
            if isinstance(req, api.TrackOutliers):
                return self._track_outliers(req)
            if isinstance(req, api.UntrackOutliers):
                return self._untrack_outliers(req)
            if isinstance(req, api.Flush):
                return self._flush_req(req)
            if isinstance(req, api.Shutdown):
                return self._shutdown_req(req)
            if isinstance(req, api.StatusReport):
                return self._status(req)
            raise ValueError(f"unhandled request {req}")
        except Exception as e:  # noqa: BLE001 - service returns errors
            rid = ""
            try:
                rid = json.loads(snippet)["request_id"] if isinstance(
                    snippet, str) else snippet.get("request_id", "")
            except Exception:
                pass
            return api.Response(request_id=rid, ok=False, error=repr(e))

    def _build(self, req: api.BuildSynopsis) -> api.Response:
        # fence: builds can grow stacks (capacity doubling) and mutate
        # routing tables; pending continuous batches retire first
        self.flush()
        kind = core.make_kind(req.kind, **req.params)
        # validate EVERY routed stream id before any allocation: a failed
        # build must not commit partial entries. Ids are arbitrary 63-bit
        # ints (hashed routing) — only unrepresentable ids (negative or
        # >= 2**63) are rejected.
        if req.per_stream_of_source:
            sid_list = (req.stream_ids if req.stream_ids is not None
                        else range(req.n_streams))
            for sid in sid_list:
                _check_stream_id(sid)
            # canonicalize + dedupe: the entry id (f"{syn}/{sid}") and the
            # routed key must agree, or non-canonical forms (7.0 vs 7)
            # would commit shadow entries that never receive updates
            sid_list = list(dict.fromkeys(int(s) for s in sid_list))
        else:
            sid_list = None
            _check_stream_id(req.stream_id)
        stack = self.stacks.get(kind)
        if stack is None:
            cap = 64
            if sid_list:
                cap = max(64, _next_pow2(len(sid_list)))
            stack = self._new_stack(kind, cap)
            self.stacks[kind] = stack

        def add_one(sid: Optional[int], syn_id: str, routed: list):
            # reuse: same id => same synopsis shared across workflows
            if syn_id in self.entries:
                return
            row = stack.alloc()
            if sid is None:
                stack.mark_source(row)
            else:
                routed.append((int(sid), row))
            self.entries[syn_id] = _Entry(
                synopsis_id=syn_id, kind_key=kind, row=row, stream_id=sid,
                federated=req.federated,
                responsible_site=req.responsible_site,
                continuous=req.continuous, source_id=req.source_id)

        routed: List[tuple] = []
        if sid_list is not None:
            for sid in sid_list:
                add_one(int(sid), f"{req.synopsis_id}/{sid}", routed)
        else:
            add_one(req.stream_id, req.synopsis_id, routed)
        if routed:
            # one vectorized table insert for the whole build
            stack.table.insert_many(
                np.asarray([s for s, _ in routed], np.int64),
                np.asarray([r for _, r in routed], np.int32))
        self._invalidate_plans()
        return api.Response(request_id=req.request_id,
                            synopsis_id=req.synopsis_id,
                            params=kind_params(kind))

    def _stop(self, req: api.StopSynopsis) -> api.Response:
        # fence: stopping frees + re-initializes rows and compacts the
        # routing table; the stopped synopses' final continuous responses
        # (already dispatched) must land in continuous_out first
        self.flush()
        ids = [k for k in self.entries
               if k == req.synopsis_id or k.startswith(req.synopsis_id + "/")]
        if not ids:
            return api.Response(request_id=req.request_id, ok=False,
                                error=f"unknown synopsis {req.synopsis_id!r}")
        freed: Dict[Any, List[int]] = {}
        for k in ids:
            e = self.entries.pop(k)
            freed.setdefault(e.kind_key, []).append(e.row)
        for kind, rows in freed.items():
            self.stacks[kind].free_rows(rows)
            # a kind nothing references anymore releases BOTH its stack
            # state and its compiled programs (the KindCaches are bounded
            # by engine lifecycle, not append-only). Kind instances are
            # value-equal across engines, so another engine still serving
            # the same parameters merely re-jits on its next batch.
            if not any(e.kind_key == kind for e in self.entries.values()):
                del self.stacks[kind]
                kops.evict_kind_caches(kind)
        # a stopped multidim family takes its key spec with it; workflows
        # watching it go dormant (the planner skips missing families)
        self.multidim.pop(req.synopsis_id, None)
        self._invalidate_plans()
        return api.Response(request_id=req.request_id,
                            synopsis_id=req.synopsis_id, value=len(ids))

    def _load(self, req: api.LoadSynopsis) -> api.Response:
        """Dynamic pluggability: import factory while the service runs."""
        mod_name, _, attr = req.factory_path.partition(":")
        factory = getattr(importlib.import_module(mod_name), attr)
        core.register_kind(req.kind_name, factory, overwrite=True)
        return api.Response(request_id=req.request_id, value=req.kind_name)

    def _query(self, req: api.AdHocQuery) -> api.Response:
        return self.query_many([req])[0]

    def query_many(self, requests: Sequence[api.AdHocQuery]
                   ) -> List[api.Response]:
        """Answer N ad-hoc queries with ONE jitted stacked-estimate
        dispatch per kind touched (the batched red path, paper Fig. 8):
        queries are grouped by kind, their args batched into padded device
        arrays, and each group reads the `synopsis`-sharded stack state in
        place — no per-query host round trip."""
        # fence: pending continuous batches were dispatched against
        # earlier state; they retire before ad-hoc reads answer, so the
        # response stream stays in ingest order
        self.flush()
        responses: List[Optional[api.Response]] = [None] * len(requests)
        groups: Dict[Any, List[int]] = {}
        for i, req in enumerate(requests):
            e = self.entries.get(req.synopsis_id)
            if e is None:
                responses[i] = api.Response(
                    request_id=req.request_id, ok=False,
                    error=f"unknown synopsis {req.synopsis_id!r}")
            elif req.query is not None and not isinstance(req.query, dict):
                # fails alone — never poisons the rest of the batch
                responses[i] = api.Response(
                    request_id=req.request_id, ok=False,
                    error="query must be an object, got "
                          f"{type(req.query).__name__}")
            else:
                groups.setdefault(e.kind_key, []).append(i)
        for kind, idxs in groups.items():
            stack = self.stacks[kind]
            rows = [self.entries[requests[i].synopsis_id].row for i in idxs]
            vals, errs = self._estimate_rows(
                kind, stack, rows, [requests[i].query or {} for i in idxs])
            for i, val, err in zip(idxs, vals, errs):
                if err is not None:
                    responses[i] = api.Response(
                        request_id=requests[i].request_id,
                        synopsis_id=requests[i].synopsis_id,
                        ok=False, error=err)
                else:
                    responses[i] = api.Response(
                        request_id=requests[i].request_id,
                        synopsis_id=requests[i].synopsis_id, value=val,
                        params=kind_params(kind))
        return responses

    def _query_many_req(self, req: api.QueryMany) -> api.Response:
        subs: List[Optional[api.AdHocQuery]] = []
        prefail: Dict[int, api.Response] = {}
        for i, q in enumerate(req.queries):
            rid = f"{req.request_id}/{i}"
            if isinstance(q, dict):
                # pass the query field through untouched (no `or {}`):
                # query_many rejects non-dict values uniformly, including
                # falsy ones like 0 or ""
                subs.append(api.AdHocQuery(
                    request_id=rid, synopsis_id=q.get("synopsis_id", ""),
                    query=q["query"] if "query" in q else {}))
            else:
                # a malformed entry fails alone; the rest of the batch runs
                prefail[i] = api.Response(
                    request_id=rid, ok=False,
                    error="query entry must be an object, got "
                          f"{type(q).__name__}")
                subs.append(None)
        answered = iter(self.query_many([s for s in subs if s is not None]))
        rs = [prefail[i] if s is None else next(answered)
              for i, s in enumerate(subs)]
        n_fail = sum(1 for r in rs if not r.ok)
        return api.Response(request_id=req.request_id, ok=n_fail == 0,
                            error=(f"{n_fail}/{len(rs)} queries failed"
                                   if n_fail else ""),
                            value=[dataclasses.asdict(r) for r in rs])

    def _ingest_req(self, req: api.Ingest) -> api.Response:
        """JSON blue path: the ack carries the monotonic batch counter
        (keys this batch's ``cq/<id>/<batch>`` continuous responses) and
        the pipeline depth at return time."""
        batch = self.ingest(req.stream_ids, req.values, req.mask)
        return api.Response(
            request_id=req.request_id,
            value=dict(batch=batch, tuples_ingested=self.tuples_ingested,
                       in_flight=self.pending_batches))

    # ------------------------------------------------------------------
    # multidim subpopulations (tentpole): a family id maps to a
    # MultidimSpec; every group is an ORDINARY per-stream entry
    # (f"<family>/<group key>") on the fused blue path, so maintenance
    # costs exactly what the same number of scalar streams would.
    # ------------------------------------------------------------------
    def _build_multidim(self, req: api.BuildMultidim) -> api.Response:
        if not req.synopsis_id:
            raise ValueError("build_multidim needs a synopsis_id")
        if req.synopsis_id in self.multidim:
            raise ValueError(
                f"multidim family {req.synopsis_id!r} already exists")
        spec = MultidimSpec(
            req.dims,
            levels=None if req.levels is None
            else [tuple(lvl) for lvl in req.levels])
        keys = spec.all_keys()
        if len(set(keys)) != len(keys):
            # birthday-bound 63-bit collision across the family's groups
            # (~n^2/2^64): astronomically rare, but aliased groups would
            # silently share one synopsis — fail loudly instead
            raise ValueError(
                "group-key collision inside the family; rename a "
                "dimension or value to re-roll the hashes")
        resp = self._build(api.BuildSynopsis(
            request_id=req.request_id, synopsis_id=req.synopsis_id,
            kind=req.kind, params=req.params, per_stream_of_source=True,
            stream_ids=keys, continuous=req.continuous))
        if resp.ok:
            self.multidim[req.synopsis_id] = spec
            resp.params = dict(resp.params, n_groups=spec.n_groups(),
                               n_levels=len(spec.levels))
        return resp

    def _ingest_multidim_req(self, req: api.IngestMultidim) -> api.Response:
        batch = self.ingest_multidim(req.synopsis_id, req.records,
                                     req.values, req.mask, req.items)
        return api.Response(
            request_id=req.request_id, synopsis_id=req.synopsis_id,
            value=dict(batch=batch, tuples_ingested=self.tuples_ingested,
                       in_flight=self.pending_batches))

    def ingest_multidim(self, synopsis_id: str, records, values,
                        mask=None, items=None) -> int:
        """Blue path for attribute-tagged records: expand each record to
        its per-level group keys host-side and feed the expansion through
        the NORMAL ``ingest`` — one fused dispatch per kind, the probe
        untouched. ``items`` optionally carries per-record item
        identities for item-hashing sketches; default is the record's
        leaf-group key (so coarse groups count distinct leaf
        subpopulations). Returns the (single) batch id."""
        spec = self.multidim.get(synopsis_id)
        if spec is None:
            raise KeyError(f"unknown multidim family {synopsis_id!r}")
        n = len(records)
        vals = np.asarray(values, np.float32)
        if len(vals) != n:
            raise ValueError(
                f"ingest_multidim mismatch: {n} records vs "
                f"{len(vals)} values — the two must align 1:1")
        msk = (np.ones(n, bool) if mask is None
               else np.asarray(mask, bool))
        if len(msk) != n:
            raise ValueError(
                f"ingest_multidim mismatch: {n} records vs "
                f"{len(msk)} mask entries — the two must align 1:1")
        if items is None:
            its = np.asarray([spec.leaf_key(r) for r in records], np.int64)
        else:
            its = np.asarray(items, np.int64)
            if len(its) != n:
                raise ValueError(
                    f"ingest_multidim mismatch: {n} records vs "
                    f"{len(its)} items — the two must align 1:1")
        lvl = len(spec.levels)
        sids = np.fromiter(
            (k for rec in records for k in spec.expand(rec)),
            np.int64, count=n * lvl)
        return self.ingest(sids, np.repeat(vals, lvl),
                           np.repeat(msk, lvl), items=np.repeat(its, lvl))

    def _subpop_query(self, req: api.SubpopQuery) -> api.Response:
        """Estimate over an arbitrary subpopulation — the covering key
        set of the predicate's level, merged + estimated in ONE fused
        dispatch (``kernels.ops.estimate_subpop``)."""
        # fence: a subpop read observes every ingested batch
        self.flush()
        spec = self.multidim.get(req.synopsis_id)
        if spec is None:
            raise KeyError(f"unknown multidim family {req.synopsis_id!r}")
        level, keys = spec.covering_keys(req.where)
        entries = [self.entries[f"{req.synopsis_id}/{k}"] for k in keys]
        kind = entries[0].kind_key
        if getattr(kind, "merge_mode", "gather") == "fresh":
            raise ValueError(
                f"{type(kind).__name__} replicas are exchanged, not "
                "merged — subpop_query needs a mergeable kind")
        args, take, errors = _plan_queries(kind, [req.query or {}])
        if errors[0] is not None:
            raise ValueError(errors[0])
        stack = self.stacks[kind]
        rows = jnp.asarray(np.asarray([e.row for e in entries], np.int32))
        out = kops.estimate_subpop(kind, stack.state, rows, *args,
                                   out_sharding=stack.out_sharding())
        kops.note_subpop(self.site, len(keys))
        return api.Response(
            request_id=req.request_id, synopsis_id=req.synopsis_id,
            value=take(jax.tree.map(np.asarray, out), 0),
            params=dict(kind_params(kind), cover_keys=len(keys),
                        level=list(level)))

    def _track_outliers(self, req: api.TrackOutliers) -> api.Response:
        if not req.workflow_id:
            raise ValueError("track_outliers needs a workflow_id")
        if req.workflow_id in self.outliers:
            raise ValueError(
                f"workflow {req.workflow_id!r} is already tracked")
        spec = self.multidim.get(req.synopsis_id)
        if spec is None:
            raise KeyError(f"unknown multidim family {req.synopsis_id!r}")
        if req.level is None:
            level = tuple(spec.dim_names)        # the leaf level
        else:
            level = tuple(n for n in spec.dim_names if n in set(req.level))
            for name in req.level:
                spec._check_dim(name)
        if level not in spec.levels:
            raise ValueError(
                f"level {level} is not materialized; available: "
                f"{spec.levels}")
        # the kind + query must plan cleanly NOW, not fail every tick
        kind = self.entries[
            f"{req.synopsis_id}/{spec.population_key()}"].kind_key
        if getattr(kind, "merge_mode", "gather") == "fresh":
            raise ValueError(
                f"{type(kind).__name__} cannot back an outlier workflow "
                "(non-mergeable replicas)")
        _, _, errors = _plan_queries(kind, [dict(req.query or {})])
        if errors[0] is not None:
            raise ValueError(errors[0])
        wf = outliers.OutlierWorkflow(
            workflow_id=req.workflow_id, synopsis_id=req.synopsis_id,
            level=level, query=dict(req.query or {}),
            threshold=float(req.threshold), min_dev=float(req.min_dev))
        self.outliers[req.workflow_id] = wf
        self._ow_plans = None
        return api.Response(
            request_id=req.request_id, synopsis_id=req.workflow_id,
            value=dict(level=list(level),
                       n_groups=len(spec.level_assignments(level))))

    def _untrack_outliers(self, req: api.UntrackOutliers) -> api.Response:
        if req.workflow_id not in self.outliers:
            raise KeyError(f"unknown workflow {req.workflow_id!r}")
        del self.outliers[req.workflow_id]
        self._ow_plans = None
        return api.Response(request_id=req.request_id,
                            synopsis_id=req.workflow_id, value=1)

    def _plan_outliers(self) -> List[outliers.OutlierPlan]:
        """One dispatch plan per live workflow: the level's group rows
        plus the population row (LAST), padded like any red-path batch,
        with the workflow's query planned once for every row. Workflows
        whose family or entries were stopped underneath them go dormant
        (skipped) instead of failing ingest."""
        plans: List[outliers.OutlierPlan] = []
        for wf in self.outliers.values():
            spec = self.multidim.get(wf.synopsis_id)
            if spec is None:
                continue
            assignments = spec.level_assignments(wf.level)
            ids = [f"{wf.synopsis_id}/{spec.group_key(a)}"
                   for a in assignments]
            ids.append(f"{wf.synopsis_id}/{spec.population_key()}")
            if any(i not in self.entries for i in ids):
                continue
            kind = self.entries[ids[0]].kind_key
            rows_arr = _pad_rows([self.entries[i].row for i in ids])
            args, take, _ = _plan_queries(
                kind, [dict(wf.query)] * len(rows_arr))
            plans.append(outliers.OutlierPlan(
                workflow=wf, kind_key=kind, assignments=assignments,
                rows=jnp.asarray(rows_arr), args=args, take=take,
                out_sharding=self.stacks[kind].out_sharding()))
        return plans

    def _flush_req(self, req: api.Flush) -> api.Response:
        drained = self.flush()
        return api.Response(
            request_id=req.request_id,
            value=dict(drained=drained,
                       batches_ingested=self.batches_ingested,
                       continuous_unread=len(self.continuous_out),
                       continuous_dropped=self.continuous_out.dropped))

    def _shutdown_req(self, req: api.Shutdown) -> api.Response:
        """Clean stop: flush (the pending continuous batches land in
        ``continuous_out`` before the ack), then ``close()`` — stacks and
        this engine's compiled-program cache entries are released. The
        ack carries the final counters; the engine object stays usable
        (a later build simply re-allocates)."""
        drained = self.flush()
        value = dict(drained=drained,
                     tuples_ingested=self.tuples_ingested,
                     batches_ingested=self.batches_ingested,
                     synopses=len(self.entries),
                     continuous_unread=len(self.continuous_out),
                     continuous_dropped=self.continuous_out.dropped)
        self.close()
        return api.Response(request_id=req.request_id, value=value)

    def _status(self, req: api.StatusReport) -> api.Response:
        per_row = {k: s.row_bytes() for k, s in self.stacks.items()}
        info = {
            sid: dict(kind=type(e.kind_key).__name__,
                      params=kind_params(e.kind_key),
                      stream=e.stream_id, federated=e.federated,
                      memory_bytes=per_row[e.kind_key])
            for sid, e in self.entries.items()}
        # elasticity probes ride ``params`` (the JSON status response
        # surfaces them) so ``value`` keeps its per-synopsis shape — the
        # gateway's tenant filtering and len(status.value) stay intact
        return api.Response(
            request_id=req.request_id, value=info,
            params=dict(
                site=self.site,
                reconcile_count=int(kops.RECONCILE_COUNT[self.site]),
                migrated_rows=int(kops.MIGRATED_ROWS[self.site]),
                rebalance_imbalance=float(
                    kops.REBALANCE_IMBALANCE[self.site]),
                checkpoint_bytes=int(kops.CHECKPOINT_BYTES[self.site]),
                dirty_rows=int(kops.DIRTY_ROWS[self.site]),
                wal_appends=int(kops.WAL_APPENDS[self.site]),
                subpop_cover_keys=int(kops.SUBPOP_COVER_KEYS[self.site]),
                outlier_emits=int(kops.OUTLIER_EMITS[self.site])))

    # ------------------------------------------------------------------
    # blue path: data
    # ------------------------------------------------------------------
    def ingest(self, stream_ids, values, mask=None, items=None) -> int:
        """One batch of (stream, value) tuples; updates EVERY maintained
        synopsis of every kind with EXACTLY ONE jitted, donated-buffer
        dispatch per kind stack — hashed routing probe, routed rows and
        data-source rows are fused into that single program.

        ``stream_ids``/``values`` accept anything ``np.asarray`` takes
        (the JSON/service path hands in plain Python lists). Stream ids
        are arbitrary ints in ``[0, 2**63)``; only unrepresentable ids
        (negative, or uint64 values >= 2**63) are masked out.

        ``items`` optionally decouples each tuple's ITEM identity (what
        the item-hashing sketches — HLL/Bloom/FM/CM/AMS — hash) from its
        ROUTING key; default is the stream id itself, the pre-multidim
        behaviour. The multidim path threads per-record item ids through
        here so a record's 2**d group copies all hash the same identity.

        Returns the batch's monotonic id — the counter that keys this
        batch's continuous responses (``cq/<synopsis>/<id>``). Eager
        engines materialize those responses before returning; pipelined
        engines park them on the bounded queue and return immediately
        (see ``flush``)."""
        sid_arr = np.asarray(stream_ids)
        # np.asarray(values, float32) is a NO-OP when the caller already
        # hands in float32 (the hot path) — .astype would always copy
        vals_np = np.asarray(values, np.float32)
        if len(vals_np) != len(sid_arr):
            raise ValueError(
                f"ingest batch mismatch: {len(sid_arr)} stream_ids vs "
                f"{len(vals_np)} values — the two must align 1:1")
        t = len(sid_arr)
        if mask is None:
            mask = np.ones(t, bool)
        else:
            mask = np.asarray(mask, bool)
            if len(mask) != t:
                raise ValueError(
                    f"ingest batch mismatch: {t} stream_ids vs "
                    f"{len(mask)} mask entries — the two must align 1:1")
        items64 = None
        if items is not None:
            items64 = np.asarray(items, np.int64)
            if len(items64) != t:
                raise ValueError(
                    f"ingest batch mismatch: {t} stream_ids vs "
                    f"{len(items64)} items — the two must align 1:1")
        sid64 = sid_arr.astype(np.int64)
        mask = mask & (sid64 >= 0)
        self.tuples_ingested += int(mask.sum())
        self.batches_ingested += 1
        # deferred dirty tracking: park this batch's surviving ids; the
        # window resolves to rows in one vectorized lookup per stack
        # (data-source rows absorb every batch, so an all-unroutable
        # batch still has to be parked to mark them)
        self._pending_dirty.append(sid64[mask])
        if len(self._pending_dirty) >= _DIRTY_RESOLVE_EVERY:
            self._resolve_dirty()
        batch_id = self.batches_ingested
        lo, hi = routing.split64(sid64)
        sid_lo = jnp.asarray(lo)
        sid_hi = jnp.asarray(hi)
        items = jnp.asarray(routing.fold64(
            sid64 if items64 is None else items64))
        vals = jnp.asarray(vals_np)
        msk = jnp.asarray(mask)
        for kind, stack in self.stacks.items():
            if stack.is_timeseries:
                self._ingest_timeseries(stack, sid_lo, sid_hi, vals, msk)
            else:
                self._ingest_stack(stack, sid_lo, sid_hi, items, vals, msk)
        pending = self._dispatch_continuous(batch_id)
        if pending is not None:
            if self._pipeline is not None:
                self._pipeline.submit(pending)
            else:
                self._retire_batch(pending)
        return batch_id

    def flush(self) -> int:
        """Pipeline barrier: materialize every pending continuous batch
        into ``continuous_out`` (oldest first — the order eager emission
        would have produced). Returns the number of batches drained; 0
        on an eager engine or an idle pipeline. This is the ONLY point a
        pipelined blue path syncs device→host; the engine calls it as a
        fence before query reads, build/stop/grow, snapshot and merge."""
        if self._pipeline is None:
            return 0
        return self._pipeline.flush()

    def close(self) -> None:
        """Retire the engine: drain the pipeline, then release every kind
        stack and this engine's share of the compiled-program caches
        (update/step/estimate entries keyed by its kinds). Idempotent;
        the engine stays usable — a later build simply re-allocates."""
        self.flush()
        for kind in list(self.stacks):
            kops.evict_kind_caches(kind)
        self.stacks.clear()
        self.entries.clear()
        self.multidim.clear()
        self.outliers.clear()
        self._invalidate_plans()

    def _invalidate_plans(self) -> None:
        """Drop the cached per-tick dispatch plans (continuous-query
        groups + outlier plans) — called by EVERY lifecycle mutation;
        both replan lazily on the next ingest."""
        self._cq_groups = None
        self._ow_plans = None

    @property
    def pending_batches(self) -> int:
        """Ingest batches whose continuous output is still in flight."""
        return self._pipeline.in_flight if self._pipeline else 0

    def _ingest_stack(self, stack: _KindStack, sid_lo, sid_hi, items,
                      vals, msk):
        klo, khi, trows = stack.device_table()
        stack.state = _update(
            stack.kind, self.backend, stack.sharding, stack.n_probe,
            stack.state, klo, khi, trows, sid_lo, sid_hi, items, vals,
            msk, stack.source_rows_idx())

    def _ingest_timeseries(self, stack: _KindStack, sid_lo, sid_hi,
                           vals, msk):
        """Time-series kinds (DFT): one tick per stream per batch — the
        batch is a StatStream 'basic window'; the last value per stream
        wins (documented resolution reduction). Route probe + step are
        one fused dispatch."""
        klo, khi, trows = stack.device_table()
        stack.state = _step_all(stack.kind, stack.sharding, stack.n_probe,
                                stack.state, klo, khi, trows, sid_lo,
                                sid_hi, vals, msk)

    def _dispatch_continuous(self, batch_id: int
                             ) -> Optional[pipeline.PendingBatch]:
        """Evaluate ALL continuous queries of a kind per ingest batch in a
        single stacked-estimate program — no per-entry row gather. The
        padded rows array, planned (default) args and output sharding are
        byte-identical between lifecycle changes, so they are cached with
        the grouping: per-ingest host work is O(1) plus the dispatch.
        Response ids key on the monotonic batch counter — a batch whose
        tuples are all masked out must still emit FRESH request ids, not
        collide with the previous batch's.

        Returns the batch's un-materialized emissions (device futures) —
        NO host sync happens here; ``_retire_batch`` materializes them
        either immediately (eager) or when the pipeline retires the
        batch. None when no continuous queries OR outlier workflows are
        registered. Outlier ticks dispatch here too (one extra
        ``estimate_all`` per workflow, same maintained state — zero
        extra builds) and score host-side at retirement."""
        if self._cq_groups is None:
            self._cq_groups = self._plan_continuous()
        if self._ow_plans is None:
            self._ow_plans = self._plan_outliers()
        if not self._cq_groups and not self._ow_plans:
            return None
        emissions = []
        for kind, (ids, rows_dev, args, take, out_sh) in \
                self._cq_groups.items():
            out = kops.estimate_all(kind, self.stacks[kind].state,
                                    rows_dev, *args, out_sharding=out_sh)
            emissions.append((ids, take, out))
        extras = []
        for plan in self._ow_plans:
            out = kops.estimate_all(
                plan.kind_key, self.stacks[plan.kind_key].state,
                plan.rows, *plan.args, out_sharding=plan.out_sharding)
            extras.append((plan, out))
        return pipeline.PendingBatch(batch_id, emissions, extras)

    def _retire_batch(self, pending: pipeline.PendingBatch) -> None:
        """Materialize one batch's continuous outputs (the only
        device→host sync of the blue path) into ``continuous_out``,
        then score the batch's outlier ticks (``ow/<wf>/<batch>``)."""
        for ids, take, out in pending.emissions:
            out = jax.tree.map(np.asarray, out)
            for i, sid in enumerate(ids):
                self.continuous_out.append(api.Response(
                    request_id=f"cq/{sid}/{pending.batch_id}",
                    synopsis_id=sid, value=take(out, i)))
        for plan, out in pending.extras:
            out = jax.tree.map(np.asarray, out)
            ests = [plan.take(out, i)
                    for i in range(len(plan.assignments) + 1)]
            payload = outliers.evaluate_tick(plan, ests)
            kops.note_outlier(self.site, len(payload["outliers"]))
            self.continuous_out.append(api.Response(
                request_id=(f"ow/{plan.workflow.workflow_id}"
                            f"/{pending.batch_id}"),
                synopsis_id=plan.workflow.workflow_id, value=payload))

    def _plan_continuous(self) -> Dict[Any, Any]:
        by_kind: Dict[Any, List[Any]] = {}
        for sid, e in self.entries.items():
            if e.continuous:
                by_kind.setdefault(e.kind_key, []).append((sid, e.row))
        groups: Dict[Any, Any] = {}
        for kind, members in by_kind.items():
            ids = [sid for sid, _ in members]
            rows_arr = _pad_rows([row for _, row in members])
            args, take, _ = _plan_queries(kind, [{}] * len(rows_arr))
            groups[kind] = (ids, jnp.asarray(rows_arr), args, take,
                            self.stacks[kind].out_sharding())
        return groups

    # ------------------------------------------------------------------
    def _estimate_rows(self, kind, stack: _KindStack, rows: Sequence[int],
                       queries: Sequence[Dict[str, Any]]):
        """Answer ``len(rows)`` queries against one kind stack with ONE
        jitted dispatch. Rows and per-query args are padded to the next
        power of two so repeated batch sizes reuse the cached program."""
        n = len(rows)
        rows_arr = _pad_rows(rows)
        args, take, errors = _plan_queries(
            kind, list(queries) + [{}] * (len(rows_arr) - n))
        out = kops.estimate_all(kind, stack.state, jnp.asarray(rows_arr),
                                *args, out_sharding=stack.out_sharding())
        out = jax.tree.map(np.asarray, out)
        return [take(out, i) for i in range(n)], errors[:n]

    def state_of(self, synopsis_id: str):
        self.flush()   # fence: a state read observes all ingested batches
        e = self.entries[synopsis_id]
        return batched.stacked_row(self.stacks[e.kind_key].state, e.row)

    def memory_bytes(self) -> int:
        return sum(
            x.size * x.dtype.itemsize
            for s in self.stacks.values() for x in jax.tree.leaves(s.state))

    # ------------------------------------------------------------------
    # fault tolerance + elasticity (all state movement rides the
    # migration plane: service/migration.py)
    # ------------------------------------------------------------------
    def migrate_rows(self, kind: Any, mapping: Dict[int, int]) -> int:
        """Live intra-stack migration: relocate row ``src`` to
        ``mapping[src]`` for a whole batch of rows at once — the
        reconciler's mover for rebalancing across the ``synopsis`` mesh
        axis (a row's position picks its device shard). Fences the
        pipeline first (at most the in-flight batches retire), then one
        on-device gather/scatter plus an atomic routing remap; the probe
        layout is untouched, so nothing retraces. Returns rows moved."""
        self.flush()
        stack = self.stacks[kind]
        mapping = {int(s): int(d) for s, d in mapping.items()
                   if int(s) != int(d)}
        if not mapping:
            return 0
        for s in mapping:
            if not stack.used[s]:
                raise ValueError(f"migrate_rows: source row {s} is free")
        migration.move_rows(stack, mapping)
        for e in self.entries.values():
            if e.kind_key == kind and e.row in mapping:
                e.row = mapping[e.row]
        self._invalidate_plans()
        kops.note_migrated(self.site, len(mapping))
        return len(mapping)

    def resize_stack(self, kind: Any, new_capacity: int) -> int:
        """Grow or shrink a kind stack to ``new_capacity`` rows (the
        reconciler's capacity knob; alloc's doubling keeps working
        independently). Growth pads with the kind's init prototype;
        shrink requires every live row below the cut — ``compact``
        packs them down first. Returns the new capacity."""
        self.flush()
        stack = self.stacks[kind]
        new_capacity = int(new_capacity)
        if new_capacity < 1:
            raise ValueError(f"resize_stack: capacity {new_capacity} < 1")
        if new_capacity == stack.capacity:
            return stack.capacity
        if new_capacity > stack.capacity:
            stack.state = batched.grow(stack.kind, stack.state,
                                       new_capacity)
            stack.used.extend([False] * (new_capacity - stack.capacity))
        else:
            if any(stack.used[new_capacity:]):
                raise ValueError(
                    f"resize_stack: live rows at/above {new_capacity}; "
                    "compact (migrate them down) first")
            stack.state = batched.shrink(stack.state, new_capacity)
            stack.used = stack.used[:new_capacity]
        stack.capacity = new_capacity
        stack._free = None
        stack._source_idx = None
        stack._place()
        self._invalidate_plans()
        return stack.capacity

    def compact(self, kind: Any, min_capacity: int = 64) -> int:
        """Free-list compaction on the migration plane: pack live rows
        to the low end (ONE ``move_rows`` batch) and shrink capacity to
        the smallest power of two holding them — the scale-down half of
        elasticity. Returns the resulting capacity."""
        stack = self.stacks[kind]
        live = [r for r, u in enumerate(stack.used) if u]
        mapping = {r: i for i, r in enumerate(live) if r != i}
        if mapping:
            self.migrate_rows(kind, mapping)
        new_cap = max(min_capacity, _next_pow2(max(len(live), 1)))
        if new_cap < stack.capacity:
            self.resize_stack(kind, new_cap)
        return stack.capacity

    def extract_synopses(self, synopsis_ids: Sequence[str], *,
                         remove: bool = True) -> List[tuple]:
        """Package synopses for a cross-engine move: one
        ``(kind, entry_metas, RowPayload)`` per kind touched — host
        payloads that implant into any engine on any device or site.
        With ``remove=True`` (a true migration) the rows are freed here
        once extracted: state re-initialized, routes dropped, entries
        gone."""
        self.flush()
        by_kind: Dict[Any, List[_Entry]] = {}
        for sid in synopsis_ids:
            e = self.entries[sid]
            by_kind.setdefault(e.kind_key, []).append(e)
        package = []
        for kind, es in by_kind.items():
            payload = migration.extract_rows(
                self.stacks[kind], [e.row for e in es])
            metas = [dict(synopsis_id=e.synopsis_id,
                          stream_id=e.stream_id, federated=e.federated,
                          responsible_site=e.responsible_site,
                          continuous=e.continuous, source_id=e.source_id)
                     for e in es]
            package.append((kind, metas, payload))
        if remove:
            for kind, metas, _ in package:
                rows = [self.entries[m["synopsis_id"]].row for m in metas]
                for m in metas:
                    del self.entries[m["synopsis_id"]]
                self.stacks[kind].free_rows(rows)
                if not any(e.kind_key == kind
                           for e in self.entries.values()):
                    del self.stacks[kind]
                    kops.evict_kind_caches(kind)
            self._invalidate_plans()
        return package

    def implant_synopses(self, package: Sequence[tuple]) -> int:
        """Absorb ``extract_synopses`` output: per kind, allocate rows,
        scatter the payload in (one dispatch per state leaf) and commit
        its routing keys with one table insert. The receiving half of a
        cross-site migration; returns synopses implanted."""
        self.flush()
        # validate BEFORE any allocation: a failed implant must not
        # commit partial state (same contract as _build)
        for _, metas, _ in package:
            for m in metas:
                if m["synopsis_id"] in self.entries:
                    raise ValueError(
                        f"implant_synopses: {m['synopsis_id']!r} already "
                        "lives here (matched ids merge via merge_from)")
        n = 0
        for kind, metas, payload in package:
            if kind not in self.stacks:
                self.stacks[kind] = self._new_stack(
                    kind, max(64, _next_pow2(len(metas))))
            stack = self.stacks[kind]
            rows = [stack.alloc() for _ in metas]
            migration.implant_rows(stack, rows, payload)
            for m, row in zip(metas, rows):
                self.entries[m["synopsis_id"]] = _Entry(
                    kind_key=kind, row=row, **m)
            n += len(metas)
            kops.note_migrated(self.site, len(metas))
        self._invalidate_plans()
        return n

    def _resolve_dirty(self) -> None:
        """Fold the parked ingest-window stream ids into each stack's
        dirty set: one vectorized table lookup per stack over the
        deduped window. Rows that moved or were freed AFTER a parked
        batch are already dirty (the plane and ``free_rows`` mark both
        ends), so resolving against the CURRENT table is exact — at
        worst a superset, never a miss."""
        if not self._pending_dirty:
            return
        sids = np.unique(np.concatenate(self._pending_dirty))
        self._pending_dirty.clear()
        for stack in self.stacks.values():
            rows = stack.table.lookup_many(sids)
            stack.mark_dirty(rows[rows >= 0])
            # data-source rows absorb EVERY batch of the window
            if stack.source_rows:
                stack.mark_dirty(stack.source_rows)

    def _manifest(self, kinds: List[Any]) -> Dict[str, Any]:
        """The restore-authoritative engine metadata every snapshot
        (full or delta) carries: per-stack lifecycle (used/source/table
        layout), the entry registry and the counters."""
        from repro.core.synopsis import name_of_kind
        return dict(
            site=self.site, backend=self.backend,
            tuples_ingested=self.tuples_ingested,
            batches_ingested=self.batches_ingested,
            wal_seq=self.wal_seq,
            stacks=[dict(kind=name_of_kind(k),
                         params=_json_params(kind_params(k)),
                         capacity=self.stacks[k].capacity,
                         used=self.stacks[k].used,
                         source_rows=self.stacks[k].source_rows,
                         table=dict(size=self.stacks[k].table.size,
                                    count=self.stacks[k].table.count,
                                    max_probe=self.stacks[k].table.max_probe))
                    for k in kinds],
            entries={sid: dict(kind_index=kinds.index(e.kind_key),
                               row=e.row, stream_id=e.stream_id,
                               federated=e.federated,
                               responsible_site=e.responsible_site,
                               continuous=e.continuous,
                               source_id=e.source_id)
                     for sid, e in self.entries.items()},
            multidim={sid: spec.to_json_dict()
                      for sid, spec in self.multidim.items()},
            outlier_workflows=[wf.to_json_dict()
                               for wf in self.outliers.values()],
        )

    def snapshot(self, directory: str, step: int = 0, *,
                 incremental: bool = False, keep: int = 3,
                 async_: bool = False, rebase_every: int = 8) -> str:
        """Engine checkpoint (state + routing + registry). The routing
        table ships as its uint32 (keys_lo, keys_hi) halves plus the
        int32 rows array — byte-identical probe layout on restore,
        independent of the target device count (the mirror is
        replicated).

        ``incremental=True`` ships a **delta**: only the rows dirtied
        since the previous snapshot into this directory (plus the full —
        small — route export and manifest), chained onto the last full
        base via ``base_step``/``delta_chain`` lineage; after
        ``rebase_every`` deltas the chain folds into a fresh full base.
        A delta does NOT fence the pipeline — pulling a dirty slice
        waits only for that stack's dispatched updates, so checkpoint
        cost is O(rows touched), fully overlapped with pipelined ingest.
        ``async_=True`` moves the npz write + fsync to a background
        thread (the save's host copy is still synchronous — state may be
        mutated immediately after return); a concurrent save into the
        same directory waits for the previous one instead of racing its
        GC. Returns ``"full"`` or ``"delta"`` — which mode was taken."""
        from repro.training import checkpoint as ckpt
        self._resolve_dirty()
        if ckpt.take_error(directory) is not None:
            # the previous background save into this directory never
            # landed (its step is not on disk), so the lineage the chain
            # bookkeeping recorded is broken and the dirty rows it
            # cleared were never shipped. Drop the chain and take a
            # fresh FULL base — it re-ships every row, so nothing the
            # failed delta covered is lost.
            self.ckpt_failures += 1
            if self._ckpt_dir == directory:
                self._ckpt_base = None
                self._ckpt_chain = []
        chain_ok = (self._ckpt_dir == directory
                    and self._ckpt_base is not None
                    and len(self._ckpt_chain) < rebase_every)
        if not incremental or not chain_ok:
            return self._snapshot_full(directory, step, keep=keep,
                                       async_=async_)
        kinds = list(self.stacks)
        arrays: Dict[str, Any] = {}
        n_rows = 0
        manifest = self._manifest(kinds)
        manifest.update(snapshot_kind="delta", base_step=self._ckpt_base,
                        delta_chain=self._ckpt_chain + [step])
        for i, k in enumerate(kinds):
            stack = self.stacks[k]
            # rows past a shrink no longer exist (the restore-side
            # capacity adjust drops them the same way)
            rows = np.asarray(
                sorted(r for r in stack.dirty if r < stack.capacity),
                np.int32)
            payload = migration.extract_rows(stack, rows)
            arrays[f"stack{i}"] = dict(
                rows=rows, state=payload.state,
                keys_lo=payload.keys_lo, keys_hi=payload.keys_hi,
                source=payload.source,
                route=migration.export_route(stack.table))
            manifest["stacks"][i]["dirty_rows"] = int(rows.size)
            n_rows += int(rows.size)
        ckpt.save(arrays, directory, step, extra_manifest=manifest,
                  keep=keep, async_=async_)
        self._ckpt_chain.append(step)
        for k in kinds:
            self.stacks[k].dirty.clear()
        kops.note_checkpoint(self.site, _tree_nbytes(arrays), n_rows)
        return "delta"

    def _snapshot_full(self, directory: str, step: int, *,
                       keep: int = 3, async_: bool = False) -> str:
        from repro.training import checkpoint as ckpt
        # fence: every pending continuous batch retires before a full
        # checkpoint — a restore must not resurrect an engine that still
        # owes responses it can no longer produce (a delta skips this:
        # its bounded pull syncs only the dirty stacks' device work)
        self.flush()
        kinds = list(self.stacks)
        arrays = {}
        for i, k in enumerate(kinds):
            stack = self.stacks[k]
            arrays[f"stack{i}"] = dict(
                state=stack.state,
                route=migration.export_route(stack.table))
        manifest = self._manifest(kinds)
        manifest.update(snapshot_kind="full", base_step=None,
                        delta_chain=[])
        ckpt.save(arrays, directory, step, extra_manifest=manifest,
                  keep=keep, async_=async_)
        self._ckpt_dir = directory
        self._ckpt_base = step
        self._ckpt_chain = []
        n_rows = 0
        for k in kinds:
            self.stacks[k].dirty.clear()
            n_rows += self.stacks[k].capacity
        kops.note_checkpoint(self.site, _tree_nbytes(arrays), n_rows)
        return "full"

    def wait_for_snapshot(self) -> None:
        """Join the in-flight background (``async_=True``) save, if any —
        the durability barrier a server takes before acking a clean
        shutdown."""
        from repro.training import checkpoint as ckpt
        if self._ckpt_dir is not None:
            ckpt.wait(self._ckpt_dir)

    @classmethod
    def restore(cls, directory: str, step: Optional[int] = None, *,
                mesh: Optional[Mesh] = None,
                rules: Optional[specs.MeshRules] = None,
                pipelined: Optional[bool] = None) -> "SDE":
        """Rebuild a running engine from a snapshot (restart path). Pass
        a ``mesh`` to restore onto a (possibly different) device mesh —
        the elastic repartition path. A delta snapshot restores its full
        base first, then replays every chained delta through the
        migration plane (``implant_rows``), landing byte-identical to a
        full snapshot of the same moment."""
        import repro.core as core_mod
        from repro.training import checkpoint as ckpt
        # structure: rebuild kinds first, then load arrays into shape
        import json as _json
        import os
        ckpt.wait(directory)
        step_ = step if step is not None else ckpt.latest_step(directory)
        with open(os.path.join(directory, f"step-{step_:08d}",
                               "manifest.json")) as f:
            man = _json.load(f)
        if man.get("snapshot_kind") == "delta":
            eng = cls.restore(directory, int(man["base_step"]), mesh=mesh,
                              rules=rules, pipelined=pipelined)
            for s in man["delta_chain"]:
                eng._apply_delta(directory, int(s))
            eng._ckpt_chain = [int(s) for s in man["delta_chain"]]
            return eng
        eng = cls(site=man["site"], backend=man["backend"], mesh=mesh,
                  rules=rules, pipelined=pipelined)
        eng.tuples_ingested = man["tuples_ingested"]
        eng.batches_ingested = man.get("batches_ingested",
                                       man["tuples_ingested"])
        eng.wal_seq = man.get("wal_seq", 0)
        kinds = []
        like = {}
        for i, sk in enumerate(man["stacks"]):
            kind = core_mod.make_kind(sk["kind"], **sk["params"])
            stack = eng._new_stack(kind, sk["capacity"])
            stack.used = list(sk["used"])
            stack.source_rows = list(sk["source_rows"])
            eng.stacks[kind] = stack
            kinds.append(kind)
            if "table" in sk:
                route_like = migration.route_like(sk["table"]["size"])
            else:
                # pre-hashed-routing snapshot: one dense int32 route array
                route_like = np.zeros(_LEGACY_ROUTE_SLOTS, np.int32)
            like[f"stack{i}"] = dict(state=stack.state, route=route_like)
        arrays, _ = ckpt.restore(like, directory, step_)
        for i, kind in enumerate(kinds):
            stack = eng.stacks[kind]
            stack.state = arrays[f"stack{i}"]["state"]
            r = arrays[f"stack{i}"]["route"]
            sk = man["stacks"][i]
            if isinstance(r, dict):
                table = migration.import_route(r, sk["table"])
            else:
                # migrate the legacy dense route into a hash table
                dense = np.asarray(r, np.int32)
                occ = np.nonzero(dense >= 0)[0]
                table = routing.RouteTable()
                table.insert_many(occ.astype(np.int64), dense[occ])
            stack.table = table
            stack.dirty.clear()    # alloc-free rebuild; snapshot-clean
            stack._place()
        for sid, e in man["entries"].items():
            eng.entries[sid] = _Entry(
                synopsis_id=sid, kind_key=kinds[e["kind_index"]],
                row=e["row"], stream_id=e["stream_id"],
                federated=e["federated"],
                responsible_site=e["responsible_site"],
                continuous=e["continuous"], source_id=e["source_id"])
        eng.multidim = {sid: MultidimSpec.from_json_dict(o)
                        for sid, o in man.get("multidim", {}).items()}
        eng.outliers = {
            o["workflow_id"]: outliers.OutlierWorkflow.from_json_dict(o)
            for o in man.get("outlier_workflows", [])}
        eng._ckpt_dir = directory
        eng._ckpt_base = step_
        eng._ckpt_chain = []
        return eng

    def _apply_delta(self, directory: str, step: int) -> None:
        """Replay one delta snapshot onto this engine: adjust each
        stack's capacity, implant the dirty-row payload through the
        migration plane, then adopt the manifest's authoritative
        lifecycle metadata (used/source rows, the EXACT exported routing
        layout — implant's insert side effects are discarded so probe
        chains land where the saver had them) and counters. Stacks
        absent from the delta were stopped before it was taken."""
        import json as _json
        import os
        import repro.core as core_mod
        from repro.training import checkpoint as ckpt
        with open(os.path.join(directory, f"step-{step:08d}",
                               "manifest.json")) as f:
            man = _json.load(f)
        kinds = []
        like = {}
        for i, sk in enumerate(man["stacks"]):
            kind = core_mod.make_kind(sk["kind"], **sk["params"])
            kinds.append(kind)
            # the template only fixes tree structure + leaf dtypes;
            # shapes come from the stored blob
            proto = jax.tree.map(np.asarray, batched.stacked_init(kind, 1))
            like[f"stack{i}"] = dict(
                rows=np.zeros(0, np.int32), state=proto,
                keys_lo=np.zeros(0, np.uint32),
                keys_hi=np.zeros(0, np.uint32),
                source=np.zeros(0, bool),
                route=migration.route_like(sk["table"]["size"]))
        arrays, _ = ckpt.restore(like, directory, step)
        for k in list(self.stacks):
            if k not in kinds:
                del self.stacks[k]
                kops.evict_kind_caches(k)
        for i, (kind, sk) in enumerate(zip(kinds, man["stacks"])):
            cap = int(sk["capacity"])
            stack = self.stacks.get(kind)
            if stack is None:
                stack = self._new_stack(kind, cap)
                self.stacks[kind] = stack
            if cap > stack.capacity:
                stack.state = batched.grow(kind, stack.state, cap)
                stack.used.extend([False] * (cap - stack.capacity))
            elif cap < stack.capacity:
                stack.state = batched.shrink(stack.state, cap)
                stack.used = stack.used[:cap]
            stack.capacity = cap
            a = arrays[f"stack{i}"]
            rows = np.asarray(a["rows"], np.int32)
            migration.implant_rows(stack, rows, migration.RowPayload(
                state=a["state"],
                keys_lo=np.asarray(a["keys_lo"], np.uint32),
                keys_hi=np.asarray(a["keys_hi"], np.uint32),
                source=np.asarray(a["source"], bool)))
            stack.used = list(sk["used"])
            stack.source_rows = list(sk["source_rows"])
            stack.table = migration.import_route(a["route"], sk["table"])
            stack.dirty.clear()
            stack._source_idx = None
            stack._free = None
            stack._dev_table = None
            stack._dev_table_version = -1
            stack._place()
        self.entries = {
            sid: _Entry(synopsis_id=sid, kind_key=kinds[e["kind_index"]],
                        row=e["row"], stream_id=e["stream_id"],
                        federated=e["federated"],
                        responsible_site=e["responsible_site"],
                        continuous=e["continuous"],
                        source_id=e["source_id"])
            for sid, e in man["entries"].items()}
        self.tuples_ingested = man["tuples_ingested"]
        self.batches_ingested = man["batches_ingested"]
        self.wal_seq = man.get("wal_seq", 0)
        self.multidim = {sid: MultidimSpec.from_json_dict(o)
                         for sid, o in man.get("multidim", {}).items()}
        self.outliers = {
            o["workflow_id"]: outliers.OutlierWorkflow.from_json_dict(o)
            for o in man.get("outlier_workflows", [])}
        self._invalidate_plans()

    def merge_from(self, other: "SDE") -> None:
        """Elastic scale-down: absorb another engine's synopses.
        Matching synopsis ids merge (mergeability) — vectorized into ONE
        row-wise merge dispatch per kind; new ids ride the migration
        plane (one extract+implant payload per kind, routing keys
        carried alongside the state — no per-row copies)."""
        # fence BOTH engines: this engine's stacks are about to mutate,
        # and the absorbed engine's pending responses must surface on its
        # own log before its state is read (state_of fences `other` too)
        self.flush()
        other.flush()
        # engines pinned to different federation sites hold committed
        # arrays on different devices, which cannot mix in one dispatch:
        # pull the absorbed engine's contributions through host numpy
        # (uncommitted) so the merge programs run where THIS engine lives
        cross = ((self.device is not None or other.device is not None)
                 and self.device is not other.device)

        def pull(state):
            return jax.tree.map(np.asarray, state) if cross else state

        matches: Dict[Any, tuple[list[int], list[int]]] = {}
        transfers = []
        for sid, oe in other.entries.items():
            if sid in self.entries:
                e = self.entries[sid]
                if oe.kind_key != e.kind_key:
                    raise ValueError(
                        f"synopsis {sid!r} is {type(e.kind_key).__name__} "
                        f"here but {type(oe.kind_key).__name__} on "
                        f"{other.site!r}; cannot merge")
                rows_a, rows_b = matches.setdefault(e.kind_key, ([], []))
                rows_a.append(e.row)
                rows_b.append(oe.row)
            else:
                transfers.append(sid)
        for kind, (rows_a, rows_b) in matches.items():
            stack = self.stacks[kind]
            stack.state = federated.merge_rows(
                kind, stack.state, jnp.asarray(rows_a, jnp.int32),
                pull(other.stacks[kind].state),
                jnp.asarray(rows_b, jnp.int32))
            stack.mark_dirty(rows_a)
        if transfers:
            self.implant_synopses(
                other.extract_synopses(transfers, remove=False))
        self.tuples_ingested += other.tuples_ingested
        self.batches_ingested += other.batches_ingested
        self._invalidate_plans()


def _json_params(params):
    return {k: v for k, v in params.items()
            if isinstance(v, (int, float, str, bool))}


def _tree_nbytes(tree) -> int:
    """Bytes a snapshot's array pytree ships (device or host leaves)."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# jitted update/step dispatch, cached per (kind, backend, sharding,
# has_sources, n_probe, fuse_probe). The cached program is the WHOLE blue
# path for one kind: hashed routing probe, routed update and data-source
# update fused into one dispatch; the state buffer is donated (in-place on
# device), and — on a mesh — pinned to the stack's `synopsis`-axis sharding
# while the routing-table mirror stays replicated.
#
# Kernel choice is the REGISTRY's, not the engine's: under
# ``backend="pallas"`` the kind's declared ``update_kernel`` resolves to a
# fused probe+scatter Pallas program (one HBM pass per batch when
# ``SDE_FUSED_PROBE`` is on); kinds without a declaration — and the
# ``backend="xla"`` path — run probe-then-``batched.stacked_update``. The
# caches are bounded KindCaches: engines evict their kinds' entries on
# stop/close (``kops.KERNEL_CACHE_SIZE`` gauges them).
# ---------------------------------------------------------------------------

_UPDATE_CACHE = kops.KindCache("update")
_STEP_CACHE = kops.KindCache("step")


def _update_fn(kind, backend: str, sharding, has_sources: bool,
               n_probe: int, fuse_probe: bool):
    def build():
        name = f"update:{type(kind).__name__}"
        kernel = (kops.resolve_update_kernel(kind, fuse_probe)
                  if backend == "pallas" else None)

        def fused(state, klo, khi, trows, sid_lo, sid_hi, items, vals, msk,
                  *src):
            kops.TRACE_COUNT[name] += 1     # runs only when jit (re)traces
            src_rows = src[0] if has_sources else None
            if kernel is not None:
                return kernel(state, klo, khi, trows, sid_lo, sid_hi,
                              items, vals, msk, src_rows, n_probe=n_probe)
            syn_idx = kops.route_probe(klo, khi, trows, sid_lo, sid_hi,
                                       n_probe=n_probe)   # [-1 => unrouted]
            return batched.stacked_update(kind, state, syn_idx, items,
                                          vals, msk, src_rows)

        kw = dict(donate_argnums=0)
        if sharding is not None:
            kw["out_shardings"] = sharding
        return jax.jit(fused, **kw)

    return _UPDATE_CACHE.get(
        (kind, backend, sharding, has_sources, n_probe, fuse_probe), build)


def _update(kind, backend, sharding, n_probe, state, klo, khi, trows,
            sid_lo, sid_hi, items, vals, msk, src_rows=None):
    kops.DISPATCH_COUNT[f"update:{type(kind).__name__}"] += 1
    fn = _update_fn(kind, backend, sharding, src_rows is not None, n_probe,
                    kops.probe_fusion_enabled())
    if src_rows is None:
        return fn(state, klo, khi, trows, sid_lo, sid_hi, items, vals, msk)
    return fn(state, klo, khi, trows, sid_lo, sid_hi, items, vals, msk,
              src_rows)


def _step_fn(kind, sharding, n_probe: int):
    def build():
        def fused(state, klo, khi, trows, sid_lo, sid_hi, vals, msk):
            capacity = jax.tree.leaves(state)[0].shape[0]
            syn_idx = kops.route_probe(klo, khi, trows, sid_lo, sid_hi,
                                       n_probe=n_probe)
            routed = msk & (syn_idx >= 0)
            rows = jnp.where(routed, syn_idx, capacity)    # overflow slot
            # LAST routed tuple per row wins, deterministically:
            # scatter-max the tuple order, then gather each winner's value
            # (.at[].set with duplicate indices applies in
            # implementation-defined order)
            order = jnp.arange(sid_lo.shape[0], dtype=jnp.int32)
            winner = jnp.full((capacity + 1,), -1, jnp.int32)
            winner = winner.at[rows].max(jnp.where(routed, order, -1))[:-1]
            hit = winner >= 0
            per_row = jnp.where(hit, vals[jnp.maximum(winner, 0)], 0.0)
            return batched.stacked_step(kind, state, per_row, hit)

        kw = dict(donate_argnums=0)
        if sharding is not None:
            kw["out_shardings"] = sharding
        return jax.jit(fused, **kw)

    return _STEP_CACHE.get((kind, sharding, n_probe), build)


def _step_all(kind, sharding, n_probe, state, klo, khi, trows, sid_lo,
              sid_hi, vals, msk):
    return _step_fn(kind, sharding, n_probe)(state, klo, khi, trows,
                                             sid_lo, sid_hi, vals, msk)


# ---------------------------------------------------------------------------
# red-path query planning: normalize N query dicts for one kind into padded
# batched device args + a per-query result slicer. Kinds taking per-query
# ``items`` (CM, Bloom, Lossy, Sticky) or ``qs`` (GK) get ONE [N, L] arg
# (L = padded max arg length); every other kind is arg-free and returns its
# full estimation pytree per row.
# ---------------------------------------------------------------------------

_ITEM_KINDS = (core.CountMin, core.BloomFilter, core.LossyCounting,
               core.StickySampling)


_next_pow2 = routing.next_pow2


def _pad_rows(rows: Sequence[int]) -> np.ndarray:
    """Pad a row-index batch to the next power of two (padding rows point
    at row 0 — reads are side-effect free — and their results are sliced
    off) so repeated batch sizes reuse one compiled program."""
    padded = np.zeros((_next_pow2(len(rows)),), np.int32)
    padded[:len(rows)] = rows
    return padded


def _check_stream_id(sid: Optional[int]) -> None:
    """Reject stream ids the engine cannot represent. None (data-source
    synopses) is always valid; anything in [0, 2**63) routes (hashed
    routing — no table-size cap)."""
    if sid is not None and not (0 <= int(sid) <= routing.MAX_STREAM_ID):
        raise ValueError(
            f"stream id {sid} outside [0, 2**63); stream ids must be "
            "non-negative 63-bit ints")


def _coerce_items(raw, default) -> np.ndarray:
    """Per-query ``items`` arg -> uint32 identities, folding 64-bit item
    ids the same way ingest folds stream ids (``routing.fold64`` is the
    identity below 2**32, so small-id queries are unchanged)."""
    arr = np.asarray(raw if raw is not None else default, np.int64).ravel()
    if arr.size and (arr.min() < 0):
        raise ValueError(f"negative item id {int(arr.min())}")
    return routing.fold64(arr)


def _plan_queries(kind, queries: Sequence[Dict[str, Any]]):
    """Returns ``(args, take, errors)``: ``args`` are the batched device
    arrays to pass to ``kernels.ops.estimate_all`` after the rows
    argument, ``take(out, i)`` slices query ``i``'s value out of the
    (host-side) batched output — dropping arg padding for argful kinds —
    and ``errors[i]`` is an error string when query ``i``'s args failed
    to coerce (that query gets default args so ONE bad query never
    poisons the rest of the batch)."""
    errors: List[Optional[str]] = [None] * len(queries)
    if isinstance(kind, core.GKQuantiles):
        key, default, np_dtype = "qs", [0.5], np.float32
    elif isinstance(kind, _ITEM_KINDS):
        key, default, np_dtype = "items", [0], np.uint32
    else:
        def take(out, i):
            return jax.tree.map(lambda x: x[i], out)
        return (), take, errors
    lists = []
    for i, q in enumerate(queries):
        try:
            if key == "items":
                lists.append(_coerce_items(q.get(key), default))
            else:
                lists.append(
                    np.asarray(q.get(key, default), np_dtype).ravel())
        except (TypeError, ValueError, OverflowError) as e:
            lists.append(np.asarray(default, np_dtype).ravel())
            errors[i] = f"bad {key!r} in query: {e!r}"
    lens = [len(lst) for lst in lists]
    width = _next_pow2(max(max(lens), 1))
    arg = np.zeros((len(queries), width), np_dtype)
    for i, lst in enumerate(lists):
        arg[i, :len(lst)] = lst

    def take(out, i):
        return out[i, :lens[i]]
    return (jnp.asarray(arg),), take, errors


# ---------------------------------------------------------------------------
# Federation (yellow path): one SDE per geo-dispersed site
# ---------------------------------------------------------------------------
class Federation:
    """The paper's multi-cluster deployment: each site runs its own SDE;
    federated queries are synthesized at the responsible site.

    Pass a ``mesh`` carrying a ``site`` axis (``launch.mesh.
    make_federation_mesh``) — or a production multi-pod mesh, whose
    ``pod`` axis plays the site role over DCN — to run federation as a
    REAL collective: each site's SDE state is pinned to its slice of the
    axis (ingest executes site-locally on that device), and
    ``query_federated`` runs ONE shard_map-ped program in which
    ``federated.merge_over_axis`` merges the partial states via
    psum/pmax/all_gather and the stacked estimate executes on the merged
    result (``kernels.ops.estimate_collective``). Without a mesh the
    legacy single-device path gathers host copies and merges them at the
    responsible site (``kernels.ops.estimate_merged``) — the oracle the
    collective path is tested byte-identical against.

    The bytes a federated answer ships are reported per query (fig 5d):
    ``query_bytes`` (host-merge: every site's state) and
    ``collective_query_bytes`` (the collective's operand bytes)."""

    def __init__(self, sites: List[str], backend: Optional[str] = None,
                 mesh: Optional[Mesh] = None):
        self.sites = list(sites)
        self.mesh = mesh
        self.site_axis: Optional[str] = None
        self.fed_mesh: Optional[Mesh] = None    # 1-D lead-device submesh
        self._site_devices = None
        if mesh is not None and not mesh.empty:
            for ax in ("site", "pod"):
                if ax in mesh.axis_names:
                    self.site_axis = ax
                    break
            if self.site_axis is None:
                raise ValueError(
                    "federation mesh needs a 'site' or 'pod' axis (use "
                    "launch.mesh.make_federation_mesh, or a multi-pod "
                    f"production mesh); got axes {mesh.axis_names}")
            n = mesh.shape[self.site_axis]
            if n != len(self.sites):
                raise ValueError(
                    f"mesh axis {self.site_axis!r} has {n} slices for "
                    f"{len(self.sites)} sites; one slice per site")
            # one lead device per site slice: the DCN endpoint of the site
            idx = mesh.axis_names.index(self.site_axis)
            dev_nd = np.moveaxis(np.asarray(mesh.devices), idx, 0)
            self._site_devices = list(dev_nd.reshape(n, -1)[:, 0])
            self.fed_mesh = Mesh(np.asarray(self._site_devices),
                                 (self.site_axis,))
            self.sdes = {s: SDE(site=s, backend=backend, device=d)
                         for s, d in zip(self.sites, self._site_devices)}
        else:
            self.sdes = {s: SDE(site=s, backend=backend)
                         for s in self.sites}

    def broadcast(self, snippet: str | dict) -> Dict[str, api.Response]:
        return {s: sde.handle(snippet) for s, sde in self.sdes.items()}

    def handle(self, snippet: str | dict):
        """JSON entry point for federated workflows: ``federated_query``
        requests are answered once at the responsible site (collective
        merge on a mesh federation, host merge otherwise), with the
        fig 5d byte metrics in the response's ``params``; every other
        request type — including anything that fails to parse — is
        broadcast to all sites (returns ``{site: Response}``, per-site
        error responses for malformed snippets, so the return shape only
        depends on the request type, never on validity)."""
        try:
            req = api.parse_request(snippet)
        except Exception:  # noqa: BLE001 - malformed: keep broadcast shape
            return self.broadcast(snippet)
        if not isinstance(req, api.FederatedQuery):
            return self.broadcast(snippet)
        try:
            value, info = self._query_federated(
                req.synopsis_id, req.query, req.responsible_site)
            return api.Response(request_id=req.request_id,
                                synopsis_id=req.synopsis_id,
                                value=value, params=info)
        except Exception as e:  # noqa: BLE001 - service returns errors
            return api.Response(request_id=req.request_id,
                                synopsis_id=req.synopsis_id,
                                ok=False, error=repr(e))

    def _partial_states(self, synopsis_id: str):
        """(kind, per-site partial states, full-coverage flag). Reading a
        site's state fences its pipeline first (``state_of`` flushes), so
        a federated answer observes every ingested batch even under
        pipelined blue paths."""
        states, kind = [], None
        for sde in self.sdes.values():
            if synopsis_id in sde.entries:
                kind = sde.entries[synopsis_id].kind_key
                states.append(sde.state_of(synopsis_id))
        if kind is None:
            raise KeyError(synopsis_id)
        return kind, states, len(states) == len(self.sdes)

    def _site_stacked(self, states: List[Any]) -> Any:
        """Stack per-site partial states into ONE [S, ...] pytree sharded
        over the site axis — zero-copy: shard s is site s's already
        device-resident state, so building the collective's operand ships
        nothing before the program runs."""
        sharding = NamedSharding(self.fed_mesh, P(self.site_axis))

        def stack(*leaves):
            shards = [jax.device_put(leaf[None], d)
                      for leaf, d in zip(leaves, self._site_devices)]
            return jax.make_array_from_single_device_arrays(
                (len(leaves),) + leaves[0].shape, sharding, shards)

        return jax.tree.map(stack, *states)

    def query_federated(self, synopsis_id: str, query: Dict[str, Any],
                        responsible: str):
        """Case 2/3: merge every site's partial synopsis and estimate
        once at the responsible site. On a mesh federation the merge is a
        real collective over the site axis fused with the estimate into
        ONE compiled program (``kernels.ops.estimate_collective``); off
        mesh, the partials are gathered and tree-merged on the
        responsible host (``kernels.ops.estimate_merged``). Both paths
        ride the same stacked-estimate entry point as the local red path
        and return byte-identical results."""
        value, _ = self._query_federated(synopsis_id, query, responsible)
        return value

    def _query_federated(self, synopsis_id: str, query: Dict[str, Any],
                         responsible: str):
        kind, states, covered = self._partial_states(synopsis_id)
        args, take, errors = _plan_queries(kind, [query or {}])
        if errors[0] is not None:
            raise ValueError(errors[0])
        host_bytes = sum(
            federated.communication_bytes(kind, s) for s in states)
        info = dict(sites=len(states), responsible_site=responsible,
                    host_merge_bytes=host_bytes)
        if self.fed_mesh is not None and covered:
            # the collective path spans the WHOLE axis: it needs one
            # partial per slice (a federated build is broadcast, so this
            # is the common case)
            out = kops.estimate_collective(
                kind, self._site_stacked(states), *args,
                mesh=self.fed_mesh, axis_name=self.site_axis)
            info.update(path="collective",
                        collective_operand_bytes=federated.
                        collective_operand_bytes(kind, states[0],
                                                 len(states)))
        else:
            if self.fed_mesh is not None:
                # partial coverage: fall back to the host merge — pull
                # the site-committed partials through host numpy so one
                # device can fold them
                states = [jax.tree.map(np.asarray, s) for s in states]
            out = kops.estimate_merged(
                kind, federated.stack_states(states), *args)
            info.update(path="host", collective_operand_bytes=host_bytes)
        return take(jax.tree.map(np.asarray, out), 0), info

    def query_bytes(self, synopsis_id: str) -> int:
        """Host-merge shipped bytes: every site sends its state to the
        responsible site (what the legacy path actually ships, and the
        fig 5d baseline the collective is compared against)."""
        total = 0
        for sde in self.sdes.values():
            if synopsis_id in sde.entries:
                total += federated.communication_bytes(
                    sde.entries[synopsis_id].kind_key,
                    sde.state_of(synopsis_id))
        return total

    def collective_query_bytes(self, synopsis_id: str) -> int:
        """Operand bytes the collective path ships across the site axis
        for one federated estimate (fig 5d): in-network psum/pmax
        reduction makes this independent of the site count for sum/max
        kinds. Never exceeds ``query_bytes``."""
        kind, states, _ = self._partial_states(synopsis_id)
        return federated.collective_operand_bytes(kind, states[0],
                                                  len(states))
