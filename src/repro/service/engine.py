"""The SDEaaS engine — one always-on service maintaining thousands of
synopses for thousands of streams (paper Section 4).

Structure mirrors the paper's architecture, adapted to JAX:

  * blue path  : ``ingest(stream_ids, values)`` — ONE jitted update per
    synopsis *kind* updates every synopsis of that kind (stacked state =
    slot sharing). Routing tables (stream -> row) are device int32 arrays,
    the analogue of RegisterSynopsis/HashData key creation.
  * red path   : ``handle(request_json)`` — queries read the same state
    through separate jitted estimate functions; they never enter (or
    back-pressure) the update path.
  * yellow path: federated synopses — ``Federation`` keeps one SDE per
    site and synthesizes global estimates at the responsible site via
    ``core.federated.merge_tree`` (collective mergeability).

Capacity management: kind stacks grow by doubling (amortized re-jit),
"a request for a new synopsis assigns new tasks, not task slots".
"""
from __future__ import annotations

import dataclasses
import importlib
import json
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import core
from repro.core import batched, federated
from repro.core.synopsis import Synopsis, kind_params
from repro.sharding import specs
from . import api

_MAX_STREAMS = 1 << 16       # routing-table size (stream-id space)


@dataclasses.dataclass
class _Entry:
    synopsis_id: str
    kind_key: Any                 # the frozen kind dataclass
    row: int
    stream_id: Optional[int]      # None => data-source synopsis
    federated: bool = False
    responsible_site: Optional[str] = None
    continuous: bool = False
    source_id: Optional[str] = None


class _KindStack:
    """All synopses of one kind: stacked state + routing table.

    On a multi-device mesh the stacked state's leading [capacity] row
    axis is partitioned over the ``synopsis`` logical axis (horizontal
    scale-out, paper Fig. 5); the routing table is replicated.
    """

    def __init__(self, kind: Synopsis, capacity: int = 64,
                 mesh: Optional[Mesh] = None,
                 rules: Optional[specs.MeshRules] = None):
        self.kind = kind
        self.capacity = capacity
        self.mesh = mesh
        self.rules = rules or specs.DEFAULT_RULES
        self.state = batched.stacked_init(kind, capacity)
        self.route = jnp.full((_MAX_STREAMS,), -1, jnp.int32)  # stream->row
        self.source_rows: List[int] = []   # rows fed by ALL tuples
        self.used: List[bool] = [False] * capacity
        self.is_timeseries = hasattr(kind, "step")
        self._source_mask = None           # device cache, see source_mask()
        self._place()

    @property
    def sharding(self) -> Optional[NamedSharding]:
        if self.mesh is None or self.mesh.empty:
            return None
        return specs.stack_sharding(self.rules, self.mesh, self.capacity)

    def _place(self):
        """Pin state rows over the synopsis axis, replicate the route."""
        sh = self.sharding
        if sh is None:
            return
        self.state = jax.tree.map(lambda x: jax.device_put(x, sh), self.state)
        self.route = jax.device_put(
            self.route, NamedSharding(self.mesh, P()))

    def source_rows_idx(self) -> Optional[jax.Array]:
        """int32 index vector of data-source rows; None when there are
        none (lets the no-source fused path skip the merge branch at
        trace time). Cached on device; invalidated on lifecycle changes."""
        if not self.source_rows:
            return None
        if self._source_mask is None:
            self._source_mask = jnp.asarray(
                np.asarray(self.source_rows, np.int32))
        return self._source_mask

    def mark_source(self, row: int):
        self.source_rows.append(row)
        self._source_mask = None

    def alloc(self) -> int:
        for i, u in enumerate(self.used):
            if not u:
                self.used[i] = True
                return i
        old_cap = self.capacity
        self.capacity *= 2
        self.state = batched.grow(self.kind, self.state, self.capacity)
        self.used.extend([False] * old_cap)
        self.used[old_cap] = True
        self._source_mask = None
        self._place()
        return old_cap

    def free(self, row: int):
        self.free_rows([row])

    def free_rows(self, rows: List[int]):
        """Release rows AND re-initialize their state: the next alloc of
        these slots must hand out fresh synopses, not the dead ones'
        counts (freed-row reuse corruption). Batched — stopping a
        per-stream group of thousands is ONE scatter, not one full-state
        copy per row."""
        for row in rows:
            self.used[row] = False
            if row in self.source_rows:
                self.source_rows.remove(row)
                self._source_mask = None
        idx = jnp.asarray(rows, jnp.int32)
        self.route = jnp.where(jnp.isin(self.route, idx), -1, self.route)
        fresh = batched.stacked_init(self.kind, len(rows))
        self.state = jax.tree.map(
            lambda x, f: x.at[idx].set(f), self.state, fresh)
        if self.sharding is not None:
            self.state = jax.tree.map(
                lambda x: jax.device_put(x, self.sharding), self.state)


class SDE:
    """One SDEaaS instance (one site/cluster in federated settings).

    Pass a ``mesh`` to shard every kind stack's row axis across devices
    (the ``synopsis`` logical axis of ``sharding/specs.py``); omit it for
    single-device operation.
    """

    def __init__(self, site: str = "site-0", backend: str = "xla",
                 mesh: Optional[Mesh] = None,
                 rules: Optional[specs.MeshRules] = None):
        self.site = site
        self.backend = backend
        self.mesh = mesh
        self.rules = rules or specs.DEFAULT_RULES
        self.stacks: Dict[Any, _KindStack] = {}
        self.entries: Dict[str, _Entry] = {}
        self.continuous_out: List[api.Response] = []
        self.tuples_ingested = 0

    def _new_stack(self, kind: Synopsis, capacity: int = 64) -> _KindStack:
        return _KindStack(kind, capacity, mesh=self.mesh, rules=self.rules)

    # ------------------------------------------------------------------
    # red path: requests
    # ------------------------------------------------------------------
    def handle(self, snippet: str | dict) -> api.Response:
        try:
            req = api.parse_request(snippet)
            if isinstance(req, api.BuildSynopsis):
                return self._build(req)
            if isinstance(req, api.StopSynopsis):
                return self._stop(req)
            if isinstance(req, api.LoadSynopsis):
                return self._load(req)
            if isinstance(req, api.AdHocQuery):
                return self._query(req)
            if isinstance(req, api.StatusReport):
                return self._status(req)
            raise ValueError(f"unhandled request {req}")
        except Exception as e:  # noqa: BLE001 - service returns errors
            rid = ""
            try:
                rid = json.loads(snippet)["request_id"] if isinstance(
                    snippet, str) else snippet.get("request_id", "")
            except Exception:
                pass
            return api.Response(request_id=rid, ok=False, error=repr(e))

    def _build(self, req: api.BuildSynopsis) -> api.Response:
        kind = core.make_kind(req.kind, **req.params)
        stack = self.stacks.get(kind)
        if stack is None:
            cap = 64
            if req.per_stream_of_source and req.n_streams:
                cap = max(64, 1 << int(np.ceil(np.log2(req.n_streams))))
            stack = self._new_stack(kind, cap)
            self.stacks[kind] = stack

        def add_one(sid: Optional[int], syn_id: str):
            # reuse: same id => same synopsis shared across workflows
            if syn_id in self.entries:
                return
            row = stack.alloc()
            if sid is None:
                stack.mark_source(row)
            else:
                stack.route = stack.route.at[sid].set(row)
            self.entries[syn_id] = _Entry(
                synopsis_id=syn_id, kind_key=kind, row=row, stream_id=sid,
                federated=req.federated,
                responsible_site=req.responsible_site,
                continuous=req.continuous, source_id=req.source_id)

        if req.per_stream_of_source:
            for sid in range(req.n_streams):
                add_one(sid, f"{req.synopsis_id}/{sid}")
        else:
            add_one(req.stream_id, req.synopsis_id)
        return api.Response(request_id=req.request_id,
                            synopsis_id=req.synopsis_id,
                            params=kind_params(kind))

    def _stop(self, req: api.StopSynopsis) -> api.Response:
        ids = [k for k in self.entries
               if k == req.synopsis_id or k.startswith(req.synopsis_id + "/")]
        if not ids:
            return api.Response(request_id=req.request_id, ok=False,
                                error=f"unknown synopsis {req.synopsis_id!r}")
        freed: Dict[Any, List[int]] = {}
        for k in ids:
            e = self.entries.pop(k)
            freed.setdefault(e.kind_key, []).append(e.row)
        for kind, rows in freed.items():
            self.stacks[kind].free_rows(rows)
        return api.Response(request_id=req.request_id,
                            synopsis_id=req.synopsis_id, value=len(ids))

    def _load(self, req: api.LoadSynopsis) -> api.Response:
        """Dynamic pluggability: import factory while the service runs."""
        mod_name, _, attr = req.factory_path.partition(":")
        factory = getattr(importlib.import_module(mod_name), attr)
        core.register_kind(req.kind_name, factory, overwrite=True)
        return api.Response(request_id=req.request_id, value=req.kind_name)

    def _query(self, req: api.AdHocQuery) -> api.Response:
        e = self.entries.get(req.synopsis_id)
        if e is None:
            return api.Response(request_id=req.request_id, ok=False,
                                error=f"unknown synopsis {req.synopsis_id!r}")
        val = self._estimate_entry(e, req.query)
        return api.Response(request_id=req.request_id,
                            synopsis_id=req.synopsis_id, value=val,
                            params=kind_params(e.kind_key))

    def _status(self, req: api.StatusReport) -> api.Response:
        info = {
            sid: dict(kind=type(e.kind_key).__name__,
                      params=kind_params(e.kind_key),
                      stream=e.stream_id, federated=e.federated,
                      memory_bytes=e.kind_key.memory_bytes())
            for sid, e in self.entries.items()}
        return api.Response(request_id=req.request_id, value=info)

    # ------------------------------------------------------------------
    # blue path: data
    # ------------------------------------------------------------------
    def ingest(self, stream_ids: np.ndarray, values: np.ndarray,
               mask: Optional[np.ndarray] = None) -> None:
        """One batch of (stream, value) tuples; updates EVERY maintained
        synopsis of every kind with EXACTLY ONE jitted, donated-buffer
        dispatch per kind stack — routing lookup, routed rows and
        data-source rows are fused into that single program."""
        t = len(stream_ids)
        if mask is None:
            mask = np.ones(t, bool)
        self.tuples_ingested += int(mask.sum())
        sids = jnp.asarray(stream_ids.astype(np.int32))
        items = jnp.asarray(stream_ids.astype(np.uint32))
        vals = jnp.asarray(values.astype(np.float32))
        msk = jnp.asarray(mask)
        for kind, stack in self.stacks.items():
            if stack.is_timeseries:
                self._ingest_timeseries(stack, sids, vals, msk)
            else:
                self._ingest_stack(stack, sids, items, vals, msk)
        self._emit_continuous()

    def _ingest_stack(self, stack: _KindStack, sids, items, vals, msk):
        stack.state = _update(
            stack.kind, self.backend, stack.sharding, stack.state,
            stack.route, sids, items, vals, msk, stack.source_rows_idx())

    def _ingest_timeseries(self, stack: _KindStack, sids, vals, msk):
        """Time-series kinds (DFT): one tick per stream per batch — the
        batch is a StatStream 'basic window'; the last value per stream
        wins (documented resolution reduction). Route scatter + step are
        one fused dispatch."""
        stack.state = _step_all(stack.kind, stack.sharding, stack.state,
                                stack.route, sids, vals, msk)

    def _emit_continuous(self):
        for sid, e in self.entries.items():
            if e.continuous:
                self.continuous_out.append(api.Response(
                    request_id=f"cq/{sid}/{self.tuples_ingested}",
                    synopsis_id=sid, value=self._estimate_entry(e, {})))

    # ------------------------------------------------------------------
    def _estimate_entry(self, e: _Entry, query: Dict[str, Any]):
        stack = self.stacks[e.kind_key]
        state = batched.stacked_row(stack.state, e.row)
        return _estimate(e.kind_key, state, query)

    def state_of(self, synopsis_id: str):
        e = self.entries[synopsis_id]
        return batched.stacked_row(self.stacks[e.kind_key].state, e.row)

    def memory_bytes(self) -> int:
        return sum(
            x.size * x.dtype.itemsize
            for s in self.stacks.values() for x in jax.tree.leaves(s.state))

    # ------------------------------------------------------------------
    # fault tolerance + elasticity
    # ------------------------------------------------------------------
    def snapshot(self, directory: str, step: int = 0) -> None:
        """Atomic engine checkpoint (state + routing + registry)."""
        from repro.core.synopsis import name_of_kind
        from repro.training import checkpoint as ckpt
        kinds = list(self.stacks)
        arrays = {f"stack{i}": dict(state=self.stacks[k].state,
                                    route=self.stacks[k].route)
                  for i, k in enumerate(kinds)}
        manifest = dict(
            site=self.site, backend=self.backend,
            tuples_ingested=self.tuples_ingested,
            stacks=[dict(kind=name_of_kind(k),
                         params=_json_params(kind_params(k)),
                         capacity=self.stacks[k].capacity,
                         used=self.stacks[k].used,
                         source_rows=self.stacks[k].source_rows)
                    for k in kinds],
            entries={sid: dict(kind_index=kinds.index(e.kind_key),
                               row=e.row, stream_id=e.stream_id,
                               federated=e.federated,
                               responsible_site=e.responsible_site,
                               continuous=e.continuous,
                               source_id=e.source_id)
                     for sid, e in self.entries.items()},
        )
        ckpt.save(arrays, directory, step, extra_manifest=manifest)

    @classmethod
    def restore(cls, directory: str, step: Optional[int] = None, *,
                mesh: Optional[Mesh] = None,
                rules: Optional[specs.MeshRules] = None) -> "SDE":
        """Rebuild a running engine from a snapshot (restart path). Pass
        a ``mesh`` to restore onto a (possibly different) device mesh —
        the elastic repartition path."""
        import repro.core as core_mod
        from repro.training import checkpoint as ckpt
        # structure: rebuild kinds first, then load arrays into shape
        import json as _json
        import os
        step_ = step if step is not None else ckpt.latest_step(directory)
        with open(os.path.join(directory, f"step-{step_:08d}",
                               "manifest.json")) as f:
            man = _json.load(f)
        eng = cls(site=man["site"], backend=man["backend"], mesh=mesh,
                  rules=rules)
        eng.tuples_ingested = man["tuples_ingested"]
        kinds = []
        like = {}
        for i, sk in enumerate(man["stacks"]):
            kind = core_mod.make_kind(sk["kind"], **sk["params"])
            stack = eng._new_stack(kind, sk["capacity"])
            stack.used = list(sk["used"])
            stack.source_rows = list(sk["source_rows"])
            eng.stacks[kind] = stack
            kinds.append(kind)
            like[f"stack{i}"] = dict(state=stack.state, route=stack.route)
        arrays, _ = ckpt.restore(like, directory, step_)
        for i, kind in enumerate(kinds):
            eng.stacks[kind].state = arrays[f"stack{i}"]["state"]
            eng.stacks[kind].route = arrays[f"stack{i}"]["route"]
            eng.stacks[kind]._place()
        for sid, e in man["entries"].items():
            eng.entries[sid] = _Entry(
                synopsis_id=sid, kind_key=kinds[e["kind_index"]],
                row=e["row"], stream_id=e["stream_id"],
                federated=e["federated"],
                responsible_site=e["responsible_site"],
                continuous=e["continuous"], source_id=e["source_id"])
        return eng

    def merge_from(self, other: "SDE") -> None:
        """Elastic scale-down: absorb another engine's synopses.
        Matching synopsis ids merge (mergeability) — vectorized into ONE
        row-wise merge dispatch per kind; new ids transfer row by row."""
        matches: Dict[Any, tuple[list[int], list[int]]] = {}
        transfers = []
        for sid, oe in other.entries.items():
            if sid in self.entries:
                e = self.entries[sid]
                if oe.kind_key != e.kind_key:
                    raise ValueError(
                        f"synopsis {sid!r} is {type(e.kind_key).__name__} "
                        f"here but {type(oe.kind_key).__name__} on "
                        f"{other.site!r}; cannot merge")
                rows_a, rows_b = matches.setdefault(e.kind_key, ([], []))
                rows_a.append(e.row)
                rows_b.append(oe.row)
            else:
                transfers.append((sid, oe))
        for kind, (rows_a, rows_b) in matches.items():
            stack = self.stacks[kind]
            stack.state = federated.merge_rows(
                kind, stack.state, jnp.asarray(rows_a, jnp.int32),
                other.stacks[kind].state, jnp.asarray(rows_b, jnp.int32))
        for sid, oe in transfers:
            kind = oe.kind_key
            if kind not in self.stacks:
                self.stacks[kind] = self._new_stack(kind, 64)
            stack = self.stacks[kind]
            row = stack.alloc()
            stack.state = batched.set_row(stack.state, row,
                                          other.state_of(sid))
            if oe.stream_id is None:
                stack.mark_source(row)
            else:
                stack.route = stack.route.at[oe.stream_id].set(row)
            self.entries[sid] = dataclasses.replace(oe, row=row)
        self.tuples_ingested += other.tuples_ingested


def _json_params(params):
    return {k: v for k, v in params.items()
            if isinstance(v, (int, float, str, bool))}


# ---------------------------------------------------------------------------
# jitted update/estimate dispatch (cached per (kind, backend, sharding,
# has_sources, shapes)). The cached program is the WHOLE blue path for one
# kind: route lookup, routed update and data-source update fused into one
# dispatch; the state buffer is donated (in-place on device), and — on a
# mesh — pinned to the stack's `synopsis`-axis sharding.
# ---------------------------------------------------------------------------
import functools


@functools.lru_cache(maxsize=None)
def _update_fn(kind, backend: str, sharding, has_sources: bool):
    def fused(state, route, sids, items, vals, msk, *src):
        src_rows = src[0] if has_sources else None
        syn_idx = route[sids]                      # [-1 => unrouted]
        routed = msk & (syn_idx >= 0)
        rows = jnp.maximum(syn_idx, 0)
        if backend == "pallas":
            from repro.kernels import ops as kops
            if isinstance(kind, core.CountMin):
                return kops.countmin_update(
                    state, rows, items, vals, routed, seeds=kind._seeds(),
                    log2_width=kind.log2_width, weighted=kind.weighted,
                    source_rows=src_rows, source_tuple_mask=msk)
            if isinstance(kind, core.AMS):
                return kops.ams_update(
                    state, rows, items, vals, routed, seeds=kind._seeds(),
                    log2_width=kind.log2_width,
                    source_rows=src_rows, source_tuple_mask=msk)
            if isinstance(kind, core.HyperLogLog):
                return kops.hll_update(
                    state, rows, items, routed, seed=kind.seed, p=kind.p,
                    source_rows=src_rows, source_tuple_mask=msk)
            # no kernel for this kind: fall through to XLA path
        return batched.stacked_update(kind, state, syn_idx, items, vals,
                                      msk, src_rows)

    kw = dict(donate_argnums=0)
    if sharding is not None:
        kw["out_shardings"] = sharding
    return jax.jit(fused, **kw)


def _update(kind, backend, sharding, state, route, sids, items, vals, msk,
            src_rows=None):
    fn = _update_fn(kind, backend, sharding, src_rows is not None)
    if src_rows is None:
        return fn(state, route, sids, items, vals, msk)
    return fn(state, route, sids, items, vals, msk, src_rows)


@functools.lru_cache(maxsize=None)
def _step_fn(kind, sharding):
    def fused(state, route, sids, vals, msk):
        capacity = jax.tree.leaves(state)[0].shape[0]
        syn_idx = route[sids]
        routed = msk & (syn_idx >= 0)
        rows = jnp.where(routed, syn_idx, capacity)    # overflow slot
        per_row = jnp.zeros((capacity + 1,), jnp.float32)
        per_row = per_row.at[rows].set(vals)           # last write wins
        hit = jnp.zeros((capacity + 1,), bool).at[rows].set(routed)
        return batched.stacked_step(kind, state, per_row[:-1], hit[:-1])

    kw = dict(donate_argnums=0)
    if sharding is not None:
        kw["out_shardings"] = sharding
    return jax.jit(fused, **kw)


def _step_all(kind, sharding, state, route, sids, vals, msk):
    return _step_fn(kind, sharding)(state, route, sids, vals, msk)


def _estimate(kind, state, query: Dict[str, Any]):
    q = dict(query)
    if isinstance(kind, (core.CountMin, core.LossyCounting,
                         core.StickySampling)):
        items = jnp.asarray(np.asarray(q.get("items", [0]), np.uint32))
        return np.asarray(kind.estimate(state, items))
    if isinstance(kind, core.BloomFilter):
        items = jnp.asarray(np.asarray(q.get("items", [0]), np.uint32))
        return np.asarray(kind.estimate(state, items))
    if isinstance(kind, core.GKQuantiles):
        qs = jnp.asarray(np.asarray(q.get("qs", [0.5]), np.float32))
        return np.asarray(kind.estimate(state, qs))
    out = kind.estimate(state)
    return jax.tree.map(np.asarray, out)


# ---------------------------------------------------------------------------
# Federation (yellow path): one SDE per geo-dispersed site
# ---------------------------------------------------------------------------
class Federation:
    """Simulates the paper's multi-cluster deployment: each site runs its
    own SDE; federated queries are merged at the responsible site. The
    bytes shipped per estimate are exactly the synopsis state size —
    reported by ``query_bytes`` (fig 5d)."""

    def __init__(self, sites: List[str], backend: str = "xla"):
        self.sdes = {s: SDE(site=s, backend=backend) for s in sites}

    def broadcast(self, snippet: str | dict) -> Dict[str, api.Response]:
        return {s: sde.handle(snippet) for s, sde in self.sdes.items()}

    def query_federated(self, synopsis_id: str, query: Dict[str, Any],
                        responsible: str):
        """Case 2/3: ship partial synopses to the responsible site, merge
        (mergeability), estimate once."""
        states, kind = [], None
        for sde in self.sdes.values():
            if synopsis_id in sde.entries:
                kind = sde.entries[synopsis_id].kind_key
                states.append(sde.state_of(synopsis_id))
        if kind is None:
            raise KeyError(synopsis_id)
        merged = federated.merge_tree(kind, states)
        return _estimate(kind, merged, query)

    def query_bytes(self, synopsis_id: str) -> int:
        total = 0
        for sde in self.sdes.values():
            if synopsis_id in sde.entries:
                total += federated.communication_bytes(
                    sde.entries[synopsis_id].kind_key,
                    sde.state_of(synopsis_id))
        return total
