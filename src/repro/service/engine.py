"""The SDEaaS engine — one always-on service maintaining thousands of
synopses for thousands of streams (paper Section 4).

Structure mirrors the paper's architecture, adapted to JAX:

  * blue path  : ``ingest(stream_ids, values)`` — ONE jitted update per
    synopsis *kind* updates every synopsis of that kind (stacked state =
    slot sharing). Routing tables (stream -> row) are device int32 arrays,
    the analogue of RegisterSynopsis/HashData key creation.
  * red path   : ``handle(request_json)`` — queries read the same state
    through separate jitted estimate functions; they never enter (or
    back-pressure) the update path.
  * yellow path: federated synopses — ``Federation`` keeps one SDE per
    site and synthesizes global estimates at the responsible site via
    ``core.federated.merge_tree`` (collective mergeability).

Capacity management: kind stacks grow by doubling (amortized re-jit),
"a request for a new synopsis assigns new tasks, not task slots".
"""
from __future__ import annotations

import dataclasses
import importlib
import json
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.core import batched, federated
from repro.core.synopsis import Synopsis, kind_params
from . import api

_MAX_STREAMS = 1 << 16       # routing-table size (stream-id space)


@dataclasses.dataclass
class _Entry:
    synopsis_id: str
    kind_key: Any                 # the frozen kind dataclass
    row: int
    stream_id: Optional[int]      # None => data-source synopsis
    federated: bool = False
    responsible_site: Optional[str] = None
    continuous: bool = False
    source_id: Optional[str] = None


class _KindStack:
    """All synopses of one kind: stacked state + routing table."""

    def __init__(self, kind: Synopsis, capacity: int = 64):
        self.kind = kind
        self.capacity = capacity
        self.state = batched.stacked_init(kind, capacity)
        self.route = jnp.full((_MAX_STREAMS,), -1, jnp.int32)  # stream->row
        self.source_rows: List[int] = []   # rows fed by ALL tuples
        self.used: List[bool] = [False] * capacity
        self.is_timeseries = hasattr(kind, "step")

    def alloc(self) -> int:
        for i, u in enumerate(self.used):
            if not u:
                self.used[i] = True
                return i
        old_cap = self.capacity
        self.capacity *= 2
        self.state = batched.grow(self.state, self.capacity)
        self.used.extend([False] * old_cap)
        self.used[old_cap] = True
        return old_cap

    def free(self, row: int):
        self.used[row] = False
        self.route = jnp.where(self.route == row, -1, self.route)
        if row in self.source_rows:
            self.source_rows.remove(row)


class SDE:
    """One SDEaaS instance (one site/cluster in federated settings)."""

    def __init__(self, site: str = "site-0", backend: str = "xla"):
        self.site = site
        self.backend = backend
        self.stacks: Dict[Any, _KindStack] = {}
        self.entries: Dict[str, _Entry] = {}
        self.continuous_out: List[api.Response] = []
        self.tuples_ingested = 0

    # ------------------------------------------------------------------
    # red path: requests
    # ------------------------------------------------------------------
    def handle(self, snippet: str | dict) -> api.Response:
        try:
            req = api.parse_request(snippet)
            if isinstance(req, api.BuildSynopsis):
                return self._build(req)
            if isinstance(req, api.StopSynopsis):
                return self._stop(req)
            if isinstance(req, api.LoadSynopsis):
                return self._load(req)
            if isinstance(req, api.AdHocQuery):
                return self._query(req)
            if isinstance(req, api.StatusReport):
                return self._status(req)
            raise ValueError(f"unhandled request {req}")
        except Exception as e:  # noqa: BLE001 - service returns errors
            rid = ""
            try:
                rid = json.loads(snippet)["request_id"] if isinstance(
                    snippet, str) else snippet.get("request_id", "")
            except Exception:
                pass
            return api.Response(request_id=rid, ok=False, error=repr(e))

    def _build(self, req: api.BuildSynopsis) -> api.Response:
        kind = core.make_kind(req.kind, **req.params)
        stack = self.stacks.get(kind)
        if stack is None:
            cap = 64
            if req.per_stream_of_source and req.n_streams:
                cap = max(64, 1 << int(np.ceil(np.log2(req.n_streams))))
            stack = _KindStack(kind, cap)
            self.stacks[kind] = stack

        def add_one(sid: Optional[int], syn_id: str):
            # reuse: same id => same synopsis shared across workflows
            if syn_id in self.entries:
                return
            row = stack.alloc()
            if sid is None:
                stack.source_rows.append(row)
            else:
                stack.route = stack.route.at[sid].set(row)
            self.entries[syn_id] = _Entry(
                synopsis_id=syn_id, kind_key=kind, row=row, stream_id=sid,
                federated=req.federated,
                responsible_site=req.responsible_site,
                continuous=req.continuous, source_id=req.source_id)

        if req.per_stream_of_source:
            for sid in range(req.n_streams):
                add_one(sid, f"{req.synopsis_id}/{sid}")
        else:
            add_one(req.stream_id, req.synopsis_id)
        return api.Response(request_id=req.request_id,
                            synopsis_id=req.synopsis_id,
                            params=kind_params(kind))

    def _stop(self, req: api.StopSynopsis) -> api.Response:
        ids = [k for k in self.entries
               if k == req.synopsis_id or k.startswith(req.synopsis_id + "/")]
        if not ids:
            return api.Response(request_id=req.request_id, ok=False,
                                error=f"unknown synopsis {req.synopsis_id!r}")
        for k in ids:
            e = self.entries.pop(k)
            self.stacks[e.kind_key].free(e.row)
        return api.Response(request_id=req.request_id,
                            synopsis_id=req.synopsis_id, value=len(ids))

    def _load(self, req: api.LoadSynopsis) -> api.Response:
        """Dynamic pluggability: import factory while the service runs."""
        mod_name, _, attr = req.factory_path.partition(":")
        factory = getattr(importlib.import_module(mod_name), attr)
        core.register_kind(req.kind_name, factory, overwrite=True)
        return api.Response(request_id=req.request_id, value=req.kind_name)

    def _query(self, req: api.AdHocQuery) -> api.Response:
        e = self.entries.get(req.synopsis_id)
        if e is None:
            return api.Response(request_id=req.request_id, ok=False,
                                error=f"unknown synopsis {req.synopsis_id!r}")
        val = self._estimate_entry(e, req.query)
        return api.Response(request_id=req.request_id,
                            synopsis_id=req.synopsis_id, value=val,
                            params=kind_params(e.kind_key))

    def _status(self, req: api.StatusReport) -> api.Response:
        info = {
            sid: dict(kind=type(e.kind_key).__name__,
                      params=kind_params(e.kind_key),
                      stream=e.stream_id, federated=e.federated,
                      memory_bytes=e.kind_key.memory_bytes())
            for sid, e in self.entries.items()}
        return api.Response(request_id=req.request_id, value=info)

    # ------------------------------------------------------------------
    # blue path: data
    # ------------------------------------------------------------------
    def ingest(self, stream_ids: np.ndarray, values: np.ndarray,
               mask: Optional[np.ndarray] = None) -> None:
        """One batch of (stream, value) tuples; updates EVERY maintained
        synopsis of every kind with one jitted call per kind stack."""
        t = len(stream_ids)
        if mask is None:
            mask = np.ones(t, bool)
        self.tuples_ingested += int(mask.sum())
        sids = jnp.asarray(stream_ids.astype(np.int32))
        items = jnp.asarray(stream_ids.astype(np.uint32))
        vals = jnp.asarray(values.astype(np.float32))
        msk = jnp.asarray(mask)
        for kind, stack in self.stacks.items():
            if stack.is_timeseries:
                self._ingest_timeseries(stack, sids, vals, msk)
            else:
                self._ingest_stack(stack, sids, items, vals, msk)
        self._emit_continuous()

    def _ingest_stack(self, stack: _KindStack, sids, items, vals, msk):
        syn_idx = stack.route[sids]                     # [-1 => unrouted]
        routed = msk & (syn_idx >= 0)
        state = _update(stack.kind, self.backend, stack.state,
                        jnp.maximum(syn_idx, 0), items, vals, routed)
        # data-source synopses see every tuple
        for row in stack.source_rows:
            state = _update(stack.kind, self.backend, state,
                            jnp.full_like(syn_idx, row), items, vals, msk)
        stack.state = state

    def _ingest_timeseries(self, stack: _KindStack, sids, vals, msk):
        """Time-series kinds (DFT): one tick per stream per batch — the
        batch is a StatStream 'basic window'; the last value per stream
        wins (documented resolution reduction)."""
        syn_idx = stack.route[sids]
        routed = msk & (syn_idx >= 0)
        rows = jnp.where(routed, syn_idx, stack.capacity)  # overflow slot
        per_row = jnp.zeros((stack.capacity + 1,), jnp.float32)
        per_row = per_row.at[rows].set(vals)               # last write wins
        hit = jnp.zeros((stack.capacity + 1,), bool).at[rows].set(routed)
        stack.state = _step_all(stack.kind, stack.state,
                                per_row[:-1], hit[:-1])

    def _emit_continuous(self):
        for sid, e in self.entries.items():
            if e.continuous:
                self.continuous_out.append(api.Response(
                    request_id=f"cq/{sid}/{self.tuples_ingested}",
                    synopsis_id=sid, value=self._estimate_entry(e, {})))

    # ------------------------------------------------------------------
    def _estimate_entry(self, e: _Entry, query: Dict[str, Any]):
        stack = self.stacks[e.kind_key]
        state = batched.stacked_row(stack.state, e.row)
        return _estimate(e.kind_key, state, query)

    def state_of(self, synopsis_id: str):
        e = self.entries[synopsis_id]
        return batched.stacked_row(self.stacks[e.kind_key].state, e.row)

    def memory_bytes(self) -> int:
        return sum(
            x.size * x.dtype.itemsize
            for s in self.stacks.values() for x in jax.tree.leaves(s.state))

    # ------------------------------------------------------------------
    # fault tolerance + elasticity
    # ------------------------------------------------------------------
    def snapshot(self, directory: str, step: int = 0) -> None:
        """Atomic engine checkpoint (state + routing + registry)."""
        from repro.core.synopsis import name_of_kind
        from repro.training import checkpoint as ckpt
        kinds = list(self.stacks)
        arrays = {f"stack{i}": dict(state=self.stacks[k].state,
                                    route=self.stacks[k].route)
                  for i, k in enumerate(kinds)}
        manifest = dict(
            site=self.site, backend=self.backend,
            tuples_ingested=self.tuples_ingested,
            stacks=[dict(kind=name_of_kind(k),
                         params=_json_params(kind_params(k)),
                         capacity=self.stacks[k].capacity,
                         used=self.stacks[k].used,
                         source_rows=self.stacks[k].source_rows)
                    for k in kinds],
            entries={sid: dict(kind_index=kinds.index(e.kind_key),
                               row=e.row, stream_id=e.stream_id,
                               federated=e.federated,
                               responsible_site=e.responsible_site,
                               continuous=e.continuous,
                               source_id=e.source_id)
                     for sid, e in self.entries.items()},
        )
        ckpt.save(arrays, directory, step, extra_manifest=manifest)

    @classmethod
    def restore(cls, directory: str, step: Optional[int] = None) -> "SDE":
        """Rebuild a running engine from a snapshot (restart path)."""
        import repro.core as core_mod
        from repro.training import checkpoint as ckpt
        # structure: rebuild kinds first, then load arrays into shape
        import json as _json
        import os
        step_ = step if step is not None else ckpt.latest_step(directory)
        with open(os.path.join(directory, f"step-{step_:08d}",
                               "manifest.json")) as f:
            man = _json.load(f)
        eng = cls(site=man["site"], backend=man["backend"])
        eng.tuples_ingested = man["tuples_ingested"]
        kinds = []
        like = {}
        for i, sk in enumerate(man["stacks"]):
            kind = core_mod.make_kind(sk["kind"], **sk["params"])
            stack = _KindStack(kind, sk["capacity"])
            stack.used = list(sk["used"])
            stack.source_rows = list(sk["source_rows"])
            eng.stacks[kind] = stack
            kinds.append(kind)
            like[f"stack{i}"] = dict(state=stack.state, route=stack.route)
        arrays, _ = ckpt.restore(like, directory, step_)
        for i, kind in enumerate(kinds):
            eng.stacks[kind].state = arrays[f"stack{i}"]["state"]
            eng.stacks[kind].route = arrays[f"stack{i}"]["route"]
        for sid, e in man["entries"].items():
            eng.entries[sid] = _Entry(
                synopsis_id=sid, kind_key=kinds[e["kind_index"]],
                row=e["row"], stream_id=e["stream_id"],
                federated=e["federated"],
                responsible_site=e["responsible_site"],
                continuous=e["continuous"], source_id=e["source_id"])
        return eng

    def merge_from(self, other: "SDE") -> None:
        """Elastic scale-down: absorb another engine's synopses.
        Matching synopsis ids merge (mergeability); new ids transfer."""
        for sid, oe in other.entries.items():
            o_state = other.state_of(sid)
            if sid in self.entries:
                e = self.entries[sid]
                merged = e.kind_key.merge(self.state_of(sid), o_state)
                stack = self.stacks[e.kind_key]
                stack.state = batched.set_row(stack.state, e.row, merged)
            else:
                kind = oe.kind_key
                if kind not in self.stacks:
                    self.stacks[kind] = _KindStack(kind, 64)
                stack = self.stacks[kind]
                row = stack.alloc()
                stack.state = batched.set_row(stack.state, row, o_state)
                if oe.stream_id is None:
                    stack.source_rows.append(row)
                else:
                    stack.route = stack.route.at[oe.stream_id].set(row)
                self.entries[sid] = dataclasses.replace(oe, row=row)
        self.tuples_ingested += other.tuples_ingested


def _json_params(params):
    return {k: v for k, v in params.items()
            if isinstance(v, (int, float, str, bool))}


# ---------------------------------------------------------------------------
# jitted update/estimate dispatch (cached per (kind, backend, shapes))
# ---------------------------------------------------------------------------
import functools


@functools.lru_cache(maxsize=None)
def _update_fn(kind, backend: str):
    if backend == "pallas":
        from repro.kernels import ops as kops
        if isinstance(kind, core.CountMin):
            seeds = kind._seeds()
            return jax.jit(lambda st, syn, it, v, m: kops.countmin_update(
                st, syn, it, v, m, seeds=seeds, log2_width=kind.log2_width,
                weighted=kind.weighted))
        if isinstance(kind, core.AMS):
            seeds = kind._seeds()
            return jax.jit(lambda st, syn, it, v, m: kops.ams_update(
                st, syn, it, v, m, seeds=seeds, log2_width=kind.log2_width))
        if isinstance(kind, core.HyperLogLog):
            return jax.jit(lambda st, syn, it, v, m: kops.hll_update(
                st, syn, it, m, seed=kind.seed, p=kind.p))
        # no kernel for this kind: fall through to XLA path
    return jax.jit(functools.partial(batched.stacked_add_batch, kind))


def _update(kind, backend, state, syn_idx, items, vals, mask):
    return _update_fn(kind, backend)(state, syn_idx, items, vals, mask)


@functools.lru_cache(maxsize=None)
def _step_fn(kind):
    return jax.jit(functools.partial(batched.stacked_step, kind))


def _step_all(kind, state, vals, mask):
    return _step_fn(kind)(state, vals, mask)


def _estimate(kind, state, query: Dict[str, Any]):
    q = dict(query)
    if isinstance(kind, (core.CountMin, core.LossyCounting,
                         core.StickySampling)):
        items = jnp.asarray(np.asarray(q.get("items", [0]), np.uint32))
        return np.asarray(kind.estimate(state, items))
    if isinstance(kind, core.BloomFilter):
        items = jnp.asarray(np.asarray(q.get("items", [0]), np.uint32))
        return np.asarray(kind.estimate(state, items))
    if isinstance(kind, core.GKQuantiles):
        qs = jnp.asarray(np.asarray(q.get("qs", [0.5]), np.float32))
        return np.asarray(kind.estimate(state, qs))
    out = kind.estimate(state)
    return jax.tree.map(np.asarray, out)


# ---------------------------------------------------------------------------
# Federation (yellow path): one SDE per geo-dispersed site
# ---------------------------------------------------------------------------
class Federation:
    """Simulates the paper's multi-cluster deployment: each site runs its
    own SDE; federated queries are merged at the responsible site. The
    bytes shipped per estimate are exactly the synopsis state size —
    reported by ``query_bytes`` (fig 5d)."""

    def __init__(self, sites: List[str], backend: str = "xla"):
        self.sdes = {s: SDE(site=s, backend=backend) for s in sites}

    def broadcast(self, snippet: str | dict) -> Dict[str, api.Response]:
        return {s: sde.handle(snippet) for s, sde in self.sdes.items()}

    def query_federated(self, synopsis_id: str, query: Dict[str, Any],
                        responsible: str):
        """Case 2/3: ship partial synopses to the responsible site, merge
        (mergeability), estimate once."""
        states, kind = [], None
        for sde in self.sdes.values():
            if synopsis_id in sde.entries:
                kind = sde.entries[synopsis_id].kind_key
                states.append(sde.state_of(synopsis_id))
        if kind is None:
            raise KeyError(synopsis_id)
        merged = federated.merge_tree(kind, states)
        return _estimate(kind, merged, query)

    def query_bytes(self, synopsis_id: str) -> int:
        total = 0
        for sde in self.sdes.values():
            if synopsis_id in sde.entries:
                total += federated.communication_bytes(
                    sde.entries[synopsis_id].kind_key,
                    sde.state_of(synopsis_id))
        return total
