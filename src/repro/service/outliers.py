"""Continuous outlier mining over multidim subpopulations (after the
streaming distance-based outlier designs surveyed in arxiv 1902.07901,
recast onto maintained synopses).

A tracked workflow names one multidim family, one level of its group-by
hierarchy and one estimate query. Each ingest tick the engine estimates
EVERY group of that level PLUS the population group in the same batched
red-path dispatch it already uses for continuous queries — off the SAME
maintained synopses, so a workflow costs zero additional builds and
zero additional blue-path work (pinned by the ``OUTLIER_EMITS`` /
entry-count probes in the tests). The deferred estimates ride the
ingest pipeline (``PendingBatch.extras``) and are scored host-side at
retirement:

  * every group's scalar stat is reduced from its estimate,
  * the level's center/scale are the median and the MAD-derived robust
    sigma (1.4826 * MAD) of the group stats — robust, so a handful of
    true outliers cannot mask themselves by inflating a mean/stddev,
  * a group is flagged when its |stat - center| exceeds BOTH
    ``threshold`` robust sigmas and the absolute floor ``min_dev``
    (the floor suppresses noise-level flags on near-constant levels,
    where MAD collapses toward 0).

One response per workflow per ingest batch (id ``ow/<workflow>/<batch>``)
reports the flagged groups with their stats and z-scores next to the
population estimate — deterministic for a given ingest history, which
the determinism test locks.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Tuple

import numpy as np

# MAD -> sigma for a normal population; the conventional robust scale
_MAD_SIGMA = 1.4826
_MIN_SCALE = 1e-12


@dataclasses.dataclass
class OutlierWorkflow:
    """One tracked continuous outlier workflow (``track_outliers``)."""
    workflow_id: str
    synopsis_id: str                     # the multidim family it watches
    level: Tuple[str, ...]               # which group-by level to score
    query: Dict[str, Any] = dataclasses.field(default_factory=dict)
    threshold: float = 3.0               # robust z-score cut
    min_dev: float = 0.0                 # absolute deviation floor

    def to_json_dict(self) -> Dict[str, Any]:
        return dict(workflow_id=self.workflow_id,
                    synopsis_id=self.synopsis_id,
                    level=list(self.level), query=dict(self.query),
                    threshold=self.threshold, min_dev=self.min_dev)

    @classmethod
    def from_json_dict(cls, obj: Dict[str, Any]) -> "OutlierWorkflow":
        return cls(workflow_id=obj["workflow_id"],
                   synopsis_id=obj["synopsis_id"],
                   level=tuple(obj["level"]), query=dict(obj["query"]),
                   threshold=float(obj["threshold"]),
                   min_dev=float(obj["min_dev"]))


@dataclasses.dataclass
class OutlierPlan:
    """One workflow's per-tick dispatch plan, prepared once per
    lifecycle epoch (invalidated together with the engine's continuous-
    query groups). ``rows`` index the level's groups followed by the
    population group into the kind's stack; ``take`` slices query i's
    estimate out of the batched output."""
    workflow: OutlierWorkflow
    kind_key: Any                        # the frozen kind dataclass
    assignments: List[Dict[str, Any]]    # group i's attribute assignment
    rows: Any                            # device rows, groups + [pop]
    args: tuple                          # stacked estimate args
    take: Callable[..., Any]
    out_sharding: Any = None


def scalar_stat(est: Any) -> float:
    """Reduce one estimate payload to a scalar: estimates are scalars or
    small vectors (a quantile list); vectors reduce to their first
    element (the caller controls which quantile leads the query)."""
    arr = np.asarray(est, np.float64).ravel()
    return float(arr[0]) if arr.size else float("nan")


def score_level(stats: np.ndarray, threshold: float, min_dev: float
                ) -> Tuple[np.ndarray, np.ndarray, float, float]:
    """Robust-z scoring of one level's group stats. Returns
    ``(flagged mask, z scores, center, scale)``; NaN stats never flag."""
    stats = np.asarray(stats, np.float64)
    finite = stats[np.isfinite(stats)]
    if finite.size == 0:
        z = np.zeros_like(stats)
        return np.zeros(stats.shape, bool), z, float("nan"), _MIN_SCALE
    center = float(np.median(finite))
    scale = _MAD_SIGMA * float(np.median(np.abs(finite - center)))
    scale = max(scale, _MIN_SCALE)
    dev = stats - center
    with np.errstate(invalid="ignore"):
        z = dev / scale
    flagged = (np.isfinite(stats)
               & (np.abs(z) >= threshold)
               & (np.abs(dev) >= min_dev))
    return flagged, np.where(np.isfinite(z), z, 0.0), center, scale


def evaluate_tick(plan: OutlierPlan, estimates: List[Any]
                  ) -> Dict[str, Any]:
    """Score one retired tick: ``estimates`` holds the materialized
    per-group estimates in plan order, population LAST. Returns the
    response payload (flagged groups + level/population context)."""
    wf = plan.workflow
    group_ests, pop_est = estimates[:-1], estimates[-1]
    stats = np.asarray([scalar_stat(e) for e in group_ests], np.float64)
    pop_stat = scalar_stat(pop_est)
    flagged, z, center, scale = score_level(stats, wf.threshold,
                                            wf.min_dev)
    outliers = [dict(group=plan.assignments[i], stat=float(stats[i]),
                     z=float(z[i]))
                for i in np.flatnonzero(flagged)]
    return dict(workflow_id=wf.workflow_id, level=list(wf.level),
                outliers=outliers, n_groups=len(group_ests),
                center=center, scale=scale, population_stat=pop_stat)
