"""StableLM-3B [hf:stabilityai/stablelm-2-1_6b family] — dense MHA."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=6912, vocab=50304,
    tensor_parallel=False,   # 2.8B: DP/FSDP only
)
