"""Gemma-7B [arXiv:2403.08295] — GeGLU, head_dim=256 (q/k/v project to
n_heads*256 = 4096 != d_model)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16,
    d_ff=24576, vocab=256000, head_dim=256,
    mlp_act="geglu",
    tensor_parallel=False,   # 9.3B: measured better DP/FSDP-only (see §Perf)
)
