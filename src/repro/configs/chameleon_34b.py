"""Chameleon-34B [arXiv:2405.09818] — early-fusion VLM; VQ image tokens
share the 65536 vocab. Backbone only; patch frontend is a stub providing
precomputed embeddings (input_specs)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab=65536,
    frontend="embeds",
    seq_shard_activations=True, optimizer="adamw8bit",
)
