"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base] — dense-MoE
hybrid: 128 experts top-2 in parallel with a dense residual MLP."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000,
    n_experts=128, top_k=2,
    moe_dense_residual=True, d_ff_dense=4864,
    expert_axis="model",
    seq_shard_activations=True, optimizer="adamw8bit",
)
