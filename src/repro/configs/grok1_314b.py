"""Grok-1 314B [hf:xai-org/grok-1] — MoE 8 experts top-2.
8 experts < 16-way model axis => experts are FSDP/TP-sharded on their
inner dims instead of an expert axis (expert_axis=None)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab=131072,
    n_experts=8, top_k=2,
    expert_axis=None,
    seq_shard_activations=True, optimizer="adamw8bit",
)
