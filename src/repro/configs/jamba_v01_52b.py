"""Jamba-v0.1 52B [arXiv:2403.19887] — 1:7 attn:mamba interleave, MoE 16e
top-2 every other layer. Sub-quadratic => runs long_500k."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=65536,
    n_experts=16, top_k=2, moe_every=2,
    ssm_state=16, ssm_head_dim=64, ssm_expand=2, ssm_chunk=64,
    attn_every=8,
    attn_layout="head",
    seq_shard_activations=False, optimizer="adamw8bit",
    # non-MoE params are ~6.5B: TP-only sharding avoids the d_model-
    # contraction all-reduces FSDP induces (§Perf iteration 4); the ~45B
    # of expert weights stay FSDP-sharded inside moe_ffn_shardmap.
    dense_fsdp=False,
    sub_quadratic=True,
)
