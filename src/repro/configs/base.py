"""Model / run configuration system.

One frozen dataclass describes an architecture; per-arch files under
repro/configs instantiate it with the exact assigned hyperparameters.
`layer_plan` expands the config into the per-layer (mixer, ffn) plan the
model builder consumes; `param_count` feeds MODEL_FLOPS for the roofline.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 => d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1           # MoE ffn every k-th layer (jamba: 2)
    moe_dense_residual: bool = False     # arctic: dense MLP || MoE
    d_ff_dense: int = 0                  # arctic residual MLP width
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / hybrid) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    conv_width: int = 4
    attn_every: int = 0          # hybrid: 1 attn layer per k layers (jamba 8)
    # --- flavors ---
    qkv_bias: bool = False       # qwen2
    mlp_act: str = "swiglu"      # swiglu | geglu
    frontend: str = "tokens"     # tokens | embeds (audio/vlm stub)
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    # --- sharding / memory policy (large-scale runnability knobs) ---
    seq_shard_activations: bool = False   # SP on the residual stream
    attn_layout: str = "seq"              # seq (ring-ish) | head (TP-gather)
    dense_fsdp: bool = True               # FSDP the non-MoE weights
    tensor_parallel: bool = True          # False => DP/FSDP only (small
    #                                       models: TP-16 over-sharding
    #                                       makes collectives dominate)
    expert_axis: Optional[str] = "model"  # None => experts FSDP-only
    remat: bool = True
    optimizer: str = "adamw"              # adamw | adamw8bit
    sub_quadratic: bool = False           # True for ssm/hybrid (long_500k ok)

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    # ------------------------------------------------------------------
    def layer_plan(self) -> List[Tuple[str, str]]:
        """Per-layer (mixer, ffn) plan.

        dense/moe:  ("attn", "dense"|"moe") every layer
        ssm:        ("mamba", "none") every layer
        hybrid:     attn every `attn_every` (jamba: layer i%8==3), rest
                    mamba; ffn alternates dense/moe every `moe_every`.
        """
        plan = []
        for i in range(self.n_layers):
            if self.family == "ssm":
                plan.append(("mamba", "none"))
                continue
            if self.family == "hybrid":
                mixer = "attn" if (i % self.attn_every
                                   == self.attn_every // 2) else "mamba"
            else:
                mixer = "attn"
            if self.n_experts and (i % self.moe_every == self.moe_every - 1):
                plan.append((mixer, "moe"))
            else:
                plan.append((mixer, "dense"))
        return plan

    def period(self) -> int:
        """Repeating period for scan-over-layers weight stacking."""
        plan = self.layer_plan()
        for p in range(1, len(plan) + 1):
            if len(plan) % p == 0 and all(
                    plan[i] == plan[i % p] for i in range(len(plan))):
                return p
        return len(plan)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Total parameters (embeddings included)."""
        d, hd = self.d_model, self.hd
        total = self.vocab * d                       # embed
        if not self.tie_embeddings:
            total += self.vocab * d                  # unembed
        if self.frontend == "embeds":
            total += d * d                           # modality stub proj
        for mixer, ffn in self.layer_plan():
            if mixer == "attn":
                q = d * self.n_heads * hd
                kv = 2 * d * self.n_kv_heads * hd
                o = self.n_heads * hd * d
                total += q + kv + o + d              # + norm
                if self.qkv_bias:
                    total += (self.n_heads + 2 * self.n_kv_heads) * hd
            elif mixer == "mamba":
                din, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
                total += d * (2 * din + 2 * ns + nh)   # in_proj (x,z,B,C,dt)
                total += self.conv_width * (din + 2 * ns)  # conv
                total += din * d                     # out proj
                total += 2 * nh + din + d            # A, D, norm, blocknorm
            if ffn == "dense":
                total += 3 * d * self.d_ff + d
            elif ffn == "moe":
                total += d * self.n_experts          # router
                total += self.n_experts * 3 * d * self.d_ff + d
                if self.moe_dense_residual:
                    total += 3 * d * (self.d_ff_dense or self.d_ff)
        total += d                                   # final norm
        return total

    def expert_param_count(self) -> int:
        """Parameters living in expert weight stacks (EP-managed)."""
        if not self.n_experts:
            return 0
        moe_layers = sum(1 for _, f in self.layer_plan() if f == "moe")
        return moe_layers * self.n_experts * 3 * self.d_model * self.d_ff

    def dense_param_count(self) -> int:
        return self.param_count() - self.expert_param_count()

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE top-k) for MODEL_FLOPS."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        moe_layers = sum(1 for _, f in self.layer_plan() if f == "moe")
        all_exp = moe_layers * self.n_experts * 3 * d * self.d_ff
        act_exp = moe_layers * self.top_k * 3 * d * self.d_ff
        return full - all_exp + act_exp


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    base = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.family != "hybrid"
                     else max(cfg.attn_every, 4)),
        d_model=128,
        n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2) or 2,
        head_dim=32,
        d_ff=256, d_ff_dense=128 if cfg.moe_dense_residual else 0,
        vocab=512,
        n_experts=min(cfg.n_experts, 4),
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=32, ssm_chunk=16,
        seq_shard_activations=False,
        remat=False,
    )
    if cfg.family == "hybrid":
        base["attn_every"] = min(cfg.attn_every, 4)
        base["n_layers"] = 2 * base["attn_every"]
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
