"""MusicGen-large [arXiv:2306.05284] — decoder-only over EnCodec tokens;
audio frontend is a stub providing precomputed frame embeddings."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=2048,
    frontend="embeds",
    tensor_parallel=False,   # 3.2B: DP/FSDP only
)
