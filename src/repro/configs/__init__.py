# Assigned architectures (public-literature configs) + shape sets.
from .base import ModelConfig, ShapeConfig, SHAPES, reduced
from .chameleon_34b import CONFIG as chameleon_34b
from .jamba_v01_52b import CONFIG as jamba_v01_52b
from .musicgen_large import CONFIG as musicgen_large
from .grok1_314b import CONFIG as grok1_314b
from .arctic_480b import CONFIG as arctic_480b
from .stablelm_3b import CONFIG as stablelm_3b
from .qwen2_05b import CONFIG as qwen2_05b
from .gemma_7b import CONFIG as gemma_7b
from .qwen2_72b import CONFIG as qwen2_72b
from .mamba2_27b import CONFIG as mamba2_27b

ARCHS = {
    "chameleon-34b": chameleon_34b,
    "jamba-v0.1-52b": jamba_v01_52b,
    "musicgen-large": musicgen_large,
    "grok-1-314b": grok1_314b,
    "arctic-480b": arctic_480b,
    "stablelm-3b": stablelm_3b,
    "qwen2-0.5b": qwen2_05b,
    "gemma-7b": gemma_7b,
    "qwen2-72b": qwen2_72b,
    "mamba2-2.7b": mamba2_27b,
}

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "ARCHS", "reduced"]
