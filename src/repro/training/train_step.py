"""The jitted train step: loss/grad (remat'd scan blocks), optional
gradient accumulation, global-norm clipping, AdamW update, and the SDE
hook — an AMS gradient sketch maintained INSIDE the step.

The sketch is the paper's technique running as a first-class citizen of
the training loop: a strided sample of every gradient leaf is folded into
one AMS sketch per step. Because gradients under pjit are already global,
the sketch is identical on every device (zero extra collectives); across
pods it is mergeable by construction (linear sketch -> psum), which is the
paper's federated path. Downstream, monitor.py reads L2-norm estimates and
per-leaf inner products from it at O(depth*width) memory.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import AMS
from repro.models import model as M
from . import optim

_SKETCH_SAMPLE = 4096      # sampled positions per gradient leaf


@dataclasses.dataclass(frozen=True)
class TrainHooks:
    grad_sketch: Optional[AMS] = AMS(eps=0.02, delta=0.05)
    sketch_enabled: bool = True


def init_train_state(cfg: ModelConfig, opt_cfg: optim.OptConfig,
                     key: jax.Array, hooks: TrainHooks = TrainHooks()):
    params = M.init_params(cfg, key)
    state = dict(
        params=params,
        opt=optim.init_opt_state(opt_cfg, params),
        step=jnp.zeros((), jnp.int32),
    )
    if hooks.sketch_enabled and hooks.grad_sketch is not None:
        state["grad_sketch"] = hooks.grad_sketch.init(None)
    return state


def _strided_sample(g: jax.Array, target: int) -> jax.Array:
    """Small strided sub-block spanning the tensor (never flattens the
    full leaf — expert grads can exceed int32 addressing)."""
    ndim = max(g.ndim, 1)
    per_dim = max(2, int(round(target ** (1.0 / ndim))))
    starts = [0] * g.ndim
    limits = list(g.shape)
    strides = [max(1, s // per_dim) for s in g.shape]
    block = jax.lax.slice(g, starts, limits, strides)
    return block.reshape(-1).astype(jnp.float32)


def _sketch_grads(sketch: AMS, sk_state: jax.Array, grads: Any) -> jax.Array:
    """Fold a strided sample of every grad leaf into the AMS sketch.
    Item ids = hash(leaf_index, position) so leaves don't collide."""
    leaves = jax.tree.leaves(grads)
    for li, g in enumerate(leaves):
        n = float(np.prod(g.shape)) if g.ndim else 1.0
        vals = _strided_sample(g, _SKETCH_SAMPLE)
        take = vals.shape[0]
        vals = vals * np.sqrt(n / take)        # unbiased L2 scaling
        items = (jnp.arange(take, dtype=jnp.uint32)
                 ^ jnp.uint32((li * 2654435761 + 12345) % (2**32)))
        sk_state = sketch.add_batch(
            sk_state, items, vals, jnp.ones((take,), bool))
    return sk_state


def make_train_step(cfg: ModelConfig, opt_cfg: optim.OptConfig,
                    constrain=lambda t, a: t, grad_accum: int = 1,
                    hooks: TrainHooks = TrainHooks(),
                    spmd=None) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, mb):
        return M.loss_fn(cfg, params, mb, constrain, spmd=spmd)

    def train_step(state, batch):
        params = state["params"]
        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def micro(carry, mb):
                acc, lsum = carry
                (l, met), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, lsum + l), met

            mbs = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, lsum), mets = jax.lax.scan(micro, (zero, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = lsum / grad_accum
            metrics = jax.tree.map(lambda m: m[-1], mets)

        new_params, new_opt, opt_metrics = optim.apply_updates(
            opt_cfg, params, grads, state["opt"])
        new_state = dict(params=new_params, opt=new_opt,
                         step=state["step"] + 1)
        if "grad_sketch" in state:
            new_state["grad_sketch"] = _sketch_grads(
                hooks.grad_sketch, state["grad_sketch"], grads)
        metrics = dict(loss=loss, **metrics, **opt_metrics)
        if "grad_sketch" in new_state:
            metrics["sketch_l2_est"] = hooks.grad_sketch.estimate(
                new_state["grad_sketch"])
        return new_state, metrics

    return train_step


def state_logical_axes(cfg: ModelConfig, opt_cfg: optim.OptConfig,
                       hooks: TrainHooks = TrainHooks()) -> Dict[str, Any]:
    p_axes = M.logical_axes(cfg)
    out = dict(
        params=p_axes,
        opt=optim.opt_state_logical_axes(opt_cfg, p_axes),
        step=(),
    )
    if hooks.sketch_enabled and hooks.grad_sketch is not None:
        out["grad_sketch"] = (None, None)     # replicated (tiny)
    return out
