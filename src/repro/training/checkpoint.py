"""Fault-tolerant checkpointing.

Guarantees:
  * atomic: write to <dir>/tmp-<step>-<pid>, fsync, rename to
    <dir>/step-<n> (a crash mid-save never corrupts the latest
    checkpoint); stale tmp dirs a crashed process left behind are swept
    on the next save into the same directory,
  * keep-k GC of old steps — lineage-aware: a step referenced as the
    ``base_step`` (or a ``delta_chain`` member) of any kept incremental
    snapshot is never collected out from under its chain,
  * async: saves run on a background thread (training never blocks on
    IO); concurrent saves into the same directory serialize — a new
    ``save`` joins the previous background write instead of racing its
    rename/GC,
  * byte-exact extended dtypes: bf16 leaves are stored as uint16 views
    with a dtype tag in the manifest and reinterpreted on restore (a
    float32 widening round trip is NOT byte-stable for NaN payloads),
  * mesh-shape agnostic restore: leaves are stored unsharded; `restore`
    device_puts them under ANY target shardings — this is the elastic
    repartition path (shrink/grow the mesh between runs),
  * exact data-pipeline resume: the pipeline offset rides in the manifest.

The synopsis engine checkpoints through the same API (its state is a
pytree), so SDE state survives restarts with the job — including the
engine's incremental (dirty-row delta) snapshots, whose manifests carry
the ``base_step``/``delta_chain`` lineage this module's GC respects.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

# one background save at a time per directory: a second save joins the
# first instead of racing its tmp-dir rename and GC sweep. The entry
# also captures the background thread's exception — a failed async save
# is re-raised by the NEXT save into the directory (or drained by
# ``take_error``), never swallowed.
class _Save:
    __slots__ = ("thread", "error")

    def __init__(self, thread: threading.Thread):
        self.thread = thread
        self.error: Optional[BaseException] = None


_SAVE_THREADS: Dict[str, _Save] = {}
# per-directory save locks: held across the join-previous / host-copy /
# register-new sequence, so two threads calling save() concurrently can
# never both pass the join and run overlapping write bodies
_DIR_LOCKS: Dict[str, threading.Lock] = {}
_SAVE_LOCK = threading.Lock()            # guards the two registries
# fallback sweep for tmp dirs whose writer pid was reused by an
# unrelated process: past this age they can no longer be a live save
_TMP_MAX_AGE_S = 3600.0


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def _to_numpy(x) -> tuple[np.ndarray, Optional[str]]:
    """npz-compatible host array + dtype tag. bf16 ships as a uint16 bit
    view (tagged ``"bfloat16"`` so restore reinterprets instead of
    casting — byte-identical round trip, half the bytes of the old f32
    widening); other extension dtypes still widen to f32."""
    arr = np.asarray(jax.device_get(x))
    if str(arr.dtype) == "bfloat16":
        return arr.view(np.uint16), "bfloat16"
    if arr.dtype.kind == "V":
        arr = np.asarray(jax.device_get(
            jax.numpy.asarray(x).astype(jax.numpy.float32)))
    return arr, None


def _dir_lock(directory: str) -> threading.Lock:
    with _SAVE_LOCK:
        return _DIR_LOCKS.setdefault(os.path.abspath(directory),
                                     threading.Lock())


def _join(directory: str) -> Optional[_Save]:
    with _SAVE_LOCK:
        s = _SAVE_THREADS.get(os.path.abspath(directory))
    if s is not None and s.thread is not threading.current_thread():
        s.thread.join()
    return s


def wait(directory: str) -> None:
    """Join the in-flight background save for ``directory`` (no-op when
    idle). ``restore``/``latest_step`` call this so a reader never races
    a half-renamed step. Join only — a failed save's exception surfaces
    from the next ``save()`` (or ``take_error``), not here."""
    _join(directory)


def take_error(directory: str) -> Optional[BaseException]:
    """Join the in-flight background save and return-and-clear the
    exception it raised (None when it landed or none ran). Callers that
    chain state onto a prior async save (the engine's delta snapshots)
    poll this BEFORE building on it."""
    s = _join(directory)
    if s is None:
        return None
    err, s.error = s.error, None
    return err


def save(state: Any, directory: str, step: int, *,
         extra_manifest: Optional[Dict] = None, keep: int = 3,
         async_: bool = False) -> threading.Thread | None:
    """Atomic (optionally async) checkpoint of a pytree. The host copy
    of ``state`` happens synchronously (the caller may mutate/donate the
    arrays right after this returns); only the npz write, fsync, rename
    and GC run on the background thread. A prior async save that FAILED
    re-raises here (so failures are never silent); drain it first with
    ``take_error`` to handle it yourself."""
    with _dir_lock(directory):           # serialize with the prior save
        prev = _join(directory)
        if prev is not None and prev.error is not None:
            err, prev.error = prev.error, None
            raise RuntimeError(
                f"previous background checkpoint into {directory} "
                "never landed") from err
        leaves, _ = _flatten_with_paths(state)
        host: Dict[str, np.ndarray] = {}
        tags: Dict[str, str] = {}
        for k, v in leaves.items():
            host[k], tag = _to_numpy(v)
            if tag is not None:
                tags[k] = tag

        def _do():
            os.makedirs(directory, exist_ok=True)
            tmp = os.path.join(directory, f"tmp-{step}-{os.getpid()}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "leaves.npz"),
                     **{k.replace("/", "__"): v for k, v in host.items()})
            manifest = dict(step=step, time=time.time(),
                            n_leaves=len(host), leaf_dtypes=tags,
                            **(extra_manifest or {}))
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            final = os.path.join(directory, f"step-{step:08d}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            _gc(directory, keep)

        if async_:
            def _run():
                try:
                    _do()
                except BaseException as e:  # noqa: BLE001 - re-raised later
                    entry.error = e

            entry = _Save(threading.Thread(target=_run, daemon=True))
            with _SAVE_LOCK:
                _SAVE_THREADS[os.path.abspath(directory)] = entry
            entry.thread.start()
            return entry.thread
        _do()
        return None


def _lineage_refs(directory: str, step_dir: str) -> set:
    """Step dirs a snapshot manifest references (its delta chain/base):
    those must survive GC or the chain cannot be restored."""
    refs: set = set()
    try:
        with open(os.path.join(directory, step_dir, "manifest.json")) as f:
            man = json.load(f)
    except (OSError, ValueError):
        return refs
    base = man.get("base_step")
    if base is not None:
        refs.add(f"step-{int(base):08d}")
    for s in man.get("delta_chain") or []:
        refs.add(f"step-{int(s):08d}")
    return refs


def _gc(directory: str, keep: int):
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step-"))
    protected = set(steps[-keep:]) if keep > 0 else set(steps)
    # lineage closure: an incremental snapshot is only restorable with
    # its base + every prior delta — protect whatever the kept manifests
    # reference (chains list every member, so one pass closes the set)
    for d in list(protected):
        protected |= _lineage_refs(directory, d)
    for d in steps:
        if d not in protected:
            shutil.rmtree(os.path.join(directory, d), ignore_errors=True)
    # sweep tmp dirs crashed saves left behind: tmp-<step>-<pid> whose
    # pid is no longer alive can never be renamed into place. Age is the
    # fallback for pid reuse — a dead writer's pid recycled by an
    # unrelated long-lived process would otherwise pin its tmp forever.
    for d in os.listdir(directory):
        if not d.startswith("tmp-"):
            continue
        full = os.path.join(directory, d)
        pid = d.rsplit("-", 1)[-1]
        try:
            alive = pid.isdigit() and _pid_alive(int(pid))
        except ValueError:
            alive = False
        if alive and int(pid) != os.getpid():
            try:
                alive = time.time() - os.path.getmtime(full) < _TMP_MAX_AGE_S
            except OSError:
                continue                 # renamed/removed under us
        if not alive:
            shutil.rmtree(full, ignore_errors=True)


def _pid_alive(pid: int) -> bool:
    if pid == os.getpid():
        return True
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True                      # exists, owned by someone else
    return True


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    wait(directory)
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step-"))
    return int(steps[-1].split("-")[1]) if steps else None


def restore(like: Any, directory: str, step: Optional[int] = None,
            shardings: Any = None) -> tuple[Any, Dict]:
    """Restore into the structure of `like`; device_put under `shardings`
    (None => default placement). Works across mesh shapes (elastic)."""
    wait(directory)                      # never read a half-written step
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step-{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    tags = manifest.get("leaf_dtypes", {})
    blob = np.load(os.path.join(path, "leaves.npz"))
    keys, treedef = _flatten_with_paths(like)
    like_leaves = list(keys.values())
    leaves = []
    for key, like_leaf in zip(keys, like_leaves):
        arr = blob[key.replace("/", "__")]
        if tags.get(key) == "bfloat16":
            # reinterpret the stored uint16 bit pattern — NOT a cast
            arr = arr.view(jax.numpy.bfloat16.dtype)
        leaves.append(jax.numpy.asarray(arr).astype(like_leaf.dtype))
    state = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, shardings)
    return state, manifest
