"""Fault-tolerant checkpointing.

Guarantees:
  * atomic: write to <dir>/tmp-<step>, fsync, rename to <dir>/step-<n>
    (a crash mid-save never corrupts the latest checkpoint),
  * keep-k GC of old steps,
  * async: saves run on a background thread (training never blocks on IO),
  * mesh-shape agnostic restore: leaves are stored unsharded; `restore`
    device_puts them under ANY target shardings — this is the elastic
    repartition path (shrink/grow the mesh between runs),
  * exact data-pipeline resume: the pipeline offset rides in the manifest.

The synopsis engine checkpoints through the same API (its state is a
pytree), so SDE state survives restarts with the job.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def _to_numpy(x) -> np.ndarray:
    """npz-compatible host array (bf16 and friends widen to f32)."""
    arr = np.asarray(jax.device_get(x))
    if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
        arr = np.asarray(jax.device_get(
            jax.numpy.asarray(x).astype(jax.numpy.float32)))
    return arr


def save(state: Any, directory: str, step: int, *,
         extra_manifest: Optional[Dict] = None, keep: int = 3,
         async_: bool = False) -> threading.Thread | None:
    """Atomic (optionally async) checkpoint of a pytree."""
    host_state = jax.tree.map(_to_numpy, state)

    def _do():
        os.makedirs(directory, exist_ok=True)
        tmp = os.path.join(directory, f"tmp-{step}-{os.getpid()}")
        os.makedirs(tmp, exist_ok=True)
        leaves, _ = _flatten_with_paths(host_state)
        np.savez(os.path.join(tmp, "leaves.npz"),
                 **{k.replace("/", "__"): v for k, v in leaves.items()})
        manifest = dict(step=step, time=time.time(),
                        n_leaves=len(leaves), **(extra_manifest or {}))
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        final = os.path.join(directory, f"step-{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(directory, keep)

    if async_:
        t = threading.Thread(target=_do, daemon=True)
        t.start()
        return t
    _do()
    return None


def _gc(directory: str, keep: int):
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step-"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step-"))
    return int(steps[-1].split("-")[1]) if steps else None


def restore(like: Any, directory: str, step: Optional[int] = None,
            shardings: Any = None) -> tuple[Any, Dict]:
    """Restore into the structure of `like`; device_put under `shardings`
    (None => default placement). Works across mesh shapes (elastic)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step-{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    blob = np.load(os.path.join(path, "leaves.npz"))
    keys, treedef = _flatten_with_paths(like)
    like_leaves = list(keys.values())
    leaves = []
    for key, like_leaf in zip(keys, like_leaves):
        arr = blob[key.replace("/", "__")]
        leaves.append(jax.numpy.asarray(arr).astype(like_leaf.dtype))
    state = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, shardings)
    return state, manifest
