"""Fault-tolerant checkpointing.

Guarantees:
  * atomic: write to <dir>/tmp-<step>-<pid>, fsync, rename to
    <dir>/step-<n> (a crash mid-save never corrupts the latest
    checkpoint); stale tmp dirs a crashed process left behind are swept
    on the next save into the same directory,
  * keep-k GC of old steps — lineage-aware: a step referenced as the
    ``base_step`` (or a ``delta_chain`` member) of any kept incremental
    snapshot is never collected out from under its chain,
  * async: saves run on a background thread (training never blocks on
    IO); concurrent saves into the same directory serialize — a new
    ``save`` joins the previous background write instead of racing its
    rename/GC,
  * byte-exact extended dtypes: bf16 leaves are stored as uint16 views
    with a dtype tag in the manifest and reinterpreted on restore (a
    float32 widening round trip is NOT byte-stable for NaN payloads),
  * mesh-shape agnostic restore: leaves are stored unsharded; `restore`
    device_puts them under ANY target shardings — this is the elastic
    repartition path (shrink/grow the mesh between runs),
  * exact data-pipeline resume: the pipeline offset rides in the manifest.

The synopsis engine checkpoints through the same API (its state is a
pytree), so SDE state survives restarts with the job — including the
engine's incremental (dirty-row delta) snapshots, whose manifests carry
the ``base_step``/``delta_chain`` lineage this module's GC respects.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

# one background save at a time per directory: a second save joins the
# first instead of racing its tmp-dir rename and GC sweep
_SAVE_THREADS: Dict[str, threading.Thread] = {}
_SAVE_LOCK = threading.Lock()


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def _to_numpy(x) -> tuple[np.ndarray, Optional[str]]:
    """npz-compatible host array + dtype tag. bf16 ships as a uint16 bit
    view (tagged ``"bfloat16"`` so restore reinterprets instead of
    casting — byte-identical round trip, half the bytes of the old f32
    widening); other extension dtypes still widen to f32."""
    arr = np.asarray(jax.device_get(x))
    if str(arr.dtype) == "bfloat16":
        return arr.view(np.uint16), "bfloat16"
    if arr.dtype.kind == "V":
        arr = np.asarray(jax.device_get(
            jax.numpy.asarray(x).astype(jax.numpy.float32)))
    return arr, None


def wait(directory: str) -> None:
    """Join the in-flight background save for ``directory`` (no-op when
    idle). ``restore``/``latest_step`` call this so a reader never races
    a half-renamed step."""
    with _SAVE_LOCK:
        t = _SAVE_THREADS.get(os.path.abspath(directory))
    if t is not None and t is not threading.current_thread():
        t.join()


def save(state: Any, directory: str, step: int, *,
         extra_manifest: Optional[Dict] = None, keep: int = 3,
         async_: bool = False) -> threading.Thread | None:
    """Atomic (optionally async) checkpoint of a pytree. The host copy
    of ``state`` happens synchronously (the caller may mutate/donate the
    arrays right after this returns); only the npz write, fsync, rename
    and GC run on the background thread."""
    wait(directory)                      # serialize with the prior save
    leaves, _ = _flatten_with_paths(state)
    host: Dict[str, np.ndarray] = {}
    tags: Dict[str, str] = {}
    for k, v in leaves.items():
        host[k], tag = _to_numpy(v)
        if tag is not None:
            tags[k] = tag

    def _do():
        os.makedirs(directory, exist_ok=True)
        tmp = os.path.join(directory, f"tmp-{step}-{os.getpid()}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "leaves.npz"),
                 **{k.replace("/", "__"): v for k, v in host.items()})
        manifest = dict(step=step, time=time.time(),
                        n_leaves=len(host), leaf_dtypes=tags,
                        **(extra_manifest or {}))
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        final = os.path.join(directory, f"step-{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(directory, keep)

    if async_:
        t = threading.Thread(target=_do, daemon=True)
        with _SAVE_LOCK:
            _SAVE_THREADS[os.path.abspath(directory)] = t
        t.start()
        return t
    _do()
    return None


def _lineage_refs(directory: str, step_dir: str) -> set:
    """Step dirs a snapshot manifest references (its delta chain/base):
    those must survive GC or the chain cannot be restored."""
    refs: set = set()
    try:
        with open(os.path.join(directory, step_dir, "manifest.json")) as f:
            man = json.load(f)
    except (OSError, ValueError):
        return refs
    base = man.get("base_step")
    if base is not None:
        refs.add(f"step-{int(base):08d}")
    for s in man.get("delta_chain") or []:
        refs.add(f"step-{int(s):08d}")
    return refs


def _gc(directory: str, keep: int):
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step-"))
    protected = set(steps[-keep:]) if keep > 0 else set(steps)
    # lineage closure: an incremental snapshot is only restorable with
    # its base + every prior delta — protect whatever the kept manifests
    # reference (chains list every member, so one pass closes the set)
    for d in list(protected):
        protected |= _lineage_refs(directory, d)
    for d in steps:
        if d not in protected:
            shutil.rmtree(os.path.join(directory, d), ignore_errors=True)
    # sweep tmp dirs crashed saves left behind: tmp-<step>-<pid> whose
    # pid is no longer alive can never be renamed into place
    for d in os.listdir(directory):
        if not d.startswith("tmp-"):
            continue
        pid = d.rsplit("-", 1)[-1]
        try:
            alive = pid.isdigit() and _pid_alive(int(pid))
        except ValueError:
            alive = False
        if not alive:
            shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def _pid_alive(pid: int) -> bool:
    if pid == os.getpid():
        return True
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True                      # exists, owned by someone else
    return True


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    wait(directory)
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step-"))
    return int(steps[-1].split("-")[1]) if steps else None


def restore(like: Any, directory: str, step: Optional[int] = None,
            shardings: Any = None) -> tuple[Any, Dict]:
    """Restore into the structure of `like`; device_put under `shardings`
    (None => default placement). Works across mesh shapes (elastic)."""
    wait(directory)                      # never read a half-written step
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step-{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    tags = manifest.get("leaf_dtypes", {})
    blob = np.load(os.path.join(path, "leaves.npz"))
    keys, treedef = _flatten_with_paths(like)
    like_leaves = list(keys.values())
    leaves = []
    for key, like_leaf in zip(keys, like_leaves):
        arr = blob[key.replace("/", "__")]
        if tags.get(key) == "bfloat16":
            # reinterpret the stored uint16 bit pattern — NOT a cast
            arr = arr.view(jax.numpy.bfloat16.dtype)
        leaves.append(jax.numpy.asarray(arr).astype(like_leaf.dtype))
    state = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, shardings)
    return state, manifest
