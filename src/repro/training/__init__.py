from . import optim, train_step, checkpoint, monitor  # noqa: F401
from .optim import OptConfig
from .train_step import make_train_step, init_train_state, TrainHooks
from .monitor import MetricMonitor
