"""SDE-backed training telemetry (the paper serving an ML workflow).

Host-side monitor that feeds per-step scalar metrics (loss, grad-norm,
per-layer/per-expert loads) into sliding-DFT synopses and reports
correlated metric groups via grid bucketing — StatStream pointed at
training dynamics. Detects e.g. experts whose load curves are highly
correlated (candidates for merging) or layers with synchronized gradient
spikes, at O(F) state per metric instead of storing full histories.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DFT
from repro.core.dft import pairwise_corr


class MetricMonitor:
    def __init__(self, window: int = 64, n_coeffs: int = 8,
                 threshold: float = 0.9):
        self.kind = DFT(window=window, n_coeffs=n_coeffs,
                        threshold=threshold)
        self.states: Dict[str, dict] = {}
        self._step = jax.jit(self.kind.step)

    def observe(self, metrics: Dict[str, float]):
        for name, value in metrics.items():
            if name not in self.states:
                self.states[name] = self.kind.init(None)
            self.states[name] = self._step(self.states[name],
                                           float(value), True)

    def correlated_groups(self) -> List[List[str]]:
        """Metric names whose recent windows are correlated above the
        threshold (same/adjacent DFT grid buckets + corr check)."""
        names = sorted(self.states)
        if len(names) < 2:
            return []
        coeffs = jnp.stack([self.kind.normalized_coeffs(self.states[n])
                            for n in names])
        corr = np.asarray(pairwise_corr(coeffs))
        groups, used = [], set()
        for i, ni in enumerate(names):
            if ni in used:
                continue
            group = [ni]
            for j in range(i + 1, len(names)):
                if corr[i, j] >= self.kind.threshold and names[j] not in used:
                    group.append(names[j])
                    used.add(names[j])
            if len(group) > 1:
                groups.append(group)
                used.update(group)
        return groups

    def snapshot(self) -> Dict[str, np.ndarray]:
        return {n: np.asarray(self.kind.normalized_coeffs(s))
                for n, s in self.states.items()}
