"""Optimizers built from scratch: AdamW and int8-moment AdamW.

The int8 variant quantizes both Adam moments to int8 with a per-row (all
dims but last) absmax scale — a distributed-optimization trick that cuts
optimizer state from 8 to ~2.1 bytes/param, which is what lets the 314B /
480B configs fit 16 GB/chip HBM at 256-512 chips (see EXPERIMENTS §Dry-run
memory table). Moments are sharded like their parameters (FSDP), so the
quantization is purely local — no collective cost.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"            # adamw | adamw8bit
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(np.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, 0.1 + 0.9 * cos)


# -- int8 moment quantization ------------------------------------------------
# m: linear symmetric int8 with per-row absmax scale.
# v: sqrt-domain int8 — v spans many orders of magnitude and its square
#    root sits in the Adam denominator; linear quantization collapses
#    small rows to 0 and the update explodes (found by test_training).
def _quantize(x: jax.Array, sqrt_domain: bool = False) -> Dict[str, jax.Array]:
    y = jnp.sqrt(jnp.maximum(x, 0.0)) if sqrt_domain else x
    absmax = jnp.max(jnp.abs(y), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(y / scale), -127, 127).astype(jnp.int8)
    return dict(q=q, scale=scale.astype(jnp.float32))


def _dequantize(d: Dict[str, jax.Array], sqrt_domain: bool = False) -> jax.Array:
    y = d["q"].astype(jnp.float32) * d["scale"]
    return y * y if sqrt_domain else y


# -----------------------------------------------------------------------------
def init_opt_state(cfg: OptConfig, params: Any) -> Dict[str, Any]:
    def zero_moment(p, sqrt_domain=False):
        z = jnp.zeros(p.shape, jnp.float32)
        if cfg.name == "adamw8bit":
            return _quantize(z, sqrt_domain)
        return z

    return dict(
        m=jax.tree.map(zero_moment, params),
        v=jax.tree.map(lambda p: zero_moment(p, True), params),
        count=jnp.zeros((), jnp.int32),
    )


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: OptConfig, params: Any, grads: Any,
                  state: Dict[str, Any]) -> Tuple[Any, Dict[str, Any],
                                                  Dict[str, jax.Array]]:
    count = state["count"] + 1
    lr = schedule(cfg, count)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    quant = cfg.name == "adamw8bit"
    is_mom = (lambda x: isinstance(x, dict) and set(x) == {"q", "scale"}) \
        if quant else None

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_f = _dequantize(m) if quant else m
        v_f = _dequantize(v, sqrt_domain=True) if quant else v
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        mhat = m_f / (1 - cfg.b1 ** count.astype(jnp.float32))
        vhat = v_f / (1 - cfg.b2 ** count.astype(jnp.float32))
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        new_p = (p.astype(jnp.float32)
                 - lr * (step_ + decay * p.astype(jnp.float32)))
        m_out = _quantize(m_f) if quant else m_f
        v_out = _quantize(v_f, sqrt_domain=True) if quant else v_f
        return new_p.astype(p.dtype), m_out, v_out

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"], is_leaf=is_mom) if quant \
        else jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"], is_leaf=is_mom) if quant \
        else jax.tree.leaves(state["v"])
    outs = [upd(p, g, m, v)
            for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in outs])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in outs])
    metrics = dict(grad_norm=gnorm, lr=lr)
    return new_params, dict(m=new_m, v=new_v, count=count), metrics


def opt_state_logical_axes(cfg: OptConfig, param_axes: Any) -> Dict[str, Any]:
    """Moments shard exactly like their parameters (scales drop last dim)."""
    def mom_axes(axes):
        if cfg.name == "adamw8bit":
            return dict(q=axes, scale=axes[:-1] + (None,))
        return axes

    is_axes = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)
    m = jax.tree.map(mom_axes, param_axes, is_leaf=is_axes)
    return dict(m=m, v=m, count=())       # count: scalar (replicated)
