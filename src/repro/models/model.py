"""Composable LM: dense / MoE / SSM / hybrid blocks assembled from a
ModelConfig, with scan-over-layer-groups (weights stacked per repeating
period), remat, and logical-axis sharding annotations throughout.

Entry points:
  init_params / params_shape / logical_axes
  forward(...)            train & prefill (returns caches for prefill)
  loss_fn(...)            next-token CE + MoE aux losses
  decode_step_fn(...)     one-token serve step against caches
  init_caches(...)        cache pytree for a (batch, s_max)
  input_specs(...)        ShapeDtypeStruct stand-ins for the dry-run
"""
from __future__ import annotations

import functools
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import rms_norm, init_dense, init_mlp, mlp_forward

_NO_CONSTRAIN = lambda t, axes: t


@jax.custom_vjp
def _ct_barrier(x):
    """Identity whose COTANGENT is forced to the primal dtype (bf16).

    f32 segments inside blocks (norm/softmax/router) otherwise promote the
    whole backward residual stream to f32 — doubling every bwd collective
    payload and activation cotangent buffer (§Perf iteration 6)."""
    return x


def _ct_fwd(x):
    return x, jnp.zeros((0,), x.dtype)     # dtype token (valid JAX type)


def _ct_bwd(token, g):
    return (g.astype(token.dtype),)


_ct_barrier.defvjp(_ct_fwd, _ct_bwd)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_block(key, cfg: ModelConfig, kind: Tuple[str, str]) -> Dict:
    mixer, ffn = kind
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": jnp.zeros((cfg.d_model,), jnp.float32)}
    if mixer == "attn":
        p["attn"] = attn_mod.init_attn(ks[0], cfg)
    else:
        p["mamba"] = ssm_mod.init_ssm(ks[0], cfg)
    if ffn != "none":
        p["ln2"] = jnp.zeros((cfg.d_model,), jnp.float32)
    if ffn == "dense":
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff)
    elif ffn == "moe":
        p["moe"] = moe_mod.init_moe(ks[2], cfg)
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict:
    plan = cfg.layer_plan()
    period = cfg.period()
    n_groups = cfg.n_layers // period
    keys = jax.random.split(key, period + 4)
    params: Dict[str, Any] = dict(
        embed=init_dense(keys[-1], (cfg.vocab, cfg.d_model)),
        final_ln=jnp.zeros((cfg.d_model,), jnp.float32),
    )
    if not cfg.tie_embeddings:
        params["head"] = init_dense(keys[-2], (cfg.d_model, cfg.vocab))
    if cfg.frontend == "embeds":
        params["stub"] = init_dense(keys[-3], (cfg.d_model, cfg.d_model))
    layers = {}
    for pos in range(period):
        gkeys = jax.random.split(keys[pos], n_groups)
        layers[f"pos{pos}"] = jax.vmap(
            lambda k: _init_block(k, cfg, plan[pos]))(gkeys)
    params["layers"] = layers
    return params


def params_shape(cfg: ModelConfig) -> Dict:
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))


def logical_axes(cfg: ModelConfig) -> Dict:
    """Pytree (matching init_params) of logical-axis tuples."""
    plan = cfg.layer_plan()
    period = cfg.period()
    g = None  # leading group axis is never sharded

    def attn_axes():
        p = dict(wq=(g, "fsdp", "tensor", None),
                 wk=(g, "fsdp", "kv_tensor", None),
                 wv=(g, "fsdp", "kv_tensor", None),
                 wo=(g, "tensor", None, "fsdp"))
        if cfg.qkv_bias:
            p.update(bq=(g, "tensor", None), bk=(g, "kv_tensor", None),
                     bv=(g, "kv_tensor", None))
        return p

    def mlp_axes():
        return dict(wg=(g, "fsdp", "tensor"), wu=(g, "fsdp", "tensor"),
                    wd=(g, "tensor", "fsdp"))

    def moe_axes():
        # E is batched (unsharded): shard-local dispatch + f-TP + d-FSDP
        # works uniformly for E = 8 / 16 / 128 (see moe_ffn_shardmap)
        p = dict(router=(g, None, None),
                 wg=(g, None, "fsdp", "tensor"),
                 wu=(g, None, "fsdp", "tensor"),
                 wd=(g, None, "tensor", "fsdp"))
        if cfg.moe_dense_residual:
            p["dense"] = mlp_axes()
        return p

    def ssm_axes():
        return dict(in_proj=(g, "fsdp", "tensor"),
                    conv_w=(g, None, "tensor"), conv_b=(g, "tensor"),
                    a_log=(g, None), d_skip=(g, None), dt_bias=(g, None),
                    norm=(g, "tensor"), out_proj=(g, "tensor", "fsdp"))

    layers = {}
    for pos in range(period):
        mixer, ffn = plan[pos]
        p: Dict[str, Any] = {"ln1": (g, None)}
        if mixer == "attn":
            p["attn"] = attn_axes()
        else:
            p["mamba"] = ssm_axes()
        if ffn != "none":
            p["ln2"] = (g, None)
        if ffn == "dense":
            p["mlp"] = mlp_axes()
        elif ffn == "moe":
            p["moe"] = moe_axes()
        layers[f"pos{pos}"] = p

    out: Dict[str, Any] = dict(
        embed=("tensor", "fsdp"), final_ln=(None,), layers=layers)
    if not cfg.tie_embeddings:
        out["head"] = ("fsdp", "tensor")
    if cfg.frontend == "embeds":
        out["stub"] = ("fsdp", "tensor")
    return out


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------
def _moe_call(h2, p, cfg, constrain, spmd):
    if spmd is not None:
        mesh, rules, mode = (spmd if len(spmd) == 3 else (*spmd, "train"))
        n_data = mesh.shape.get("data", 1)
        experts_too_big = (cfg.expert_param_count() * 2
                           / mesh.shape.get("model", 1) > 12e9)
        if (mode == "decode" and experts_too_big
                and cfg.n_experts % n_data == 0
                and h2.shape[0] % _moe_batch_div(mesh) == 0):
            # giants whose expert weights can't replicate: EP-resident
            # decode (see moe_ffn_ep_decode)
            return moe_mod.moe_ffn_ep_decode(h2, p, cfg, mesh, rules)
        return moe_mod.moe_ffn_shardmap(h2, p, cfg, mesh, rules, mode)
    return moe_mod.moe_ffn(h2, p, cfg, constrain)


def _moe_batch_div(mesh):
    n = 1
    for a in ("pod", "data"):
        n *= mesh.shape.get(a, 1)
    return n


def _block_fwd(x, p, kind, cfg: ModelConfig, positions, constrain,
               spmd=None):
    """Train/prefill block. Returns (x, cache, aux)."""
    mixer, ffn = kind
    aux = dict(lb_loss=jnp.zeros((), jnp.float32),
               z_loss=jnp.zeros((), jnp.float32))
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if mixer == "attn":
        out, cache = attn_mod.attention(h, p["attn"], cfg, positions,
                                        constrain=constrain)
    else:
        out, cache = ssm_mod.ssm_forward(h, p["mamba"], cfg,
                                         constrain=constrain)
    x = x + out
    x = constrain(x, ("batch", "seq", None))
    if ffn != "none":
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if ffn == "dense":
            x = x + mlp_forward(h2, p["mlp"], cfg.mlp_act)
        else:
            mo, moe_aux = _moe_call(h2, p["moe"], cfg, constrain, spmd)
            x = x + mo
            aux["lb_loss"] += moe_aux["lb_loss"]
            aux["z_loss"] += moe_aux["z_loss"]
    x = constrain(x, ("batch", "seq", None))
    return x, cache, aux


def _block_decode(x, p, kind, cfg: ModelConfig, cache, pos, constrain,
                  spmd=None):
    mixer, ffn = kind
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if mixer == "attn":
        out, cache = attn_mod.decode_step(h, p["attn"], cfg, cache, pos)
    else:
        out, cache = ssm_mod.ssm_decode_step(h, p["mamba"], cfg, cache)
    x = x + out
    if ffn != "none":
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if ffn == "dense":
            x = x + mlp_forward(h2, p["mlp"], cfg.mlp_act)
        else:
            mo, _ = _moe_call(h2, p["moe"], cfg, constrain, spmd)
            x = x + mo
    return x, cache


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------
def _embed_inputs(cfg: ModelConfig, params, batch, constrain):
    if "embeds" in batch:
        x = batch["embeds"].astype(jnp.bfloat16) @ params["stub"]
    else:
        x = params["embed"][batch["tokens"]]
    return constrain(x, ("batch", "seq", None))


def forward(cfg: ModelConfig, params: Dict, batch: Dict,
            constrain=_NO_CONSTRAIN, *, want_caches: bool = False,
            last_logit_only: bool = False, spmd=None):
    """Returns (logits, caches, aux). Caches only when want_caches."""
    plan = cfg.layer_plan()
    period = cfg.period()
    x = _embed_inputs(cfg, params, batch, constrain)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def group_fn(carry, group_params):
        x, lb, zl = carry
        caches = {}
        for pos in range(period):
            fn = functools.partial(_block_fwd, kind=plan[pos], cfg=cfg,
                                   positions=positions, constrain=constrain,
                                   spmd=spmd)
            if cfg.remat:
                fn = jax.checkpoint(fn)
            x, cache, aux = fn(x, group_params[f"pos{pos}"])
            if os.environ.get("REPRO_CT_BARRIER", "1") == "1":
                x = _ct_barrier(x)
            caches[f"pos{pos}"] = cache
            lb = lb + aux["lb_loss"]
            zl = zl + aux["z_loss"]
        return (x, lb, zl), (caches if want_caches else None)

    zero = jnp.zeros((), jnp.float32)
    (x, lb, zl), caches = jax.lax.scan(group_fn, (x, zero, zero),
                                       params["layers"])
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    if last_logit_only:
        x = x[:, -1:, :]
    head = (params["embed"].T if cfg.tie_embeddings else params["head"])
    logits = x @ head.astype(x.dtype)
    logits = constrain(logits, ("batch", None, "tensor"))
    aux = dict(lb_loss=lb, z_loss=zl)
    return logits, caches, aux


def loss_fn(cfg: ModelConfig, params: Dict, batch: Dict,
            constrain=_NO_CONSTRAIN, spmd=None):
    """Next-token CE (labels already shifted by the pipeline) + MoE aux."""
    logits, _, aux = forward(cfg, params, batch, constrain, spmd=spmd)
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, batch["labels"][..., None].astype(jnp.int32), axis=-1)[..., 0]
    ce = jnp.mean(lse - gold)
    total = ce + 0.01 * aux["lb_loss"] + 0.001 * aux["z_loss"]
    metrics = dict(ce=ce, lb_loss=aux["lb_loss"], z_loss=aux["z_loss"])
    return total, metrics


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------
def init_caches(cfg: ModelConfig, batch: int, s_max: int) -> Dict:
    plan = cfg.layer_plan()
    period = cfg.period()
    n_groups = cfg.n_layers // period
    caches = {}
    for pos in range(period):
        mixer = plan[pos][0]
        if mixer == "attn":
            one = attn_mod.init_cache(cfg, batch, s_max)
        else:
            one = ssm_mod.init_ssm_cache(cfg, batch)
        caches[f"pos{pos}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape).copy(), one)
    return caches


def cache_logical_axes(cfg: ModelConfig) -> Dict:
    plan = cfg.layer_plan()
    period = cfg.period()
    out = {}
    for pos in range(period):
        if plan[pos][0] == "attn":
            out[f"pos{pos}"] = dict(k=(None, "batch", "kv_seq", None, None),
                                    v=(None, "batch", "kv_seq", None, None))
        else:
            out[f"pos{pos}"] = dict(h=(None, "batch", "tensor", None, None),
                                    conv=(None, "batch", None, "tensor"))
    return out


def decode_step_fn(cfg: ModelConfig, params: Dict, caches: Dict,
                   tokens: jax.Array, pos: jax.Array,
                   constrain=_NO_CONSTRAIN, spmd=None):
    """One serve step: tokens [B] at position `pos` -> logits [B, vocab]."""
    plan = cfg.layer_plan()
    period = cfg.period()
    x = params["embed"][tokens][:, None, :]          # [B, 1, d]
    x = constrain(x, ("batch", None, None))

    def group_fn(x, scanned):
        group_params, cache = scanned
        new_caches = {}
        for p in range(period):
            x, c = _block_decode(x, group_params[f"pos{p}"], plan[p], cfg,
                                 cache[f"pos{p}"], pos, constrain,
                                 spmd=spmd)
            new_caches[f"pos{p}"] = c
        return x, new_caches

    x, new_caches = jax.lax.scan(group_fn, x, (params["layers"], caches))
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["head"])
    logits = (x @ head.astype(x.dtype))[:, 0, :]
    logits = constrain(logits, ("batch", "tensor"))
    return logits, new_caches


# ---------------------------------------------------------------------------
# dry-run stand-ins
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    if shape.mode in ("train", "prefill"):
        specs = {}
        if cfg.frontend == "embeds":
            specs["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                   jnp.bfloat16)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if shape.mode == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return specs
    # decode: one new token against an s-long cache
    return dict(tokens=jax.ShapeDtypeStruct((b,), jnp.int32),
                pos=jax.ShapeDtypeStruct((), jnp.int32))
