"""Mamba-2 SSD layer (state-space duality, [arXiv:2405.21060]).

Chunked train/prefill: a lax.scan over sequence chunks carries the
[B, H, P, N] state; within each chunk the dual quadratic form runs as
dense einsums (MXU work), giving O(S * Q) time with Q-sized attention-like
blocks instead of O(S^2). Decode is the O(1) recurrent step on the carried
state — no KV cache, which is why the ssm/hybrid archs are the long_500k
architectures.

Layout: x [B,S,d] -> in_proj -> [z | x_conv | B | C | dt]; causal depthwise
conv over (x,B,C); scalar-A-per-head discretization; gated RMSNorm out.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from .layers import init_dense, rms_norm


def init_ssm(key, cfg: ModelConfig) -> Dict:
    d, din, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = din + 2 * n
    ks = jax.random.split(key, 4)
    return dict(
        in_proj=init_dense(ks[0], (d, 2 * din + 2 * n + h)),
        conv_w=(jax.random.normal(ks[1], (cfg.conv_width, conv_ch),
                                  jnp.float32) * 0.1).astype(jnp.bfloat16),
        conv_b=jnp.zeros((conv_ch,), jnp.bfloat16),
        a_log=jnp.zeros((h,), jnp.float32),              # A = -exp(a_log)
        d_skip=jnp.ones((h,), jnp.float32),
        dt_bias=jnp.zeros((h,), jnp.float32),
        norm=jnp.zeros((din,), jnp.float32),
        out_proj=init_dense(ks[2], (din, d)),
    )


def _split(z: jax.Array, cfg: ModelConfig):
    din, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    zg = z[..., :din]
    xbc = z[..., din:2 * din + 2 * n]
    dt = z[..., 2 * din + 2 * n:]
    return zg, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over sequence (f32 accum to match the decode
    path bit-for-bit). xbc [B,S,C], w [W,C]."""
    width = w.shape[0]
    pad = jnp.pad(xbc.astype(jnp.float32), ((0, 0), (width - 1, 0), (0, 0)))
    wf = w.astype(jnp.float32)
    out = sum(pad[:, i:i + xbc.shape[1], :] * wf[i][None, None, :]
              for i in range(width))
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(xbc.dtype)


def _ssd_chunks(xs, bmat, cmat, dt, a, cfg: ModelConfig, h0=None):
    """Chunk-scanned SSD. xs [B,S,H,P]; bmat/cmat [B,S,N]; dt [B,S,H].

    Returns (y [B,S,H,P], h_final [B,H,P,N]).
    """
    b, s, h, p = xs.shape
    n = bmat.shape[-1]
    q = min(cfg.ssm_chunk, s)
    pad = (-s) % q
    if pad:
        # dt = 0 on padding => exp(dt*A) = 1 (state carried) and zero input
        # injection: padding is an exact identity on the recurrence.
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    s_orig, s = s, s + pad
    nc = s // q

    xs_c = xs.reshape(b, nc, q, h, p).transpose(1, 0, 2, 3, 4)
    b_c = bmat.reshape(b, nc, q, n).transpose(1, 0, 2, 3)
    c_c = cmat.reshape(b, nc, q, n).transpose(1, 0, 2, 3)
    dt_c = dt.reshape(b, nc, q, h).transpose(1, 0, 2, 3)
    dalog_c = dt_c * a[None, None, None, :]              # [nc,B,Q,H] (<= 0)

    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)

    causal = jnp.tril(jnp.ones((q, q), bool))

    def step(h_prev, inp):
        xc, bc, cc, dtc, dal = inp
        seg = jnp.cumsum(dal, axis=1)                    # [B,Q,H]
        # carry-in contribution decayed to each position
        y_inter = jnp.einsum("bqn,bhpn,bqh->bqhp", cc, h_prev,
                             jnp.exp(seg)).astype(jnp.float32)
        # intra-chunk dual (attention-like) form
        cb = jnp.einsum("bqn,bsn->bqs", cc, bc)          # [B,Q,Q]
        decay = jnp.exp(seg[:, :, None, :] - seg[:, None, :, :])  # [B,q,s,H]
        w = cb[..., None] * decay * dtc[:, None, :, :]
        w = jnp.where(causal[None, :, :, None], w, 0.0)
        y_intra = jnp.einsum("bqsh,bshp->bqhp", w, xc.astype(jnp.float32))
        # state update
        tot = seg[:, -1, :]                              # [B,H]
        w_state = jnp.exp(tot[:, None, :] - seg) * dtc   # [B,Q,H]
        h_new = (h_prev * jnp.exp(tot)[:, :, None, None]
                 + jnp.einsum("bqh,bqn,bqhp->bhpn", w_state, bc,
                              xc.astype(jnp.float32)))
        # stack per-chunk outputs in bf16: halves the scan-carry HBM and
        # collective payloads (§Perf iteration 2); accumulation stays f32
        return h_new, (y_inter + y_intra).astype(xs.dtype)

    h_final, y = jax.lax.scan(step, h0, (xs_c, b_c, c_c, dt_c, dalog_c))
    y = y.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    return y[:, :s_orig], h_final


def ssm_forward(x: jax.Array, prm: Dict, cfg: ModelConfig,
                h0=None, constrain=lambda t, a: t) -> Tuple[jax.Array, Dict]:
    """Train/prefill pass. Returns (out [B,S,d], cache)."""
    din, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    p = cfg.ssm_head_dim
    z = x @ prm["in_proj"]
    zg, xbc, dt_raw = _split(z, cfg)
    xbc = _causal_conv(xbc, prm["conv_w"], prm["conv_b"])
    xs = xbc[..., :din].reshape(*x.shape[:2], h, p)
    bmat = xbc[..., din:din + n].astype(jnp.float32)
    cmat = xbc[..., din + n:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + prm["dt_bias"])
    # one resharding into the head-sharded layout the whole chunk scan
    # uses — otherwise GSPMD re-lays-out (all-to-all) EVERY chunk when
    # the residual stream is sequence-sharded (§Perf iteration 2)
    xs = constrain(xs, ("batch", None, "tensor", None))
    bmat = constrain(bmat, ("batch", None, None))
    cmat = constrain(cmat, ("batch", None, None))
    dt = constrain(dt, ("batch", None, "tensor"))
    # gate lives in the same head-sharded layout as y: the elementwise
    # gate/norm chain is then collective-free (§Perf iteration 3)
    zg = constrain(zg, ("batch", None, "tensor"))
    a = -jnp.exp(prm["a_log"])
    y, h_final = _ssd_chunks(xs, bmat, cmat, dt, a, cfg, h0)
    y = y + (prm["d_skip"][None, None, :, None]
             * xs.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(*x.shape[:2], din).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(zg), prm["norm"], cfg.norm_eps)
    y = constrain(y, ("batch", None, "tensor"))
    out = y @ prm["out_proj"]
    # decode resumes from the final state + the last W-1 *pre-conv* inputs
    raw_xbc = _split(z, cfg)[1]
    cache = dict(h=h_final,
                 conv=jax.lax.dynamic_slice_in_dim(
                     raw_xbc, z.shape[1] - (cfg.conv_width - 1),
                     cfg.conv_width - 1, axis=1))
    return out, cache


def ssm_decode_step(x: jax.Array, prm: Dict, cfg: ModelConfig,
                    cache: Dict) -> Tuple[jax.Array, Dict]:
    """One-token recurrent step. x [B,1,d]; cache {h [B,H,P,N],
    conv [B,W-1,C]}."""
    din, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    p = cfg.ssm_head_dim
    z = x @ prm["in_proj"]
    zg, xbc_new, dt_raw = _split(z, cfg)
    # conv over the stored tail + new sample
    window = jnp.concatenate([cache["conv"],
                              xbc_new.astype(cache["conv"].dtype)], axis=1)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                          prm["conv_w"].astype(jnp.float32))
    xbc = jax.nn.silu(conv_out + prm["conv_b"].astype(jnp.float32))[:, None]
    xs = xbc[..., :din].reshape(x.shape[0], h, p)
    bmat = xbc[..., din:din + n][:, 0]                      # [B,N]
    cmat = xbc[..., din + n:][:, 0]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + prm["dt_bias"])
    a = -jnp.exp(prm["a_log"])
    da = jnp.exp(dt * a)                                    # [B,H]
    h_new = (cache["h"] * da[:, :, None, None]
             + jnp.einsum("bh,bn,bhp->bhpn", dt, bmat,
                          xs.astype(jnp.float32)))
    y = jnp.einsum("bn,bhpn->bhp", cmat, h_new)
    y = y + prm["d_skip"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(x.shape[0], 1, din).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(zg), prm["norm"], cfg.norm_eps)
    out = y @ prm["out_proj"]
    new_conv = window[:, 1:]
    return out, dict(h=h_new, conv=new_conv)


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> Dict:
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state
    return dict(
        h=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                    jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype),
    )
