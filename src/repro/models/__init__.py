from . import attention, layers, moe, ssm  # noqa: F401
from .model import (init_params, params_shape, logical_axes, forward,
                    loss_fn, decode_step_fn, init_caches,
                    cache_logical_axes, input_specs)  # noqa: F401
