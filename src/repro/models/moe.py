"""Mixture-of-Experts FFN with sort-based capacity dispatch.

TPU-native dispatch: instead of the GShard [T, E, C] one-hot dispatch
tensor (O(T*E*C) memory — infeasible at 128 experts), tokens are routed by
argsort(expert_id) + rank-within-expert into a fixed [E, C, d] buffer,
expert GEMMs run as one batched einsum over the stacked expert weights,
and results scatter back weighted by router probabilities. Overflow
(rank >= capacity) drops tokens — standard capacity-factor semantics.

Sharding: the [E, C, d] buffer is constrained to the expert axis, so the
token->buffer scatter lowers to the EP all-to-all under pjit. An auxiliary
load-balance loss (Switch) and router z-loss are returned for training;
the SDE's CountMin expert-load synopsis consumes the same assignment
stream for monitoring.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from .layers import init_dense, init_mlp, mlp_forward

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:
    # jax <= 0.4 compat: experimental location, check_vma was check_rep
    from jax.experimental.shard_map import shard_map as _experimental_sm

    def _shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
        return _experimental_sm(f, mesh=mesh, in_specs=in_specs,
                                out_specs=out_specs, check_rep=check_vma)


def init_moe(key, cfg: ModelConfig) -> Dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = dict(
        router=init_dense(ks[0], (d, e)).astype(jnp.float32),
        wg=init_dense(ks[1], (e, d, f), in_axis=1),
        wu=init_dense(ks[2], (e, d, f), in_axis=1),
        wd=init_dense(ks[3], (e, f, d), in_axis=1),
    )
    if cfg.moe_dense_residual:
        p["dense"] = init_mlp(ks[4], d, cfg.d_ff_dense or cfg.d_ff)
    return p


def capacity_of(cfg: ModelConfig, n_tokens: int) -> int:
    """Per-shard expert capacity. Dispatch is shard-LOCAL (shard_map), so
    no mesh-divisibility constraint applies — keep the floor small: a
    64-floor made arctic's decode GEMMs 32x larger than the routed
    tokens (§Perf)."""
    cap = int(np.ceil(n_tokens * cfg.top_k * cfg.capacity_factor
                      / cfg.n_experts))
    return max(8, int(np.ceil(cap / 8)) * 8)


def _dispatch_plan(flat: jax.Array, router: jax.Array, e: int, k: int,
                   cap: int):
    """Local routing: top-k, rank-within-expert via argsort, capacity
    masking. Pure local compute — no collectives."""
    t = flat.shape[0]
    logits = flat.astype(jnp.float32) @ router                  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)                        # [T, k]
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

    e_flat = topi.reshape(t * k)
    w_flat = topw.reshape(t * k)
    tok_idx = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(e_flat, stable=True)
    sorted_e = e_flat[order]
    is_start = jnp.concatenate(
        [jnp.ones((1,), jnp.int32),
         (sorted_e[1:] != sorted_e[:-1]).astype(jnp.int32)])
    run_id = jnp.cumsum(is_start) - 1
    start_pos = jnp.where(is_start == 1, jnp.arange(t * k), 0)
    start_of_run = jax.ops.segment_max(start_pos, run_id,
                                       num_segments=t * k)
    rank_sorted = jnp.arange(t * k) - start_of_run[run_id]
    rank = jnp.zeros((t * k,), jnp.int32).at[order].set(rank_sorted)

    keep = rank < cap
    dest_e = jnp.where(keep, e_flat, 0)
    # dropped assignments go OUT OF BOUNDS (mode="drop" discards them);
    # routing them to slot (0,0) would zero-clobber a real token's slot
    dest_c = jnp.where(keep, rank, cap)
    return dict(logits=logits, probs=probs, topi=topi, keep=keep,
                dest_e=dest_e, dest_c=dest_c, tok_idx=tok_idx,
                w_flat=w_flat)


def _expert_compute(flat, plan, p_wg, p_wu, p_wd, e, cap):
    """Scatter -> batched expert GEMMs -> gather/combine. All LOCAL."""
    t, d = flat.shape
    buf = jnp.zeros((e, cap, d), flat.dtype)
    vals = jnp.where(plan["keep"][:, None], flat[plan["tok_idx"]], 0)
    # non-keep entries carry dest_c == cap (out of bounds) -> dropped
    buf = buf.at[plan["dest_e"], plan["dest_c"]].set(vals, mode="drop")
    g = jnp.einsum("ecd,edf->ecf", buf, p_wg)
    u = jnp.einsum("ecd,edf->ecf", buf, p_wu)
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, p_wd)
    back = out_buf[plan["dest_e"], plan["dest_c"]]
    back = jnp.where(plan["keep"][:, None],
                     back * plan["w_flat"][:, None].astype(back.dtype),
                     0).astype(flat.dtype)
    return jnp.zeros((t, d), flat.dtype).at[plan["tok_idx"]].add(back)


def _aux_losses(plan, e):
    load = jnp.mean(jax.nn.one_hot(plan["topi"][:, 0], e,
                                   dtype=jnp.float32), 0)
    imp = jnp.mean(plan["probs"], axis=0)
    return dict(
        lb_loss=e * jnp.sum(load * imp),
        z_loss=jnp.mean(jax.nn.logsumexp(plan["logits"], axis=-1) ** 2),
        expert_load=jax.lax.stop_gradient(load),
    )


def moe_ffn(x: jax.Array, p: Dict, cfg: ModelConfig,
            constrain=lambda t, axes: t) -> Tuple[jax.Array, Dict]:
    """Reference (single-mesh / smoke-test) path: x [B,S,d] ->
    (out [B,S,d], aux). Distributed runs use moe_ffn_shardmap — same
    math, shard-local dispatch."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    cap = capacity_of(cfg, t)
    flat = x.reshape(t, d)
    plan = _dispatch_plan(flat, p["router"], e, k, cap)
    out = _expert_compute(flat, plan, p["wg"], p["wu"], p["wd"], e, cap)
    if p.get("dense") is not None:
        out = out + mlp_forward(flat, p["dense"], cfg.mlp_act)
    return out.reshape(b, s, d), _aux_losses(plan, e)


def moe_ffn_shardmap(x: jax.Array, p: Dict, cfg: ModelConfig, mesh,
                     rules, mode: str = "train") -> Tuple[jax.Array, Dict]:
    """Distributed MoE (§Perf iteration 1 — see EXPERIMENTS.md).

    The pjit scatter/gather dispatch lowers to catastrophic all-reduces
    ([2M, 4096] f32 per layer). This path instead runs dispatch/combine
    SHARD-LOCALLY under shard_map:

      tokens   sharded over ("pod","data")       — local top-k + scatter
      experts  batched (E unsharded)             — works for E=8/16/128
      d_ff     sharded over "model"              — Megatron-style TP
      d_model  weights sharded over "data" (FSDP), all-gathered on use

    Collectives per layer: weight all-gather (FSDP) + ONE bf16 psum of
    [T_loc, d] for the f-contraction. No all-to-all, no giant gathers.
    Per-shard capacity doubles as shard-level load balancing.
    """
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_batch = max(_axis_size(mesh, batch_axes), 1)
    if b % n_batch != 0:
        # tiny/odd batches (long_500k b=1): replicated reference path
        return moe_ffn(x, p, cfg)
    tp = "model"
    t_loc = (b // n_batch) * s
    cap = capacity_of(cfg, t_loc)

    if mode == "train":
        fsdp = getattr(rules, "fsdp", "data") is not None
    else:
        # serving: keep expert weights resident when their TP shard fits
        fsdp = cfg.expert_param_count() * 2 / mesh.shape.get(
            "model", 1) > 12e9

    def local_fn(x_loc, router, wg, wu, wd, dense):
        bl, sl, _ = x_loc.shape
        flat = x_loc.reshape(bl * sl, d)
        if fsdp:
            # FSDP: gather the d_model shard of the weights on use
            wg = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, "data", axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, "data", axis=2, tiled=True)
        plan = _dispatch_plan(flat, router, e, k, cap)
        out = _expert_compute(flat, plan, wg, wu, wd, e, cap)
        if dense is not None:
            dg, du, dd = dense["wg"], dense["wu"], dense["wd"]
            if fsdp:
                dg = jax.lax.all_gather(dg, "data", axis=0, tiled=True)
                du = jax.lax.all_gather(du, "data", axis=0, tiled=True)
                dd = jax.lax.all_gather(dd, "data", axis=1, tiled=True)
            out = out + mlp_forward(flat, dict(wg=dg, wu=du, wd=dd),
                                    cfg.mlp_act)
        # f-contraction partial sums -> one psum over the TP axis
        out = jax.lax.psum(out, tp)
        aux = _aux_losses(plan, e)
        aux = jax.tree.map(lambda v: jax.lax.pmean(v, batch_axes), aux)
        return out.reshape(bl, sl, d), aux

    batch_spec = batch_axes if batch_axes else None
    dax = "data" if fsdp else None
    dense_spec = (dict(wg=P(dax, tp), wu=P(dax, tp), wd=P(tp, dax))
                  if p.get("dense") is not None else None)
    in_specs = (
        P(batch_spec, None, None),                  # x: tokens over batch
        P(None, None),                              # router: replicated
        P(None, dax, tp),                           # wg [E, d, f]
        P(None, dax, tp),                           # wu
        P(None, tp, dax),                           # wd [E, f, d]
        dense_spec,                                 # arctic residual
    )
    out_specs = (P(batch_spec, None, None),
                 dict(lb_loss=P(), z_loss=P(), expert_load=P(None)))
    fn = _shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                    out_specs=out_specs, check_vma=False)
    out, aux = fn(x, p["router"], p["wg"], p["wu"], p["wd"],
                  p.get("dense"))
    return out, aux


def _axis_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def moe_ffn_ep_decode(x: jax.Array, p: Dict, cfg: ModelConfig, mesh,
                      rules) -> Tuple[jax.Array, Dict]:
    """Expert-parallel DECODE path (§Perf iteration 13).

    For the MoE giants (arctic 937 GB of expert weights), the serving
    bottleneck is re-gathering FSDP-sharded weights every token. Here the
    weights stay RESIDENT: experts sharded over "data" (E/16 per shard)
    and d_ff over "model" (f/16) — 3.7 GB/device for arctic. The decode
    batch is tiny (128 tokens), so instead of an all-to-all we simply
    all-gather the tokens (~2 MB), let every shard run ITS experts on the
    tokens routed to them, and psum the partial outputs over both axes.

    Requires E % data_size == 0; caller falls back otherwise.
    """
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_data = mesh.shape["data"]
    e_local = e // n_data
    t_glob = b * s
    cap = capacity_of(cfg, t_glob)

    def _my_batch_start(bl):
        pos = jnp.int32(0)
        mul = 1
        for a in reversed(batch_axes):
            pos = pos + jax.lax.axis_index(a) * mul
            mul = mul * mesh.shape[a]
        return pos * bl

    def local_fn(x_loc, router, wg, wu, wd, dense):
        # gather ALL tokens (tiny at decode batch sizes: ~2 MB)
        xg = jax.lax.all_gather(x_loc, batch_axes, axis=0, tiled=True)
        flat = xg.reshape(t_glob, d)
        plan = _dispatch_plan(flat, router, e, k, cap)
        # keep only assignments owned by MY expert shard
        shard = jax.lax.axis_index("data")
        mine = plan["dest_e"] // e_local == shard
        plan = dict(plan, keep=plan["keep"] & mine,
                    dest_e=jnp.where(mine, plan["dest_e"] % e_local, 0),
                    dest_c=jnp.where(mine, plan["dest_c"], cap))
        out = _expert_compute(flat, plan, wg, wu, wd, e_local, cap)
        if dense is not None:
            # dense residual: d_ff over model; count it on data-shard 0
            dres = mlp_forward(flat, dense, cfg.mlp_act)
            out = out + jnp.where(shard == 0, dres, 0).astype(out.dtype)
        # sum expert partials (data axis) AND f-contraction (model axis)
        out = jax.lax.psum(out, ("data", "model"))
        out = out.reshape(xg.shape)
        idx = _my_batch_start(x_loc.shape[0])
        out = jax.lax.dynamic_slice_in_dim(out, idx, x_loc.shape[0], 0)
        aux = dict(lb_loss=jnp.zeros((), jnp.float32),
                   z_loss=jnp.zeros((), jnp.float32),
                   expert_load=jnp.zeros((e,), jnp.float32))
        return out, aux

    batch_spec = batch_axes if batch_axes else None
    dense_spec = (dict(wg=P(None, "model"), wu=P(None, "model"),
                       wd=P("model", None))
                  if p.get("dense") is not None else None)
    in_specs = (
        P(batch_spec, None, None),
        P(None, None),
        P("data", None, "model"),                   # wg [E, d, f] resident
        P("data", None, "model"),
        P("data", "model", None),                   # wd [E, f, d]
        dense_spec,
    )
    out_specs = (P(batch_spec, None, None),
                 dict(lb_loss=P(), z_loss=P(), expert_load=P(None)))
    fn = _shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                    out_specs=out_specs, check_vma=False)
    out, aux = fn(x, p["router"], p["wg"], p["wu"], p["wd"],
                  p.get("dense"))
    return out, aux
