"""GQA attention: train/prefill (causal, optionally Q-chunked for long
sequences) and single-token decode against a KV cache.

Decode supports a seq-sharded cache: softmax over the (sharded) cache axis
is expressed as global ops under pjit, so the partitioner emits the
flash-decoding-style partial-softmax combine across chips.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from .layers import apply_rope, init_dense

import os

_NEG = -1e30
# Q-chunk long sequences (peak-memory control); env override for perf
# experiments (§Perf)
_CHUNK_THRESHOLD = int(os.environ.get("REPRO_ATTN_CHUNK_THRESHOLD", 4096))
_Q_CHUNK = int(os.environ.get("REPRO_ATTN_Q_CHUNK", 1024))
_EXPAND_KV = os.environ.get("REPRO_ATTN_EXPAND_KV", "1") == "1"


def init_attn(key, cfg: ModelConfig) -> Dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = dict(
        wq=init_dense(ks[0], (d, h, hd)),
        wk=init_dense(ks[1], (d, kv, hd)),
        wv=init_dense(ks[2], (d, kv, hd)),
        wo=init_dense(ks[3], (h, hd, d), in_axis=(0, 1)),
    )
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), jnp.bfloat16)
        p["bk"] = jnp.zeros((kv, hd), jnp.bfloat16)
        p["bv"] = jnp.zeros((kv, hd), jnp.bfloat16)
    return p


def _qkv(x, p, cfg: ModelConfig, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _expand_kv(k, cfg: ModelConfig, constrain):
    """Broadcast KV heads to the full H so every attention tensor carries
    an H axis shardable by TP — the (kv, g) reshape would break head
    sharding whenever kv < the model-axis size (§Perf iteration 3)."""
    if not _EXPAND_KV:
        return k
    g = cfg.n_heads // cfg.n_kv_heads
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
    return constrain(k, ("batch", None, "tensor", None))


def _gqa_scores(q, k, cfg: ModelConfig):
    """q [B,Sq,H,hd], k [B,Sk,KV|H,hd] -> scores.

    Expanded path: [B,H,Sq,Sk]. Grouped path: [B,KV,G,Sq,Sk]."""
    if k.shape[2] == cfg.n_heads:
        return jnp.einsum("bqhd,bshd->bhqs", q, k) / np.sqrt(cfg.hd)
    g = cfg.n_heads // cfg.n_kv_heads
    b, sq = q.shape[0], q.shape[1]
    qg = q.reshape(b, sq, cfg.n_kv_heads, g, cfg.hd)
    return jnp.einsum("bqkgh,bskh->bkgqs", qg, k) / np.sqrt(cfg.hd)


def _gqa_out(probs, v, cfg: ModelConfig):
    if probs.ndim == 4:
        return jnp.einsum("bhqs,bshd->bqhd", probs, v)
    b = probs.shape[0]
    sq = probs.shape[3]
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(b, sq, cfg.n_heads, cfg.hd)


def attention(x: jax.Array, p: Dict, cfg: ModelConfig,
              positions: jax.Array,
              constrain=lambda t, a: t) -> Tuple[jax.Array, Dict]:
    """Causal self-attention; returns (out [B,S,d], cache {k, v})."""
    b, s, _ = x.shape
    q, k, v = _qkv(x, p, cfg, positions)
    cache = dict(k=k, v=v)
    if cfg.attn_layout == "head":
        # head-sharded layout: gather seq once, expand kv to H so every
        # attention tensor carries a TP-shardable H axis (§Perf iter 3)
        q = constrain(q, ("batch", None, "tensor", None))
        k = _expand_kv(k, cfg, constrain)
        v = _expand_kv(v, cfg, constrain)
    # "seq" layout: leave q/k/v in the residual stream's (SP) layout and
    # let GSPMD schedule a ring/permute attention — measured better for
    # the big dense archs (§Perf qwen2-72b iterations)
    if s > _CHUNK_THRESHOLD:
        out = _chunked_causal(q, k, v, cfg)
    else:
        scores = _gqa_scores(q, k, cfg).astype(jnp.float32)
        mask = jnp.tril(jnp.ones((s, s), bool))
        mask = mask[(None,) * (scores.ndim - 2)]
        scores = jnp.where(mask, scores, _NEG)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = _gqa_out(probs, v, cfg)
    return jnp.einsum("bqhk,hkd->bqd", out, p["wo"]), cache


def _chunked_causal(q, k, v, cfg: ModelConfig):
    """Scan over Q chunks (flash-style peak-memory control for 32k+)."""
    b, s, h, hd = q.shape
    nc = s // _Q_CHUNK
    qc = q.reshape(b, nc, _Q_CHUNK, h, hd).transpose(1, 0, 2, 3, 4)

    def chunk(ci, qi):
        # keys up to the end of this chunk matter; causal-mask the tail
        scores = _gqa_scores(qi, k, cfg).astype(jnp.float32)
        kpos = jnp.arange(s)[None, :]
        qpos = ci * _Q_CHUNK + jnp.arange(_Q_CHUNK)[:, None]
        causal = (kpos <= qpos)[(None,) * (scores.ndim - 2)]
        scores = jnp.where(causal, scores, _NEG)
        probs = jax.nn.softmax(scores, axis=-1).astype(qi.dtype)
        return _gqa_out(probs, v, cfg)

    out = jax.lax.map(lambda args: chunk(*args),
                      (jnp.arange(nc), qc))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)


def decode_step(x: jax.Array, p: Dict, cfg: ModelConfig, cache: Dict,
                pos: jax.Array) -> Tuple[jax.Array, Dict]:
    """x [B,1,d]; cache k/v [B,S_max,KV,hd]; pos [] current position.

    Writes the new kv at `pos`, attends over cache[<= pos].
    """
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = _qkv(x, p, cfg, positions)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"],
                                            k_new.astype(cache["k"].dtype),
                                            pos, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"],
                                            v_new.astype(cache["v"].dtype),
                                            pos, axis=1)
    # decode keeps the grouped-KV form unless the arch runs head layout:
    # expanding kv would materialize a G-times copy of the (huge) cache
    g = cfg.n_heads // cfg.n_kv_heads
    expand = g > 1 and _EXPAND_KV and cfg.attn_layout == "head"
    k_exp = jnp.repeat(k, g, axis=2) if expand else k
    v_exp = jnp.repeat(v, g, axis=2) if expand else v
    scores = _gqa_scores(q, k_exp, cfg).astype(jnp.float32)
    s_max = k.shape[1]
    valid = jnp.arange(s_max) <= pos
    scores = jnp.where(valid[(None,) * (scores.ndim - 2)], scores, _NEG)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = _gqa_out(probs, v_exp, cfg)
    return jnp.einsum("bqhk,hkd->bqd", out, p["wo"]), dict(k=k, v=v)


def init_cache(cfg: ModelConfig, batch: int, s_max: int,
               dtype=jnp.bfloat16) -> Dict:
    shape = (batch, s_max, cfg.n_kv_heads, cfg.hd)
    return dict(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))
