"""Shared layers: norms, rotary embeddings, MLPs, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale)).astype(dt)


def init_dense(key, shape, in_axis: int = 0) -> jax.Array:
    fan_in = shape[in_axis] if isinstance(in_axis, int) else int(
        np.prod([shape[a] for a in in_axis]))
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(jnp.bfloat16)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [..., S, H, hd]; positions [..., S]."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))
    ang = positions[..., None].astype(jnp.float32) * freqs        # [...,S,hd/2]
    cos = jnp.cos(ang)[..., None, :]                              # [...,S,1,hd/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def mlp_forward(x: jax.Array, p: dict, act: str) -> jax.Array:
    """Gated MLP: SwiGLU (silu) or GeGLU (gelu)."""
    g = x @ p["wg"]
    u = x @ p["wu"]
    gate = jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)
    return (gate * u) @ p["wd"]


def init_mlp(key, d_model: int, d_ff: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return dict(
        wg=init_dense(k1, (d_model, d_ff)),
        wu=init_dense(k2, (d_model, d_ff)),
        wd=init_dense(k3, (d_ff, d_model)),
    )
