"""Vectorized 32-bit hash families used by every sketch in the SDE.

TPU adaptation note: all hashing is expressed as elementwise uint32
arithmetic (multiply-shift + murmur3 finalizer mixing) so a batch of T
updates hashes in one fused vector op -- no host loops, no 64-bit ops
(works with jax_enable_x64 disabled).

Guarantees: ``bucket_hash`` is 2-universal (multiply-shift, Dietzfelbinger
et al.); ``sign_hash`` uses two independent mixed draws which empirically
behaves 4-wise-independent-like for AMS/count-sketch purposes (validated
statistically in tests against exact second moments).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

_U32 = jnp.uint32

# murmur3 32-bit finalizer constants
_C1 = np.uint32(0x85EBCA6B)
_C2 = np.uint32(0xC2B2AE35)
_GOLDEN = np.uint32(0x9E3779B9)


def mix32(x: jax.Array) -> jax.Array:
    """murmur3 fmix32: a high-quality 32-bit bijective mixer."""
    x = x.astype(_U32)
    x = x ^ (x >> 16)
    x = x * _C1
    x = x ^ (x >> 13)
    x = x * _C2
    x = x ^ (x >> 16)
    return x


def hash_u32(x: jax.Array, seed) -> jax.Array:
    """Seeded full-width 32-bit hash of integer identities."""
    seed = jnp.asarray(seed, dtype=_U32)
    return mix32(x.astype(_U32) ^ (seed * _GOLDEN + jnp.uint32(1)))


def row_seeds(base_seed: int, rows: int) -> np.ndarray:
    """Deterministic per-row seeds for a d-row sketch (host-side constant)."""
    rng = np.random.RandomState(base_seed)
    return rng.randint(1, 2**31 - 1, size=(rows,), dtype=np.int64).astype(np.uint32)


def bucket_hash(x: jax.Array, seeds: jax.Array, log2_width: int) -> jax.Array:
    """Map items ``x[T]`` to buckets ``[T, d]`` in ``[0, 2**log2_width)``.

    Multiply-shift over the mixed identity: take the top ``log2_width`` bits
    of ``a * mix(x ^ seed)`` which is 2-universal for odd ``a``.
    """
    h = hash_u32(x[..., None], seeds[None, :])          # [T, d]
    a = (seeds * jnp.uint32(2) + jnp.uint32(1))          # odd multipliers
    v = h * a[None, :]
    return (v >> np.uint32(32 - log2_width)).astype(jnp.int32)


def sign_hash(x: jax.Array, seeds: jax.Array) -> jax.Array:
    """±1 signs ``[T, d]`` for AMS/count-sketch style updates."""
    h = hash_u32(x[..., None], seeds[None, :] ^ jnp.uint32(0xA5A5A5A5))
    bit = (h >> np.uint32(31)).astype(jnp.float32)
    return 1.0 - 2.0 * bit


def uniform01(x: jax.Array, seed) -> jax.Array:
    """Deterministic per-item uniform(0,1) floats from identities."""
    h = hash_u32(x, seed)
    return h.astype(jnp.float32) * np.float32(1.0 / 4294967296.0)


def clz32(x: jax.Array) -> jax.Array:
    """Count leading zeros of uint32 (32 for x == 0)."""
    return jax.lax.clz(x.astype(_U32)).astype(jnp.int32)


def ctz32(x: jax.Array) -> jax.Array:
    """Count trailing zeros of uint32 (32 for x == 0)."""
    # isolate lowest set bit, then clz gives 31 - position
    low = x & (~x + jnp.uint32(1))
    return jnp.where(x == 0, 32, 31 - clz32(low)).astype(jnp.int32)
