"""Federated scalability: synopsis mergeability -> jax.lax collectives.

The paper's yellow/purple paths (geo-dispersed sites exchanging synopses,
a responsible site synthesizing the global estimate) map onto mesh-axis
collectives:

  merge_mode == "sum"   (CountMin, AMS, RHP)       -> lax.psum
  merge_mode == "max"   (HLL, Bloom, FM bitmaps)   -> lax.pmax
  merge_mode == "gather"(samples, quantiles, ...)  -> all_gather + tree merge
  merge_mode == "fresh" (DFT replicas)             -> exchanged, not reduced

On a TPU fleet the `pod` axis plays the role of the WAN between clusters
(DCN links) and the `data` axis the intra-cluster workers; communication
cost of a federated estimate is exactly the collective's operand bytes —
which is what benchmarks/fig5 reports against the ship-the-raw-stream
baseline.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from .synopsis import Synopsis


def fresh_count(kind: Synopsis, state: Any) -> jax.Array:
    """Freshness key of a ``merge_mode == "fresh"`` replica: the number of
    ticks it has absorbed. Kinds may override via a ``fresh_count(state)``
    method; the default reads ``state["count"]`` (DFT)."""
    if hasattr(kind, "fresh_count"):
        return kind.fresh_count(state)
    return state["count"]


def merge_over_axis(kind: Synopsis, state: Any, axis_name: str) -> Any:
    """Global merge of per-shard synopsis states along a mesh axis.

    Must be called inside shard_map/pmap/vmap context where `axis_name`
    exists. Every shard returns the SAME merged state (psum/pmax results
    are replicated by construction; the gather and fresh branches compute
    an identical reduction on every shard), and the result is
    byte-identical to the host-side ``merge_reduce`` fold over the same
    shards in axis order.
    """
    mode = getattr(kind, "merge_mode", "gather")
    if mode == "sum":
        return jax.tree.map(lambda x: lax.psum(x, axis_name), state)
    if mode == "max":
        return jax.tree.map(lambda x: lax.pmax(x, axis_name), state)
    if mode == "fresh":
        # keep-max-count replica selection: replicas are exchanged, not
        # reduced. Only the SCALAR tick counts are all-gathered; the
        # winning replica is then broadcast with one state-sized masked
        # psum. Ties keep the lowest site index — the explicit selection
        # rule ``merge_reduce`` applies to fresh stacks (the
        # keep-strictly-fresher pairwise ``merge`` is not associative on
        # ties, so N-way fresh folds select, they don't fold).
        counts = lax.all_gather(fresh_count(kind, state), axis_name)
        winner = jnp.argmax(counts)          # first max on ties
        mine = lax.axis_index(axis_name) == winner

        def broadcast_winner(x):
            # float leaves are summed as their integer BIT PATTERNS
            # (losers contribute 0), because a float psum is not
            # byte-preserving for the winner: XLA seeds add-reductions
            # with +0.0, which flips the sign of any -0.0 slot in the
            # winning replica and breaks byte-identity with the host
            # fold. Integer adds against zero are exact bit-wise.
            if jnp.issubdtype(x.dtype, jnp.floating):
                bits_dtype = jnp.uint16 if x.dtype.itemsize == 2 \
                    else jnp.uint32
                bits = lax.bitcast_convert_type(x, bits_dtype)
                picked = lax.psum(
                    jnp.where(mine, bits, jnp.zeros_like(bits)), axis_name)
                return lax.bitcast_convert_type(picked, x.dtype)
            return lax.psum(jnp.where(mine, x, jnp.zeros_like(x)),
                            axis_name)

        return jax.tree.map(broadcast_winner, state)
    # generic: all-gather shards then fold with the kind's merge. The
    # fold is merge_reduce — the SAME pairwise tree the host-side
    # responsible-site path runs — so collective and host merges are
    # byte-identical even for order-sensitive merges (samples, quantile
    # summaries). The [N] leading axis of the gathered stack is static,
    # so the whole fold inlines into the calling program.
    gathered = jax.tree.map(
        functools.partial(lax.all_gather, axis_name=axis_name), state)
    return merge_reduce(kind, gathered)


def estimate_over_axis(kind: Synopsis, state: Any, axis_name: str,
                       *args: Any) -> Any:
    """Federated estimate as a real collective: merge the per-site partial
    states over ``axis_name`` (psum/pmax/all_gather — see
    ``merge_over_axis``) and run the kind's estimate on the merged state,
    all inside the calling shard_map/pmap program. Every shard of the
    axis computes the identical answer, so the responsible site reads its
    local copy without another hop."""
    return kind.estimate(merge_over_axis(kind, state, axis_name), *args)


def merge_rows(kind: Synopsis, stacked_a: Any, rows_a: jax.Array,
               stacked_b: Any, rows_b: jax.Array) -> Any:
    """Merge selected rows of stack B into selected rows of stack A in one
    vectorized dispatch (elastic scale-down absorbs a whole engine's
    synopses per kind, not one Python-loop merge per synopsis).

    ``rows_a[i]`` receives merge(stacked_a[rows_a[i]], stacked_b[rows_b[i]]).
    Rows of A must be distinct (each synopsis id owns one row).
    """
    sub_a = jax.tree.map(lambda x: x[rows_a], stacked_a)
    sub_b = jax.tree.map(lambda x: x[rows_b], stacked_b)
    merged = jax.vmap(kind.merge)(sub_a, sub_b)
    return jax.tree.map(
        lambda x, m: x.at[rows_a].set(m), stacked_a, merged)


def stack_states(states: list[Any]) -> Any:
    """Stack per-site partial states into one [S, ...] pytree so the
    responsible-site merge runs as a single jitted program."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


def merge_reduce(kind: Synopsis, stacked: Any) -> Any:
    """N-way merge (responsible-site synthesis, Case 3): reduce a [S, ...]
    stack of partial states to one merged state with vmapped pairwise
    merges — ceil(log2 S) merge steps instead of S - 1 sequential ones,
    all inside the calling program (jit-friendly: S is a static shape).
    Mergeability makes any reduction order valid.

    ``merge_mode == "fresh"`` stacks are SELECTED, not folded: keep the
    replica with the max count, ties to the lowest row. The pairwise
    keep-strictly-fresher ``merge`` is not associative on ties (the
    bracket position, not the row order, would pick the winner), so an
    explicit argmax keeps this path, the sequential fold and
    ``merge_over_axis`` byte-identical."""
    if getattr(kind, "merge_mode", "gather") == "fresh":
        winner = jnp.argmax(fresh_count(kind, stacked))
        return jax.tree.map(lambda x: x[winner], stacked)
    n = jax.tree.leaves(stacked)[0].shape[0]
    while n > 1:
        half = n // 2
        lo = jax.tree.map(lambda x: x[:half], stacked)
        hi = jax.tree.map(lambda x: x[half:2 * half], stacked)
        merged = jax.vmap(kind.merge)(lo, hi)
        if n % 2:
            tail = jax.tree.map(lambda x: x[2 * half:], stacked)
            merged = jax.tree.map(
                lambda m, t: jnp.concatenate([m, t]), merged, tail)
        stacked = merged
        n = half + (n % 2)
    return jax.tree.map(lambda x: x[0], stacked)


def communication_bytes(kind: Synopsis, state: Any) -> int:
    """Bytes a site ships to the responsible site for one federated
    estimate = the synopsis state size (paper: 'only small bitmaps')."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(state))


def collective_operand_bytes(kind: Synopsis, state: Any,
                             n_sites: int) -> int:
    """Bytes that cross the site axis for ONE federated estimate on the
    collective path (fig 5d). ``sum``/``max`` merges combine in-network
    (the psum/pmax reduction tree adds partials hop by hop), so the
    responsible site receives one state-sized operand regardless of the
    number of sites. ``fresh`` ships the scalar tick counts plus one
    state-sized masked psum. ``gather`` has no in-network combine: every
    site's state lands at the merge point — the same bytes the host-merge
    path ships. Never exceeds ``n_sites *`` the per-site
    ``communication_bytes`` of the host-merge path."""
    b = communication_bytes(kind, state)
    mode = getattr(kind, "merge_mode", "gather")
    if mode in ("sum", "max"):
        return b
    if mode == "fresh":
        # the count gather rides along; clamped so degenerate cases
        # (one site, tiny states) never exceed the host-merge bound
        count = fresh_count(kind, state)
        return min(b + n_sites * count.dtype.itemsize, n_sites * b)
    return n_sites * b
