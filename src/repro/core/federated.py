"""Federated scalability: synopsis mergeability -> jax.lax collectives.

The paper's yellow/purple paths (geo-dispersed sites exchanging synopses,
a responsible site synthesizing the global estimate) map onto mesh-axis
collectives:

  merge_mode == "sum"   (CountMin, AMS, RHP)       -> lax.psum
  merge_mode == "max"   (HLL, Bloom, FM bitmaps)   -> lax.pmax
  merge_mode == "gather"(samples, quantiles, ...)  -> all_gather + tree merge
  merge_mode == "fresh" (DFT replicas)             -> exchanged, not reduced

On a TPU fleet the `pod` axis plays the role of the WAN between clusters
(DCN links) and the `data` axis the intra-cluster workers; communication
cost of a federated estimate is exactly the collective's operand bytes —
which is what benchmarks/fig5 reports against the ship-the-raw-stream
baseline.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from .synopsis import Synopsis


def merge_over_axis(kind: Synopsis, state: Any, axis_name: str) -> Any:
    """Global merge of per-shard synopsis states along a mesh axis.

    Must be called inside shard_map/pmap context where `axis_name` exists.
    """
    mode = getattr(kind, "merge_mode", "gather")
    if mode == "sum":
        return jax.tree.map(lambda x: lax.psum(x, axis_name), state)
    if mode == "max":
        return jax.tree.map(lambda x: lax.pmax(x, axis_name), state)
    if mode == "fresh":
        # keep the replica with the max count: gather then reduce via merge
        pass
    # generic: all-gather shards then fold with the kind's merge
    gathered = jax.tree.map(
        functools.partial(lax.all_gather, axis_name=axis_name), state)
    n = lax.psum(1, axis_name)

    def fold(acc, i):
        shard = jax.tree.map(lambda x: x[i], gathered)
        return kind.merge(acc, shard), None

    first = jax.tree.map(lambda x: x[0], gathered)
    if isinstance(n, int):  # static axis size
        acc = first
        for i in range(1, n):
            acc = kind.merge(acc, jax.tree.map(lambda x: x[i], gathered))
        return acc
    acc, _ = jax.lax.scan(fold, first, jnp.arange(1, n))
    return acc


def merge_rows(kind: Synopsis, stacked_a: Any, rows_a: jax.Array,
               stacked_b: Any, rows_b: jax.Array) -> Any:
    """Merge selected rows of stack B into selected rows of stack A in one
    vectorized dispatch (elastic scale-down absorbs a whole engine's
    synopses per kind, not one Python-loop merge per synopsis).

    ``rows_a[i]`` receives merge(stacked_a[rows_a[i]], stacked_b[rows_b[i]]).
    Rows of A must be distinct (each synopsis id owns one row).
    """
    sub_a = jax.tree.map(lambda x: x[rows_a], stacked_a)
    sub_b = jax.tree.map(lambda x: x[rows_b], stacked_b)
    merged = jax.vmap(kind.merge)(sub_a, sub_b)
    return jax.tree.map(
        lambda x, m: x.at[rows_a].set(m), stacked_a, merged)


def stack_states(states: list[Any]) -> Any:
    """Stack per-site partial states into one [S, ...] pytree so the
    responsible-site merge runs as a single jitted program."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


def merge_reduce(kind: Synopsis, stacked: Any) -> Any:
    """N-way merge (responsible-site synthesis, Case 3): reduce a [S, ...]
    stack of partial states to one merged state with vmapped pairwise
    merges — ceil(log2 S) merge steps instead of S - 1 sequential ones,
    all inside the calling program (jit-friendly: S is a static shape).
    Mergeability makes any reduction order valid."""
    n = jax.tree.leaves(stacked)[0].shape[0]
    while n > 1:
        half = n // 2
        lo = jax.tree.map(lambda x: x[:half], stacked)
        hi = jax.tree.map(lambda x: x[half:2 * half], stacked)
        merged = jax.vmap(kind.merge)(lo, hi)
        if n % 2:
            tail = jax.tree.map(lambda x: x[2 * half:], stacked)
            merged = jax.tree.map(
                lambda m, t: jnp.concatenate([m, t]), merged, tail)
        stacked = merged
        n = half + (n % 2)
    return jax.tree.map(lambda x: x[0], stacked)


def communication_bytes(kind: Synopsis, state: Any) -> int:
    """Bytes a site ships to the responsible site for one federated
    estimate = the synopsis state size (paper: 'only small bitmaps')."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(state))
