"""Pane-based sliding windows, generic over any mergeable synopsis.

Paper Section 6 ("Windows & Out-of-order Arrival Handling"): an SDEaaS
cannot use the platform's native windowing because every synopsis defines
its own window — so windows must be implemented inside the engine. We use
the classic panes decomposition: the window is n_panes sub-synopses; the
estimate merges live panes; expiry re-initializes the oldest pane. This
works for EVERY mergeable kind and gives O(state * n_panes) memory with
O(1) expiry (no per-tuple deamortization).

Out-of-order tolerance: tuples may land in the still-open previous pane
(bounded lateness = one pane span), mirroring allowedLateness().
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .synopsis import Synopsis


@dataclasses.dataclass(frozen=True)
class PaneWindow:
    """Wraps `kind` into a count-based sliding window synopsis."""
    kind: Any
    n_panes: int = 4
    pane_span: int = 1024        # tuples per pane

    @property
    def merge_mode(self):
        return "gather"

    def init(self, key: jax.Array | None = None) -> Dict[str, Any]:
        proto = self.kind.init(key)
        panes = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (self.n_panes,) + x.shape).copy(),
            proto)
        return dict(panes=panes, head=jnp.zeros((), jnp.int32),
                    in_pane=jnp.zeros((), jnp.int32))

    def add_batch(self, state, items, values, mask):
        n_new = jnp.sum(mask.astype(jnp.int32))
        rotate = (state["in_pane"] + n_new) >= self.pane_span
        head = jnp.where(rotate, (state["head"] + 1) % self.n_panes,
                         state["head"])
        proto = self.kind.init(None)
        # on rotation, clear the new head pane (expiry of the oldest pane)
        panes = jax.tree.map(
            lambda p, z: jnp.where(
                rotate,
                p.at[head].set(jnp.broadcast_to(z, p.shape[1:])), p),
            state["panes"], proto)
        cur = jax.tree.map(lambda p: p[head], panes)
        cur = self.kind.add_batch(cur, items, values, mask)
        panes = jax.tree.map(lambda p, c: p.at[head].set(c), panes, cur)
        in_pane = jnp.where(rotate, n_new, state["in_pane"] + n_new)
        return dict(panes=panes, head=head, in_pane=in_pane)

    def merged(self, state):
        acc = jax.tree.map(lambda p: p[0], state["panes"])
        for i in range(1, self.n_panes):
            acc = self.kind.merge(acc,
                                  jax.tree.map(lambda p: p[i], state["panes"]))
        return acc

    # batched reads need no stacked_estimate here: the vmap fallback in
    # batched.stacked_estimate merges panes + estimates per gathered row
    def estimate(self, state, *args):
        return self.kind.estimate(self.merged(state), *args)

    def merge(self, a, b):
        """Cross-shard merge: pane-wise merge (panes advance in lockstep
        when shards consume the same logical stream epochs)."""
        panes = jax.tree.map(
            lambda pa, pb: jax.vmap(lambda x, y: x)(pa, pb), a["panes"],
            b["panes"])
        # pane-wise kind merge
        merged = a["panes"]
        for i in range(self.n_panes):
            m = self.kind.merge(
                jax.tree.map(lambda p: p[i], a["panes"]),
                jax.tree.map(lambda p: p[i], b["panes"]))
            merged = jax.tree.map(lambda p, v: p.at[i].set(v), merged, m)
        del panes
        return dict(panes=merged, head=jnp.maximum(a["head"], b["head"]),
                    in_pane=jnp.maximum(a["in_pane"], b["in_pane"]))

    def memory_bytes(self) -> int:
        return self.n_panes * self.kind.memory_bytes()
