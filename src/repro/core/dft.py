"""Sliding-window DFT synopsis (StatStream [Zhu & Shasha 2002]).

The paper's vertical-scalability engine: each stream keeps the first
``n_coeffs`` DFT coefficients of its length-``window`` sliding window,
updated incrementally in O(n_coeffs) per tick:

    X_F(t+1) = (X_F(t) - x_out + x_in) * e^{+2 pi i F / n}

Normalized (unitary, z-scored) coefficients U_F = X_F / (sigma * n) satisfy
(for F != 0, real series, conjugate symmetry):

    corr(x, y) = 1 - d^2(U'_x, U'_y) / 2,     d^2 = 2 * sum_{F>=1} |U_xF - U_yF|^2

and truncation to few coefficients only *under*-estimates d — so grid
bucketing with cell size eps = sqrt(2 (1 - T)) prunes pairs with NO false
dismissals (paper Section 7). |U_F| <= sqrt(2)/2, hence the sqrt(2)-diameter
grid of the paper.

Complex numbers are carried as a trailing [., 2] (re, im) axis: TPU-native
(complex64 is poorly supported on MXU paths).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DFT:
    window: int = 64
    n_coeffs: int = 8           # coefficients F = 1 .. n_coeffs (F=0 is 0 when z-scored)
    threshold: float = 0.9      # similarity threshold T -> grid cell eps
    grid_coeffs: int = 2        # leading coefficients used for bucket coords
    seed: int = 23

    merge_mode = "fresh"        # DFT replicas are exchanged, not reduced

    @property
    def eps(self) -> float:
        return math.sqrt(2.0 * max(1e-6, 1.0 - self.threshold))

    @property
    def grid_cells(self) -> int:
        return int(math.ceil(math.sqrt(2.0) / self.eps))

    # ------------------------------------------------------------------
    def init(self, key: jax.Array | None = None) -> Dict[str, jax.Array]:
        del key
        f = self.n_coeffs
        return dict(
            ring=jnp.zeros((self.window,), jnp.float32),
            pos=jnp.zeros((), jnp.int32),
            count=jnp.zeros((), jnp.int32),
            total=jnp.zeros((), jnp.float32),
            totsq=jnp.zeros((), jnp.float32),
            coeff=jnp.zeros((f, 2), jnp.float32),
        )

    def _twiddle(self) -> jax.Array:
        """e^{+2 pi i F / n} for F = 1..n_coeffs as [F, 2] (re, im)."""
        fs = np.arange(1, self.n_coeffs + 1, dtype=np.float64)
        ang = 2.0 * np.pi * fs / self.window
        return jnp.asarray(np.stack([np.cos(ang), np.sin(ang)], -1),
                           dtype=jnp.float32)

    def _step(self, state: Dict[str, jax.Array], x: jax.Array,
              valid: jax.Array) -> Dict[str, jax.Array]:
        tw = self._twiddle()
        x_out = state["ring"][state["pos"]]
        delta = x - x_out
        re = state["coeff"][:, 0] + delta
        im = state["coeff"][:, 1]
        # complex multiply by twiddle
        new_re = re * tw[:, 0] - im * tw[:, 1]
        new_im = re * tw[:, 1] + im * tw[:, 0]
        coeff = jnp.stack([new_re, new_im], -1)
        new = dict(
            ring=state["ring"].at[state["pos"]].set(x),
            pos=(state["pos"] + 1) % self.window,
            count=jnp.minimum(state["count"] + 1, np.int32(2**30)),
            total=state["total"] + delta,
            totsq=state["totsq"] + x * x - x_out * x_out,
            coeff=coeff,
        )
        return jax.tree.map(lambda n, o: jnp.where(valid, n, o), new, state)

    def add_batch(self, state: Dict[str, jax.Array], items: jax.Array,
                  values: jax.Array, mask: jax.Array) -> Dict[str, jax.Array]:
        """Feed a (time-ordered) run of ticks of this stream. `items` unused."""
        del items

        def body(s, xv):
            x, valid = xv
            return self._step(s, x, valid), None

        state, _ = jax.lax.scan(body, state, (values.astype(jnp.float32), mask))
        return state

    def step(self, state, value, valid=True):
        """One tick (vmap-friendly across thousands of streams)."""
        return self._step(state, jnp.asarray(value, jnp.float32),
                          jnp.asarray(valid))

    # ------------------------------------------------------------------
    def estimate(self, state: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
        """Return normalized coefficients + grid bucket (paper: 'coefficients
        and the bucket identifier')."""
        coeffs = self.normalized_coeffs(state)
        coords, bucket = self.bucket_of(coeffs)
        return dict(coeffs=coeffs, coords=coords, bucket=bucket)

    def normalized_coeffs(self, state) -> jax.Array:
        n = float(self.window)
        mean = state["total"] / n
        var = jnp.maximum(state["totsq"] / n - mean * mean, 1e-12)
        sigma = jnp.sqrt(var)
        return state["coeff"] / (sigma * n)

    def bucket_of(self, coeffs: jax.Array):
        """Grid coords over the first grid_coeffs (re, im) pairs, cell = eps."""
        g = self.grid_coeffs
        flat = coeffs[..., :g, :].reshape(*coeffs.shape[:-2], 2 * g)
        half = math.sqrt(2.0) / 2.0
        coords = jnp.floor((flat + half) / self.eps).astype(jnp.int32)
        coords = jnp.clip(coords, 0, self.grid_cells - 1)
        # pack coords into a single id (row-major over the small grid)
        mult = jnp.asarray(
            [self.grid_cells ** i for i in range(2 * g)], jnp.int32)
        bucket = jnp.sum(coords * mult, axis=-1)
        return coords, bucket

    def merge(self, a, b):
        """DFT synopses are exchanged between sites, not reduced; keep the
        replica that has seen more ticks (documented deviation)."""
        fresher = b["count"] > a["count"]
        return jax.tree.map(lambda x, y: jnp.where(fresher, y, x), a, b)

    def memory_bytes(self) -> int:
        return (self.window + 4 + 2 * self.n_coeffs) * 4


# ---------------------------------------------------------------------------
# Batch helpers over many streams (used by service.planner + benchmarks)
# ---------------------------------------------------------------------------

def corr_from_coeffs(cx: jax.Array, cy: jax.Array) -> jax.Array:
    """corr ~= 1 - d_trunc^2 / 2 with d^2 = 2 sum_F |cx - cy|^2."""
    d2 = 2.0 * jnp.sum((cx - cy) ** 2, axis=(-2, -1))
    return 1.0 - 0.5 * d2


def pairwise_corr(coeffs: jax.Array) -> jax.Array:
    """All-pairs correlation estimates from stacked coeffs [N, F, 2].

    corr_ij = 1 - (|c_i|^2 + |c_j|^2 - 2 <c_i, c_j>)  (factor 2 folded in)
    The <c_i, c_j> Gram matrix is one MXU matmul — this is the hot spot
    kernels/corr_kernel.py tiles for VMEM.
    """
    n = coeffs.shape[0]
    flat = coeffs.reshape(n, -1)
    sq = jnp.sum(flat * flat, axis=-1)
    gram = flat @ flat.T
    return 1.0 - (sq[:, None] + sq[None, :] - 2.0 * gram)


def adjacent_bucket_mask(coords: jax.Array) -> jax.Array:
    """[N, N] mask: True where streams fall in the same or adjacent grid
    cells (the only candidate pairs; everything else is pruned)."""
    diff = jnp.abs(coords[:, None, :] - coords[None, :, :])
    return jnp.all(diff <= 1, axis=-1)
