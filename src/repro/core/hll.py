"""HyperLogLog [Flajolet et al. 2007] — distinct count estimation.

Parameter follows the paper's Table 1: relative standard error
rse ~= 1.04 / sqrt(2**p)  =>  p = ceil(log2((1.04 / rse)**2)).

State: 2**p registers, each the max leading-zero rank seen.
Merge = elementwise max (the paper's federated HLL merge).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from . import hashing


def _alpha(m: int) -> float:
    if m == 16:
        return 0.673
    if m == 32:
        return 0.697
    if m == 64:
        return 0.709
    return 0.7213 / (1.0 + 1.079 / m)


@dataclasses.dataclass(frozen=True)
class HyperLogLog:
    rse: float = 0.0325          # default ~ p=10
    seed: int = 11

    merge_mode = "max"           # federated merge is one pmax
    update_kernel = "hll_max"            # kernels.ops registry name

    @property
    def p(self) -> int:
        return max(4, min(18, int(math.ceil(math.log2((1.04 / self.rse) ** 2)))))

    @property
    def m(self) -> int:
        return 1 << self.p

    def init(self, key: jax.Array | None = None) -> jax.Array:
        del key
        return jnp.zeros((self.m,), dtype=jnp.int32)

    def _bucket_rank(self, items: jax.Array):
        h = hashing.hash_u32(items, self.seed)
        bucket = (h >> np.uint32(32 - self.p)).astype(jnp.int32)
        rest = (h << np.uint32(self.p)).astype(jnp.uint32)
        rank = jnp.where(rest == 0, 32 - self.p + 1,
                         hashing.clz32(rest) + 1).astype(jnp.int32)
        return bucket, rank

    def add_batch(self, state: jax.Array, items: jax.Array,
                  values: jax.Array, mask: jax.Array) -> jax.Array:
        del values
        bucket, rank = self._bucket_rank(items)
        rank = jnp.where(mask, rank, 0)
        return state.at[bucket].max(rank)

    def stacked_add_batch(self, state, syn_idx, items, values, mask):
        del values
        bucket, rank = self._bucket_rank(items)
        rank = jnp.where(mask, rank, 0)
        return state.at[syn_idx, bucket].max(rank)

    def estimate(self, state: jax.Array) -> jax.Array:
        m = float(self.m)
        raw = _alpha(self.m) * m * m / jnp.sum(jnp.exp2(-state.astype(jnp.float32)))
        zeros = jnp.sum(state == 0).astype(jnp.float32)
        # linear counting small-range correction
        lc = m * jnp.log(m / jnp.maximum(zeros, 1.0))
        return jnp.where((raw <= 2.5 * m) & (zeros > 0), lc, raw)

    def stacked_estimate(self, state: jax.Array, rows: jax.Array) -> jax.Array:
        """Cardinality of each requested row of a register stack [n, m]."""
        regs = state[rows]                                     # [N, m]
        m = float(self.m)
        raw = _alpha(self.m) * m * m / jnp.sum(
            jnp.exp2(-regs.astype(jnp.float32)), axis=-1)
        zeros = jnp.sum(regs == 0, axis=-1).astype(jnp.float32)
        lc = m * jnp.log(m / jnp.maximum(zeros, 1.0))
        return jnp.where((raw <= 2.5 * m) & (zeros > 0), lc, raw)

    def merge(self, a: jax.Array, b: jax.Array) -> jax.Array:
        return jnp.maximum(a, b)

    def memory_bytes(self) -> int:
        return self.m * 4
