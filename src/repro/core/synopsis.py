"""Synopsis protocol + runtime registry (the paper's `Synopsis` base class).

A synopsis *kind* is a frozen dataclass holding static parameters (Table 1
of the paper) and exposing the paper's three methods as pure functions:

    init(key)                       -> state pytree
    add_batch(state, items, values, mask) -> state
    estimate(state, ...)            -> estimation pytree
    merge(a, b)                     -> state            (mergeability, [11])

``state`` is a pytree of fixed-shape jnp arrays, which makes every kind
vmappable (thousands of synopses of one kind share one compiled update --
the TPU analogue of Flink slot sharing) and shardable via shard_map.

The registry provides the paper's *Load Synopsis* pluggability: new kinds
can be registered while the engine is running; each kind gets its own jit
cache so loading one never recompiles the others.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Protocol, runtime_checkable

import jax


@runtime_checkable
class Synopsis(Protocol):
    """Structural protocol every synopsis kind satisfies."""

    def init(self, key: jax.Array) -> Any: ...

    def add_batch(self, state: Any, items: jax.Array, values: jax.Array,
                  mask: jax.Array) -> Any: ...

    def estimate(self, state: Any, *args: Any) -> Any: ...

    def merge(self, a: Any, b: Any) -> Any: ...


# ---------------------------------------------------------------------------
# Runtime registry (Load Synopsis / pluggability)
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[..., Synopsis]] = {}

# name -> concrete type the factory produced (filled lazily by make_kind).
# Needed because a factory may be any callable, not only the kind class
# itself — snapshot manifests must still map instances back to a name.
_PRODUCED_TYPES: Dict[str, type] = {}


def register_kind(name: str, factory: Callable[..., Synopsis],
                  *, overwrite: bool = False) -> None:
    """Register a synopsis kind at runtime (paper: Load Synopsis request)."""
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"synopsis kind {name!r} already registered")
    _REGISTRY[name] = factory
    _PRODUCED_TYPES.pop(name, None)


def make_kind(name: str, **params: Any) -> Synopsis:
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown synopsis kind {name!r}; known: {sorted(_REGISTRY)}")
    kind = _REGISTRY[name](**params)
    _PRODUCED_TYPES[name] = type(kind)
    return kind


def known_kinds() -> list[str]:
    return sorted(_REGISTRY)


def kind_params(kind: Synopsis) -> Dict[str, Any]:
    """Static parameters of a kind (for SDE Status reports)."""
    if dataclasses.is_dataclass(kind):
        return {f.name: getattr(kind, f.name) for f in dataclasses.fields(kind)}
    return {}


def name_of_kind(kind: Synopsis) -> str:
    """Registry name of a kind instance (for snapshot manifests).

    Prefers a class-registered name; falls back to the type the factory
    produced, so kinds plugged in via Load Synopsis with a non-class
    factory (lambda / function) survive snapshot/restore.
    """
    for name, factory in _REGISTRY.items():
        if factory is type(kind):
            return name
    for name, produced in _PRODUCED_TYPES.items():
        if produced is type(kind):
            return name
    raise KeyError(f"kind {type(kind).__name__} not in registry")
