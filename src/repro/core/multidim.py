"""Multidimensional subpopulation keys — attribute vectors in the 64-bit
stream-id space (the Hydra construction of arxiv 2208.04927, adapted).

The engine routes on scalar 63-bit stream ids (``service/routing.py``)
and its fused blue path probes that key space inside the update kernels.
This module generalizes the key WITHOUT touching any of that machinery:
a d-dimensional attribute tuple (``{"region": "EU", "platform":
"mobile"}``) is encoded into the SAME ``[0, 2**63)`` id space, so
multidim groups are ordinary routed streams — ``fold64``/``split64``,
the RouteTable and the probe-inside-the-kernel dispatch all apply
unchanged.

Encoding (Hydra-style): every dimension contributes one 64-bit hash —
``fmix64`` of the dimension's seed combined with the attribute value's
stable hash (blake2b for strings, fmix64 for ints); a dimension a group
does NOT fix contributes its wildcard hash instead. The per-dimension
hashes fold left-to-right through another fmix64 round and the result is
masked to 63 bits. Distinct assignments collide with probability
~ ``n_groups**2 / 2**64`` (birthday bound over the documented 63-bit
space) — negligible against every sketch's own error.

A :class:`MultidimSpec` declares the dimensions with their (finite)
domains and materializes a **dyadic family of levels**: one group-by per
subset of dimensions, from the all-wildcard population group (the empty
level) down to the full cross product (the leaf level). One engine
``build_multidim`` request allocates one synopsis per group across every
level; each ingested record expands to its ``2**d`` group keys (one per
level). A ``subpop_query`` — a conjunction of per-dimension predicates —
resolves to the level that fixes EXACTLY the predicate's dimensions, and
its covering key set is the cross product of the predicate's value
lists: the minimal set of maintained groups whose union IS the
subpopulation. The engine merges that covering set and estimates once,
in a single fused dispatch (``kernels.ops.estimate_subpop``).
"""
from __future__ import annotations

import hashlib
import itertools
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

_MASK63 = (1 << 63) - 1
_MASK64 = (1 << 64) - 1
_GOLD64 = 0x9E3779B97F4A7C15
_WILDCARD = 0xA5A5A5A55A5A5A5A     # the "dimension not fixed" sentinel

# guard rails: the full dyadic family has 2**d levels and
# prod(1 + |domain_i|) groups — keep both human-sized
MAX_DIMS = 8
MAX_GROUPS = 1 << 20


def _fmix64(x: int) -> int:
    """murmur3 fmix64 on Python ints (no numpy overflow games)."""
    x &= _MASK64
    x ^= x >> 33
    x = (x * 0xFF51AFD7ED558CCD) & _MASK64
    x ^= x >> 33
    x = (x * 0xC4CEB9FE1A85EC53) & _MASK64
    x ^= x >> 33
    return x


def _atom_hash(value: Any) -> int:
    """Stable 64-bit hash of one attribute value. Ints hash as ints
    (process-independent), everything else by its UTF-8 string form
    through blake2b — NEVER Python's salted ``hash``."""
    if isinstance(value, bool):       # bool is an int subclass; keep the
        value = f"b:{value}"          # two types distinct anyway
    if isinstance(value, int):
        return _fmix64(value ^ _GOLD64)
    digest = hashlib.blake2b(str(value).encode("utf-8"),
                             digest_size=8).digest()
    return int.from_bytes(digest, "little")


class MultidimSpec:
    """Declared dimensions + domains of one multidim synopsis family.

    ``dims`` maps dimension name -> finite domain (the attribute values
    the family groups by), in declaration order; ``levels`` is the
    materialized subset family — every subset of the dimension names by
    default (the full dyadic family), or an explicit list of name
    tuples. The empty level — the population group — is always
    materialized: the outlier workflow scores every tracked group
    against it.
    """

    def __init__(self, dims: Dict[str, Sequence[Any]],
                 levels: Optional[Iterable[Sequence[str]]] = None):
        if not dims:
            raise ValueError("multidim spec needs at least one dimension")
        if len(dims) > MAX_DIMS:
            raise ValueError(
                f"{len(dims)} dimensions > MAX_DIMS={MAX_DIMS} (the "
                "dyadic family has 2**d levels; keep d small)")
        self.dim_names: List[str] = list(dims)
        self.domains: Dict[str, List[Any]] = {}
        for name, domain in dims.items():
            vals = list(dict.fromkeys(domain))   # dedupe, keep order
            if not vals:
                raise ValueError(f"dimension {name!r} has an empty domain")
            self.domains[name] = vals
        # per-dim seeds + per-(dim, value) hashes, precomputed once
        self._dim_seed = {name: _fmix64(_atom_hash(name) ^ (i * _GOLD64))
                          for i, name in enumerate(self.dim_names)}
        self._value_hash = {
            name: {v: _fmix64(self._dim_seed[name] ^ _atom_hash(v))
                   for v in vals}
            for name, vals in self.domains.items()}
        self._wild_hash = {name: _fmix64(self._dim_seed[name] ^ _WILDCARD)
                           for name in self.dim_names}
        # per-leaf-assignment expansion memo (ingest hot path); bounded
        # by the leaf cross product, itself bounded by MAX_GROUPS
        self._expand_memo: Dict[Tuple[Any, ...], List[int]] = {}
        if levels is None:
            lvls = [tuple(sub) for r in range(len(self.dim_names) + 1)
                    for sub in itertools.combinations(self.dim_names, r)]
        else:
            lvls = []
            for lvl in levels:
                t = tuple(lvl)
                for name in t:
                    self._check_dim(name)
                if len(set(t)) != len(t):
                    raise ValueError(f"level {t} repeats a dimension")
                # canonical order: declaration order of the dims
                t = tuple(n for n in self.dim_names if n in t)
                if t not in lvls:
                    lvls.append(t)
            if () not in lvls:        # the population group is mandatory
                lvls.insert(0, ())
        self.levels: List[Tuple[str, ...]] = lvls
        if self.n_groups() > MAX_GROUPS:
            raise ValueError(
                f"{self.n_groups()} groups > MAX_GROUPS={MAX_GROUPS}; "
                "shrink the domains or materialize fewer levels")

    # -- sizes ----------------------------------------------------------
    def n_groups(self) -> int:
        """Total maintained groups (synopsis rows) across all levels."""
        total = 0
        for lvl in self.levels:
            n = 1
            for name in lvl:
                n *= len(self.domains[name])
            total += n
        return total

    # -- encoding -------------------------------------------------------
    def _check_dim(self, name: str) -> None:
        if name not in self.domains:
            raise ValueError(
                f"unknown dimension {name!r}; declared: {self.dim_names}")

    def group_key(self, assignment: Dict[str, Any]) -> int:
        """63-bit key of the group fixing exactly ``assignment``'s
        dimensions (every other dimension is wildcard). Raises on unknown
        dimensions or out-of-domain values."""
        for name in assignment:
            self._check_dim(name)
        acc = _GOLD64
        for name in self.dim_names:          # declaration order — stable
            if name in assignment:
                v = assignment[name]
                try:
                    h = self._value_hash[name][v]
                except (KeyError, TypeError):
                    raise ValueError(
                        f"value {v!r} outside dimension {name!r}'s "
                        f"declared domain") from None
            else:
                h = self._wild_hash[name]
            acc = _fmix64((acc * _GOLD64 + h) & _MASK64)
        return acc & _MASK63

    def population_key(self) -> int:
        """Key of the all-wildcard group (the empty level)."""
        return self.group_key({})

    def level_assignments(self, level: Sequence[str]
                          ) -> List[Dict[str, Any]]:
        """Every assignment of one level (cross product of its domains),
        in deterministic declaration order."""
        lvl = tuple(n for n in self.dim_names if n in set(level))
        for name in level:
            self._check_dim(name)
        combos = itertools.product(*(self.domains[n] for n in lvl))
        return [dict(zip(lvl, combo)) for combo in combos]

    def level_keys(self, level: Sequence[str]) -> List[int]:
        return [self.group_key(a) for a in self.level_assignments(level)]

    def all_keys(self) -> List[int]:
        """Keys of EVERY maintained group, every level — the stream-id
        list one per-stream ``build`` request allocates."""
        out: List[int] = []
        for lvl in self.levels:
            out.extend(self.level_keys(lvl))
        return out

    def expand(self, attrs: Dict[str, Any]) -> List[int]:
        """Keys of every group one fully-assigned record belongs to —
        one per materialized level (``2**d`` for the full family). The
        record must assign EVERY dimension."""
        missing = [n for n in self.dim_names if n not in attrs]
        if missing:
            raise ValueError(f"record is missing dimensions {missing}")
        extra = [n for n in attrs if n not in self.domains]
        if extra:
            raise ValueError(f"record has unknown dimensions {extra}")
        try:
            leaf = tuple(attrs[n] for n in self.dim_names)
            keys = self._expand_memo.get(leaf)
        except TypeError:                # unhashable value: no memo
            leaf, keys = None, None
        if keys is None:
            keys = [self.group_key({n: attrs[n] for n in lvl})
                    for lvl in self.levels]
            if leaf is not None:
                self._expand_memo[leaf] = keys
        return keys

    def leaf_key(self, attrs: Dict[str, Any]) -> int:
        """Key of the full-assignment (leaf) group of one record."""
        return self.group_key({n: attrs[n] for n in self.dim_names})

    # -- predicates -----------------------------------------------------
    def covering_keys(self, where: Dict[str, Any]
                      ) -> Tuple[Tuple[str, ...], List[int]]:
        """Resolve a conjunction of per-dimension predicates to its
        covering key set: ``where`` maps dimension -> value or list of
        values; the answering level fixes EXACTLY the predicate's
        dimensions, and the covering set is the cross product of the
        per-dimension value lists — the minimal set of maintained groups
        whose union is the subpopulation. Returns ``(level, keys)``."""
        for name in where:
            self._check_dim(name)
        level = tuple(n for n in self.dim_names if n in where)
        if level not in self.levels:
            raise ValueError(
                f"level {level} is not materialized; available levels: "
                f"{self.levels}")
        lists = []
        for name in level:
            v = where[name]
            vals = list(v) if isinstance(v, (list, tuple)) else [v]
            if not vals:
                raise ValueError(f"empty predicate for dimension {name!r}")
            lists.append([(name, x) for x in vals])
        keys = [self.group_key(dict(combo))
                for combo in itertools.product(*lists)]
        return level, keys

    # -- (de)serialization — snapshot manifests carry specs -------------
    def to_json_dict(self) -> Dict[str, Any]:
        return dict(dims={n: list(v) for n, v in self.domains.items()},
                    levels=[list(lvl) for lvl in self.levels])

    @classmethod
    def from_json_dict(cls, obj: Dict[str, Any]) -> "MultidimSpec":
        return cls(dict(obj["dims"]),
                   levels=[tuple(lvl) for lvl in obj["levels"]])

    def __eq__(self, other: Any) -> bool:
        return (isinstance(other, MultidimSpec)
                and self.domains == other.domains
                and self.levels == other.levels)

    def __repr__(self) -> str:
        return (f"MultidimSpec(dims={self.dim_names}, "
                f"levels={len(self.levels)}, groups={self.n_groups()})")
