# The paper's primary contribution: a mergeable synopses engine in JAX.
# Every kind from Table 1 of the paper is registered here; Load Synopsis
# pluggability goes through synopsis.register_kind at runtime.
from . import hashing  # noqa: F401
from .synopsis import (Synopsis, register_kind, make_kind, known_kinds,
                       kind_params)  # noqa: F401
from .countmin import CountMin
from .hll import HyperLogLog
from .ams import AMS
from .bloom import BloomFilter
from .fm import FMSketch
from .dft import DFT
from .rhp import RHP
from .lossy import LossyCounting
from .sticky import StickySampling
from .sampler import ReservoirSampler
from .gk import GKQuantiles
from .coreset import CoreSetTree
from .window import PaneWindow
from .multidim import MultidimSpec
from . import batched, federated  # noqa: F401

for _name, _factory in {
    "countmin": CountMin,
    "hyperloglog": HyperLogLog,
    "ams": AMS,
    "bloom": BloomFilter,
    "fm": FMSketch,
    "dft": DFT,
    "rhp": RHP,
    "lossy_counting": LossyCounting,
    "sticky_sampling": StickySampling,
    "chain_sampler": ReservoirSampler,
    "gk_quantiles": GKQuantiles,
    "coreset_tree": CoreSetTree,
}.items():
    register_kind(_name, _factory)

__all__ = [
    "Synopsis", "register_kind", "make_kind", "known_kinds", "kind_params",
    "CountMin", "HyperLogLog", "AMS", "BloomFilter", "FMSketch", "DFT",
    "RHP", "LossyCounting", "StickySampling", "ReservoirSampler",
    "GKQuantiles", "CoreSetTree", "PaneWindow", "MultidimSpec",
    "batched", "federated",
]
