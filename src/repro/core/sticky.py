"""Sticky Sampling [Manku & Motwani 2002] — probabilistic frequent items.

Parameters (support s, eps, delta) per the paper's Table 1. Capacity
t = ceil(ln(1/(s*delta)) / eps) entries; new keys are admitted with
probability 1/r where the sampling rate r doubles per epoch; at each epoch
change tracked counts are geometrically decremented.

JAX adaptation: admission coins come from a counter-based hash (stateless
PRNG), epoch decrements use one geometric draw per slot; fixed-capacity
table like lossy.py. All deviations are statistical-equivalent and tested
on zipf streams (support recall / false-positive behaviour).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import hashing

_EMPTY = np.uint32(0xFFFFFFFF)


@dataclasses.dataclass(frozen=True)
class StickySampling:
    support: float = 0.01
    eps: float = 0.002
    delta: float = 0.01
    seed: int = 37

    merge_mode = "gather"

    @property
    def capacity(self) -> int:
        t = math.log(1.0 / (self.support * self.delta)) / self.eps
        return int(min(max(8, math.ceil(t / 16.0)), 4096))

    def init(self, key: jax.Array | None = None) -> Dict[str, jax.Array]:
        del key
        return dict(
            keys=jnp.full((self.capacity,), _EMPTY, jnp.uint32),
            counts=jnp.zeros((self.capacity,), jnp.float32),
            n_seen=jnp.zeros((), jnp.int32),
            epoch=jnp.zeros((), jnp.int32),
        )

    def _rate(self, epoch):
        return jnp.exp2(epoch.astype(jnp.float32))      # r = 2^epoch

    def _step(self, s, item, valid):
        keys, counts = s["keys"], s["counts"]
        n = s["n_seen"] + 1
        # epoch boundaries at 2t, 4t, 8t ... (t = capacity * 16 heuristic)
        t = self.capacity * 16
        want_epoch = jnp.maximum(
            0, jnp.floor(jnp.log2(jnp.maximum(n.astype(jnp.float32) / t, 1.0)))
        ).astype(jnp.int32)
        bump = want_epoch > s["epoch"]
        # geometric decrement on epoch change (one draw per slot)
        u = hashing.uniform01(
            jnp.arange(self.capacity, dtype=jnp.uint32) ^ n.astype(jnp.uint32),
            self.seed)
        geo = jnp.floor(jnp.log(jnp.maximum(u, 1e-9)) / math.log(0.5))
        counts = jnp.where(bump, jnp.maximum(counts - geo, 0.0), counts)
        keys = jnp.where(bump & (counts <= 0), _EMPTY, keys)

        hit = keys == item
        any_hit = jnp.any(hit)
        empty = keys == _EMPTY
        any_empty = jnp.any(empty)
        coin = hashing.uniform01(item ^ n.astype(jnp.uint32), self.seed + 1)
        admit = coin < 1.0 / self._rate(jnp.maximum(want_epoch, s["epoch"]))
        slot = jnp.where(any_hit, jnp.argmax(hit), jnp.argmax(empty))
        do = valid & (any_hit | (any_empty & admit))
        keys = keys.at[slot].set(jnp.where(do, item, keys[slot]))
        counts = counts.at[slot].set(
            jnp.where(do, counts[slot] + 1.0, counts[slot]))
        return dict(keys=keys, counts=counts,
                    n_seen=jnp.where(valid, n, s["n_seen"]),
                    epoch=jnp.maximum(want_epoch, s["epoch"]))

    def add_batch(self, state, items, values, mask):
        del values

        def body(s, t):
            return self._step(s, t[0], t[1]), None

        state, _ = jax.lax.scan(body, state, (items.astype(jnp.uint32), mask))
        return state

    def estimate(self, state, items: jax.Array) -> jax.Array:
        eq = state["keys"][None, :] == items.astype(jnp.uint32)[:, None]
        return jnp.sum(jnp.where(eq, state["counts"][None, :], 0.0), axis=-1)

    def stacked_estimate(self, state, rows: jax.Array,
                         items: jax.Array) -> jax.Array:
        """Batched frequency queries over the sampled tables (see
        LossyCounting.stacked_estimate — same table-gather layout)."""
        keys = state["keys"][rows]                             # [N, cap]
        counts = state["counts"][rows]
        eq = keys[:, None, :] == items.astype(jnp.uint32)[:, :, None]
        return jnp.sum(jnp.where(eq, counts[:, None, :], 0.0), axis=-1)

    def frequent_items(self, state):
        thr = (self.support - self.eps) * state["n_seen"].astype(jnp.float32)
        keep = state["counts"] >= jnp.maximum(thr, 1.0)
        return state["keys"], state["counts"], keep

    def merge(self, a, b):
        """Approximate merge: union of tables, keep highest counts."""
        keys = jnp.concatenate([a["keys"], b["keys"]])
        counts = jnp.concatenate([a["counts"], b["counts"]])
        order = jnp.argsort(-counts)[: self.capacity]
        return dict(keys=keys[order], counts=counts[order],
                    n_seen=a["n_seen"] + b["n_seen"],
                    epoch=jnp.maximum(a["epoch"], b["epoch"]))

    def memory_bytes(self) -> int:
        return self.capacity * 8
