"""GK quantile summary [Greenwald & Khanna 2001] — eps-approximate quantiles.

Implemented as a fixed-size merge-and-prune summary (the KLL/`mergeable
summaries` formulation used by modern sketch libraries incl. Yahoo
DataSketches): the summary is m = ceil(4/eps) values at equi-spaced
quantile positions of the weighted empirical distribution; add/merge =
weighted re-quantization. GK's deterministic worst-case bound is traded for
the standard randomized/compaction bound — recorded deviation, rank error
validated ~ eps*N in tests. Fixed shapes, fully jittable, MERGEABLE.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class GKQuantiles:
    eps: float = 0.01
    seed: int = 43

    merge_mode = "gather"

    @property
    def m(self) -> int:
        return max(8, int(math.ceil(4.0 / self.eps)))

    def init(self, key: jax.Array | None = None) -> Dict[str, jax.Array]:
        del key
        return dict(
            values=jnp.zeros((self.m,), jnp.float32),
            n=jnp.zeros((), jnp.float32),
        )

    def _requantize(self, values, weights, total):
        """Resample m equi-spaced quantiles from weighted points."""
        order = jnp.argsort(values)
        v = values[order]
        w = weights[order]
        cum = jnp.cumsum(w) - 0.5 * w                   # midpoint ranks
        targets = (jnp.arange(self.m, dtype=jnp.float32) + 0.5) / self.m * total
        idx = jnp.searchsorted(cum, targets)
        idx = jnp.clip(idx, 0, values.shape[0] - 1)
        return v[idx]

    def add_batch(self, state, items, values, mask):
        del items
        w_new = mask.astype(jnp.float32)
        t_new = jnp.sum(w_new)
        n = state["n"]
        total = n + t_new
        # guard: masked-out values must not pollute the sort — push to +inf
        vals_in = jnp.where(mask, values.astype(jnp.float32), jnp.inf)
        all_v = jnp.concatenate([state["values"], vals_in])
        all_w = jnp.concatenate(
            [jnp.full((self.m,), n / self.m, jnp.float32), w_new])
        new_vals = self._requantize(all_v, all_w, total)
        # cold start: before the summary holds data, it contains zeros with
        # weight 0 — requantize handles it since their weight is ~0.
        return dict(values=new_vals, n=total)

    def estimate(self, state, qs: jax.Array) -> jax.Array:
        """Quantile queries q in [0, 1]."""
        idx = jnp.clip((qs * self.m).astype(jnp.int32), 0, self.m - 1)
        return state["values"][idx]

    def stacked_estimate(self, state, rows: jax.Array,
                         qs: jax.Array) -> jax.Array:
        """Batched quantile queries: query q reads ``qs[q]`` quantiles of
        summary row ``rows[q]`` — [N, Q] in one gather."""
        idx = jnp.clip((qs * self.m).astype(jnp.int32), 0, self.m - 1)
        return state["values"][rows[:, None], idx]

    def rank(self, state, x: jax.Array) -> jax.Array:
        """Approximate rank of x (count of items <= x)."""
        frac = jnp.mean((state["values"] <= x[..., None]).astype(jnp.float32),
                        axis=-1)
        return frac * state["n"]

    def merge(self, a, b):
        total = a["n"] + b["n"]
        values = jnp.concatenate([a["values"], b["values"]])
        weights = jnp.concatenate([
            jnp.full((self.m,), a["n"] / self.m, jnp.float32),
            jnp.full((self.m,), b["n"] / self.m, jnp.float32)])
        return dict(values=self._requantize(values, weights,
                                            jnp.maximum(total, 1e-9)),
                    n=total)

    def memory_bytes(self) -> int:
        return self.m * 4
