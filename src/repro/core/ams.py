"""AMS sketch [Alon, Matias, Szegedy 1996] — L2 norm / inner product.

Fast-AMS / count-sketch layout: d independent rows of w counters; each
update adds sign_j(x) * v to counter [j, h_j(x)]. Row estimate of <u, v>
is the row dot product; the final estimate is the median over rows
(the paper's Section 7 formula). w = O(1/eps^2), d = O(log 1/delta).

Merge = elementwise addition (linear sketch) — this linearity is also why
AMS gradient sketches merge across data-parallel workers with one psum.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from . import hashing


@dataclasses.dataclass(frozen=True)
class AMS:
    eps: float = 0.05
    delta: float = 0.05
    seed: int = 13

    merge_mode = "sum"
    update_kernel = "ams_scatter"        # kernels.ops registry name

    @property
    def depth(self) -> int:
        return max(1, int(math.ceil(4.0 * math.log(1.0 / self.delta))))

    @property
    def log2_width(self) -> int:
        return max(1, int(math.ceil(math.log2(max(2.0, 4.0 / self.eps ** 2)))))

    @property
    def width(self) -> int:
        return 1 << self.log2_width

    def _seeds(self) -> jax.Array:
        return jnp.asarray(hashing.row_seeds(self.seed, self.depth))

    def init(self, key: jax.Array | None = None) -> jax.Array:
        del key
        return jnp.zeros((self.depth, self.width), dtype=jnp.float32)

    def add_batch(self, state: jax.Array, items: jax.Array,
                  values: jax.Array, mask: jax.Array) -> jax.Array:
        seeds = self._seeds()
        idx = hashing.bucket_hash(items, seeds, self.log2_width)   # [T,d]
        sgn = hashing.sign_hash(items, seeds)                       # [T,d]
        v = (values * mask.astype(jnp.float32))[:, None] * sgn      # [T,d]
        rows = jnp.arange(self.depth)[None, :]
        return state.at[rows, idx].add(v)

    def stacked_add_batch(self, state, syn_idx, items, values, mask):
        seeds = self._seeds()
        idx = hashing.bucket_hash(items, seeds, self.log2_width)
        sgn = hashing.sign_hash(items, seeds)
        v = (values * mask.astype(jnp.float32))[:, None] * sgn
        rows = jnp.arange(self.depth)[None, :]
        return state.at[syn_idx[:, None], rows, idx].add(v)

    def add_dense(self, state: jax.Array, vec: jax.Array) -> jax.Array:
        """Sketch a dense vector (gradient sketching): item ids = positions."""
        items = jnp.arange(vec.shape[0], dtype=jnp.uint32)
        return self.add_batch(state, items, vec, jnp.ones_like(vec, dtype=bool))

    def estimate(self, state: jax.Array) -> jax.Array:
        """L2-norm^2 estimate (self inner product)."""
        return self.inner_product(state, state)

    def stacked_estimate(self, state: jax.Array, rows: jax.Array) -> jax.Array:
        """L2-norm^2 of each requested row of a stack [n, d, w]."""
        sub = state[rows]                                      # [N, d, w]
        return jnp.median(jnp.sum(sub * sub, axis=-1), axis=-1)

    def inner_product(self, a: jax.Array, b: jax.Array) -> jax.Array:
        row = jnp.sum(a * b, axis=-1)          # [d]
        return jnp.median(row)

    def point_query(self, state: jax.Array, items: jax.Array) -> jax.Array:
        """Count-sketch point frequency estimate (median of sign*counter)."""
        seeds = self._seeds()
        idx = hashing.bucket_hash(items, seeds, self.log2_width)
        sgn = hashing.sign_hash(items, seeds)
        rows = jnp.arange(self.depth)[None, :]
        return jnp.median(state[rows, idx] * sgn, axis=-1)

    def merge(self, a: jax.Array, b: jax.Array) -> jax.Array:
        return a + b

    def memory_bytes(self) -> int:
        return self.depth * self.width * 4
