"""Stacked maintenance of thousands of synopses of one kind.

This is the TPU analogue of Flink slot sharing (paper Section 6, "...And
One SDEaaS For All"): all synopses of a kind live in ONE stacked pytree
with a leading [capacity] axis, and a single compiled program updates all
of them. Adding a synopsis assigns a row; growing past capacity doubles
the stack (amortized re-jit), mirroring "a request for a new synopsis
assigns new tasks, not task slots".

Routing: a batch of (syn_idx, item, value) tuples updates rows via the
kind's ``stacked_add_batch`` scatter path when available (CM/HLL/AMS/
Bloom/FM/RHP), else via a generic vmap fallback where each row consumes
the full batch masked to its own tuples (scan-based kinds).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .synopsis import Synopsis


def stacked_init(kind: Synopsis, capacity: int) -> Any:
    proto = kind.init(None)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (capacity,) + x.shape).copy(), proto)


def grow(kind: Synopsis, stacked: Any, new_capacity: int) -> Any:
    """Double capacity, padding NEW rows with the kind's init prototype.

    Zero-padding is wrong for kinds whose empty state is not all-zeros
    (LossyCounting/StickySampling init ``keys`` to an all-ones sentinel:
    zero-padded rows would look occupied by item 0).
    """
    capacity = jax.tree.leaves(stacked)[0].shape[0]
    fresh = stacked_init(kind, new_capacity - capacity)
    return jax.tree.map(
        lambda x, f: jnp.concatenate([x, f], axis=0), stacked, fresh)


def shrink(stacked: Any, new_capacity: int) -> Any:
    """Drop trailing rows (the grow() inverse). The caller — the
    migration plane — must have compacted live rows below
    ``new_capacity`` first; anything above the cut is discarded."""
    return jax.tree.map(lambda x: x[:new_capacity], stacked)


def stacked_add_batch(kind: Synopsis, stacked: Any, syn_idx: jax.Array,
                      items: jax.Array, values: jax.Array,
                      mask: jax.Array) -> Any:
    if hasattr(kind, "stacked_add_batch"):
        return kind.stacked_add_batch(stacked, syn_idx, items, values, mask)
    # generic fallback: every row sees the batch masked to its tuples
    capacity = jax.tree.leaves(stacked)[0].shape[0]

    def per_row(row_state, row_id):
        row_mask = mask & (syn_idx == row_id)
        return kind.add_batch(row_state, items, values, row_mask)

    return jax.vmap(per_row)(stacked, jnp.arange(capacity))


def stacked_update(kind: Synopsis, stacked: Any, syn_idx: jax.Array,
                   items: jax.Array, values: jax.Array, mask: jax.Array,
                   source_rows: jax.Array | None = None) -> Any:
    """Fused routed + data-source update — ONE dispatch for the whole kind.

    ``syn_idx`` may contain -1 for unrouted tuples; ``source_rows`` is an
    int32 index vector of rows fed by ALL tuples (data-source synopses).
    Scatter-path kinds get the source contribution via mergeability: the
    batch is summarized ONCE into a fresh synopsis, merged into just the
    source rows and scattered back (exact — every scatter kind's merge
    is elementwise sum/max; work is proportional to the number of source
    rows, not capacity). Scan-path kinds fold the source rows into the
    per-row mask of the single vmap.
    """
    routed = mask & (syn_idx >= 0)
    rows = jnp.maximum(syn_idx, 0)
    if hasattr(kind, "stacked_add_batch"):
        out = kind.stacked_add_batch(stacked, rows, items, values, routed)
        if source_rows is not None:
            fresh = kind.add_batch(kind.init(None), items, values, mask)
            sub = jax.tree.map(lambda x: x[source_rows], out)
            merged = jax.vmap(lambda r: kind.merge(r, fresh))(sub)
            out = jax.tree.map(
                lambda x, m: x.at[source_rows].set(m), out, merged)
        return out
    capacity = jax.tree.leaves(stacked)[0].shape[0]
    source_mask = jnp.zeros((capacity,), bool)
    if source_rows is not None:
        source_mask = source_mask.at[source_rows].set(True)

    def per_row(row_state, row_id, is_src):
        row_mask = mask & ((syn_idx == row_id) | is_src)
        return kind.add_batch(row_state, items, values, row_mask)

    return jax.vmap(per_row)(stacked, jnp.arange(capacity), source_mask)


def stacked_step(kind: Synopsis, stacked: Any, values: jax.Array,
                 mask: jax.Array) -> Any:
    """Time-series path: one tick per stream per step (DFT & friends)."""
    return jax.vmap(kind.step)(stacked, values, mask)


def stacked_estimate(kind: Synopsis, stacked: Any, rows: jax.Array | None,
                     *args: Any) -> Any:
    """Batched red path: estimates for ``rows`` of the stack in ONE program
    (the read-side twin of ``stacked_update``).

    ``rows`` is an int32 index vector (None => every row); each extra query
    arg carries a leading axis matching ``rows`` so query q evaluates row
    ``rows[q]`` with its OWN arguments (N ad-hoc queries, one dispatch).
    Kinds provide ``stacked_estimate`` for gather-specialized reads; the
    fallback vmaps the scalar ``estimate`` over the gathered rows.
    """
    if rows is None:
        capacity = jax.tree.leaves(stacked)[0].shape[0]
        rows = jnp.arange(capacity, dtype=jnp.int32)
    if hasattr(kind, "stacked_estimate"):
        return kind.stacked_estimate(stacked, rows, *args)
    sub = jax.tree.map(lambda x: x[rows], stacked)
    return jax.vmap(lambda s, *a: kind.estimate(s, *a))(sub, *args)


def stacked_row(stacked: Any, row: int) -> Any:
    return jax.tree.map(lambda x: x[row], stacked)


def set_row(stacked: Any, row: int, state: Any) -> Any:
    return jax.tree.map(lambda x, v: x.at[row].set(v), stacked, state)
