"""Random Hyperplane Projection (RHP / SimHash) [Charikar 2002; Giatrakos
et al. 2013] — cosine-similarity LSH bitmaps.

State: b running dot products of the stream's frequency/feature vector v
with b ±1 hyperplanes (linear in v => incremental and MERGEABLE by
addition). The bitmap is sign(dots); Hamming distance between bitmaps
estimates the angle:  cos_sim ~= cos(pi * ham / b).  The paper uses the
Hamming weight of such bitmaps for correlation-aware hashing of streams to
workers — ``bucket_of`` packs the first g bits into a bucket id.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from . import hashing


@dataclasses.dataclass(frozen=True)
class RHP:
    n_bits: int = 64           # bitmap size
    threshold: float = 0.9     # similarity threshold (for candidate pruning)
    bucket_bits: int = 8       # leading bits forming the bucket id
    seed: int = 29

    merge_mode = "sum"
    update_kernel = "rhp_project"        # kernels.ops registry name

    def _seeds(self) -> jax.Array:
        return jnp.asarray(hashing.row_seeds(self.seed, self.n_bits))

    def init(self, key: jax.Array | None = None) -> jax.Array:
        del key
        return jnp.zeros((self.n_bits,), jnp.float32)

    def add_batch(self, state: jax.Array, items: jax.Array,
                  values: jax.Array, mask: jax.Array) -> jax.Array:
        sgn = hashing.sign_hash(items, self._seeds())          # [T, b]
        v = (values * mask.astype(jnp.float32))[:, None]
        return state + jnp.sum(sgn * v, axis=0)

    def stacked_add_batch(self, state, syn_idx, items, values, mask):
        sgn = hashing.sign_hash(items, self._seeds())
        v = (values * mask.astype(jnp.float32))[:, None]
        return state.at[syn_idx].add(sgn * v)

    def signature(self, state: jax.Array) -> jax.Array:
        return (state > 0).astype(jnp.int32)

    def estimate(self, state: jax.Array) -> dict:
        sig = self.signature(state)
        return dict(signature=sig, hamming_weight=jnp.sum(sig),
                    bucket=self.bucket_of(sig))

    def stacked_estimate(self, state: jax.Array, rows: jax.Array) -> dict:
        """Signature/bucket of each requested row of a stack [n, b]
        (``signature`` and ``bucket_of`` are already batch-generic)."""
        sig = self.signature(state[rows])                      # [N, b]
        return dict(signature=sig, hamming_weight=jnp.sum(sig, axis=-1),
                    bucket=self.bucket_of(sig))

    def bucket_of(self, sig: jax.Array) -> jax.Array:
        g = self.bucket_bits
        mult = jnp.asarray([1 << i for i in range(g)], jnp.int32)
        return jnp.sum(sig[..., :g] * mult, axis=-1)

    def merge(self, a: jax.Array, b: jax.Array) -> jax.Array:
        return a + b     # dot products are linear in the stream

    def memory_bytes(self) -> int:
        return self.n_bits * 4


def cosine_similarity(sig_a: jax.Array, sig_b: jax.Array,
                      n_bits: int) -> jax.Array:
    ham = jnp.sum(jnp.abs(sig_a - sig_b), axis=-1).astype(jnp.float32)
    return jnp.cos(jnp.pi * ham / n_bits)
