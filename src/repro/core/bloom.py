"""Bloom filter [Bloom 1970] — set membership.

Parameters per the paper's Table 1: (#elements n, false-positive rate fpr)
=> m = ceil(-n ln fpr / ln(2)^2) bits, k = round(m/n ln 2) hash functions.
Bits are stored as an int32 0/1 vector (TPU-friendly; packing to words is a
serialization concern, handled by the checkpoint layer).

Merge = elementwise OR.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from . import hashing


@dataclasses.dataclass(frozen=True)
class BloomFilter:
    n_elements: int = 10000
    fpr: float = 0.01
    seed: int = 17

    merge_mode = "max"
    update_kernel = "bloom_bitset"       # kernels.ops registry name

    @property
    def log2_bits(self) -> int:
        m = -self.n_elements * math.log(self.fpr) / (math.log(2.0) ** 2)
        return max(3, int(math.ceil(math.log2(max(8.0, m)))))

    @property
    def n_bits(self) -> int:
        return 1 << self.log2_bits

    @property
    def k(self) -> int:
        return max(1, int(round(self.n_bits / self.n_elements * math.log(2.0))))

    def _seeds(self) -> jax.Array:
        return jnp.asarray(hashing.row_seeds(self.seed, self.k))

    def init(self, key: jax.Array | None = None) -> jax.Array:
        del key
        return jnp.zeros((self.n_bits,), dtype=jnp.int32)

    def add_batch(self, state: jax.Array, items: jax.Array,
                  values: jax.Array, mask: jax.Array) -> jax.Array:
        del values
        idx = hashing.bucket_hash(items, self._seeds(), self.log2_bits)  # [T,k]
        upd = jnp.broadcast_to(mask.astype(jnp.int32)[:, None], idx.shape)
        return state.at[idx].max(upd)

    def stacked_add_batch(self, state, syn_idx, items, values, mask):
        del values
        idx = hashing.bucket_hash(items, self._seeds(), self.log2_bits)
        upd = jnp.broadcast_to(mask.astype(jnp.int32)[:, None], idx.shape)
        return state.at[syn_idx[:, None], idx].max(upd)

    def estimate(self, state: jax.Array, items: jax.Array) -> jax.Array:
        """Membership queries — True means 'possibly present'."""
        idx = hashing.bucket_hash(items, self._seeds(), self.log2_bits)
        return jnp.all(state[idx] > 0, axis=-1)

    def stacked_estimate(self, state: jax.Array, rows: jax.Array,
                         items: jax.Array) -> jax.Array:
        """Batched membership: query q tests ``items[q]`` against bit
        vector ``rows[q]`` of the stack [n, bits] in one gather."""
        idx = hashing.bucket_hash(items, self._seeds(), self.log2_bits)
        return jnp.all(state[rows[:, None, None], idx] > 0, axis=-1)

    def merge(self, a: jax.Array, b: jax.Array) -> jax.Array:
        return jnp.maximum(a, b)

    def memory_bytes(self) -> int:
        return self.n_bits // 8
