"""FM sketch [Flajolet & Martin 1985] — distinct count via PCSA bitmaps.

nmaps independent 32-bit bitmaps; each item selects a bitmap and sets bit
rho = #trailing-zeros of the remaining hash bits (geometric). Estimate is
the PCSA formula  nmaps / phi * 2**mean(R)  with phi = 0.77351, where R is
the lowest unset bit index per bitmap (the paper's Section 4.2 walkthrough).

Merge = bitmap OR — the paper's flagship federated example ("communicating
only small bitmaps ... and performing a bitwise OR").
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from . import hashing

_PHI = 0.77351


@dataclasses.dataclass(frozen=True)
class FMSketch:
    bitmap_size: int = 32
    nmaps: int = 64          # averaging maps: rse ~ 0.78/sqrt(nmaps)
    seed: int = 19

    merge_mode = "max"       # bitmap OR == max on {0,1}
    update_kernel = "fm_bitmap"          # kernels.ops registry name

    @property
    def log2_nmaps(self) -> int:
        return int(math.log2(self.nmaps))

    def __post_init__(self):
        if 1 << int(math.log2(self.nmaps)) != self.nmaps:
            raise ValueError("nmaps must be a power of two")

    def init(self, key: jax.Array | None = None) -> jax.Array:
        del key
        return jnp.zeros((self.nmaps, self.bitmap_size), dtype=jnp.int32)

    def add_batch(self, state: jax.Array, items: jax.Array,
                  values: jax.Array, mask: jax.Array) -> jax.Array:
        del values
        which, pos = self._which_pos(items)
        return state.at[which, pos].max(mask.astype(jnp.int32))

    def _which_pos(self, items):
        """Bitmap selector = top bits; geometric position = trailing zeros
        of the low bits (disjoint bit ranges of one mixed hash)."""
        h = hashing.hash_u32(items, self.seed)
        which = (h >> np.uint32(32 - self.log2_nmaps)).astype(jnp.int32)
        pos = jnp.minimum(hashing.ctz32(h), self.bitmap_size - 1)
        return which, pos

    def stacked_add_batch(self, state, syn_idx, items, values, mask):
        del values
        which, pos = self._which_pos(items)
        return state.at[syn_idx, which, pos].max(mask.astype(jnp.int32))

    def estimate(self, state: jax.Array) -> jax.Array:
        # R per bitmap: index of lowest unset bit
        unset = state == 0                                     # [nmaps, bits]
        first_unset = jnp.argmax(unset, axis=-1)
        all_set = ~jnp.any(unset, axis=-1)
        r = jnp.where(all_set, self.bitmap_size, first_unset).astype(jnp.float32)
        return self.nmaps / _PHI * jnp.exp2(jnp.mean(r))

    def stacked_estimate(self, state: jax.Array, rows: jax.Array) -> jax.Array:
        """PCSA estimate of each requested row of a stack [n, nmaps, bits]."""
        unset = state[rows] == 0                               # [N, maps, bits]
        first_unset = jnp.argmax(unset, axis=-1)
        all_set = ~jnp.any(unset, axis=-1)
        r = jnp.where(all_set, self.bitmap_size, first_unset).astype(jnp.float32)
        return self.nmaps / _PHI * jnp.exp2(jnp.mean(r, axis=-1))

    def merge(self, a: jax.Array, b: jax.Array) -> jax.Array:
        return jnp.maximum(a, b)

    def memory_bytes(self) -> int:
        return self.nmaps * self.bitmap_size // 8
