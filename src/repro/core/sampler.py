"""Chain / reservoir sampler [Babcock, Datar, Motwani 2002] — uniform sample.

Whole-stream mode is Vitter's reservoir-R; sliding-window mode is obtained
by composing this kind with core.window.PaneWindow (sample-per-pane +
weighted subsample on merge), which is the mergeable-summaries formulation
of windowed sampling — recorded deviation from the chain-sample pointer
structure, same uniformity guarantee per pane.

Randomness is counter-based (hash of n_seen), so the sampler is a pure
function of the stream — replayable across checkpoint restore.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import hashing


@dataclasses.dataclass(frozen=True)
class ReservoirSampler:
    sample_size: int = 64
    seed: int = 41

    merge_mode = "gather"

    def init(self, key: jax.Array | None = None) -> Dict[str, jax.Array]:
        del key
        return dict(
            values=jnp.zeros((self.sample_size,), jnp.float32),
            items=jnp.zeros((self.sample_size,), jnp.uint32),
            n_seen=jnp.zeros((), jnp.int32),
        )

    def _step(self, s, item, v, valid):
        n = s["n_seen"]
        u = hashing.uniform01(n.astype(jnp.uint32) * jnp.uint32(2654435761)
                              ^ item, self.seed)
        j = (u * (n + 1).astype(jnp.float32)).astype(jnp.int32)
        fill = n < self.sample_size
        slot = jnp.where(fill, n, j)
        do = valid & (fill | (j < self.sample_size))
        return dict(
            values=s["values"].at[slot].set(
                jnp.where(do, v, s["values"][slot])),
            items=s["items"].at[slot].set(
                jnp.where(do, item, s["items"][slot])),
            n_seen=n + valid.astype(jnp.int32),
        )

    def add_batch(self, state, items, values, mask):
        def body(s, t):
            return self._step(s, t[0], t[1], t[2]), None

        state, _ = jax.lax.scan(
            body, state,
            (items.astype(jnp.uint32), values.astype(jnp.float32), mask))
        return state

    def estimate(self, state) -> Dict[str, jax.Array]:
        k = jnp.minimum(state["n_seen"], self.sample_size)
        valid = jnp.arange(self.sample_size) < k
        return dict(values=state["values"], items=state["items"], valid=valid)

    def stacked_estimate(self, state, rows: jax.Array) -> Dict[str, jax.Array]:
        """Samples of each requested row of the stacked reservoirs."""
        k = jnp.minimum(state["n_seen"][rows], self.sample_size)   # [N]
        valid = jnp.arange(self.sample_size)[None, :] < k[:, None]
        return dict(values=state["values"][rows],
                    items=state["items"][rows], valid=valid)

    def merge(self, a, b):
        """Weighted reservoir merge: slot i keeps a's item with probability
        n_a / (n_a + n_b) — unbiased union sample."""
        na = a["n_seen"].astype(jnp.float32)
        nb = b["n_seen"].astype(jnp.float32)
        p = na / jnp.maximum(na + nb, 1.0)
        u = hashing.uniform01(
            jnp.arange(self.sample_size, dtype=jnp.uint32)
            ^ (a["n_seen"] + b["n_seen"]).astype(jnp.uint32), self.seed + 2)
        take_a = u < p
        return dict(
            values=jnp.where(take_a, a["values"], b["values"]),
            items=jnp.where(take_a, a["items"], b["items"]),
            n_seen=a["n_seen"] + b["n_seen"],
        )

    def memory_bytes(self) -> int:
        return self.sample_size * 8
