"""CountMin sketch [Cormode & Muthukrishnan 2005] — count/frequency estimation.

Parameters follow the paper's Table 1: (eps, delta) with w = ceil(e/eps)
(rounded up to a power of two so multiply-shift bucket hashing applies) and
d = ceil(ln(1/delta)). Estimate error <= eps * N with prob >= 1 - delta.

Merge = elementwise addition (CM sketches are linear).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from . import hashing


def _pow2_at_least(x: float) -> int:
    return max(1, int(math.ceil(math.log2(max(2.0, x)))))


@dataclasses.dataclass(frozen=True)
class CountMin:
    eps: float = 0.01
    delta: float = 0.01
    seed: int = 7
    weighted: bool = True   # value-weighted counts (paper uses counts of bids)

    merge_mode = "sum"      # linear sketch -> federated merge is one psum
    update_kernel = "countmin_scatter"   # kernels.ops registry name

    @property
    def depth(self) -> int:
        return max(1, int(math.ceil(math.log(1.0 / self.delta))))

    @property
    def log2_width(self) -> int:
        return _pow2_at_least(math.e / self.eps)

    @property
    def width(self) -> int:
        return 1 << self.log2_width

    def _seeds(self) -> jax.Array:
        return jnp.asarray(hashing.row_seeds(self.seed, self.depth))

    def init(self, key: jax.Array | None = None) -> jax.Array:
        del key
        return jnp.zeros((self.depth, self.width), dtype=jnp.float32)

    def add_batch(self, state: jax.Array, items: jax.Array,
                  values: jax.Array, mask: jax.Array) -> jax.Array:
        idx = hashing.bucket_hash(items, self._seeds(), self.log2_width)  # [T,d]
        v = (values if self.weighted else jnp.ones_like(values))
        v = (v * mask.astype(jnp.float32))[:, None]                        # [T,1]
        rows = jnp.arange(self.depth)[None, :]
        return state.at[rows, idx].add(jnp.broadcast_to(v, idx.shape))

    def stacked_add_batch(self, state: jax.Array, syn_idx: jax.Array,
                          items: jax.Array, values: jax.Array,
                          mask: jax.Array) -> jax.Array:
        """Update a stack of synopses [n, d, w] routed by syn_idx [T] —
        the vmap/slot-sharing path (thousands of CM sketches, one kernel)."""
        idx = hashing.bucket_hash(items, self._seeds(), self.log2_width)
        v = (values if self.weighted else jnp.ones_like(values))
        v = (v * mask.astype(jnp.float32))[:, None]
        rows = jnp.arange(self.depth)[None, :]
        return state.at[syn_idx[:, None], rows, idx].add(
            jnp.broadcast_to(v, idx.shape))

    def estimate(self, state: jax.Array, items: jax.Array) -> jax.Array:
        """Point frequency query for a batch of items."""
        idx = hashing.bucket_hash(items, self._seeds(), self.log2_width)
        rows = jnp.arange(self.depth)[None, :]
        return jnp.min(state[rows, idx], axis=-1)

    def stacked_estimate(self, state: jax.Array, rows: jax.Array,
                         items: jax.Array) -> jax.Array:
        """Batched point queries against a stack [n, d, w]: query q reads
        row ``rows[q]`` for its own ``items[q]`` — one gather, no per-row
        state materialization (the red-path twin of stacked_add_batch)."""
        idx = hashing.bucket_hash(items, self._seeds(), self.log2_width)
        d_idx = jnp.arange(self.depth)[None, None, :]          # [N, I, d]
        return jnp.min(state[rows[:, None, None], d_idx, idx], axis=-1)

    def merge(self, a: jax.Array, b: jax.Array) -> jax.Array:
        return a + b

    # -- inner product (used by the planner for approximate joins) ---------
    def inner_product(self, a: jax.Array, b: jax.Array) -> jax.Array:
        return jnp.min(jnp.sum(a * b, axis=-1))

    def memory_bytes(self) -> int:
        return self.depth * self.width * 4
