"""CoreSetTree (StreamKM++ [Ackermann et al. 2012]) — clustering coresets.

Merge-reduce tree over weighted point buckets: every ingested batch becomes
a level-0 bucket (padded to ``bucket_size``); two buckets at the same level
are reduced to one at the next level via kmeans++-style D^2 sampling. The
coreset is the union of occupied buckets; ``weighted_kmeans`` runs Lloyd
iterations over it (the paper's ExtractClusters stage).

Deviation recorded in DESIGN.md: buckets are batch-aligned instead of
exactly-m-point aligned (fixed shapes for jit); the merge-reduce semantics
and O(log N) bucket count are unchanged. Randomness is counter-hashed so
the tree is replayable.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import hashing

_LEVELS = 20        # supports 2^20 batches


@dataclasses.dataclass(frozen=True)
class CoreSetTree:
    bucket_size: int = 64
    dim: int = 2
    seed: int = 47

    merge_mode = "gather"

    def init(self, key: jax.Array | None = None) -> Dict[str, jax.Array]:
        del key
        return dict(
            points=jnp.zeros((_LEVELS, self.bucket_size, self.dim), jnp.float32),
            weights=jnp.zeros((_LEVELS, self.bucket_size), jnp.float32),
            occupied=jnp.zeros((_LEVELS,), bool),
            ticket=jnp.zeros((), jnp.uint32),
        )

    # -- D^2-sampling reduce: 2m weighted points -> m ----------------------
    def _reduce(self, pts: jax.Array, wts: jax.Array, ticket: jax.Array):
        m = self.bucket_size

        def pick(carry, i):
            mind2, chosen_idx, chosen_mask = carry
            probs = wts * mind2
            probs = jnp.where(chosen_mask, 0.0, probs)
            cum = jnp.cumsum(probs)
            u = hashing.uniform01(ticket * jnp.uint32(7919)
                                  + i.astype(jnp.uint32), self.seed)
            target = u * jnp.maximum(cum[-1], 1e-30)
            j = jnp.searchsorted(cum, target)
            j = jnp.clip(j, 0, pts.shape[0] - 1)
            d2 = jnp.sum((pts - pts[j]) ** 2, axis=-1)
            return ((jnp.minimum(mind2, d2), chosen_idx.at[i].set(j),
                     chosen_mask.at[j].set(True)), None)

        init = (jnp.full((pts.shape[0],), jnp.inf, jnp.float32),
                jnp.zeros((m,), jnp.int32),
                jnp.zeros((pts.shape[0],), bool))
        (mind2, idx, _), _ = jax.lax.scan(pick, init, jnp.arange(m))
        centers = pts[idx]
        # assign every point to nearest chosen center, sum weights
        d2 = jnp.sum((pts[:, None, :] - centers[None, :, :]) ** 2, axis=-1)
        assign = jnp.argmin(d2, axis=-1)
        new_w = jax.ops.segment_sum(wts, assign, num_segments=m)
        return centers, new_w

    def _insert(self, state, pts, wts):
        def cond(c):
            lvl, _, _, st = c
            return st["occupied"][lvl] & (lvl < _LEVELS - 1)

        def body(c):
            lvl, pts, wts, st = c
            both_p = jnp.concatenate([pts, st["points"][lvl]])
            both_w = jnp.concatenate([wts, st["weights"][lvl]])
            ticket = st["ticket"] + 1
            rp, rw = self._reduce(both_p, both_w, ticket)
            st = dict(st, occupied=st["occupied"].at[lvl].set(False),
                      ticket=ticket)
            return (lvl + 1, rp, rw, st)

        lvl, pts, wts, state = jax.lax.while_loop(
            cond, body, (jnp.zeros((), jnp.int32), pts, wts, state))
        return dict(
            points=state["points"].at[lvl].set(pts),
            weights=state["weights"].at[lvl].set(wts),
            occupied=state["occupied"].at[lvl].set(True),
            ticket=state["ticket"] + 1,
        )

    def add_batch(self, state, items, values, mask):
        """`values` is a [T, dim] (or [T] when dim == 1) point batch,
        T <= bucket_size."""
        del items
        v = values.astype(jnp.float32)
        if v.ndim == 1:
            v = v[:, None]
        t = v.shape[0]
        assert t <= self.bucket_size, "feed batches of <= bucket_size points"
        pad = self.bucket_size - t
        pts = jnp.pad(v, ((0, pad), (0, 0)))
        wts = jnp.pad(mask.astype(jnp.float32), (0, pad))
        return self._insert(state, pts, wts)

    def estimate(self, state) -> Dict[str, jax.Array]:
        """The coreset: stacked weighted points (weight 0 = inactive)."""
        occ = state["occupied"][:, None]
        w = jnp.where(occ, state["weights"], 0.0)
        return dict(points=state["points"].reshape(-1, self.dim),
                    weights=w.reshape(-1))

    def merge(self, a, b):
        """Insert b's occupied buckets into a (federated coreset union)."""
        state = a
        for lvl in range(_LEVELS):
            pts = b["points"][lvl]
            wts = jnp.where(b["occupied"][lvl], b["weights"][lvl], 0.0)
            # inserting a zero-weight bucket is a harmless no-op on estimates
            state = self._insert(state, pts, wts)
        return state

    def memory_bytes(self) -> int:
        return _LEVELS * self.bucket_size * (self.dim + 1) * 4


def weighted_kmeans(points: jax.Array, weights: jax.Array, k: int,
                    iters: int = 10, seed: int = 0):
    """Lloyd iterations over a weighted coreset (ExtractClusters)."""
    n = points.shape[0]
    u = hashing.uniform01(jnp.arange(n, dtype=jnp.uint32), seed)
    order = jnp.argsort(-weights * (1.0 + 0.01 * u))    # weight-biased init
    centers = points[order[:k]]

    def step(centers, _):
        d2 = jnp.sum((points[:, None, :] - centers[None, :, :]) ** 2, axis=-1)
        assign = jnp.argmin(d2, axis=-1)
        onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32) * weights[:, None]
        tot = jnp.sum(onehot, axis=0)
        new = (onehot.T @ points) / jnp.maximum(tot[:, None], 1e-9)
        centers = jnp.where(tot[:, None] > 0, new, centers)
        cost = jnp.sum(jnp.min(d2, axis=-1) * weights)
        return centers, cost

    centers, costs = jax.lax.scan(step, centers, None, length=iters)
    return centers, costs[-1]
