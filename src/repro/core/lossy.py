"""Lossy Counting [Manku & Motwani 2002] — frequent items / counts.

Implemented as the Misra-Gries / Space-Saving fixed-table variant with
k = ceil(1/eps) slots: identical eps*N error guarantee, fixed shapes
(TPU-friendly), and — unlike textbook Lossy Counting — MERGEABLE in the
sense of Agarwal et al. [11] (the paper's own mergeability reference).

Deviation recorded in DESIGN.md: bucket-boundary deletions are replaced by
min-count eviction; guarantees are equivalent (err <= N/k <= eps*N).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

_EMPTY = np.uint32(0xFFFFFFFF)


@dataclasses.dataclass(frozen=True)
class LossyCounting:
    eps: float = 0.01
    seed: int = 31

    merge_mode = "gather"

    @property
    def k(self) -> int:
        return max(4, int(math.ceil(1.0 / self.eps)))

    def init(self, key: jax.Array | None = None) -> Dict[str, jax.Array]:
        del key
        return dict(
            keys=jnp.full((self.k,), _EMPTY, jnp.uint32),
            counts=jnp.zeros((self.k,), jnp.float32),
            error=jnp.zeros((self.k,), jnp.float32),
        )

    def _step(self, s, item, v, valid):
        keys, counts, error = s["keys"], s["counts"], s["error"]
        hit = keys == item
        any_hit = jnp.any(hit)
        empty = keys == _EMPTY
        any_empty = jnp.any(empty)
        # slot selection: matching slot; else first empty; else min-count
        hit_slot = jnp.argmax(hit)
        empty_slot = jnp.argmax(empty)
        min_slot = jnp.argmin(counts)
        slot = jnp.where(any_hit, hit_slot,
                         jnp.where(any_empty, empty_slot, min_slot))
        evict = (~any_hit) & (~any_empty)
        new_err = jnp.where(evict, counts[slot], error[slot])
        base = jnp.where(any_hit, counts[slot],
                         jnp.where(any_empty, 0.0, counts[slot]))
        new_keys = keys.at[slot].set(jnp.where(valid, item, keys[slot]))
        new_counts = counts.at[slot].set(
            jnp.where(valid, base + v, counts[slot]))
        new_error = error.at[slot].set(jnp.where(valid, new_err, error[slot]))
        return dict(keys=new_keys, counts=new_counts, error=new_error)

    def add_batch(self, state, items, values, mask):
        def body(s, t):
            item, v, valid = t
            return self._step(s, item, v, valid), None

        state, _ = jax.lax.scan(
            body, state,
            (items.astype(jnp.uint32), values.astype(jnp.float32), mask))
        return state

    def estimate(self, state, items: jax.Array) -> jax.Array:
        """Frequency estimates (0 when not tracked); over-count <= eps*N."""
        eq = state["keys"][None, :] == items.astype(jnp.uint32)[:, None]
        return jnp.sum(jnp.where(eq, state["counts"][None, :], 0.0), axis=-1)

    def stacked_estimate(self, state, rows: jax.Array,
                         items: jax.Array) -> jax.Array:
        """Batched frequency queries: query q matches ``items[q]`` against
        the key table of row ``rows[q]`` — [N, I] from one table gather."""
        keys = state["keys"][rows]                             # [N, k]
        counts = state["counts"][rows]
        eq = keys[:, None, :] == items.astype(jnp.uint32)[:, :, None]
        return jnp.sum(jnp.where(eq, counts[:, None, :], 0.0), axis=-1)

    def frequent_items(self, state, min_count: float):
        keep = (state["counts"] - state["error"]) >= min_count
        return state["keys"], state["counts"], keep

    def merge(self, a, b):
        """Mergeable-summaries merge: coalesce matching keys, keep top-k,
        subtract the (k+1)-th largest residual count (Agarwal et al.)."""
        keys = jnp.concatenate([a["keys"], b["keys"]])
        counts = jnp.concatenate([a["counts"], b["counts"]])
        error = jnp.concatenate([a["error"], b["error"]])
        # coalesce duplicates (O(k^2) compare — k is small by construction)
        eq = (keys[:, None] == keys[None, :]) & (keys[:, None] != _EMPTY)
        first = jnp.argmax(eq, axis=1)              # representative slot
        is_rep = first == jnp.arange(keys.shape[0])
        summed = jnp.sum(jnp.where(eq, counts[None, :], 0.0), axis=1)
        err = jnp.max(jnp.where(eq, error[None, :], 0.0), axis=1)
        counts = jnp.where(is_rep & (keys != _EMPTY), summed, 0.0)
        error = jnp.where(is_rep & (keys != _EMPTY), err, 0.0)
        keys = jnp.where(is_rep & (counts > 0), keys, _EMPTY)
        order = jnp.argsort(-counts)
        kth = counts[order[self.k]] if counts.shape[0] > self.k else 0.0
        topk = order[: self.k]
        new_counts = jnp.maximum(counts[topk] - kth, 0.0)
        return dict(
            keys=jnp.where(new_counts > 0, keys[topk], _EMPTY),
            counts=new_counts,
            error=jnp.where(new_counts > 0, error[topk] + kth, 0.0),
        )

    def memory_bytes(self) -> int:
        return self.k * 12
