"""Serving driver: batched prefill + decode against KV/SSM caches.

CPU-runnable on reduced configs; the full-config serve_step programs are
exercised by the dry-run (decode_32k / long_500k cells).

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import model as M


def serve(cfg, params, prompts: np.ndarray, gen: int, s_max: int):
    """prompts [B, P] int32 -> generated [B, gen]."""
    b, p = prompts.shape
    logits, pre_caches, _ = M.forward(
        cfg, params, dict(tokens=jnp.asarray(prompts)), want_caches=True,
        last_logit_only=True)

    caches = M.init_caches(cfg, b, s_max)
    # install prefill caches (attention caches pad to s_max; ssm as-is)
    def install(serve_leaf, pre_leaf):
        if serve_leaf.shape == pre_leaf.shape:
            return pre_leaf
        pad = [(0, 0)] * pre_leaf.ndim
        pad[2] = (0, serve_leaf.shape[2] - pre_leaf.shape[2])
        return jnp.pad(pre_leaf, pad)

    new_caches = {}
    for k, v in caches.items():
        pc = pre_caches[k]
        new_caches[k] = jax.tree.map(install, v, pc)

    step = jax.jit(lambda pr, c, t, pos: M.decode_step_fn(cfg, pr, c, t, pos))
    tok = jnp.argmax(logits[:, -1, :], -1)
    out = [np.asarray(tok)]
    caches = new_caches
    for i in range(gen - 1):
        logits_i, caches = step(params, caches, tok, jnp.int32(p + i))
        tok = jnp.argmax(logits_i, -1)
        out.append(np.asarray(tok))
    return np.stack(out, 1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=list(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = reduced(ARCHS[args.arch])
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.RandomState(args.seed)
    prompts = rng.randint(0, cfg.vocab,
                          (args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    gen = serve(cfg, params, prompts, args.gen,
                s_max=args.prompt_len + args.gen)
    dt = time.time() - t0
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} generated={gen.shape[1]} tokens "
          f"in {dt:.2f}s ({args.batch*gen.shape[1]/dt:.1f} tok/s)")
    print("first sequences:", gen[:2, :8].tolist())
    return gen


if __name__ == "__main__":
    main()
