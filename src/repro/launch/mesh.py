"""Production mesh construction (a FUNCTION — importing this module never
touches jax device state).

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model); the pod axis
carries data parallelism + federated synopsis merges over DCN.
"""
from __future__ import annotations

import jax

from repro.configs.base import ModelConfig
from repro.sharding.specs import MeshRules


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_federation_mesh(n_sites: int | None = None, *, devices=None):
    """1-D mesh whose single ``site`` axis carries the federation's
    synopsis merges: one lead device per geo-dispersed site, the axis
    playing the role of the DCN links between clusters. Pass the result
    to ``Federation(mesh=...)`` — each site's SDE state is pinned to its
    slice and ``federated.merge_over_axis`` runs over the axis. On a
    production multi-pod mesh, hand ``make_production_mesh(multi_pod=
    True)`` to ``Federation`` instead: the ``pod`` axis plays the site
    role and the federation takes one lead device per pod."""
    import numpy as np
    devs = list(devices) if devices is not None else jax.devices()
    n = n_sites if n_sites is not None else len(devs)
    if not 1 <= n <= len(devs):
        raise ValueError(
            f"federation mesh needs one device per site: asked for {n} "
            f"sites, have {len(devs)} devices")
    from jax.sharding import Mesh
    return Mesh(np.asarray(devs[:n]), ("site",))


def try_federation_mesh(n_sites: int, *, devices=None):
    """``make_federation_mesh`` when the host has a device per site, else
    None — the one-liner demos/benchmarks use to fall back to the
    host-merge federation on single-device machines."""
    devs = list(devices) if devices is not None else jax.devices()
    if len(devs) < n_sites:
        return None
    return make_federation_mesh(n_sites, devices=devs)


def make_debug_mesh(n_devices: int | None = None):
    """Small mesh over whatever devices exist (tests)."""
    n = n_devices or len(jax.devices())
    if n >= 4:
        return jax.make_mesh((n // 2, 2), ("data", "model"))
    return jax.make_mesh((n, 1), ("data", "model"))


def rules_for(cfg: ModelConfig, *, mode: str = "train") -> MeshRules:
    """Per-architecture sharding rules (see DESIGN.md and configs)."""
    if not cfg.tensor_parallel and mode == "train":
        # small models, TRAIN: batch over (data, model) — 256-way DP
        # inside a pod, plus pure cross-pod DP on the pod axis; weights
        # FSDP over "model"; zero TP collectives. Serving keeps TP rules
        # (the 32k KV caches need the model axis; §Perf iterations).
        return MeshRules(
            batch=("data", "model"),
            fsdp="model", tensor=None, expert=None, seq=None,
            kv_seq="model",
        )
    fsdp = "data" if cfg.dense_fsdp else None
    if mode in ("prefill", "decode"):
        # serving: re-gathering FSDP weights every decoded token dominates
        # the step. Replicate over the data axis whenever the TP shard of
        # the NON-expert weights fits HBM (expert stacks are managed
        # separately: EP-resident decode or shard_map FSDP).
        if cfg.dense_param_count() * 2 / 16 < 12e9:
            fsdp = None
    return MeshRules(
        batch=("pod", "data"),
        fsdp=fsdp,
        tensor="model",
        expert=cfg.expert_axis,
        seq=("model" if (cfg.seq_shard_activations and mode == "train")
             else None),
        kv_seq="model",
    )
