"""Trip-count-aware cost model over compiled HLO text.

XLA's `compiled.cost_analysis()` counts while-loop bodies ONCE — useless
for scan-over-layers models (an 80-layer model reports 1 layer of FLOPs).
This analyzer walks the HLO computation graph, multiplies every while body
by its `known_trip_count` backend config, and accounts:

  flops            2*M*K*N per dot (dots dominate transformer FLOPs)
  bytes            per top-level op: operands + outputs (fusion = one
                   kernel, matching XLA's bytes-accessed convention)
  collective bytes output size per all-gather/all-reduce/reduce-scatter/
                   all-to-all/collective-permute, trip-multiplied

All values are per-device (the HLO module is the per-device SPMD program).
"""
from __future__ import annotations

import json
import re
from typing import Dict, List, Tuple

_DT_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
             "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
             "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(
    r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128|"
    r"f8e4m3fn|f8e5m2)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "iota"}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DT_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class _Computation:
    def __init__(self, name: str):
        self.name = name
        self.lines: List[str] = []
        self.symbols: Dict[str, str] = {}     # %name -> type string


def _parse_computations(text: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    current = None
    entry = None
    for line in text.splitlines():
        m = re.match(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->.*{", line)
        if m:
            current = _Computation(m.group(2))
            comps[current.name] = current
            if m.group(1):
                entry = current.name
            continue
        if line.startswith("}"):
            current = None
            continue
        if current is None:
            continue
        current.lines.append(line)
        d = _DEF_RE.match(line)
        if d:
            rhs = d.group(2)
            # the type is the leading "(tuple)" or scalar type of the rhs
            tm = re.match(r"^(\([^=]*?\)|[\w\[\],]+(?:\{[\d,]*\})?)", rhs)
            current.symbols["%" + d.group(1)] = tm.group(1) if tm else ""
    if entry:
        comps["__entry__"] = comps[entry]
    return comps


def _opcode_of(rhs_after_type: str) -> str:
    m = re.match(r"\s*([\w\-]+)\(", rhs_after_type)
    return m.group(1) if m else ""


def analyze_hlo(text: str) -> Dict:
    comps = _parse_computations(text)
    entry = comps.get("__entry__")
    if entry is None:
        return dict(flops=0.0, bytes=0.0, collective_bytes=0.0,
                    collectives={}, note="no ENTRY found")

    # multipliers: computation name -> accumulated trip multiplier
    mult: Dict[str, float] = {entry.name: 1.0}
    fused_internal: set = set()
    order = [entry.name]
    seen = {entry.name}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult.get(cname, 1.0)
        for line in comp.lines:
            wm = re.search(r"body=%([\w.\-]+), *condition=%([\w.\-]+)|"
                           r"condition=%([\w.\-]+), *body=%([\w.\-]+)", line)
            if wm and " while(" in line:
                body = wm.group(1) or wm.group(4)
                cond = wm.group(2) or wm.group(3)
                trip = 1.0
                tm = re.search(r'"known_trip_count":{"n":"(\d+)"}', line)
                if tm:
                    trip = float(tm.group(1))
                for target, f in ((body, trip), (cond, trip + 1)):
                    mult[target] = mult.get(target, 0.0) + m * f
                    if target not in seen:
                        seen.add(target)
                        order.append(target)
            for ref in re.findall(r"calls=%([\w.\-]+)", line):
                fused_internal.add(ref)
                mult[ref] = mult.get(ref, 0.0) + m
                if ref not in seen:
                    seen.add(ref)
                    order.append(ref)
            for ref in re.findall(r"to_apply=%([\w.\-]+)", line):
                fused_internal.add(ref)

    flops = 0.0
    bytes_total = 0.0        # upper bound: every top-level kernel
    bytes_dot = 0.0          # roofline model: dot traffic only (perfect
    #                          elementwise fusion assumed — TPU-realistic)
    coll_bytes = {k: 0.0 for k in _COLLECTIVES}
    coll_counts = {k: 0.0 for k in _COLLECTIVES}

    for cname in seen:
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult.get(cname, 1.0)
        count_bytes = cname not in fused_internal
        for line in comp.lines:
            d = _DEF_RE.match(line)
            if not d:
                continue
            rhs = d.group(2)
            tm = re.match(r"^(\([^=]*?\)|[\w\[\],]+(?:\{[\d,]*\})?)\s*(.*)$",
                          rhs)
            if not tm:
                continue
            out_type, rest = tm.group(1), tm.group(2)
            op = _opcode_of(rest)
            # ---- flops: dots (incl. inside fusions) ----
            if op in ("dot", "dot-general") or " dot(" in rhs:
                out_dims = _shape_dims(out_type)
                out_elems = 1
                for x in out_dims:
                    out_elems *= x
                cm = re.search(r"lhs_contracting_dims={([0-9,]*)}", rhs)
                k = 1
                op_bytes = _shape_bytes(out_type)
                am = re.search(r"dot\((%[\w.\-]+),\s*(%[\w.\-]+)", rhs)
                if am:
                    for ref in am.groups():
                        if ref in comp.symbols:
                            op_bytes += _shape_bytes(comp.symbols[ref])
                if cm and am and am.group(1) in comp.symbols:
                    lhs_dims = _shape_dims(comp.symbols[am.group(1)])
                    for ci in cm.group(1).split(","):
                        if ci and int(ci) < len(lhs_dims):
                            k *= lhs_dims[int(ci)]
                flops += m * 2.0 * out_elems * k
                bytes_dot += m * op_bytes
            # ---- bytes: top-level kernels only ----
            if count_bytes and op and op not in _FREE_OPS:
                b = _shape_bytes(out_type)
                for ref in re.findall(r"(%[\w.\-]+)", rest):
                    if ref in comp.symbols:
                        b += _shape_bytes(comp.symbols[ref])
                bytes_total += m * b
            # ---- collectives ----
            for c in _COLLECTIVES:
                if op == c or op == c + "-start":
                    cb = _shape_bytes(out_type)
                    coll_bytes[c] += m * cb
                    coll_counts[c] += m
                    break

    # arguments (params/caches) are read at least once per step
    arg_bytes = 0.0
    for line in entry.lines:
        d = _DEF_RE.match(line)
        if d and " parameter(" in d.group(2):
            arg_bytes += _shape_bytes(comps[entry.name].symbols
                                      ["%" + d.group(1)])
    return dict(
        flops=flops,
        bytes=bytes_dot + arg_bytes,
        bytes_upper=bytes_total,
        arg_bytes=arg_bytes,
        collective_bytes=sum(coll_bytes.values()),
        collectives=dict(bytes_by_op=coll_bytes, counts=coll_counts),
    )
