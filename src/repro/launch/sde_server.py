"""JSON-lines SDEaaS front end — the launch-layer driver for the engine.

One JSON request per input line (the paper's Kafka RequestTopic contract,
Section 3), one JSON response per output line. Blue-path data rides the
same channel as control/queries via ``{"type": "ingest", ...}`` — its
ack carries the monotonic batch counter — and ``{"type": "flush"}`` is
the explicit pipeline barrier. Continuous-query responses are
interleaved into the output as their batches retire: immediately after
each request on an eager engine, deferred until the bounded pipeline
retires the batch (or a flush/fence drains it) on a pipelined one. EOF
performs a final flush so no continuous response is ever lost.

  PYTHONPATH=src python -m repro.launch.sde_server --pipelined \
      < requests.jsonl > responses.jsonl
"""
from __future__ import annotations

import argparse
import sys
from typing import IO, Iterable, Optional

from repro.service import SDE


def _drain_continuous(sde: SDE, out: IO[str]) -> int:
    """Pop every retired continuous response onto the wire (in emission
    order — the log is append-right, so we pop from the left)."""
    n = 0
    while sde.continuous_out:
        out.write(sde.continuous_out.popleft().to_json() + "\n")
        n += 1
    return n


def serve_lines(lines: Iterable[str], sde: Optional[SDE] = None, *,
                out: IO[str] = sys.stdout) -> int:
    """Drive ``sde`` (or a fresh eager/env-default engine) with
    JSON-lines requests; write one response line per request plus the
    continuous responses retired so far. Construct the SDE yourself to
    pick the execution mode (``SDE(pipelined=True, ...)``). Returns the
    number of requests handled."""
    if sde is None:
        sde = SDE()
    n_requests = 0
    for line in lines:
        line = line.strip()
        if not line:
            continue
        out.write(sde.handle(line).to_json() + "\n")
        n_requests += 1
        _drain_continuous(sde, out)
    sde.flush()                      # final barrier: retire everything
    _drain_continuous(sde, out)
    return n_requests


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--pipelined", action="store_true",
                    help="bounded async ingest queue (deferred emission)")
    ap.add_argument("--depth", type=int, default=2,
                    help="pipeline depth (in-flight ingest batches)")
    ap.add_argument("--input", default="-",
                    help="requests file, '-' for stdin")
    args = ap.parse_args(argv)
    lines = sys.stdin if args.input == "-" else open(args.input)
    sde = SDE(pipelined=args.pipelined, pipeline_depth=args.depth)
    n = serve_lines(lines, sde)
    print(f"[sde-server] handled {n} requests; "
          f"{sde.tuples_ingested:,} tuples in {sde.batches_ingested} "
          f"batches; continuous dropped={sde.continuous_out.dropped}",
          file=sys.stderr)
    return n


if __name__ == "__main__":
    main()
