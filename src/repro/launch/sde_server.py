"""SDEaaS front end — JSON-lines driver and multi-client socket server.

Two serving modes over the same JSON request contract (the paper's Kafka
RequestTopic, Section 3):

  * **JSON-lines** (default; kept for tests and one-shot replay): one
    request per input line, one response per output line, continuous
    responses interleaved as their batches retire. EOF — or a
    ``{"type": "shutdown"}`` request — performs a final flush so no
    continuous response is ever lost.

      PYTHONPATH=src python -m repro.launch.sde_server --pipelined \
          < requests.jsonl > responses.jsonl

  * **Socket server** (``--port``): N concurrent newline-delimited-JSON
    TCP clients multiplexed onto ONE engine through the
    ``SynopsisGateway`` micro-batcher — concurrent ingest coalesces to
    one fused blue-path dispatch per kind per tick, concurrent queries
    to one stacked-estimate dispatch, and each connection receives only
    its own acks plus the continuous responses of the synopses it
    built. Admission control (``--max-in-flight``) delays reads — and
    therefore acks — when a client floods, pushing backpressure into
    its TCP window instead of the engine's queue.

      PYTHONPATH=src python -m repro.launch.sde_server --port 7077 \
          --pipelined
"""
from __future__ import annotations

import argparse
import asyncio
import contextlib
import itertools
import json
import sys
from typing import IO, Iterable, Optional

from repro.service import SDE, api
from repro.service.gateway import SynopsisGateway


def _drain_continuous(sde: SDE, out: IO[str]) -> int:
    """Write every retired continuous response (emission order) with ONE
    write call — a pipelined drain can retire thousands of responses at
    once, and one syscall per response dominated the drain cost."""
    rs = sde.continuous_out.drain()
    if rs:
        out.write("".join(r.to_json() + "\n" for r in rs))
    return len(rs)


def serve_lines(lines: Iterable[str], sde: Optional[SDE] = None, *,
                out: IO[str] = sys.stdout, reconciler=None,
                wal=None, checkpointer=None) -> int:
    """Drive ``sde`` (or a fresh eager/env-default engine) with
    JSON-lines requests; write one response line per request plus the
    continuous responses retired so far. Construct the SDE yourself to
    pick the execution mode (``SDE(pipelined=True, ...)``). Stops after
    acking a successful ``shutdown`` (the engine has already flushed and
    closed); plain EOF gets the same final flush. A ``reconciler``
    rides the request loop (``maybe_step`` after each request — its
    interval does the throttling); a ``wal`` (service/wal.py) records
    every state-mutating request durably before its ack line is written
    (lifecycle requests pre-apply, ingest post-apply keyed by the
    engine-assigned batch id — a refused ingest never reaches the log),
    and a ``checkpointer`` snapshots every N ingested batches. Returns
    the number of requests handled."""
    if sde is None:
        sde = SDE()
    n_requests = 0
    for line in lines:
        line = line.strip()
        if not line:
            continue
        try:
            req = json.loads(line)
        except json.JSONDecodeError:
            req = line               # engine's handler reports the error
        seq = None
        rtype = req.get("type") if isinstance(req, dict) else None
        if wal is not None and rtype in api.MUTATING_REQUESTS:
            # lifecycle: logged pre-apply (replay re-executes verbatim;
            # a request that fails live fails identically on replay). A
            # WAL write error must not kill serving — the request is
            # refused instead, keeping "acked => in the WAL" intact.
            try:
                seq = wal.append_request(req)
                wal.sync()           # durable before apply AND ack
            except Exception as e:  # noqa: BLE001 - serving must survive
                out.write(api.Response(
                    request_id=str(req.get("request_id", "")), ok=False,
                    error=f"WAL append failed: {e!r}").to_json() + "\n")
                n_requests += 1
                continue
        resp = sde.handle(req)
        if wal is not None and rtype == "ingest_multidim" and resp.ok:
            # multidim ingest is a data record too: logged post-apply,
            # keyed by the engine-assigned batch id, replayed through
            # ``sde.handle`` (the expansion is deterministic per spec)
            try:
                seq = wal.append_ingest_multidim(resp.value["batch"], req)
                wal.sync()           # durable before ack
            except Exception as e:  # noqa: BLE001 - serving must survive
                resp = api.Response(
                    request_id=resp.request_id, ok=False,
                    error=f"ingested but WAL append failed: {e!r}")
                seq = None
        if wal is not None and rtype == "ingest" and resp.ok:
            # ingest: logged POST-apply with the batch id the engine
            # actually assigned — a malformed batch the engine refused
            # (acked with an error, no batch id) never reaches the log,
            # so replay cannot be poisoned or consume an acked id
            try:
                seq = wal.append_ingest(
                    resp.value["batch"], req.get("stream_ids", []),
                    req.get("values", []), req.get("mask"))
                wal.sync()           # durable before ack
            except Exception as e:  # noqa: BLE001 - serving must survive
                # applied but not durable: ack an error so no client
                # counts on this batch surviving a crash
                resp = api.Response(
                    request_id=resp.request_id, ok=False,
                    error=f"ingested but WAL append failed: {e!r}")
                seq = None
        if seq is not None:
            sde.wal_seq = seq
        out.write(resp.to_json() + "\n")
        n_requests += 1
        _drain_continuous(sde, out)
        if resp.ok and isinstance(req, dict) \
                and req.get("type") == "shutdown":
            return n_requests        # shutdown already flushed + closed
        if checkpointer is not None:
            try:
                checkpointer.maybe_snapshot()
            except Exception as e:  # noqa: BLE001 - serving must survive
                print(f"[sde-server] checkpoint error: {e!r}",
                      file=sys.stderr)
        if reconciler is not None:
            try:
                reconciler.maybe_step()
            except Exception as e:  # noqa: BLE001 - serving must survive
                print(f"[sde-server] reconcile error: {e!r}",
                      file=sys.stderr)
    sde.flush()                      # final barrier: retire everything
    _drain_continuous(sde, out)
    return n_requests


async def serve_socket(sde: Optional[SDE] = None,
                       host: str = "127.0.0.1", port: int = 0, *,
                       tick_interval: float = 0.001,
                       max_in_flight: int = 8,
                       client_log_cap: Optional[int] = 1024,
                       ready: Optional[asyncio.Future] = None,
                       err: IO[str] = sys.stderr,
                       reconciler=None, wal=None,
                       checkpointer=None) -> SynopsisGateway:
    """Run the multi-client socket server until a client sends a
    successful ``{"type": "shutdown"}``. ``port=0`` binds an ephemeral
    port; the bound port is announced on ``err`` and resolved into
    ``ready`` (when given), so tests can connect without racing. A
    ``reconciler`` rides the gateway tick; so do the durability hooks —
    ``wal`` (fsynced once per tick, before its acks go out) and
    ``checkpointer`` (incremental snapshot every N ingested batches).
    Returns the gateway (engine closed, probes/commit log intact)."""
    gw = SynopsisGateway(sde, tick_interval=tick_interval,
                         max_in_flight=max_in_flight,
                         client_log_cap=client_log_cap,
                         reconciler=reconciler, wal=wal,
                         checkpointer=checkpointer)
    await gw.start()
    conn_seq = itertools.count()
    writers = set()

    async def handle_conn(reader: asyncio.StreamReader,
                          writer: asyncio.StreamWriter) -> None:
        client = gw.connect(f"conn-{next(conn_seq)}")
        writers.add(writer)
        wlock = asyncio.Lock()       # acks and continuous pushes interleave
        pending = set()

        async def write_lines(text: str) -> None:
            async with wlock:
                writer.write(text.encode())
                await writer.drain()

        async def finish(fut) -> None:
            try:
                await write_lines((await fut).to_json() + "\n")
            except (ConnectionError, RuntimeError):
                pass                 # client gone mid-ack
            finally:
                client.release()

        async def push_continuous() -> None:
            while True:
                await client.wakeup.wait()
                client.wakeup.clear()
                rs = client.log.drain()
                if rs:
                    await write_lines(
                        "".join(r.to_json() + "\n" for r in rs))

        pusher = asyncio.create_task(push_continuous())
        try:
            while True:
                # admission control: no read until a response slot frees,
                # so a flooding client sees delayed acks (TCP backpressure)
                await client.admit()
                line = await reader.readline()
                if not line:
                    client.release()
                    break
                line = line.strip()
                if not line:
                    client.release()
                    continue
                try:
                    req = json.loads(line)
                    if not isinstance(req, dict):
                        raise ValueError("request must be a JSON object")
                except Exception as e:  # noqa: BLE001 - report, keep serving
                    await write_lines(api.Response(
                        request_id="", ok=False,
                        error=repr(e)).to_json() + "\n")
                    client.release()
                    continue
                task = asyncio.create_task(
                    finish(gw.submit_nowait(client, req)))
                pending.add(task)
                task.add_done_callback(pending.discard)
                if req.get("type") == "shutdown":
                    break            # ack (in flight) is this conn's last line
        finally:
            if pending:
                await asyncio.gather(*pending, return_exceptions=True)
            pusher.cancel()
            with contextlib.suppress(asyncio.CancelledError):
                await pusher
            rs = client.log.drain()
            if rs:                   # final push of routed continuous output
                with contextlib.suppress(ConnectionError, RuntimeError):
                    await write_lines(
                        "".join(r.to_json() + "\n" for r in rs))
            gw.disconnect(client)
            writers.discard(writer)
            with contextlib.suppress(ConnectionError):
                writer.close()

    server = await asyncio.start_server(handle_conn, host, port)
    bound = server.sockets[0].getsockname()[1]
    print(f"[sde-server] listening on {host}:{bound}", file=err, flush=True)
    if ready is not None and not ready.done():
        ready.set_result(bound)
    async with server:
        await gw.closed_event.wait()
        await asyncio.sleep(0.05)    # let shutdown acks reach their clients
        server.close()
        await server.wait_closed()
        for w in list(writers):      # EOF every idle connection
            with contextlib.suppress(ConnectionError):
                w.close()
        while writers:               # their handlers finish promptly
            await asyncio.sleep(0.01)
    await gw.stop()
    return gw


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--pipelined", action="store_true",
                    help="bounded async ingest queue (deferred emission)")
    ap.add_argument("--depth", type=int, default=2,
                    help="pipeline depth (in-flight ingest batches)")
    ap.add_argument("--input", default="-",
                    help="requests file, '-' for stdin (JSON-lines mode)")
    ap.add_argument("--port", type=int, default=None,
                    help="serve N concurrent TCP clients through the "
                         "micro-batching gateway (0 = ephemeral port)")
    ap.add_argument("--host", default="127.0.0.1",
                    help="bind address for --port mode")
    ap.add_argument("--tick", type=float, default=0.001,
                    help="gateway micro-batch tick interval, seconds")
    ap.add_argument("--max-in-flight", type=int, default=8,
                    help="per-client admission-control window")
    ap.add_argument("--reconcile-interval", type=float, default=None,
                    help="run the elasticity reconciler every S seconds "
                         "(off the gateway tick in --port mode, off the "
                         "request loop otherwise)")
    ap.add_argument("--reconcile-hll", default="reconcile-hll",
                    help="synopsis id of the estimator HLL "
                         "(#pieces of work)")
    ap.add_argument("--reconcile-cm", default="reconcile-cm",
                    help="synopsis id of the estimator CountMin "
                         "(per-piece load)")
    ap.add_argument("--reconcile-workers", type=int, default=None,
                    help="worker-slice count for placement (default: the "
                         "synopsis mesh axis size)")
    ap.add_argument("--wal", default=None, metavar="PATH",
                    help="write-ahead ingest log: every state-mutating "
                         "request is durable (fsynced) before its ack")
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="take periodic engine snapshots into DIR")
    ap.add_argument("--checkpoint-interval", type=int, default=8,
                    help="snapshot every N ingested batches (default 8)")
    ap.add_argument("--checkpoint-keep", type=int, default=3,
                    help="keep-k snapshot GC (delta bases are protected)")
    ap.add_argument("--rebase-every", type=int, default=8,
                    help="fold the delta chain into a fresh full base "
                         "every N deltas (default 8)")
    ap.add_argument("--full-snapshots", action="store_true",
                    help="synchronous full snapshots instead of "
                         "incremental async deltas (the pre-durability "
                         "baseline; fig12 measures the difference)")
    ap.add_argument("--recover", action="store_true",
                    help="restore the latest snapshot from "
                         "--checkpoint-dir and replay the --wal tail "
                         "before serving")
    args = ap.parse_args(argv)
    from repro.service import wal as wal_mod
    if args.recover:
        sde = wal_mod.recover(args.checkpoint_dir, args.wal,
                              pipelined=args.pipelined)
        print(f"[sde-server] recovered: {sde.batches_ingested} batches, "
              f"{len(sde.entries)} synopses, wal_seq={sde.wal_seq}",
              file=sys.stderr, flush=True)
    else:
        sde = SDE(pipelined=args.pipelined, pipeline_depth=args.depth)
    wal = (wal_mod.WriteAheadLog(args.wal, tag=sde.site)
           if args.wal else None)
    checkpointer = (wal_mod.Checkpointer(
        sde, args.checkpoint_dir, interval=args.checkpoint_interval,
        keep=args.checkpoint_keep, rebase_every=args.rebase_every,
        incremental=not args.full_snapshots,
        async_=not args.full_snapshots, wal=wal)
        if args.checkpoint_dir else None)
    reconciler = None
    if args.reconcile_interval is not None:
        from repro.service.reconciler import Reconciler
        # None when the flag is unset — the Reconciler then infers the
        # synopsis mesh axis size (the documented default) and raises a
        # clear ValueError when there is neither a mesh nor a flag
        reconciler = Reconciler(
            sde, args.reconcile_hll, args.reconcile_cm,
            n_workers=args.reconcile_workers,
            interval=args.reconcile_interval)
    try:
        if args.port is not None:
            gw = asyncio.run(serve_socket(
                sde, args.host, args.port, tick_interval=args.tick,
                max_in_flight=args.max_in_flight, reconciler=reconciler,
                wal=wal, checkpointer=checkpointer))
            n = gw.requests
        elif args.input == "-":
            n = serve_lines(sys.stdin, sde, reconciler=reconciler,
                            wal=wal, checkpointer=checkpointer)
        else:
            with open(args.input) as fh:
                n = serve_lines(fh, sde, reconciler=reconciler,
                                wal=wal, checkpointer=checkpointer)
        print(f"[sde-server] handled {n} requests; "
              f"{sde.tuples_ingested:,} tuples in {sde.batches_ingested} "
              f"batches; continuous dropped={sde.continuous_out.dropped}",
              file=sys.stderr)
        return n
    finally:
        if wal is not None:
            wal.close()
        sde.wait_for_snapshot()      # join the background save, if any
        sde.close()                  # idempotent after a shutdown request


if __name__ == "__main__":
    main()
