import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede every other import: jax locks the device count on first
#   init. The dry-run (and only the dry-run) runs on 512 placeholder
#   devices so jax.make_mesh can build the production meshes.
"""Multi-pod dry-run: .lower().compile() every (architecture x input-shape
x mesh) cell, prove the distribution config is coherent, and extract the
roofline terms (memory_analysis + cost_analysis + collective-byte scan of
the compiled HLO).

Usage:
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --out benchmarks/out
"""
import argparse
import dataclasses
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, SHAPES
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import make_production_mesh, rules_for
from repro.models import model as M
from repro.sharding.specs import MeshRules, constrainer, sharding_for
from repro.training import optim, train_step as TS
from repro.launch.hlo_cost import analyze_hlo

# TPU v5e-class hardware constants (roofline denominators)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


# ---------------------------------------------------------------------------
# shardings for states / batches
# ---------------------------------------------------------------------------
def _attach(shape_tree, axes_tree, rules: MeshRules, mesh):
    """ShapeDtypeStructs + logical axes -> sharded ShapeDtypeStructs."""
    is_axes = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)

    def one(sds, axes):
        sh = sharding_for(rules, axes, mesh, sds.shape)
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sh)

    return jax.tree.map(one, shape_tree, axes_tree, is_leaf=is_axes)


def _batch_axes(specs: Dict[str, jax.ShapeDtypeStruct]):
    out = {}
    for k, v in specs.items():
        if k == "pos":
            out[k] = ()
        elif k == "embeds":
            out[k] = ("batch", "seq", None)
        else:
            out[k] = ("batch",) + (None,) * (len(v.shape) - 1)
    return out


def runnable(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """None if the cell runs; otherwise the documented skip reason."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("skip: pure full-attention arch at 524k context "
                "(sub-quadratic rule; see DESIGN.md)")
    return None


# ---------------------------------------------------------------------------
# the three lowered programs
# ---------------------------------------------------------------------------
def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               rules_override: Optional[MeshRules] = None,
               grad_accum: int = 1):
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    skip = runnable(cfg, shape)
    if skip:
        raise RuntimeError(skip)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_override or rules_for(cfg, mode=shape.mode)
    constrain = constrainer(rules, mesh)
    opt_cfg = optim.OptConfig(name=cfg.optimizer)
    hooks = TS.TrainHooks()

    spmd = (mesh, rules, shape.mode) if cfg.n_experts else None
    with mesh:
        if shape.mode == "train":
            state_shapes = jax.eval_shape(
                lambda: TS.init_train_state(cfg, opt_cfg,
                                            jax.random.PRNGKey(0), hooks))
            state_axes = TS.state_logical_axes(cfg, opt_cfg, hooks)
            state_in = _attach(state_shapes, state_axes, rules, mesh)
            specs = M.input_specs(cfg, shape)
            batch_in = _attach(specs, _batch_axes(specs), rules, mesh)
            fn = TS.make_train_step(cfg, opt_cfg, constrain,
                                    grad_accum=grad_accum, hooks=hooks,
                                    spmd=spmd)
            lowered = jax.jit(fn).lower(state_in, batch_in)

        elif shape.mode == "prefill":
            params_shapes = M.params_shape(cfg)
            params_in = _attach(params_shapes, M.logical_axes(cfg),
                                rules, mesh)
            specs = M.input_specs(cfg, shape)
            batch_in = _attach(specs, _batch_axes(specs), rules, mesh)

            def prefill(params, batch):
                logits, caches, _ = M.forward(
                    cfg, params, batch, constrain, want_caches=True,
                    last_logit_only=True, spmd=spmd)
                return logits, caches

            lowered = jax.jit(prefill).lower(params_in, batch_in)

        else:  # decode
            params_shapes = M.params_shape(cfg)
            params_in = _attach(params_shapes, M.logical_axes(cfg),
                                rules, mesh)
            cache_shapes = jax.eval_shape(
                lambda: M.init_caches(cfg, shape.global_batch,
                                      shape.seq_len))
            cache_in = _attach(cache_shapes, M.cache_logical_axes(cfg),
                               rules, mesh)
            specs = M.input_specs(cfg, shape)
            batch_in = _attach(specs, _batch_axes(specs), rules, mesh)

            def serve_step(params, caches, tokens, pos):
                logits, new_caches = M.decode_step_fn(
                    cfg, params, caches, tokens, pos, constrain, spmd=spmd)
                return jnp.argmax(logits, -1), new_caches

            lowered = jax.jit(serve_step).lower(
                params_in, cache_in, batch_in["tokens"], batch_in["pos"])
    return lowered, cfg, shape, mesh


# ---------------------------------------------------------------------------
# roofline extraction
# ---------------------------------------------------------------------------
def analyze(lowered, cfg: ModelConfig, shape: ShapeConfig, mesh) -> Dict:
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    # trip-count-aware per-device accounting (cost_analysis counts while
    # bodies once — see launch/hlo_cost.py)
    acc = analyze_hlo(hlo)
    coll = dict(acc["collectives"], total_bytes=acc["collective_bytes"])
    n_chips = int(np.prod(list(mesh.shape.values())))

    flops = float(acc["flops"])
    bytes_accessed = float(acc["bytes"])
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    collective_s = acc["collective_bytes"] / ICI_BW

    tokens = shape.global_batch * (1 if shape.mode == "decode"
                                   else shape.seq_len)
    n_active = cfg.active_param_count()
    if shape.mode == "train":
        model_flops_global = 6 * n_active * tokens   # fwd + bwd
    else:
        model_flops_global = 2 * n_active * tokens   # fwd only
    model_flops_per_chip = model_flops_global / n_chips

    terms = dict(compute_s=compute_s, memory_s=memory_s,
                 collective_s=collective_s)
    dominant = max(terms, key=terms.get)
    denom = max(compute_s, memory_s, collective_s, 1e-30)
    useful_frac = model_flops_per_chip / PEAK_FLOPS / denom

    record = dict(
        arch=cfg.name, shape=shape.name, mode=shape.mode,
        mesh=dict(mesh.shape), chips=n_chips,
        compile_seconds=round(compile_s, 1),
        per_device=dict(
            flops=flops, bytes_accessed=bytes_accessed,
            bytes_upper=float(acc["bytes_upper"]),
            arg_bytes=float(acc["arg_bytes"]),
            xla_flops_scan_once=float(cost.get("flops", 0.0)),
            xla_bytes_scan_once=float(cost.get("bytes accessed", 0.0)),
            argument_bytes=getattr(mem, "argument_size_in_bytes", None),
            output_bytes=getattr(mem, "output_size_in_bytes", None),
            temp_bytes=getattr(mem, "temp_size_in_bytes", None),
            peak_bytes=(getattr(mem, "temp_size_in_bytes", 0) or 0)
            + (getattr(mem, "argument_size_in_bytes", 0) or 0),
        ),
        collectives=coll,
        roofline=dict(
            **{k: float(v) for k, v in terms.items()},
            dominant=dominant,
            model_flops_global=float(model_flops_global),
            model_flops_per_chip=float(model_flops_per_chip),
            hlo_useful_ratio=float(model_flops_per_chip
                                   / max(flops, 1e-30)),
            roofline_fraction=float(useful_frac),
        ),
    )
    return record


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             grad_accum: int = 1) -> Dict:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    skip = runnable(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    # every record shape (ok/skip/error) carries the normalized
    # ``mesh_name`` — roofline.py filters on it, and the legacy skip/error
    # records that stuffed the name into ``mesh`` broke that filter
    if skip:
        return dict(arch=arch, shape=shape_name, mesh=mesh_name,
                    mesh_name=mesh_name, skipped=skip)
    try:
        lowered, cfg, shape, mesh = lower_cell(arch, shape_name, multi_pod,
                                               grad_accum=grad_accum)
        rec = analyze(lowered, cfg, shape, mesh)
        rec["mesh_name"] = mesh_name
        return rec
    except Exception:
        return dict(arch=arch, shape=shape_name, mesh=mesh_name,
                    mesh_name=mesh_name,
                    error=traceback.format_exc()[-4000:])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/out")
    ap.add_argument("--grad-accum", type=int, default=1)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[cached] {tag}")
                    continue
                t0 = time.time()
                rec = run_cell(arch, shape, mp, grad_accum=args.grad_accum)
                rec["wall_seconds"] = round(time.time() - t0, 1)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = ("SKIP" if "skipped" in rec
                          else "ERR " if "error" in rec else "ok  ")
                extra = ""
                if "roofline" in rec:
                    r = rec["roofline"]
                    extra = (f" dom={r['dominant']}"
                             f" frac={r['roofline_fraction']:.3f}")
                print(f"[{status}] {tag} ({rec['wall_seconds']}s){extra}",
                      flush=True)


if __name__ == "__main__":
    main()
