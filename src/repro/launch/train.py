"""End-to-end training driver.

CPU-friendly on reduced configs (smoke/examples); on a real fleet the same
driver runs the full config under the production mesh (the dry-run proves
those programs compile). Features: resumable checkpoints (atomic, keep-k,
async), SDE telemetry (gradient AMS sketch + DFT metric monitor), exact
data-pipeline resume, optional grad accumulation.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
      --preset reduced --steps 100 --batch 8 --seq 128 --ckpt /tmp/run1
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.streams import TokenPipeline
from repro.training import (OptConfig, TrainHooks, MetricMonitor,
                            make_train_step, init_train_state)
from repro.training import checkpoint as ckpt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=list(ARCHS))
    ap.add_argument("--preset", default="reduced",
                    choices=["reduced", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.preset == "reduced":
        cfg = reduced(cfg)
    opt_cfg = OptConfig(name=cfg.optimizer if args.preset == "full"
                        else "adamw", lr=args.lr,
                        warmup_steps=max(args.steps // 20, 5),
                        total_steps=args.steps)
    hooks = TrainHooks()

    state = init_train_state(cfg, opt_cfg, jax.random.PRNGKey(args.seed),
                             hooks)
    n_params = sum(int(np.prod(x.shape))
                   for x in jax.tree.leaves(state["params"]))
    print(f"[train] arch={cfg.name} preset={args.preset} "
          f"params={n_params/1e6:.1f}M vocab={cfg.vocab}")

    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq,
                         batch=args.batch, seed=args.seed)
    start_step = 0
    if args.ckpt and ckpt.latest_step(args.ckpt) is not None:
        state, manifest = ckpt.restore(state, args.ckpt)
        pipe.restore(manifest["pipeline"])
        start_step = manifest["step"]
        print(f"[train] resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(cfg, opt_cfg,
                                      grad_accum=args.grad_accum,
                                      hooks=hooks))
    monitor = MetricMonitor(window=32)
    pending_save = None
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        state, metrics = step_fn(state, batch)
        monitor.observe({k: float(v) for k, v in metrics.items()
                         if np.ndim(v) == 0})
        if (step + 1) % args.log_every == 0:
            dt = (time.time() - t0) / args.log_every
            tok_s = args.batch * args.seq / dt
            print(f"step {step+1:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"sketchL2 {float(metrics.get('sketch_l2_est', 0)):.1f} "
                  f"tok/s {tok_s:,.0f}", flush=True)
            t0 = time.time()
        if args.ckpt and (step + 1) % args.ckpt_every == 0:
            if pending_save is not None:
                pending_save.join()
            pending_save = ckpt.save(
                state, args.ckpt, step + 1,
                extra_manifest={"pipeline": pipe.state()}, async_=True)
    if pending_save is not None:
        pending_save.join()
    if args.ckpt:
        ckpt.save(state, args.ckpt, args.steps,
                  extra_manifest={"pipeline": pipe.state()})
    groups = monitor.correlated_groups()
    if groups:
        print(f"[SDE monitor] correlated metric groups: {groups}")
    print(f"[train] done: distinct tokens seen (HLL) "
          f"~{pipe.distinct_tokens():,.0f}")
    return state


if __name__ == "__main__":
    main()
