import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Perf-iteration microscope: lower one cell and print the top dots (by
trip-multiplied FLOPs), top collectives (by trip-multiplied bytes), and
the largest live buffers — the 'profile' the §Perf loop reasons over.

  PYTHONPATH=src python -m repro.launch.inspect_cell --arch qwen2-72b \
      --shape train_4k
"""
import argparse
import re
from collections import defaultdict

import numpy as np

from repro.launch import dryrun
from repro.launch.hlo_cost import (_parse_computations, _DEF_RE, _SHAPE_RE,
                                   _shape_bytes, _shape_dims, _COLLECTIVES)


def _multipliers(comps):
    entry = comps["__entry__"]
    mult = {entry.name: 1.0}
    order, seen = [entry.name], {entry.name}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult.get(cname, 1.0)
        for line in comp.lines:
            wm = re.search(r"body=%([\w.\-]+), *condition=%([\w.\-]+)|"
                           r"condition=%([\w.\-]+), *body=%([\w.\-]+)", line)
            if wm and " while(" in line:
                body = wm.group(1) or wm.group(4)
                trip = 1.0
                tm = re.search(r'"known_trip_count":{"n":"(\d+)"}', line)
                if tm:
                    trip = float(tm.group(1))
                mult[body] = mult.get(body, 0.0) + m * trip
                if body not in seen:
                    seen.add(body)
                    order.append(body)
            for ref in re.findall(r"calls=%([\w.\-]+)", line):
                mult[ref] = mult.get(ref, 0.0) + m
                if ref not in seen:
                    seen.add(ref)
                    order.append(ref)
    return mult, seen


def inspect(hlo_text: str, top: int = 12):
    comps = _parse_computations(hlo_text)
    mult, seen = _multipliers(comps)
    dots, colls, bufs = [], [], []
    for cname in seen:
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult.get(cname, 1.0)
        for line in comp.lines:
            d = _DEF_RE.match(line)
            if not d:
                continue
            rhs = d.group(2)
            tm = re.match(r"^(\([^=]*?\)|[\w\[\],]+(?:\{[\d,]*\})?)\s*(.*)$",
                          rhs)
            if not tm:
                continue
            out_type, rest = tm.group(1), tm.group(2)
            meta = re.search(r'op_name="([^"]+)"', rhs)
            op_name = meta.group(1) if meta else d.group(1)
            if " dot(" in rhs:
                out_dims = _shape_dims(out_type)
                out_elems = float(np.prod(out_dims)) if out_dims else 1.0
                cm = re.search(r"lhs_contracting_dims={([0-9,]*)}", rhs)
                k = 1
                am = re.search(r"dot\((%[\w.\-]+)", rhs)
                if cm and am and am.group(1) in comp.symbols:
                    lhs_dims = _shape_dims(comp.symbols[am.group(1)])
                    for ci in cm.group(1).split(","):
                        if ci and int(ci) < len(lhs_dims):
                            k *= lhs_dims[int(ci)]
                dots.append((m * 2.0 * out_elems * k, m, out_type[:48],
                             op_name[-80:]))
            opm = re.match(r"\s*([\w\-]+)\(", rest)
            op = opm.group(1) if opm else ""
            if any(op == c or op == c + "-start" for c in _COLLECTIVES):
                colls.append((m * _shape_bytes(out_type), m, op,
                              out_type[:64], op_name[-70:]))
            b = _shape_bytes(out_type)
            if b > 2**28:
                bufs.append((b, out_type[:64], op[:20], op_name[-60:]))

    print("== top dots (flops x trip, per device) ==")
    for f, m, shp, name in sorted(dots, reverse=True)[:top]:
        print(f"  {f:.3e} (x{m:4.0f}) {shp:48s} {name}")
    print(f"  TOTAL dot flops: {sum(d[0] for d in dots):.3e}")
    print("== top collectives (bytes x trip, per device) ==")
    for b, m, op, shp, name in sorted(colls, reverse=True)[:top]:
        print(f"  {b/2**30:8.2f} GiB (x{m:4.0f}) {op:18s} {shp:40s} {name}")
    print(f"  TOTAL collective: {sum(c[0] for c in colls)/2**30:.2f} GiB")
    print("== largest single buffers ==")
    seen_shapes = set()
    for b, shp, op, name in sorted(bufs, reverse=True)[:top]:
        key = (shp, op)
        if key in seen_shapes:
            continue
        seen_shapes.add(key)
        print(f"  {b/2**30:8.2f} GiB {op:14s} {shp:52s} {name}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--top", type=int, default=12)
    args = ap.parse_args()
    lowered, cfg, shape, mesh = dryrun.lower_cell(args.arch, args.shape,
                                                  args.multi)
    compiled = lowered.compile()
    inspect(compiled.as_text(), args.top)


if __name__ == "__main__":
    main()
