"""Stacked bit-set OR kernel: k-position max-scatter into [n, m] bitsets.

Serves every bit-vector sketch whose update sets a handful of positions
per tuple and whose merge is OR (== max on {0, 1} int32 lanes):

  * Bloom filters: k hash positions per tuple (``idx [T, k]``);
  * FM/PCSA bitmaps via ``fm_bitmap.py``: one flattened (map, bit)
    position per tuple (k == 1).

Update rule per grid cell (hash h, synopsis tile s, bit tile m):

    bits[syn, m] |= upd_t * [syn_t == syn] * [idx_t[h] == m]

materialized as the same [T_t, S_t, M_t] one-hot max cube as the HLL
kernel (max has no matmul form). Grid: (k, S_tiles, M_tiles, T_tiles) —
T innermost so each output tile accumulates in VMEM across the batch
sweep; the k axis is outermost, so each output tile is revisited once per
hash function and max-folded (init happens at h == 0, t == 0).

Both entry points are provided: :func:`bitset_max_update` takes routed
rows (probe-then-scatter), :func:`bitset_probe_max_update` fuses the
routing probe into the kernel (one HBM pass; see onehot_matmul for the
scratch-cached probe pattern).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import probe


def _cube(syn, pos, upd, s, m_, *, s_tile, m_tile):
    s_ids = s * s_tile + jax.lax.broadcasted_iota(jnp.int32, (1, s_tile), 1)
    m_ids = m_ * m_tile + jax.lax.broadcasted_iota(jnp.int32, (1, m_tile), 1)
    cmp_s = (syn[:, None] == s_ids)                        # [T_t, S_t]
    cmp_m = (pos[:, None] == m_ids)                        # [T_t, M_t]
    cube = jnp.where(cmp_s[:, :, None] & cmp_m[:, None, :],
                     upd[:, None, None], 0)                # [T_t, S_t, M_t]
    return jnp.max(cube, axis=0)


def _kernel(bits_ref, syn_ref, idx_ref, upd_ref, out_ref, *, s_tile, m_tile):
    h = pl.program_id(0)
    s = pl.program_id(1)
    m_ = pl.program_id(2)
    t = pl.program_id(3)
    tile = _cube(syn_ref[...], idx_ref[..., 0], upd_ref[...], s, m_,
                 s_tile=s_tile, m_tile=m_tile)

    @pl.when((h == 0) & (t == 0))
    def _init():
        out_ref[...] = jnp.maximum(bits_ref[...], tile)

    @pl.when((h > 0) | (t > 0))
    def _acc():
        out_ref[...] = jnp.maximum(out_ref[...], tile)


@functools.partial(jax.jit, static_argnames=("s_tile", "m_tile", "t_tile",
                                             "interpret"))
def bitset_max_update(bits: jax.Array, syn_idx: jax.Array, idx: jax.Array,
                      upd: jax.Array, *, s_tile: int = 8, m_tile: int = 128,
                      t_tile: int = 128, interpret: bool = True) -> jax.Array:
    """bits [n, m] i32 |= scatter of T tuples at idx [T, k]; upd [T] i32
    is 0/1 (0 = masked no-op, and syn_idx -1 matches no row). All dims
    must be tile multiples (ops.py pads)."""
    n, m = bits.shape
    t_total, k = idx.shape
    grid = (k, n // s_tile, m // m_tile, t_total // t_tile)
    return pl.pallas_call(
        functools.partial(_kernel, s_tile=s_tile, m_tile=m_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((s_tile, m_tile), lambda h, s, m_, t: (s, m_)),
            pl.BlockSpec((t_tile,), lambda h, s, m_, t: (t,)),
            pl.BlockSpec((t_tile, 1), lambda h, s, m_, t: (t, h)),
            pl.BlockSpec((t_tile,), lambda h, s, m_, t: (t,)),
        ],
        out_specs=pl.BlockSpec((s_tile, m_tile), lambda h, s, m_, t: (s, m_)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.int32),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(bits, syn_idx, idx, upd)


def _fused_kernel(bits_ref, klo_ref, khi_ref, trw_ref, slo_ref, shi_ref,
                  idx_ref, upd_ref, out_ref, syn_ref, *, s_tile, m_tile,
                  t_tile, n_probe):
    h = pl.program_id(0)
    s = pl.program_id(1)
    m_ = pl.program_id(2)
    t = pl.program_id(3)

    @pl.when((h == 0) & (s == 0) & (m_ == 0))
    def _probe():
        syn_ref[pl.ds(t * t_tile, t_tile)] = probe.probe_rows(
            klo_ref[...], khi_ref[...], trw_ref[...],
            slo_ref[...], shi_ref[...], n_probe=n_probe)

    syn = syn_ref[pl.ds(t * t_tile, t_tile)]
    tile = _cube(syn, idx_ref[..., 0], upd_ref[...], s, m_,
                 s_tile=s_tile, m_tile=m_tile)

    @pl.when((h == 0) & (t == 0))
    def _init():
        out_ref[...] = jnp.maximum(bits_ref[...], tile)

    @pl.when((h > 0) | (t > 0))
    def _acc():
        out_ref[...] = jnp.maximum(out_ref[...], tile)


@functools.partial(jax.jit, static_argnames=("n_probe", "s_tile", "m_tile",
                                             "t_tile", "interpret"))
def bitset_probe_max_update(bits: jax.Array, keys_lo: jax.Array,
                            keys_hi: jax.Array, table_rows: jax.Array,
                            sid_lo: jax.Array, sid_hi: jax.Array,
                            idx: jax.Array, upd: jax.Array, *, n_probe: int,
                            s_tile: int = 8, m_tile: int = 128,
                            t_tile: int = 128,
                            interpret: bool = True) -> jax.Array:
    """Fused routing probe + bit-set max-scatter, one HBM pass."""
    n, m = bits.shape
    t_total, k = idx.shape
    size = keys_lo.shape[0]
    grid = (k, n // s_tile, m // m_tile, t_total // t_tile)
    tbl = lambda: pl.BlockSpec((size,), lambda h, s, m_, t: (0,))
    return pl.pallas_call(
        functools.partial(_fused_kernel, s_tile=s_tile, m_tile=m_tile,
                          t_tile=t_tile, n_probe=n_probe),
        grid=grid,
        in_specs=[
            pl.BlockSpec((s_tile, m_tile), lambda h, s, m_, t: (s, m_)),
            tbl(), tbl(), tbl(),
            pl.BlockSpec((t_tile,), lambda h, s, m_, t: (t,)),
            pl.BlockSpec((t_tile,), lambda h, s, m_, t: (t,)),
            pl.BlockSpec((t_tile, 1), lambda h, s, m_, t: (t, h)),
            pl.BlockSpec((t_tile,), lambda h, s, m_, t: (t,)),
        ],
        out_specs=pl.BlockSpec((s_tile, m_tile), lambda h, s, m_, t: (s, m_)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.int32),
        scratch_shapes=[pltpu.VMEM((t_total,), jnp.int32)],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(bits, keys_lo, keys_hi, table_rows, sid_lo, sid_hi, idx, upd)
