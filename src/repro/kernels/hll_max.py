"""HyperLogLog register max-scatter kernel.

Max has no matmul form, so this kernel tiles the (synopsis x register)
plane into VMEM blocks and sweeps the update batch in the innermost grid
dimension, keeping a running elementwise max per block:

    regs[syn, m] = max(regs[syn, m], max_t rank_t * [syn_t==syn][bkt_t==m])

The [T_t, S_t, M_t] one-hot cube is materialized per step — tiles are
sized so it stays ~0.5 MB (VPU-bound kernel; roofline: memory term).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import probe


def _kernel(regs_ref, syn_ref, bkt_ref, rank_ref, out_ref, *, s_tile, m_tile):
    t = pl.program_id(2)
    s_base = pl.program_id(0) * s_tile
    m_base = pl.program_id(1) * m_tile

    syn = syn_ref[...]
    bkt = bkt_ref[...]
    rank = rank_ref[...]

    s_ids = s_base + jax.lax.broadcasted_iota(jnp.int32, (1, s_tile), 1)
    m_ids = m_base + jax.lax.broadcasted_iota(jnp.int32, (1, m_tile), 1)
    cmp_s = (syn[:, None] == s_ids)                       # [T_t, S_t]
    cmp_m = (bkt[:, None] == m_ids)                       # [T_t, M_t]
    cube = jnp.where(cmp_s[:, :, None] & cmp_m[:, None, :],
                     rank[:, None, None], 0)              # [T_t, S_t, M_t]
    tile = jnp.max(cube, axis=0)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.maximum(regs_ref[...], tile)

    @pl.when(t > 0)
    def _acc():
        out_ref[...] = jnp.maximum(out_ref[...], tile)


@functools.partial(jax.jit, static_argnames=("s_tile", "m_tile", "t_tile",
                                             "interpret"))
def hll_max_update(regs: jax.Array, syn_idx: jax.Array, bucket: jax.Array,
                   rank: jax.Array, *, s_tile: int = 8, m_tile: int = 128,
                   t_tile: int = 128, interpret: bool = True) -> jax.Array:
    """regs [n, m] int32; syn_idx/bucket/rank [T] int32 (rank 0 = masked)."""
    n, m = regs.shape
    t_total = syn_idx.shape[0]
    grid = (n // s_tile, m // m_tile, t_total // t_tile)
    return pl.pallas_call(
        functools.partial(_kernel, s_tile=s_tile, m_tile=m_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((s_tile, m_tile), lambda s, m_, t: (s, m_)),
            pl.BlockSpec((t_tile,), lambda s, m_, t: (t,)),
            pl.BlockSpec((t_tile,), lambda s, m_, t: (t,)),
            pl.BlockSpec((t_tile,), lambda s, m_, t: (t,)),
        ],
        out_specs=pl.BlockSpec((s_tile, m_tile), lambda s, m_, t: (s, m_)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.int32),
        interpret=interpret,
    )(regs, syn_idx, bucket, rank)


# ---------------------------------------------------------------------------
# fused probe + max-scatter: the routing probe runs INSIDE the kernel on the
# first (s=0, m=0) sweep over T and caches routed rows in a VMEM scratch
# shared across the sequential grid — one HBM pass per batch (see
# onehot_matmul._fused_kernel for the pattern).
# ---------------------------------------------------------------------------
def _fused_kernel(regs_ref, klo_ref, khi_ref, trw_ref, slo_ref, shi_ref,
                  bkt_ref, rank_ref, out_ref, syn_ref, *, s_tile, m_tile,
                  t_tile, n_probe):
    s = pl.program_id(0)
    m_ = pl.program_id(1)
    t = pl.program_id(2)

    @pl.when((s == 0) & (m_ == 0))
    def _probe():
        syn_ref[pl.ds(t * t_tile, t_tile)] = probe.probe_rows(
            klo_ref[...], khi_ref[...], trw_ref[...],
            slo_ref[...], shi_ref[...], n_probe=n_probe)

    syn = syn_ref[pl.ds(t * t_tile, t_tile)]        # -1 => matches no row
    bkt = bkt_ref[...]
    rank = rank_ref[...]

    s_ids = s * s_tile + jax.lax.broadcasted_iota(jnp.int32, (1, s_tile), 1)
    m_ids = m_ * m_tile + jax.lax.broadcasted_iota(jnp.int32, (1, m_tile), 1)
    cmp_s = (syn[:, None] == s_ids)                       # [T_t, S_t]
    cmp_m = (bkt[:, None] == m_ids)                       # [T_t, M_t]
    cube = jnp.where(cmp_s[:, :, None] & cmp_m[:, None, :],
                     rank[:, None, None], 0)              # [T_t, S_t, M_t]
    tile = jnp.max(cube, axis=0)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.maximum(regs_ref[...], tile)

    @pl.when(t > 0)
    def _acc():
        out_ref[...] = jnp.maximum(out_ref[...], tile)


@functools.partial(jax.jit, static_argnames=("n_probe", "s_tile", "m_tile",
                                             "t_tile", "interpret"))
def hll_probe_max_update(regs: jax.Array, keys_lo: jax.Array,
                         keys_hi: jax.Array, table_rows: jax.Array,
                         sid_lo: jax.Array, sid_hi: jax.Array,
                         bucket: jax.Array, rank: jax.Array, *,
                         n_probe: int, s_tile: int = 8, m_tile: int = 128,
                         t_tile: int = 128,
                         interpret: bool = True) -> jax.Array:
    """Fused routing probe + register max-scatter, one HBM pass.

    regs [n, m] i32; keys_lo/keys_hi/table_rows: routing-table mirror;
    sid_lo/sid_hi [T] uint32 halves; bucket/rank [T] i32 (rank 0 =
    masked). All dims must be tile multiples (ops.py pads)."""
    n, m = regs.shape
    t_total = sid_lo.shape[0]
    size = keys_lo.shape[0]
    grid = (n // s_tile, m // m_tile, t_total // t_tile)
    tbl = lambda: pl.BlockSpec((size,), lambda s, m_, t: (0,))
    return pl.pallas_call(
        functools.partial(_fused_kernel, s_tile=s_tile, m_tile=m_tile,
                          t_tile=t_tile, n_probe=n_probe),
        grid=grid,
        in_specs=[
            pl.BlockSpec((s_tile, m_tile), lambda s, m_, t: (s, m_)),
            tbl(), tbl(), tbl(),
            pl.BlockSpec((t_tile,), lambda s, m_, t: (t,)),
            pl.BlockSpec((t_tile,), lambda s, m_, t: (t,)),
            pl.BlockSpec((t_tile,), lambda s, m_, t: (t,)),
            pl.BlockSpec((t_tile,), lambda s, m_, t: (t,)),
        ],
        out_specs=pl.BlockSpec((s_tile, m_tile), lambda s, m_, t: (s, m_)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.int32),
        scratch_shapes=[pltpu.VMEM((t_total,), jnp.int32)],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(regs, keys_lo, keys_hi, table_rows, sid_lo, sid_hi, bucket, rank)
