"""HyperLogLog register max-scatter kernel.

Max has no matmul form, so this kernel tiles the (synopsis x register)
plane into VMEM blocks and sweeps the update batch in the innermost grid
dimension, keeping a running elementwise max per block:

    regs[syn, m] = max(regs[syn, m], max_t rank_t * [syn_t==syn][bkt_t==m])

The [T_t, S_t, M_t] one-hot cube is materialized per step — tiles are
sized so it stays ~0.5 MB (VPU-bound kernel; roofline: memory term).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(regs_ref, syn_ref, bkt_ref, rank_ref, out_ref, *, s_tile, m_tile):
    t = pl.program_id(2)
    s_base = pl.program_id(0) * s_tile
    m_base = pl.program_id(1) * m_tile

    syn = syn_ref[...]
    bkt = bkt_ref[...]
    rank = rank_ref[...]

    s_ids = s_base + jax.lax.broadcasted_iota(jnp.int32, (1, s_tile), 1)
    m_ids = m_base + jax.lax.broadcasted_iota(jnp.int32, (1, m_tile), 1)
    cmp_s = (syn[:, None] == s_ids)                       # [T_t, S_t]
    cmp_m = (bkt[:, None] == m_ids)                       # [T_t, M_t]
    cube = jnp.where(cmp_s[:, :, None] & cmp_m[:, None, :],
                     rank[:, None, None], 0)              # [T_t, S_t, M_t]
    tile = jnp.max(cube, axis=0)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.maximum(regs_ref[...], tile)

    @pl.when(t > 0)
    def _acc():
        out_ref[...] = jnp.maximum(out_ref[...], tile)


@functools.partial(jax.jit, static_argnames=("s_tile", "m_tile", "t_tile",
                                             "interpret"))
def hll_max_update(regs: jax.Array, syn_idx: jax.Array, bucket: jax.Array,
                   rank: jax.Array, *, s_tile: int = 8, m_tile: int = 128,
                   t_tile: int = 128, interpret: bool = True) -> jax.Array:
    """regs [n, m] int32; syn_idx/bucket/rank [T] int32 (rank 0 = masked)."""
    n, m = regs.shape
    t_total = syn_idx.shape[0]
    grid = (n // s_tile, m // m_tile, t_total // t_tile)
    return pl.pallas_call(
        functools.partial(_kernel, s_tile=s_tile, m_tile=m_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((s_tile, m_tile), lambda s, m_, t: (s, m_)),
            pl.BlockSpec((t_tile,), lambda s, m_, t: (t,)),
            pl.BlockSpec((t_tile,), lambda s, m_, t: (t,)),
            pl.BlockSpec((t_tile,), lambda s, m_, t: (t,)),
        ],
        out_specs=pl.BlockSpec((s_tile, m_tile), lambda s, m_, t: (s, m_)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.int32),
        interpret=interpret,
    )(regs, syn_idx, bucket, rank)
