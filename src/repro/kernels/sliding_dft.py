"""Batched sliding-DFT step kernel (StatStream over thousands of streams).

One tick per stream:  X_F <- (X_F + delta) * e^{2 pi i F / n}, delta =
x_in - x_out, vectorized over S streams x F coefficients with complex
arithmetic in (re, im) planes. Pure VPU elementwise kernel; the win over
stock XLA is fusing the 6-op complex multiply + mask into one VMEM pass
over the [S, F] coefficient planes (memory-roofline workload).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(re_ref, im_ref, delta_ref, mask_ref, twr_ref, twi_ref,
            out_re_ref, out_im_ref):
    re = re_ref[...]                       # [S_t, F]
    im = im_ref[...]
    delta = delta_ref[...][:, None]        # [S_t, 1]
    mask = mask_ref[...][:, None]
    twr = twr_ref[...]                     # [1, F]
    twi = twi_ref[...]

    re2 = re + delta
    new_re = re2 * twr - im * twi
    new_im = re2 * twi + im * twr
    out_re_ref[...] = jnp.where(mask > 0, new_re, re)
    out_im_ref[...] = jnp.where(mask > 0, new_im, im)


@functools.partial(jax.jit, static_argnames=("s_tile", "interpret"))
def sliding_dft_step(re: jax.Array, im: jax.Array, delta: jax.Array,
                     mask: jax.Array, tw_re: jax.Array, tw_im: jax.Array,
                     *, s_tile: int = 512, interpret: bool = True):
    """re/im [S, F] f32, delta/mask [S] f32, tw_re/tw_im [F] f32."""
    s, f = re.shape
    grid = (s // s_tile,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((s_tile, f), lambda i: (i, 0)),
            pl.BlockSpec((s_tile, f), lambda i: (i, 0)),
            pl.BlockSpec((s_tile,), lambda i: (i,)),
            pl.BlockSpec((s_tile,), lambda i: (i,)),
            pl.BlockSpec((1, f), lambda i: (0, 0)),
            pl.BlockSpec((1, f), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((s_tile, f), lambda i: (i, 0)),
            pl.BlockSpec((s_tile, f), lambda i: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((s, f), jnp.float32),
                   jax.ShapeDtypeStruct((s, f), jnp.float32)],
        interpret=interpret,
    )(re, im, delta, mask, tw_re[None, :], tw_im[None, :])
