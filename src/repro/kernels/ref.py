"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def onehot_scatter_add(counts, syn_idx, idx, values, signs):
    """counts [n, d, w] scatter-add oracle."""
    n, d, w = counts.shape
    v = values[:, None] * signs                       # [T, d]
    rows = jnp.arange(d)[None, :]
    return counts.at[syn_idx[:, None], rows, idx].add(v)


def hll_max_update(regs, syn_idx, bucket, rank):
    """regs [n, m] max-scatter oracle (rank 0 entries are no-ops)."""
    return regs.at[syn_idx, bucket].max(rank)


def bitset_max_update(bits, syn_idx, idx, upd):
    """bits [n, m] k-position OR oracle: idx [T, k], upd [T] 0/1 (upd 0
    and syn_idx -1 entries are no-ops — -1 rows are dropped, not
    wrapped)."""
    keep = (syn_idx >= 0) & (upd > 0)
    u = jnp.where(keep, upd, 0)[:, None]
    rows = jnp.maximum(syn_idx, 0)
    return bits.at[rows[:, None], idx].max(jnp.broadcast_to(u, idx.shape))


def fm_bit_update(state, syn_idx, which, pos, upd):
    """state [n, maps, bits] single-bit OR oracle (same -1/0 no-ops)."""
    keep = (syn_idx >= 0) & (upd > 0)
    u = jnp.where(keep, upd, 0)
    return state.at[jnp.maximum(syn_idx, 0), which, pos].max(u)


def rhp_project_update(state, syn_idx, values, signs):
    """state [n, b] routed sign-row add oracle: values [T] (mask folded),
    signs [T, b]; syn_idx -1 entries are dropped."""
    v = jnp.where(syn_idx >= 0, values, 0.0)
    return state.at[jnp.maximum(syn_idx, 0)].add(v[:, None] * signs)


def sliding_dft_step(re, im, delta, mask, tw_re, tw_im):
    re2 = re + delta[:, None]
    new_re = re2 * tw_re[None, :] - im * tw_im[None, :]
    new_im = re2 * tw_im[None, :] + im * tw_re[None, :]
    m = (mask > 0)[:, None]
    return jnp.where(m, new_re, re), jnp.where(m, new_im, im)


def pairwise_corr(x):
    sq = jnp.sum(x * x, axis=-1)
    gram = x @ x.T
    return 1.0 - (sq[:, None] + sq[None, :] - 2.0 * gram)


def flash_attention(q, k, v, causal=True):
    """Plain softmax attention oracle. q/k/v [BH, S, D]."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(float(d))
    if causal:
        mask = jnp.tril(jnp.ones((q.shape[1], k.shape[1]), bool))
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
