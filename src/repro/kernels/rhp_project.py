"""Stacked RHP/SimHash projection kernel: routed row-add of sign rows.

RHP state is b running hyperplane dot products per synopsis ([n, b] f32);
a batch of T tuples adds ``v_t * sgn_t`` into its routed row. Because the
sign matrix is DENSE (every tuple touches all b planes), the update is a
pure matmul — no one-hot bucket side:

    state[syn, :] += sum_t (syn_t == syn) * v_t * sgn[t, :]
                   =       A^T @ sgn
    A[t, syn] = (syn_t == syn) * v_t

i.e. an [S_tile x T_tile] x [T_tile x B_tile] MXU matmul per grid cell,
the densest of the scatter kernels. Grid: (S_tiles, B_tiles, T_tiles),
T innermost; the state block folds into the t == 0 accumulation and the
operand is aliased to the output (in-place, no delta buffer).

:func:`rhp_project_update` takes routed rows; :func:`rhp_probe_update`
fuses the routing probe into the kernel (one HBM pass; the probe result
is cached in a VMEM scratch on the first (s=0, b=0) sweep over T).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import probe


def _tile(syn, val, sgn, s, *, s_tile):
    s_ids = s * s_tile + jax.lax.broadcasted_iota(jnp.int32, (1, s_tile), 1)
    a = jnp.where(syn[:, None] == s_ids, val[:, None], 0.0)      # [T_t, S_t]
    return jax.lax.dot_general(
        a, sgn, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                      # [S_t, B_t]


def _kernel(state_ref, syn_ref, val_ref, sgn_ref, out_ref, *, s_tile):
    s = pl.program_id(0)
    t = pl.program_id(2)
    tile = _tile(syn_ref[...], val_ref[...], sgn_ref[...], s, s_tile=s_tile)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = state_ref[...] + tile

    @pl.when(t > 0)
    def _acc():
        out_ref[...] += tile


@functools.partial(jax.jit, static_argnames=("s_tile", "b_tile", "t_tile",
                                             "interpret"))
def rhp_project_update(state: jax.Array, syn_idx: jax.Array,
                       values: jax.Array, signs: jax.Array, *,
                       s_tile: int = 128, b_tile: int = 128,
                       t_tile: int = 512,
                       interpret: bool = True) -> jax.Array:
    """state [n, b] f32 += routed sign-row add. syn_idx [T] i32 (-1
    matches no row), values [T] f32 (mask pre-folded), signs [T, b] f32.
    All dims must be tile multiples (ops.py pads)."""
    n, b = state.shape
    t_total = syn_idx.shape[0]
    grid = (n // s_tile, b // b_tile, t_total // t_tile)
    return pl.pallas_call(
        functools.partial(_kernel, s_tile=s_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((s_tile, b_tile), lambda s, b_, t: (s, b_)),
            pl.BlockSpec((t_tile,), lambda s, b_, t: (t,)),
            pl.BlockSpec((t_tile,), lambda s, b_, t: (t,)),
            pl.BlockSpec((t_tile, b_tile), lambda s, b_, t: (t, b_)),
        ],
        out_specs=pl.BlockSpec((s_tile, b_tile), lambda s, b_, t: (s, b_)),
        out_shape=jax.ShapeDtypeStruct((n, b), jnp.float32),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(state, syn_idx, values, signs)


def _fused_kernel(state_ref, klo_ref, khi_ref, trw_ref, slo_ref, shi_ref,
                  val_ref, sgn_ref, out_ref, syn_ref, *, s_tile, t_tile,
                  n_probe):
    s = pl.program_id(0)
    b_ = pl.program_id(1)
    t = pl.program_id(2)

    @pl.when((s == 0) & (b_ == 0))
    def _probe():
        syn_ref[pl.ds(t * t_tile, t_tile)] = probe.probe_rows(
            klo_ref[...], khi_ref[...], trw_ref[...],
            slo_ref[...], shi_ref[...], n_probe=n_probe)

    syn = syn_ref[pl.ds(t * t_tile, t_tile)]
    tile = _tile(syn, val_ref[...], sgn_ref[...], s, s_tile=s_tile)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = state_ref[...] + tile

    @pl.when(t > 0)
    def _acc():
        out_ref[...] += tile


@functools.partial(jax.jit, static_argnames=("n_probe", "s_tile", "b_tile",
                                             "t_tile", "interpret"))
def rhp_probe_update(state: jax.Array, keys_lo: jax.Array,
                     keys_hi: jax.Array, table_rows: jax.Array,
                     sid_lo: jax.Array, sid_hi: jax.Array,
                     values: jax.Array, signs: jax.Array, *, n_probe: int,
                     s_tile: int = 128, b_tile: int = 128,
                     t_tile: int = 512,
                     interpret: bool = True) -> jax.Array:
    """Fused routing probe + sign-row projection add, one HBM pass."""
    n, b = state.shape
    t_total = sid_lo.shape[0]
    size = keys_lo.shape[0]
    grid = (n // s_tile, b // b_tile, t_total // t_tile)
    tbl = lambda: pl.BlockSpec((size,), lambda s, b_, t: (0,))
    return pl.pallas_call(
        functools.partial(_fused_kernel, s_tile=s_tile, t_tile=t_tile,
                          n_probe=n_probe),
        grid=grid,
        in_specs=[
            pl.BlockSpec((s_tile, b_tile), lambda s, b_, t: (s, b_)),
            tbl(), tbl(), tbl(),
            pl.BlockSpec((t_tile,), lambda s, b_, t: (t,)),
            pl.BlockSpec((t_tile,), lambda s, b_, t: (t,)),
            pl.BlockSpec((t_tile,), lambda s, b_, t: (t,)),
            pl.BlockSpec((t_tile, b_tile), lambda s, b_, t: (t, b_)),
        ],
        out_specs=pl.BlockSpec((s_tile, b_tile), lambda s, b_, t: (s, b_)),
        out_shape=jax.ShapeDtypeStruct((n, b), jnp.float32),
        scratch_shapes=[pltpu.VMEM((t_total,), jnp.int32)],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(state, keys_lo, keys_hi, table_rows, sid_lo, sid_hi, values, signs)
