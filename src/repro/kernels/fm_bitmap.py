"""FM/PCSA bitmap update on the bit-set kernel.

An FM sketch is ``nmaps`` bitmaps of ``bitmap_size`` bits per synopsis
([n, maps, bits] int32 0/1); each tuple sets ONE bit: position
``rho = ctz(hash)`` of bitmap ``which = top-bits(hash)``. Flattening the
(map, bit) plane to a single axis turns the update into exactly the
k == 1 case of the generic bit-set OR kernel (``bitset_or.py``):

    flat_pos = which * bitmap_size + pos          # in [0, maps*bits)
    flat[syn, flat_pos] |= mask

The reshape [n, maps, bits] <-> [n, maps*bits] is a row-major layout
no-op — XLA folds it into the kernel's operand/result, so the flattened
call still makes one HBM pass over the state in the fused form.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import bitset_or


def _flatten(state: jax.Array, which: jax.Array, pos: jax.Array,
             bitmap_size: int, m_tile: int):
    """Row-major flatten + zero-pad the flat axis to the bit tile (the
    pad columns sit past every reachable flat_pos, so they stay zero)."""
    n = state.shape[0]
    flat = state.reshape(n, -1)
    q = flat.shape[1]
    q_pad = (-q) % m_tile
    if q_pad:
        flat = jnp.pad(flat, ((0, 0), (0, q_pad)))
    flat_pos = (which * bitmap_size + pos).astype(jnp.int32)[:, None]
    return flat, flat_pos, q


def fm_bit_update(state: jax.Array, syn_idx: jax.Array, which: jax.Array,
                  pos: jax.Array, upd: jax.Array, *, s_tile: int = 8,
                  m_tile: int = 128, t_tile: int = 128,
                  interpret: bool = True) -> jax.Array:
    """state [n, maps, bits] i32 |= one bit per tuple at (which, pos).
    upd [T] i32 0/1; syn_idx -1 matches no row. n and T must be tile
    multiples (ops.py pads); the flat maps*bits axis is padded here."""
    flat, flat_pos, q = _flatten(state, which, pos, state.shape[2], m_tile)
    out = bitset_or.bitset_max_update(
        flat, syn_idx, flat_pos, upd, s_tile=s_tile, m_tile=m_tile,
        t_tile=t_tile, interpret=interpret)
    return out[:, :q].reshape(state.shape)


def fm_probe_bit_update(state: jax.Array, keys_lo: jax.Array,
                        keys_hi: jax.Array, table_rows: jax.Array,
                        sid_lo: jax.Array, sid_hi: jax.Array,
                        which: jax.Array, pos: jax.Array, upd: jax.Array, *,
                        n_probe: int, s_tile: int = 8, m_tile: int = 128,
                        t_tile: int = 128,
                        interpret: bool = True) -> jax.Array:
    """Fused routing probe + FM bit scatter, one HBM pass."""
    flat, flat_pos, q = _flatten(state, which, pos, state.shape[2], m_tile)
    out = bitset_or.bitset_probe_max_update(
        flat, keys_lo, keys_hi, table_rows, sid_lo, sid_hi, flat_pos, upd,
        n_probe=n_probe, s_tile=s_tile, m_tile=m_tile, t_tile=t_tile,
        interpret=interpret)
    return out[:, :q].reshape(state.shape)
