"""Sketch scatter-add as a dense one-hot matmul on the MXU.

THE central TPU adaptation of the paper's hot loop. CPU/Flink (and GPU)
update a CountMin/AMS sketch with scatter-adds; TPUs hate scatter but love
dense matmuls. A block of T updates routed to a stack of sketches becomes

    counts[syn, j, w] += sum_t (syn_t == syn) * v_t * s_tj * (idx_tj == w)
                       =        A^T @ B
    A[t, syn] = (syn_t == syn) * v_t * sign_tj      (one-hot rows, weighted)
    B[t, w]   = (idx_tj == w)                       (one-hot buckets)

i.e. an [S_tile x T_tile] x [T_tile x W_tile] matmul per grid cell — 100%
MXU work, zero scatter. The same kernel serves CountMin (sign == 1) and
AMS/count-sketch (sign == ±1), and the stacked thousands-of-synopses path
(paper's slot sharing) for free via the `syn` one-hot.

Grid: (d, S_tiles, W_tiles, T_tiles); T is innermost so each output tile
is revisited consecutively and accumulated in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import probe


def _kernel(syn_ref, idx_ref, val_ref, sgn_ref, out_ref, *, s_tile, w_tile):
    t = pl.program_id(3)
    s_base = pl.program_id(1) * s_tile
    w_base = pl.program_id(2) * w_tile

    syn = syn_ref[...]                      # [T_t]
    idx = idx_ref[..., 0]                   # [T_t]   (this j's buckets)
    val = val_ref[...] * sgn_ref[..., 0]    # [T_t]   (sign folded in)

    s_ids = s_base + jax.lax.broadcasted_iota(jnp.int32, (1, s_tile), 1)
    w_ids = w_base + jax.lax.broadcasted_iota(jnp.int32, (1, w_tile), 1)

    a = jnp.where(syn[:, None] == s_ids, val[:, None], 0.0)      # [T_t, S_t]
    b = (idx[:, None] == w_ids).astype(jnp.float32)              # [T_t, W_t]
    tile = jax.lax.dot_general(
        a, b, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                      # [S_t, W_t]

    @pl.when(t == 0)
    def _init():
        out_ref[...] = tile[:, None, :]

    @pl.when(t > 0)
    def _acc():
        out_ref[...] += tile[:, None, :]


@functools.partial(jax.jit, static_argnames=("s_tile", "w_tile", "t_tile",
                                             "interpret"))
def onehot_scatter_add(counts: jax.Array, syn_idx: jax.Array,
                       idx: jax.Array, values: jax.Array,
                       signs: jax.Array, *, s_tile: int = 128,
                       w_tile: int = 256, t_tile: int = 512,
                       interpret: bool = True) -> jax.Array:
    """counts [n, d, w] += one-hot scatter of T updates. All dims must be
    multiples of their tiles (ops.py pads).

    syn_idx [T] i32, idx [T, d] i32, values [T] f32, signs [T, d] f32.
    Returns the *delta* accumulated into a fresh buffer plus `counts`.
    """
    n, d, w = counts.shape
    t_total = syn_idx.shape[0]
    grid = (d, n // s_tile, w // w_tile, t_total // t_tile)

    delta = pl.pallas_call(
        functools.partial(_kernel, s_tile=s_tile, w_tile=w_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((t_tile,), lambda j, s, w_, t: (t,)),
            pl.BlockSpec((t_tile, 1), lambda j, s, w_, t: (t, j)),
            pl.BlockSpec((t_tile,), lambda j, s, w_, t: (t,)),
            pl.BlockSpec((t_tile, 1), lambda j, s, w_, t: (t, j)),
        ],
        out_specs=pl.BlockSpec((s_tile, 1, w_tile),
                               lambda j, s, w_, t: (s, j, w_)),
        out_shape=jax.ShapeDtypeStruct((n, d, w), jnp.float32),
        interpret=interpret,
    )(syn_idx, idx, values, signs)
    return counts + delta


# ---------------------------------------------------------------------------
# fused probe + scatter: ONE HBM pass. The routing-table mirror rides into
# VMEM as whole-array blocks; the first (j=0, s=0, w=0) sweep over T probes
# each batch tile ONCE and caches the routed rows in a VMEM scratch shared
# across the sequential grid; every later output tile re-reads the scratch.
# The counts block is folded into the t == 0 accumulation (no separate
# delta buffer, no `counts + delta` second pass) and the counts operand is
# aliased to the output, so the state is updated in place.
# ---------------------------------------------------------------------------
def _fused_kernel(cnt_ref, klo_ref, khi_ref, trw_ref, slo_ref, shi_ref,
                  idx_ref, val_ref, sgn_ref, out_ref, syn_ref, *,
                  s_tile, w_tile, t_tile, n_probe):
    j = pl.program_id(0)
    s = pl.program_id(1)
    w_ = pl.program_id(2)
    t = pl.program_id(3)

    @pl.when((j == 0) & (s == 0) & (w_ == 0))
    def _probe():
        syn_ref[pl.ds(t * t_tile, t_tile)] = probe.probe_rows(
            klo_ref[...], khi_ref[...], trw_ref[...],
            slo_ref[...], shi_ref[...], n_probe=n_probe)

    syn = syn_ref[pl.ds(t * t_tile, t_tile)]        # -1 => matches no row
    idx = idx_ref[..., 0]
    val = val_ref[...] * sgn_ref[..., 0]

    s_ids = s * s_tile + jax.lax.broadcasted_iota(jnp.int32, (1, s_tile), 1)
    w_ids = w_ * w_tile + jax.lax.broadcasted_iota(jnp.int32, (1, w_tile), 1)
    a = jnp.where(syn[:, None] == s_ids, val[:, None], 0.0)      # [T_t, S_t]
    b = (idx[:, None] == w_ids).astype(jnp.float32)              # [T_t, W_t]
    tile = jax.lax.dot_general(
        a, b, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                      # [S_t, W_t]

    @pl.when(t == 0)
    def _init():
        out_ref[...] = cnt_ref[...] + tile[:, None, :]

    @pl.when(t > 0)
    def _acc():
        out_ref[...] += tile[:, None, :]


@functools.partial(jax.jit, static_argnames=("n_probe", "s_tile", "w_tile",
                                             "t_tile", "interpret"))
def onehot_probe_scatter(counts: jax.Array, keys_lo: jax.Array,
                         keys_hi: jax.Array, table_rows: jax.Array,
                         sid_lo: jax.Array, sid_hi: jax.Array,
                         idx: jax.Array, values: jax.Array,
                         signs: jax.Array, *, n_probe: int,
                         s_tile: int = 128, w_tile: int = 256,
                         t_tile: int = 512,
                         interpret: bool = True) -> jax.Array:
    """Fused routing probe + one-hot scatter-add, one HBM pass.

    counts [n, d, w] f32; keys_lo/keys_hi/table_rows: the routing-table
    device mirror (pow2 size); sid_lo/sid_hi [T] uint32 stream-id halves;
    idx [T, d] i32, values [T] f32 (mask pre-folded), signs [T, d] f32.
    All dims must be tile multiples (ops.py pads; padded tuples carry
    value 0 and/or an unroutable sid, so they are no-ops).
    """
    n, d, w = counts.shape
    t_total = sid_lo.shape[0]
    size = keys_lo.shape[0]
    grid = (d, n // s_tile, w // w_tile, t_total // t_tile)
    tbl = lambda: pl.BlockSpec((size,), lambda j, s, w_, t: (0,))
    return pl.pallas_call(
        functools.partial(_fused_kernel, s_tile=s_tile, w_tile=w_tile,
                          t_tile=t_tile, n_probe=n_probe),
        grid=grid,
        in_specs=[
            pl.BlockSpec((s_tile, 1, w_tile), lambda j, s, w_, t: (s, j, w_)),
            tbl(), tbl(), tbl(),
            pl.BlockSpec((t_tile,), lambda j, s, w_, t: (t,)),
            pl.BlockSpec((t_tile,), lambda j, s, w_, t: (t,)),
            pl.BlockSpec((t_tile, 1), lambda j, s, w_, t: (t, j)),
            pl.BlockSpec((t_tile,), lambda j, s, w_, t: (t,)),
            pl.BlockSpec((t_tile, 1), lambda j, s, w_, t: (t, j)),
        ],
        out_specs=pl.BlockSpec((s_tile, 1, w_tile),
                               lambda j, s, w_, t: (s, j, w_)),
        out_shape=jax.ShapeDtypeStruct((n, d, w), jnp.float32),
        scratch_shapes=[pltpu.VMEM((t_total,), jnp.int32)],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(counts, keys_lo, keys_hi, table_rows, sid_lo, sid_hi,
      idx, values, signs)
