"""Flash-attention forward kernel (streaming softmax over KV blocks).

The §Roofline analysis shows every prefill cell is memory-dominated by
S x S score traffic; this kernel never materializes scores in HBM: the
[bq x bk] tile lives in VMEM, with running (max, denom, acc) carried in
VMEM scratch across the KV grid dimension (innermost, so each (batch*head,
q-block) revisits its scratch consecutively).

Grid: (B*H, Sq/bq, Sk/bk). Causal masking by absolute positions. bq=bk=
128/256 keeps the working set (2 q/k/v tiles + score tile + acc) well
under 16 MB VMEM with MXU-aligned dims.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, bq, bk, n_kb):
    kb = pl.program_id(2)
    qb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                   # [bq, D]
    k = k_ref[0]                                   # [bk, D]
    v = v_ref[0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale          # [bq, bk]
    if causal:
        qpos = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos, s, _NEG)

    m_prev = m_scr[...]                            # [bq, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                         # [bq, bk]
    corr = jnp.exp(m_prev - m_new)                 # [bq, 1]
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = (acc_scr[...] * corr
                    + jax.lax.dot_general(
                        p, v.astype(jnp.float32),
                        (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_scr[...] = m_new

    @pl.when(kb == n_kb - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = 128, bk: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q [BH, Sq, D], k/v [BH, Sk, D] -> out [BH, Sq, D].

    Sq % bq == 0 and Sk % bk == 0 (ops-level wrappers pad)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    n_kb = sk // bk
    scale = 1.0 / np.sqrt(d)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, bq=bq,
                          bk=bk, n_kb=n_kb),
        grid=(bh, sq // bq, n_kb),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),      # running max
            pltpu.VMEM((bq, 1), jnp.float32),      # running denominator
            pltpu.VMEM((bq, d), jnp.float32),      # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
