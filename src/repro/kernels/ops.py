"""Public jit'd wrappers around the Pallas kernels + the update-kernel
registry.

These are the entry points the engine uses. Each wrapper:
  * does the hashing / layout prep in plain jnp (cheap, fusable),
  * pads every dimension to its kernel tile,
  * picks interpret mode automatically (True off-TPU, so the kernels
    VALIDATE on CPU and compile natively on TPU; override with
    ``SDE_PALLAS_INTERPRET=0/1``),
  * exposes the same signature as the core/ scatter path so the engine
    can flip between `backend="xla"` and `backend="pallas"`.

Kernel dispatch is a REGISTRY, not a type ladder: a kind declares
``update_kernel = "<name>"`` and :func:`resolve_update_kernel` returns the
matching builder's update function — uniform signature, probe fused into
the kernel when ``SDE_FUSED_PROBE`` is on (the default). Kinds without a
declaration fall back to ``batched.stacked_update`` in the engine.
"""
from __future__ import annotations

import collections
import functools
import logging
import os
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import batched, federated, hashing
from . import (bitset_or, fm_bitmap, hll_max, onehot_matmul, probe,
               rhp_project, sliding_dft, pairwise_corr as pc)

_logger = logging.getLogger("repro.kernels")

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:
    # jax <= 0.4 compat: experimental location, check_vma was check_rep
    from jax.experimental.shard_map import shard_map as _experimental_sm

    def _shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
        return _experimental_sm(f, mesh=mesh, in_specs=in_specs,
                                out_specs=out_specs, check_rep=check_vma)


_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")
_interpret_logged = False


def _interpret() -> bool:
    """Pallas interpret mode: auto (True off-TPU) unless overridden by
    ``SDE_PALLAS_INTERPRET`` (1/true/yes/on or 0/false/no/off). The chosen
    mode is logged once per process. Read at trace time — flipping the env
    var mid-session only affects programs not yet traced."""
    global _interpret_logged
    raw = os.environ.get("SDE_PALLAS_INTERPRET", "").strip().lower()
    if raw in _TRUTHY:
        mode, why = True, f"SDE_PALLAS_INTERPRET={raw}"
    elif raw in _FALSY:
        mode, why = False, f"SDE_PALLAS_INTERPRET={raw}"
    elif raw:
        raise ValueError(
            f"SDE_PALLAS_INTERPRET={raw!r} not understood — use one of "
            f"{_TRUTHY + _FALSY} or unset for auto")
    else:
        mode = jax.default_backend() != "tpu"
        why = f"auto (jax backend: {jax.default_backend()})"
    if not _interpret_logged:
        _logger.info("pallas interpret mode: %s [%s]", mode, why)
        _interpret_logged = True
    return mode


def probe_fusion_enabled() -> bool:
    """Whether registry kernels fuse the routing probe into the Pallas
    grid (one HBM pass) — on unless ``SDE_FUSED_PROBE`` is falsy."""
    return os.environ.get("SDE_FUSED_PROBE", "1").strip().lower() \
        not in _FALSY


def _pad_to(x: jax.Array, mult: int, axis: int = 0, value=0):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad, constant_values=value)


# ---------------------------------------------------------------------------
# blue path: hashed stream routing. The engine keeps each kind stack's
# stream->row map in an open-addressing hash table (service/routing.py
# owns the host-side inserts); this is the device half — a vectorized
# fixed-bound linear probe traced INSIDE the fused update programs, so
# routing arbitrary 63-bit stream ids still costs zero extra dispatches.
# The probe math lives in kernels/probe.py so the SAME code runs both as
# plain jnp here and inside the Pallas grids of the fused kernels.
# ---------------------------------------------------------------------------

# hi half of an empty slot; valid ids < 2**63 have hi <= 2**31-1. Batch
# padding uses it as the sid hi so padded lanes probe to row -1. (numpy
# scalar: a bare python int overflows jit's weak-int32 argument parsing)
_EMPTY_HI = np.uint32(0xFFFFFFFF)


def route_probe(keys_lo: jax.Array, keys_hi: jax.Array, rows: jax.Array,
                sid_lo: jax.Array, sid_hi: jax.Array, *,
                n_probe: int) -> jax.Array:
    """Rows for a batch of stream ids via linear probing: ``-1`` for
    unrouted ids. Keys are stored as uint32 (lo, hi) halves so the probe
    needs no 64-bit lanes; ``n_probe`` is the static trip count (the
    table's longest insert displacement, pow2-rounded by the engine so
    retraces stay bounded). The probe is a ``fori_loop`` gather chain —
    plain jnp, fusable into the caller's single blue-path dispatch. The
    slot hash must stay in lockstep with ``service.routing.slot_hash``.
    """
    return probe.probe_rows(keys_lo, keys_hi, rows,
                            sid_lo.astype(jnp.uint32),
                            sid_hi.astype(jnp.uint32), n_probe=n_probe)


def _pad_sids(sid_lo: jax.Array, sid_hi: jax.Array, t_tile: int):
    """Pad a stream-id batch to the T tile: padded lanes get the
    empty-slot hi pattern, which no occupied table slot carries, so the
    in-kernel probe resolves them to -1 (match nothing)."""
    lo = _pad_to(sid_lo.astype(jnp.uint32), t_tile)
    hi = _pad_to(sid_hi.astype(jnp.uint32), t_tile, value=_EMPTY_HI)
    return lo, hi


def _source_fold(out: jax.Array, idx: jax.Array, contrib: jax.Array,
                 source_rows: jax.Array) -> jax.Array:
    """Add a fresh single sketch into the data-source rows: out [n, d, w]
    indexed at source_rows += scatter(contrib at idx). Both CM and AMS
    merges are linear, so adding the batch's fresh sketch is exact; work
    is proportional to the number of source rows, not capacity."""
    n, d, w = out.shape
    rows = jnp.arange(d)[None, :]
    fresh = jnp.zeros((d, w), jnp.float32).at[rows, idx].add(contrib)
    return out.at[source_rows].add(fresh[None])


# ---------------------------------------------------------------------------
# red path: cached stacked-estimate dispatch (mirrors the engine's _update
# cache). ONE jitted program per (kind, out-sharding) answers any batch of
# ad-hoc/continuous queries against that kind's stack: per-row estimates are
# computed where the rows live (the [capacity] axis stays `synopsis`-sharded
# inside the program, so no state gather crosses the mesh) and only the tiny
# estimate vectors are replicated to the host via ``out_shardings``.
#
# ``TRACE_COUNT`` increments at trace time only and ``DISPATCH_COUNT`` on
# every call — tests use them to assert "one dispatch, one compiled program
# per kind per query-batch shape". ``KERNEL_CACHE_SIZE`` gauges how many
# compiled entries each KindCache holds (the caches are BOUNDED: engines
# evict their kinds' entries on stop/close instead of growing forever).
# ---------------------------------------------------------------------------

TRACE_COUNT: collections.Counter = collections.Counter()
DISPATCH_COUNT: collections.Counter = collections.Counter()
KERNEL_CACHE_SIZE: collections.Counter = collections.Counter()

# Blue-path pipeline probes: the engine's bounded ingest queue
# (service/pipeline.py) reports how many dispatched-but-unmaterialized
# batches are in flight, keyed by engine site. ``PIPELINE_IN_FLIGHT`` is
# the current gauge, ``PIPELINE_MAX_IN_FLIGHT`` the high-water mark —
# tests and benchmarks assert batches actually overlap (depth reached)
# and that fences drain back to zero, without reaching into internals.
PIPELINE_IN_FLIGHT: collections.Counter = collections.Counter()
PIPELINE_MAX_IN_FLIGHT: collections.Counter = collections.Counter()


def note_in_flight(tag: str, depth: int) -> None:
    """Record a pipeline's current in-flight batch depth."""
    PIPELINE_IN_FLIGHT[tag] = depth
    if depth > PIPELINE_MAX_IN_FLIGHT[tag]:
        PIPELINE_MAX_IN_FLIGHT[tag] = depth


# Gateway coalescing probes: the multi-client micro-batcher
# (service/gateway.py) reports how many client requests each fused
# dispatch absorbed. ``GATEWAY_TICKS`` counts micro-batcher ticks per
# gateway tag; ``GATEWAY_COALESCED`` counts client requests folded into
# coalesced engine calls, keyed by request class ("ingest" / "query").
# Tests pair these with TRACE_COUNT/DISPATCH_COUNT to assert that N
# concurrent clients cost ONE blue-path dispatch per kind per tick —
# serving cost scales with tick count, not client count.
GATEWAY_TICKS: collections.Counter = collections.Counter()
GATEWAY_COALESCED: collections.Counter = collections.Counter()


def note_coalesced(klass: str, n: int) -> None:
    """Record ``n`` client requests coalesced into one engine call."""
    GATEWAY_COALESCED[klass] += n


# Elasticity probes: the reconciler (service/reconciler.py) and the
# migration plane (service/migration.py via SDE.migrate_rows /
# implant_synopses) report the control loop's work. ``RECONCILE_COUNT``
# counts reconcile passes per tag (engine site or federation),
# ``MIGRATED_ROWS`` totals rows moved by the plane per engine site, and
# ``REBALANCE_IMBALANCE`` gauges the latest max/mean worker-load ratio a
# reconcile observed (1.0 = perfectly balanced). All three surface
# through ``SDE._status`` into the JSON status response.
RECONCILE_COUNT: collections.Counter = collections.Counter()
MIGRATED_ROWS: collections.Counter = collections.Counter()
REBALANCE_IMBALANCE: collections.Counter = collections.Counter()


def note_migrated(site: str, n_rows: int) -> None:
    """Record ``n_rows`` rows moved by the migration plane."""
    MIGRATED_ROWS[site] += n_rows


def note_reconcile(tag: str, imbalance: float) -> None:
    """Record one reconcile pass and the imbalance it measured."""
    RECONCILE_COUNT[tag] += 1
    REBALANCE_IMBALANCE[tag] = float(imbalance)


# Durability probes: the checkpoint path (SDE.snapshot) and the
# write-ahead ingest log (service/wal.py) report what persistence ships.
# ``CHECKPOINT_BYTES`` accumulates bytes handed to ``checkpoint.save``
# per engine site — benchmarks diff it around one save to compare a
# dirty-row delta against a full snapshot (the fig12 byte gate).
# ``DIRTY_ROWS`` gauges the row count the LATEST snapshot shipped (full:
# every capacity row; delta: only rows touched since the previous
# snapshot). ``WAL_APPENDS`` counts records appended to the write-ahead
# log per tag. All three surface through ``SDE._status``.
CHECKPOINT_BYTES: collections.Counter = collections.Counter()
DIRTY_ROWS: collections.Counter = collections.Counter()
WAL_APPENDS: collections.Counter = collections.Counter()


def note_checkpoint(site: str, n_bytes: int, n_rows: int) -> None:
    """Record one snapshot: bytes shipped (cumulative) and rows shipped
    (latest-snapshot gauge)."""
    CHECKPOINT_BYTES[site] += int(n_bytes)
    DIRTY_ROWS[site] = int(n_rows)


def note_wal_append(tag: str, n: int = 1) -> None:
    """Record ``n`` records appended to a write-ahead log."""
    WAL_APPENDS[tag] += n


# Subpopulation / outlier-workflow probes: ``SUBPOP_COVER_KEYS``
# accumulates how many covering-set group keys ``subpop_query`` merged
# per engine site — paired with DISPATCH_COUNT it pins "K maintained
# groups answered in ONE fused dispatch". ``OUTLIER_EMITS`` counts
# flagged subpopulations the continuous outlier workflow emitted per
# site; tests also use it (with the entry counters) to pin that the
# workflow rides the ALREADY-maintained synopses — zero extra builds.
SUBPOP_COVER_KEYS: collections.Counter = collections.Counter()
OUTLIER_EMITS: collections.Counter = collections.Counter()


def note_subpop(site: str, n_keys: int) -> None:
    """Record one subpop query's covering-set size."""
    SUBPOP_COVER_KEYS[site] += int(n_keys)


def note_outlier(site: str, n_flagged: int) -> None:
    """Record flagged subpopulations emitted by an outlier tick."""
    OUTLIER_EMITS[site] += int(n_flagged)


_KIND_CACHES: list["KindCache"] = []


class KindCache:
    """Bounded replacement for the old ``lru_cache(maxsize=None)`` jit
    caches: a dict keyed by tuples whose FIRST element is the kind
    instance, so an engine can evict every compiled program belonging to
    a kind it stops serving. Size is exported via ``KERNEL_CACHE_SIZE``
    (one gauge per cache name)."""

    def __init__(self, name: str):
        self.name = name
        self._entries: Dict[tuple, Any] = {}
        _KIND_CACHES.append(self)

    def get(self, key: tuple, build: Callable[[], Any]) -> Any:
        try:
            return self._entries[key]
        except KeyError:
            pass
        fn = self._entries[key] = build()
        KERNEL_CACHE_SIZE[self.name] = len(self._entries)
        return fn

    def evict_kind(self, kind) -> int:
        dead = [k for k in self._entries if k[0] == kind]
        for k in dead:
            del self._entries[k]
        if dead:
            KERNEL_CACHE_SIZE[self.name] = len(self._entries)
        return len(dead)

    def clear(self) -> int:
        n = len(self._entries)
        self._entries.clear()
        KERNEL_CACHE_SIZE[self.name] = 0
        return n


def evict_kind_caches(kind) -> int:
    """Drop every cached compiled program keyed to ``kind`` across all
    registered caches (estimate + engine update/step). Returns the number
    of entries evicted. Value-equal kind instances share entries, so this
    only forgets programs no OTHER engine could be sharing once the kind
    is value-unique to the evicting engine — eviction is a recompile-cost
    policy, never a correctness concern."""
    return sum(c.evict_kind(kind) for c in _KIND_CACHES)


def kernel_cache_size() -> int:
    """Total compiled entries across all kind caches (== the sum of the
    ``KERNEL_CACHE_SIZE`` gauges)."""
    return sum(len(c._entries) for c in _KIND_CACHES)


_ESTIMATE_ALL = KindCache("estimate_all")
_ESTIMATE_MERGED = KindCache("estimate_merged")
_ESTIMATE_COLLECTIVE = KindCache("estimate_collective")
_ESTIMATE_SUBPOP = KindCache("estimate_subpop")


def _estimate_all_fn(kind, out_sharding):
    name = type(kind).__name__

    def build():
        def program(state, rows, *query_args):
            TRACE_COUNT[name] += 1      # runs only when jit (re)traces
            return batched.stacked_estimate(kind, state, rows, *query_args)

        kw = {}
        if out_sharding is not None:
            kw["out_shardings"] = out_sharding
        return jax.jit(program, **kw)

    return _ESTIMATE_ALL.get((kind, out_sharding), build)


def estimate_all(kind, state, rows: jax.Array, *query_args,
                 out_sharding=None):
    """Batched red-path entry point: estimates for ``rows`` of ``state``
    with per-query args (leading axis == rows) in ONE jitted dispatch.

    ``out_sharding`` replicates the (small) estimate outputs when the stack
    is `synopsis`-sharded over a mesh; pass None off-mesh.
    """
    DISPATCH_COUNT[type(kind).__name__] += 1
    return _estimate_all_fn(kind, out_sharding)(state, rows, *query_args)


def _estimate_merged_fn(kind):
    name = type(kind).__name__

    def build():
        def program(states, *query_args):
            TRACE_COUNT[name] += 1
            merged = federated.merge_reduce(kind, states)
            one = jax.tree.map(lambda x: x[None], merged)
            return batched.stacked_estimate(
                kind, one, jnp.zeros((1,), jnp.int32), *query_args)

        return jax.jit(program)

    return _ESTIMATE_MERGED.get((kind,), build)


def _estimate_subpop_fn(kind, n_rows, out_sharding):
    name = type(kind).__name__

    def build():
        def program(state, rows, *query_args):
            TRACE_COUNT[name] += 1
            sub = jax.tree.map(lambda x: x[rows], state)
            merged = federated.merge_reduce(kind, sub)
            one = jax.tree.map(lambda x: x[None], merged)
            return batched.stacked_estimate(
                kind, one, jnp.zeros((1,), jnp.int32), *query_args)

        kw = {}
        if out_sharding is not None:
            kw["out_shardings"] = out_sharding
        return jax.jit(program, **kw)

    return _ESTIMATE_SUBPOP.get((kind, n_rows, out_sharding), build)


def estimate_subpop(kind, state, rows: jax.Array, *query_args,
                    out_sharding=None):
    """Subpopulation red path: gather a covering set of ``rows`` from a
    kind's stack, tree-merge them (``federated.merge_reduce``) and
    estimate the merged synopsis — ONE jitted dispatch, the
    ``subpop_query`` analog of ``estimate_merged``. Returns a leading
    [1] query axis. The covering set is NOT padded — padding would
    double-count sum-merge kinds — so the program retraces per distinct
    covering-set size (bounded by the distinct predicate shapes a
    workload issues; the gauge is ``KERNEL_CACHE_SIZE['estimate_subpop']``).
    """
    DISPATCH_COUNT[type(kind).__name__] += 1
    return _estimate_subpop_fn(kind, int(rows.shape[0]), out_sharding)(
        state, rows, *query_args)


def estimate_merged(kind, states_stacked, *query_args):
    """Federated red path: tree-merge a [S, ...] stack of per-site partial
    states and estimate the result, fused into ONE jitted dispatch (the
    responsible-site synthesis of paper Case 2/3). Returns a leading [1]
    query axis like ``estimate_all`` with a single row."""
    DISPATCH_COUNT[type(kind).__name__] += 1
    return _estimate_merged_fn(kind)(states_stacked, *query_args)


def _estimate_collective_fn(kind, mesh, axis_name):
    name = type(kind).__name__

    def build():
        def program(states, *query_args):
            TRACE_COUNT[name] += 1

            def shard_fn(shard, *qargs):
                local = jax.tree.map(lambda x: jnp.squeeze(x, 0), shard)
                merged = federated.merge_over_axis(kind, local, axis_name)
                one = jax.tree.map(lambda x: x[None], merged)
                return batched.stacked_estimate(
                    kind, one, jnp.zeros((1,), jnp.int32), *qargs)

            fn = _shard_map(shard_fn, mesh=mesh,
                            in_specs=(P(axis_name),) + (P(),) * len(
                                query_args),
                            out_specs=P(), check_vma=False)
            return fn(states, *query_args)

        return jax.jit(program)

    return _ESTIMATE_COLLECTIVE.get((kind, mesh, axis_name), build)


def estimate_collective(kind, states_stacked, *query_args, mesh, axis_name):
    """Federated red path as a REAL collective (paper Case 2/3 over DCN):
    ``states_stacked`` is a [S, ...] pytree SHARDED over ``axis_name`` —
    shard s is site s's local partial state, resident on site s's device —
    and the merge runs INSIDE the compiled program
    (``federated.merge_over_axis``: psum/pmax/all_gather over the site
    axis), with the stacked estimate executed on the merged result. One
    jitted dispatch, no host gather; the per-shard merge result is
    identical on every site, so the replicated output IS the responsible
    site's answer. Output layout matches ``estimate_merged`` (leading [1]
    query axis); the same TRACE_COUNT/DISPATCH_COUNT probes apply."""
    DISPATCH_COUNT[type(kind).__name__] += 1
    return _estimate_collective_fn(kind, mesh, axis_name)(
        states_stacked, *query_args)


# ---------------------------------------------------------------------------
# blue path: per-kind update wrappers. Every wrapper takes EITHER routed
# rows (``syn_idx``, -1 = drop) or a ``route`` tuple
# ``(keys_lo, keys_hi, table_rows, sid_lo, sid_hi, n_probe)`` — the second
# form fuses the routing probe into the Pallas grid so state + table are
# read in ONE HBM pass per batch.
# ---------------------------------------------------------------------------


def countmin_update(counts: jax.Array, syn_idx: jax.Array, items: jax.Array,
                    values: jax.Array, mask: jax.Array, *, seeds: jax.Array,
                    log2_width: int, weighted: bool = True,
                    source_rows: jax.Array | None = None,
                    source_tuple_mask: jax.Array | None = None) -> jax.Array:
    """Pallas-backed stacked CountMin update. counts [n, d, w].

    ``source_rows`` indexes data-source rows fed by every tuple under
    ``source_tuple_mask`` [T] (defaults to all tuples): their delta is
    accumulated ONCE as a fresh single sketch and broadcast-added (CM is
    linear), fused into the same dispatch as the routed kernel scatter.
    """
    n, d, w = counts.shape
    idx = hashing.bucket_hash(items, seeds, log2_width)
    v = values if weighted else jnp.ones_like(values)
    vm = v * mask.astype(jnp.float32)
    signs = jnp.ones((items.shape[0], d), jnp.float32)
    out = _scatter_call(counts, syn_idx, idx, vm, signs)
    if source_rows is not None:
        tm = mask if source_tuple_mask is None else source_tuple_mask
        vs = (v * tm.astype(jnp.float32))[:, None]
        out = _source_fold(out, idx, jnp.broadcast_to(vs, idx.shape),
                           source_rows)
    return out


def ams_update(counts: jax.Array, syn_idx: jax.Array, items: jax.Array,
               values: jax.Array, mask: jax.Array, *, seeds: jax.Array,
               log2_width: int,
               source_rows: jax.Array | None = None,
               source_tuple_mask: jax.Array | None = None) -> jax.Array:
    """Pallas-backed stacked AMS/count-sketch update. counts [n, d, w]."""
    idx = hashing.bucket_hash(items, seeds, log2_width)
    sgn = hashing.sign_hash(items, seeds)
    v = values * mask.astype(jnp.float32)
    out = _scatter_call(counts, syn_idx, idx, v, sgn)
    if source_rows is not None:
        tm = mask if source_tuple_mask is None else source_tuple_mask
        vs = (values * tm.astype(jnp.float32))[:, None] * sgn
        out = _source_fold(out, idx, vs, source_rows)
    return out


def _scatter_call(counts, syn_idx, idx, values, signs, *, route=None):
    n, d, w = counts.shape
    t_tile = 512
    s_tile = min(128, n) if n % min(128, n) == 0 else n
    w_tile = min(256, w)
    # pad T; padded rows get syn_idx = -1 / an unroutable sid -> match
    # nothing (values are also padded to 0)
    idx = _pad_to(idx.astype(jnp.int32), t_tile, value=-1)
    values = _pad_to(values.astype(jnp.float32), t_tile)
    signs = _pad_to(signs.astype(jnp.float32), t_tile)
    # pad n/w to tiles
    n_pad = (-n) % s_tile
    w_pad = (-w) % w_tile
    padded = jnp.pad(counts, ((0, n_pad), (0, 0), (0, w_pad)))
    if route is None:
        syn_idx = _pad_to(syn_idx.astype(jnp.int32), t_tile, value=-1)
        out = onehot_matmul.onehot_scatter_add(
            padded, syn_idx, idx, values, signs, s_tile=s_tile,
            w_tile=w_tile, t_tile=t_tile, interpret=_interpret())
    else:
        klo, khi, trows, slo, shi, n_probe = route
        slo, shi = _pad_sids(slo, shi, t_tile)
        out = onehot_matmul.onehot_probe_scatter(
            padded, klo, khi, trows, slo, shi, idx, values, signs,
            n_probe=n_probe, s_tile=s_tile, w_tile=w_tile, t_tile=t_tile,
            interpret=_interpret())
    return out[:n, :, :w]


def hll_update(regs: jax.Array, syn_idx: jax.Array, items: jax.Array,
               mask: jax.Array, *, seed: int, p: int,
               source_rows: jax.Array | None = None,
               source_tuple_mask: jax.Array | None = None) -> jax.Array:
    """Pallas-backed stacked HLL update. regs [n, m]. Data-source rows
    (``source_rows``) take an elementwise max with a fresh single-HLL of
    the batch — merge = max, fused into the same dispatch."""
    bucket, raw_rank = _hll_prep(items, seed, p)
    rank = jnp.where(mask, raw_rank, 0).astype(jnp.int32)
    out = _hll_call(regs, syn_idx, bucket, rank)
    if source_rows is not None:
        tm = mask if source_tuple_mask is None else source_tuple_mask
        src_rank = jnp.where(tm, raw_rank, 0).astype(jnp.int32)
        fresh = jnp.zeros((regs.shape[1],), jnp.int32).at[bucket].max(
            src_rank)
        out = out.at[source_rows].max(fresh[None, :])
    return out


def _hll_prep(items, seed: int, p: int):
    h = hashing.hash_u32(items, seed)
    bucket = (h >> np.uint32(32 - p)).astype(jnp.int32)
    rest = (h << np.uint32(p)).astype(jnp.uint32)
    raw_rank = jnp.where(rest == 0, 32 - p + 1, hashing.clz32(rest) + 1)
    return bucket, raw_rank


def _hll_call(regs, syn_idx, bucket, rank, *, route=None):
    n, m = regs.shape
    t_tile = 128
    s_tile = min(8, n)
    m_tile = min(128, m)
    bucket = _pad_to(bucket.astype(jnp.int32), t_tile)
    rank = _pad_to(rank.astype(jnp.int32), t_tile)   # pad rank 0 => no-op
    n_pad = (-n) % s_tile
    m_pad = (-m) % m_tile
    padded = jnp.pad(regs, ((0, n_pad), (0, m_pad)))
    if route is None:
        syn_idx = _pad_to(syn_idx.astype(jnp.int32), t_tile, value=-1)
        out = hll_max.hll_max_update(
            padded, syn_idx, bucket, rank, s_tile=s_tile, m_tile=m_tile,
            t_tile=t_tile, interpret=_interpret())
    else:
        klo, khi, trows, slo, shi, n_probe = route
        slo, shi = _pad_sids(slo, shi, t_tile)
        out = hll_max.hll_probe_max_update(
            padded, klo, khi, trows, slo, shi, bucket, rank,
            n_probe=n_probe, s_tile=s_tile, m_tile=m_tile, t_tile=t_tile,
            interpret=_interpret())
    return out[:n, :m]


def bloom_update(bits: jax.Array, syn_idx: jax.Array, items: jax.Array,
                 mask: jax.Array, *, seeds: jax.Array, log2_bits: int,
                 source_rows: jax.Array | None = None,
                 source_tuple_mask: jax.Array | None = None) -> jax.Array:
    """Pallas-backed stacked Bloom update. bits [n, m] int32 0/1; each
    tuple ORs its k hash positions into its routed row. Data-source rows
    take the OR (== max) of a fresh single-filter of the batch."""
    idx = hashing.bucket_hash(items, seeds, log2_bits)          # [T, k]
    upd = mask.astype(jnp.int32)
    out = _bitset_call(bits, syn_idx, idx, upd)
    if source_rows is not None:
        tm = mask if source_tuple_mask is None else source_tuple_mask
        out = out.at[source_rows].max(
            _bloom_fresh(bits.shape[1], idx, tm)[None])
    return out


def _bloom_fresh(m: int, idx, tuple_mask):
    u = jnp.broadcast_to(tuple_mask.astype(jnp.int32)[:, None], idx.shape)
    return jnp.zeros((m,), jnp.int32).at[idx].max(u)


def _bitset_call(bits, syn_idx, idx, upd, *, route=None):
    n, m = bits.shape
    t_tile = 128
    s_tile = min(8, n)
    m_tile = min(128, m)
    idx = _pad_to(idx.astype(jnp.int32), t_tile, value=-1)
    upd = _pad_to(upd.astype(jnp.int32), t_tile)     # pad upd 0 => no-op
    n_pad = (-n) % s_tile
    m_pad = (-m) % m_tile
    padded = jnp.pad(bits, ((0, n_pad), (0, m_pad)))
    if route is None:
        syn_idx = _pad_to(syn_idx.astype(jnp.int32), t_tile, value=-1)
        out = bitset_or.bitset_max_update(
            padded, syn_idx, idx, upd, s_tile=s_tile, m_tile=m_tile,
            t_tile=t_tile, interpret=_interpret())
    else:
        klo, khi, trows, slo, shi, n_probe = route
        slo, shi = _pad_sids(slo, shi, t_tile)
        out = bitset_or.bitset_probe_max_update(
            padded, klo, khi, trows, slo, shi, idx, upd, n_probe=n_probe,
            s_tile=s_tile, m_tile=m_tile, t_tile=t_tile,
            interpret=_interpret())
    return out[:n, :m]


def fm_update(state: jax.Array, syn_idx: jax.Array, which: jax.Array,
              pos: jax.Array, mask: jax.Array, *,
              source_rows: jax.Array | None = None,
              source_tuple_mask: jax.Array | None = None) -> jax.Array:
    """Pallas-backed stacked FM/PCSA update. state [n, maps, bits] int32
    0/1; each tuple sets bit (which, pos) of its routed row. The caller
    provides (which, pos) from the kind's hash split (``FMSketch
    ._which_pos``)."""
    upd = mask.astype(jnp.int32)
    out = _fm_call(state, syn_idx, which, pos, upd)
    if source_rows is not None:
        tm = mask if source_tuple_mask is None else source_tuple_mask
        fresh = jnp.zeros(state.shape[1:], jnp.int32).at[which, pos].max(
            tm.astype(jnp.int32))
        out = out.at[source_rows].max(fresh[None])
    return out


def _fm_call(state, syn_idx, which, pos, upd, *, route=None):
    n = state.shape[0]
    q = state.shape[1] * state.shape[2]
    t_tile = 128
    s_tile = min(8, n)
    m_tile = min(128, q)
    which = _pad_to(which.astype(jnp.int32), t_tile)
    pos = _pad_to(pos.astype(jnp.int32), t_tile)
    upd = _pad_to(upd.astype(jnp.int32), t_tile)     # pad upd 0 => no-op
    n_pad = (-n) % s_tile
    padded = jnp.pad(state, ((0, n_pad), (0, 0), (0, 0)))
    if route is None:
        syn_idx = _pad_to(syn_idx.astype(jnp.int32), t_tile, value=-1)
        out = fm_bitmap.fm_bit_update(
            padded, syn_idx, which, pos, upd, s_tile=s_tile, m_tile=m_tile,
            t_tile=t_tile, interpret=_interpret())
    else:
        klo, khi, trows, slo, shi, n_probe = route
        slo, shi = _pad_sids(slo, shi, t_tile)
        out = fm_bitmap.fm_probe_bit_update(
            padded, klo, khi, trows, slo, shi, which, pos, upd,
            n_probe=n_probe, s_tile=s_tile, m_tile=m_tile, t_tile=t_tile,
            interpret=_interpret())
    return out[:n]


def rhp_update(state: jax.Array, syn_idx: jax.Array, items: jax.Array,
               values: jax.Array, mask: jax.Array, *, seeds: jax.Array,
               source_rows: jax.Array | None = None,
               source_tuple_mask: jax.Array | None = None) -> jax.Array:
    """Pallas-backed stacked RHP/SimHash update. state [n, b] f32; each
    tuple adds ``v * sign_row`` into its routed row (dense — a matmul).
    Data-source rows add the batch's summed projection (linear merge)."""
    sgn = hashing.sign_hash(items, seeds)                       # [T, b]
    v = values * mask.astype(jnp.float32)
    out = _rhp_call(state, syn_idx, v, sgn)
    if source_rows is not None:
        tm = mask if source_tuple_mask is None else source_tuple_mask
        vs = (values * tm.astype(jnp.float32))[:, None]
        out = out.at[source_rows].add(jnp.sum(sgn * vs, axis=0)[None])
    return out


def _rhp_call(state, syn_idx, values, signs, *, route=None):
    n, b = state.shape
    t_tile = 512
    s_tile = min(128, n) if n % min(128, n) == 0 else n
    b_tile = min(128, b)
    values = _pad_to(values.astype(jnp.float32), t_tile)
    signs = _pad_to(signs.astype(jnp.float32), t_tile)
    n_pad = (-n) % s_tile
    b_pad = (-b) % b_tile
    padded = jnp.pad(state, ((0, n_pad), (0, b_pad)))
    if b_pad:
        signs = jnp.pad(signs, ((0, 0), (0, b_pad)))
    if route is None:
        syn_idx = _pad_to(syn_idx.astype(jnp.int32), t_tile, value=-1)
        out = rhp_project.rhp_project_update(
            padded, syn_idx, values, signs, s_tile=s_tile, b_tile=b_tile,
            t_tile=t_tile, interpret=_interpret())
    else:
        klo, khi, trows, slo, shi, n_probe = route
        slo, shi = _pad_sids(slo, shi, t_tile)
        out = rhp_project.rhp_probe_update(
            padded, klo, khi, trows, slo, shi, values, signs,
            n_probe=n_probe, s_tile=s_tile, b_tile=b_tile, t_tile=t_tile,
            interpret=_interpret())
    return out[:n, :b]


# ---------------------------------------------------------------------------
# the update-kernel registry. A kind opts into the Pallas blue path by
# declaring ``update_kernel = "<name>"``; the engine resolves the name here
# at dispatch time — no isinstance ladder anywhere. Every registered
# builder returns an update fn with the SAME signature:
#
#     fn(state, keys_lo, keys_hi, table_rows, sid_lo, sid_hi,
#        items, values, mask, source_rows, *, n_probe) -> state'
#
# where ``source_rows`` may be None and ``n_probe`` is static. When built
# with ``fuse_probe=True`` the routing probe runs INSIDE the Pallas grid
# (one HBM pass over state + table per batch); with False it runs as the
# jnp ``route_probe`` ahead of the plain scatter kernel (two logical
# passes, same results — the equivalence tests flip ``SDE_FUSED_PROBE``).
# The per-batch source fold stays outside the kernel either way: it is
# O(source rows), not O(capacity), and fuses into the same dispatch.
# ---------------------------------------------------------------------------

UPDATE_KERNELS: Dict[str, Callable] = {}


def register_update_kernel(name: str, builder: Callable, *,
                           overwrite: bool = False) -> None:
    """Register ``builder(kind, fuse_probe) -> update_fn`` under ``name``.
    Kinds reference kernels by name (``update_kernel = name``), so plugged
    kinds can reuse a stock kernel or bring their own without the engine
    learning any new types."""
    if name in UPDATE_KERNELS and not overwrite:
        raise ValueError(f"update kernel {name!r} already registered "
                         "(pass overwrite=True to replace)")
    UPDATE_KERNELS[name] = builder


def resolve_update_kernel(kind, fuse_probe: bool | None = None):
    """The registry lookup the engine dispatches through: returns the
    kind's built update fn, or None when the kind declares no
    ``update_kernel`` (engine falls back to ``batched.stacked_update``).
    ``fuse_probe`` defaults to :func:`probe_fusion_enabled`."""
    name = getattr(kind, "update_kernel", None)
    if name is None:
        return None
    builder = UPDATE_KERNELS.get(name)
    if builder is None:
        raise KeyError(
            f"{type(kind).__name__} declares update_kernel={name!r} but no "
            f"such kernel is registered — register_update_kernel({name!r}, "
            "builder) first, or drop the declaration to use the XLA "
            "fallback")
    if fuse_probe is None:
        fuse_probe = probe_fusion_enabled()
    return builder(kind, fuse_probe)


def _route_or_rows(fuse, klo, khi, trows, slo, shi, n_probe):
    """(route tuple, None) when fusing; (None, routed rows) when not."""
    if fuse:
        return (klo, khi, trows, slo, shi, n_probe), None
    return None, route_probe(klo, khi, trows, slo, shi, n_probe=n_probe)


def _countmin_kernel(kind, fuse):
    def fn(state, klo, khi, trows, slo, shi, items, vals, msk, src_rows, *,
           n_probe):
        seeds = kind._seeds()
        idx = hashing.bucket_hash(items, seeds, kind.log2_width)
        v = vals if kind.weighted else jnp.ones_like(vals)
        vm = v * msk.astype(jnp.float32)
        signs = jnp.ones((items.shape[0], kind.depth), jnp.float32)
        route, syn = _route_or_rows(fuse, klo, khi, trows, slo, shi, n_probe)
        out = _scatter_call(state, syn, idx, vm, signs, route=route)
        if src_rows is not None:
            out = _source_fold(out, idx,
                               jnp.broadcast_to(vm[:, None], idx.shape),
                               src_rows)
        return out
    return fn


def _ams_kernel(kind, fuse):
    def fn(state, klo, khi, trows, slo, shi, items, vals, msk, src_rows, *,
           n_probe):
        seeds = kind._seeds()
        idx = hashing.bucket_hash(items, seeds, kind.log2_width)
        sgn = hashing.sign_hash(items, seeds)
        v = vals * msk.astype(jnp.float32)
        route, syn = _route_or_rows(fuse, klo, khi, trows, slo, shi, n_probe)
        out = _scatter_call(state, syn, idx, v, sgn, route=route)
        if src_rows is not None:
            out = _source_fold(out, idx, v[:, None] * sgn, src_rows)
        return out
    return fn


def _hll_kernel(kind, fuse):
    def fn(state, klo, khi, trows, slo, shi, items, vals, msk, src_rows, *,
           n_probe):
        bucket, raw_rank = _hll_prep(items, kind.seed, kind.p)
        rank = jnp.where(msk, raw_rank, 0).astype(jnp.int32)
        route, syn = _route_or_rows(fuse, klo, khi, trows, slo, shi, n_probe)
        out = _hll_call(state, syn, bucket, rank, route=route)
        if src_rows is not None:
            fresh = jnp.zeros((state.shape[1],), jnp.int32).at[bucket].max(
                rank)
            out = out.at[src_rows].max(fresh[None, :])
        return out
    return fn


def _bloom_kernel(kind, fuse):
    def fn(state, klo, khi, trows, slo, shi, items, vals, msk, src_rows, *,
           n_probe):
        idx = hashing.bucket_hash(items, kind._seeds(), kind.log2_bits)
        upd = msk.astype(jnp.int32)
        route, syn = _route_or_rows(fuse, klo, khi, trows, slo, shi, n_probe)
        out = _bitset_call(state, syn, idx, upd, route=route)
        if src_rows is not None:
            out = out.at[src_rows].max(
                _bloom_fresh(state.shape[1], idx, msk)[None])
        return out
    return fn


def _fm_kernel(kind, fuse):
    def fn(state, klo, khi, trows, slo, shi, items, vals, msk, src_rows, *,
           n_probe):
        which, pos = kind._which_pos(items)
        upd = msk.astype(jnp.int32)
        route, syn = _route_or_rows(fuse, klo, khi, trows, slo, shi, n_probe)
        out = _fm_call(state, syn, which, pos, upd, route=route)
        if src_rows is not None:
            fresh = jnp.zeros(state.shape[1:], jnp.int32).at[
                which, pos].max(upd)
            out = out.at[src_rows].max(fresh[None])
        return out
    return fn


def _rhp_kernel(kind, fuse):
    def fn(state, klo, khi, trows, slo, shi, items, vals, msk, src_rows, *,
           n_probe):
        sgn = hashing.sign_hash(items, kind._seeds())
        v = vals * msk.astype(jnp.float32)
        route, syn = _route_or_rows(fuse, klo, khi, trows, slo, shi, n_probe)
        out = _rhp_call(state, syn, v, sgn, route=route)
        if src_rows is not None:
            out = out.at[src_rows].add(
                jnp.sum(sgn * v[:, None], axis=0)[None])
        return out
    return fn


register_update_kernel("countmin_scatter", _countmin_kernel)
register_update_kernel("ams_scatter", _ams_kernel)
register_update_kernel("hll_max", _hll_kernel)
register_update_kernel("bloom_bitset", _bloom_kernel)
register_update_kernel("fm_bitmap", _fm_kernel)
register_update_kernel("rhp_project", _rhp_kernel)


def dft_step(re: jax.Array, im: jax.Array, delta: jax.Array,
             mask: jax.Array, tw_re: jax.Array, tw_im: jax.Array):
    """Pallas-backed batched sliding-DFT tick. re/im [S, F]."""
    s, f = re.shape
    s_tile = 512 if s % 512 == 0 else (s if s <= 512 else 128)
    pad = (-s) % s_tile
    if pad:
        re = jnp.pad(re, ((0, pad), (0, 0)))
        im = jnp.pad(im, ((0, pad), (0, 0)))
        delta = jnp.pad(delta, (0, pad))
        mask = jnp.pad(mask, (0, pad))
    out_re, out_im = sliding_dft.sliding_dft_step(
        re, im, delta.astype(jnp.float32), mask.astype(jnp.float32),
        tw_re, tw_im, s_tile=s_tile, interpret=_interpret())
    return out_re[:s], out_im[:s]


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = 128,
                    bk: int = 128) -> jax.Array:
    """Streaming-softmax attention, O(S) HBM. q/k/v [BH, S, D]; pads S
    to block multiples (padded keys are masked by the causal/neg-inf
    path: padded QUERIES produce garbage rows which are sliced off)."""
    from . import flash_attention as fa
    bh, sq, d = q.shape
    sk = k.shape[1]
    pq, pk = (-sq) % bq, (-sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk:
        # padded keys get -inf via causal mask only when causal; for
        # non-causal, pad keys with -inf-producing zeros is unsafe ->
        # require divisibility there
        assert causal or pk == 0, "non-causal needs Sk % bk == 0"
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
    out = fa.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                             interpret=_interpret())
    return out[:, :sq]


def corr_matrix(coeffs: jax.Array, *, tile: int = 256) -> jax.Array:
    """Pairwise correlation estimates from [N, F, 2] or [N, K] coeffs."""
    x = coeffs.reshape(coeffs.shape[0], -1).astype(jnp.float32)
    n, k = x.shape
    t = min(tile, n)
    n_pad = (-n) % t
    k_pad = (-k) % 128                    # MXU lane alignment
    x = jnp.pad(x, ((0, n_pad), (0, k_pad)))
    out = pc.pairwise_corr(x, i_tile=t, j_tile=t, interpret=_interpret())
    return out[:n, :n]
