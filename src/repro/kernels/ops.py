"""Public jit'd wrappers around the Pallas kernels.

These are the entry points the engine uses. Each wrapper:
  * does the hashing / layout prep in plain jnp (cheap, fusable),
  * pads every dimension to its kernel tile,
  * picks interpret mode automatically (True off-TPU, so the kernels
    VALIDATE on CPU and compile natively on TPU),
  * exposes the same signature as the core/ scatter path so the engine
    can flip between `backend="xla"` and `backend="pallas"`.
"""
from __future__ import annotations

import collections
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import batched, federated, hashing
from . import onehot_matmul, hll_max, sliding_dft, pairwise_corr as pc

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:
    # jax <= 0.4 compat: experimental location, check_vma was check_rep
    from jax.experimental.shard_map import shard_map as _experimental_sm

    def _shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
        return _experimental_sm(f, mesh=mesh, in_specs=in_specs,
                                out_specs=out_specs, check_rep=check_vma)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, mult: int, axis: int = 0, value=0):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad, constant_values=value)


# ---------------------------------------------------------------------------
# blue path: hashed stream routing. The engine keeps each kind stack's
# stream->row map in an open-addressing hash table (service/routing.py
# owns the host-side inserts); this is the device half — a vectorized
# fixed-bound linear probe traced INSIDE the fused update programs, so
# routing arbitrary 63-bit stream ids still costs zero extra dispatches.
# ---------------------------------------------------------------------------

_ROUTE_GOLDEN = jnp.uint32(0x9E3779B9)
_ROUTE_EMPTY_HI = jnp.uint32(0xFFFFFFFF)   # hi half of an empty slot; valid
                                           # ids < 2**63 have hi <= 2**31-1


def route_probe(keys_lo: jax.Array, keys_hi: jax.Array, rows: jax.Array,
                sid_lo: jax.Array, sid_hi: jax.Array, *,
                n_probe: int) -> jax.Array:
    """Rows for a batch of stream ids via linear probing: ``-1`` for
    unrouted ids. Keys are stored as uint32 (lo, hi) halves so the probe
    needs no 64-bit lanes; ``n_probe`` is the static trip count (the
    table's longest insert displacement, pow2-rounded by the engine so
    retraces stay bounded). The probe is a ``fori_loop`` gather chain —
    plain jnp, fusable into the caller's single blue-path dispatch. The
    slot hash must stay in lockstep with ``service.routing.slot_hash``.
    """
    size_mask = jnp.int32(keys_lo.shape[0] - 1)
    sid_lo = sid_lo.astype(jnp.uint32)
    sid_hi = sid_hi.astype(jnp.uint32)
    h = hashing.mix32(sid_lo ^ hashing.mix32(sid_hi ^ _ROUTE_GOLDEN))
    slot0 = (h & size_mask.astype(jnp.uint32)).astype(jnp.int32)

    def body(_, carry):
        row, slot, done = carry
        k_hi = keys_hi[slot]
        hit = (keys_lo[slot] == sid_lo) & (k_hi == sid_hi)
        empty = k_hi == _ROUTE_EMPTY_HI
        row = jnp.where(hit & ~done, rows[slot], row)
        done = done | hit | empty
        slot = jnp.where(done, slot, (slot + 1) & size_mask)
        return row, slot, done

    row0 = jnp.full(sid_lo.shape, -1, jnp.int32)
    done0 = jnp.zeros(sid_lo.shape, bool)
    row, _, _ = jax.lax.fori_loop(0, n_probe, body, (row0, slot0, done0))
    return row


def _source_fold(out: jax.Array, idx: jax.Array, contrib: jax.Array,
                 source_rows: jax.Array) -> jax.Array:
    """Add a fresh single sketch into the data-source rows: out [n, d, w]
    indexed at source_rows += scatter(contrib at idx). Both CM and AMS
    merges are linear, so adding the batch's fresh sketch is exact; work
    is proportional to the number of source rows, not capacity."""
    n, d, w = out.shape
    rows = jnp.arange(d)[None, :]
    fresh = jnp.zeros((d, w), jnp.float32).at[rows, idx].add(contrib)
    return out.at[source_rows].add(fresh[None])


# ---------------------------------------------------------------------------
# red path: cached stacked-estimate dispatch (mirrors the engine's _update
# cache). ONE jitted program per (kind, out-sharding) answers any batch of
# ad-hoc/continuous queries against that kind's stack: per-row estimates are
# computed where the rows live (the [capacity] axis stays `synopsis`-sharded
# inside the program, so no state gather crosses the mesh) and only the tiny
# estimate vectors are replicated to the host via ``out_shardings``.
#
# ``TRACE_COUNT`` increments at trace time only and ``DISPATCH_COUNT`` on
# every call — tests use them to assert "one dispatch, one compiled program
# per kind per query-batch shape".
# ---------------------------------------------------------------------------

TRACE_COUNT: collections.Counter = collections.Counter()
DISPATCH_COUNT: collections.Counter = collections.Counter()

# Blue-path pipeline probes: the engine's bounded ingest queue
# (service/pipeline.py) reports how many dispatched-but-unmaterialized
# batches are in flight, keyed by engine site. ``PIPELINE_IN_FLIGHT`` is
# the current gauge, ``PIPELINE_MAX_IN_FLIGHT`` the high-water mark —
# tests and benchmarks assert batches actually overlap (depth reached)
# and that fences drain back to zero, without reaching into internals.
PIPELINE_IN_FLIGHT: collections.Counter = collections.Counter()
PIPELINE_MAX_IN_FLIGHT: collections.Counter = collections.Counter()


def note_in_flight(tag: str, depth: int) -> None:
    """Record a pipeline's current in-flight batch depth."""
    PIPELINE_IN_FLIGHT[tag] = depth
    if depth > PIPELINE_MAX_IN_FLIGHT[tag]:
        PIPELINE_MAX_IN_FLIGHT[tag] = depth


@functools.lru_cache(maxsize=None)
def _estimate_all_fn(kind, out_sharding):
    name = type(kind).__name__

    def program(state, rows, *query_args):
        TRACE_COUNT[name] += 1          # runs only when jit (re)traces
        return batched.stacked_estimate(kind, state, rows, *query_args)

    kw = {}
    if out_sharding is not None:
        kw["out_shardings"] = out_sharding
    return jax.jit(program, **kw)


def estimate_all(kind, state, rows: jax.Array, *query_args,
                 out_sharding=None):
    """Batched red-path entry point: estimates for ``rows`` of ``state``
    with per-query args (leading axis == rows) in ONE jitted dispatch.

    ``out_sharding`` replicates the (small) estimate outputs when the stack
    is `synopsis`-sharded over a mesh; pass None off-mesh.
    """
    DISPATCH_COUNT[type(kind).__name__] += 1
    return _estimate_all_fn(kind, out_sharding)(state, rows, *query_args)


@functools.lru_cache(maxsize=None)
def _estimate_merged_fn(kind):
    name = type(kind).__name__

    def program(states, *query_args):
        TRACE_COUNT[name] += 1
        merged = federated.merge_reduce(kind, states)
        one = jax.tree.map(lambda x: x[None], merged)
        return batched.stacked_estimate(
            kind, one, jnp.zeros((1,), jnp.int32), *query_args)

    return jax.jit(program)


def estimate_merged(kind, states_stacked, *query_args):
    """Federated red path: tree-merge a [S, ...] stack of per-site partial
    states and estimate the result, fused into ONE jitted dispatch (the
    responsible-site synthesis of paper Case 2/3). Returns a leading [1]
    query axis like ``estimate_all`` with a single row."""
    DISPATCH_COUNT[type(kind).__name__] += 1
    return _estimate_merged_fn(kind)(states_stacked, *query_args)


@functools.lru_cache(maxsize=None)
def _estimate_collective_fn(kind, mesh, axis_name):
    name = type(kind).__name__

    def program(states, *query_args):
        TRACE_COUNT[name] += 1

        def shard_fn(shard, *qargs):
            local = jax.tree.map(lambda x: jnp.squeeze(x, 0), shard)
            merged = federated.merge_over_axis(kind, local, axis_name)
            one = jax.tree.map(lambda x: x[None], merged)
            return batched.stacked_estimate(
                kind, one, jnp.zeros((1,), jnp.int32), *qargs)

        fn = _shard_map(shard_fn, mesh=mesh,
                        in_specs=(P(axis_name),) + (P(),) * len(query_args),
                        out_specs=P(), check_vma=False)
        return fn(states, *query_args)

    return jax.jit(program)


def estimate_collective(kind, states_stacked, *query_args, mesh, axis_name):
    """Federated red path as a REAL collective (paper Case 2/3 over DCN):
    ``states_stacked`` is a [S, ...] pytree SHARDED over ``axis_name`` —
    shard s is site s's local partial state, resident on site s's device —
    and the merge runs INSIDE the compiled program
    (``federated.merge_over_axis``: psum/pmax/all_gather over the site
    axis), with the stacked estimate executed on the merged result. One
    jitted dispatch, no host gather; the per-shard merge result is
    identical on every site, so the replicated output IS the responsible
    site's answer. Output layout matches ``estimate_merged`` (leading [1]
    query axis); the same TRACE_COUNT/DISPATCH_COUNT probes apply."""
    DISPATCH_COUNT[type(kind).__name__] += 1
    return _estimate_collective_fn(kind, mesh, axis_name)(
        states_stacked, *query_args)


def countmin_update(counts: jax.Array, syn_idx: jax.Array, items: jax.Array,
                    values: jax.Array, mask: jax.Array, *, seeds: jax.Array,
                    log2_width: int, weighted: bool = True,
                    source_rows: jax.Array | None = None,
                    source_tuple_mask: jax.Array | None = None) -> jax.Array:
    """Pallas-backed stacked CountMin update. counts [n, d, w].

    ``source_rows`` indexes data-source rows fed by every tuple under
    ``source_tuple_mask`` [T] (defaults to all tuples): their delta is
    accumulated ONCE as a fresh single sketch and broadcast-added (CM is
    linear), fused into the same dispatch as the routed kernel scatter.
    """
    n, d, w = counts.shape
    idx = hashing.bucket_hash(items, seeds, log2_width)
    v = values if weighted else jnp.ones_like(values)
    vm = v * mask.astype(jnp.float32)
    signs = jnp.ones((items.shape[0], d), jnp.float32)
    out = _scatter_call(counts, syn_idx, idx, vm, signs)
    if source_rows is not None:
        tm = mask if source_tuple_mask is None else source_tuple_mask
        vs = (v * tm.astype(jnp.float32))[:, None]
        out = _source_fold(out, idx, jnp.broadcast_to(vs, idx.shape),
                           source_rows)
    return out


def ams_update(counts: jax.Array, syn_idx: jax.Array, items: jax.Array,
               values: jax.Array, mask: jax.Array, *, seeds: jax.Array,
               log2_width: int,
               source_rows: jax.Array | None = None,
               source_tuple_mask: jax.Array | None = None) -> jax.Array:
    """Pallas-backed stacked AMS/count-sketch update. counts [n, d, w]."""
    idx = hashing.bucket_hash(items, seeds, log2_width)
    sgn = hashing.sign_hash(items, seeds)
    v = values * mask.astype(jnp.float32)
    out = _scatter_call(counts, syn_idx, idx, v, sgn)
    if source_rows is not None:
        tm = mask if source_tuple_mask is None else source_tuple_mask
        vs = (values * tm.astype(jnp.float32))[:, None] * sgn
        out = _source_fold(out, idx, vs, source_rows)
    return out


def _scatter_call(counts, syn_idx, idx, values, signs):
    n, d, w = counts.shape
    t_tile = 512
    s_tile = min(128, n) if n % min(128, n) == 0 else n
    w_tile = min(256, w)
    # pad T; padded rows get syn_idx = -1 -> match nothing
    syn_idx = _pad_to(syn_idx.astype(jnp.int32), t_tile, value=-1)
    idx = _pad_to(idx.astype(jnp.int32), t_tile, value=-1)
    values = _pad_to(values.astype(jnp.float32), t_tile)
    signs = _pad_to(signs.astype(jnp.float32), t_tile)
    # pad n/w to tiles
    n_pad = (-n) % s_tile
    w_pad = (-w) % w_tile
    padded = jnp.pad(counts, ((0, n_pad), (0, 0), (0, w_pad)))
    out = onehot_matmul.onehot_scatter_add(
        padded, syn_idx, idx, values, signs, s_tile=s_tile, w_tile=w_tile,
        t_tile=t_tile, interpret=_interpret())
    return out[:n, :, :w]


def hll_update(regs: jax.Array, syn_idx: jax.Array, items: jax.Array,
               mask: jax.Array, *, seed: int, p: int,
               source_rows: jax.Array | None = None,
               source_tuple_mask: jax.Array | None = None) -> jax.Array:
    """Pallas-backed stacked HLL update. regs [n, m]. Data-source rows
    (``source_rows``) take an elementwise max with a fresh single-HLL of
    the batch — merge = max, fused into the same dispatch."""
    n, m = regs.shape
    h = hashing.hash_u32(items, seed)
    bucket = (h >> np.uint32(32 - p)).astype(jnp.int32)
    rest = (h << np.uint32(p)).astype(jnp.uint32)
    raw_rank = jnp.where(rest == 0, 32 - p + 1, hashing.clz32(rest) + 1)
    rank = jnp.where(mask, raw_rank, 0).astype(jnp.int32)
    src_fresh = None
    if source_rows is not None:
        tm = mask if source_tuple_mask is None else source_tuple_mask
        src_rank = jnp.where(tm, raw_rank, 0).astype(jnp.int32)
        src_fresh = jnp.zeros((m,), jnp.int32).at[bucket].max(src_rank)

    t_tile = 128
    s_tile = min(8, n)
    m_tile = min(128, m)
    syn_idx = _pad_to(syn_idx.astype(jnp.int32), t_tile)
    bucket = _pad_to(bucket, t_tile)
    rank = _pad_to(rank, t_tile)          # pad rank 0 => no-op
    n_pad = (-n) % s_tile
    m_pad = (-m) % m_tile
    padded = jnp.pad(regs, ((0, n_pad), (0, m_pad)))
    out = hll_max.hll_max_update(padded, syn_idx, bucket, rank,
                                 s_tile=s_tile, m_tile=m_tile, t_tile=t_tile,
                                 interpret=_interpret())
    out = out[:n, :m]
    if src_fresh is not None:
        out = out.at[source_rows].max(src_fresh[None, :])
    return out


def dft_step(re: jax.Array, im: jax.Array, delta: jax.Array,
             mask: jax.Array, tw_re: jax.Array, tw_im: jax.Array):
    """Pallas-backed batched sliding-DFT tick. re/im [S, F]."""
    s, f = re.shape
    s_tile = 512 if s % 512 == 0 else (s if s <= 512 else 128)
    pad = (-s) % s_tile
    if pad:
        re = jnp.pad(re, ((0, pad), (0, 0)))
        im = jnp.pad(im, ((0, pad), (0, 0)))
        delta = jnp.pad(delta, (0, pad))
        mask = jnp.pad(mask, (0, pad))
    out_re, out_im = sliding_dft.sliding_dft_step(
        re, im, delta.astype(jnp.float32), mask.astype(jnp.float32),
        tw_re, tw_im, s_tile=s_tile, interpret=_interpret())
    return out_re[:s], out_im[:s]


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = 128,
                    bk: int = 128) -> jax.Array:
    """Streaming-softmax attention, O(S) HBM. q/k/v [BH, S, D]; pads S
    to block multiples (padded keys are masked by the causal/neg-inf
    path: padded QUERIES produce garbage rows which are sliced off)."""
    from . import flash_attention as fa
    bh, sq, d = q.shape
    sk = k.shape[1]
    pq, pk = (-sq) % bq, (-sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk:
        # padded keys get -inf via causal mask only when causal; for
        # non-causal, pad keys with -inf-producing zeros is unsafe ->
        # require divisibility there
        assert causal or pk == 0, "non-causal needs Sk % bk == 0"
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
    out = fa.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                             interpret=_interpret())
    return out[:, :sq]


def corr_matrix(coeffs: jax.Array, *, tile: int = 256) -> jax.Array:
    """Pairwise correlation estimates from [N, F, 2] or [N, K] coeffs."""
    x = coeffs.reshape(coeffs.shape[0], -1).astype(jnp.float32)
    n, k = x.shape
    t = min(tile, n)
    n_pad = (-n) % t
    k_pad = (-k) % 128                    # MXU lane alignment
    x = jnp.pad(x, ((0, n_pad), (0, k_pad)))
    out = pc.pairwise_corr(x, i_tile=t, j_tile=t, interpret=_interpret())
    return out[:n, :n]
