# Pallas TPU kernels for the SDE's compute hot spots:
#   onehot_matmul   — CountMin/AMS scatter-add as MXU one-hot matmuls
#   hll_max         — HLL register max-scatter (tiled VPU max sweep)
#   sliding_dft     — batched StatStream sliding-DFT tick
#   pairwise_corr   — blocked Gram/correlation (AggregativeOperation)
#   flash_attention — streaming-softmax attention (prefill memory fix)
# ops.py = jit'd wrappers (interpret=True off-TPU); ref.py = jnp oracles.
from . import ops, ref  # noqa: F401
