"""The hashed-routing linear probe, as pure vector math.

One implementation serves BOTH halves of the blue path:

  * the XLA path: ``kernels.ops.route_probe`` calls :func:`probe_rows` on
    plain device arrays before handing rows to ``batched.stacked_update``
    (probe-then-scatter — two passes over the batch);
  * the Pallas path: the fused update kernels call :func:`probe_rows` on
    VALUES LOADED INSIDE the kernel body (the routing-table mirror rides
    into VMEM as a whole-array block) and cache the result in a VMEM
    scratch shared across the sequential grid — probe once per batch,
    scatter in the same kernel, ONE HBM pass.

Everything here is shape-polymorphic jnp on uint32/int32 lanes — legal
both under jit and inside a Pallas kernel (gathers + ``fori_loop`` lower
fine in interpret and Mosaic). The slot hash must stay in lockstep with
``service.routing.slot_hash`` (the host-side insert path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing

# numpy scalars, NOT jnp arrays: a pre-existing device array captured by a
# Pallas kernel body is rejected ("captures constants"); numpy scalars are
# inlined into the kernel jaxpr as literals.
ROUTE_GOLDEN = np.uint32(0x9E3779B9)
ROUTE_EMPTY_HI = np.uint32(0xFFFFFFFF)    # hi half of an empty slot; valid
                                          # ids < 2**63 have hi <= 2**31-1


def slot0(sid_lo: jax.Array, sid_hi: jax.Array, size: int) -> jax.Array:
    """Initial probe slot per stream id (uint32 halves), table size pow2."""
    h = hashing.mix32(sid_lo ^ hashing.mix32(sid_hi ^ ROUTE_GOLDEN))
    return (h & jnp.uint32(size - 1)).astype(jnp.int32)


def probe_rows(keys_lo: jax.Array, keys_hi: jax.Array, rows: jax.Array,
               sid_lo: jax.Array, sid_hi: jax.Array, *,
               n_probe: int) -> jax.Array:
    """Rows for a batch of stream ids via linear probing: ``-1`` for
    unrouted ids. Keys are stored as uint32 (lo, hi) halves so the probe
    needs no 64-bit lanes; ``n_probe`` is the static trip count (the
    table's longest insert displacement, pow2-rounded by the engine so
    retraces stay bounded). The probe is a ``fori_loop`` gather chain —
    plain jnp, fusable into the caller's single blue-path dispatch or
    traceable inside a Pallas kernel body.
    """
    size_mask = jnp.int32(keys_lo.shape[0] - 1)
    sid_lo = sid_lo.astype(jnp.uint32)
    sid_hi = sid_hi.astype(jnp.uint32)
    slot = slot0(sid_lo, sid_hi, keys_lo.shape[0])

    def body(_, carry):
        row, slot, done = carry
        k_hi = keys_hi[slot]
        hit = (keys_lo[slot] == sid_lo) & (k_hi == sid_hi)
        empty = k_hi == ROUTE_EMPTY_HI
        row = jnp.where(hit & ~done, rows[slot], row)
        done = done | hit | empty
        slot = jnp.where(done, slot, (slot + 1) & size_mask)
        return row, slot, done

    row0 = jnp.full(sid_lo.shape, -1, jnp.int32)
    done0 = jnp.zeros(sid_lo.shape, bool)
    row, _, _ = jax.lax.fori_loop(0, n_probe, body, (row0, slot, done0))
    return row
