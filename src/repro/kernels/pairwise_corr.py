"""Blocked pairwise-correlation kernel (the AggregativeOperation hot spot).

corr[i, j] = 1 - (|c_i|^2 + |c_j|^2 - 2 <c_i, c_j>)  over DFT coefficient
vectors c (flattened [N, K], K = 2 * n_coeffs). The Gram matrix <c_i, c_j>
is a blocked [I_t x K] x [K x J_t] MXU matmul; K is padded to the 128 lane
width by ops.py. This is the paper's 12.5M-pairs workload: after DFT
bucket pruning only candidate blocks are evaluated (mask via bucket
adjacency happens outside; the kernel is the dense inner engine).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xi_ref, xj_ref, sqi_ref, sqj_ref, out_ref):
    xi = xi_ref[...]                       # [I_t, K]
    xj = xj_ref[...]                       # [J_t, K]
    gram = jax.lax.dot_general(
        xi, xj, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # [I_t, J_t]
    sqi = sqi_ref[...][:, None]
    sqj = sqj_ref[...][None, :]
    out_ref[...] = 1.0 - (sqi + sqj - 2.0 * gram)


@functools.partial(jax.jit, static_argnames=("i_tile", "j_tile", "interpret"))
def pairwise_corr(x: jax.Array, *, i_tile: int = 256, j_tile: int = 256,
                  interpret: bool = True) -> jax.Array:
    """x [N, K] flattened normalized DFT coeffs -> corr estimates [N, N]."""
    n, k = x.shape
    sq = jnp.sum(x * x, axis=-1)
    grid = (n // i_tile, n // j_tile)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((i_tile, k), lambda i, j: (i, 0)),
            pl.BlockSpec((j_tile, k), lambda i, j: (j, 0)),
            pl.BlockSpec((i_tile,), lambda i, j: (i,)),
            pl.BlockSpec((j_tile,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((i_tile, j_tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(x, x, sq, sq)
