"""Logical-axis -> mesh-axis sharding rule system (MaxText-style).

Every tensor in the model is annotated with logical axis names; the rules
map them onto physical mesh axes:

  batch    -> ("pod", "data")   data parallel (+ pod DP across pods)
  fsdp     -> "data"            parameter/optimizer-state sharding (ZeRO-3)
  tensor   -> "model"           tensor parallel (heads / d_ff / vocab)
  expert   -> "model" | None    expert parallel (per-arch: arctic yes,
                                grok no — 8 experts don't divide 16)
  seq      -> "model" | None    sequence/context parallel for activations
                                and seq-sharded KV caches
  synopsis -> "data"            SDE kind-stack row axis: the [capacity]
                                leading dim of every stacked synopsis
                                state is partitioned across workers
                                (paper Fig. 5 scale-out)

Rules compose per-architecture via ModelConfig flags; unknown / None
logical names map to replicated dims. When a logical dim does not divide
its mesh axis the rule degrades to replicated (recorded by callers).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshRules:
    batch: Tuple[str, ...] = ("pod", "data")
    fsdp: Optional[str] = "data"
    tensor: Optional[str] = "model"
    expert: Optional[str] = "model"
    seq: Optional[str] = None          # activations seq axis (SP)
    kv_seq: Optional[str] = "model"    # decode cache seq axis
    synopsis: Optional[str] = "data"   # SDE stacked-state row axis

    def resolve(self, logical: Optional[str], mesh: Mesh):
        if logical is None:
            return None
        axes = getattr(self, logical, None) if logical != "batch" else None
        if logical == "batch":
            present = tuple(a for a in self.batch if a in mesh.axis_names)
            return present if present else None
        if axes is None:
            return None
        return axes if axes in mesh.axis_names else None


DEFAULT_RULES = MeshRules()


def spec_for(rules: MeshRules, logical_axes: Tuple[Optional[str], ...],
             mesh: Mesh, dim_sizes: Tuple[int, ...] = ()) -> P:
    """PartitionSpec from logical axis names; degrades to replicated when
    the dim does not divide the mesh axis."""
    parts = []
    for i, name in enumerate(logical_axes):
        ax = rules.resolve(name, mesh)
        if ax is not None and dim_sizes:
            size = dim_sizes[i]
            if isinstance(ax, tuple):
                # degrade to the longest divisible prefix of the axes
                while ax and size % np_prod(
                        [mesh.shape[a] for a in ax]) != 0:
                    ax = ax[:-1]
                ax = ax or None
            elif size % mesh.shape[ax] != 0:
                ax = None
        parts.append(ax)
    return P(*parts)


def np_prod(xs):
    out = 1
    for x in xs:
        out *= x
    return out


def sharding_for(rules: MeshRules, logical_axes, mesh: Mesh,
                 dim_sizes=()) -> NamedSharding:
    return NamedSharding(mesh, spec_for(rules, logical_axes, mesh, dim_sizes))


def stack_sharding(rules: MeshRules, mesh: Mesh,
                   capacity: int) -> NamedSharding:
    """Sharding for a stacked synopsis state: partition the leading
    [capacity] row axis over the ``synopsis`` logical axis, replicate
    everything trailing (the per-row sketch dims). A P spec shorter than
    the leaf rank leaves the remaining dims replicated, so ONE sharding
    covers every leaf of the stacked pytree."""
    return NamedSharding(mesh, spec_for(rules, ("synopsis",), mesh,
                                        (capacity,)))


def constrainer(rules: MeshRules, mesh: Mesh):
    """Returns constrain(tensor, logical_axes) used inside model code."""
    def constrain(t: jax.Array, logical_axes: Tuple[Optional[str], ...]):
        if mesh.empty:
            return t
        spec = spec_for(rules, logical_axes, mesh, t.shape)
        return jax.lax.with_sharding_constraint(t, NamedSharding(mesh, spec))
    return constrain


def shard_params_spec(logical_tree, rules: MeshRules, mesh: Mesh,
                      shape_tree):
    """Map a pytree of logical-axis tuples (+ matching shapes) to
    NamedShardings."""
    return jax.tree.map(
        lambda axes, shp: sharding_for(rules, axes, mesh, shp.shape),
        logical_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))
