from .specs import MeshRules, DEFAULT_RULES, spec_for, constrainer, shard_params_spec  # noqa: F401
