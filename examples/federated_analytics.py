"""Federated scalability demo (paper Section 8.1d): geo-dispersed sites
each maintain local synopses; a responsible site synthesizes global
estimates by exchanging ONLY synopsis states — orders of magnitude less
traffic than shipping the raw streams.

  PYTHONPATH=src python examples/federated_analytics.py --sites 8
"""
import argparse

import numpy as np

from repro.service import Federation
from repro.streams import StockStream


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--sites", type=int, default=4)
    ap.add_argument("--streams-per-site", type=int, default=250)
    ap.add_argument("--batches", type=int, default=50)
    args = ap.parse_args(argv)

    names = [f"site-{i}" for i in range(args.sites)]
    fed = Federation(names)
    fed.broadcast({"type": "build", "request_id": "b1",
                   "synopsis_id": "global_cardinality",
                   "kind": "hyperloglog", "params": {"rse": 0.02},
                   "federated": True, "responsible_site": names[0]})
    fed.broadcast({"type": "build", "request_id": "b2",
                   "synopsis_id": "global_volume",
                   "kind": "countmin", "params": {"eps": 0.005,
                                                  "delta": 0.01},
                   "federated": True, "responsible_site": names[0]})

    # each site sees a disjoint slice of the global stock universe
    raw_bytes = 0
    for i, name in enumerate(names):
        stock = StockStream(n_streams=args.streams_per_site, seed=i)
        for _ in range(args.batches):
            sids, vals = stock.level2_batch(4096)
            gids = sids.astype(np.uint32) + i * args.streams_per_site
            fed.sdes[name].ingest(gids, vals)
            raw_bytes += len(sids) * 16          # what raw shipping costs

    true_total = args.sites * args.streams_per_site
    est = float(fed.query_federated("global_cardinality", {}, names[0]))
    syn_bytes = fed.query_bytes("global_cardinality") \
        + fed.query_bytes("global_volume")
    vol = fed.query_federated("global_volume", {"items": [3]}, names[0])

    print(f"sites: {args.sites}, streams/site: {args.streams_per_site}")
    print(f"global distinct streams: {est:,.0f} (true {true_total:,})")
    print(f"global volume of stream 3 (CM): {float(vol[0]):,.0f}")
    print(f"communication for the federated answer: {syn_bytes/1e3:,.1f} KB")
    print(f"raw-stream shipping would cost:        {raw_bytes/1e3:,.1f} KB")
    print(f"=> federated gain: {raw_bytes/max(syn_bytes,1):,.1f}x")


if __name__ == "__main__":
    main()
