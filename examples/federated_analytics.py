"""Federated scalability demo (paper Section 8.1d): geo-dispersed sites
each maintain local synopses; a responsible site synthesizes global
estimates by exchanging ONLY synopsis states — orders of magnitude less
traffic than shipping the raw streams. With one device per site
available, the sites are mapped onto a `site` mesh axis and every
federated answer runs as ONE compiled collective program (psum/pmax over
the axis); otherwise the host-merge path answers identically.

  PYTHONPATH=src python examples/federated_analytics.py --sites 8
"""
import argparse

import numpy as np

from repro.service import Federation
from repro.streams import StockStream


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--sites", type=int, default=4)
    ap.add_argument("--streams-per-site", type=int, default=250)
    ap.add_argument("--batches", type=int, default=50)
    args = ap.parse_args(argv)

    from repro.launch.mesh import try_federation_mesh
    names = [f"site-{i}" for i in range(args.sites)]
    fed = Federation(names, mesh=try_federation_mesh(args.sites))
    fed.broadcast({"type": "build", "request_id": "b1",
                   "synopsis_id": "global_cardinality",
                   "kind": "hyperloglog", "params": {"rse": 0.02},
                   "federated": True, "responsible_site": names[0]})
    fed.broadcast({"type": "build", "request_id": "b2",
                   "synopsis_id": "global_volume",
                   "kind": "countmin", "params": {"eps": 0.005,
                                                  "delta": 0.01},
                   "federated": True, "responsible_site": names[0]})

    # each site sees a disjoint slice of the global stock universe
    raw_bytes = 0
    for i, name in enumerate(names):
        stock = StockStream(n_streams=args.streams_per_site, seed=i)
        for _ in range(args.batches):
            sids, vals = stock.level2_batch(4096)
            gids = sids.astype(np.uint32) + i * args.streams_per_site
            fed.sdes[name].ingest(gids, vals)
            raw_bytes += len(sids) * 16          # what raw shipping costs

    true_total = args.sites * args.streams_per_site
    card = fed.handle({"type": "federated_query", "request_id": "q1",
                       "synopsis_id": "global_cardinality",
                       "responsible_site": names[0]})
    vol = fed.handle({"type": "federated_query", "request_id": "q2",
                      "synopsis_id": "global_volume",
                      "query": {"items": [3]},
                      "responsible_site": names[0]})
    # the response params carry what the EXECUTED path actually shipped
    # across the site axis, plus the host-merge baseline (fig 5d)
    shipped = sum(r.params["collective_operand_bytes"] for r in (card, vol))
    host_bytes = sum(r.params["host_merge_bytes"] for r in (card, vol))

    print(f"sites: {args.sites}, streams/site: {args.streams_per_site}, "
          f"merge path: {card.params['path']}")
    print(f"global distinct streams: {float(card.value):,.0f} "
          f"(true {true_total:,})")
    print(f"global volume of stream 3 (CM): {float(vol.value[0]):,.0f}")
    print(f"communication for the federated answer: {shipped/1e3:,.1f} KB")
    print(f"host-merge state shipping would cost:  {host_bytes/1e3:,.1f} KB")
    print(f"raw-stream shipping would cost:        {raw_bytes/1e3:,.1f} KB")
    print(f"=> federated gain: {raw_bytes/max(shipped,1):,.1f}x vs raw, "
          f"{host_bytes/max(shipped,1):,.1f}x vs host-merge")


if __name__ == "__main__":
    main()
