"""Quickstart: the Synopses Data Engine in 60 seconds.

Builds synopses over a synthetic stock stream through the SDEaaS JSON API,
queries them, merges federated states, and shows the DFT correlation
bucketing — the paper's core loop end to end on one CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import asyncio
import json

import numpy as np

from repro.service import SDE, Federation
from repro.streams import StockStream


def main():
    sde = SDE()

    # 1. Build synopses on-the-fly (paper Section 3: Build Synopsis).
    #    One request maintains a CountMin per stock for 500 stocks.
    #    Stream ids are ARBITRARY non-negative ints (< 2**63): routing is
    #    hashed, so 64-bit hashed user ids / sensor UUIDs work as-is —
    #    no re-keying to a dense range. Pass `stream_ids=[...]` on a
    #    per-stream build to cover a sparse/hashed id population.
    for req in [
        {"type": "build", "request_id": "r1", "synopsis_id": "bids",
         "kind": "countmin", "params": {"eps": 0.01, "delta": 0.05},
         "per_stream_of_source": True, "n_streams": 500,
         "source_id": "stocks"},
        {"type": "build", "request_id": "r2", "synopsis_id": "cardinality",
         "kind": "hyperloglog", "params": {"rse": 0.02}},
        {"type": "build", "request_id": "r3", "synopsis_id": "dft",
         "kind": "dft", "params": {"window": 64, "n_coeffs": 8,
                                   "threshold": 0.9},
         "per_stream_of_source": True, "n_streams": 500},
    ]:
        resp = sde.handle(req)
        assert resp.ok, resp.error
        print(f"built {resp.synopsis_id}: {resp.params}")

    # 2. Ingest the stream (blue path) — one call updates EVERYTHING.
    stock = StockStream(n_streams=500, group_size=10, seed=0)
    for _ in range(200):
        sids, vals = stock.level1_batch(2000)
        sde.ingest(sids, vals)
    print(f"\ningested {sde.tuples_ingested:,} tuples; engine state = "
          f"{sde.memory_bytes()/1e6:.1f} MB for "
          f"{len(sde.entries)} synopses")

    # 2b. Pipelined ingest: `SDE(pipelined=True)` parks each batch's
    #     continuous-query outputs on a bounded (depth-2) queue instead
    #     of syncing device->host inside ingest, so host prep for the
    #     next batch overlaps the device work of the previous one.
    #     Syncs happen ONLY when (a) a newer batch pushes an old one off
    #     the queue, (b) you call flush() — the explicit barrier — or
    #     (c) the engine fences itself before a query/stop/build/
    #     snapshot, which is why both modes return identical results.
    psde = SDE(pipelined=True)
    resp = psde.handle({"type": "build", "request_id": "p1",
                        "synopsis_id": "live", "kind": "hyperloglog",
                        "params": {"rse": 0.02}, "continuous": True})
    assert resp.ok, resp.error
    pstock = StockStream(n_streams=500, group_size=10, seed=1)
    for _ in range(8):
        sids, vals = pstock.level1_batch(2000)
        batch = psde.ingest(sids, vals)       # returns without syncing
    print(f"\npipelined ingest: batch {batch} acked, "
          f"{psde.pending_batches} batches still in flight")
    drained = psde.flush()                    # the explicit barrier
    print(f"flush() drained {drained} batches -> "
          f"{len(psde.continuous_out)} continuous responses "
          f"(latest cardinality "
          f"{float(psde.continuous_out[-1].value):,.0f})")

    # 2c. Pallas backend: `SDE(backend="pallas")` (or SDE_BACKEND=pallas)
    #     runs the blue path through hand-written Pallas kernels instead
    #     of XLA's scatter lowering. Every scatter kind covers it —
    #     countmin, ams, hyperloglog, bloom, fm and rhp each declare
    #     `update_kernel = "<name>"` resolved from the kernels.ops
    #     registry at dispatch (no isinstance ladder); scan kinds and the
    #     DFT step path fall back to the same XLA programs as backend=
    #     "xla". By default the routing probe runs INSIDE the kernel grid
    #     (one HBM pass over state + table per kind per batch;
    #     SDE_FUSED_PROBE=0 splits it out again), and states stay
    #     byte-identical to the XLA backend either way — that equivalence
    #     plus the modeled HBM gain is CI-gated by
    #     `python -m benchmarks.roofline --check` (EXPERIMENTS.md
    #     §Roofline). Off-TPU the kernels run in interpret mode
    #     (override with SDE_PALLAS_INTERPRET=0/1). A plugged kind reuses
    #     a stock kernel by declaring its name, or brings its own via
    #     `kernels.ops.register_update_kernel(name, builder)`.
    ksde = SDE(backend="pallas")
    resp = ksde.handle({"type": "build", "request_id": "k1",
                        "synopsis_id": "kbids", "kind": "countmin",
                        "params": {"eps": 0.1, "delta": 0.1},
                        "per_stream_of_source": True, "n_streams": 500,
                        "source_id": "stocks"})
    assert resp.ok, resp.error
    kstock = StockStream(n_streams=500, group_size=10, seed=0)
    for _ in range(4):
        ksde.ingest(*kstock.level1_batch(2000))
    q = ksde.handle({"type": "adhoc", "request_id": "kq",
                     "synopsis_id": "kbids/42", "query": {"items": [42]}})
    print(f"\npallas backend: stock 42 bid volume (CM) "
          f"{float(q.value[0]):,.1f} via fused probe+update kernel")

    # 2d. Serving many clients: the `SynopsisGateway` front door
    #     multiplexes N concurrent clients onto ONE engine. Per tick it
    #     concatenates every client's ingest into one fused blue-path
    #     dispatch per kind (the acks below all carry the same batch id
    #     and coalesced=8) and folds concurrent ad-hoc queries into one
    #     `query_many` dispatch. A request's `tenant` namespaces its
    #     synopsis keys ("acme::cm" vs "globex::cm" in the engine) while
    #     STREAM ids stay shared — many workflows, same streams — which
    #     is exactly what makes their traffic coalescible.
    #     `python -m repro.launch.sde_server --port 7077` serves this
    #     over TCP with per-client backpressure.
    async def serve_clients():
        from repro.service import SynopsisGateway
        gw = SynopsisGateway(SDE(), tick_interval=0.001)
        await gw.start()

        async def one_client(j):
            tenant = "acme" if j % 2 else "globex"
            c = gw.connect(f"client-{j}", tenant=tenant)
            r = await gw.submit(c, {
                "type": "build", "request_id": f"b{j}",
                "synopsis_id": f"cm{j}", "kind": "countmin",
                "params": {"eps": 0.05, "delta": 0.1, "weighted": False}})
            assert r.ok, r.error
            rng = np.random.RandomState(j)
            r = await gw.submit(c, {
                "type": "ingest", "request_id": f"i{j}",
                "stream_ids": rng.randint(0, 500, 64).tolist(),
                "values": [1.0] * 64})
            return r.value

        acks = await asyncio.gather(*(one_client(j) for j in range(8)))
        await gw.stop()
        return acks

    acks = asyncio.run(serve_clients())
    coalesced = max(a["coalesced"] for a in acks)
    print(f"\ngateway: 8 clients' ingest coalesced "
          f"{coalesced}-wide into {len({a['batch'] for a in acks})} "
          f"fused batch(es) — dispatch cost amortizes across clients")

    # 2e. Elastic rebalancing: the `Reconciler` closes the loop the
    #     balancer opens (paper Section 7). The engine's OWN estimator
    #     synopses measure the load — HLL says how many streams are
    #     active, CountMin says how heavy each one is — then WFD plans a
    #     target placement over `n_workers` row slices, `Placement.diff`
    #     reduces it to minimal moves, and the migration plane
    #     (`service/migration.py`) relocates exactly those rows, routing
    #     entries remapped atomically so fused programs never retrace.
    #     Live it rides the gateway tick or `sde_server
    #     --reconcile-interval`; here one explicit `step()` after skewed
    #     traffic shows the mechanism.
    from repro.service import Reconciler
    esde = SDE()
    for req in [
        {"type": "build", "request_id": "e1", "synopsis_id": "load",
         "kind": "countmin", "params": {"eps": 0.05, "delta": 0.1,
                                        "weighted": False},
         "per_stream_of_source": True, "n_streams": 64},
        {"type": "build", "request_id": "e2", "synopsis_id": "ehll",
         "kind": "hyperloglog", "params": {"rse": 0.05}},
        {"type": "build", "request_id": "e3", "synopsis_id": "ecm",
         "kind": "countmin", "params": {"eps": 0.01, "delta": 0.01,
                                        "weighted": False}},
    ]:
        assert esde.handle(req).ok
    rng = np.random.RandomState(7)
    hot = rng.choice(64, 4096, p=np.where(np.arange(64) < 8,
                                          0.9 / 8, 0.1 / 56))
    esde.ingest(hot.astype(np.int64), np.ones(4096, np.float32))
    rep = Reconciler(esde, "ehll", "ecm", n_workers=4).step()
    assert rep["applied"], rep       # skewed traffic always rebalances
    print(f"\nreconciler: applied={rep['applied']} "
          f"moves={rep['moves']} rows={rep['migrated_rows']} "
          f"imbalance {rep['imbalance_before']:.2f} -> "
          f"{rep['imbalance_after']:.2f} — hot streams spread across "
          f"4 workers, state moved byte-exactly")

    # 2f. Durable mode: checkpointing off the hot path. A `WriteAheadLog`
    #     records every state-mutating call durably before its ack
    #     (lifecycle requests pre-apply; ingest batches after a
    #     successful apply, keyed by the engine-assigned batch id, so a
    #     refused batch never lands in the log), and a `Checkpointer`
    #     takes an
    #     incremental snapshot every `interval` ingested batches — a
    #     dirty-row DELTA chained on the last full base, written by a
    #     background thread, so the steady-state cost is O(rows touched),
    #     not O(engine state). `recover` = restore the latest snapshot +
    #     replay the WAL tail through the normal ingest path; monotonic
    #     seq/batch watermarks make the replay exactly-once, so the
    #     recovered engine is byte-identical to the acked pre-crash
    #     state. Serving processes get all of this with
    #     `python -m repro.launch.sde_server --wal ingest.wal
    #     --checkpoint-dir ckpt --checkpoint-interval 8` (add `--recover`
    #     on restart; `--full-snapshots` opts back into the old
    #     synchronous full path).
    import tempfile
    from repro.service import Checkpointer, WriteAheadLog, recover
    ck_dir = tempfile.mkdtemp()
    wal_path = ck_dir + "/ingest.wal"
    dsde = SDE()
    wal = WriteAheadLog(wal_path, tag=dsde.site)
    ckp = Checkpointer(dsde, ck_dir, interval=4)   # delta every 4 batches
    breq = {"type": "build", "request_id": "d1", "synopsis_id": "dcm",
            "kind": "countmin", "params": {"eps": 0.05, "delta": 0.1},
            "per_stream_of_source": True, "n_streams": 64}
    wal.append_request(breq)
    assert dsde.handle(breq).ok
    dsde.wal_seq = wal.seq
    drng = np.random.RandomState(3)
    for _ in range(10):                  # 2 deltas + a 2-batch WAL tail
        sids = drng.randint(0, 64, 256).astype(np.int64)
        vals = np.ones(256, np.float32)
        batch = dsde.ingest(sids, vals)
        wal.append_ingest(batch, sids, vals)   # post-apply: acked id
        wal.sync()                       # durable-before-ack point
        dsde.wal_seq = wal.seq
        ckp.maybe_snapshot()
    wal.close()
    dsde.wait_for_snapshot()
    back = recover(ck_dir, wal_path)     # "kill -9", then this
    q1 = dsde.handle({"type": "adhoc", "request_id": "dq",
                      "synopsis_id": "dcm/9", "query": {"items": [9]}})
    q2 = back.handle({"type": "adhoc", "request_id": "dq",
                      "synopsis_id": "dcm/9", "query": {"items": [9]}})
    assert float(q1.value[0]) == float(q2.value[0])
    print(f"\ndurable mode: {ckp.snapshots} incremental snapshots + "
          f"{back.batches_ingested - 8}-batch WAL tail -> recovered "
          f"engine matches (stream 9 count {float(q2.value[0]):,.0f})")

    # 2g. Multidim subpopulations + the continuous outlier workflow.
    #     `build_multidim` declares attribute dimensions with finite
    #     domains; every subset of dimensions (a "level") gets one
    #     synopsis per value combination, all encoded into the SAME
    #     63-bit stream-id space the router already speaks — so multidim
    #     groups are ordinary routed streams and ingest stays ONE fused
    #     dispatch per kind. `subpop_query` answers a conjunction of
    #     per-dimension predicates by merging the minimal covering key
    #     set in one fused gather+merge+estimate dispatch (vs scanning
    #     every leaf synopsis — fig13 gates the >= 4x win). A tracked
    #     outlier workflow re-scores one level against the population
    #     every ingest tick off the SAME synopses (zero extra builds),
    #     flagging robust-z outliers through the continuous channel.
    msde = SDE()
    assert msde.handle({
        "type": "build_multidim", "request_id": "m1", "synopsis_id":
        "trades", "kind": "countmin",
        "params": {"eps": 0.005, "delta": 0.01, "weighted": False},
        "dims": {"region": ["EU", "US", "APAC"],
                 "venue": ["lit", "dark"]}}).ok
    assert msde.handle({
        "type": "track_outliers", "request_id": "m2", "workflow_id":
        "hot-venues", "synopsis_id": "trades", "level": ["region"],
        "query": {"items": [1]}, "threshold": 2.0}).ok
    mrng = np.random.RandomState(11)
    recs = [{"region": str(r), "venue": str(v)} for r, v in zip(
        mrng.choice(["EU", "US", "APAC"], 3000, p=[0.7, 0.2, 0.1]),
        mrng.choice(["lit", "dark"], 3000))]
    assert msde.handle({
        "type": "ingest_multidim", "request_id": "m3", "synopsis_id":
        "trades", "records": recs, "values": [1.0] * len(recs),
        "items": [1] * len(recs)}).ok
    sq = msde.handle({"type": "subpop_query", "request_id": "m4",
                      "synopsis_id": "trades",
                      "where": {"region": ["EU", "US"], "venue": "lit"},
                      "query": {"items": [1]}})
    msde.flush()
    ow = [r for r in msde.continuous_out.drain()
          if r.synopsis_id == "hot-venues"][-1]
    print(f"\nsubpop EU|US x lit trades: {float(sq.value[0]):,.0f} "
          f"(covering {sq.params['cover_keys']} keys, one dispatch); "
          f"outlier tick flagged {[o['group'] for o in ow.value['outliers']]}")
    msde.close()

    # 3. Ad-hoc queries (red path).
    q = sde.handle({"type": "adhoc", "request_id": "q1",
                    "synopsis_id": "cardinality"})
    print(f"\ndistinct stocks (HLL):   {float(q.value):,.0f}  (true 500)")
    q = sde.handle({"type": "adhoc", "request_id": "q2",
                    "synopsis_id": "bids/42", "query": {"items": [42]}})
    print(f"stock 42 bid volume (CM): {float(q.value[0]):,.1f}")
    q = sde.handle({"type": "adhoc", "request_id": "q3",
                    "synopsis_id": "dft/7"})
    print(f"stock 7 DFT bucket:       {int(q.value['bucket'])} "
          f"(coeffs {q.value['coeffs'].shape})")

    # 3b. Querying at scale: N ad-hoc queries of one kind are answered by
    #     ONE jitted stacked-estimate dispatch (the batched red path) —
    #     this is what keeps thousands of concurrent SDEaaS queries from
    #     serializing on host round trips (paper Fig. 8).
    batch = sde.handle({
        "type": "query_many", "request_id": "qm",
        "queries": [{"synopsis_id": f"bids/{s}", "query": {"items": [s]}}
                    for s in range(100)]})
    vols = [float(r["value"][0]) for r in batch.value]
    print(f"\n100 bid volumes in one dispatch: "
          f"min={min(vols):,.0f} max={max(vols):,.0f}")

    # 4. Federated queries across two 'sites' (yellow path). With one
    #    device per site available, pass a mesh whose `site` axis plays
    #    the DCN between clusters: each site's state lives on its own
    #    device, site ingest runs site-locally, and a federated query is
    #    ONE compiled collective program — `federated.merge_over_axis`
    #    psum/pmax-merges the partial synopses ACROSS the axis and the
    #    estimate executes at the responsible site. Without enough
    #    devices the same API answers via the host-merge path; results
    #    are byte-identical either way.
    from repro.launch.mesh import try_federation_mesh
    fed = Federation(["eu", "us"], mesh=try_federation_mesh(2))
    fed.broadcast({"type": "build", "request_id": "f", "synopsis_id": "h",
                   "kind": "hyperloglog", "params": {"rse": 0.02},
                   "federated": True, "responsible_site": "eu"})
    fed.sdes["eu"].ingest(np.arange(0, 3000, dtype=np.uint32),
                          np.ones(3000, np.float32))
    fed.sdes["us"].ingest(np.arange(2000, 5000, dtype=np.uint32),
                          np.ones(3000, np.float32))
    #    The JSON `federated_query` request reports the fig 5d metrics:
    #    what the collective shipped across the site axis vs what
    #    gathering every site's state to the responsible host would ship.
    resp = fed.handle({"type": "federated_query", "request_id": "fq",
                       "synopsis_id": "h", "responsible_site": "eu"})
    print(f"\nfederated distinct count: {float(resp.value):,.0f} "
          f"(true 5,000) via the {resp.params['path']} path — shipped "
          f"{resp.params['collective_operand_bytes']:,} bytes "
          f"(host-merge would ship "
          f"{resp.params['host_merge_bytes']:,})")

    # 5. Status report.
    st = sde.handle({"type": "status", "request_id": "s"})
    print(f"\nSDE status: {len(st.value)} synopses live; sample entry:")
    k = sorted(st.value)[0]
    print(" ", k, "->", json.dumps(st.value[k], default=str)[:100])


if __name__ == "__main__":
    main()
