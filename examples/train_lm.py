"""End-to-end training example: train a qwen2-family model for a few
hundred steps with the full substrate — atomic/async checkpoints, exact
resume, and SDE telemetry (gradient AMS sketch + DFT metric monitor: the
paper's engine serving an ML workflow).

Defaults are CPU-sized (~10M params); `--d-model 768 --layers 12` gives
~100M. The same code path scales to the production mesh (the dry-run
proves the full-size programs compile).

  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.streams import TokenPipeline
from repro.training import (OptConfig, MetricMonitor, init_train_state,
                            make_train_step)
from repro.training import checkpoint as ckpt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args(argv)

    cfg = reduced(ARCHS["qwen2-0.5b"],
                  d_model=args.d_model, n_layers=args.layers,
                  n_heads=max(args.d_model // 64, 2), n_kv_heads=2,
                  head_dim=64, d_ff=args.d_model * 4, vocab=8192)
    print(f"model: {cfg.param_count()/1e6:.1f}M params "
          f"({cfg.n_layers}L x {cfg.d_model}d, qwen2 family)")

    opt = OptConfig(lr=1e-3, warmup_steps=args.steps // 20 + 1,
                    total_steps=args.steps)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq,
                         batch=args.batch, seed=0)
    start = 0
    if ckpt.latest_step(args.ckpt) is not None:
        state, man = ckpt.restore(state, args.ckpt)
        pipe.restore(man["pipeline"])
        start = man["step"]
        print(f"resumed from step {start}")

    step_fn = jax.jit(make_train_step(cfg, opt))
    mon = MetricMonitor(window=32)
    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        state, m = step_fn(state, batch)
        mon.observe({k: float(v) for k, v in m.items() if np.ndim(v) == 0})
        if (step + 1) % 25 == 0:
            tok_s = args.batch * args.seq * 25 / (time.time() - t0)
            print(f"step {step+1:4d}  loss {float(m['loss']):.4f}  "
                  f"gradL2(sketch) {float(m['sketch_l2_est']):.1f}  "
                  f"{tok_s:,.0f} tok/s", flush=True)
            t0 = time.time()
        if (step + 1) % 100 == 0:
            ckpt.save(state, args.ckpt, step + 1,
                      extra_manifest={"pipeline": pipe.state()},
                      async_=True)
    print("SDE monitor correlated metrics:", mon.correlated_groups())
    print(f"distinct tokens seen (HLL estimate): "
          f"{pipe.distinct_tokens():,.0f}")


if __name__ == "__main__":
    main()
