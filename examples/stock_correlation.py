"""The paper's flagship workflow (Figure 4): discover correlated groups
among N stock streams using SDE.DFT bucketing instead of exact O(N^2 w)
pairwise Pearson — with zero false dismissals.

  PYTHONPATH=src python examples/stock_correlation.py --streams 1000
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import core
from repro.core import batched
from repro.core.dft import pairwise_corr, adjacent_bucket_mask
from repro.streams import StockStream


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=500)
    ap.add_argument("--window", type=int, default=64)
    ap.add_argument("--threshold", type=float, default=0.9)
    args = ap.parse_args(argv)
    n = args.streams

    stock = StockStream(n_streams=n, group_size=10, noise=0.2, seed=7)
    kind = core.DFT(window=args.window, n_coeffs=8,
                    threshold=args.threshold)

    # maintain DFT synopses for all N streams (one vmapped state)
    states = batched.stacked_init(kind, n)
    step = jax.jit(lambda st, v: batched.stacked_step(
        kind, st, v, jnp.ones(n, bool)))
    series = stock.ticks(args.window * 3)
    t0 = time.time()
    for t in range(series.shape[0]):
        states = step(states, jnp.asarray(series[t]))
    jax.block_until_ready(states)
    print(f"maintained {n} DFT synopses over {series.shape[0]} ticks "
          f"in {time.time()-t0:.2f}s")

    # bucketize + prune + estimate
    coeffs = jax.vmap(kind.normalized_coeffs)(states)
    coords = np.asarray(jax.vmap(
        lambda s: kind.bucket_of(kind.normalized_coeffs(s))[0])(states))
    cand = np.asarray(adjacent_bucket_mask(jnp.asarray(coords)))
    corr = np.asarray(pairwise_corr(coeffs))
    iu = np.triu_indices(n, 1)
    hot = cand[iu] & (corr[iu] >= args.threshold)
    pairs = [(int(a), int(b)) for a, b, h in zip(*iu, hot) if h]
    print(f"candidate fraction after bucket pruning: {cand[iu].mean():.3f}")
    print(f"correlated pairs found: {len(pairs)}")

    # validate vs exact Pearson on raw windows
    w = series[-args.window:].T
    wn = w - w.mean(1, keepdims=True)
    wn /= np.maximum(np.linalg.norm(wn, axis=1, keepdims=True), 1e-9)
    exact = wn @ wn.T
    true_pairs = {(int(a), int(b)) for a, b in zip(*iu)
                  if exact[a, b] >= args.threshold}
    missed = true_pairs - set(pairs)
    same_group = sum(1 for a, b in pairs
                     if stock.group_of(a) == stock.group_of(b))
    print(f"true pairs >= {args.threshold}: {len(true_pairs)}; "
          f"missed by pruning: {len(missed)} (must be 0)")
    print(f"within-planted-group pairs among found: "
          f"{same_group}/{len(pairs)}")
    assert not missed, "no-false-dismissal property violated!"


if __name__ == "__main__":
    main()
