"""Kind-stack lifecycle regressions + the fused single-dispatch blue path.

Covers the three state-corruption bugs (freed-row reuse, grow padding,
plugged-kind snapshot naming) and the scale contract: `ingest` issues
exactly ONE jitted update dispatch per kind per batch, and kind stacks
carry a NamedSharding over the `synopsis` axis on multi-device meshes.
"""
import os
import subprocess
import sys
import tempfile
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import core
from repro.core import batched, federated
from repro.service import SDE
from repro.service import engine as engine_mod


def _build_cm(eng, syn_id, *, stream_id=None, per_stream=False, n=None):
    req = {"type": "build", "request_id": "b", "synopsis_id": syn_id,
           "kind": "countmin",
           "params": {"eps": 0.02, "delta": 0.1, "weighted": False}}
    if per_stream:
        req.update(per_stream_of_source=True, n_streams=n)
    elif stream_id is not None:
        req["stream_id"] = stream_id
    r = eng.handle(req)
    assert r.ok, r.error
    return r


# ---------------------------------------------------------------------------
# tentpole contract: one jitted update dispatch per kind per batch
# ---------------------------------------------------------------------------
@pytest.mark.smoke
def test_single_update_dispatch_per_kind_per_batch(monkeypatch):
    calls = []
    orig = engine_mod._update

    def counting(kind, *a, **k):
        calls.append(kind)
        return orig(kind, *a, **k)

    monkeypatch.setattr(engine_mod, "_update", counting)
    eng = SDE()
    # routed synopses + a data-source synopsis of the SAME kind (the old
    # path paid one extra dispatch per source row per batch) + a second
    # kind that is source-only
    _build_cm(eng, "cm", per_stream=True, n=50)
    _build_cm(eng, "cm_all")
    r = eng.handle({"type": "build", "request_id": "b2",
                    "synopsis_id": "hll", "kind": "hyperloglog",
                    "params": {"rse": 0.03}})
    assert r.ok, r.error
    rng = np.random.RandomState(0)
    n_batches = 4
    for _ in range(n_batches):
        sids = rng.randint(0, 50, 256).astype(np.uint32)
        eng.ingest(sids, np.ones(256, np.float32))
    assert len(calls) == n_batches * len(eng.stacks)


def test_fused_source_and_routed_are_exact():
    """Routed rows and data-source rows agree with ground truth after the
    single fused dispatch (CM unweighted counts are exact per-stream)."""
    for backend in ("xla", "pallas"):
        eng = SDE(backend=backend)
        _build_cm(eng, "cm", per_stream=True, n=32)
        _build_cm(eng, "cm_all")
        rng = np.random.RandomState(1)
        sids = rng.randint(0, 32, 512).astype(np.uint32)
        eng.ingest(sids, np.ones(512, np.float32))
        q = eng.handle({"type": "adhoc", "request_id": "q",
                        "synopsis_id": "cm/5", "query": {"items": [5]}})
        assert float(q.value[0]) == float((sids == 5).sum())
        q = eng.handle({"type": "adhoc", "request_id": "q2",
                        "synopsis_id": "cm_all", "query": {"items": [5]}})
        assert float(q.value[0]) == float((sids == 5).sum())


def test_scan_kind_source_row_sees_every_tuple():
    """The vmap-fallback (scan) kinds fold source rows into the same
    single dispatch; a source LossyCounting must track the heavy item."""
    eng = SDE()
    r = eng.handle({"type": "build", "request_id": "b", "synopsis_id":
                    "lc", "kind": "lossy_counting",
                    "params": {"eps": 0.01}})
    assert r.ok, r.error
    items = np.concatenate([np.full(300, 7), np.arange(50)])
    np.random.RandomState(0).shuffle(items)
    eng.ingest(items.astype(np.uint32), np.ones(len(items), np.float32))
    q = eng.handle({"type": "adhoc", "request_id": "q", "synopsis_id":
                    "lc", "query": {"items": [7]}})
    assert float(q.value[0]) >= 300


# ---------------------------------------------------------------------------
# bug 1: freed rows must hand fresh state to the next synopsis
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind_name,params", [
    ("countmin", {"eps": 0.02, "delta": 0.1, "weighted": False}),
    ("hyperloglog", {"rse": 0.03}),
    ("lossy_counting", {"eps": 0.02}),
])
def test_freed_row_reuse_starts_fresh(kind_name, params):
    eng = SDE()
    build = {"type": "build", "request_id": "b", "synopsis_id": "x",
             "kind": kind_name, "params": params, "stream_id": 1}
    assert eng.handle(build).ok
    eng.ingest(np.ones(200, np.uint32), np.ones(200, np.float32))
    q = eng.handle({"type": "adhoc", "request_id": "q", "synopsis_id":
                    "x", "query": {"items": [1]}})
    assert float(np.asarray(q.value).ravel()[0]) > 0
    assert eng.handle({"type": "stop", "request_id": "s",
                       "synopsis_id": "x"}).ok
    # rebuild the SAME id: alloc hands back the same row — it must not
    # carry the dead synopsis's counts
    assert eng.handle(dict(build, request_id="b2")).ok
    q = eng.handle({"type": "adhoc", "request_id": "q2", "synopsis_id":
                    "x", "query": {"items": [1]}})
    assert float(np.asarray(q.value).ravel()[0]) == 0.0


# ---------------------------------------------------------------------------
# bug 2: grow must pad with the kind's init prototype, not zeros
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind_name", sorted(core.known_kinds()))
def test_grow_pads_with_init_prototype(kind_name):
    kind = core.make_kind(kind_name)
    stacked = batched.stacked_init(kind, 4)
    grown = batched.grow(kind, stacked, 8)
    proto = batched.stacked_init(kind, 4)
    for g, p in zip(jax.tree.leaves(grown), jax.tree.leaves(proto)):
        assert g.shape[0] == 8
        np.testing.assert_array_equal(np.asarray(g[4:]), np.asarray(p))


def test_grown_lossy_rows_are_not_occupied_by_item_zero():
    """The observable corruption: after doubling, a fresh LossyCounting
    row must report 0 for item 0 (zero-padded keys claimed otherwise)."""
    eng = SDE()
    for i in range(65):     # 65th alloc doubles the 64-row stack
        r = eng.handle({"type": "build", "request_id": "b",
                        "synopsis_id": f"lc{i}", "kind": "lossy_counting",
                        "params": {"eps": 0.05}, "stream_id": i})
        assert r.ok, r.error
    eng.ingest(np.full(10, 3, np.uint32), np.ones(10, np.float32))
    q = eng.handle({"type": "adhoc", "request_id": "q", "synopsis_id":
                    "lc64", "query": {"items": [0]}})
    assert float(q.value[0]) == 0.0


# ---------------------------------------------------------------------------
# bug 3: snapshot/restore of kinds plugged in with non-class factories
# ---------------------------------------------------------------------------
def _narrow_cm(**params):
    """A function (NOT a class) factory, as Load Synopsis allows."""
    return core.CountMin(**params)


def test_plugged_kind_snapshot_roundtrip():
    core.register_kind("plugged_cm", _narrow_cm, overwrite=True)
    eng = SDE()
    r = eng.handle({"type": "build", "request_id": "b", "synopsis_id":
                    "p", "kind": "plugged_cm",
                    "params": {"eps": 0.02, "delta": 0.1,
                               "weighted": False}, "stream_id": 4})
    assert r.ok, r.error
    eng.ingest(np.full(64, 4, np.uint32), np.ones(64, np.float32))
    with tempfile.TemporaryDirectory() as d:
        eng.snapshot(d, 1)
        eng2 = SDE.restore(d)
    for e in (eng, eng2):
        q = e.handle({"type": "adhoc", "request_id": "q", "synopsis_id":
                      "p", "query": {"items": [4]}})
        assert q.ok, q.error
        assert float(q.value[0]) == 64.0


# ---------------------------------------------------------------------------
# elastic merge: vectorized row-wise merge per kind
# ---------------------------------------------------------------------------
def test_merge_rows_matches_scalar_merge():
    kind = core.CountMin(eps=0.02, delta=0.1, weighted=False)
    a = batched.stacked_init(kind, 8)
    b = batched.stacked_init(kind, 8)
    rng = np.random.RandomState(0)
    items = jnp.asarray(rng.randint(0, 100, 256).astype(np.uint32))
    ones = jnp.ones(256, jnp.float32)
    mask = jnp.ones(256, bool)
    a = batched.stacked_add_batch(kind, a, items % 8, items, ones, mask)
    b = batched.stacked_add_batch(kind, b, (items + 3) % 8, items, ones,
                                  mask)
    rows_a = jnp.asarray([1, 4, 6], jnp.int32)
    rows_b = jnp.asarray([0, 2, 5], jnp.int32)
    out = federated.merge_rows(kind, a, rows_a, b, rows_b)
    for ra, rb in zip([1, 4, 6], [0, 2, 5]):
        expect = kind.merge(batched.stacked_row(a, ra),
                            batched.stacked_row(b, rb))
        np.testing.assert_allclose(np.asarray(batched.stacked_row(out, ra)),
                                   np.asarray(expect))
    # untouched rows unchanged
    np.testing.assert_array_equal(np.asarray(batched.stacked_row(out, 0)),
                                  np.asarray(batched.stacked_row(a, 0)))


# ---------------------------------------------------------------------------
# sharding: stacks carry a NamedSharding over the `synopsis` axis
# ---------------------------------------------------------------------------
_SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    from jax.sharding import NamedSharding
    from repro.service import SDE

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    eng = SDE(mesh=mesh)
    eng.handle({"type": "build", "request_id": "b", "synopsis_id": "cm",
                "kind": "countmin",
                "params": {"eps": 0.01, "delta": 0.05, "weighted": False},
                "per_stream_of_source": True, "n_streams": 50})
    eng.handle({"type": "build", "request_id": "b2", "synopsis_id": "h",
                "kind": "hyperloglog", "params": {"rse": 0.03}})
    rng = np.random.RandomState(0)
    sids = rng.randint(0, 50, 512).astype(np.uint32)
    for _ in range(3):
        eng.ingest(sids, np.ones(512, np.float32))
    for stack in eng.stacks.values():
        for leaf in jax.tree.leaves(stack.state):
            sh = leaf.sharding
            assert isinstance(sh, NamedSharding), sh
            assert sh.spec and sh.spec[0] == "data", sh.spec
    q = eng.handle({"type": "adhoc", "request_id": "q", "synopsis_id":
                    "cm/7", "query": {"items": [7]}})
    assert float(q.value[0]) == 3.0 * float((sids == 7).sum()), q.value
    # capacity doubling keeps the placement
    for i in range(70):
        eng.handle({"type": "build", "request_id": "g",
                    "synopsis_id": f"g{i}", "kind": "hyperloglog",
                    "params": {"rse": 0.03}, "stream_id": 60 + i})
    eng.ingest(sids, np.ones(512, np.float32))
    hstack = [s for s in eng.stacks.values() if s.capacity == 128][0]
    assert hstack.state.sharding.spec[0] == "data"
    print("OK")
""")


def test_stacks_sharded_over_synopsis_axis_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
