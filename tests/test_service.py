"""SDEaaS service behaviour: the paper's API contract (Section 3)."""
import json

import numpy as np
import pytest

from repro.service import SDE, Federation
from repro.service.planner import Planner, WorkflowSpec
from repro import core


@pytest.fixture
def sde():
    eng = SDE()
    r = eng.handle({"type": "build", "request_id": "b1",
                    "synopsis_id": "cm", "kind": "countmin",
                    "params": {"eps": 0.01, "delta": 0.05,
                               "weighted": False},
                    "per_stream_of_source": True, "n_streams": 50})
    assert r.ok, r.error
    r = eng.handle({"type": "build", "request_id": "b2",
                    "synopsis_id": "hll", "kind": "hyperloglog",
                    "params": {"rse": 0.03}})
    assert r.ok, r.error
    rng = np.random.RandomState(0)
    for _ in range(20):
        sids = rng.randint(0, 50, 256).astype(np.uint32)
        eng.ingest(sids, np.ones(256, np.float32))
    return eng


@pytest.mark.smoke
def test_adhoc_query(sde):
    q = sde.handle({"type": "adhoc", "request_id": "q", "synopsis_id":
                    "cm/7", "query": {"items": [7]}})
    assert q.ok
    # ~20*256/50 tuples per stream
    assert 50 < float(q.value[0]) < 160


def test_data_source_synopsis(sde):
    q = sde.handle({"type": "adhoc", "request_id": "q2",
                    "synopsis_id": "hll"})
    assert abs(float(q.value) - 50) < 10


def test_status_and_reuse(sde):
    st = sde.handle({"type": "status", "request_id": "s"})
    assert len(st.value) == 51
    # re-building the same synopsis id reuses it (no duplication)
    sde.handle({"type": "build", "request_id": "b3", "synopsis_id": "hll",
                "kind": "hyperloglog", "params": {"rse": 0.03}})
    st2 = sde.handle({"type": "status", "request_id": "s2"})
    assert len(st2.value) == 51


def test_stop(sde):
    r = sde.handle({"type": "stop", "request_id": "x",
                    "synopsis_id": "cm"})
    assert r.ok and r.value == 50
    q = sde.handle({"type": "adhoc", "request_id": "q3",
                    "synopsis_id": "cm/7", "query": {"items": [7]}})
    assert not q.ok


def test_unknown_request_is_error(sde):
    r = sde.handle({"type": "adhoc", "request_id": "e",
                    "synopsis_id": "nope"})
    assert not r.ok


def test_json_roundtrip(sde):
    q = sde.handle(json.dumps({"type": "adhoc", "request_id": "jq",
                               "synopsis_id": "hll"}))
    out = json.loads(q.to_json())
    assert out["request_id"] == "jq" and out["ok"]


def test_continuous_query():
    eng = SDE()
    eng.handle({"type": "build", "request_id": "c", "synopsis_id": "h",
                "kind": "hyperloglog", "params": {"rse": 0.05},
                "continuous": True})
    eng.ingest(np.arange(100, dtype=np.uint32), np.ones(100, np.float32))
    eng.ingest(np.arange(100, dtype=np.uint32), np.ones(100, np.float32))
    assert len(eng.continuous_out) == 2


def test_load_synopsis_pluggability():
    eng = SDE()
    r = eng.handle({"type": "load", "request_id": "l",
                    "kind_name": "my_cm",
                    "factory_path": "repro.core.countmin:CountMin"})
    assert r.ok
    r = eng.handle({"type": "build", "request_id": "b", "synopsis_id":
                    "x", "kind": "my_cm", "params": {"eps": 0.05,
                                                     "delta": 0.1}})
    assert r.ok


def test_federation_merge():
    fed = Federation(["eu", "us", "ap"])
    fed.broadcast({"type": "build", "request_id": "f", "synopsis_id":
                   "h", "kind": "hyperloglog", "params": {"rse": 0.03},
                   "federated": True, "responsible_site": "eu"})
    fed.sdes["eu"].ingest(np.arange(0, 2000, dtype=np.uint32),
                          np.ones(2000, np.float32))
    fed.sdes["us"].ingest(np.arange(1000, 3000, dtype=np.uint32),
                          np.ones(2000, np.float32))
    fed.sdes["ap"].ingest(np.arange(2500, 4000, dtype=np.uint32),
                          np.ones(1500, np.float32))
    est = float(fed.query_federated("h", {}, "eu"))
    assert abs(est - 4000) / 4000 < 0.15
    assert fed.query_bytes("h") < 3 * 4000 * 4  # far less than raw data


def test_planner_budget():
    p = Planner(WorkflowSpec(n_streams=5000))
    assert p.choose(0.0).name == "Plan0-exact"
    assert "DFT" in p.choose(0.08).name
    costs = {pl.name: pl.cost for pl in p.plans()}
    assert costs["Plan2-DFT"] < costs["Plan0-exact"]


def test_pallas_backend_engine():
    eng = SDE(backend="pallas")
    eng.handle({"type": "build", "request_id": "b", "synopsis_id": "cm",
                "kind": "countmin",
                "params": {"eps": 0.02, "delta": 0.1, "weighted": False},
                "per_stream_of_source": True, "n_streams": 32})
    rng = np.random.RandomState(1)
    sids = rng.randint(0, 32, 512).astype(np.uint32)
    eng.ingest(sids, np.ones(512, np.float32))
    q = eng.handle({"type": "adhoc", "request_id": "q", "synopsis_id":
                    "cm/5", "query": {"items": [5]}})
    assert float(q.value[0]) == float((sids == 5).sum())


def test_engine_snapshot_restore_and_continue():
    import tempfile
    eng = SDE()
    eng.handle({"type": "build", "request_id": "b", "synopsis_id": "cm",
                "kind": "countmin",
                "params": {"eps": 0.02, "delta": 0.1, "weighted": False},
                "per_stream_of_source": True, "n_streams": 64})
    rng = np.random.RandomState(0)
    sids = rng.randint(0, 64, 2048).astype(np.uint32)
    eng.ingest(sids, np.ones(2048, np.float32))
    with tempfile.TemporaryDirectory() as d:
        eng.snapshot(d, 1)
        eng2 = SDE.restore(d)
    q1 = eng.handle({"type": "adhoc", "request_id": "q", "synopsis_id":
                     "cm/5", "query": {"items": [5]}})
    q2 = eng2.handle({"type": "adhoc", "request_id": "q", "synopsis_id":
                      "cm/5", "query": {"items": [5]}})
    assert float(q1.value[0]) == float(q2.value[0])
    eng2.ingest(sids, np.ones(2048, np.float32))     # keeps running
    q3 = eng2.handle({"type": "adhoc", "request_id": "q", "synopsis_id":
                      "cm/5", "query": {"items": [5]}})
    assert float(q3.value[0]) == 2 * float(q1.value[0])


def test_engine_elastic_merge():
    a, b = SDE(), SDE()
    for e in (a, b):
        e.handle({"type": "build", "request_id": "b", "synopsis_id":
                  "hll", "kind": "hyperloglog", "params": {"rse": 0.03}})
    a.ingest(np.arange(0, 1500, dtype=np.uint32), np.ones(1500, np.float32))
    b.ingest(np.arange(1000, 2500, dtype=np.uint32),
             np.ones(1500, np.float32))
    a.merge_from(b)
    q = a.handle({"type": "adhoc", "request_id": "q", "synopsis_id": "hll"})
    assert abs(float(q.value) - 2500) / 2500 < 0.1


def test_cost_estimator_load_balancer():
    """Paper Section 7: HLL + CM as the optimizer's cost estimator,
    WFD bin packing balances skewed streams."""
    from repro.service.balancer import plan_workers, worst_fit_decreasing
    eng = SDE()
    eng.handle({"type": "build", "request_id": "b1", "synopsis_id":
                "card", "kind": "hyperloglog", "params": {"rse": 0.03}})
    eng.handle({"type": "build", "request_id": "b2", "synopsis_id":
                "freq", "kind": "countmin",
                "params": {"eps": 0.005, "delta": 0.01,
                           "weighted": False}})
    rng = np.random.RandomState(0)
    sids = (rng.zipf(1.3, 50000) % 64).astype(np.uint32)  # heavy skew
    eng.ingest(sids, np.ones(len(sids), np.float32))
    placement = plan_workers(eng, "card", "freq", list(range(64)),
                             capacity_per_worker=8000.0)
    assert placement.n_workers >= 4
    # indivisible-stream floor: the heaviest single stream / mean load
    true = np.bincount(sids, minlength=64).astype(float)
    floor = true.max() / (true.sum() / placement.n_workers)
    assert placement.imbalance <= max(1.05, floor * 1.10)
    # and never worse than naive round-robin on the same loads
    rr_loads = [float(true[w::placement.n_workers].sum())
                for w in range(placement.n_workers)]
    rr_imb = max(rr_loads) / (sum(rr_loads) / len(rr_loads))
    assert placement.imbalance <= rr_imb + 0.05
