"""End-to-end behaviour tests for the paper's system.

1. The paper's flagship workflow: thousands of stock streams -> SDE DFT
   synopses -> bucket pruning -> correlated groups, validated against the
   planted group structure (zero false dismissals).
2. The SDE serving an LM training run: pipeline stats + gradient sketch +
   checkpoint/restart fault injection.
"""
import tempfile

import pytest

import numpy as np
import jax
import jax.numpy as jnp

from repro import core
from repro.core import batched
from repro.service import SDE
from repro.streams import StockStream, TokenPipeline
from repro.configs import ARCHS, reduced
from repro.training import OptConfig, init_train_state, make_train_step
from repro.training import checkpoint as ckpt


@pytest.mark.smoke
def test_stock_correlation_workflow():
    n, window = 200, 64
    stock = StockStream(n_streams=n, group_size=10, noise=0.2, seed=11)
    kind = core.DFT(window=window, n_coeffs=8, threshold=0.9)

    states = batched.stacked_init(kind, n)
    step = jax.jit(lambda st, v: batched.stacked_step(
        kind, st, v, jnp.ones(n, bool)))
    series = stock.ticks(window * 3)
    for t in range(series.shape[0]):
        states = step(states, jnp.asarray(series[t]))

    coeffs = jax.vmap(kind.normalized_coeffs)(states)
    from repro.core.dft import pairwise_corr, adjacent_bucket_mask
    corr = np.asarray(pairwise_corr(coeffs))
    coords = np.asarray(jax.vmap(
        lambda s: kind.bucket_of(kind.normalized_coeffs(s))[0])(states))
    cand = np.asarray(adjacent_bucket_mask(jnp.asarray(coords)))

    # exact ground truth from the raw windows
    w = series[-window:].T
    wn = (w - w.mean(1, keepdims=True))
    wn /= np.maximum(np.linalg.norm(wn, axis=1, keepdims=True), 1e-9)
    exact = wn @ wn.T
    hot = np.triu(exact, 1) >= 0.9
    # no false dismissals: every truly-correlated pair is a candidate
    assert (cand[hot]).all()
    # and estimates on candidates track the truth
    err = np.abs(corr[hot] - exact[hot])
    assert err.mean() < 0.1


def test_sde_serves_training_workflow():
    cfg = reduced(ARCHS["qwen2-0.5b"])
    opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=30)
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=16, batch=2, seed=3)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, opt))

    with tempfile.TemporaryDirectory() as d:
        for i in range(5):
            state, metrics = step(state, {k: jnp.asarray(v)
                                          for k, v in pipe.next_batch().items()})
        ckpt.save(state, d, 5, extra_manifest={"pipeline": pipe.state()})
        # SDE cost-estimator facilities over the token stream:
        distinct = pipe.distinct_tokens()
        assert distinct > 0
        top_freq = pipe.token_frequency([1, 2, 3])
        assert (np.asarray(top_freq) > 0).all()
        # gradient sketch telemetry present and positive
        assert float(metrics["sketch_l2_est"]) > 0

        # fault injection: lose the process, restore, continue
        state2, man = ckpt.restore(state, d)
        pipe2 = TokenPipeline(vocab=cfg.vocab, seq_len=16, batch=2, seed=3)
        pipe2.restore(man["pipeline"])
        state2, m2 = step(state2, {k: jnp.asarray(v)
                                   for k, v in pipe2.next_batch().items()})
        assert np.isfinite(float(m2["loss"]))
        assert int(state2["step"]) == 6


def test_sde_engine_sustains_thousands_of_synopses():
    eng = SDE()
    r = eng.handle({"type": "build", "request_id": "big", "synopsis_id":
                    "cm", "kind": "countmin",
                    "params": {"eps": 0.05, "delta": 0.1},
                    "per_stream_of_source": True, "n_streams": 2048})
    assert r.ok, r.error
    rng = np.random.RandomState(0)
    sids = rng.randint(0, 2048, 4096).astype(np.uint32)
    eng.ingest(sids, np.ones(4096, np.float32))
    st = eng.handle({"type": "status", "request_id": "s"})
    assert len(st.value) == 2048
    # one stacked state, not 2048 separate buffers
    assert len(eng.stacks) == 1
