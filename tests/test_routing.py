"""Hashed stream routing: the open-addressing table, the fused device
probe, arbitrary-63-bit-id ingest, and snapshot/restore of the table —
including restore onto a different device count.

The contract under test (ISSUE 3): stream ids are arbitrary ints in
[0, 2**63); nothing is clamped, rejected or dropped for being "too big";
the probe runs inside the fused blue-path programs so ingest stays ONE
jitted dispatch per kind per batch.
"""
import os
import subprocess
import sys
import tempfile
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops as kops
from repro.service import SDE, routing
from repro.service import engine as engine_mod


# ---------------------------------------------------------------------------
# RouteTable host-side unit behaviour
# ---------------------------------------------------------------------------
@pytest.mark.smoke
def test_table_insert_lookup_roundtrip_63bit():
    t = routing.RouteTable()
    rng = np.random.RandomState(0)
    ids = np.unique(rng.randint(0, 2**63 - 1, 4096, dtype=np.int64))
    rows = np.arange(len(ids), dtype=np.int32)
    t.insert_many(ids, rows)
    assert t.count == len(ids)
    for i in rng.choice(len(ids), 64, replace=False):
        assert t.lookup(int(ids[i])) == int(rows[i])
    # misses miss (ids drawn outside the inserted set)
    present = set(int(x) for x in ids)
    for probe in (7, 2**40 + 1, 2**62 + 11):
        if probe not in present:
            assert t.lookup(probe) == -1
    # invariants: pow2 size, bounded load, bounded probe chains
    assert t.size & (t.size - 1) == 0
    assert t.load <= 0.7
    assert t.max_probe <= routing.PROBE_CAP


def test_table_duplicate_insert_updates_row():
    t = routing.RouteTable()
    t.insert(2**50 + 3, 1)
    t.insert(2**50 + 3, 9)
    assert t.lookup(2**50 + 3) == 9
    assert t.count == 1


def test_table_remove_rows_compacts_without_tombstones():
    t = routing.RouteTable()
    ids = np.arange(100, dtype=np.int64) * (2**33)   # all > 2**32
    t.insert_many(ids, np.arange(100, dtype=np.int32))
    t.remove_rows(np.arange(0, 100, 2, dtype=np.int32))
    assert t.count == 50
    for i in range(100):
        want = -1 if i % 2 == 0 else i
        assert t.lookup(int(ids[i])) == want, i
    # freed capacity is reusable: re-insert the removed half
    t.insert_many(ids[::2], np.arange(0, 100, 2, dtype=np.int32))
    assert all(t.lookup(int(ids[i])) == i for i in range(100))


def test_table_intra_batch_duplicates_last_wins():
    """A key appearing twice in ONE insert_many must end up in one slot
    with the last row mapping (sequential-insert semantics) — not two
    copies inflating count."""
    t = routing.RouteTable()
    t.insert_many([5, 5, 2**40, 5], [1, 2, 7, 3])
    assert t.count == 2
    assert t.lookup(5) == 3
    assert t.lookup(2**40) == 7
    assert int((t.keys == 5).sum()) == 1


def test_table_remove_rows_noop_keeps_layout():
    """Removing rows nothing routes to (a source-only stop) must not
    rebuild the table or invalidate the device mirror."""
    t = routing.RouteTable()
    t.insert_many([1, 2, 3], [0, 1, 2])
    version, keys = t.version, t.keys.copy()
    t.remove_rows(np.asarray([50, 51], np.int32))
    assert t.version == version
    np.testing.assert_array_equal(t.keys, keys)


def test_build_canonicalizes_duplicate_id_forms():
    """Non-canonical numeric forms of the same id (7 vs 7.0) must not
    commit shadow entries that never receive updates."""
    eng = SDE()
    r = eng.handle({"type": "build", "request_id": "b", "synopsis_id":
                    "cm", "kind": "countmin",
                    "params": {"eps": 0.02, "delta": 0.1,
                               "weighted": False},
                    "per_stream_of_source": True,
                    "stream_ids": [7, 7.0, 2**40]})
    assert r.ok, r.error
    assert set(eng.entries) == {"cm/7", f"cm/{2**40}"}
    eng.ingest(np.asarray([7, 7], np.int64), np.ones(2, np.float32))
    q = eng.handle({"type": "adhoc", "request_id": "q", "synopsis_id":
                    "cm/7", "query": {"items": [7]}})
    assert float(q.value[0]) == 2.0


def test_table_rejects_unrepresentable_ids():
    t = routing.RouteTable()
    for bad in (-1, 1 << 63):
        with pytest.raises(ValueError, match="2\\*\\*63"):
            t.insert(bad, 0)


def test_table_grow_keeps_probe_bound_at_scale():
    """A large id population must settle with probe chains <= PROBE_CAP
    (the fused loop's static bound) — clustering triggers growth."""
    t = routing.RouteTable()
    rng = np.random.RandomState(1)
    ids = np.unique(rng.randint(0, 2**63 - 1, 100_000, dtype=np.int64))
    t.insert_many(ids, np.arange(len(ids), dtype=np.int32))
    assert t.max_probe <= routing.PROBE_CAP
    assert t.load <= 0.7
    sample = rng.choice(len(ids), 32, replace=False)
    assert all(t.lookup(int(ids[i])) == int(i) for i in sample)


# ---------------------------------------------------------------------------
# device probe == host table
# ---------------------------------------------------------------------------
@pytest.mark.smoke
def test_route_probe_matches_host_lookup():
    t = routing.RouteTable()
    rng = np.random.RandomState(2)
    ids = np.unique(rng.randint(0, 2**63 - 1, 2000, dtype=np.int64))
    t.insert_many(ids, np.arange(len(ids), dtype=np.int32))
    # half hits, half misses
    queries = np.concatenate([
        ids[rng.choice(len(ids), 500)],
        rng.randint(0, 2**63 - 1, 500, dtype=np.int64)])
    lo, hi = routing.split64(t.keys)
    qlo, qhi = routing.split64(queries)
    got = np.asarray(kops.route_probe(
        jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(t.rows),
        jnp.asarray(qlo), jnp.asarray(qhi),
        n_probe=engine_mod._next_pow2(t.max_probe)))
    want = np.asarray([t.lookup(int(q)) for q in queries], np.int32)
    np.testing.assert_array_equal(got, want)


def test_slot_hash_host_device_lockstep():
    """The host inserter and the jitted probe MUST hash to the same
    slots — otherwise lookups silently miss."""
    from repro.core import hashing
    rng = np.random.RandomState(3)
    ids = rng.randint(0, 2**63 - 1, 256, dtype=np.int64)
    lo, hi = routing.split64(ids)
    size = 1 << 14
    host = routing.slot_hash(lo, hi, size)
    dev_h = hashing.mix32(jnp.asarray(lo)
                          ^ hashing.mix32(jnp.asarray(hi)
                                          ^ jnp.uint32(0x9E3779B9)))
    dev = np.asarray(dev_h).astype(np.int64) & (size - 1)
    np.testing.assert_array_equal(host, dev)


# ---------------------------------------------------------------------------
# engine: arbitrary ids, exactness, single fused dispatch
# ---------------------------------------------------------------------------
@pytest.mark.smoke
def test_high_stream_ids_exact_and_single_dispatch(monkeypatch):
    calls = []
    orig = engine_mod._update

    def counting(kind, *a, **k):
        calls.append(kind)
        return orig(kind, *a, **k)

    monkeypatch.setattr(engine_mod, "_update", counting)
    eng = SDE()
    rng = np.random.RandomState(0)
    sid_pop = np.unique(rng.randint(0, 2**63 - 1, 64, dtype=np.int64))
    r = eng.handle({"type": "build", "request_id": "b", "synopsis_id":
                    "cm", "kind": "countmin",
                    "params": {"eps": 0.02, "delta": 0.1,
                               "weighted": False},
                    "per_stream_of_source": True,
                    "stream_ids": [int(s) for s in sid_pop]})
    assert r.ok, r.error
    eng.handle({"type": "build", "request_id": "b2", "synopsis_id":
                "card", "kind": "hyperloglog", "params": {"rse": 0.03}})
    n_batches = 3
    sids = sid_pop[rng.randint(0, len(sid_pop), 1024)]
    for _ in range(n_batches):
        eng.ingest(sids, np.ones(len(sids), np.float32))
    # one fused dispatch per kind per batch, probe included
    assert len(calls) == n_batches * len(eng.stacks)
    # zero dropped tuples
    assert eng.tuples_ingested == n_batches * len(sids)
    # exact per-stream counts on ids far beyond the old 2**16 cap
    for sid in sid_pop[:8]:
        q = eng.handle({"type": "adhoc", "request_id": "q",
                        "synopsis_id": f"cm/{sid}",
                        "query": {"items": [int(sid)]}})
        assert q.ok, q.error
        assert float(q.value[0]) == n_batches * float((sids == sid).sum())
    # the data-source HLL sees the whole (folded) id population
    q = eng.handle({"type": "adhoc", "request_id": "qh",
                    "synopsis_id": "card"})
    assert abs(float(q.value) - len(sid_pop)) / len(sid_pop) < 0.25


def test_high_ids_pallas_backend_matches_xla():
    out = {}
    rng = np.random.RandomState(4)
    sid_pop = np.unique(rng.randint(0, 2**63 - 1, 32, dtype=np.int64))
    sids = sid_pop[rng.randint(0, len(sid_pop), 512)]
    for backend in ("xla", "pallas"):
        eng = SDE(backend=backend)
        eng.handle({"type": "build", "request_id": "b", "synopsis_id":
                    "cm", "kind": "countmin",
                    "params": {"eps": 0.02, "delta": 0.1,
                               "weighted": False},
                    "per_stream_of_source": True,
                    "stream_ids": [int(s) for s in sid_pop]})
        eng.ingest(sids, np.ones(len(sids), np.float32))
        q = eng.handle({"type": "adhoc", "request_id": "q",
                        "synopsis_id": f"cm/{sid_pop[3]}",
                        "query": {"items": [int(sid_pop[3])]}})
        assert q.ok, q.error
        out[backend] = float(q.value[0])
    assert out["xla"] == out["pallas"] == float((sids == sid_pop[3]).sum())


def test_timeseries_kind_routes_hashed_ids():
    def fresh():
        eng = SDE()
        r = eng.handle({"type": "build", "request_id": "b", "synopsis_id":
                        "dft", "kind": "dft",
                        "params": {"window": 16, "n_coeffs": 4},
                        "stream_id": 2**45 + 17})
        assert r.ok, r.error
        return eng

    eng = fresh()
    sid = 2**45 + 17
    for v in (1.0, -1.0, 0.5):
        eng.ingest(np.asarray([sid], np.int64),
                   np.asarray([v], np.float32))
    q = eng.handle({"type": "adhoc", "request_id": "q",
                    "synopsis_id": "dft"})
    assert q.ok, q.error
    # duplicate ids inside one batch: the LAST tuple's value ticks the
    # stream, deterministically — equivalent to a single-tuple batch
    dup, single = fresh(), fresh()
    dup.ingest(np.asarray([sid, 123, sid], np.int64),
               np.asarray([1.0, 9.0, 2.0], np.float32))
    single.ingest(np.asarray([sid], np.int64),
                  np.asarray([2.0], np.float32))
    for a, b in zip(jax.tree.leaves(dup.state_of("dft")),
                    jax.tree.leaves(single.state_of("dft"))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# satellite: snapshot -> restore round-trips of the hashed routing table
# ---------------------------------------------------------------------------
def _build_big_id_engine(n_streams=96, n_tuples=2048, seed=0):
    rng = np.random.RandomState(seed)
    sid_pop = np.unique(rng.randint(0, 2**63 - 1, n_streams,
                                    dtype=np.int64))
    eng = SDE()
    eng.handle({"type": "build", "request_id": "b", "synopsis_id": "cm",
                "kind": "countmin",
                "params": {"eps": 0.02, "delta": 0.1, "weighted": False},
                "per_stream_of_source": True,
                "stream_ids": [int(s) for s in sid_pop]})
    sids = sid_pop[rng.randint(0, len(sid_pop), n_tuples)]
    eng.ingest(sids, np.ones(n_tuples, np.float32))
    return eng, sid_pop, sids


def test_snapshot_restore_roundtrips_hashed_routing():
    eng, sid_pop, sids = _build_big_id_engine()
    with tempfile.TemporaryDirectory() as d:
        eng.snapshot(d, 1)
        eng2 = SDE.restore(d)
    # the table restored byte-identical (layout, occupancy, probe bound)
    t1 = next(iter(eng.stacks.values())).table
    t2 = next(iter(eng2.stacks.values())).table
    np.testing.assert_array_equal(t1.keys, t2.keys)
    np.testing.assert_array_equal(t1.rows, t2.rows)
    assert (t1.count, t1.max_probe) == (t2.count, t2.max_probe)
    assert eng2.batches_ingested == eng.batches_ingested
    # query equivalence pre/post restore
    for sid in sid_pop[:6]:
        qs = [e.handle({"type": "adhoc", "request_id": "q",
                        "synopsis_id": f"cm/{sid}",
                        "query": {"items": [int(sid)]}})
              for e in (eng, eng2)]
        assert qs[0].ok and qs[1].ok
        assert float(qs[0].value[0]) == float(qs[1].value[0])
    # post-restore ingest keeps routing: counts double on a re-ingest
    sid = int(sid_pop[3])
    before = float(eng2.handle(
        {"type": "adhoc", "request_id": "q", "synopsis_id": f"cm/{sid}",
         "query": {"items": [sid]}}).value[0])
    eng2.ingest(sids, np.ones(len(sids), np.float32))
    after = float(eng2.handle(
        {"type": "adhoc", "request_id": "q", "synopsis_id": f"cm/{sid}",
         "query": {"items": [sid]}}).value[0])
    assert after == 2 * before and before == float((sids == sid).sum())


def test_table_reinsert_does_not_grow():
    """Re-inserting existing keys (row updates) must not count toward
    load or trigger a pointless grow-and-rehash."""
    t = routing.RouteTable()
    ids = np.arange(40, dtype=np.int64)
    t.insert_many(ids, np.arange(40, dtype=np.int32))
    size = t.size
    t.insert_many(ids, np.arange(40, dtype=np.int32)[::-1])
    assert t.size == size and t.count == 40
    assert t.lookup(0) == 39


def test_restore_migrates_legacy_dense_route_snapshot():
    """Snapshots written by the pre-hashed-routing engine (one dense
    int32 ``route`` array per stack, no ``table`` manifest entry) must
    restore: the dense route is migrated into a RouteTable."""
    import json as _json
    eng, sid_pop, sids = None, None, None
    rng = np.random.RandomState(5)
    eng = SDE()
    eng.handle({"type": "build", "request_id": "b", "synopsis_id": "cm",
                "kind": "countmin",
                "params": {"eps": 0.02, "delta": 0.1, "weighted": False},
                "per_stream_of_source": True, "n_streams": 50})
    sids = rng.randint(0, 50, 512).astype(np.uint32)
    eng.ingest(sids, np.ones(512, np.float32))
    with tempfile.TemporaryDirectory() as d:
        eng.snapshot(d, 1)
        # rewrite the snapshot into the LEGACY layout: dense route array,
        # no table metadata, no batch counter
        step_dir = os.path.join(d, "step-00000001")
        blob = dict(np.load(os.path.join(step_dir, "leaves.npz")))
        table = next(iter(eng.stacks.values())).table
        dense = np.full(1 << 16, -1, np.int32)
        keys, rows = table.items()
        dense[keys] = rows
        for k in list(blob):
            if "__route__" in k:
                del blob[k]
        blob["stack0__route"] = dense
        np.savez(os.path.join(step_dir, "leaves.npz"), **blob)
        with open(os.path.join(step_dir, "manifest.json")) as f:
            man = _json.load(f)
        del man["batches_ingested"]
        for sk in man["stacks"]:
            del sk["table"]
        with open(os.path.join(step_dir, "manifest.json"), "w") as f:
            _json.dump(man, f)
        eng2 = SDE.restore(d)
    for sid in (3, 17, 49):
        q1 = eng.handle({"type": "adhoc", "request_id": "q",
                         "synopsis_id": f"cm/{sid}",
                         "query": {"items": [sid]}})
        q2 = eng2.handle({"type": "adhoc", "request_id": "q",
                          "synopsis_id": f"cm/{sid}",
                          "query": {"items": [sid]}})
        assert q1.ok and q2.ok
        assert float(q1.value[0]) == float(q2.value[0])
    # the migrated table keeps routing new ingests
    eng2.ingest(sids, np.ones(512, np.float32))
    q3 = eng2.handle({"type": "adhoc", "request_id": "q",
                      "synopsis_id": "cm/3", "query": {"items": [3]}})
    assert float(q3.value[0]) == 2 * float((sids == 3).sum())


_RESTORE_MESH_SCRIPT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    from jax.sharding import NamedSharding
    from repro.service import SDE

    rng = np.random.RandomState(0)
    sid_pop = np.unique(rng.randint(0, 2**63 - 1, 96, dtype=np.int64))
    eng = SDE()        # snapshot written WITHOUT a mesh (1-device layout)
    eng.handle({"type": "build", "request_id": "b", "synopsis_id": "cm",
                "kind": "countmin",
                "params": {"eps": 0.02, "delta": 0.1, "weighted": False},
                "per_stream_of_source": True,
                "stream_ids": [int(s) for s in sid_pop]})
    sids = sid_pop[rng.randint(0, len(sid_pop), 2048)]
    eng.ingest(sids, np.ones(len(sids), np.float32))
    d = tempfile.mkdtemp()
    eng.snapshot(d, 1)

    # restore onto an 8-device mesh: state rows shard over `synopsis`,
    # the routing-table mirror replicates
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    eng2 = SDE.restore(d, mesh=mesh)
    stack = next(iter(eng2.stacks.values()))
    for leaf in jax.tree.leaves(stack.state):
        assert isinstance(leaf.sharding, NamedSharding)
        assert leaf.sharding.spec and leaf.sharding.spec[0] == "data"
    for arr in stack.device_table():
        assert not arr.sharding.spec, arr.sharding   # replicated
    # ingest/query equivalence after the elastic repartition
    sid = int(sid_pop[5])
    q = eng2.handle({"type": "adhoc", "request_id": "q",
                     "synopsis_id": f"cm/{sid}", "query": {"items": [sid]}})
    assert float(q.value[0]) == float((sids == sid).sum()), q.value
    eng2.ingest(sids, np.ones(len(sids), np.float32))
    q = eng2.handle({"type": "adhoc", "request_id": "q2",
                     "synopsis_id": f"cm/{sid}", "query": {"items": [sid]}})
    assert float(q.value[0]) == 2 * float((sids == sid).sum()), q.value
    print("OK")
""")


def test_restore_onto_different_device_count():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _RESTORE_MESH_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


# ---------------------------------------------------------------------------
# satellite: JSON/service path hands ingest plain Python lists
# ---------------------------------------------------------------------------
def test_ingest_accepts_python_lists():
    eng = SDE()
    sid = 2**33 + 5
    eng.handle({"type": "build", "request_id": "b", "synopsis_id": "cm",
                "kind": "countmin",
                "params": {"eps": 0.02, "delta": 0.1, "weighted": False},
                "stream_id": sid})
    eng.ingest([sid, sid, sid], [1.0, 1.0, 1.0])          # plain lists
    eng.ingest([sid], [2.5], mask=[True])                 # list mask too
    q = eng.handle({"type": "adhoc", "request_id": "q", "synopsis_id":
                    "cm", "query": {"items": [sid]}})
    assert float(q.value[0]) == 4.0
    assert eng.tuples_ingested == 4


# ---------------------------------------------------------------------------
# satellite: continuous-query request ids never collide
# ---------------------------------------------------------------------------
def test_continuous_request_ids_unique_across_masked_batches():
    eng = SDE()
    eng.handle({"type": "build", "request_id": "c", "synopsis_id": "h",
                "kind": "hyperloglog", "params": {"rse": 0.05},
                "continuous": True})
    # two consecutive batches whose tuples are ALL masked out (negative
    # ids): tuples_ingested stays flat, so the old tuple-count key
    # collided; the batch counter must not
    for _ in range(2):
        eng.ingest(np.asarray([-1, -2], np.int64),
                   np.ones(2, np.float32))
    eng.ingest(np.arange(50, dtype=np.int64), np.ones(50, np.float32))
    rids = [r.request_id for r in eng.continuous_out]
    assert len(rids) == 3
    assert len(set(rids)) == len(rids), rids


# ---------------------------------------------------------------------------
# satellite: stopping a data-source synopsis must not leave a stale
# source-row index absorbing every tuple
# ---------------------------------------------------------------------------
def test_stopped_source_row_stops_absorbing():
    eng = SDE()
    eng.handle({"type": "build", "request_id": "b1", "synopsis_id":
                "all", "kind": "countmin",
                "params": {"eps": 0.02, "delta": 0.1, "weighted": False}})
    eng.handle({"type": "build", "request_id": "b2", "synopsis_id":
                "one", "kind": "countmin",
                "params": {"eps": 0.02, "delta": 0.1, "weighted": False},
                "stream_id": 7})
    eng.ingest(np.asarray([7, 8, 9], np.int64), np.ones(3, np.float32))
    assert eng.handle({"type": "stop", "request_id": "s",
                       "synopsis_id": "all"}).ok
    # the freed source row is reused by a ROUTED synopsis; if the cached
    # source index were stale it would keep absorbing every tuple
    eng.handle({"type": "build", "request_id": "b3", "synopsis_id":
                "two", "kind": "countmin",
                "params": {"eps": 0.02, "delta": 0.1, "weighted": False},
                "stream_id": 2**40})
    eng.ingest(np.asarray([7, 7, 2**40], np.int64),
               np.ones(3, np.float32))
    q = eng.handle({"type": "adhoc", "request_id": "q", "synopsis_id":
                    "two", "query": {"items": [2**40, 7]}})
    assert float(q.value[0]) == 1.0     # its own stream only
    assert float(q.value[1]) == 0.0     # nothing absorbed from stream 7
    q = eng.handle({"type": "adhoc", "request_id": "q2", "synopsis_id":
                    "one", "query": {"items": [7]}})
    assert float(q.value[0]) == 3.0     # routed synopsis unaffected
