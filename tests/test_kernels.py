"""Per-kernel shape/dtype sweeps: Pallas (interpret mode on CPU) vs the
pure-jnp oracles in kernels/ref.py."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.core import hashing


@pytest.mark.parametrize("t,n,d,log2w", [
    (64, 1, 3, 8), (700, 20, 4, 9), (1024, 128, 5, 10), (333, 7, 2, 7),
])
@pytest.mark.smoke
def test_countmin_kernel_sweep(t, n, d, log2w):
    rng = np.random.RandomState(t + n)
    seeds = jnp.asarray(hashing.row_seeds(7, d))
    counts = jnp.asarray(rng.rand(n, d, 1 << log2w).astype(np.float32))
    syn = rng.randint(0, n, t).astype(np.int32)
    items = rng.randint(0, 100000, t).astype(np.uint32)
    vals = rng.randn(t).astype(np.float32)
    mask = rng.rand(t) > 0.2
    out_k = ops.countmin_update(counts, syn, items, vals, mask,
                                seeds=seeds, log2_width=log2w)
    idx = hashing.bucket_hash(jnp.asarray(items), seeds, log2w)
    v = jnp.asarray(vals * mask)
    out_r = ref.onehot_scatter_add(counts, jnp.asarray(syn), idx, v,
                                   jnp.ones((t, d), jnp.float32))
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("t,n,d,log2w", [(256, 4, 5, 8), (900, 33, 3, 9)])
def test_ams_kernel_sweep(t, n, d, log2w):
    rng = np.random.RandomState(t)
    seeds = jnp.asarray(hashing.row_seeds(13, d))
    counts = jnp.zeros((n, d, 1 << log2w), jnp.float32)
    syn = rng.randint(0, n, t).astype(np.int32)
    items = rng.randint(0, 100000, t).astype(np.uint32)
    vals = rng.randn(t).astype(np.float32)
    mask = rng.rand(t) > 0.1
    out_k = ops.ams_update(counts, syn, items, vals, mask, seeds=seeds,
                           log2_width=log2w)
    idx = hashing.bucket_hash(jnp.asarray(items), seeds, log2w)
    sgn = hashing.sign_hash(jnp.asarray(items), seeds)
    out_r = ref.onehot_scatter_add(counts, jnp.asarray(syn), idx,
                                   jnp.asarray(vals * mask), sgn)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("t,n,p", [(128, 1, 8), (513, 16, 10), (900, 5, 6)])
def test_hll_kernel_sweep(t, n, p):
    rng = np.random.RandomState(p)
    regs = jnp.asarray(rng.randint(0, 5, (n, 1 << p)).astype(np.int32))
    syn = rng.randint(0, n, t).astype(np.int32)
    items = rng.randint(0, 10**6, t).astype(np.uint32)
    mask = rng.rand(t) > 0.3
    out_k = ops.hll_update(regs, syn, items, mask, seed=11, p=p)
    h = hashing.hash_u32(jnp.asarray(items), 11)
    bucket = (h >> np.uint32(32 - p)).astype(jnp.int32)
    rest = (h << np.uint32(p)).astype(jnp.uint32)
    rank = jnp.where(rest == 0, 32 - p + 1, hashing.clz32(rest) + 1)
    rank = jnp.where(jnp.asarray(mask), rank, 0).astype(jnp.int32)
    out_r = ref.hll_max_update(regs, jnp.asarray(syn), bucket, rank)
    assert (np.asarray(out_k) == np.asarray(out_r)).all()


@pytest.mark.parametrize("t,n,log2b,k", [
    (100, 3, 8, 4), (513, 16, 9, 6), (256, 8, 7, 3),
])
def test_bloom_kernel_sweep(t, n, log2b, k):
    rng = np.random.RandomState(t + k)
    seeds = jnp.asarray(hashing.row_seeds(17, k))
    bits = jnp.asarray(rng.randint(0, 2, (n, 1 << log2b)).astype(np.int32))
    syn = rng.randint(-1, n, t).astype(np.int32)    # -1 = unrouted no-op
    items = rng.randint(0, 10**6, t).astype(np.uint32)
    mask = rng.rand(t) > 0.3
    out_k = ops.bloom_update(bits, jnp.asarray(syn), jnp.asarray(items),
                             jnp.asarray(mask), seeds=seeds,
                             log2_bits=log2b)
    idx = hashing.bucket_hash(jnp.asarray(items), seeds, log2b)
    out_r = ref.bitset_max_update(bits, jnp.asarray(syn), idx,
                                  jnp.asarray(mask).astype(jnp.int32))
    assert (np.asarray(out_k) == np.asarray(out_r)).all()


@pytest.mark.parametrize("t,n,maps,nbits", [
    (100, 3, 8, 16), (513, 16, 64, 32), (256, 9, 16, 24),
])
def test_fm_kernel_sweep(t, n, maps, nbits):
    rng = np.random.RandomState(t + maps)
    state = jnp.asarray(rng.randint(0, 2, (n, maps, nbits)).astype(np.int32))
    syn = rng.randint(-1, n, t).astype(np.int32)
    which = rng.randint(0, maps, t).astype(np.int32)
    pos = rng.randint(0, nbits, t).astype(np.int32)
    mask = rng.rand(t) > 0.3
    out_k = ops.fm_update(state, jnp.asarray(syn), jnp.asarray(which),
                          jnp.asarray(pos), jnp.asarray(mask))
    out_r = ref.fm_bit_update(state, jnp.asarray(syn), jnp.asarray(which),
                              jnp.asarray(pos),
                              jnp.asarray(mask).astype(jnp.int32))
    assert (np.asarray(out_k) == np.asarray(out_r)).all()


@pytest.mark.parametrize("t,n,b", [(100, 3, 64), (700, 130, 64),
                                   (513, 16, 200)])
def test_rhp_kernel_sweep(t, n, b):
    rng = np.random.RandomState(t + b)
    seeds = jnp.asarray(hashing.row_seeds(29, b))
    state = jnp.asarray(rng.randn(n, b).astype(np.float32))
    syn = rng.randint(-1, n, t).astype(np.int32)
    items = rng.randint(0, 10**6, t).astype(np.uint32)
    vals = rng.randn(t).astype(np.float32)
    mask = rng.rand(t) > 0.2
    out_k = ops.rhp_update(state, jnp.asarray(syn), jnp.asarray(items),
                           jnp.asarray(vals), jnp.asarray(mask),
                           seeds=seeds)
    sgn = hashing.sign_hash(jnp.asarray(items), seeds)
    out_r = ref.rhp_project_update(state, jnp.asarray(syn),
                                   jnp.asarray(vals * mask), sgn)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("s,f", [(100, 8), (512, 16), (1111, 4)])
def test_dft_kernel_sweep(s, f):
    rng = np.random.RandomState(s)
    re = rng.randn(s, f).astype(np.float32)
    im = rng.randn(s, f).astype(np.float32)
    delta = rng.randn(s).astype(np.float32)
    mask = (rng.rand(s) > 0.2).astype(np.float32)
    ang = 2 * np.pi * np.arange(1, f + 1) / 64
    twr = np.cos(ang).astype(np.float32)
    twi = np.sin(ang).astype(np.float32)
    kr, ki = ops.dft_step(*map(jnp.asarray, (re, im, delta, mask, twr, twi)))
    rr, ri = ref.sliding_dft_step(*map(jnp.asarray,
                                       (re, im, delta, mask, twr, twi)))
    np.testing.assert_allclose(np.asarray(kr), np.asarray(rr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(ki), np.asarray(ri), atol=1e-5)


@pytest.mark.parametrize("bh,s,d,bq,bk,causal,dtype", [
    (2, 256, 64, 128, 128, True, jnp.float32),
    (4, 128, 128, 64, 128, True, jnp.float32),
    (2, 200, 64, 128, 128, True, jnp.float32),    # padded seq
    (2, 256, 64, 128, 128, False, jnp.float32),
    (2, 256, 64, 128, 128, True, jnp.bfloat16),
])
def test_flash_attention_sweep(bh, s, d, bq, bk, causal, dtype):
    rng = np.random.RandomState(s + d)
    q = jnp.asarray(rng.randn(bh, s, d).astype(np.float32) * 0.3, dtype)
    k = jnp.asarray(rng.randn(bh, s, d).astype(np.float32) * 0.3, dtype)
    v = jnp.asarray(rng.randn(bh, s, d).astype(np.float32), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk)
    want = ref.flash_attention(q, k, v, causal=causal)
    a = np.asarray(out, np.float32)
    b = np.asarray(want, np.float32)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    assert np.abs(a - b).max() / (np.abs(b).max() + 1e-9) < tol


@pytest.mark.parametrize("n,k", [(64, 16), (300, 16), (512, 40)])
def test_corr_kernel_sweep(n, k):
    rng = np.random.RandomState(n)
    x = (rng.randn(n, k) * 0.1).astype(np.float32)
    out_k = ops.corr_matrix(jnp.asarray(x))
    out_r = ref.pairwise_corr(jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-4, atol=1e-5)
