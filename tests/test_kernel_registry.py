"""The update-kernel registry contract (PR 6 tentpole).

Every scatter kind resolves its Pallas kernel by NAME (no isinstance
dispatch in the engine); fused-probe and probe-then-scatter forms are
byte-identical to the XLA reference path across all twelve kinds,
including unrouted ids and data-source rows; the compiled-program caches
are bounded and release per-kind entries on stop/close; the env
overrides (SDE_PALLAS_INTERPRET, SDE_FUSED_PROBE) follow their contract.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import core
from repro.core import batched
from repro.kernels import ops
from repro.service import SDE
from repro.service import routing


# small-footprint params per kind: the matrix builds 24 routed rows per
# kind and runs Pallas in interpret mode, so sketch widths stay tiny
_PARAMS = {
    "countmin": {"eps": 0.1, "delta": 0.1, "weighted": False},
    "ams": {"eps": 0.1, "delta": 0.1},
    "hyperloglog": {"rse": 0.1},
    "bloom": {"n_elements": 64, "fpr": 0.05},
    "fm": {"nmaps": 8, "bitmap_size": 16},
    "rhp": {"n_bits": 64},
    "dft": {"window": 16, "n_coeffs": 4, "threshold": 0.9},
    "lossy_counting": {"eps": 0.05},
    "sticky_sampling": {},
    "chain_sampler": {},
    "gk_quantiles": {},
    "coreset_tree": {"bucket_size": 256, "dim": 1},
}

# engine-level skips: kinds whose blue path never reaches the update
# registry, with the reason stated
_SKIP = {
    "dft": "timeseries kind: ingest runs the stacked step path "
           "(route probe fused into stacked_step), not the update "
           "registry",
}


def _hashed_pop(rng, n):
    """n distinct 62-bit stream ids (exercises hashed routing, not the
    dense 0..n-1 id space)."""
    pop = np.unique(rng.randint(0, 2**62, size=4 * n, dtype=np.int64))
    return pop[:n]


def _tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# registry resolution
# ---------------------------------------------------------------------------
def test_every_scatter_kind_declares_a_registered_kernel():
    for name in core.known_kinds():
        if name not in _PARAMS:
            # kinds plugged in by other test modules (register_kind has
            # process-global effect); the contract covers the stock set
            continue
        kind = core.make_kind(name, **_PARAMS[name])
        kname = getattr(kind, "update_kernel", None)
        if hasattr(kind, "stacked_add_batch") and not hasattr(kind, "step"):
            assert kname in ops.UPDATE_KERNELS, (
                f"{name} has a scatter path but no registered kernel")
            assert callable(ops.resolve_update_kernel(kind, True))
            assert callable(ops.resolve_update_kernel(kind, False))
        elif kname is None:
            assert ops.resolve_update_kernel(kind) is None


def test_unregistered_kernel_name_raises_with_guidance():
    class Odd:
        update_kernel = "no_such_kernel"

    with pytest.raises(KeyError, match="no_such_kernel"):
        ops.resolve_update_kernel(Odd())


def test_register_duplicate_kernel_requires_overwrite():
    builder = lambda kind, fuse: None
    ops.register_update_kernel("_test_dup", builder)
    try:
        with pytest.raises(ValueError, match="already registered"):
            ops.register_update_kernel("_test_dup", builder)
        ops.register_update_kernel("_test_dup", builder, overwrite=True)
    finally:
        ops.UPDATE_KERNELS.pop("_test_dup", None)


# ---------------------------------------------------------------------------
# registry-level equivalence: fused probe == probe-then-scatter == XLA
# ---------------------------------------------------------------------------
_SCATTER_KINDS = [
    core.CountMin(eps=0.1, delta=0.1, weighted=False),
    core.AMS(eps=0.1, delta=0.1),
    core.HyperLogLog(rse=0.1),
    core.BloomFilter(n_elements=64, fpr=0.05),
    core.FMSketch(nmaps=8, bitmap_size=16),
    core.RHP(n_bits=64),
]


@pytest.mark.parametrize("kind", _SCATTER_KINDS,
                         ids=lambda k: type(k).__name__)
def test_registry_kernel_matches_xla_reference(kind):
    n, t = 24, 300
    rng = np.random.RandomState(3)
    pop = _hashed_pop(rng, n)
    table = routing.RouteTable()
    table.insert_many(pop, np.arange(n, dtype=np.int32))
    klo, khi = (jnp.asarray(h) for h in routing.split64(table.keys))
    trows = jnp.asarray(table.rows)
    n_probe = routing.next_pow2(table.max_probe)

    sids = pop[rng.randint(0, n, t)]
    sids[::13] = int(pop.max()) + 7          # unrouted: must be dropped
    slo, shi = (jnp.asarray(h) for h in routing.split64(sids))
    items = jnp.asarray(routing.fold64(sids))
    vals = jnp.asarray(rng.randint(1, 4, t).astype(np.float32))
    msk = jnp.asarray(rng.rand(t) > 0.2)
    src = jnp.asarray([1, 5], jnp.int32)     # data-source rows

    state = batched.stacked_init(kind, n)
    outs = {}
    for fuse in (True, False):
        fn = ops.resolve_update_kernel(kind, fuse)
        outs[fuse] = np.asarray(fn(state, klo, khi, trows, slo, shi,
                                   items, vals, msk, src, n_probe=n_probe))
    rows = ops.route_probe(klo, khi, trows, slo, shi, n_probe=n_probe)
    want = np.asarray(batched.stacked_update(kind, state, rows, items,
                                             vals, msk, src))
    assert np.array_equal(outs[True], want), "fused probe diverged"
    assert np.array_equal(outs[False], want), "unfused kernel diverged"


# ---------------------------------------------------------------------------
# engine-level matrix: pallas backend == xla backend for ALL twelve kinds
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind_name", sorted(core.known_kinds()))
def test_engine_backend_matrix(kind_name):
    if kind_name in _SKIP:
        pytest.skip(_SKIP[kind_name])
    n = 24
    rng = np.random.RandomState(7)
    pop = _hashed_pop(rng, n)
    batches = []
    for _ in range(2):
        sids = pop[rng.randint(0, n, 256)]
        sids[::17] = int(pop.max()) + 3      # unrouted ids in every batch
        vals = rng.randint(1, 5, 256).astype(np.float32)
        batches.append((sids, vals))
    states = {}
    for backend in ("xla", "pallas"):
        eng = SDE(backend=backend)
        r = eng.handle({"type": "build", "request_id": "b",
                        "synopsis_id": "s", "kind": kind_name,
                        "params": _PARAMS[kind_name],
                        "per_stream_of_source": True,
                        "stream_ids": [int(s) for s in pop]})
        assert r.ok, r.error
        r = eng.handle({"type": "build", "request_id": "b2",
                        "synopsis_id": "src", "kind": kind_name,
                        "params": _PARAMS[kind_name]})
        assert r.ok, r.error
        for sids, vals in batches:
            eng.ingest(sids, vals)
        states[backend] = next(iter(eng.stacks.values())).state
        eng.close()
    assert _tree_equal(states["xla"], states["pallas"]), (
        f"{kind_name}: pallas state != xla state")


# ---------------------------------------------------------------------------
# dispatch discipline: one trace, one dispatch per batch on the fused path
# ---------------------------------------------------------------------------
def test_fused_pallas_update_one_trace_one_dispatch_per_batch():
    # unique eps => fresh cache entry => trace count starts at zero here
    eng = SDE(backend="pallas")
    r = eng.handle({"type": "build", "request_id": "b", "synopsis_id": "c",
                    "kind": "countmin",
                    "params": {"eps": 0.0421, "delta": 0.1,
                               "weighted": False},
                    "per_stream_of_source": True, "n_streams": 16})
    assert r.ok, r.error
    d0 = ops.DISPATCH_COUNT["update:CountMin"]
    t0 = ops.TRACE_COUNT["update:CountMin"]
    rng = np.random.RandomState(0)
    for _ in range(3):
        eng.ingest(rng.randint(0, 16, 128).astype(np.uint32),
                   np.ones(128, np.float32))
    assert ops.DISPATCH_COUNT["update:CountMin"] - d0 == 3
    assert ops.TRACE_COUNT["update:CountMin"] - t0 == 1
    eng.close()


# ---------------------------------------------------------------------------
# bounded caches: stop/close release the kind's compiled programs
# ---------------------------------------------------------------------------
def test_update_cache_entries_released_on_stop():
    g0 = ops.KERNEL_CACHE_SIZE["update"]
    eng = SDE(backend="xla")
    r = eng.handle({"type": "build", "request_id": "b", "synopsis_id": "c",
                    "kind": "countmin",
                    "params": {"eps": 0.0517, "delta": 0.1,
                               "weighted": False},
                    "per_stream_of_source": True, "n_streams": 8})
    assert r.ok, r.error
    eng.ingest(np.arange(8, dtype=np.uint32), np.ones(8, np.float32))
    assert ops.KERNEL_CACHE_SIZE["update"] > g0
    r = eng.handle({"type": "stop", "request_id": "s", "synopsis_id": "c"})
    assert r.ok, r.error
    assert ops.KERNEL_CACHE_SIZE["update"] == g0
    assert not eng.stacks


def test_close_releases_every_kind_cache_entry():
    g0 = {c: ops.KERNEL_CACHE_SIZE[c] for c in ("update", "step")}
    eng = SDE(backend="pallas")
    for i, (kname, params) in enumerate([
            ("hyperloglog", {"rse": 0.0987}),
            ("rhp", {"n_bits": 56}),
            ("dft", {"window": 24, "n_coeffs": 4, "threshold": 0.9})]):
        r = eng.handle({"type": "build", "request_id": f"b{i}",
                        "synopsis_id": f"s{i}", "kind": kname,
                        "params": params, "per_stream_of_source": True,
                        "n_streams": 8})
        assert r.ok, r.error
    eng.ingest(np.arange(8, dtype=np.uint32), np.ones(8, np.float32))
    assert ops.KERNEL_CACHE_SIZE["update"] > g0["update"]
    assert ops.KERNEL_CACHE_SIZE["step"] > g0["step"]
    eng.close()
    for c in ("update", "step"):
        assert ops.KERNEL_CACHE_SIZE[c] == g0[c]
    assert not eng.stacks and not eng.entries


# ---------------------------------------------------------------------------
# env overrides
# ---------------------------------------------------------------------------
def test_pallas_interpret_env_override(monkeypatch):
    monkeypatch.setenv("SDE_PALLAS_INTERPRET", "1")
    assert ops._interpret() is True
    monkeypatch.setenv("SDE_PALLAS_INTERPRET", "off")
    assert ops._interpret() is False
    monkeypatch.setenv("SDE_PALLAS_INTERPRET", "bogus")
    with pytest.raises(ValueError, match="SDE_PALLAS_INTERPRET"):
        ops._interpret()
    monkeypatch.delenv("SDE_PALLAS_INTERPRET")
    assert ops._interpret() is (jax.default_backend() != "tpu")


def test_fused_probe_env_toggle(monkeypatch):
    monkeypatch.delenv("SDE_FUSED_PROBE", raising=False)
    assert ops.probe_fusion_enabled() is True     # fused by default
    monkeypatch.setenv("SDE_FUSED_PROBE", "0")
    assert ops.probe_fusion_enabled() is False
    monkeypatch.setenv("SDE_FUSED_PROBE", "1")
    assert ops.probe_fusion_enabled() is True


def test_backend_env_default(monkeypatch):
    monkeypatch.setenv("SDE_BACKEND", "pallas")
    assert SDE().backend == "pallas"
    monkeypatch.delenv("SDE_BACKEND")
    assert SDE().backend == "xla"
    assert SDE(backend="xla").backend == "xla"


# ---------------------------------------------------------------------------
# multi-device: pallas backend on a synopsis-sharded 8-device mesh
# ---------------------------------------------------------------------------
_PALLAS_SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    from repro.service import SDE

    states = {}
    for backend in ("xla", "pallas"):
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        eng = SDE(backend=backend, mesh=mesh)
        r = eng.handle({"type": "build", "request_id": "b",
                        "synopsis_id": "cm", "kind": "countmin",
                        "params": {"eps": 0.1, "delta": 0.1,
                                   "weighted": False},
                        "per_stream_of_source": True, "n_streams": 64})
        assert r.ok, r.error
        r = eng.handle({"type": "build", "request_id": "b2",
                        "synopsis_id": "all", "kind": "countmin",
                        "params": {"eps": 0.1, "delta": 0.1,
                                   "weighted": False}})
        assert r.ok, r.error
        rng = np.random.RandomState(0)
        for _ in range(2):
            sids = rng.randint(0, 64, 512).astype(np.uint32)
            eng.ingest(sids, np.ones(512, np.float32))
        stack = next(iter(eng.stacks.values()))
        assert stack.state.sharding.spec[0] == "data", stack.state.sharding
        states[backend] = np.asarray(stack.state)
    assert np.array_equal(states["xla"], states["pallas"])
    print("OK")
""")


def test_pallas_backend_sharded_over_synopsis_axis():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    out = subprocess.run([sys.executable, "-c", _PALLAS_SHARDED_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
