"""Cross-tenant micro-batching gateway tests.

The contract under test (the PR's acceptance invariants):

  * N concurrent clients interleaving ingest/query/build traffic leave
    the engine byte-identical to a serialized single-client replay of
    the gateway's commit log (coalescing is state-invisible).
  * Probe-verified amortization: one blue-path dispatch per kind per
    tick regardless of client count (``DISPATCH_COUNT`` vs
    ``GATEWAY_COALESCED``), and one stacked-estimate dispatch for a
    tick's worth of concurrent ad-hoc queries.
  * Tenant namespaces isolate synopsis keys; stream ids stay shared.
  * Continuous responses route to the building client's bounded log.
  * Admission control caps per-client in-flight requests.
  * The socket server round-trips all of it over TCP, eager and
    pipelined, and ``shutdown`` stops it cleanly.
"""
import asyncio
import builtins
import io
import json

import jax
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.launch import sde_server
from repro.service import SDE, SynopsisGateway, replay_log

CM = {"eps": 0.05, "delta": 0.1, "weighted": False}


def _build(synopsis_id="cm", request_id="b", **kw):
    return dict({"type": "build", "request_id": request_id,
                 "synopsis_id": synopsis_id, "kind": "countmin",
                 "params": CM}, **kw)


def _ingest(request_id, sids, vals=None):
    return {"type": "ingest", "request_id": request_id,
            "stream_ids": list(map(int, sids)),
            "values": [1.0] * len(sids) if vals is None else list(vals)}


def _assert_states_equal(a: SDE, b: SDE):
    assert sorted(a.stacks) == sorted(b.stacks)
    assert sorted(a.entries) == sorted(b.entries)
    for kind in a.stacks:
        for x, y in zip(jax.tree.leaves(a.stacks[kind].state),
                        jax.tree.leaves(b.stacks[kind].state)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# tenant namespaces
# ---------------------------------------------------------------------------
@pytest.mark.smoke
def test_tenant_namespacing_and_isolation():
    gw = SynopsisGateway(SDE())
    acme = gw.connect("a0", tenant="acme")
    glob = gw.connect("g0", tenant="globex")
    admin = gw.connect("root")           # empty tenant = admin view
    fa = gw.submit_nowait(acme, _build())
    fg = gw.submit_nowait(glob, _build())
    gw.tick()
    assert fa.result().ok and fg.result().ok
    # same client-visible id, two engine entries — and responses carry
    # the client-visible (stripped) id back
    assert sorted(gw.sde.entries) == ["acme::cm", "globex::cm"]
    assert fa.result().synopsis_id == "cm"
    # a tenant cannot reach across: "globex::cm" namespaces to
    # "acme::globex::cm", which does not exist
    fx = gw.submit_nowait(acme, {"type": "adhoc", "request_id": "x",
                                 "synopsis_id": "globex::cm",
                                 "query": {"items": [1]}})
    fs = gw.submit_nowait(acme, {"type": "status", "request_id": "s"})
    fr = gw.submit_nowait(admin, {"type": "status", "request_id": "r"})
    gw.tick()
    assert not fx.result().ok
    assert list(fs.result().value) == ["cm"]          # own, stripped
    assert sorted(fr.result().value) == ["acme::cm", "globex::cm"]


@pytest.mark.smoke
def test_separator_bearing_tenant_names_rejected():
    # tenant "a" + synopsis "b::c" and tenant "a::b" + synopsis "c" both
    # namespace to "a::b::c" — a separator-bearing TENANT silently
    # merges two tenants' namespaces, so it is refused at the door
    gw = SynopsisGateway(SDE())
    with pytest.raises(ValueError, match="reserved namespace separator"):
        gw.connect("evil", tenant="a::b")
    assert "evil" not in gw.clients
    # the per-request tenant override is the other door in
    c = gw.connect("c0", tenant="a")
    f = gw.submit_nowait(c, dict(_build(), tenant="a::b"))
    gw.tick()
    assert not f.result().ok
    assert "reserved namespace separator" in f.result().error
    assert not gw.sde.entries             # nothing reached the engine
    # SYNOPSIS ids may carry "::" freely — the split stays unambiguous
    # because only the left side is separator-clean. Round-trip one:
    fb = gw.submit_nowait(c, _build(synopsis_id="b::c"))
    fi = gw.submit_nowait(c, _ingest("i", [1, 2, 3]))
    fq = gw.submit_nowait(c, {"type": "adhoc", "request_id": "q",
                              "synopsis_id": "b::c",
                              "query": {"items": [1]}})
    gw.tick()
    assert fb.result().ok and fi.result().ok and fq.result().ok
    assert list(gw.sde.entries) == ["a::b::c"]
    assert fq.result().synopsis_id == "b::c"          # stripped exactly
    assert float(np.asarray(fq.result().value).ravel()[0]) >= 1.0


def test_outlier_workflow_routes_to_tracking_client():
    gw = SynopsisGateway(SDE())
    acme = gw.connect("a0", tenant="acme")
    other = gw.connect("g0", tenant="globex")
    fb = gw.submit_nowait(acme, {
        "type": "build_multidim", "request_id": "b", "synopsis_id": "md",
        "kind": "countmin", "params": CM,
        "dims": {"region": ["EU", "US"]}})
    ft = gw.submit_nowait(acme, {
        "type": "track_outliers", "request_id": "t",
        "workflow_id": "w", "synopsis_id": "md",
        "level": ["region"], "query": {"items": [7]}, "threshold": 0.0})
    gw.tick()
    assert fb.result().ok, fb.result().error
    assert ft.result().ok, ft.result().error
    assert gw._subs["acme::w"] == ("a0", "acme")
    fi = gw.submit_nowait(acme, {
        "type": "ingest_multidim", "request_id": "i",
        "synopsis_id": "md",
        "records": [{"region": "EU"}, {"region": "US"}],
        "values": [1.0, 1.0], "items": [7, 7]})
    gw.tick()
    assert fi.result().ok, fi.result().error
    gw.sde.flush()
    gw.tick()                             # route the retired emissions
    out = acme.log.drain()
    assert out and all(r.synopsis_id == "w" for r in out)
    assert out[0].request_id.startswith("ow/w/")      # prefix stripped
    assert not other.log.drain()
    # commit-log replay reproduces the multidim state serially
    replayed = replay_log(gw.commit_log)
    _assert_states_equal(replayed, gw.sde)
    replayed.close(), gw.sde.close()


# ---------------------------------------------------------------------------
# the headline invariant: 64 clients, ONE dispatch per kind per tick
# ---------------------------------------------------------------------------
@pytest.mark.smoke
def test_64_clients_one_blue_dispatch_per_tick():
    gw = SynopsisGateway(SDE())
    clients = [gw.connect(f"c{i}") for i in range(64)]
    gw.submit_nowait(clients[0], _build())
    gw.tick()
    d0 = kops.DISPATCH_COUNT.get("update:CountMin", 0)
    c0 = kops.GATEWAY_COALESCED.get("ingest", 0)
    rng = np.random.RandomState(0)
    futs = [gw.submit_nowait(c, _ingest(f"i{i}", rng.randint(0, 100, 16)))
            for i, c in enumerate(clients)]
    n = gw.tick()
    assert n == 64
    assert kops.DISPATCH_COUNT["update:CountMin"] - d0 == 1
    assert kops.GATEWAY_COALESCED["ingest"] - c0 == 64
    acks = [f.result() for f in futs]
    assert all(a.ok for a in acks)
    # every client was folded into the SAME engine batch
    assert len({a.value["batch"] for a in acks}) == 1
    assert all(a.value["coalesced"] == 64 for a in acks)
    assert all(a.value["tuples"] == 16 for a in acks)


def test_query_run_coalesces_to_one_red_dispatch():
    gw = SynopsisGateway(SDE())
    acme = gw.connect("a0", tenant="acme")
    glob = gw.connect("g0", tenant="globex")
    gw.submit_nowait(acme, _build(stream_id=1))
    gw.submit_nowait(glob, _build(stream_id=2))
    gw.tick()
    sids = np.array([1] * 3 + [2] * 5)
    gw.submit_nowait(acme, _ingest("i", sids))
    gw.tick()
    d0 = kops.DISPATCH_COUNT.get("CountMin", 0)
    q0 = gw.submit_nowait(acme, {"type": "adhoc", "request_id": "qa",
                                 "synopsis_id": "cm",
                                 "query": {"items": [1]}})
    q1 = gw.submit_nowait(glob, {"type": "adhoc", "request_id": "qg",
                                 "synopsis_id": "cm",
                                 "query": {"items": [2]}})
    q2 = gw.submit_nowait(glob, {"type": "query_many", "request_id": "qm",
                                 "queries": [
                                     {"synopsis_id": "cm",
                                      "query": {"items": [2]}},
                                     {"synopsis_id": "nope"}]})
    gw.tick()
    # one stacked-estimate dispatch answered all three requests
    assert kops.DISPATCH_COUNT["CountMin"] - d0 == 1
    assert float(np.ravel(q0.result().value)[0]) == 3.0
    assert float(np.ravel(q1.result().value)[0]) == 5.0
    many = q2.result()
    assert not many.ok                   # one sub-query hit a missing key
    assert float(np.ravel(many.value[0]["value"])[0]) == 5.0
    assert many.value[0]["synopsis_id"] == "cm"      # ns stripped
    assert not many.value[1]["ok"]
    # per-part validation: a malformed ingest fails ALONE in its run
    good = gw.submit_nowait(acme, _ingest("ok", [1, 2]))
    bad = gw.submit_nowait(glob, {"type": "ingest", "request_id": "bad",
                                  "stream_ids": [1, 2], "values": [1.0]})
    gw.tick()
    assert good.result().ok
    assert not bad.result().ok and "mismatch" in bad.result().error


# ---------------------------------------------------------------------------
# concurrent clients == serialized oracle, byte for byte
# ---------------------------------------------------------------------------
def test_concurrent_clients_match_serialized_oracle():
    async def drive():
        gw = SynopsisGateway(SDE(), tick_interval=0.001)
        await gw.start()
        d0 = kops.DISPATCH_COUNT.get("update:CountMin", 0)
        c0 = kops.GATEWAY_COALESCED.get("ingest", 0)

        async def client_traffic(j):
            tenant = f"t{j % 4}"
            c = gw.connect(f"c{j}", tenant=tenant)
            r = await gw.submit(c, _build(f"cm{j}", request_id=f"b{j}"))
            assert r.ok, r.error
            rng = np.random.RandomState(j)
            for k in range(6):
                r = await gw.submit(
                    c, _ingest(f"i{j}/{k}", rng.randint(0, 50, 32),
                               rng.uniform(0.5, 2.0, 32)))
                assert r.ok, r.error
                if k % 2:
                    q = await gw.submit(
                        c, {"type": "adhoc", "request_id": f"q{j}/{k}",
                            "synopsis_id": f"cm{j}",
                            "query": {"items": [int(rng.randint(50))]}})
                    assert q.ok, q.error

        await asyncio.gather(*(client_traffic(j) for j in range(8)))
        await gw.stop()
        return gw, d0, c0

    gw, d0, c0 = asyncio.run(drive())
    n_ingest_calls = sum(1 for e in gw.commit_log if e[0] == "ingest")
    n_ingest_reqs = 8 * 6
    # every coalesced call was ONE dispatch; concurrency actually
    # amortized (strictly fewer engine calls than client requests)
    assert kops.DISPATCH_COUNT["update:CountMin"] - d0 == n_ingest_calls
    assert kops.GATEWAY_COALESCED["ingest"] - c0 == n_ingest_reqs
    assert n_ingest_calls < n_ingest_reqs
    gw.sde.flush()
    _assert_states_equal(gw.sde, replay_log(gw.commit_log))


def test_commit_log_replays_on_pipelined_oracle():
    """The oracle is execution-mode-agnostic: replaying the commit log
    on a PIPELINED engine matches the gateway's eager engine bytewise."""
    gw = SynopsisGateway(SDE())
    c = gw.connect("c0", tenant="acme")
    gw.submit_nowait(c, _build())
    gw.tick()
    rng = np.random.RandomState(7)
    for k in range(4):
        for j in range(3):
            gw.submit_nowait(c, _ingest(f"i{k}/{j}",
                                        rng.randint(0, 40, 16),
                                        rng.uniform(0.5, 2.0, 16)))
        gw.tick()
    gw.sde.flush()
    _assert_states_equal(gw.sde, replay_log(gw.commit_log,
                                            SDE(pipelined=True)))


# ---------------------------------------------------------------------------
# continuous-query routing
# ---------------------------------------------------------------------------
def test_continuous_responses_route_to_subscriber():
    gw = SynopsisGateway(SDE())
    sub = gw.connect("sub", tenant="acme")
    other = gw.connect("other", tenant="acme")
    gw.submit_nowait(sub, _build(continuous=True))
    gw.tick()
    gw.submit_nowait(other, _ingest("i0", [1, 2, 3]))
    gw.submit_nowait(other, _ingest("i1", [1, 1, 4]))
    gw.tick()
    gw.sde.flush()                       # pipelined engine: retire, then
    gw.tick()                            # an empty tick still routes
    assert len(sub.log) == 1             # one coalesced batch => one cq
    assert len(other.log) == 0 and len(gw.unrouted) == 0
    r = sub.log.popleft()
    assert r.synopsis_id == "cm"         # ns stripped on the way out
    assert r.request_id == "cq/cm/1"
    oracle = replay_log(gw.commit_log)
    ro = oracle.continuous_out.popleft()
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), r.value, ro.value)
    # stop drops the subscription; a disconnected subscriber's responses
    # fall into the bounded unrouted log
    gw.submit_nowait(sub, {"type": "stop", "request_id": "s",
                           "synopsis_id": "cm"})
    gw.tick()
    assert gw._subs == {}
    gw.submit_nowait(sub, _build("cm2", continuous=True))
    gw.tick()
    gw.disconnect(sub)
    gw.submit_nowait(other, _ingest("i2", [5, 6]))
    gw.tick()
    gw.sde.flush()
    gw.tick()
    assert len(gw.unrouted) == 1


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------
def test_admission_control_caps_in_flight():
    async def drive():
        gw = SynopsisGateway(SDE(), max_in_flight=2)
        c = gw.connect("c0")
        gw.submit_nowait(c, _build())
        gw.tick()
        subs = [asyncio.ensure_future(
            gw.submit(c, _ingest(f"i{k}", [1, 2]))) for k in range(3)]
        for _ in range(10):
            await asyncio.sleep(0)
        assert gw.queued == 2            # third submission NOT admitted
        gw.tick()                        # acks 1+2 -> slots free
        for _ in range(10):
            await asyncio.sleep(0)
        assert gw.queued == 1            # third got in only after acks
        gw.tick()
        acks = await asyncio.gather(*subs)
        assert all(a.ok for a in acks)
        # the delayed request rode a LATER batch than the admitted pair
        assert acks[2].value["batch"] > acks[0].value["batch"]

    asyncio.run(drive())


# ---------------------------------------------------------------------------
# socket server round-trip
# ---------------------------------------------------------------------------
@pytest.mark.smoke
@pytest.mark.parametrize("pipelined", [False, True])
def test_socket_server_roundtrip(pipelined):
    async def drive():
        ready = asyncio.get_running_loop().create_future()
        server = asyncio.ensure_future(sde_server.serve_socket(
            SDE(pipelined=pipelined), port=0, tick_interval=0.001,
            ready=ready, err=io.StringIO()))
        port = await asyncio.wait_for(ready, 10)
        reader, writer = await asyncio.open_connection("127.0.0.1", port)
        reqs = [dict(_build(continuous=True), tenant="acme"),
                dict(_ingest("i0", [1, 2, 3]), tenant="acme"),
                {"type": "adhoc", "request_id": "q", "tenant": "acme",
                 "synopsis_id": "cm", "query": {"items": [1]}},
                {"type": "shutdown", "request_id": "bye"}]
        writer.write("".join(json.dumps(r) + "\n" for r in reqs).encode())
        await writer.drain()
        lines = []
        while True:                      # server EOFs after shutdown ack
            line = await asyncio.wait_for(reader.readline(), 10)
            if not line:
                break
            lines.append(json.loads(line))
        writer.close()
        gw = await asyncio.wait_for(server, 10)
        return gw, lines

    gw, lines = asyncio.run(drive())
    by_id = {r["request_id"]: r for r in lines}
    assert by_id["b"]["ok"] and by_id["i0"]["ok"] and by_id["q"]["ok"]
    assert by_id["q"]["synopsis_id"] == "cm"
    assert float(np.ravel(by_id["q"]["value"])[0]) == 1.0
    assert by_id["bye"]["ok"]
    assert by_id["bye"]["value"]["tuples_ingested"] == 3
    # the builder's connection received its continuous response
    cq = [r for r in lines if r["request_id"].startswith("cq/")]
    assert len(cq) == 1 and cq[0]["synopsis_id"] == "cm"
    # shutdown closed the engine and the gateway refuses new work
    assert gw.closed and gw.sde.stacks == {}
    fut = gw.submit_nowait(
        type("C", (), {"tenant": "", "client_id": "late"})(),
        {"type": "status", "request_id": "late"})
    assert not fut.result().ok


# ---------------------------------------------------------------------------
# shutdown request — engine level and JSON-lines server
# ---------------------------------------------------------------------------
def test_shutdown_request_flushes_and_closes():
    eng = SDE(pipelined=True)
    assert eng.handle(_build(continuous=True)).ok
    eng.ingest(np.array([1, 2], np.uint32), np.ones(2, np.float32))
    assert eng.pending_batches > 0
    r = eng.handle({"type": "shutdown", "request_id": "bye"})
    assert r.ok
    assert r.value["drained"] >= 1
    assert r.value["tuples_ingested"] == 2
    assert r.value["continuous_unread"] == 1
    assert eng.stacks == {} and eng.entries == {}


def test_serve_lines_stops_after_shutdown():
    lines = [json.dumps(_build()),
             json.dumps(_ingest("i", [1, 2])),
             json.dumps({"type": "shutdown", "request_id": "bye"}),
             json.dumps(_ingest("never", [3]))]
    out = io.StringIO()
    n = sde_server.serve_lines(lines, out=out)
    assert n == 3                        # the post-shutdown line is dead
    ids = [json.loads(l)["request_id"] for l in out.getvalue().splitlines()]
    assert ids == ["b", "i", "bye"]


# ---------------------------------------------------------------------------
# satellite fixes: file-handle lifetime, batched continuous drain
# ---------------------------------------------------------------------------
def test_main_closes_input_file(tmp_path, monkeypatch, capsys):
    req = tmp_path / "reqs.jsonl"
    req.write_text(json.dumps(_build()) + "\n"
                   + json.dumps(_ingest("i", [1, 2, 3])) + "\n")
    opened = []
    real_open = builtins.open
    def spy(path, *a, **kw):
        fh = real_open(path, *a, **kw)
        if str(path) == str(req):
            opened.append(fh)
        return fh
    monkeypatch.setattr(builtins, "open", spy)
    n = sde_server.main(["--input", str(req)])
    assert n == 2
    assert opened and all(fh.closed for fh in opened)


def test_drain_continuous_writes_once():
    class CountingOut(io.StringIO):
        calls = 0
        def write(self, s):
            CountingOut.calls += 1
            return super().write(s)

    eng = SDE()
    assert eng.handle(_build(continuous=True)).ok
    for k in range(3):
        eng.ingest(np.array([1, 2], np.uint32), np.ones(2, np.float32))
    eng.flush()                          # retire under SDE_PIPELINED=1 too
    assert len(eng.continuous_out) == 3
    out = CountingOut()
    n = sde_server._drain_continuous(eng, out)
    assert n == 3 and CountingOut.calls == 1
    assert len(out.getvalue().splitlines()) == 3
    assert len(eng.continuous_out) == 0
