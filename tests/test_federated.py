"""Real-collective federation: per-kind merge-equivalence test matrix.

PR 5 contract:
  * ``federated.merge_over_axis`` over an N-way axis equals the host-side
    responsible-site fold (``merge_reduce`` — the legacy oracle) bit-for-
    bit for EVERY registered kind, with sum/max/gather/fresh all
    exercised. Validated in-process with vmap(axis_name=...) collectives
    (psum/pmax/all_gather/axis_index work on one device under vmap) and
    on a real 8-device mesh in a subprocess.
  * the ``merge_mode == "fresh"`` branch performs the documented
    keep-max-count replica selection (DFT), ties to the lowest site.
  * ``Federation(mesh=...)`` answers ``query_federated`` as ONE compiled
    collective program (TRACE/DISPATCH probes on
    ``kernels.ops.estimate_collective``) byte-identical to the legacy
    host-merge Federation oracle, with collective operand bytes <=
    host-merge shipped bytes (fig 5d).
  * hypothesis properties: site merging is order-insensitive for sum/max
    kinds, and the mesh path equals the host oracle on random
    builds/ingests.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import pytest

from repro import core
from repro.core import federated
from repro.kernels import ops as kops
from repro.service import Federation

N_SITES = 4

_PARAMS = {
    "countmin": {"eps": 0.05, "delta": 0.1, "weighted": False},
    "hyperloglog": {"rse": 0.05},
    "ams": {"eps": 0.2, "delta": 0.2},
    "bloom": {"n_elements": 256, "fpr": 0.02},
    "fm": {"nmaps": 16},
    "dft": {"window": 16, "n_coeffs": 4},
    "rhp": {"n_bits": 32},
    "lossy_counting": {"eps": 0.05},
    "sticky_sampling": {},
    "chain_sampler": {"sample_size": 16},
    "gk_quantiles": {"eps": 0.05},
    "coreset_tree": {"bucket_size": 32, "dim": 1},
}

# per-kind federated query args (kinds not listed take no args)
_QUERY = {
    "countmin": {"items": [3, 7, 11]},
    "bloom": {"items": [3, 7, 11]},
    "lossy_counting": {"items": [3, 7, 11]},
    "sticky_sampling": {"items": [3, 7, 11]},
    "gk_quantiles": {"qs": [0.25, 0.5, 0.75]},
}


def _feed(kind, items, values):
    """One site's partial state. Values are INTEGER-valued floats so sum
    merges are exact in float32 regardless of reduction order — the
    bit-for-bit comparisons below rely on it."""
    items = np.asarray(items, np.uint32)
    values = np.asarray(values, np.float32)
    return jax.jit(kind.add_batch)(kind.init(None), items, values,
                                   np.ones(len(items), bool))


def _site_states(kind_name, kind, n_sites=N_SITES, seed=7):
    rng = np.random.RandomState(seed)
    states = []
    for s in range(n_sites):
        if kind_name == "dft":
            # different tick counts per site => fresh-mode selection real
            n = 5 + 3 * s
            states.append(_feed(kind, np.zeros(n), rng.randint(-5, 6, n)))
        else:
            states.append(_feed(kind, rng.randint(0, 300, 32),
                                rng.randint(1, 5, 32)))
    return states


def _tree_equal(a, b):
    """BYTE-level tree equality: assert_array_equal alone treats
    -0.0 == +0.0, which would hide a merge path flipping zero signs."""
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        x, y = np.asarray(x), np.asarray(y)
        np.testing.assert_array_equal(x, y)
        assert x.tobytes() == y.tobytes(), (x, y)


def _vmap_merge(kind, states):
    """merge_over_axis under vmap-with-axis-name: the collective
    semantics (psum/pmax/all_gather/axis_index over the mapped axis) on
    one device — every output row is one shard's view of the merge."""
    return jax.jit(jax.vmap(
        lambda s: federated.merge_over_axis(kind, s, "site"),
        axis_name="site"))(federated.stack_states(states))


# ---------------------------------------------------------------------------
# the matrix: merge_over_axis == host responsible-site fold, per kind
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind_name", sorted(core.known_kinds()))
def test_merge_over_axis_matches_host_fold(kind_name):
    kind = core.make_kind(kind_name, **_PARAMS[kind_name])
    states = _site_states(kind_name, kind)
    oracle = federated.merge_reduce(kind, federated.stack_states(states))
    merged = _vmap_merge(kind, states)
    merged = jax.tree.map(np.asarray, merged)
    oracle = jax.tree.map(np.asarray, oracle)
    # every shard of the axis holds the SAME merged state, and it is
    # byte-identical to the host fold the legacy Federation runs
    for r in range(N_SITES):
        _tree_equal(jax.tree.map(lambda x: x[r], merged), oracle)
    mode = getattr(kind, "merge_mode", "gather")
    if mode != "gather":
        # psum/pmax/fresh additionally match the plain sequential fold
        # (gather kinds legitimately depend on fold shape; both paths use
        # the same pairwise tree, asserted above)
        seq = states[0]
        for s in states[1:]:
            seq = kind.merge(seq, s)
        _tree_equal(oracle, jax.tree.map(np.asarray, seq))


@pytest.mark.smoke
def test_merge_over_axis_smoke_sum_and_max():
    for kind_name in ("countmin", "hyperloglog"):
        test_merge_over_axis_matches_host_fold(kind_name)


# ---------------------------------------------------------------------------
# fresh mode: keep-max-count replica selection (DFT)
# ---------------------------------------------------------------------------
def test_fresh_merge_keeps_max_count_replica():
    kind = core.DFT(window=8, n_coeffs=2)
    rng = np.random.RandomState(3)
    ticks = [3, 9, 5, 7]                   # site 1 is freshest
    states = [_feed(kind, np.zeros(n), rng.randint(-4, 5, n))
              for n in ticks]
    merged = jax.tree.map(lambda x: x[0], _vmap_merge(kind, states))
    # the selected replica IS site 1's state, bit for bit — exchanged,
    # not reduced
    _tree_equal(merged, states[1])
    assert int(np.asarray(merged["count"])) == 9


def test_fresh_merge_tie_keeps_lowest_site():
    kind = core.DFT(window=8, n_coeffs=2)
    rng = np.random.RandomState(4)
    states = [_feed(kind, np.zeros(n), rng.randint(-4, 5, n))
              for n in (6, 6, 2)]
    merged = jax.tree.map(lambda x: x[0], _vmap_merge(kind, states))
    _tree_equal(merged, states[0])         # first max wins, like the fold
    seq = states[0]
    for s in states[1:]:
        seq = kind.merge(seq, s)
    _tree_equal(merged, seq)


def test_fresh_merge_tie_across_tree_bracket_boundary():
    """Regression: counts [5, 9, 9, 5] tie the max ACROSS the pairwise
    tree's halving boundary. A tournament of the keep-strictly-fresher
    ``merge`` would crown site 2 (bracket position), while the
    sequential fold and the collective argmax crown site 1 — so fresh
    stacks must be SELECTED, keeping collective, ``merge_reduce`` and
    the sequential fold byte-identical."""
    kind = core.DFT(window=8, n_coeffs=2)
    rng = np.random.RandomState(5)
    states = [_feed(kind, np.zeros(n), rng.randint(-4, 5, n))
              for n in (5, 9, 9, 5)]
    seq = states[0]
    for s in states[1:]:
        seq = kind.merge(seq, s)
    tree_fold = federated.merge_reduce(kind,
                                       federated.stack_states(states))
    merged = jax.tree.map(lambda x: x[0], _vmap_merge(kind, states))
    _tree_equal(merged, states[1])
    _tree_equal(tree_fold, states[1])
    _tree_equal(seq, states[1])


def test_fresh_merge_preserves_negative_zero_bytes():
    """Regression: the winner broadcast is a masked psum; losers must
    contribute -0.0 (not +0.0) for float leaves, or a -0.0 slot in the
    winning replica's ring would come back as +0.0 — a byte-level
    divergence from the host fold."""
    kind = core.DFT(window=4, n_coeffs=2)
    states = [
        _feed(kind, np.zeros(2), np.array([1.0, 2.0])),
        _feed(kind, np.zeros(3), np.array([-0.0, 3.0, -0.0])),  # winner
    ]
    assert np.signbit(np.asarray(states[1]["ring"])).any()
    merged = jax.tree.map(lambda x: x[0], _vmap_merge(kind, states))
    _tree_equal(merged, states[1])
    np.testing.assert_array_equal(np.signbit(np.asarray(merged["ring"])),
                                  np.signbit(np.asarray(
                                      states[1]["ring"])))


def test_estimate_over_axis_matches_merged_estimate():
    kind = core.HyperLogLog(rse=0.05)
    states = _site_states("hyperloglog", kind)
    out = jax.jit(jax.vmap(
        lambda s: federated.estimate_over_axis(kind, s, "site"),
        axis_name="site"))(federated.stack_states(states))
    oracle = kind.estimate(
        federated.merge_reduce(kind, federated.stack_states(states)))
    for r in range(N_SITES):
        assert float(np.asarray(out)[r]) == float(np.asarray(oracle))


# ---------------------------------------------------------------------------
# fig 5d byte accounting: collective operands never exceed host shipping
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind_name", sorted(core.known_kinds()))
def test_collective_operand_bytes_bounded_by_host(kind_name):
    kind = core.make_kind(kind_name, **_PARAMS[kind_name])
    state = kind.init(None)
    per_site = federated.communication_bytes(kind, state)
    for n in (1, 2, 4, 16):
        coll = federated.collective_operand_bytes(kind, state, n)
        assert coll <= n * per_site, (kind_name, n)
    mode = getattr(kind, "merge_mode", "gather")
    if mode in ("sum", "max"):
        # in-network reduction: independent of the site count
        assert federated.collective_operand_bytes(kind, state, 16) \
            == per_site


# ---------------------------------------------------------------------------
# hypothesis properties (skipped when hypothesis is not installed — the
# rest of this module must still run, so no module-level importorskip)
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st, HealthCheck
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

_MULTIDEV = len(jax.devices()) >= 2

_SUM_MAX_KINDS = ("countmin", "ams", "rhp", "hyperloglog", "bloom", "fm")

if _HAVE_HYPOTHESIS:
    _settings = dict(deadline=None, max_examples=15,
                     suppress_health_check=[HealthCheck.too_slow,
                                            HealthCheck.data_too_large])

    _site_batches = st.lists(
        st.lists(st.integers(0, 200), min_size=1, max_size=40),
        min_size=2, max_size=5)

    @pytest.mark.parametrize("kind_name", _SUM_MAX_KINDS)
    @given(data=st.data())
    @settings(**_settings)
    def test_site_merge_order_insensitive(kind_name, data):
        """Commutative/associative site merging: any arrival order of
        the sites' partials folds to the identical state for sum/max
        kinds (integer-valued updates keep float sums exact)."""
        kind = core.make_kind(kind_name, **_PARAMS[kind_name])
        batches = data.draw(_site_batches)
        perm = data.draw(st.permutations(range(len(batches))))
        states = [_feed(kind, b, np.ones(len(b))) for b in batches]

        def fold(ss):
            acc = ss[0]
            for s in ss[1:]:
                acc = kind.merge(acc, s)
            return jax.tree.map(np.asarray, acc)

        _tree_equal(fold(states), fold([states[i] for i in perm]))

    @pytest.mark.skipif(not _MULTIDEV, reason="needs >= 2 devices "
                        "(CI federated job forces 8 host devices)")
    @given(data=st.data())
    @settings(deadline=None, max_examples=10,
              suppress_health_check=[HealthCheck.too_slow,
                                     HealthCheck.data_too_large])
    def test_mesh_query_matches_host_oracle_property(data):
        """query_federated over the mesh path == the legacy host-merge
        Federation oracle, byte-identical, on random builds/ingests.
        Batches are padded to a fixed length (masked) so every example
        reuses the same compiled programs."""
        from repro.launch.mesh import make_federation_mesh
        kind_name = data.draw(st.sampled_from(
            ["countmin", "hyperloglog", "fm", "chain_sampler"]))
        per_site = [data.draw(st.lists(st.integers(0, 10**6),
                                       min_size=1, max_size=64))
                    for _ in range(2)]
        sites = ["eu", "us"]
        fed = Federation(sites, mesh=make_federation_mesh(2))
        oracle = Federation(sites)
        build = {"type": "build", "request_id": "b", "synopsis_id": "g",
                 "kind": kind_name, "params": _PARAMS[kind_name],
                 "federated": True, "responsible_site": "eu"}
        for f in (fed, oracle):
            assert all(r.ok for r in f.broadcast(build).values())
        for name, ids in zip(sites, per_site):
            sids = np.zeros(64, np.int64)
            sids[:len(ids)] = ids
            mask = np.zeros(64, bool)
            mask[:len(ids)] = True
            vals = np.ones(64, np.float32)
            fed.sdes[name].ingest(sids, vals, mask)
            oracle.sdes[name].ingest(sids, vals, mask)
        query = _QUERY.get(kind_name, {})
        got = fed.query_federated("g", query, "eu")
        want = oracle.query_federated("g", query, "eu")
        _tree_equal(got, want)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_site_merge_order_insensitive():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_mesh_query_matches_host_oracle_property():
        pass


# ---------------------------------------------------------------------------
# Federation engine path: metrics, fallbacks, JSON errors
# ---------------------------------------------------------------------------
@pytest.mark.smoke
def test_federated_query_reports_byte_metrics_host_path():
    fed = Federation(["eu", "us"])
    fed.broadcast({"type": "build", "request_id": "b", "synopsis_id": "h",
                   "kind": "hyperloglog", "params": {"rse": 0.03},
                   "federated": True, "responsible_site": "eu"})
    fed.sdes["eu"].ingest(np.arange(500, dtype=np.uint32),
                          np.ones(500, np.float32))
    fed.sdes["us"].ingest(np.arange(300, 800, dtype=np.uint32),
                          np.ones(500, np.float32))
    r = fed.handle({"type": "federated_query", "request_id": "q",
                    "synopsis_id": "h", "responsible_site": "eu"})
    assert r.ok, r.error
    assert r.params["path"] == "host"
    assert r.params["sites"] == 2
    assert r.params["host_merge_bytes"] == fed.query_bytes("h")
    assert r.params["collective_operand_bytes"] \
        == r.params["host_merge_bytes"]
    assert abs(float(r.value) - 800) / 800 < 0.15
    # collective accounting is still quotable off-mesh (pmax: one state)
    assert fed.collective_query_bytes("h") == fed.query_bytes("h") // 2
    # unknown synopsis fails as a Response, not an exception
    r = fed.handle({"type": "federated_query", "request_id": "q2",
                    "synopsis_id": "nope", "responsible_site": "eu"})
    assert not r.ok and "nope" in r.error
    # non-federated requests broadcast as before
    rs = fed.handle({"type": "status", "request_id": "s"})
    assert set(rs) == {"eu", "us"}
    # malformed snippets keep the broadcast {site: Response} shape —
    # per-site error responses, never a bare Response the caller's
    # dict-iteration would trip over
    rs = fed.handle({"type": "status", "request_id": "s", "bogus": 1})
    assert set(rs) == {"eu", "us"}
    assert all(not r.ok and "bogus" in r.error for r in rs.values())


@pytest.mark.skipif(not _MULTIDEV, reason="needs >= 2 devices")
def test_mesh_partial_coverage_falls_back_to_host():
    from repro.launch.mesh import make_federation_mesh
    fed = Federation(["eu", "us"], mesh=make_federation_mesh(2))
    # build on ONE site only: the collective spans the whole axis, so a
    # partial synopsis must take the host-merge fallback
    fed.sdes["eu"].handle({"type": "build", "request_id": "b",
                           "synopsis_id": "h", "kind": "hyperloglog",
                           "params": {"rse": 0.03}})
    fed.sdes["eu"].ingest(np.arange(400, dtype=np.uint32),
                          np.ones(400, np.float32))
    r = fed.handle({"type": "federated_query", "request_id": "q",
                    "synopsis_id": "h", "responsible_site": "eu"})
    assert r.ok, r.error
    assert r.params["path"] == "host" and r.params["sites"] == 1
    assert abs(float(r.value) - 400) / 400 < 0.15


@pytest.mark.skipif(not _MULTIDEV, reason="needs >= 2 devices")
def test_mesh_collective_one_dispatch_and_metrics():
    from repro.launch.mesh import make_federation_mesh
    fed = Federation(["eu", "us"], mesh=make_federation_mesh(2))
    oracle = Federation(["eu", "us"])
    build = {"type": "build", "request_id": "b", "synopsis_id": "cm",
             "kind": "countmin",
             "params": {"eps": 0.0213, "delta": 0.1, "weighted": False},
             "federated": True, "responsible_site": "eu"}
    for f in (fed, oracle):
        assert all(r.ok for r in f.broadcast(build).values())
    rng = np.random.RandomState(0)
    for name in ("eu", "us"):
        sids = rng.randint(0, 50, 256).astype(np.uint32)
        for f in (fed, oracle):
            f.sdes[name].ingest(sids.copy(), np.ones(256, np.float32))
    want = oracle.query_federated("cm", {"items": [1, 2, 3]}, "eu")
    kops.DISPATCH_COUNT.clear()
    kops.TRACE_COUNT.clear()
    for _ in range(3):
        r = fed.handle({"type": "federated_query", "request_id": "q",
                        "synopsis_id": "cm", "query": {"items": [1, 2, 3]},
                        "responsible_site": "eu"})
        assert r.ok, r.error
        np.testing.assert_array_equal(np.asarray(r.value),
                                      np.asarray(want))
    # merge + estimate fused into ONE collective program per query...
    assert kops.DISPATCH_COUNT["CountMin"] == 3
    # ... and repeated queries reuse ONE compiled program
    assert kops.TRACE_COUNT["CountMin"] == 1
    assert r.params["path"] == "collective"
    # CM is a linear sketch: the psum combines in-network, so the
    # collective ships ONE state regardless of the site count
    assert r.params["collective_operand_bytes"] \
        == r.params["host_merge_bytes"] // 2
    assert fed.collective_query_bytes("cm") \
        == r.params["collective_operand_bytes"]


# ---------------------------------------------------------------------------
# the full per-kind matrix on a REAL 8-device mesh (4-site federation,
# every registered kind, byte-identical vs the host oracle + probes)
# ---------------------------------------------------------------------------
_MESH_MATRIX_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np, jax
    from repro import core
    from repro.core import federated
    from repro.kernels import ops as kops
    from repro.launch.mesh import make_federation_mesh
    from repro.service import Federation

    PARAMS = %s
    QUERY = %s
    N_SITES = 4
    sites = [f"s{i}" for i in range(N_SITES)]
    rng = np.random.RandomState(11)
    for kind_name in sorted(core.known_kinds()):
        fed = Federation(sites, mesh=make_federation_mesh(N_SITES))
        oracle = Federation(sites)
        build = {"type": "build", "request_id": "b", "synopsis_id": "g",
                 "kind": kind_name, "params": PARAMS[kind_name],
                 "federated": True, "responsible_site": sites[0]}
        if kind_name == "dft":
            build["stream_id"] = 0       # time-series kinds are routed
        for f in (fed, oracle):
            assert all(r.ok for r in f.broadcast(build).values()), kind_name
        for i, name in enumerate(sites):
            if kind_name == "dft":
                # one tick per ingest batch; different counts per site
                for v in rng.randint(-5, 6, 4 + 2 * i):
                    for f in (fed, oracle):
                        f.sdes[name].ingest(np.zeros(1, np.int64),
                                            np.full(1, v, np.float32))
            else:
                sids = rng.randint(i * 100, i * 100 + 90, 32)
                vals = rng.randint(1, 5, 32).astype(np.float32)
                for f in (fed, oracle):
                    f.sdes[name].ingest(sids.astype(np.int64).copy(),
                                        vals.copy())
        q = QUERY.get(kind_name, {})
        want = oracle.query_federated("g", q, sites[0])
        kops.DISPATCH_COUNT.clear()
        kops.TRACE_COUNT.clear()
        pname = type(core.make_kind(kind_name,
                                    **PARAMS[kind_name])).__name__
        for rep in range(2):
            r = fed.handle({"type": "federated_query", "request_id": "q",
                            "synopsis_id": "g", "query": q,
                            "responsible_site": sites[0]})
            assert r.ok, (kind_name, r.error)
            for a, b in zip(jax.tree.leaves(r.value),
                            jax.tree.leaves(want)):
                np.testing.assert_array_equal(np.asarray(a),
                                              np.asarray(b),
                                              err_msg=kind_name)
        assert r.params["path"] == "collective", kind_name
        assert kops.DISPATCH_COUNT[pname] == 2, (kind_name,
                                                 kops.DISPATCH_COUNT)
        assert kops.TRACE_COUNT[pname] == 1, (kind_name,
                                              kops.TRACE_COUNT)
        assert r.params["collective_operand_bytes"] \\
            <= r.params["host_merge_bytes"], kind_name
        print(kind_name, "OK")
    print("ALL_OK")
""") % (repr(_PARAMS), repr(_QUERY))


def test_mesh_matrix_all_kinds_byte_identical():
    """Every registered kind, federated over a real 8-device mesh: one
    compiled collective program per query, byte-identical to the legacy
    host-merge oracle, collective bytes <= host bytes (the PR 5
    acceptance criterion end to end)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    out = subprocess.run([sys.executable, "-c", _MESH_MATRIX_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ALL_OK" in out.stdout, out.stdout[-2000:]
