"""Durability: incremental dirty-row snapshots + write-ahead ingest log.

The contract under test (paper Section 4's always-on SDEaaS): an acked
request is recoverable — kill the serving process ANYWHERE and
``recover`` (latest snapshot + WAL tail replay) rebuilds the engine
byte-identically to one that applied the acked stream once, in order.
Incremental (delta) snapshots must restore byte-identical to full ones,
survive migration/compaction in the chain, and land on a different
device mesh; the checkpoint layer must round-trip bf16 NaN payloads,
sweep crashed saves' tmp dirs, serialize concurrent async saves, and
never GC a delta chain's base.
"""
import io
import json
import os
import subprocess
import sys
import textwrap
import threading
import time

import jax
import numpy as np
import pytest

from repro.service import (SDE, Checkpointer, WriteAheadLog, recover,
                           replay)
from repro.service.wal import read_records
from repro.training import checkpoint as ckpt

_CM = {"eps": 0.02, "delta": 0.1, "weighted": False}
_DFT = {"window": 16, "n_coeffs": 4}
_N_STREAMS = 20


def _build(eng):
    for req in (
        {"type": "build", "request_id": "b1", "synopsis_id": "cm",
         "kind": "countmin", "params": _CM,
         "per_stream_of_source": True, "n_streams": _N_STREAMS},
        {"type": "build", "request_id": "b2", "synopsis_id": "src",
         "kind": "countmin", "params": _CM},
        {"type": "build", "request_id": "b3", "synopsis_id": "dft",
         "kind": "dft", "params": _DFT,
         "per_stream_of_source": True, "n_streams": 4},
    ):
        r = eng.handle(req)
        assert r.ok, r.error


def _batch(rng, n=64):
    """Integer-valued routed traffic (exact float32 sums — the byte
    comparisons rely on it)."""
    return (rng.randint(0, _N_STREAMS, n).astype(np.int64),
            rng.randint(1, 5, n).astype(np.float32))


def _assert_engines_equal(a: SDE, b: SDE):
    """FULL byte equality: stack state, allocation, routing layout,
    registry and counters."""
    assert list(a.stacks) == list(b.stacks)
    for kind in a.stacks:
        sa, sb = a.stacks[kind], b.stacks[kind]
        assert sa.capacity == sb.capacity
        assert list(sa.used) == list(sb.used)
        assert sorted(sa.source_rows) == sorted(sb.source_rows)
        for x, y in zip(jax.tree.leaves(sa.state),
                        jax.tree.leaves(sb.state)):
            x, y = np.asarray(x), np.asarray(y)
            assert x.tobytes() == y.tobytes()
        np.testing.assert_array_equal(sa.table.keys, sb.table.keys)
        np.testing.assert_array_equal(sa.table.rows, sb.table.rows)
        assert sa.table.count == sb.table.count
        assert sa.table.max_probe == sb.table.max_probe
    assert set(a.entries) == set(b.entries)
    for sid in a.entries:
        ea, eb = a.entries[sid], b.entries[sid]
        for f in ("kind_key", "row", "stream_id", "federated",
                  "responsible_site", "continuous", "source_id"):
            assert getattr(ea, f) == getattr(eb, f), (sid, f)
    assert a.batches_ingested == b.batches_ingested
    assert a.tuples_ingested == b.tuples_ingested
    assert a.wal_seq == b.wal_seq


# ---------------------------------------------------------------------------
# tentpole: incremental restore == full restore, with lifecycle +
# migration + compaction inside the delta chain
# ---------------------------------------------------------------------------
@pytest.mark.smoke
def test_incremental_restore_equals_full(tmp_path):
    rng = np.random.RandomState(0)
    eng = SDE()
    _build(eng)
    d_inc, d_full = str(tmp_path / "inc"), str(tmp_path / "full")

    eng.ingest(*_batch(rng))
    assert eng.snapshot(d_inc, 0, incremental=True) == "full"  # no base
    eng.ingest(*_batch(rng))
    # lifecycle + structural churn INSIDE the chain: stop a synopsis,
    # compact its stack, migrate a row — the deltas must carry all of it
    r = eng.handle({"type": "stop", "request_id": "s",
                    "synopsis_id": "dft/1"})
    assert r.ok, r.error
    dft_kind = eng.entries["dft/0"].kind_key
    eng.compact(dft_kind, min_capacity=2)
    assert eng.snapshot(d_inc, 1, incremental=True) == "delta"
    cm_kind = eng.entries["cm/0"].kind_key
    stack = eng.stacks[cm_kind]
    free = [i for i in range(stack.capacity) if not stack.used[i]]
    if free:
        eng.migrate_rows(cm_kind, {eng.entries["cm/0"].row: free[0]})
    eng.ingest(*_batch(rng))
    assert eng.snapshot(d_inc, 2, incremental=True) == "delta"

    from_chain = SDE.restore(d_inc)          # base 0 + deltas 1, 2
    _assert_engines_equal(from_chain, eng)
    eng.snapshot(d_full, 7)                  # full of the same moment
    from_full = SDE.restore(d_full)
    _assert_engines_equal(from_chain, from_full)
    # a restored engine EXTENDS the chain it was restored from
    from_chain.ingest(*_batch(rng))
    assert from_chain.snapshot(d_inc, 3, incremental=True) == "delta"
    eng.close(), from_chain.close(), from_full.close()


def test_delta_chain_pipelined_and_rebase(tmp_path):
    """Deltas under the pipelined engine (no fence) restore identically,
    and the chain rebases to a fresh full after ``rebase_every``."""
    rng = np.random.RandomState(1)
    eng = SDE(pipelined=True)
    _build(eng)
    d = str(tmp_path / "ck")
    eng.snapshot(d, 0)
    modes = []
    for step in range(1, 5):
        for _ in range(3):
            eng.ingest(*_batch(rng))
        modes.append(eng.snapshot(d, step, incremental=True,
                                  async_=True, rebase_every=3))
    eng.wait_for_snapshot()
    # steps 1..3 extend the chain; the 4th hits rebase_every and folds
    assert modes == ["delta", "delta", "delta", "full"]
    eng.flush()
    back = SDE.restore(d, pipelined=True)
    _assert_engines_equal(back, eng)
    eng.close(), back.close()


# ---------------------------------------------------------------------------
# tentpole: kill -9 anywhere, recover byte-identically (exactly-once)
# ---------------------------------------------------------------------------
_SERVER_SCRIPT = textwrap.dedent("""
    import json, os, sys, time
    import numpy as np
    from repro.service import SDE, WriteAheadLog, Checkpointer
    from repro.launch import sde_server

    wal_path, ck_dir, pipelined = (
        sys.argv[1], sys.argv[2], sys.argv[3] == "1")
    sde = SDE(pipelined=pipelined)
    wal = WriteAheadLog(wal_path, tag=sde.site)
    ckp = Checkpointer(sde, ck_dir, interval=3, keep=2, rebase_every=4)
    rng = np.random.RandomState(7)
    reqs = [
        {"type": "build", "request_id": "b1", "synopsis_id": "cm",
         "kind": "countmin",
         "params": {"eps": 0.02, "delta": 0.1, "weighted": False},
         "per_stream_of_source": True, "n_streams": 20},
        {"type": "build", "request_id": "b2", "synopsis_id": "src",
         "kind": "countmin",
         "params": {"eps": 0.02, "delta": 0.1, "weighted": False}},
    ]
    for i in range(40):
        sids = rng.randint(0, 20, 48)
        vals = rng.randint(1, 5, 48)
        reqs.append({"type": "ingest", "request_id": f"i{i}",
                     "stream_ids": [int(s) for s in sids],
                     "values": [float(v) for v in vals]})
    devnull = open(os.devnull, "w")
    for i, req in enumerate(reqs):
        sde_server.serve_lines([json.dumps(req)], sde, out=devnull,
                               wal=wal, checkpointer=ckp)
        print(f"ACK {i}", flush=True)      # durable: wal.sync() ran
    print("DONE", flush=True)
    while True:                            # hold state until SIGKILL
        time.sleep(0.1)
""")


@pytest.mark.parametrize("pipelined", [False, True],
                         ids=["eager", "pipelined"])
def test_sigkill_recovery_byte_identical(tmp_path, pipelined):
    wal_path = str(tmp_path / "ingest.wal")
    ck_dir = str(tmp_path / "ck")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    env.pop("SDE_PIPELINED", None)
    proc = subprocess.Popen(
        [sys.executable, "-c", _SERVER_SCRIPT, wal_path, ck_dir,
         "1" if pipelined else "0"],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
        env=env)
    try:
        # kill mid-stream, between checkpoints (interval=3, acks 2..41
        # are ingest batches): after ACK 17 the engine holds batches the
        # latest snapshot does NOT — recovery must stitch snapshot + tail
        for line in proc.stdout:
            if line.strip() == "ACK 17":
                break
        else:
            pytest.fail(f"server died early: {proc.stderr.read()[-2000:]}")
    finally:
        proc.kill()
        proc.wait(timeout=60)

    assert ckpt.latest_step(ck_dir) is not None   # it did checkpoint
    recovered = recover(ck_dir, wal_path, pipelined=pipelined)
    assert recovered.batches_ingested == 16       # acked ingests exactly
    # the oracle applies the acked stream ONCE, in order, eagerly
    oracle = SDE(pipelined=False)
    replay(oracle, wal_path)
    recovered.flush()
    oracle.flush()
    _assert_engines_equal(recovered, oracle)

    # the recovered server keeps serving: WAL seq resumes, checkpoints
    # extend the existing lineage, and a second recovery still matches
    wal2 = WriteAheadLog(wal_path, tag=recovered.site)
    assert wal2.seq == recovered.wal_seq
    ckp2 = Checkpointer(recovered, ck_dir, interval=3, keep=2,
                        rebase_every=4)
    rng = np.random.RandomState(99)
    for i in range(4):
        sids, vals = _batch(rng, 48)
        wal2.append_ingest(recovered.batches_ingested + 1, sids, vals)
        wal2.sync()
        recovered.ingest(sids, vals)
        recovered.wal_seq = wal2.seq
        ckp2.maybe_snapshot()
        oracle.ingest(sids, vals)
        oracle.wal_seq = wal2.seq
    wal2.close()
    recovered.wait_for_snapshot()
    recovered.flush()
    again = recover(ck_dir, wal_path, pipelined=False)
    oracle.flush()
    _assert_engines_equal(again, oracle)
    _assert_engines_equal(recovered, oracle)
    recovered.close(), oracle.close(), again.close()


# ---------------------------------------------------------------------------
# delta chain restores onto a DIFFERENT device mesh (elastic restart)
# ---------------------------------------------------------------------------
_MESH_SCRIPT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    from jax.sharding import NamedSharding
    from repro.service import SDE

    rng = np.random.RandomState(0)
    eng = SDE()          # chain written WITHOUT a mesh (1-device layout)
    eng.handle({"type": "build", "request_id": "b", "synopsis_id": "cm",
                "kind": "countmin",
                "params": {"eps": 0.02, "delta": 0.1, "weighted": False},
                "per_stream_of_source": True, "n_streams": 24})
    sids = rng.randint(0, 24, 512).astype(np.int64)
    eng.ingest(sids, np.ones(512, np.float32))
    d = tempfile.mkdtemp()
    eng.snapshot(d, 0)
    sids2 = rng.randint(0, 24, 512).astype(np.int64)
    eng.ingest(sids2, np.ones(512, np.float32))
    assert eng.snapshot(d, 1, incremental=True) == "delta"

    mesh = jax.make_mesh((8, 1), ("data", "model"))
    eng2 = SDE.restore(d, mesh=mesh)     # base + delta, repartitioned
    stack = next(iter(eng2.stacks.values()))
    for leaf in jax.tree.leaves(stack.state):
        assert isinstance(leaf.sharding, NamedSharding)
        assert leaf.sharding.spec and leaf.sharding.spec[0] == "data"
    q = eng2.handle({"type": "adhoc", "request_id": "q",
                     "synopsis_id": "cm/5", "query": {"items": [5]}})
    want = float((sids == 5).sum() + (sids2 == 5).sum())
    assert float(q.value[0]) == want, (q.value, want)
    print("OK")
""")


def test_delta_restore_onto_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    out = subprocess.run([sys.executable, "-c", _MESH_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


# ---------------------------------------------------------------------------
# WAL semantics: idempotent replay, torn tails, interior corruption
# ---------------------------------------------------------------------------
def test_wal_replay_idempotent(tmp_path):
    path = str(tmp_path / "w.wal")
    rng = np.random.RandomState(3)
    live = SDE()
    wal = WriteAheadLog(path)
    for req in ({"type": "build", "request_id": "b", "synopsis_id": "cm",
                 "kind": "countmin", "params": _CM,
                 "per_stream_of_source": True, "n_streams": _N_STREAMS},):
        wal.append_request(req)
        assert live.handle(req).ok
        live.wal_seq = wal.seq
    batches = [_batch(rng, 32) for _ in range(5)]
    for sids, vals in batches:
        wal.append_ingest(live.batches_ingested + 1, sids, vals)
        live.ingest(sids, vals)
        live.wal_seq = wal.seq
    wal.close()

    fresh = SDE()
    assert replay(fresh, path) == 6
    _assert_engines_equal(fresh, live)
    assert replay(fresh, path) == 0          # idempotent: second pass
    _assert_engines_equal(fresh, live)

    # overlapping tail: the file grows a duplicate of its last 3 records
    # (same seqs — two writers raced into one log); still exactly-once
    with open(path) as f:
        lines = [ln for ln in f.read().split("\n") if ln]
    with open(path, "a") as f:
        f.write("\n".join(lines[-3:]) + "\n")
    assert replay(fresh, path) == 0
    _assert_engines_equal(fresh, live)
    live.close(), fresh.close()


@pytest.mark.smoke
def test_wal_torn_tail_tolerated_interior_raises(tmp_path):
    path = str(tmp_path / "w.wal")
    wal = WriteAheadLog(path)
    wal.append_ingest(1, [3, 3], [1.0, 2.0])
    wal.append_ingest(2, [4], [1.0], mask=[True])
    wal.close()
    with open(path, "a") as f:
        f.write('{"seq": 3, "kind": "ing')    # crash mid-append
    recs = list(read_records(path))
    assert [r["seq"] for r in recs] == [1, 2]  # torn tail dropped
    eng = SDE()
    eng.handle({"type": "build", "request_id": "b", "synopsis_id": "cm",
                "kind": "countmin", "params": _CM,
                "per_stream_of_source": True, "n_streams": 5})
    assert replay(eng, path) == 2
    assert eng.batches_ingested == 2
    # a reopened WAL resumes numbering past everything readable
    wal2 = WriteAheadLog(path)
    assert wal2.seq == 2
    wal2.close()
    # interior corruption is NOT a torn append: it must raise
    with open(path) as f:
        lines = f.read().split("\n")
    lines.insert(1, '{"seq": broken')
    with open(path, "w") as f:
        f.write("\n".join(lines))
    with pytest.raises(ValueError, match="corrupt WAL record"):
        list(read_records(path))         # generator: consume to detect
    eng.close()


def test_wal_read_records_streams(tmp_path):
    """``read_records`` is a lazy generator: records ahead of an
    interior corruption still stream out one at a time, and the raise
    fires exactly when iteration crosses the corrupt line — never at
    open. A replay over a huge log holds one record, not the list."""
    import types
    path = str(tmp_path / "w.wal")
    wal = WriteAheadLog(path)
    for i in range(4):
        wal.append_ingest(i + 1, [i], [1.0])
    wal.close()
    it = read_records(path)
    assert isinstance(it, types.GeneratorType)
    assert next(it)["seq"] == 1          # lazy: nothing else parsed yet
    # corrupt record 3 of 4 — the good prefix must still stream
    with open(path) as f:
        lines = [ln for ln in f.read().split("\n") if ln]
    lines[2] = '{"seq": broken'
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    it = read_records(path)
    assert [next(it)["seq"], next(it)["seq"]] == [1, 2]
    with pytest.raises(ValueError, match="corrupt WAL record"):
        next(it)
    # the SAME bad line as the final line is a torn append: dropped
    with open(path, "w") as f:
        f.write("\n".join(lines[:2] + [lines[2]]) + "\n")
    assert [r["seq"] for r in read_records(path)] == [1, 2]


def test_wal_recovers_multidim_ingest_and_workflows(tmp_path):
    """Crash-recovery of the multidim plane: ``build_multidim`` /
    ``track_outliers`` replay as lifecycle requests (pre-apply records)
    and ``ingest_multidim`` as post-apply data records keyed by batch
    id — the recovered engine answers the same subpop query and keeps
    the workflow tracked."""
    import io

    from repro.launch import sde_server
    path = str(tmp_path / "w.wal")
    wal = WriteAheadLog(path)
    dims = {"region": ["EU", "US"], "platform": ["web", "mobile"]}
    recs = [{"region": "EU", "platform": "web"},
            {"region": "US", "platform": "mobile"},
            {"region": "EU", "platform": "mobile"}]
    reqs = [
        {"type": "build_multidim", "request_id": "b", "synopsis_id": "md",
         "kind": "countmin", "params": _CM, "dims": dims},
        {"type": "track_outliers", "request_id": "t", "workflow_id": "w",
         "synopsis_id": "md", "level": ["region"],
         "query": {"items": [5]}},
        {"type": "ingest_multidim", "request_id": "i", "synopsis_id":
         "md", "records": recs, "values": [1.0, 2.0, 3.0],
         "items": [5, 5, 5]},
    ]
    sde = SDE()
    out = io.StringIO()
    sde_server.serve_lines([json.dumps(r) for r in reqs], sde,
                           out=out, wal=wal)
    assert all(json.loads(ln)["ok"]
               for ln in out.getvalue().splitlines()
               if json.loads(ln).get("request_id"))
    wal.close()
    kinds = [r.get("kind") for r in read_records(path)]
    assert kinds == ["req", "req", "ingest_md"]
    recovered = recover(None, path)
    sde.flush()
    _assert_engines_equal(recovered, sde)
    assert recovered.multidim["md"] == sde.multidim["md"]
    assert "w" in recovered.outliers
    q = {"type": "subpop_query", "request_id": "q", "synopsis_id": "md",
         "where": {"region": "EU"}, "query": {"items": [5]}}
    np.testing.assert_allclose(np.asarray(recovered.handle(q).value),
                               np.asarray(sde.handle(q).value))
    # replay is idempotent: a second pass applies nothing
    assert replay(recovered, path) == 0
    sde.close(), recovered.close()


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                          # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    _batch_st = st.lists(
        st.tuples(st.lists(st.integers(0, 7), min_size=1, max_size=12),
                  st.integers(1, 4)),
        min_size=1, max_size=6)

    @given(batches=_batch_st, dup_tail=st.integers(0, 6),
           extra_passes=st.integers(1, 3))
    @settings(deadline=None, max_examples=15,
              suppress_health_check=[HealthCheck.too_slow])
    def test_wal_replay_idempotence_property(tmp_path_factory, batches,
                                             dup_tail, extra_passes):
        """Replaying a WAL any number of times, with any duplicated
        tail appended, equals applying the acked stream exactly once."""
        tmp = tmp_path_factory.mktemp("wal")
        path = str(tmp / "w.wal")
        build = {"type": "build", "request_id": "b", "synopsis_id":
                 "cm", "kind": "countmin", "params": _CM,
                 "per_stream_of_source": True, "n_streams": 8}
        live = SDE()
        wal = WriteAheadLog(path)
        wal.append_request(build)
        assert live.handle(build).ok
        live.wal_seq = wal.seq
        for sids, val in batches:
            a = np.asarray(sids, np.int64)
            v = np.full(a.size, val, np.float32)
            wal.append_ingest(live.batches_ingested + 1, a, v)
            live.ingest(a, v)
            live.wal_seq = wal.seq
        wal.close()
        with open(path) as f:
            lines = [ln for ln in f.read().split("\n") if ln]
        if dup_tail:
            with open(path, "a") as f:
                f.write("\n".join(lines[-dup_tail:]) + "\n")
        fresh = SDE()
        replay(fresh, path)
        for _ in range(extra_passes - 1):
            assert replay(fresh, path) == 0
        _assert_engines_equal(fresh, live)
        live.close()
        fresh.close()


# ---------------------------------------------------------------------------
# checkpoint layer: bf16 bit-exactness, tmp sweep, async serialization,
# lineage-aware GC, keep= plumbing
# ---------------------------------------------------------------------------
@pytest.mark.smoke
def test_bf16_checkpoint_byte_identical(tmp_path):
    """bf16 leaves round-trip as bit patterns — including NaN payloads
    a float32 widening round trip would canonicalize."""
    bits = np.array([0x7FC1, 0x7F81, 0xFFC0, 0x8000, 0x0001, 0x3F80],
                    np.uint16)
    arr = jax.numpy.asarray(bits.view(jax.numpy.bfloat16.dtype))
    state = {"w": arr, "f": jax.numpy.arange(4, dtype=jax.numpy.float32)}
    d = str(tmp_path / "ck")
    ckpt.save(state, d, 0)
    back, man = ckpt.restore(state, d)
    assert man["leaf_dtypes"] == {"w": "bfloat16"}
    got = np.asarray(jax.device_get(back["w"])).view(np.uint16)
    np.testing.assert_array_equal(got, bits)     # BIT equality
    np.testing.assert_array_equal(np.asarray(back["f"]),
                                  np.asarray(state["f"]))
    # and the stored file really holds uint16, not widened f32
    blob = np.load(os.path.join(d, "step-00000000", "leaves.npz"))
    assert blob["w"].dtype == np.uint16


def test_stale_tmp_dirs_swept(tmp_path):
    d = str(tmp_path / "ck")
    os.makedirs(d)
    stale = os.path.join(d, "tmp-5-99999999")   # pid past pid_max: dead
    mine = os.path.join(d, f"tmp-6-{os.getpid()}")
    os.makedirs(stale)
    os.makedirs(mine)
    ckpt.save({"x": np.arange(3)}, d, 7)
    assert not os.path.exists(stale)      # dead pid: swept
    assert os.path.exists(mine)           # live pid: left alone
    assert ckpt.latest_step(d) == 7


def test_concurrent_async_saves_serialize(tmp_path):
    d = str(tmp_path / "ck")
    for step in range(4):
        ckpt.save({"x": np.full(1 << 16, step, np.int32)}, d, step,
                  keep=2, async_=True)
    ckpt.wait(d)
    assert ckpt.latest_step(d) == 3
    back, man = ckpt.restore({"x": np.zeros(1 << 16, np.int32)}, d)
    assert man["step"] == 3
    assert int(np.asarray(back["x"])[0]) == 3
    steps = sorted(p for p in os.listdir(d) if p.startswith("step-"))
    assert len(steps) == 2                # keep= plumbed through
    assert not [p for p in os.listdir(d) if p.startswith("tmp-")]


def test_gc_never_collects_delta_lineage(tmp_path):
    """keep=2 with a 4-delta chain: the base and interior deltas are
    outside the keep window but referenced by kept manifests — GC must
    leave the whole chain restorable."""
    rng = np.random.RandomState(5)
    eng = SDE()
    _build(eng)
    d = str(tmp_path / "ck")
    eng.snapshot(d, 0, keep=2)
    for step in range(1, 5):
        eng.ingest(*_batch(rng))
        assert eng.snapshot(d, step, incremental=True, keep=2,
                            rebase_every=10) == "delta"
    names = sorted(p for p in os.listdir(d) if p.startswith("step-"))
    assert names == [f"step-{s:08d}" for s in range(5)]  # all protected
    back = SDE.restore(d)                 # latest delta needs ALL of them
    _assert_engines_equal(back, eng)
    eng.close(), back.close()


# ---------------------------------------------------------------------------
# acked => recoverable, even against malformed requests: a refused
# ingest never reaches the WAL (logged post-apply), replay tolerates
# pre-fix poisoned records, and the log is truncated behind snapshots
# ---------------------------------------------------------------------------
def test_malformed_ingest_never_poisons_wal(tmp_path):
    """An ingest the engine refuses (length mismatch, non-numeric
    values) is acked with an error, serving continues, NOTHING lands in
    the WAL, and recovery replays exactly the acked batches — the batch
    id the bad request would have stolen goes to the next good one."""
    from repro.launch import sde_server
    path = str(tmp_path / "w.wal")
    sde = SDE()
    wal = WriteAheadLog(path)
    out = io.StringIO()
    reqs = [
        {"type": "build", "request_id": "b", "synopsis_id": "cm",
         "kind": "countmin", "params": _CM,
         "per_stream_of_source": True, "n_streams": _N_STREAMS},
        {"type": "ingest", "request_id": "good1",
         "stream_ids": [1, 2], "values": [1.0, 2.0]},
        {"type": "ingest", "request_id": "bad-mismatch",
         "stream_ids": [1, 2, 3], "values": [1.0]},
        {"type": "ingest", "request_id": "bad-values",
         "stream_ids": [1], "values": ["not-a-number"]},
        {"type": "ingest", "request_id": "good2",
         "stream_ids": [3, 4], "values": [3.0, 4.0]},
    ]
    n = sde_server.serve_lines([json.dumps(r) for r in reqs], sde,
                               out=out, wal=wal)
    assert n == len(reqs)            # serving survived the bad batches
    by_id = {r["request_id"]: r
             for r in map(json.loads, out.getvalue().splitlines())
             if r.get("request_id")}
    assert by_id["good1"]["ok"] and by_id["good2"]["ok"]
    assert not by_id["bad-mismatch"]["ok"]
    assert not by_id["bad-values"]["ok"]
    assert sde.batches_ingested == 2
    wal.close()
    ingests = [r for r in read_records(path) if r["kind"] == "ingest"]
    assert [r["batch"] for r in ingests] == [1, 2]   # acked ids only
    recovered = recover(None, path)
    sde.flush()
    _assert_engines_equal(recovered, sde)
    sde.close(), recovered.close()


def test_gateway_malformed_ingest_never_poisons_wal(tmp_path):
    """Same contract through the micro-batching gateway: the coalesced
    tick logs post-apply, so a tick whose every part is malformed adds
    nothing to the WAL."""
    import asyncio
    from repro.service.gateway import SynopsisGateway
    path = str(tmp_path / "w.wal")
    wal = WriteAheadLog(path)
    gw = SynopsisGateway(SDE(), wal=wal)

    async def drive():
        await gw.start()
        c = gw.connect("c")
        ok = await gw.submit(c, {
            "type": "build", "request_id": "b", "synopsis_id": "cm",
            "kind": "countmin", "params": _CM,
            "per_stream_of_source": True, "n_streams": _N_STREAMS})
        assert ok.ok, ok.error
        bad = await gw.submit(c, {"type": "ingest", "request_id": "x",
                                  "stream_ids": [1, 2], "values": [1.0]})
        assert not bad.ok
        good = await gw.submit(c, {"type": "ingest", "request_id": "g",
                                   "stream_ids": [1], "values": [2.0]})
        assert good.ok and good.value["batch"] == 1
        await gw.stop()

    asyncio.run(drive())
    wal.close()
    ingests = [r for r in read_records(path) if r["kind"] == "ingest"]
    assert [r["batch"] for r in ingests] == [1]
    recovered = recover(None, path)
    assert recovered.batches_ingested == 1
    recovered.close()


def test_replay_tolerates_poisoned_prefix_record(tmp_path):
    """A pre-fix WAL could hold a record for an ingest that FAILED live
    (it was logged before validation): replay must neither crash on it
    nor let it consume the batch id the next acked batch owns."""
    path = str(tmp_path / "w.wal")
    wal = WriteAheadLog(path)
    wal.append_request(
        {"type": "build", "request_id": "b", "synopsis_id": "cm",
         "kind": "countmin", "params": _CM,
         "per_stream_of_source": True, "n_streams": _N_STREAMS})
    wal.append_ingest(1, [1, 2, 3], [1.0])       # poisoned: mismatch
    wal.append_ingest(1, [5, 5], [1.0, 1.0])     # the REAL acked batch 1
    wal.close()
    eng = SDE()
    assert replay(eng, path) == 2                # build + real batch
    assert eng.batches_ingested == 1
    assert eng.wal_seq == 3                      # cursor passed the poison
    r = eng.handle({"type": "adhoc", "request_id": "q",
                    "synopsis_id": "cm/5", "query": {"items": [5]}})
    assert float(r.value[0]) == 2.0              # acked data not skipped
    eng.close()


def test_wal_truncated_after_durable_snapshot(tmp_path):
    """The Checkpointer drops WAL records folded into a snapshot that
    durably landed, so the log stops growing without bound — and a
    reopened WAL resumes its numbering past the dropped records instead
    of reusing seqs replay would then skip."""
    from repro.launch import sde_server
    path = str(tmp_path / "w.wal")
    d = str(tmp_path / "ck")
    sde = SDE()
    wal = WriteAheadLog(path)
    ckp = Checkpointer(sde, d, interval=2, keep=2, rebase_every=3,
                       wal=wal)
    rng = np.random.RandomState(21)
    reqs = [{"type": "build", "request_id": "b", "synopsis_id": "cm",
             "kind": "countmin", "params": _CM,
             "per_stream_of_source": True, "n_streams": _N_STREAMS}]
    for i in range(12):
        sids, vals = _batch(rng, 24)
        reqs.append({"type": "ingest", "request_id": f"i{i}",
                     "stream_ids": [int(s) for s in sids],
                     "values": [float(v) for v in vals]})
    sde_server.serve_lines([json.dumps(r) for r in reqs], sde,
                           out=io.StringIO(), wal=wal, checkpointer=ckp)
    recs = list(read_records(path))
    assert any(r.get("kind") == "trunc" for r in recs)
    assert len([r for r in recs if r.get("kind") == "ingest"]) < 12
    sde.wait_for_snapshot()
    recovered = recover(d, path)
    sde.flush()
    _assert_engines_equal(recovered, sde)
    wal.close()
    wal2 = WriteAheadLog(path)               # numbering survives rotation
    assert wal2.seq == sde.wal_seq == 13
    wal2.close()
    sde.close(), recovered.close()


# ---------------------------------------------------------------------------
# checkpoint layer: failed background saves surface and force a fresh
# full base; concurrent saves hold the per-directory lock; tmp age-out
# ---------------------------------------------------------------------------
def test_failed_async_save_forces_full_rebase(tmp_path, monkeypatch):
    """A background delta write that dies (disk full) must not chain:
    the next snapshot detects it, drops the broken lineage and takes a
    FULL base that re-ships the rows the failed delta cleared."""
    rng = np.random.RandomState(11)
    eng = SDE()
    _build(eng)
    d = str(tmp_path / "ck")
    assert eng.snapshot(d, 0, incremental=True, async_=True) == "full"
    eng.wait_for_snapshot()
    eng.ingest(*_batch(rng))
    real_savez, fail = np.savez, {"on": True}

    def maybe_boom(*a, **k):
        if fail["on"]:
            raise OSError("disk full")
        return real_savez(*a, **k)

    monkeypatch.setattr(np, "savez", maybe_boom)
    assert eng.snapshot(d, 1, incremental=True, async_=True) == "delta"
    eng.wait_for_snapshot()              # failure captured, not raised
    fail["on"] = False
    eng.ingest(*_batch(rng))
    assert eng.snapshot(d, 2, incremental=True, async_=True) == "full"
    assert eng.ckpt_failures == 1
    eng.wait_for_snapshot()
    eng.flush()
    back = SDE.restore(d)                # latest = the recovery full
    _assert_engines_equal(back, eng)
    eng.close(), back.close()


def test_failed_async_save_raises_on_next_save(tmp_path, monkeypatch):
    d = str(tmp_path / "ck")
    real_savez, fail = np.savez, {"on": True}

    def maybe_boom(*a, **k):
        if fail["on"]:
            raise OSError("disk full")
        return real_savez(*a, **k)

    monkeypatch.setattr(np, "savez", maybe_boom)
    t = ckpt.save({"x": np.arange(3)}, d, 0, async_=True)
    t.join()
    fail["on"] = False
    with pytest.raises(RuntimeError, match="never landed"):
        ckpt.save({"x": np.arange(3)}, d, 1, async_=True)
    ckpt.save({"x": np.arange(3)}, d, 2, async_=True)  # error drained
    ckpt.wait(d)
    assert ckpt.latest_step(d) == 2


def test_threaded_saves_serialize(tmp_path):
    """save() from many threads at once: the per-directory lock keeps
    the join-previous/register sequence atomic, so every step lands and
    no tmp dir is orphaned by an overlapping rename/GC."""
    d = str(tmp_path / "ck")
    threads = [threading.Thread(
        target=ckpt.save,
        args=({"x": np.full(1 << 14, i, np.int32)}, d, i),
        kwargs=dict(keep=10, async_=True)) for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    ckpt.wait(d)
    steps = sorted(p for p in os.listdir(d) if p.startswith("step-"))
    assert len(steps) == 6
    assert not [p for p in os.listdir(d) if p.startswith("tmp-")]


def test_stale_tmp_aged_out_despite_live_pid(tmp_path):
    """pid reuse fallback: a tmp dir owned by a live pid that is not
    ours is swept once it is older than the age cap — a recycled pid
    must not pin a crashed save's tmp dir forever."""
    d = str(tmp_path / "ck")
    os.makedirs(d)
    reused = os.path.join(d, "tmp-5-1")      # pid 1: always alive
    os.makedirs(reused)
    past = time.time() - 2 * 3600
    os.utime(reused, (past, past))
    fresh = os.path.join(d, "tmp-6-1")       # young: could be live
    os.makedirs(fresh)
    ckpt.save({"x": np.arange(3)}, d, 7)
    assert not os.path.exists(reused)        # aged out
    assert os.path.exists(fresh)             # too young to condemn


def test_checkpointer_paces_and_recovers_empty(tmp_path):
    """Checkpointer fires every ``interval`` ingested batches; recover
    with nothing on disk hands back a fresh engine."""
    rng = np.random.RandomState(8)
    eng = SDE()
    _build(eng)
    d = str(tmp_path / "ck")
    ckp = Checkpointer(eng, d, interval=2, async_=False)
    assert ckp.maybe_snapshot() is None          # nothing ingested yet
    eng.ingest(*_batch(rng))
    assert ckp.maybe_snapshot() is None          # 1 < interval
    eng.ingest(*_batch(rng))
    assert ckp.maybe_snapshot() == "full"        # first = base
    eng.ingest(*_batch(rng))
    eng.ingest(*_batch(rng))
    assert ckp.maybe_snapshot() == "delta"
    assert ckp.snapshots == 2
    eng.close()
    empty = recover(str(tmp_path / "nothing"), str(tmp_path / "no.wal"))
    assert empty.batches_ingested == 0 and not empty.stacks
    empty.close()
