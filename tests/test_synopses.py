"""Accuracy-bound unit tests for every synopsis kind (paper Table 1)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import core


@pytest.fixture(scope="module")
def zipf_stream():
    rng = np.random.RandomState(0)
    items = rng.zipf(1.3, 30000).astype(np.uint32) % 5000
    return items, np.ones(len(items), np.float32), np.ones(len(items), bool)


@pytest.mark.smoke
def test_countmin_bounds(zipf_stream):
    items, vals, mask = zipf_stream
    cm = core.CountMin(eps=0.005, delta=0.01)
    st = jax.jit(cm.add_batch)(cm.init(None), items, vals, mask)
    q = np.arange(20, dtype=np.uint32)
    est = np.asarray(cm.estimate(st, q))
    true = np.array([(items == i).sum() for i in q], np.float32)
    assert (est >= true - 1e-3).all(), "CM must never underestimate"
    assert (est - true <= cm.eps * len(items)).all()


def test_hll_accuracy(zipf_stream):
    items, vals, mask = zipf_stream
    hll = core.HyperLogLog(rse=0.02)
    st = jax.jit(hll.add_batch)(hll.init(None), items, vals, mask)
    true = len(np.unique(items))
    assert abs(float(hll.estimate(st)) - true) / true < 5 * 0.02


def test_ams_l2(zipf_stream):
    items, vals, mask = zipf_stream
    ams = core.AMS(eps=0.05, delta=0.05)
    st = jax.jit(ams.add_batch)(ams.init(None), items, vals, mask)
    freqs = np.bincount(items).astype(np.float64)
    true = float((freqs ** 2).sum())
    assert abs(float(ams.estimate(st)) - true) / true < 3 * ams.eps


def test_ams_inner_product(zipf_stream):
    items, vals, mask = zipf_stream
    ams = core.AMS(eps=0.05, delta=0.05)
    a = jax.jit(ams.add_batch)(ams.init(None), items[:15000], vals[:15000],
                               mask[:15000])
    b = jax.jit(ams.add_batch)(ams.init(None), items[15000:], vals[15000:],
                               mask[15000:])
    fa = np.bincount(items[:15000], minlength=5000).astype(np.float64)
    fb = np.bincount(items[15000:], minlength=5000).astype(np.float64)
    true = float(fa @ fb)
    assert abs(float(ams.inner_product(a, b)) - true) / true < 0.2


def test_fm_distinct(zipf_stream):
    items, vals, mask = zipf_stream
    fm = core.FMSketch(nmaps=128)
    st = jax.jit(fm.add_batch)(fm.init(None), items, vals, mask)
    true = len(np.unique(items))
    assert abs(float(fm.estimate(st)) - true) / true < 0.3


def test_bloom(zipf_stream):
    items, vals, mask = zipf_stream
    bl = core.BloomFilter(n_elements=3000, fpr=0.01)
    st = jax.jit(bl.add_batch)(bl.init(None), items[:3000], vals[:3000],
                               mask[:3000])
    present = np.unique(items[:3000])
    absent = (np.arange(500) + 100000).astype(np.uint32)
    assert bool(np.asarray(bl.estimate(st, present)).all()), "no false negatives"
    assert float(np.asarray(bl.estimate(st, absent)).mean()) < 0.05


def test_dft_correlation():
    rng = np.random.RandomState(1)
    n, F = 64, 12
    d = core.DFT(window=n, n_coeffs=F, threshold=0.9)
    t = np.arange(300)
    x = np.sin(0.25 * t) + 0.1 * rng.randn(300)
    y = np.sin(0.25 * t + 0.1) + 0.1 * rng.randn(300)
    feed = jax.jit(d.add_batch)
    sx = feed(d.init(None), np.zeros(300, np.uint32), x.astype(np.float32),
              np.ones(300, bool))
    sy = feed(d.init(None), np.zeros(300, np.uint32), y.astype(np.float32),
              np.ones(300, bool))
    from repro.core.dft import corr_from_coeffs
    est = float(corr_from_coeffs(d.normalized_coeffs(sx),
                                 d.normalized_coeffs(sy)))
    true = np.corrcoef(x[-n:], y[-n:])[0, 1]
    assert abs(est - true) < 0.1
    # truncation must not overestimate the distance (no false dismissals)
    assert est >= true - 0.05


def test_lossy_counting_heavy_hitters(zipf_stream):
    items, vals, mask = zipf_stream
    lc = core.LossyCounting(eps=0.01)
    st = jax.jit(lc.add_batch)(lc.init(None), items[:5000], vals[:5000],
                               mask[:5000])
    freqs = np.bincount(items[:5000])
    heavy = np.where(freqs > 0.02 * 5000)[0].astype(np.uint32)
    est = np.asarray(lc.estimate(st, heavy))
    true = freqs[heavy]
    assert (est >= true - 0.01 * 5000 - 1).all()


def test_gk_quantiles():
    rng = np.random.RandomState(2)
    gk = core.GKQuantiles(eps=0.02)
    data = rng.randn(16384).astype(np.float32)
    st = gk.init(None)
    add = jax.jit(gk.add_batch)
    for i in range(16):
        st = add(st, np.zeros(1024, np.uint32), data[i * 1024:(i + 1) * 1024],
                 np.ones(1024, bool))
    qs = np.array([0.05, 0.25, 0.5, 0.75, 0.95], np.float32)
    est = np.asarray(gk.estimate(st, qs))
    for q, e in zip(qs, est):
        true_rank = (data <= e).mean()
        assert abs(true_rank - q) < 6 * gk.eps


def test_reservoir_uniformity():
    rs = core.ReservoirSampler(sample_size=256)
    items = np.arange(10000, dtype=np.uint32)
    st = jax.jit(rs.add_batch)(rs.init(None), items,
                               items.astype(np.float32),
                               np.ones(10000, bool))
    out = rs.estimate(st)
    sample = np.asarray(out["items"])[np.asarray(out["valid"])]
    assert len(sample) == 256
    assert len(np.unique(sample)) == 256
    # mean of a uniform sample of [0, 10000) should be near 5000
    assert abs(sample.astype(np.float64).mean() - 5000) < 800


def test_coreset_kmeans():
    rng = np.random.RandomState(3)
    centers = np.array([[0, 0], [6, 6], [-6, 6]], np.float32)
    pts = np.concatenate([c + 0.4 * rng.randn(150, 2).astype(np.float32)
                          for c in centers])
    rng.shuffle(pts)
    tree = core.CoreSetTree(bucket_size=32, dim=2)
    st = tree.init(None)
    add = jax.jit(tree.add_batch)
    for i in range(0, len(pts), 32):
        chunk = pts[i:i + 32]
        m = np.ones(len(chunk), bool)
        if len(chunk) < 32:
            chunk = np.pad(chunk, ((0, 32 - len(chunk)), (0, 0)))
            m = np.pad(m, (0, 32 - len(m)))
        st = add(st, np.zeros(32, np.uint32), chunk, m)
    est = tree.estimate(st)
    assert abs(float(est["weights"].sum()) - len(pts)) < 1e-3
    from repro.core.coreset import weighted_kmeans
    km, _ = weighted_kmeans(est["points"], est["weights"], 3, iters=15)
    km = np.sort(np.asarray(km), axis=0)
    true = np.sort(centers, axis=0)
    assert np.abs(km - true).max() < 1.0


def test_sticky_sampling_recall():
    rng = np.random.RandomState(4)
    ss = core.StickySampling(support=0.05, eps=0.01)
    zipf = rng.zipf(1.5, 20000).astype(np.uint32) % 1000
    st = jax.jit(ss.add_batch)(ss.init(None), zipf,
                               np.ones(20000, np.float32),
                               np.ones(20000, bool))
    keys, counts, keep = ss.frequent_items(st)
    freqs = np.bincount(zipf, minlength=1000)
    true_frequent = set(np.where(freqs >= 0.05 * 20000)[0].tolist())
    found = set(int(k) for k, kp in zip(np.asarray(keys), np.asarray(keep))
                if kp and k != 0xFFFFFFFF)
    assert true_frequent.issubset(found)


def test_rhp_cosine():
    rng = np.random.RandomState(5)
    rh = core.RHP(n_bits=256)
    va = rng.randn(400).astype(np.float32)
    vb = (va + 0.15 * rng.randn(400)).astype(np.float32)
    ids = np.arange(400, dtype=np.uint32)
    one = jax.jit(rh.add_batch)
    sa = one(rh.init(None), ids, va, np.ones(400, bool))
    sb = one(rh.init(None), ids, vb, np.ones(400, bool))
    from repro.core.rhp import cosine_similarity
    est = float(cosine_similarity(rh.signature(sa), rh.signature(sb), 256))
    true = float(va @ vb / np.linalg.norm(va) / np.linalg.norm(vb))
    assert abs(est - true) < 0.15


def test_pane_window_expiry():
    pw = core.PaneWindow(core.CountMin(eps=0.01, delta=0.05), n_panes=4,
                         pane_span=128)
    st = pw.init(None)
    add = jax.jit(pw.add_batch)
    for i in range(8):
        items = np.full(128, i, np.uint32)
        st = add(st, items, np.ones(128, np.float32), np.ones(128, bool))
    recent = float(pw.estimate(st, np.array([7], np.uint32))[0])
    expired = float(pw.estimate(st, np.array([0], np.uint32))[0])
    assert recent == 128.0
    assert expired == 0.0
