"""Elasticity reconciler tests (PR 8): sample -> plan -> diff -> migrate.

The contract under test:

  * ``worst_fit_decreasing`` is fully deterministic: heaviest piece
    first (input order breaks load ties), equally loaded bins hand out
    the LOWEST worker id — the tie rule the reconciler's no-flap
    behavior depends on, locked here.
  * ``Placement.diff`` relabels the target's workers to maximally
    overlap the previous placement (Hungarian on the overlap matrix) and
    returns the minimal move set; applying the delta to ``prev``
    reproduces the target assignment exactly (property-tested).
  * a ``Reconciler.step`` against a skewed stream improves the max/mean
    imbalance, migrates rows between worker slices of the row axis
    (single engine) or synopses between sites (federation), and the
    reconciled engine is BYTE-identical to a from-scratch engine built
    directly at the target placement — migration is invisible to state.
  * hysteresis damps: a balanced stream reconciles to zero moves.
  * probes (``RECONCILE_COUNT`` / ``MIGRATED_ROWS`` /
    ``REBALANCE_IMBALANCE``) surface through the JSON ``status``
    response; the gateway tick and ``serve_lines`` drive
    ``maybe_step`` and survive a raising reconciler.
"""
import json

import jax
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.launch import sde_server
from repro.service import (SDE, Federation, Placement, Reconciler,
                           SynopsisGateway, worst_fit_decreasing)

CM = {"eps": 0.05, "delta": 0.1, "weighted": False}


def _mk_engine(streams, n_est_eps=0.01):
    """Engine with per-stream CountMins (prefix ``pt``) plus the two
    estimator synopses the reconciler samples. The estimator CM uses a
    different eps so it lives in its OWN kind stack — placement moves
    only the per-stream stack."""
    eng = SDE()
    for req in (
        {"type": "build", "request_id": "b1", "synopsis_id": "pt",
         "kind": "countmin", "params": CM,
         "per_stream_of_source": True, "stream_ids": list(streams)},
        {"type": "build", "request_id": "b2", "synopsis_id": "rhll",
         "kind": "hyperloglog", "params": {"rse": 0.05}},
        {"type": "build", "request_id": "b3", "synopsis_id": "rcm",
         "kind": "countmin", "params": {"eps": n_est_eps, "delta": 0.01,
                                        "weighted": False}},
    ):
        r = eng.handle(req)
        assert r.ok, r.error
    return eng


def _skewed(streams, hot, n=512, seed=0, frac=0.8):
    """80% of the traffic on ``hot``, integer values (exact f32 sums)."""
    rng = np.random.RandomState(seed)
    pick = np.where(rng.rand(n) < frac,
                    rng.choice(hot, n), rng.choice(streams, n))
    return pick.astype(np.int64), np.ones(n, np.float32)


def _stack_bytes(eng):
    eng.flush()
    return {str(k): [np.asarray(x).tobytes()
                     for x in jax.tree.leaves(s.state)]
            for k, s in eng.stacks.items()}


# ---------------------------------------------------------------------------
# satellite: the WFD tie rule, locked
# ---------------------------------------------------------------------------
@pytest.mark.smoke
def test_wfd_lowest_worker_id_tie_rule():
    # all-equal loads: the heap must hand out 0, 1, 2, 0, 1, 2, ...
    p = worst_fit_decreasing([10, 11, 12, 13, 14, 15],
                             [2.0] * 6, 3)
    assert p.assignments == {10: 0, 11: 1, 12: 2, 13: 0, 14: 1, 15: 2}
    # load ties between bins resolve to the LOWEST id even mid-pack
    p = worst_fit_decreasing([1, 2, 3], [4.0, 2.0, 2.0], 2)
    assert p.assignments == {1: 0, 2: 1, 3: 1}
    assert p.loads == [4.0, 4.0]
    # equal stream loads keep input order (stable sort)
    p = worst_fit_decreasing([9, 4, 7], [1.0, 1.0, 1.0], 2)
    assert p.assignments == {9: 0, 4: 1, 7: 0}
    # and the whole thing is reproducible
    args = (list(range(40)), list(np.random.RandomState(0).rand(40)), 5)
    assert worst_fit_decreasing(*args).assignments \
        == worst_fit_decreasing(*args).assignments


@pytest.mark.smoke
def test_wfd_duplicate_candidates_coalesce():
    # an estimator can list the same stream twice; packing the
    # duplicates separately left the dict assignment holding only the
    # LAST bin while BOTH loads stayed in the bin totals, so
    # sum(p.loads) drifted above the load of the streams assigned
    p = worst_fit_decreasing([7, 7, 9], [3.0, 3.0, 4.0], 2)
    assert p.assignments == {7: 0, 9: 1}              # 7 is ONE piece
    assert sorted(p.loads) == [4.0, 6.0]
    # the invariant the bug broke: bin totals == assigned stream loads
    rng = np.random.RandomState(2)
    ids = rng.randint(0, 20, 64)                      # heavy duplication
    loads = rng.rand(64) + 0.01
    p = worst_fit_decreasing(ids, loads, 4)
    assert np.isclose(sum(p.loads), loads.sum())
    per_stream = {}
    for s, load in zip(ids, loads):
        per_stream[int(s)] = per_stream.get(int(s), 0.0) + float(load)
    assert set(p.assignments) == set(per_stream)
    for w in range(4):
        assert np.isclose(
            p.loads[w], sum(load for s, load in per_stream.items()
                            if p.assignments[s] == w))
    with pytest.raises(ValueError, match="align 1:1"):
        worst_fit_decreasing([1, 2], [1.0], 2)


def test_wfd_imbalance_sane():
    rng = np.random.RandomState(1)
    loads = rng.pareto(1.5, 64) + 0.01
    p = worst_fit_decreasing(list(range(64)), loads, 8)
    assert np.isclose(sum(p.loads), loads.sum())
    # WFD never exceeds mean + the heaviest piece
    assert max(p.loads) <= loads.sum() / 8 + loads.max() + 1e-9


# ---------------------------------------------------------------------------
# satellite: Placement.diff — apply(delta, prev) == target, moves minimal
# ---------------------------------------------------------------------------
def _random_placement(rng, streams, w):
    assign = {s: int(rng.randint(0, w)) for s in streams}
    loads = [0.0] * w
    for s in assign:
        loads[assign[s]] += 1.0
    return Placement(assignments=assign, loads=loads, n_workers=w)


def test_diff_apply_reproduces_target_property():
    rng = np.random.RandomState(7)
    for trial in range(30):
        w = int(rng.randint(1, 6))
        n = int(rng.randint(1, 40))
        streams = list(rng.choice(10_000, n, replace=False))
        prev = _random_placement(rng, streams[:int(rng.randint(0, n + 1))],
                                 w)
        target = _random_placement(rng, streams, w)
        delta = target.diff(prev)
        got = delta.apply(prev)
        assert got == delta.target.assignments, trial
        # relabeling permutes labels, it never regroups streams
        groups = lambda p: sorted(
            tuple(sorted(s for s, ww in p.assignments.items() if ww == k))
            for k in range(p.n_workers))
        assert groups(delta.target) == groups(target)
        # every listed move is a real move
        for s, pw, dw in delta.moves:
            assert prev.assignments.get(s) == pw and pw != dw


def test_diff_relabel_minimizes_moves():
    # identical placement under permuted labels: ZERO moves after the
    # Hungarian relabel (a naive label-wise diff would move everything)
    prev = Placement(assignments={s: s % 4 for s in range(32)},
                     loads=[8.0] * 4, n_workers=4)
    perm = [2, 3, 1, 0]
    tgt = Placement(assignments={s: perm[s % 4] for s in range(32)},
                    loads=[8.0] * 4, n_workers=4)
    delta = tgt.diff(prev)
    assert delta.moves == [] and delta.dropped == []
    assert delta.target.assignments == prev.assignments
    # one genuinely misplaced stream -> exactly one move
    shifted = {s: perm[s % 4] for s in range(32)}
    shifted[5] = perm[2]
    tgt2 = Placement(assignments=shifted,
                     loads=[8.0, 7.0, 9.0, 8.0], n_workers=4)
    d2 = tgt2.diff(prev)
    assert [(s, pw) for s, pw, _ in d2.moves] == [(5, 1)]


# ---------------------------------------------------------------------------
# the loop, single engine: skew in, rebalanced rows out
# ---------------------------------------------------------------------------
@pytest.mark.smoke
def test_reconcile_single_engine_rebalances():
    streams = list(range(32))
    eng = _mk_engine(streams)
    rec = Reconciler(eng, "rhll", "rcm", n_workers=4, min_gain=0.0)
    count0 = kops.RECONCILE_COUNT[eng.site]

    eng.ingest(*_skewed(streams, hot=[0, 1]))
    rep = rec.step()
    assert rep["applied"], rep
    assert rep["moves"] == rep["migrated_rows"] > 0
    assert rep["imbalance_after"] < rep["imbalance_before"]

    # rows landed inside their assigned workers' slices of the row axis
    kind = eng.entries["pt/0"].kind_key
    cap = eng.stacks[kind].capacity
    assign = {s: eng.entries[f"pt/{s}"].row * 4 // cap for s in streams}
    assert assign[0] != assign[1]         # the two heavy streams split

    # probes reached the JSON status response
    st = eng.handle({"type": "status", "request_id": "s"})
    assert st.params["reconcile_count"] \
        == kops.RECONCILE_COUNT[eng.site] > count0
    assert st.params["migrated_rows"] >= rep["migrated_rows"]
    assert st.params["rebalance_imbalance"] \
        == pytest.approx(rep["imbalance_after"])
    json.loads(st.to_json())              # and serializes

    # queries and further ingest survive the move
    q = eng.handle({"type": "adhoc", "request_id": "q",
                    "synopsis_id": "pt/0", "query": {"items": [0]}})
    assert q.ok
    eng.ingest(np.full(50, 0, np.int64), np.ones(50, np.float32))
    eng.flush()
    q2 = eng.handle({"type": "adhoc", "request_id": "q2",
                     "synopsis_id": "pt/0", "query": {"items": [0]}})
    assert float(np.asarray(q2.value)[0]) \
        == float(np.asarray(q.value)[0]) + 50


def test_reconcile_non_pow2_worker_count_terminates():
    # regression: the capacity search used to double a pow2 capacity
    # forever looking for divisibility by 3 — plan directly instead
    streams = list(range(12))
    eng = _mk_engine(streams)
    rec = Reconciler(eng, "rhll", "rcm", n_workers=3, min_gain=0.0)
    eng.ingest(*_skewed(streams, hot=[0, 1]))
    rep = rec.step()
    assert rep["applied"], rep
    kind = eng.entries["pt/0"].kind_key
    cap = eng.stacks[kind].capacity
    ss = cap // 3
    assert cap % 3 == 0 and ss & (ss - 1) == 0           # pow2 slices
    # every row landed inside its worker's slice, heavy streams split
    assign = {s: eng.entries[f"pt/{s}"].row * 3 // cap for s in streams}
    assert set(assign.values()) <= {0, 1, 2}
    assert assign[0] != assign[1]


def test_reconcile_skips_are_quiet():
    streams = list(range(8))
    eng = SDE()
    rec = Reconciler(eng, "rhll", "rcm", n_workers=2)
    rep = rec.step()
    assert rep["reason"] == "estimator synopses not built yet"
    # skip reports carry the SAME schema as applied ones — consumers
    # index imbalance_before/after without guarding on the path
    assert rep["imbalance_before"] is None
    assert rep["imbalance_after"] is None
    eng2 = _mk_engine(streams)
    rec2 = Reconciler(eng2, "rhll", "rcm", n_workers=2)
    assert rec2.step()["reason"] == "no traffic since last pass"
    # first pass spreads the (all-in-slice-0) rows; a second pass over
    # equally balanced traffic is within hysteresis — reconcilers damp
    sids = np.asarray(streams * 64, np.int64)
    eng2.ingest(sids, np.ones(len(sids), np.float32))
    assert rec2.step()["applied"]
    eng2.ingest(sids, np.ones(len(sids), np.float32))
    rep = rec2.step()
    assert not rep["applied"] and rep["reason"] == "within hysteresis"
    assert rep["migrated_rows"] == 0
    # windowing: no NEW traffic means "no traffic since last pass"
    assert rec2.step()["reason"] == "no traffic since last pass"


def test_reconciler_needs_a_worker_count():
    with pytest.raises(ValueError, match="n_workers"):
        Reconciler(SDE(), "h", "c")          # no mesh to infer from


# ---------------------------------------------------------------------------
# the acceptance oracle: reconciled state == from-scratch build at the
# target placement, byte for byte
# ---------------------------------------------------------------------------
def test_reconcile_byte_identical_to_rebuild_at_target():
    streams = list(range(16))
    phase_a = _skewed(streams, hot=[0, 1], seed=3)
    phase_b = _skewed(streams, hot=[14, 15], seed=4)

    live = _mk_engine(streams)
    live.ingest(*phase_a)
    rec = Reconciler(live, "rhll", "rcm", n_workers=4, min_gain=0.0)
    rep = rec.step()
    assert rep["applied"]
    live.ingest(*phase_b)
    live.flush()

    # rebuild from scratch: same builds (same rows), then jump STRAIGHT
    # to the reconciled engine's final placement, then ALL the traffic
    fresh = _mk_engine(streams)
    kind = fresh.entries["pt/0"].kind_key
    fresh.resize_stack(kind, live.stacks[kind].capacity)
    mapping = {fresh.entries[f"pt/{s}"].row: live.entries[f"pt/{s}"].row
               for s in streams}
    fresh.migrate_rows(kind, mapping)
    fresh.ingest(*phase_a)
    fresh.ingest(*phase_b)
    fresh.flush()

    for s in streams:
        assert fresh.entries[f"pt/{s}"].row == live.entries[f"pt/{s}"].row
    assert _stack_bytes(live) == _stack_bytes(fresh)


# ---------------------------------------------------------------------------
# federation: synopses ship between sites through the migration plane
# ---------------------------------------------------------------------------
def test_reconcile_federated_ships_synopses():
    streams = list(range(8))
    fed = Federation(["eu", "us"])
    for rid, (sid, kind, params) in enumerate([
            ("rhll", "hyperloglog", {"rse": 0.05}),
            ("rcm", "countmin", {"eps": 0.01, "delta": 0.01,
                                 "weighted": False})]):
        rs = fed.broadcast({"type": "build", "request_id": f"b{rid}",
                            "synopsis_id": sid, "kind": kind,
                            "params": params})
        assert all(r.ok for r in rs.values())
    r = fed.sdes["eu"].handle({"type": "build", "request_id": "p",
                               "synopsis_id": "pt", "kind": "countmin",
                               "params": CM, "per_stream_of_source": True,
                               "stream_ids": streams})
    assert r.ok, r.error

    sids, vals = _skewed(streams, hot=[0], seed=5, frac=0.5)
    fed.sdes["eu"].ingest(sids, vals)
    counts = {s: int(np.count_nonzero(sids == s)) for s in streams}

    rec = Reconciler(fed, "rhll", "rcm", min_gain=0.0)
    rep = rec.step()
    assert rep["applied"] and rep["migrated_rows"] > 0

    moved = [s for s in streams if f"pt/{s}" in fed.sdes["us"].entries]
    stayed = [s for s in streams if f"pt/{s}" in fed.sdes["eu"].entries]
    assert sorted(moved + stayed) == streams and moved
    assert kops.RECONCILE_COUNT["federation"] > 0
    # federated passes are ALSO tagged per member site, so each site's
    # JSON status (keyed by its own site tag) shows the loop's activity
    for site in ("eu", "us"):
        st = fed.sdes[site].handle({"type": "status", "request_id": "st"})
        assert st.params["reconcile_count"] > 0
        assert st.params["rebalance_imbalance"] \
            == pytest.approx(rep["imbalance_after"])

    # shipped synopses answer exactly at the new site, then keep counting
    for s in moved:
        q = fed.sdes["us"].handle({"type": "adhoc", "request_id": "q",
                                   "synopsis_id": f"pt/{s}",
                                   "query": {"items": [s]}})
        assert q.ok and float(np.asarray(q.value)[0]) == counts[s]
    s0 = moved[0]
    fed.sdes["us"].ingest(np.full(10, s0, np.int64),
                          np.ones(10, np.float32))
    fed.sdes["us"].flush()
    q = fed.sdes["us"].handle({"type": "adhoc", "request_id": "q2",
                               "synopsis_id": f"pt/{s0}",
                               "query": {"items": [s0]}})
    assert float(np.asarray(q.value)[0]) == counts[s0] + 10


# ---------------------------------------------------------------------------
# drive wires: gateway tick and serve_lines
# ---------------------------------------------------------------------------
def test_gateway_tick_drives_reconciler():
    streams = list(range(16))
    eng = _mk_engine(streams)
    rec = Reconciler(eng, "rhll", "rcm", n_workers=4, min_gain=0.0)
    gw = SynopsisGateway(eng, reconciler=rec)
    c = gw.connect("c0")
    sids, vals = _skewed(streams, hot=[2, 3], seed=6)
    f = gw.submit_nowait(c, {"type": "ingest", "request_id": "i",
                             "stream_ids": [int(s) for s in sids],
                             "values": [float(v) for v in vals]})
    gw.tick()
    assert f.result().ok
    assert gw.reconcile_error is None
    assert rec.last_report is not None and rec.last_report["applied"]
    # an empty tick still drives the loop (a quiet window skips cheaply)
    gw.tick()
    assert rec.last_report["reason"] == "no traffic since last pass"

    # a raising reconciler must not take the gateway down
    class Boom:
        def maybe_step(self):
            raise RuntimeError("boom")
    gw.reconciler = Boom()
    gw.tick()
    assert gw.reconcile_error == "RuntimeError('boom')"
    f2 = gw.submit_nowait(c, {"type": "status", "request_id": "s"})
    gw.tick()
    assert f2.result().ok


def test_serve_lines_drives_reconciler():
    streams = list(range(8))
    eng = _mk_engine(streams)
    rec = Reconciler(eng, "rhll", "rcm", n_workers=2, min_gain=0.0)
    sids, _ = _skewed(streams, hot=[0], seed=8, frac=0.9)
    lines = [json.dumps({"type": "ingest", "request_id": "i",
                         "stream_ids": [int(s) for s in sids],
                         "values": [1.0] * len(sids)})]
    import io
    out = io.StringIO()
    n = sde_server.serve_lines(lines, eng, out=out, reconciler=rec)
    assert n == 1
    assert rec.last_report is not None and rec.last_report["applied"]


def test_server_flags_construct_reconciler():
    # --reconcile-interval wires a Reconciler into JSON-lines mode; an
    # empty-input run proves the flag path end to end
    import io as _io
    import sys as _sys
    old = _sys.stdin
    _sys.stdin = _io.StringIO("")
    try:
        n = sde_server.main(["--reconcile-interval", "0.5",
                             "--reconcile-workers", "2"])
    finally:
        _sys.stdin = old
    assert n == 0
