"""Multidim subpopulation analytics + the continuous outlier workflow.

The acceptance matrix of the tentpole:

  * per-kind oracle — ``subpop_query`` over a 2-d family matches the
    brute-force host-side group-by within each sketch's own error
    budget, in BOTH blue-path modes (eager and pipelined),
  * one fused dispatch answers a predicate however many covering keys
    it expands to (``DISPATCH_COUNT``),
  * multidim key encoding properties (hypothesis when available):
    determinism, 63-bit range, injectivity over a family's groups,
    insertion-order independence,
  * the outlier workflow flags a planted hot group, is deterministic
    across runs AND across execution modes, and costs ZERO additional
    builds (entry count + stack capacities pinned).
"""
import numpy as np
import pytest

from repro.core import MultidimSpec
from repro.kernels import ops as kops
from repro.service import SDE

_DIMS = {"region": ["EU", "US", "APAC", "LATAM"],
         "platform": ["web", "mobile"]}
_N = 1600


def _workload(n=_N, seed=0):
    rng = np.random.RandomState(seed)
    regions = rng.choice(_DIMS["region"], n, p=[0.4, 0.3, 0.2, 0.1])
    platforms = rng.choice(_DIMS["platform"], n, p=[0.65, 0.35])
    records = [{"region": str(r), "platform": str(p)}
               for r, p in zip(regions, platforms)]
    values = rng.uniform(0.0, 100.0, n)
    return records, values


def _family(kind, params, pipelined, records, values, items=None):
    sde = SDE(pipelined=pipelined)
    r = sde.handle({"type": "build_multidim", "request_id": "b",
                    "synopsis_id": "md", "kind": kind, "params": params,
                    "dims": _DIMS})
    assert r.ok, r.error
    req = {"type": "ingest_multidim", "request_id": "i",
           "synopsis_id": "md", "records": records,
           "values": [float(v) for v in values]}
    if items is not None:
        req["items"] = [int(x) for x in items]
    r = sde.handle(req)
    assert r.ok, r.error
    return sde


def _mask(records, where):
    def hit(rec):
        return all(rec[d] in (v if isinstance(v, list) else [v])
                   for d, v in where.items())
    return np.asarray([hit(rec) for rec in records])


def _subpop(sde, where, query=None):
    r = sde.handle({"type": "subpop_query", "request_id": "q",
                    "synopsis_id": "md", "where": where,
                    "query": query or {}})
    assert r.ok, r.error
    return np.asarray(r.value, np.float64).ravel()


_WHERES = [{"region": "EU"},
           {"region": ["EU", "US"], "platform": "web"},
           {"platform": "mobile"}]


# ---------------------------------------------------------------------------
# tentpole: per-kind oracle matrix, eager + pipelined
# ---------------------------------------------------------------------------
@pytest.mark.smoke
@pytest.mark.parametrize("pipelined", [False, True],
                         ids=["eager", "pipelined"])
def test_subpop_countmin_oracle(pipelined):
    records, values = _workload()
    sde = _family("countmin",
                  {"eps": 0.002, "delta": 0.01, "weighted": False},
                  pipelined, records, values)
    spec = sde.multidim["md"]
    leaf = spec.leaf_key({"region": "EU", "platform": "web"})
    for where in _WHERES:
        sub = _mask(records, where)
        true = sum(1 for rec, s in zip(records, sub)
                   if s and rec["region"] == "EU"
                   and rec["platform"] == "web")
        est = _subpop(sde, where, {"items": [leaf]})[0]
        tol = 0.002 * sub.sum() + 1.0       # eps * covering mass
        assert abs(est - true) <= tol, (where, est, true)
    sde.close()


@pytest.mark.parametrize("pipelined", [False, True],
                         ids=["eager", "pipelined"])
@pytest.mark.parametrize("kind,params,rel_tol", [
    ("hyperloglog", {"rse": 0.02}, 0.12),
    ("fm", {"nmaps": 256}, 0.35),
], ids=["hll", "fm"])
def test_subpop_distinct_oracle(kind, params, rel_tol, pipelined):
    records, values = _workload()
    # one distinct item per record: the subpop distinct count IS the
    # subpopulation size
    sde = _family(kind, params, pipelined, records, values,
                  items=np.arange(len(records)))
    for where in _WHERES:
        true = int(_mask(records, where).sum())
        est = _subpop(sde, where)[0]
        assert abs(est - true) <= rel_tol * true + 5, (where, est, true)
    sde.close()


@pytest.mark.parametrize("pipelined", [False, True],
                         ids=["eager", "pipelined"])
def test_subpop_bloom_membership(pipelined):
    records, values = _workload()
    sde = _family("bloom", {"n_elements": 4096, "fpr": 0.001},
                  pipelined, records, values)
    spec = sde.multidim["md"]
    present = spec.leaf_key(records[0])
    absent = 123456789                    # never ingested anywhere
    for where in _WHERES:
        est = _subpop(sde, where, {"items": [present, absent]})
        in_sub = bool(_mask([records[0]], where)[0])
        if in_sub:                        # Bloom: no false negatives
            assert est[0] == 1.0, where
        assert est[1] == 0.0, where       # fpr 1e-3: a hit is a bug
    sde.close()


@pytest.mark.parametrize("pipelined", [False, True],
                         ids=["eager", "pipelined"])
def test_subpop_ams_f2_oracle(pipelined):
    records, values = _workload()
    sde = _family("ams", {"eps": 0.02, "delta": 0.05},
                  pipelined, records, values)
    spec = sde.multidim["md"]
    for where in _WHERES:
        sub = _mask(records, where)
        leaf_mass = {}                    # AMS is value-weighted
        for rec, v, s in zip(records, values, sub):
            if s:
                k = spec.leaf_key(rec)
                leaf_mass[k] = leaf_mass.get(k, 0.0) + float(v)
        true = float(sum(m * m for m in leaf_mass.values()))
        est = _subpop(sde, where)[0]
        assert abs(est - true) <= 0.3 * true, (where, est, true)
    sde.close()


@pytest.mark.parametrize("pipelined", [False, True],
                         ids=["eager", "pipelined"])
def test_subpop_gk_median_oracle(pipelined):
    records, values = _workload()
    sde = _family("gk_quantiles", {"eps": 0.01}, pipelined,
                  records, values)
    for where in _WHERES:
        sub = _mask(records, where)
        sub_vals = np.sort(values[sub])
        est = _subpop(sde, where, {"qs": [0.5]})[0]
        # rank accuracy: the estimated median's rank inside the true
        # subpop values stays near n/2 (merging covering summaries
        # compounds eps; 8% of n is a generous envelope over eps=1%)
        rank = np.searchsorted(sub_vals, est)
        assert abs(rank - len(sub_vals) / 2) <= 0.08 * len(sub_vals) + 2, \
            (where, est, rank, len(sub_vals))
    sde.close()


# ---------------------------------------------------------------------------
# tentpole: one fused dispatch per predicate + the cover-keys probe
# ---------------------------------------------------------------------------
@pytest.mark.smoke
def test_subpop_single_fused_dispatch():
    records, values = _workload(400)
    sde = _family("countmin", {"eps": 0.01, "delta": 0.05,
                               "weighted": False},
                  False, records, values)
    sde.flush()                           # fence outside the window
    for where, n_cover in [({"region": "EU"}, 1),
                           ({"region": ["EU", "US", "APAC"]}, 3),
                           ({"region": ["EU", "US"],
                             "platform": ["web", "mobile"]}, 4)]:
        d0 = int(kops.DISPATCH_COUNT["CountMin"])
        c0 = int(kops.SUBPOP_COVER_KEYS[sde.site])
        r = sde.handle({"type": "subpop_query", "request_id": "q",
                        "synopsis_id": "md", "where": where,
                        "query": {"items": [7]}})
        assert r.ok, r.error
        assert r.params["cover_keys"] == n_cover
        assert int(kops.DISPATCH_COUNT["CountMin"]) - d0 == 1, \
            "a covering set must merge+estimate in ONE fused dispatch"
        assert int(kops.SUBPOP_COVER_KEYS[sde.site]) - c0 == n_cover
    sde.close()


def test_subpop_validation_errors():
    records, values = _workload(200)
    sde = _family("countmin", {"eps": 0.01, "delta": 0.05,
                               "weighted": False},
                  False, records, values)
    # unknown dimension
    r = sde.handle({"type": "subpop_query", "request_id": "q1",
                    "synopsis_id": "md", "where": {"planet": "earth"}})
    assert not r.ok and "unknown dimension" in r.error
    # unknown family
    r = sde.handle({"type": "subpop_query", "request_id": "q2",
                    "synopsis_id": "nope", "where": {"region": "EU"}})
    assert not r.ok
    # duplicate family id refused
    r = sde.handle({"type": "build_multidim", "request_id": "b2",
                    "synopsis_id": "md", "kind": "countmin",
                    "params": {}, "dims": _DIMS})
    assert not r.ok and "already exists" in r.error
    sde.close()


def test_subpop_rejects_non_mergeable_kind():
    # DFT replicas are exchanged, never merged — a covering-set merge
    # would fabricate coefficients
    sde = SDE()
    r = sde.handle({"type": "build_multidim", "request_id": "b",
                    "synopsis_id": "md", "kind": "dft",
                    "params": {"window": 16, "n_coeffs": 4},
                    "dims": {"a": ["x", "y"]}})
    assert r.ok, r.error
    r = sde.handle({"type": "subpop_query", "request_id": "q",
                    "synopsis_id": "md", "where": {"a": "x"}})
    assert not r.ok and "mergeable" in r.error
    r = sde.handle({"type": "track_outliers", "request_id": "t",
                    "workflow_id": "w", "synopsis_id": "md",
                    "level": ["a"]})
    assert not r.ok
    sde.close()


def test_explicit_levels_gate_queries():
    records, values = _workload(200)
    sde = SDE()
    r = sde.handle({"type": "build_multidim", "request_id": "b",
                    "synopsis_id": "md", "kind": "countmin",
                    "params": {"eps": 0.01, "delta": 0.05},
                    "dims": _DIMS, "levels": [["region"]]})
    assert r.ok, r.error
    # population + region only: 1 + 4 groups
    assert r.params["n_groups"] == 5 and r.params["n_levels"] == 2
    r = sde.handle({"type": "ingest_multidim", "request_id": "i",
                    "synopsis_id": "md", "records": records,
                    "values": [1.0] * len(records)})
    assert r.ok, r.error
    assert _subpop(sde, {"region": "EU"}, {"items": [3]}).size == 1
    r = sde.handle({"type": "subpop_query", "request_id": "q",
                    "synopsis_id": "md", "where": {"platform": "web"}})
    assert not r.ok and "not materialized" in r.error
    sde.close()


# ---------------------------------------------------------------------------
# multidim key encoding properties
# ---------------------------------------------------------------------------
def _spec_roundtrip_and_keys(spec):
    keys = spec.all_keys()
    assert all(0 <= k < (1 << 63) for k in keys)
    assert len(set(keys)) == len(keys)    # injective across the family
    again = MultidimSpec.from_json_dict(spec.to_json_dict())
    assert again == spec and again.all_keys() == keys


@pytest.mark.smoke
def test_multidim_keys_basics():
    spec = MultidimSpec(_DIMS)
    _spec_roundtrip_and_keys(spec)
    # insertion order of the ASSIGNMENT dict is irrelevant
    assert (spec.group_key({"region": "EU", "platform": "web"})
            == spec.group_key({"platform": "web", "region": "EU"}))
    # declaration order of the DIMENSIONS is load-bearing
    other = MultidimSpec({"platform": _DIMS["platform"],
                          "region": _DIMS["region"]})
    assert (spec.group_key({"region": "EU"})
            != other.group_key({"region": "EU"}))
    # expand covers every level exactly once, leaf included
    rec = {"region": "US", "platform": "mobile"}
    ks = spec.expand(rec)
    assert len(ks) == len(spec.levels) == 4
    assert spec.population_key() in ks and spec.leaf_key(rec) in ks
    # bools never alias their int twins
    bspec = MultidimSpec({"flag": [True, False, 1, 0]})
    _spec_roundtrip_and_keys(bspec)
    with pytest.raises(ValueError):
        spec.group_key({"region": "MOON"})
    with pytest.raises(ValueError):
        spec.expand({"region": "EU"})     # platform missing


try:
    from hypothesis import given, settings, HealthCheck
    from hypothesis import strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                       # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    _names = st.text("abcdefgh", min_size=1, max_size=4)
    _atoms = st.one_of(st.integers(-2**40, 2**40),
                       st.text(max_size=6), st.booleans())

    @settings(max_examples=40, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.dictionaries(_names, st.lists(_atoms, min_size=1,
                                            max_size=5, unique=True),
                           min_size=1, max_size=3))
    def test_multidim_keys_property(dims):
        spec = MultidimSpec(dims)
        _spec_roundtrip_and_keys(spec)
        # every leaf expansion lands on maintained groups, population
        # always included
        leaf = {n: vs[0] for n, vs in spec.domains.items()}
        ks = spec.expand(leaf)
        maintained = set(spec.all_keys())
        assert set(ks) <= maintained
        assert spec.population_key() in ks
        # covering keys of a full assignment = that leaf alone
        lvl, cover = spec.covering_keys(leaf)
        assert cover == [spec.leaf_key(leaf)]
        assert lvl == tuple(spec.dim_names)


# ---------------------------------------------------------------------------
# the continuous outlier workflow
# ---------------------------------------------------------------------------
def _hot_workload(n=900, seed=3):
    """Uniform across the grid except region EU, which runs ~6x hot —
    the planted outlier every configuration must flag."""
    rng = np.random.RandomState(seed)
    regions = rng.choice(_DIMS["region"], n, p=[0.7, 0.1, 0.1, 0.1])
    platforms = rng.choice(_DIMS["platform"], n)
    return ([{"region": str(r), "platform": str(p)}
             for r, p in zip(regions, platforms)],
            np.ones(n))


def _drive_outliers(pipelined, n_ticks=3):
    records, values = _hot_workload()
    sde = SDE(pipelined=pipelined)
    r = sde.handle({"type": "build_multidim", "request_id": "b",
                    "synopsis_id": "md", "kind": "countmin",
                    "params": {"eps": 0.005, "delta": 0.01,
                               "weighted": False},
                    "dims": _DIMS, "continuous": False})
    assert r.ok, r.error
    # every record carries the same item id, so a CM point query of
    # item 42 reads each group's total tuple count — the stat the
    # workflow scores across the region level
    r = sde.handle({"type": "track_outliers", "request_id": "t",
                    "workflow_id": "hot-regions", "synopsis_id": "md",
                    "level": ["region"], "query": {"items": [42]},
                    "threshold": 2.0, "min_dev": 1.0})
    assert r.ok, r.error
    step = len(records) // n_ticks
    for i in range(n_ticks):
        chunk = records[i * step:(i + 1) * step]
        r = sde.handle({"type": "ingest_multidim", "request_id": f"i{i}",
                        "synopsis_id": "md", "records": chunk,
                        "values": [1.0] * len(chunk),
                        "items": [42] * len(chunk)})
        assert r.ok, r.error
    sde.flush()
    out = [resp for resp in sde.continuous_out.drain()
           if resp.synopsis_id == "hot-regions"]
    payloads = [resp.value for resp in out]
    ids = [resp.request_id for resp in out]
    sde.close()
    return ids, payloads


@pytest.mark.smoke
@pytest.mark.parametrize("pipelined", [False, True],
                         ids=["eager", "pipelined"])
def test_outlier_workflow_flags_planted_hot_group(pipelined):
    ids, payloads = _drive_outliers(pipelined)
    assert len(payloads) == 3             # one response per ingest tick
    assert all(i.startswith("ow/hot-regions/") for i in ids)
    final = payloads[-1]
    assert final["n_groups"] == 4
    flagged = [o["group"] for o in final["outliers"]]
    assert {"region": "EU"} in flagged, final
    eu = next(o for o in final["outliers"]
              if o["group"] == {"region": "EU"})
    assert eu["z"] > 0 and eu["stat"] > final["center"]


def test_outlier_workflow_deterministic_across_modes():
    a = _drive_outliers(False)
    b = _drive_outliers(False)
    c = _drive_outliers(True)
    assert a == b                         # bit-for-bit rerun stability
    assert a == c                         # eager == pipelined


def test_outlier_workflow_zero_additional_builds():
    records, values = _hot_workload(300)
    sde = SDE()
    r = sde.handle({"type": "build_multidim", "request_id": "b",
                    "synopsis_id": "md", "kind": "countmin",
                    "params": {"eps": 0.01, "delta": 0.05,
                               "weighted": False}, "dims": _DIMS})
    assert r.ok, r.error
    r = sde.handle({"type": "ingest_multidim", "request_id": "i0",
                    "synopsis_id": "md", "records": records,
                    "values": [1.0] * len(records)})
    assert r.ok, r.error
    sde.flush()
    n_entries = len(sde.entries)
    caps = {k: s.capacity for k, s in sde.stacks.items()}
    e0 = int(kops.OUTLIER_EMITS[sde.site])
    r = sde.handle({"type": "track_outliers", "request_id": "t",
                    "workflow_id": "w", "synopsis_id": "md",
                    "level": ["region"], "query": {"items": [1]},
                    "threshold": 0.0})   # threshold 0: every tick flags
    assert r.ok, r.error
    for i in range(2):
        r = sde.handle({"type": "ingest_multidim", "request_id": f"i{i}",
                        "synopsis_id": "md", "records": records[:50],
                        "values": [1.0] * 50, "items": [1] * 50})
        assert r.ok, r.error
    sde.flush()
    # the workflow rode the maintained synopses: no entry appeared, no
    # stack grew, yet emissions flowed
    assert len(sde.entries) == n_entries
    assert {k: s.capacity for k, s in sde.stacks.items()} == caps
    assert int(kops.OUTLIER_EMITS[sde.site]) > e0
    assert any(resp.synopsis_id == "w"
               for resp in sde.continuous_out.drain())
    # untrack silences the stream
    r = sde.handle({"type": "untrack_outliers", "request_id": "u",
                    "workflow_id": "w"})
    assert r.ok and not sde.outliers
    sde.handle({"type": "ingest_multidim", "request_id": "ix",
                "synopsis_id": "md", "records": records[:10],
                "values": [1.0] * 10})
    sde.flush()
    assert not [resp for resp in sde.continuous_out.drain()
                if resp.synopsis_id == "w"]
    sde.close()


def test_multidim_snapshot_roundtrip(tmp_path):
    records, values = _workload(300)
    sde = _family("countmin", {"eps": 0.01, "delta": 0.05,
                               "weighted": False},
                  False, records, values)
    r = sde.handle({"type": "track_outliers", "request_id": "t",
                    "workflow_id": "w", "synopsis_id": "md",
                    "level": ["region"], "query": {"items": [5]}})
    assert r.ok, r.error
    sde.flush()
    before = _subpop(sde, {"region": "EU"}, {"items": [5]})
    sde.snapshot(str(tmp_path))
    sde.close()
    back = SDE.restore(str(tmp_path))
    assert back.multidim["md"] == MultidimSpec(_DIMS)
    assert "w" in back.outliers and back.outliers["w"].level == ("region",)
    after = _subpop(back, {"region": "EU"}, {"items": [5]})
    np.testing.assert_allclose(after, before)
    back.close()
