"""Sharding rule system + distributed-path equivalence.

The shard_map MoE and the reference MoE must agree numerically; validated
in a subprocess with 8 fake devices (jax locks the device count at init,
so the multi-device check cannot run in this process).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.sharding.specs import MeshRules, spec_for


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)
        self.empty = False


@pytest.mark.smoke
def test_spec_degrades_on_indivisible_dims():
    mesh = _FakeMesh({"data": 16, "model": 16})
    rules = MeshRules()
    # kv=8 does not divide 16 -> replicated
    spec = spec_for(rules, ("tensor",), mesh, (8,))
    assert spec == type(spec)(None)
    spec = spec_for(rules, ("tensor",), mesh, (64,))
    assert spec[0] == "model"


def test_batch_prefix_degradation():
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    rules = MeshRules(batch=("data", "model"))
    # batch 128 divides data(16) but not data*model(256): degrade prefix
    spec = spec_for(rules, ("batch",), mesh, (128,))
    assert spec[0] in ("data", ("data",))
    spec = spec_for(rules, ("batch",), mesh, (256,))
    assert spec[0] == ("data", "model")


_MOE_EQUIV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs import ARCHS, reduced
    from repro.models import moe as moe_mod
    from repro.launch.mesh import rules_for

    cfg = reduced(ARCHS["grok-1-314b"], capacity_factor=8.0)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rules = rules_for(cfg)
    key = jax.random.PRNGKey(0)
    p = moe_mod.init_moe(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    ref_out, ref_aux = moe_mod.moe_ffn(x, p, cfg)
    with mesh:
        sm_out, sm_aux = jax.jit(
            lambda x, p: moe_mod.moe_ffn_shardmap(x, p, cfg, mesh, rules)
        )(x, p)
    a = np.asarray(ref_out, np.float32)
    b = np.asarray(sm_out, np.float32)
    rel = float(np.abs(a - b).max() / (np.abs(a).max() + 1e-9))
    # per-shard routing differs only via per-shard capacity; with
    # capacity_factor=8 nothing drops => must match closely
    print(json.dumps({"rel": rel}))
    import json as _j
""").replace("import json as _j", "")

_MOE_EQUIV_SCRIPT = "import json\n" + _MOE_EQUIV_SCRIPT


@pytest.mark.slow
def test_moe_shardmap_matches_reference():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _MOE_EQUIV_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    rel = json.loads(out.stdout.strip().splitlines()[-1])["rel"]
    assert rel < 0.05, f"shard_map MoE diverges: rel={rel}"


@pytest.mark.slow
def test_dryrun_tiny_mesh_compiles():
    """A miniature dry-run (8 fake devices, reduced arch) proves the
    lower+compile machinery end to end without the 512-device cost."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from repro.configs import ARCHS, reduced, SHAPES
        import dataclasses
        from repro.launch.mesh import rules_for
        from repro.sharding.specs import constrainer
        from repro.training import optim, train_step as TS
        from repro.models import model as M

        cfg = reduced(ARCHS["qwen2-0.5b"])
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rules = rules_for(cfg)
        constrain = constrainer(rules, mesh)
        opt = optim.OptConfig()
        state = jax.eval_shape(lambda: TS.init_train_state(
            cfg, opt, jax.random.PRNGKey(0)))
        batch = dict(tokens=jax.ShapeDtypeStruct((8, 32), jnp.int32),
                     labels=jax.ShapeDtypeStruct((8, 32), jnp.int32))
        fn = TS.make_train_step(cfg, opt, constrain)
        with mesh:
            compiled = jax.jit(fn).lower(state, batch).compile()
        assert compiled.memory_analysis() is not None
        print("OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


_EP_EQUIV_SCRIPT = """
import json, os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.configs import ARCHS, reduced
from repro.models import moe as moe_mod
from repro.launch.mesh import rules_for

cfg = reduced(ARCHS["arctic-480b"], capacity_factor=8.0)
mesh = jax.make_mesh((4, 2), ("data", "model"))
rules = rules_for(cfg, mode="decode")
p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
x = (jax.random.normal(jax.random.PRNGKey(1), (8, 1, cfg.d_model),
                       jnp.float32) * 0.3).astype(jnp.bfloat16)
ref, _ = moe_mod.moe_ffn(x, p, cfg)
with mesh:
    ep, _ = jax.jit(lambda x, p: moe_mod.moe_ffn_ep_decode(
        x, p, cfg, mesh, rules))(x, p)
a, b = np.asarray(ref, np.float32), np.asarray(ep, np.float32)
print(json.dumps({"rel": float(np.abs(a-b).max()/(np.abs(a).max()+1e-9))}))
"""


@pytest.mark.slow
def test_moe_ep_decode_matches_reference():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _EP_EQUIV_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    rel = json.loads(out.stdout.strip().splitlines()[-1])["rel"]
    assert rel < 0.05, f"EP decode diverges: rel={rel}"
