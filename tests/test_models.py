"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs; decode consistency for the serving path."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES, reduced
from repro.models import model as M
from repro.training import OptConfig, init_train_state, make_train_step

ALL_ARCHS = list(ARCHS)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced(ARCHS[arch])
    key = jax.random.PRNGKey(0)
    B, S = 2, 32
    if cfg.frontend == "embeds":
        batch = dict(embeds=jnp.ones((B, S, cfg.d_model), jnp.bfloat16),
                     labels=jnp.ones((B, S), jnp.int32))
    else:
        batch = dict(tokens=jnp.zeros((B, S), jnp.int32),
                     labels=jnp.ones((B, S), jnp.int32))
    params = M.init_params(cfg, key)
    logits, _, aux = M.forward(cfg, params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    state = init_train_state(cfg, opt, key)
    step = jax.jit(make_train_step(cfg, opt))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state["step"]) == 1


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-2.7b",
                                  "jamba-v0.1-52b"])
def test_decode_matches_forward(arch):
    cfg = reduced(ARCHS[arch], remat=False, capacity_factor=8.0)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              cfg.vocab)
    full, _, _ = M.forward(cfg, params, dict(tokens=toks))
    _, caches, _ = M.forward(cfg, params, dict(tokens=toks[:, :S]),
                             want_caches=True)
    s_max = 64
    serve = M.init_caches(cfg, B, s_max)
    new_serve = {}
    for kname, v in serve.items():
        pc = caches[kname]
        if "k" in pc:
            def put(sc, c):
                pad = [(0, 0)] * c.ndim
                pad[2] = (0, s_max - c.shape[2])
                return jnp.pad(c, pad)
            new_serve[kname] = dict(k=put(v["k"], pc["k"]),
                                    v=put(v["v"], pc["v"]))
        else:
            new_serve[kname] = pc
    logits_d, _ = M.decode_step_fn(cfg, params, new_serve, toks[:, S],
                                   jnp.int32(S))
    a = np.asarray(full[:, S, :], np.float32)
    b = np.asarray(logits_d, np.float32)
    rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
    assert rel < 0.05, f"decode diverges from forward: {rel}"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_count_close_to_label(arch):
    cfg = ARCHS[arch]
    n = cfg.param_count() / 1e9
    label = dict(
        **{"chameleon-34b": 34, "jamba-v0.1-52b": 52, "musicgen-large": 3.3,
           "grok-1-314b": 314, "arctic-480b": 480, "stablelm-3b": 2.8,
           "qwen2-0.5b": 0.5, "gemma-7b": 8.5, "qwen2-72b": 72,
           "mamba2-2.7b": 2.7})[arch]
    assert abs(n - label) / label < 0.35, f"{arch}: {n:.1f}B vs ~{label}B"


@pytest.mark.smoke
def test_input_specs_cover_all_cells():
    for arch, cfg in ARCHS.items():
        for shape in SHAPES.values():
            specs = M.input_specs(cfg, shape)
            assert specs, (arch, shape.name)
            for v in specs.values():
                assert isinstance(v, jax.ShapeDtypeStruct)


def test_long_500k_skip_rule():
    from repro.launch.dryrun import runnable
    n_run = sum(1 for cfg in ARCHS.values()
                if runnable(cfg, SHAPES["long_500k"]) is None)
    assert n_run == 2          # jamba + mamba2 only
