"""Hypothesis property tests on the system's invariants.

The central invariant from the paper (mergeability, [11]):
    estimate(merge(sketch(A), sketch(B))) ~= estimate(sketch(A ++ B))
plus structural properties: CM one-sided error, Bloom no-false-negatives,
HLL monotonicity, window conservation.
"""
import numpy as np
import jax
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st, HealthCheck

from repro import core

_settings = dict(deadline=None, max_examples=20,
                 suppress_health_check=[HealthCheck.too_slow,
                                        HealthCheck.data_too_large])

streams = st.lists(st.integers(0, 500), min_size=1, max_size=400)


def _feed(kind, items):
    items = np.asarray(items, np.uint32)
    return jax.jit(kind.add_batch)(
        kind.init(None), items, np.ones(len(items), np.float32),
        np.ones(len(items), bool))


@pytest.mark.smoke
@given(a=streams, b=streams)
@settings(**_settings)
def test_cm_merge_equals_concat(a, b):
    cm = core.CountMin(eps=0.02, delta=0.1)
    merged = cm.merge(_feed(cm, a), _feed(cm, b))
    both = _feed(cm, a + b)
    q = np.asarray(sorted(set(a + b))[:16], np.uint32)
    np.testing.assert_allclose(np.asarray(cm.estimate(merged, q)),
                               np.asarray(cm.estimate(both, q)), rtol=1e-5)


@given(a=streams, b=streams)
@settings(**_settings)
def test_hll_merge_equals_concat(a, b):
    h = core.HyperLogLog(rse=0.05)
    merged = h.merge(_feed(h, a), _feed(h, b))
    both = _feed(h, a + b)
    assert float(h.estimate(merged)) == pytest.approx(
        float(h.estimate(both)), rel=1e-6)


@given(a=streams, b=streams)
@settings(**_settings)
def test_fm_merge_commutative(a, b):
    fm = core.FMSketch(nmaps=32)
    m1 = fm.merge(_feed(fm, a), _feed(fm, b))
    m2 = fm.merge(_feed(fm, b), _feed(fm, a))
    assert float(fm.estimate(m1)) == float(fm.estimate(m2))


@given(items=streams)
@settings(**_settings)
def test_cm_never_underestimates(items):
    cm = core.CountMin(eps=0.05, delta=0.2)
    state = _feed(cm, items)
    q = np.asarray(sorted(set(items))[:16], np.uint32)
    est = np.asarray(cm.estimate(state, q))
    true = np.asarray([items.count(i) for i in q.tolist()], np.float32)
    assert (est >= true - 1e-4).all()


@given(items=streams)
@settings(**_settings)
def test_bloom_no_false_negatives(items):
    bl = core.BloomFilter(n_elements=500, fpr=0.05)
    state = _feed(bl, items)
    q = np.asarray(sorted(set(items)), np.uint32)
    assert bool(np.asarray(bl.estimate(state, q)).all())


@given(a=streams, b=streams)
@settings(**_settings)
def test_hll_monotone_under_union(a, b):
    h = core.HyperLogLog(rse=0.05)
    sa = _feed(h, a)
    merged = h.merge(sa, _feed(h, b))
    assert float(h.estimate(merged)) >= float(h.estimate(sa)) - 1e-6


@given(a=streams, b=streams)
@settings(**_settings)
def test_ams_merge_linear(a, b):
    ams = core.AMS(eps=0.1, delta=0.1)
    merged = ams.merge(_feed(ams, a), _feed(ams, b))
    both = _feed(ams, a + b)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(both),
                               rtol=1e-4, atol=1e-4)


@given(items=st.lists(st.floats(-100, 100, allow_nan=False,
                                width=32), min_size=8, max_size=300))
@settings(**_settings)
def test_gk_rank_bounded(items):
    gk = core.GKQuantiles(eps=0.05)
    arr = np.asarray(items, np.float32)
    state = jax.jit(gk.add_batch)(gk.init(None),
                                  np.zeros(len(arr), np.uint32), arr,
                                  np.ones(len(arr), bool))
    med = float(gk.estimate(state, np.array([0.5], np.float32))[0])
    tol = 6 * gk.eps + 1.0 / len(arr)
    # tie-safe rank bracket: strict rank below, weak rank above the target
    assert (arr < med).mean() <= 0.5 + tol
    assert (arr <= med).mean() >= 0.5 - tol


@given(n_a=st.integers(32, 300), n_b=st.integers(32, 300))
@settings(**_settings)
def test_reservoir_merge_count(n_a, n_b):
    """Merging two warm reservoirs keeps the union count and a full,
    well-sourced sample (items come from either input stream)."""
    rs = core.ReservoirSampler(sample_size=32)
    a = _feed(rs, list(range(n_a)))
    b = _feed(rs, list(range(1000, 1000 + n_b)))
    merged = rs.merge(a, b)
    assert int(merged["n_seen"]) == n_a + n_b
    out = rs.estimate(merged)
    assert int(np.asarray(out["valid"]).sum()) == 32
    sample = np.asarray(out["items"])
    assert (((sample < n_a) | ((sample >= 1000) & (sample < 1000 + n_b)))
            .all())
