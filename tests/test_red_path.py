"""Sharded red path: jitted batched stacked-estimate programs.

Covers the PR 2 contract:
  * per-kind correctness — ``stacked_estimate`` over a row batch equals the
    scalar ``estimate`` per row for EVERY registered kind;
  * scale — ``query_many`` answers N queries against a kind with exactly
    ONE jitted dispatch per kind per query batch, and repeated same-shape
    batches reuse ONE compiled program (trace-count probe);
  * continuous queries — emission is one stacked-estimate program per kind
    per ingest batch, never a per-entry ``stacked_row`` gather, including
    on a multi-device ``synopsis``-sharded mesh;
  * satellites — actual per-row status bytes, stream-id routing guard.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import core
from repro.core import batched
from repro.kernels import ops as kops
from repro.service import SDE, Federation, api
from repro.service import engine as engine_mod


# ---------------------------------------------------------------------------
# per-kind equivalence: stacked_estimate == per-row scalar estimate
# ---------------------------------------------------------------------------
_PARAMS = {
    "countmin": {"eps": 0.05, "delta": 0.1, "weighted": False},
    "hyperloglog": {"rse": 0.05},
    "ams": {"eps": 0.2, "delta": 0.2},
    "bloom": {"n_elements": 256, "fpr": 0.02},
    "fm": {"nmaps": 16},
    "dft": {"window": 16, "n_coeffs": 4},
    "rhp": {"n_bits": 32},
    "lossy_counting": {"eps": 0.05},
    "sticky_sampling": {},
    "chain_sampler": {"sample_size": 16},
    "gk_quantiles": {"eps": 0.05},
    "coreset_tree": {"bucket_size": 32, "dim": 1},
}


def _query_args(kind_name, n, rng):
    """Per-query args with a leading [n] axis (each query distinct)."""
    if kind_name in ("countmin", "bloom", "lossy_counting",
                     "sticky_sampling"):
        return (jnp.asarray(rng.randint(0, 50, (n, 3)).astype(np.uint32)),)
    if kind_name == "gk_quantiles":
        return (jnp.asarray(rng.uniform(0.0, 1.0, (n, 4)).astype(
            np.float32)),)
    return ()


@pytest.mark.parametrize("kind_name", sorted(core.known_kinds()))
def test_stacked_estimate_matches_per_row(kind_name):
    kind = core.make_kind(kind_name, **_PARAMS[kind_name])
    cap = 8
    state = batched.stacked_init(kind, cap)
    rng = np.random.RandomState(0)
    t = 32
    syn = jnp.asarray(rng.randint(0, cap, t).astype(np.int32))
    items = jnp.asarray(rng.randint(0, 50, t).astype(np.uint32))
    vals = jnp.asarray(rng.uniform(0.5, 2.0, t).astype(np.float32))
    mask = jnp.ones(t, bool)
    state = batched.stacked_update(kind, state, syn, items, vals, mask)

    row_list = [5, 0, 3, 5]        # duplicates allowed: N queries, one row
    rows = jnp.asarray(row_list, jnp.int32)
    args = _query_args(kind_name, len(row_list), rng)
    out = batched.stacked_estimate(kind, state, rows, *args)
    out = jax.tree.map(np.asarray, out)
    for i, r in enumerate(row_list):
        single = kind.estimate(batched.stacked_row(state, r),
                               *[a[i] for a in args])
        jax.tree.map(
            lambda g, s: np.testing.assert_allclose(
                np.asarray(g), np.asarray(s), rtol=1e-5, atol=1e-5),
            jax.tree.map(lambda x: x[i], out),
            jax.tree.map(np.asarray, single))


def test_pane_window_stacked_estimate_matches_per_row():
    """The window wrapper (not in the registry) batches too: pane merge +
    inner estimate vmapped over the gathered rows."""
    kind = core.PaneWindow(core.CountMin(eps=0.05, delta=0.1,
                                         weighted=False),
                           n_panes=2, pane_span=64)
    cap = 4
    state = batched.stacked_init(kind, cap)
    rng = np.random.RandomState(0)
    syn = jnp.asarray(rng.randint(0, cap, 32).astype(np.int32))
    items = jnp.asarray(rng.randint(0, 20, 32).astype(np.uint32))
    ones = jnp.ones(32, jnp.float32)
    state = batched.stacked_update(kind, state, syn, items, ones,
                                   jnp.ones(32, bool))
    rows = jnp.asarray([2, 0], jnp.int32)
    q_items = jnp.asarray(rng.randint(0, 20, (2, 3)).astype(np.uint32))
    out = np.asarray(batched.stacked_estimate(kind, state, rows, q_items))
    for i, r in enumerate([2, 0]):
        single = kind.estimate(batched.stacked_row(state, r), q_items[i])
        np.testing.assert_allclose(out[i], np.asarray(single))


def test_batched_estimate_is_one_program():
    """jax.make_jaxpr probe: ONE program answers N queries with their own
    per-query items — the batched output aval carries the [N, I] axes."""
    kind = core.CountMin(eps=0.031, delta=0.1, weighted=False)
    state = batched.stacked_init(kind, 16)
    rows = jnp.arange(8, dtype=jnp.int32)
    items = jnp.zeros((8, 4), jnp.uint32)
    jaxpr = jax.make_jaxpr(
        lambda s, r, it: batched.stacked_estimate(kind, s, r, it))(
            state, rows, items)
    assert jaxpr.out_avals[0].shape == (8, 4)


# ---------------------------------------------------------------------------
# query_many: one dispatch per kind per batch, one compiled program
# ---------------------------------------------------------------------------
@pytest.mark.smoke
def test_query_many_single_dispatch_per_kind():
    eng = SDE()
    # unique params => unique jit-cache key, so the trace count below is
    # not satisfied by a program compiled in another test
    r = eng.handle({"type": "build", "request_id": "b1",
                    "synopsis_id": "cm", "kind": "countmin",
                    "params": {"eps": 0.017, "delta": 0.1,
                               "weighted": False},
                    "per_stream_of_source": True, "n_streams": 50})
    assert r.ok, r.error
    r = eng.handle({"type": "build", "request_id": "b2",
                    "synopsis_id": "hll", "kind": "hyperloglog",
                    "params": {"rse": 0.0417}})
    assert r.ok, r.error
    rng = np.random.RandomState(0)
    sids = rng.randint(0, 50, 512).astype(np.uint32)
    eng.ingest(sids, np.ones(512, np.float32))

    reqs = [api.AdHocQuery(request_id=f"q{s}", synopsis_id=f"cm/{s}",
                           query={"items": [int(s)]})
            for s in range(20)]
    reqs.append(api.AdHocQuery(request_id="qh", synopsis_id="hll"))
    kops.DISPATCH_COUNT.clear()
    kops.TRACE_COUNT.clear()
    n_batches = 3
    for _ in range(n_batches):
        rs = eng.query_many(reqs)
    # N queries against a kind = ONE dispatch for that kind per batch
    assert kops.DISPATCH_COUNT["CountMin"] == n_batches
    assert kops.DISPATCH_COUNT["HyperLogLog"] == n_batches
    # ... and every same-shape batch reuses ONE compiled program
    assert kops.TRACE_COUNT["CountMin"] == 1
    assert kops.TRACE_COUNT["HyperLogLog"] == 1
    # correctness: unweighted per-stream CM counts are exact
    for s in range(20):
        assert float(rs[s].value[0]) == float((sids == s).sum()), s
    assert abs(float(rs[20].value) - 50) < 10


def test_query_many_mixed_arg_lengths_and_errors():
    eng = SDE()
    eng.handle({"type": "build", "request_id": "b", "synopsis_id": "cm",
                "kind": "countmin",
                "params": {"eps": 0.02, "delta": 0.1, "weighted": False},
                "per_stream_of_source": True, "n_streams": 8})
    sids = np.arange(8, dtype=np.uint32).repeat(16)
    eng.ingest(sids, np.ones(len(sids), np.float32))
    rs = eng.query_many([
        api.AdHocQuery(request_id="a", synopsis_id="cm/1",
                       query={"items": [1]}),
        api.AdHocQuery(request_id="b", synopsis_id="nope"),
        api.AdHocQuery(request_id="c", synopsis_id="cm/2",
                       query={"items": [2, 3, 4]}),
    ])
    assert rs[0].ok and len(rs[0].value) == 1
    assert float(rs[0].value[0]) == 16.0
    assert not rs[1].ok and "unknown synopsis" in rs[1].error
    # padded arg width is sliced back to the query's own length
    assert rs[2].ok and len(rs[2].value) == 3
    assert float(rs[2].value[0]) == 16.0
    # one query with uncoercible args fails alone, not the whole batch
    rs = eng.query_many([
        api.AdHocQuery(request_id="good", synopsis_id="cm/1",
                       query={"items": [1]}),
        api.AdHocQuery(request_id="bad", synopsis_id="cm/2",
                       query={"items": ["oops"]}),
    ])
    assert rs[0].ok and float(rs[0].value[0]) == 16.0
    assert not rs[1].ok and "items" in rs[1].error
    # ... and so does one whose query field is not an object at all
    rs = eng.query_many([
        api.AdHocQuery(request_id="bad2", synopsis_id="cm/2", query=5),
        api.AdHocQuery(request_id="good2", synopsis_id="cm/3",
                       query={"items": [3]}),
    ])
    assert not rs[0].ok and "must be an object" in rs[0].error
    assert rs[1].ok and float(rs[1].value[0]) == 16.0


def test_query_many_json_request():
    eng = SDE()
    eng.handle({"type": "build", "request_id": "b", "synopsis_id": "h",
                "kind": "hyperloglog", "params": {"rse": 0.05}})
    eng.ingest(np.arange(200, dtype=np.uint32), np.ones(200, np.float32))
    resp = eng.handle({"type": "query_many", "request_id": "m",
                       "queries": [{"synopsis_id": "h"},
                                   {"synopsis_id": "h"}]})
    assert resp.ok
    assert len(resp.value) == 2
    for sub in resp.value:
        assert sub["ok"] and abs(float(sub["value"]) - 200) < 40
    # a non-dict entry fails alone; the rest of the batch still answers
    resp = eng.handle({"type": "query_many", "request_id": "m2",
                       "queries": [{"synopsis_id": "h"}, "oops"]})
    assert not resp.ok and len(resp.value) == 2
    assert resp.error == "1/2 queries failed"
    assert resp.value[0]["ok"]
    assert abs(float(resp.value[0]["value"]) - 200) < 40
    assert not resp.value[1]["ok"]
    assert "must be an object" in resp.value[1]["error"]
    # falsy non-dict query fields are rejected too, not coerced to {}
    resp = eng.handle({"type": "query_many", "request_id": "m3",
                       "queries": [{"synopsis_id": "h", "query": 0}]})
    assert not resp.ok
    assert "must be an object" in resp.value[0]["error"]


def test_federated_query_single_fused_dispatch():
    fed = Federation(["eu", "us"])
    fed.broadcast({"type": "build", "request_id": "f", "synopsis_id": "h",
                   "kind": "hyperloglog", "params": {"rse": 0.03},
                   "federated": True, "responsible_site": "eu"})
    fed.sdes["eu"].ingest(np.arange(0, 2000, dtype=np.uint32),
                          np.ones(2000, np.float32))
    fed.sdes["us"].ingest(np.arange(1000, 3000, dtype=np.uint32),
                          np.ones(2000, np.float32))
    kops.DISPATCH_COUNT.clear()
    est = float(fed.query_federated("h", {}, "eu"))
    # merge-over-sites + estimate fused into one program
    assert kops.DISPATCH_COUNT["HyperLogLog"] == 1
    assert abs(est - 3000) / 3000 < 0.1


# ---------------------------------------------------------------------------
# continuous queries: one program per kind per ingest, never stacked_row
# ---------------------------------------------------------------------------
def test_continuous_emission_batched_no_stacked_row(monkeypatch):
    eng = SDE()
    r = eng.handle({"type": "build", "request_id": "b1",
                    "synopsis_id": "cm", "kind": "countmin",
                    "params": {"eps": 0.02, "delta": 0.1,
                               "weighted": False},
                    "per_stream_of_source": True, "n_streams": 10,
                    "continuous": True})
    assert r.ok, r.error
    r = eng.handle({"type": "build", "request_id": "b2",
                    "synopsis_id": "h", "kind": "hyperloglog",
                    "params": {"rse": 0.05}, "continuous": True})
    assert r.ok, r.error

    def boom(*a, **k):
        raise AssertionError("red path gathered a row to the host")

    monkeypatch.setattr(batched, "stacked_row", boom)
    plans = []
    orig_plan = engine_mod._plan_queries
    monkeypatch.setattr(engine_mod, "_plan_queries",
                        lambda *a: plans.append(1) or orig_plan(*a))
    kops.DISPATCH_COUNT.clear()
    sids = np.arange(10, dtype=np.uint32).repeat(20)
    eng.ingest(sids, np.ones(len(sids), np.float32))
    # 10 per-stream CMs + 1 HLL, all continuous
    assert len(eng.continuous_out) == 11
    assert kops.DISPATCH_COUNT["CountMin"] == 1
    assert kops.DISPATCH_COUNT["HyperLogLog"] == 1
    # the grouping + arg planning is cached: further ingests re-dispatch
    # without re-planning on the host
    eng.ingest(sids, np.ones(len(sids), np.float32))
    assert len(eng.continuous_out) == 22
    assert len(plans) == 2          # one plan per kind, first ingest only
    hll_out = [o for o in eng.continuous_out if o.synopsis_id == "h"]
    assert abs(float(hll_out[0].value) - 10) < 5
    # lifecycle changes rebuild the grouping
    eng.handle({"type": "stop", "request_id": "s", "synopsis_id": "h"})
    eng.ingest(sids, np.ones(len(sids), np.float32))
    assert len(eng.continuous_out) == 22 + 10
    assert len(plans) == 3          # replanned once after the stop


_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    from jax.sharding import NamedSharding
    from repro.service import SDE, api
    from repro.kernels import ops as kops

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    eng = SDE(mesh=mesh)
    eng.handle({"type": "build", "request_id": "b", "synopsis_id": "cm",
                "kind": "countmin",
                "params": {"eps": 0.01, "delta": 0.05, "weighted": False},
                "per_stream_of_source": True, "n_streams": 12,
                "continuous": True})
    eng.handle({"type": "build", "request_id": "b2", "synopsis_id": "h",
                "kind": "hyperloglog", "params": {"rse": 0.03},
                "continuous": True})
    rng = np.random.RandomState(0)
    sids = rng.randint(0, 12, 512).astype(np.uint32)
    kops.DISPATCH_COUNT.clear()
    n_batches = 3
    for _ in range(n_batches):
        eng.ingest(sids, np.ones(512, np.float32))
    # all continuous queries of a kind = ONE estimate dispatch per ingest
    assert kops.DISPATCH_COUNT["CountMin"] == n_batches
    assert kops.DISPATCH_COUNT["HyperLogLog"] == n_batches
    assert len(eng.continuous_out) == n_batches * 13
    # state stays row-sharded over the synopsis axis after queries
    for stack in eng.stacks.values():
        for leaf in jax.tree.leaves(stack.state):
            assert isinstance(leaf.sharding, NamedSharding)
            assert leaf.sharding.spec and leaf.sharding.spec[0] == "data"
    # batched ad-hoc values against the sharded stack are exact
    reqs = [api.AdHocQuery(request_id=f"q{s}", synopsis_id=f"cm/{s}",
                           query={"items": [int(s)]}) for s in range(12)]
    rs = eng.query_many(reqs)
    for s, r in enumerate(rs):
        got = float(r.value[0])
        want = float(n_batches) * float((sids == s).sum())
        assert got == want, (s, got, want)
    last = [o for o in eng.continuous_out if o.synopsis_id == "h"][-1]
    assert abs(float(last.value) - 12) < 6
    print("OK")
""")


def test_continuous_queries_on_multidevice_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _MESH_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


# ---------------------------------------------------------------------------
# satellites: actual per-row bytes in status, stream-id routing guard
# ---------------------------------------------------------------------------
def test_status_reports_actual_row_bytes():
    eng = SDE()
    r = eng.handle({"type": "build", "request_id": "b", "synopsis_id":
                    "bf", "kind": "bloom",
                    "params": {"n_elements": 100, "fpr": 0.01},
                    "stream_id": 1})
    assert r.ok, r.error
    st = eng.handle({"type": "status", "request_id": "s"})
    kind = eng.entries["bf"].kind_key
    # bits are int32 lanes in the stacked state: 4 bytes per bit, not the
    # packed n_bits/8 the abstract kind declares
    assert st.value["bf"]["memory_bytes"] == kind.n_bits * 4
    assert st.value["bf"]["memory_bytes"] != kind.memory_bytes()
    # and the row slice accounts for the whole engine state
    stack = eng.stacks[kind]
    assert stack.row_bytes() * stack.capacity == eng.memory_bytes()


def test_register_stream_id_guard():
    """Hashed routing: ids past the old 2**16 dense-table cap build and
    route fine; only unrepresentable ids (negative / >= 2**63) are
    rejected — and rejected BEFORE committing anything."""
    eng = SDE()
    for bad in (-1, 1 << 63, (1 << 63) + 5):
        r = eng.handle({"type": "build", "request_id": "b",
                        "synopsis_id": f"x{bad}", "kind": "hyperloglog",
                        "params": {"rse": 0.05}, "stream_id": bad})
        assert not r.ok and "2**63" in r.error, bad
    # a per-stream build with one unrepresentable id fails atomically
    r = eng.handle({"type": "build", "request_id": "b", "synopsis_id":
                    "big", "kind": "hyperloglog", "params": {"rse": 0.05},
                    "per_stream_of_source": True,
                    "stream_ids": [7, -3]})
    assert not r.ok and "2**63" in r.error
    assert not eng.entries and not eng.stacks   # nothing committed
    # ids far past the old 65536-slot table are accepted and routable
    sid = (1 << 16) + 12345
    r = eng.handle({"type": "build", "request_id": "b", "synopsis_id":
                    "ok", "kind": "hyperloglog", "params": {"rse": 0.05},
                    "stream_id": sid})
    assert r.ok, r.error
    eng.ingest(np.full(64, sid, np.int64), np.ones(64, np.float32))
    q = eng.handle({"type": "adhoc", "request_id": "q", "synopsis_id":
                    "ok"})
    assert float(q.value) > 0
    # tuples of OTHER high ids update nothing here (no clamping onto
    # this synopsis) but still count as ingested — they are valid data
    before = float(q.value)
    seen = eng.tuples_ingested
    eng.ingest(np.full(8, sid + 1, np.int64), np.ones(8, np.float32))
    assert eng.tuples_ingested == seen + 8
    # negative ids are unrepresentable: dropped, not counted
    eng.ingest(np.full(8, -5, np.int64), np.ones(8, np.float32))
    assert eng.tuples_ingested == seen + 8
    q = eng.handle({"type": "adhoc", "request_id": "q2", "synopsis_id":
                    "ok"})
    assert float(q.value) == before


# ---------------------------------------------------------------------------
# balancer satellite: workload estimation rides the batched path
# ---------------------------------------------------------------------------
def test_balancer_uses_batched_query_path():
    from repro.service.balancer import estimate_workload
    eng = SDE()
    eng.handle({"type": "build", "request_id": "b1", "synopsis_id":
                "card", "kind": "hyperloglog", "params": {"rse": 0.03}})
    eng.handle({"type": "build", "request_id": "b2", "synopsis_id":
                "freq", "kind": "countmin",
                "params": {"eps": 0.005, "delta": 0.01,
                           "weighted": False}})
    sids = np.arange(32, dtype=np.uint32).repeat(8)
    eng.ingest(sids, np.ones(len(sids), np.float32))
    kops.DISPATCH_COUNT.clear()
    n_active, loads = estimate_workload(eng, "card", "freq",
                                        list(range(32)))
    # the 32 per-stream loads are ONE CM dispatch, not 32
    assert kops.DISPATCH_COUNT["CountMin"] == 1
    assert kops.DISPATCH_COUNT["HyperLogLog"] == 1
    assert abs(n_active - 32) < 6
    np.testing.assert_allclose(loads, 8.0)
    with pytest.raises(KeyError):
        estimate_workload(eng, "missing", "freq", [0])
