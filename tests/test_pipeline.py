"""Pipelined blue path: bounded async ingest queue semantics (PR 4).

Covers the tentpole contract:
  * eager-vs-pipelined EXACT equivalence per registered kind —
    byte-identical synopsis state and identical continuous responses
    (ids and values, in the same order);
  * fencing — stop/grow/snapshot/merge mid-flight retire every pending
    batch before mutating stacks or routing tables, and ``query_many``
    drains before reading state;
  * flush — the explicit barrier drains ALL pending batches; monotonic
    batch-counter request ids are preserved under overlap;
  * satellites — bounded ``continuous_out`` with a dropped-count stat,
    in-flight depth probes in ``kernels.ops``, ingest length-mismatch
    guard, JSON ``ingest``/``flush`` requests with batch-counter acks,
    and the launch-layer JSON-lines server.
"""
import io
import json
import tempfile

import numpy as np
import jax
import pytest

from repro import core
from repro.kernels import ops as kops
from repro.service import SDE

_PARAMS = {
    "countmin": {"eps": 0.05, "delta": 0.1, "weighted": False},
    "hyperloglog": {"rse": 0.05},
    "ams": {"eps": 0.2, "delta": 0.2},
    "bloom": {"n_elements": 256, "fpr": 0.02},
    "fm": {"nmaps": 16},
    "dft": {"window": 16, "n_coeffs": 4},
    "rhp": {"n_bits": 32},
    "lossy_counting": {"eps": 0.05},
    "sticky_sampling": {},
    "chain_sampler": {"sample_size": 16},
    "gk_quantiles": {"eps": 0.05},
    "coreset_tree": {"bucket_size": 32, "dim": 1},
}

_N_STREAMS = 6


def _build_continuous(eng: SDE, kind_name: str):
    r = eng.handle({"type": "build", "request_id": f"b-{kind_name}",
                    "synopsis_id": kind_name, "kind": kind_name,
                    "params": _PARAMS[kind_name],
                    "per_stream_of_source": True,
                    "n_streams": _N_STREAMS, "continuous": True})
    assert r.ok, r.error


def _batches(n_batches=5, tuples=24, seed=0):
    # tuples <= 32: the coreset kind ingests at most bucket_size points
    # per batch, and every other kind is size-agnostic
    rng = np.random.RandomState(seed)
    return [(rng.randint(0, _N_STREAMS, tuples).astype(np.uint32),
             rng.uniform(0.5, 2.0, tuples).astype(np.float32))
            for _ in range(n_batches)]


def _assert_engines_equal(eager: SDE, piped: SDE):
    """Byte-identical stack state + identical continuous responses."""
    assert list(eager.stacks) == list(piped.stacks)
    for kind in eager.stacks:
        for a, b in zip(jax.tree.leaves(eager.stacks[kind].state),
                        jax.tree.leaves(piped.stacks[kind].state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert len(eager.continuous_out) == len(piped.continuous_out)
    for ra, rb in zip(eager.continuous_out, piped.continuous_out):
        assert ra.request_id == rb.request_id
        assert ra.synopsis_id == rb.synopsis_id
        jax.tree.map(
            lambda x, y: np.testing.assert_array_equal(
                np.asarray(x), np.asarray(y)), ra.value, rb.value)


# ---------------------------------------------------------------------------
# tentpole: exact equivalence per kind
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind_name", sorted(core.known_kinds()))
def test_eager_vs_pipelined_equivalence(kind_name):
    eager = SDE(pipelined=False)
    piped = SDE(pipelined=True)
    for eng in (eager, piped):
        _build_continuous(eng, kind_name)
    for sids, vals in _batches():
        eager.ingest(sids, vals)
        piped.ingest(sids, vals)
    assert piped.pending_batches > 0     # emission actually deferred
    piped.flush()
    _assert_engines_equal(eager, piped)


def test_equivalence_multi_kind_single_engine():
    """Several kinds (incl. the time-series path) in ONE engine, many
    batches: interleaved per-kind dispatches retire in ingest order."""
    names = ["countmin", "hyperloglog", "dft"]
    eager = SDE(pipelined=False)
    piped = SDE(pipelined=True)
    for eng in (eager, piped):
        for name in names:
            _build_continuous(eng, name)
    for sids, vals in _batches(n_batches=7, seed=3):
        eager.ingest(sids, vals)
        piped.ingest(sids, vals)
    piped.flush()
    _assert_engines_equal(eager, piped)


# ---------------------------------------------------------------------------
# tentpole: no host sync inside pipelined ingest
# ---------------------------------------------------------------------------
def test_pipelined_ingest_defers_materialization(monkeypatch):
    """A pipelined ingest must NOT materialize estimate outputs to host
    (the eager path's ``jax.tree.map(np.asarray, out)`` sync)."""
    piped = SDE(pipelined=True)
    _build_continuous(piped, "hyperloglog")
    sids, vals = _batches(1)[0]
    piped.ingest(sids, vals)             # warm up: plan + compile
    piped.flush()

    synced = []
    orig = np.asarray

    def spying_asarray(x, *a, **k):
        if isinstance(x, jax.Array):
            synced.append(type(x).__name__)
        return orig(x, *a, **k)

    monkeypatch.setattr(np, "asarray", spying_asarray)
    piped.ingest(sids, vals)
    assert synced == []                  # ingest returned with zero syncs
    monkeypatch.undo()
    assert piped.flush() == 1            # the sync happens at the barrier
    assert len(piped.continuous_out) == 2 * _N_STREAMS


# ---------------------------------------------------------------------------
# bounded queue: depth, retirement on overflow, flush drains all
# ---------------------------------------------------------------------------
@pytest.mark.smoke
def test_bounded_depth_and_flush_drains_all():
    eng = SDE(pipelined=True, pipeline_depth=2)
    _build_continuous(eng, "hyperloglog")
    batches = _batches(n_batches=3)
    eng.ingest(*batches[0])
    assert eng.pending_batches == 1 and len(eng.continuous_out) == 0
    eng.ingest(*batches[1])
    assert eng.pending_batches == 2 and len(eng.continuous_out) == 0
    # the 3rd submission exceeds depth 2: batch 1 retires, 2+3 in flight
    eng.ingest(*batches[2])
    assert eng.pending_batches == 2
    assert len(eng.continuous_out) == _N_STREAMS
    assert all(r.request_id.endswith("/1") for r in eng.continuous_out)
    # explicit barrier drains everything, oldest first; idempotent
    assert eng.flush() == 2
    assert eng.pending_batches == 0
    assert len(eng.continuous_out) == 3 * _N_STREAMS
    assert eng.flush() == 0


def test_monotonic_batch_ids_under_overlap():
    eng = SDE(pipelined=True, pipeline_depth=2)
    _build_continuous(eng, "hyperloglog")
    n = 5
    got_ids = [eng.ingest(*b) for b in _batches(n_batches=n)]
    assert got_ids == list(range(1, n + 1))
    eng.flush()
    rids = [r.request_id for r in eng.continuous_out]
    assert len(set(rids)) == len(rids)
    # responses surface in ingest order with the batch counter intact
    batch_of = [int(r.rsplit("/", 1)[1]) for r in rids]
    assert batch_of == sorted(batch_of)
    assert set(batch_of) == set(range(1, n + 1))


def test_in_flight_depth_probes():
    tag = "probe-site"
    kops.PIPELINE_IN_FLIGHT.pop(tag, None)
    kops.PIPELINE_MAX_IN_FLIGHT.pop(tag, None)
    eng = SDE(site=tag, pipelined=True, pipeline_depth=2)
    _build_continuous(eng, "hyperloglog")
    for b in _batches(n_batches=4):
        eng.ingest(*b)
    # the bounded queue really double-buffers: depth reached, never beyond
    assert kops.PIPELINE_MAX_IN_FLIGHT[tag] == 2
    assert kops.PIPELINE_IN_FLIGHT[tag] == 2
    eng.flush()
    assert kops.PIPELINE_IN_FLIGHT[tag] == 0
    assert kops.PIPELINE_MAX_IN_FLIGHT[tag] == 2


def test_bad_pipeline_depth_rejected():
    with pytest.raises(ValueError, match="depth"):
        SDE(pipelined=True, pipeline_depth=0)


# ---------------------------------------------------------------------------
# fencing: lifecycle events drain the pipeline before mutating state
# ---------------------------------------------------------------------------
def test_stop_fences_mid_flight():
    eng = SDE(pipelined=True)
    _build_continuous(eng, "hyperloglog")
    eng.ingest(*_batches(1)[0])
    assert eng.pending_batches == 1
    r = eng.handle({"type": "stop", "request_id": "s",
                    "synopsis_id": "hyperloglog"})
    assert r.ok, r.error
    # the stopped synopses' final responses landed BEFORE the rows freed
    assert eng.pending_batches == 0
    assert len(eng.continuous_out) == _N_STREAMS


def test_build_grow_fences_mid_flight():
    """A build that doubles stack capacity mid-flight must retire the
    pending batches first — and stay exactly equivalent to eager."""
    eager = SDE(pipelined=False)
    piped = SDE(pipelined=True)
    for eng in (eager, piped):
        _build_continuous(eng, "countmin")
    batches = _batches(n_batches=2, seed=5)
    for b in batches:
        eager.ingest(*b)
        piped.ingest(*b)
    assert piped.pending_batches == 2
    # 100 more routed synopses of the same kind (fresh stream ids —
    # each id routes to one row per kind): forces 64 -> 128 growth
    grow = {"type": "build", "request_id": "g", "synopsis_id": "more",
            "kind": "countmin", "params": _PARAMS["countmin"],
            "per_stream_of_source": True,
            "stream_ids": list(range(100, 200))}
    assert eager.handle(grow).ok
    assert piped.handle(grow).ok
    assert piped.pending_batches == 0
    assert piped.stacks[next(iter(piped.stacks))].capacity == 128
    for b in _batches(n_batches=2, seed=6):
        eager.ingest(*b)
        piped.ingest(*b)
    piped.flush()
    _assert_engines_equal(eager, piped)


def test_snapshot_fences_mid_flight():
    eager = SDE(pipelined=False)
    piped = SDE(pipelined=True)
    for eng in (eager, piped):
        _build_continuous(eng, "countmin")
    for b in _batches(n_batches=3, seed=7):
        eager.ingest(*b)
        piped.ingest(*b)
    assert piped.pending_batches > 0
    with tempfile.TemporaryDirectory() as d:
        piped.snapshot(d, 1)
        # snapshot is itself a fence ...
        assert piped.pending_batches == 0
        restored = SDE.restore(d)
    # ... and the checkpointed state equals the eager engine's
    piped.flush()
    _assert_engines_equal(eager, piped)
    for kind in eager.stacks:
        for a, b in zip(jax.tree.leaves(eager.stacks[kind].state),
                        jax.tree.leaves(restored.stacks[kind].state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_query_many_fences_mid_flight():
    eng = SDE(pipelined=True)
    _build_continuous(eng, "countmin")
    sids, vals = _batches(1, seed=9)[0]
    eng.ingest(sids, np.ones_like(vals))
    assert eng.pending_batches == 1
    q = eng.handle({"type": "adhoc", "request_id": "q",
                    "synopsis_id": "countmin/2", "query": {"items": [2]}})
    assert q.ok, q.error
    # the read fenced first: continuous responses precede the answer ...
    assert eng.pending_batches == 0
    assert len(eng.continuous_out) == _N_STREAMS
    # ... and the answer observes the in-flight batch (exact CM count)
    assert float(q.value[0]) == float((sids == 2).sum())


def test_merge_from_fences_both_engines():
    a = SDE(pipelined=True)
    b = SDE(site="site-b", pipelined=True)
    for eng in (a, b):
        _build_continuous(eng, "hyperloglog")
    a.ingest(np.arange(0, 40, dtype=np.uint32) % _N_STREAMS,
             np.ones(40, np.float32))
    b.ingest(np.arange(0, 40, dtype=np.uint32) % _N_STREAMS,
             np.ones(40, np.float32))
    assert a.pending_batches == 1 and b.pending_batches == 1
    a.merge_from(b)
    assert a.pending_batches == 0 and b.pending_batches == 0
    assert len(a.continuous_out) == _N_STREAMS
    assert len(b.continuous_out) == _N_STREAMS


# ---------------------------------------------------------------------------
# satellite: bounded continuous_out
# ---------------------------------------------------------------------------
def test_continuous_out_bounded_with_dropped_stat():
    eng = SDE(pipelined=False, continuous_out_cap=3)
    r = eng.handle({"type": "build", "request_id": "b", "synopsis_id":
                    "h", "kind": "hyperloglog", "params": {"rse": 0.05},
                    "continuous": True})
    assert r.ok, r.error
    for b in _batches(n_batches=5):
        eng.ingest(*b)
    # newest 3 kept, oldest 2 dropped (and counted)
    assert len(eng.continuous_out) == 3
    assert eng.continuous_out.dropped == 2
    assert [r.request_id for r in eng.continuous_out] == \
        ["cq/h/3", "cq/h/4", "cq/h/5"]
    # cap=None / 0 means unbounded
    assert SDE(continuous_out_cap=None).continuous_out.maxlen is None
    assert SDE(continuous_out_cap=0).continuous_out.maxlen is None


# ---------------------------------------------------------------------------
# satellite: ingest input hygiene
# ---------------------------------------------------------------------------
def test_ingest_length_mismatch_is_clear_error():
    eng = SDE()
    with pytest.raises(ValueError, match="2 stream_ids vs 3 values"):
        eng.ingest([1, 2], [1.0, 2.0, 3.0])
    # a wrong-length mask is rejected too (never silently broadcast)
    with pytest.raises(ValueError, match="3 stream_ids vs 1 mask"):
        eng.ingest([1, 2, 3], [1.0, 2.0, 3.0], mask=[False])
    # nothing committed: the counters never moved
    assert eng.tuples_ingested == 0 and eng.batches_ingested == 0
    # the JSON path surfaces the same error as a response, not a crash
    r = eng.handle({"type": "ingest", "request_id": "i",
                    "stream_ids": [1, 2], "values": [1.0]})
    assert not r.ok and "stream_ids" in r.error


def test_ingest_no_copy_for_float32_values():
    """float32 input must flow through np.asarray un-copied."""
    vals = np.ones(8, np.float32)
    assert np.asarray(vals, np.float32) is vals          # the invariant
    eng = SDE()
    _build_continuous(eng, "countmin")
    eng.ingest(np.arange(8, dtype=np.uint32) % _N_STREAMS, vals)
    assert eng.tuples_ingested == 8


# ---------------------------------------------------------------------------
# satellite: JSON ingest/flush requests + the launch JSON-lines server
# ---------------------------------------------------------------------------
@pytest.mark.smoke
def test_json_ingest_ack_carries_batch_counter():
    eng = SDE(pipelined=True)
    _build_continuous(eng, "hyperloglog")
    a1 = eng.handle({"type": "ingest", "request_id": "i1",
                     "stream_ids": [0, 1, 2], "values": [1.0, 1.0, 1.0]})
    a2 = eng.handle({"type": "ingest", "request_id": "i2",
                     "stream_ids": [3, 4], "values": [1.0, 1.0],
                     "mask": [True, False]})
    assert a1.ok and a1.value["batch"] == 1
    assert a2.ok and a2.value["batch"] == 2
    assert a2.value["tuples_ingested"] == 4      # one tuple masked out
    assert a2.value["in_flight"] == 2
    fl = eng.handle({"type": "flush", "request_id": "f"})
    assert fl.ok and fl.value["drained"] == 2
    assert fl.value["batches_ingested"] == 2
    assert len(eng.continuous_out) == 2 * _N_STREAMS
    # flush on an idle pipeline (and on eager engines) is a cheap no-op
    assert eng.handle({"type": "flush", "request_id": "f2"}
                      ).value["drained"] == 0
    assert SDE().flush() == 0


def test_sde_server_json_lines_roundtrip():
    from repro.launch.sde_server import serve_lines
    requests = [
        {"type": "build", "request_id": "b", "synopsis_id": "h",
         "kind": "hyperloglog", "params": {"rse": 0.05},
         "continuous": True},
        {"type": "ingest", "request_id": "i1",
         "stream_ids": [1, 2, 3], "values": [1.0, 1.0, 1.0]},
        {"type": "ingest", "request_id": "i2",
         "stream_ids": [4, 5], "values": [1.0, 1.0]},
        {"type": "adhoc", "request_id": "q", "synopsis_id": "h"},
    ]
    out = io.StringIO()
    n = serve_lines((json.dumps(r) for r in requests),
                    SDE(pipelined=True), out=out)
    assert n == len(requests)
    resp = [json.loads(line) for line in out.getvalue().splitlines()]
    by_id = {r["request_id"]: r for r in resp}
    assert by_id["i1"]["value"]["batch"] == 1
    assert by_id["i2"]["value"]["batch"] == 2
    # both batches' continuous responses surfaced (the ad-hoc query
    # fences), keyed by the acked batch counters, in ingest order
    cq = [r["request_id"] for r in resp if r["request_id"].startswith("cq/")]
    assert cq == ["cq/h/1", "cq/h/2"]
    assert by_id["q"]["ok"]
    # EOF flushes: a trailing un-fenced ingest still emits
    out2 = io.StringIO()
    serve_lines((json.dumps(r) for r in requests[:2]),
                SDE(pipelined=True), out=out2)
    assert any(line.startswith('{"request_id": "cq/h/1"')
               or '"cq/h/1"' in line for line in out2.getvalue().splitlines())
